"""Similarity queries over a q-gram index (the paper's 5 experiment shape).

    PYTHONPATH=src python examples/similarity_search.py

Builds a bigram -> record bitmap index over a synthetic corpus of strings,
then answers approximate-match queries with the Sarawagi-Kirpal threshold
T = |s| + q - 1 - k*q: every record within edit distance k shares >= T
q-grams with the query.  Candidates come out as a bitmap; the final
edit-distance verification runs only on candidates (the paper's screening
pattern).  Compares the bitmap algorithms against the integer-list
competitors on the same query.
"""
import time

import jax.numpy as jnp
import numpy as np

from repro.core import cardinality, from_positions, threshold, to_positions_np
from repro.core import listalgos as LA

Q = 2  # bigrams, as Ferro et al.
rng = np.random.default_rng(0)
ALPHA = "abcdefghijklmnopqrstuvwxyz"


def rand_name():
    n = rng.integers(6, 14)
    return "".join(ALPHA[i] for i in rng.integers(0, 26, n))


def qgrams(s):
    # sentinel padding so #grams = |s| + q - 1 (the paper's T formula assumes it)
    s = "#" * (Q - 1) + s + "$" * (Q - 1)
    return {s[i : i + Q] for i in range(len(s) - Q + 1)}


def edit_distance(a, b):
    dp = list(range(len(b) + 1))
    for i, ca in enumerate(a, 1):
        prev, dp[0] = dp[0], i
        for j, cb in enumerate(b, 1):
            prev, dp[j] = dp[j], min(dp[j] + 1, dp[j - 1] + 1, prev + (ca != cb))
    return dp[-1]


# corpus with planted near-duplicates
corpus = [rand_name() for _ in range(4000)]
target = corpus[123]
corpus.append(target[:-1] + "x")          # distance 1
corpus.append("q" + target[1:])           # distance 1
R = len(corpus)

# build the bigram bitmap index
index: dict[str, list[int]] = {}
for rid, s in enumerate(corpus):
    for g in qgrams(s):
        index.setdefault(g, []).append(rid)
print(f"corpus: {R} records, {len(index)} distinct bigrams")

k = 1  # edit-distance budget
grams = sorted(qgrams(target))
T = max(1, len(target) + Q - 1 - k * Q)
lists = [np.asarray(index.get(g, []), dtype=np.int64) for g in grams]
bm = jnp.stack([from_positions(l, R) for l in lists])
print(f"query {target!r}: N={len(grams)} bigram bitmaps, threshold T={T}")

threshold(bm, T, algorithm="fused").block_until_ready()  # compile (tabulated per N,T)
t0 = time.perf_counter()
cand_bm = threshold(bm, T, algorithm="fused")
cands = to_positions_np(cand_bm)
t_bitmap = time.perf_counter() - t0
print(f"bitmap threshold  : {len(cands)} candidates in {t_bitmap * 1e3:.1f} ms")

t0 = time.perf_counter()
cands_list = LA.dsk(lists, T, R)
t_dsk = time.perf_counter() - t0
print(f"DivideSkip (host) : {len(cands_list)} candidates in {t_dsk * 1e3:.1f} ms")
assert np.array_equal(cands, cands_list)

matches = [rid for rid in cands if edit_distance(target, corpus[rid]) <= k]
print(f"verified matches within distance {k}: {sorted(matches)}")
assert 123 in matches and R - 2 in matches and R - 1 in matches
print("planted near-duplicates found - OK")
