"""Similarity queries over a q-gram index (the paper's 5 experiment shape).

    PYTHONPATH=src python examples/similarity_search.py

Builds a bigram bitmap index over a synthetic corpus with
``repro.search.build_qgram_index``, then answers approximate-match
queries through the planner path: the Sarawagi-Kirpal bound over a
record's DISTINCT bigrams says every record within edit distance k
shares >= T = n_grams - k*q of the query's grams.  Candidates come out
as a bitmap; edit-distance verification runs only on candidates (the
paper's screening pattern), and ``topk`` relaxes T stepwise for
nearest-neighbour queries.

Crucially, T can be <= 0 (short strings, generous k) -- then the filter
is VACUOUS and every record is a candidate.  An earlier version of this
example clamped ``T = max(1, ...)``, silently dropping true matches that
share zero grams with the query; the vacuous demo at the bottom is the
regression this file exists to remember.
"""
import time

import numpy as np

from repro.core import listalgos as LA
from repro.search import build_qgram_index, edit_distance

Q = 2  # bigrams, as Ferro et al.
rng = np.random.default_rng(0)
ALPHA = "abcdefghijklmnopqrstuvwxyz"


def rand_name():
    n = rng.integers(6, 14)
    return "".join(ALPHA[i] for i in rng.integers(0, 26, n))


# corpus with planted near-duplicates
corpus = [rand_name() for _ in range(4000)]
target = corpus[123]
corpus.append(target[:-1] + "x")          # distance 1
corpus.append("q" + target[1:])           # distance 1
corpus.append("qz")                       # shares ZERO bigrams with "zq"
R = len(corpus)

idx = build_qgram_index(corpus, q=Q)
print(f"corpus: {R} records, {len(idx.index.names)} tokenizer columns")

k = 1  # edit-distance budget
cand = idx.candidates(target, k)
print(
    f"query {target!r}: {cand.n_grams} distinct bigrams, threshold T={cand.t}"
)

idx.candidates(target, k)  # warm the compiled-circuit cache
t0 = time.perf_counter()
cand = idx.candidates(target, k)
t_bitmap = time.perf_counter() - t0
print(f"bitmap threshold  : {len(cand)} candidates in {t_bitmap * 1e3:.1f} ms")

# the same T-occurrence query over the paper's integer-list competitor
lists = idx.posting_lists(target)
t0 = time.perf_counter()
cands_list = LA.dsk(lists, cand.t, R)
t_dsk = time.perf_counter() - t0
print(f"DivideSkip (host) : {len(cands_list)} candidates in {t_dsk * 1e3:.1f} ms")
assert np.array_equal(cand.ids, cands_list)

matches = idx.search(target, k)
print(f"verified matches within distance {k}: {sorted(matches.ids.tolist())}")
assert {123, R - 3, R - 2} <= set(matches.ids.tolist())
print("planted near-duplicates found - OK")

# nearest neighbours by adaptive threshold relaxation: starts at the exact
# T for k_edits=0 and relaxes stepwise, verifying only each step's new band
top = idx.topk(target, 3)
print(
    f"top-3 neighbours: ids {top.ids.tolist()} at distances "
    f"{top.distances.tolist()} ({top.relaxations} relaxation steps, "
    f"{top.verified} verifications)"
)
assert top.ids.tolist()[0] == 123 and top.distances.tolist() == [0, 1, 1]

# the vacuous-threshold case the old clamp got wrong: a 2-char query with
# k=3 has T = n_grams - k*q <= 0, so NO record can be excluded -- the
# planted "qz" (distance 2) shares zero bigrams with "zq" and the clamped
# filter would silently drop it
short = "zq"
vac = idx.candidates(short, k=3)
print(
    f"query {short!r} with k=3: T={vac.t} (vacuous={vac.vacuous}) -> "
    f"{len(vac)} candidates"
)
assert vac.vacuous and len(vac) == R, "non-positive T must candidate ALL rows"
hits = idx.search(short, k=3)
assert R - 1 in hits.ids.tolist(), "zero-shared-gram match must be found"
assert all(edit_distance(short, corpus[i]) <= 3 for i in hits.ids.tolist())
print(f"verified {len(hits.ids)} matches within distance 3 - vacuous case OK")
