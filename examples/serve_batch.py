"""Batched serving with continuous batching and bitmap slot tracking.

    PYTHONPATH=src python examples/serve_batch.py [--arch recurrentgemma-2b]

Feeds a stream of variable-length prompts through the slot-pool engine;
slot occupancy is tracked with packed bitmaps (the paper's machinery in the
serving layer).  Prints per-request outputs and throughput.
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serve import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    engine = ServeEngine(cfg, params, batch_slots=args.slots, max_seq=128)
    rng = np.random.default_rng(0)
    pending = [
        Request(rid=i, prompt=rng.integers(1, cfg.vocab, rng.integers(3, 12)).tolist(),
                max_new=args.max_new)
        for i in range(args.requests)
    ]
    print(f"{args.requests} requests -> {args.slots} slots ({args.arch} reduced)")
    t0 = time.time()
    done = engine.run_until_drained(list(pending))
    dt = time.time() - t0
    for r in sorted(done, key=lambda r: r.rid)[:6]:
        print(f"  rid {r.rid:2d}: prompt[{len(r.prompt)}] -> {r.out}")
    toks = sum(len(r.out) for r in done)
    print(f"served {len(done)} requests, {toks} tokens, {engine.step_count} engine steps, "
          f"{toks / dt:.1f} tok/s")
    assert len(done) == args.requests
    print("OK")


if __name__ == "__main__":
    main()
