"""End-to-end training driver (deliverable b): train an LM for a few hundred
steps through the full production stack -- sharded step, checkpointing,
straggler monitor, deterministic data stream.

    PYTHONPATH=src python examples/train_lm.py                 # ~2 min on CPU
    PYTHONPATH=src python examples/train_lm.py --width 768 --layers 12  # ~100M

The default is a CPU-sized qwen3-family model; --width/--layers scale the
same config up to the ~100M class (the code path is identical -- this just
trades wall-clock).  Loss on the synthetic copy-structure stream drops from
~7 to <2 within a few hundred steps.
"""
import argparse
import dataclasses
import sys
import time

import jax

from repro.ckpt import CheckpointManager
from repro.configs import get_config
from repro.data import DataConfig, lm_batch
from repro.ft import StragglerMonitor
from repro.train import OptConfig, TrainConfig, init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--width", type=int, default=128)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--vocab", type=int, default=2048)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args(argv)

    base = get_config("qwen3-1.7b", reduced=True)
    cfg = dataclasses.replace(
        base,
        name=f"qwen3-example-{args.width}x{args.layers}",
        d_model=args.width,
        n_layers=args.layers,
        n_heads=max(4, args.width // 32),
        n_kv_heads=max(2, args.width // 64),
        head_dim=32,
        d_ff=args.width * 3,
        vocab=args.vocab,
    )
    n_params = cfg.param_count()
    print(f"model {cfg.name}: {n_params / 1e6:.1f}M params")

    tc = TrainConfig(
        opt=OptConfig(peak_lr=3e-3, warmup_steps=20, total_steps=args.steps)
    )
    dc = DataConfig(vocab=cfg.vocab, batch=args.batch, seq=args.seq, seed=0)
    state = init_train_state(cfg, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(cfg, tc), donate_argnums=0)
    mgr = CheckpointManager(args.ckpt_dir, keep=2)
    monitor = StragglerMonitor()

    first_loss = None
    t_start = time.time()
    for step in range(args.steps):
        t0 = time.time()
        state, metrics = step_fn(state, lm_batch(dc, step))
        loss = float(metrics["loss"])
        if first_loss is None:
            first_loss = loss
        monitor.record(step, time.time() - t0)
        if step % 25 == 0 or step == args.steps - 1:
            tok_s = args.batch * args.seq / max(time.time() - t0, 1e-9)
            print(f"step {step:4d}  loss {loss:.3f}  lr {float(metrics['lr']):.2e}  "
                  f"{tok_s / 1e3:.1f}k tok/s")
        if (step + 1) % 100 == 0:
            mgr.save(step + 1, state)
    mgr.wait()
    mgr.save(args.steps, state)
    mgr.wait()
    dt = time.time() - t_start
    print(f"trained {args.steps} steps in {dt:.0f}s; "
          f"loss {first_loss:.2f} -> {loss:.2f}; "
          f"checkpoints at {args.ckpt_dir} (latest step {mgr.latest_step()})")
    assert loss < first_loss - 1.0, "loss did not improve"
    print("OK")


if __name__ == "__main__":
    main()
