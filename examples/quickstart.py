"""Quickstart: threshold and symmetric queries over bitmaps.

    PYTHONPATH=src python examples/quickstart.py

The paper's motivating example: stores x products.  Which products are on
sale in at least 2 stores?  In exactly 3?  In 2 to 10?  All answers are
bitmaps, so they compose with further index operations.
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (
    cardinality,
    exactly,
    interval,
    pack,
    plan_threshold,
    threshold,
    to_positions_np,
    unpack,
)

N_STORES, N_PRODUCTS = 12, 10_000
rng = np.random.default_rng(0)

# each store's "on sale" set as one bitmap row
on_sale = rng.random((N_STORES, N_PRODUCTS)) < 0.15
bitmaps = pack(jnp.asarray(on_sale))
print(f"{N_STORES} stores x {N_PRODUCTS} products, "
      f"cardinalities: {np.asarray(cardinality(bitmaps))[:6]}...")

# threshold: on sale in >= 2 stores (theta(2, .)), via the fused kernel
hot = threshold(bitmaps, 2, algorithm="fused")
print(f"on sale in >=2 stores : {int(cardinality(hot)):6d} products")

# the planner picks the paper-recommended algorithm from (N, T, stats)
plan = plan_threshold(N_STORES, 2)
print(f"planner says          : {plan.algorithm} ({plan.rationale})")

# delta function: exactly 3 stores
just3 = exactly(bitmaps, 3, r=N_PRODUCTS)
print(f"in exactly 3 stores   : {int(cardinality(just3)):6d}")

# interval: the paper's "2 to 10 stores" example
mid = interval(bitmaps, 2, 10, r=N_PRODUCTS)
print(f"in 2..10 stores       : {int(cardinality(mid)):6d}")

# results are bitmaps: compose with a further AND (e.g. "and in store 0")
also_store0 = jnp.bitwise_and(hot, bitmaps[0])
print(f">=2 stores AND store 0: {int(cardinality(also_store0)):6d}")

# verify against per-position counts
counts = on_sale.sum(0)
assert (np.asarray(unpack(hot, N_PRODUCTS)) == (counts >= 2)).all()
assert (np.asarray(unpack(just3, N_PRODUCTS)) == (counts == 3)).all()
print("verified against position counts - OK")
print("first few >=2-store products:", to_positions_np(hot)[:8])
