"""Quickstart: composable queries over a bitmap index.

    PYTHONPATH=src python examples/quickstart.py

The paper's motivating example: stores x products, one bitmap per store of
the products it has on sale.  The headline query from the abstract --
"on sale in 2 to 10 stores" -- is one expression; because every result is
again a bitmap, queries compose and feed back in as virtual columns.
"""
import jax.numpy as jnp
import numpy as np

from repro.core.bitmaps import unpack
from repro.query import And, BitmapIndex, Col, Interval, Not, Parity, Threshold

N_STORES, N_PRODUCTS = 12, 10_000
rng = np.random.default_rng(0)

# each store's "on sale" set as one index column; building the index
# tile-classifies every column (the storage engine's build-time work)
on_sale = rng.random((N_STORES, N_PRODUCTS)) < 0.15
idx = BitmapIndex.from_dense(
    jnp.asarray(on_sale), names=[f"store{i}" for i in range(N_STORES)]
)
stats = idx.stats()  # free: computed once when the TileStore was built
print(f"{idx.n} stores x {idx.r} products, "
      f"cardinalities: {stats.cardinalities[:6]}...")
print(f"tile stats: {stats.clean_fraction:.0%} clean tiles, "
      f"{stats.dirty_words} dirty words stored")

# per-container tile census for the abstract's 2..10-stores query: dirty
# tiles live as dense words, sparse position lists or run intervals --
# whichever is cheapest (compressed_words <= the dense dirty pack)
census = idx.store.container_census()
print(f"container census               : {census['dense']} dense / "
      f"{census['sparse']} sparse / {census['run']} run tiles, "
      f"{census['storage_words']} words stored "
      f"(dense pack would be {census['dense_equiv_words']})")

# the abstract's query: on sale in 2 to 10 stores -- with the chosen plan
# and its estimated cost (words touched) from the tile-stats cost model
plan = idx.explain(Interval(2, 10))
print(f"plan for Interval(2, 10)      : {plan.algorithm} "
      f"(~{plan.cost:.0f} words touched; {plan.rationale})")
mid = idx.execute(Interval(2, 10))
print(f"on sale in 2..10 stores       : {idx.count(Interval(2, 10)):6d} products")

# no string algorithm= argument anywhere: the planner picks the backend
plan = idx.explain(Threshold(2))
print(f"planner for Threshold(2)      : {plan.algorithm} ({plan.rationale})")

# queries compose: in 2..10 stores AND NOT in store 0, one compiled circuit
q = And(Interval(2, 10), Not(Col("store0")))
print(f"...and not in store 0         : {idx.count(q):6d}")

# operators build the same trees: & | ~ -
q2 = Interval(2, 10) & ~Threshold(11)
print(f"in 2..10 but never 11+        : {idx.count(q2):6d}")

# independent queries batch into ONE jitted multi-output circuit call
hot, odd, rare = idx.execute_many([Threshold(2), Parity(), Interval(1, 1)])
print(f"threshold/parity/exactly-once : "
      f"{int(unpack(hot, idx.r).sum())} / {int(unpack(odd, idx.r).sum())} / "
      f"{int(unpack(rare, idx.r).sum())}")

# results are bitmaps: feed one back in as a virtual column and keep
# querying (add_column returns a NEW index; the old one stays valid) --
# the result column is itself compressed into the cheapest container
idx = idx.add_column("hot", hot)
promo = idx.execute(And(Col("hot"), Col("store0")))
print(f"hot AND in store 0            : {int(unpack(promo, idx.r).sum()):6d}")
rare = idx.execute(Interval(6, 12))  # a handful of products match
idx = idx.add_column("rare", rare)
c = idx.store.container_census(slots=[idx.names.index("rare")])
print(f"'rare' stored as              : {c['sparse']} sparse / {c['run']} run "
      f"/ {c['dense']} dense tiles ({c['storage_words']} words)")

# sub-queries can even vote inside a threshold: 2 of these 3 criteria
panel = Threshold(2, over=(Col("store0"), Col("store1"), Interval(4, 10)))
print(f"2 of [s0, s1, broadly on sale]: {idx.count(panel):6d}")

# shard the row space (host-sequenced here; pass mesh= on real devices):
# still ONE compiled circuit, but a per-shard plan from each shard's own
# tile statistics -- clean shards skip tiles, dense shards sweep
sidx = idx.shard(n_shards=4)
print(f"sharded plan (4 row shards)   : {sidx.plan(Interval(2, 10)).backends}")
sres = sidx.execute(Interval(2, 10))  # per-shard bitmaps, gather only to print
assert np.array_equal(
    np.asarray(sres.gather()), np.asarray(idx.execute(Interval(2, 10)))
)
print("sharded == unsharded - OK")

# verify against per-position counts
counts = on_sale.sum(0)
assert (np.asarray(unpack(mid, idx.r)) == ((counts >= 2) & (counts <= 10))).all()
assert (np.asarray(unpack(hot, idx.r)) == (counts >= 2)).all()
assert (np.asarray(unpack(promo, idx.r)) == ((counts >= 2) & on_sale[0])).all()
print("verified against position counts - OK")

# -- streaming updates: no rebuilds -----------------------------------------
# the index so far is frozen at build time; production sees sustained
# writes.  StreamingIndex absorbs them as tile deltas and keeps registered
# query results fresh incrementally (repro.stream)
from repro.query import BitmapIndex
from repro.stream import StreamingIndex

stream = StreamingIndex(
    BitmapIndex.from_dense(
        jnp.asarray(on_sale), names=[f"store{i}" for i in range(N_STORES)]
    )
)
stream.materialize("mid", Interval(2, 10))  # the abstract's query, maintained
before = stream.count("mid")

# pick a product on sale in exactly 1 store; ONE store putting it on sale
# moves it into the "2 to 10 stores" band -- the materialized result flips
# without a rebuild, by re-running the circuit over ONE tile
product = int(np.nonzero(counts == 1)[0][0])
store = next(f"store{i}" for i in range(N_STORES) if not on_sale[i, product])
stream.set_bits(store, [product])
after = stream.count("mid")  # incrementally-maintained count: O(1) read
info = stream.view_info("mid")
print(f"product {product} goes on sale in {store}: "
      f"'in 2..10 stores' {before} -> {after} "
      f"({info['tiles_refreshed']} tile refreshed, "
      f"{info['words_touched']} words touched, 0 rebuilds)")
assert after == before + 1
assert stream.delta_stats()["compactions"] == 0  # pure delta, base untouched

# -- persistence: save the index, kill the process state, re-serve ----------
# the durable StreamingIndex logs every mutation batch to a WAL ahead of
# applying it; checkpoint() folds the log into a .bmsnap snapshot.  A new
# process recovers the snapshot as np.memmap views (zero copy -- words
# page in only as queries touch them) and replays the WAL tail, views
# included (repro.persist)
import shutil
import tempfile

workdir = tempfile.mkdtemp(prefix="quickstart_persist_")
stream.attach_durable(workdir)      # snapshot now, WAL from here on
stream.set_bits("store1", [product])  # logged AND applied
live_mid, live_total = stream.count("mid"), stream.count(Threshold(1))

del stream, idx, sidx               # "kill" the in-memory state

from repro.stream import StreamingIndex as _SI  # fresh import, fresh process

revived = _SI.recover(workdir)      # memmap load + WAL replay
print(f"recovered from {workdir}: 'in 2..10 stores' = {revived.count('mid')}"
      f" (view re-registered, WAL tail replayed)")
assert revived.count("mid") == live_mid
assert revived.count(Threshold(1)) == live_total
revived.set_bits("store2", [product])  # the recovered index keeps serving
print("recovered index keeps absorbing writes - OK")

# -- serving: many clients, one coalescing front-end -------------------------
# the abstract's query under load: concurrent clients submit to a
# QueryServer, which collapses identical in-flight requests to ONE
# execution, rides shape-bucketed micro-batches through execute_many, and
# caches results keyed on per-column versions -- a write invalidates
# exactly the entries reading a touched column (repro.serve)
import threading

from repro.serve import QueryServer

with QueryServer(revived, window=0.001) as server:
    requests = [Interval(2, 10), Interval(2, 10) & ~Col("store0"), Threshold(11)]

    def client():
        for f in [server.submit(q) for q in requests * 3]:
            f.result(30)

    clients = [threading.Thread(target=client) for _ in range(8)]
    for t in clients:
        t.start()
    for t in clients:
        t.join()
    served = server.info()
    print(f"served 8 clients x {len(requests) * 3} requests: "
          f"{served['executed']} executions "
          f"({served['cache_hits']} cache hits, {served['dedup_hits']} deduped, "
          f"{served['batches']} micro-batches)")
    assert served["served"] == 8 * len(requests) * 3
    assert served["executed"] <= len(requests) * 2  # dedup + cache did the rest

    baseline = np.asarray(server.submit(Interval(2, 10)).result(30))
    revived.set_bits("store3", [product])  # invalidates only readers of store3
    fresh = np.asarray(server.submit(Interval(2, 10)).result(30))
    print(f"write to store3 invalidated {server.info()['invalidations']} "
          f"cache entries; resubmit observes the new bits "
          f"({'changed' if not np.array_equal(baseline, fresh) else 'same count band'})")
shutil.rmtree(workdir)
