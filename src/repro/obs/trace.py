"""Per-query trace spans.

A span tree covers one request end to end: plan (memo hit/miss,
candidates, predicted words/us), compile (circuit-cache hit/miss),
dispatch (engine, launches, tiles by case), decode (words gathered by
container kind).  Every span carries *predicted* cost attributes next
to *measured* wall time and words, so predicted-vs-realised drift is a
first-class queryable quantity rather than something reconstructed from
logs.

Spans parent through a contextvar, so instrumented layers never thread
a span argument through call signatures -- ``span("compile")`` inside a
running ``span("execute")`` nests automatically, including across the
serving front-end's batcher thread (each thread/context gets its own
stack).  When tracing is disabled, ``span()`` returns a shared no-op
singleton: one branch, zero allocation.
"""
from __future__ import annotations

import time
from contextvars import ContextVar

enabled = False  # toggled by repro.obs.enable()/disable()

_CURRENT: ContextVar["Span | None"] = ContextVar("repro_obs_span", default=None)
_ROOT_LISTENERS: list = []


class Span:
    __slots__ = ("name", "attrs", "children", "t0", "wall_s", "_token")

    def __init__(self, name: str, attrs: dict | None = None) -> None:
        self.name = name
        self.attrs = dict(attrs) if attrs else {}
        self.children: list[Span] = []
        self.t0 = 0.0
        self.wall_s = 0.0
        self._token = None

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        parent = _CURRENT.get()
        if parent is not None:
            parent.children.append(self)
        self._token = _CURRENT.set(self)
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        self.wall_s = time.perf_counter() - self.t0
        _CURRENT.reset(self._token)
        if _CURRENT.get() is None:
            for fn in _ROOT_LISTENERS:
                fn(self)

    def find(self, name: str) -> "Span | None":
        """Depth-first search for the first descendant span named *name*."""
        for c in self.children:
            if c.name == name:
                return c
            hit = c.find(name)
            if hit is not None:
                return hit
        return None

    def iter(self):
        yield self
        for c in self.children:
            yield from c.iter()

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "wall_us": self.wall_s * 1e6,
            "attrs": dict(self.attrs),
            "children": [c.to_dict() for c in self.children],
        }

    def format(self, indent: int = 0) -> str:
        """Human-readable span tree (quickstart/docs surface)."""
        pad = "  " * indent
        attrs = " ".join(f"{k}={v}" for k, v in self.attrs.items())
        lines = [f"{pad}{self.name} [{self.wall_s * 1e6:.0f}us] {attrs}".rstrip()]
        for c in self.children:
            lines.append(c.format(indent + 1))
        return "\n".join(lines)


class _NullSpan:
    """Disabled-mode span: every operation is a no-op on a singleton."""

    __slots__ = ()
    attrs: dict = {}
    children: list = []
    wall_s = 0.0
    name = ""

    def set(self, **attrs) -> "_NullSpan":
        return self

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass

    def find(self, name: str):
        return None

    def to_dict(self) -> dict:
        return {}


NULL_SPAN = _NullSpan()


def span(name: str, **attrs):
    """Open a span (context manager).  No-op singleton when disabled."""
    if not enabled:
        return NULL_SPAN
    return Span(name, attrs)


def current_span():
    """The innermost open span in this context (NULL_SPAN when none/off)."""
    if not enabled:
        return NULL_SPAN
    return _CURRENT.get() or NULL_SPAN


def add_root_listener(fn) -> None:
    """Call *fn(root_span)* whenever a root span completes."""
    if fn not in _ROOT_LISTENERS:
        _ROOT_LISTENERS.append(fn)


def merge_span_trees(name: str, roots: list) -> Span:
    """Fold per-shard span trees under one synthetic parent (dist path)."""
    out = Span(name)
    out.children = [r for r in roots if isinstance(r, Span)]
    out.wall_s = max((r.wall_s for r in out.children), default=0.0)
    return out
