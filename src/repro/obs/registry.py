"""Process-wide metrics registry: counters, gauges, histograms.

One schema for every subsystem's accounting (query planner, executors,
kernels, serving front-end, streaming, persistence tiers).  Three design
constraints drive the implementation:

* **Exact cross-shard / cross-thread merging.**  Every histogram shares
  the same FIXED log-spaced bucket edges (``BUCKET_EDGES``), so merging
  two histograms is exact integer addition of bucket counts -- order
  and grouping never change the result (associative + commutative),
  which is what lets ``repro.dist`` fold per-shard observations into
  one process view without approximation.
* **Thread safety.**  The serving front-end increments from a batcher
  thread while clients read; a single registry lock guards every
  mutation and snapshot.
* **Near-zero disabled cost.**  When ``registry.enabled`` is False every
  ``inc``/``set``/``observe`` is one attribute load and a branch -- no
  lock, no allocation, no mutation (tests assert *zero* registry
  mutations in disabled mode).

Exporters: Prometheus text exposition format (``export_prometheus``)
and JSONL (``export_jsonl``), one line per metric family.
"""
from __future__ import annotations

import json
import math
import threading
from bisect import bisect_left
from typing import Iterable

# Fixed log-spaced bucket edges: 4 buckets per decade, 1e-7 .. 1e9.
# Seconds-scale latencies (100ns .. hours) and word counts (1 .. 1e9)
# both land inside the span; everything else folds into the +Inf bucket.
BUCKETS_PER_DECADE = 4
_LO_DECADE, _HI_DECADE = -7, 9
BUCKET_EDGES: tuple[float, ...] = tuple(
    10.0 ** (k / BUCKETS_PER_DECADE)
    for k in range(
        _LO_DECADE * BUCKETS_PER_DECADE, _HI_DECADE * BUCKETS_PER_DECADE + 1
    )
)


def _label_key(label_names: tuple[str, ...], labels: dict) -> tuple:
    # hot path: build the key directly; a missing/extra label falls
    # through to the error (no set allocations per observation)
    try:
        key = tuple(str(labels[k]) for k in label_names)
    except KeyError:
        key = None
    if key is None or len(labels) != len(label_names):
        raise ValueError(
            f"expected labels {label_names}, got {tuple(labels)}"
        )
    return key


def _fmt_labels(label_names: tuple[str, ...], key: tuple) -> str:
    if not label_names:
        return ""
    inner = ",".join(
        f'{n}="{v}"' for n, v in zip(label_names, key)
    )
    return "{" + inner + "}"


def _fmt_value(v: float) -> str:
    if v == math.inf:
        return "+Inf"
    f = float(v)
    return str(int(f)) if f == int(f) else repr(f)


class HistogramState:
    """Bucket counts + sum/count for one labelled histogram series."""

    __slots__ = ("counts", "sum", "count")

    def __init__(self) -> None:
        self.counts = [0] * (len(BUCKET_EDGES) + 1)  # +1: the +Inf bucket
        self.sum = 0.0
        self.count = 0

    def observe(self, value: float) -> None:
        self.counts[bisect_left(BUCKET_EDGES, value)] += 1
        self.sum += value
        self.count += 1

    def merge(self, other: "HistogramState") -> "HistogramState":
        """Exact merge: same fixed edges everywhere, so bucket counts add."""
        out = HistogramState()
        out.counts = [a + b for a, b in zip(self.counts, other.counts)]
        out.sum = self.sum + other.sum
        out.count = self.count + other.count
        return out

    def quantile(self, q: float) -> float:
        """Estimate the q-quantile (0..1) from bucket counts.

        Log-interpolates inside the winning bucket; the underflow bucket
        reports its upper edge and the overflow bucket the last edge (a
        finite lower bound -- callers asserting finiteness rely on it).
        """
        if self.count == 0:
            return math.nan
        rank = q * self.count
        cum = 0
        for i, c in enumerate(self.counts):
            cum += c
            if cum >= rank and c:
                if i >= len(BUCKET_EDGES):
                    return BUCKET_EDGES[-1]
                if i == 0:
                    return BUCKET_EDGES[0]
                lo, hi = BUCKET_EDGES[i - 1], BUCKET_EDGES[i]
                frac = (rank - (cum - c)) / c
                return lo * (hi / lo) ** max(0.0, min(1.0, frac))
        return BUCKET_EDGES[-1]

    def to_dict(self) -> dict:
        return {"counts": list(self.counts), "sum": self.sum, "count": self.count}

    @classmethod
    def from_dict(cls, d: dict) -> "HistogramState":
        out = cls()
        out.counts = list(d["counts"])
        out.sum = float(d["sum"])
        out.count = int(d["count"])
        return out


class _Metric:
    __slots__ = ("name", "help", "label_names", "_reg", "_series")

    def __init__(self, reg: "MetricsRegistry", name: str, help: str,
                 label_names: tuple[str, ...]) -> None:
        self._reg = reg
        self.name = name
        self.help = help
        self.label_names = tuple(label_names)
        self._series: dict = {}

    def _key(self, labels: dict) -> tuple:
        return _label_key(self.label_names, labels)

    def series(self) -> dict:
        with self._reg._lock:
            return dict(self._series)


class _BoundCounter:
    """A counter series with its label key pre-bound.

    Hot sites that always increment the same labelled series (kernel
    launch counters) pay one enabled check + lock per inc instead of
    rebuilding the label key each call.  Holds only the key, never the
    value, so ``MetricsRegistry.reset`` stays authoritative.
    """

    __slots__ = ("_metric", "_key")

    def __init__(self, metric: "Counter", key: tuple) -> None:
        self._metric = metric
        self._key = key

    def inc(self, n: float = 1) -> None:
        m = self._metric
        reg = m._reg
        if not reg.enabled:
            return
        with reg._lock:
            m._series[self._key] = m._series.get(self._key, 0) + n


class Counter(_Metric):
    kind = "counter"

    def inc(self, n: float = 1, **labels) -> None:
        if not self._reg.enabled:
            return
        key = self._key(labels)
        with self._reg._lock:
            self._series[key] = self._series.get(key, 0) + n

    def bind(self, **labels) -> _BoundCounter:
        """Pre-resolve one labelled series for repeated hot-path incs."""
        return _BoundCounter(self, self._key(labels))

    def value(self, **labels) -> float:
        with self._reg._lock:
            return self._series.get(self._key(labels), 0)


class Gauge(_Metric):
    kind = "gauge"

    def set(self, v: float, **labels) -> None:
        if not self._reg.enabled:
            return
        key = self._key(labels)
        with self._reg._lock:
            self._series[key] = v

    def inc(self, n: float = 1, **labels) -> None:
        if not self._reg.enabled:
            return
        key = self._key(labels)
        with self._reg._lock:
            self._series[key] = self._series.get(key, 0) + n

    def value(self, **labels) -> float:
        with self._reg._lock:
            return self._series.get(self._key(labels), 0)


class Histogram(_Metric):
    kind = "histogram"

    def observe(self, v: float, **labels) -> None:
        if not self._reg.enabled:
            return
        key = self._key(labels)
        with self._reg._lock:
            state = self._series.get(key)
            if state is None:
                state = self._series[key] = HistogramState()
            state.observe(v)

    def state(self, **labels) -> HistogramState:
        with self._reg._lock:
            return self._series.get(self._key(labels)) or HistogramState()

    def merged(self) -> HistogramState:
        """Exact merge of every labelled series into one state."""
        out = HistogramState()
        with self._reg._lock:
            for s in self._series.values():
                out = out.merge(s)
        return out

    def quantile(self, q: float, **labels) -> float:
        if labels or not self.label_names:
            return self.state(**labels).quantile(q)
        return self.merged().quantile(q)


class MetricsRegistry:
    """A named set of metric families behind one lock.

    The process-wide default instance (``repro.obs.REGISTRY``) starts
    *disabled*; subsystems that need always-on accounting (the serving
    front-end's ``info()`` counters) hold their own always-enabled
    instance and mirror into the global one.
    """

    def __init__(self, enabled: bool = False) -> None:
        self.enabled = enabled
        self._lock = threading.RLock()
        self._metrics: dict[str, _Metric] = {}

    def _get_or_create(self, cls, name: str, help: str,
                       label_names: Iterable[str]) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = cls(self, name, help, tuple(label_names))
            elif not isinstance(m, cls):
                raise TypeError(f"metric {name!r} already registered as {m.kind}")
            return m

    def counter(self, name: str, help: str = "", labels: Iterable[str] = ()) -> Counter:
        return self._get_or_create(Counter, name, help, labels)

    def gauge(self, name: str, help: str = "", labels: Iterable[str] = ()) -> Gauge:
        return self._get_or_create(Gauge, name, help, labels)

    def histogram(self, name: str, help: str = "",
                  labels: Iterable[str] = ()) -> Histogram:
        return self._get_or_create(Histogram, name, help, labels)

    def metrics(self) -> list[_Metric]:
        with self._lock:
            return list(self._metrics.values())

    def reset(self) -> None:
        """Zero every series (families stay registered)."""
        with self._lock:
            for m in self._metrics.values():
                m._series.clear()

    def snapshot(self) -> dict:
        """Plain-dict view of every family (for dump / tests)."""
        out = {}
        with self._lock:
            for m in self._metrics.values():
                samples = {}
                for key, v in m._series.items():
                    label = ",".join(key) if key else ""
                    samples[label] = (
                        v.to_dict() if isinstance(v, HistogramState) else v
                    )
                out[m.name] = {
                    "type": m.kind,
                    "help": m.help,
                    "labels": list(m.label_names),
                    "samples": samples,
                }
        return out

    # -- exporters ---------------------------------------------------------

    def export_prometheus(self) -> str:
        """Prometheus text exposition format (version 0.0.4)."""
        lines: list[str] = []
        with self._lock:
            for m in self._metrics.values():
                lines.append(f"# HELP {m.name} {m.help}")
                lines.append(f"# TYPE {m.name} {m.kind}")
                if isinstance(m, Histogram):
                    for key, st in m._series.items():
                        base = list(zip(m.label_names, key))
                        cum = 0
                        for edge, c in zip(
                            list(BUCKET_EDGES) + [math.inf], st.counts
                        ):
                            cum += c
                            lbl = "{" + ",".join(
                                f'{n}="{v}"' for n, v in
                                base + [("le", _fmt_value(edge))]
                            ) + "}"
                            lines.append(f"{m.name}_bucket{lbl} {cum}")
                        sfx = _fmt_labels(m.label_names, key)
                        lines.append(f"{m.name}_sum{sfx} {st.sum!r}")
                        lines.append(f"{m.name}_count{sfx} {st.count}")
                else:
                    for key, v in m._series.items():
                        sfx = _fmt_labels(m.label_names, key)
                        lines.append(f"{m.name}{sfx} {_fmt_value(v)}")
        return "\n".join(lines) + "\n"

    def export_jsonl(self) -> str:
        """One JSON object per metric family, one per line."""
        snap = self.snapshot()
        return "\n".join(
            json.dumps({"name": name, **fam}, sort_keys=True)
            for name, fam in snap.items()
        ) + ("\n" if snap else "")


def lint_prometheus(text: str) -> list[str]:
    """promtool-style pure-Python format check; returns problem strings.

    Checks: every sample's metric name was declared by a # TYPE line,
    HELP/TYPE precede samples, names are legal, label syntax parses,
    values parse as floats, histogram buckets are cumulative and end in
    an le="+Inf" bucket matching _count.
    """
    import re

    problems: list[str] = []
    typed: dict[str, str] = {}
    name_re = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
    sample_re = re.compile(
        r"^([a-zA-Z_:][a-zA-Z0-9_:]*)(\{([^}]*)\})?\s+(\S+)$"
    )
    label_re = re.compile(r'^[a-zA-Z_][a-zA-Z0-9_]*="[^"]*"$')
    buckets: dict[tuple, list[tuple[float, float]]] = {}
    counts: dict[tuple, float] = {}
    for ln, line in enumerate(text.splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# HELP "):
            parts = line.split(None, 3)
            if len(parts) < 3 or not name_re.match(parts[2]):
                problems.append(f"line {ln}: malformed HELP")
            continue
        if line.startswith("# TYPE "):
            parts = line.split()
            if len(parts) != 4 or parts[3] not in (
                "counter", "gauge", "histogram", "summary", "untyped"
            ):
                problems.append(f"line {ln}: malformed TYPE")
            else:
                typed[parts[2]] = parts[3]
            continue
        if line.startswith("#"):
            continue
        m = sample_re.match(line)
        if not m:
            problems.append(f"line {ln}: unparseable sample {line!r}")
            continue
        name, _, labelstr, value = m.groups()
        base = name
        for sfx in ("_bucket", "_sum", "_count"):
            if name.endswith(sfx) and name[: -len(sfx)] in typed:
                base = name[: -len(sfx)]
        if base not in typed:
            problems.append(f"line {ln}: sample {name!r} missing # TYPE")
        labels = {}
        if labelstr:
            for pair in labelstr.split(","):
                if not label_re.match(pair):
                    problems.append(f"line {ln}: bad label {pair!r}")
                else:
                    k, v = pair.split("=", 1)
                    labels[k] = v.strip('"')
        try:
            fval = float(value)
        except ValueError:
            problems.append(f"line {ln}: bad value {value!r}")
            continue
        if name.endswith("_bucket") and typed.get(base) == "histogram":
            le = labels.get("le")
            if le is None:
                problems.append(f"line {ln}: bucket missing le label")
            else:
                key = (base,) + tuple(
                    sorted((k, v) for k, v in labels.items() if k != "le")
                )
                buckets.setdefault(key, []).append((float(le), fval))
        if name.endswith("_count") and typed.get(base) == "histogram":
            counts[(base,) + tuple(sorted(labels.items()))] = fval
    for key, bl in buckets.items():
        vals = [c for _, c in bl]
        if vals != sorted(vals):
            problems.append(f"{key[0]}: bucket counts not cumulative")
        if not bl or bl[-1][0] != math.inf:
            problems.append(f"{key[0]}: missing le=+Inf bucket")
        elif key in counts and counts[key] != bl[-1][1]:
            problems.append(f"{key[0]}: +Inf bucket != _count")
    return problems
