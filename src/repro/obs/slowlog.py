"""Slow-query log: threshold-gated ring buffer of completed span trees.

Any root span whose wall time crosses the threshold is recorded (plan
attributes + the full span tree as JSON-ready dicts) into a bounded
deque, so production incidents leave evidence without unbounded memory.
"""
from __future__ import annotations

import threading
import time
from collections import deque


class SlowQueryLog:
    def __init__(self, threshold_s: float = 0.050, capacity: int = 128) -> None:
        self.threshold_s = threshold_s
        self._entries: deque = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self.dropped = 0  # recorded past capacity (ring overwrote)

    def set_threshold(self, threshold_s: float) -> None:
        self.threshold_s = threshold_s

    def maybe_record(self, root_span) -> bool:
        if root_span.wall_s < self.threshold_s:
            return False
        plan = root_span.find("plan")
        entry = {
            "ts": time.time(),
            "name": root_span.name,
            "wall_us": root_span.wall_s * 1e6,
            "plan": dict(plan.attrs) if plan is not None else dict(root_span.attrs),
            "span": root_span.to_dict(),
        }
        with self._lock:
            if len(self._entries) == self._entries.maxlen:
                self.dropped += 1
            self._entries.append(entry)
        return True

    def entries(self) -> list[dict]:
        with self._lock:
            return list(self._entries)

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self.dropped = 0
