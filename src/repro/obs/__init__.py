"""repro.obs -- unified observability: metrics, trace spans, drift, slow log.

One layer every subsystem reports into (the paper's words-touched cost
accounting made operational):

* ``REGISTRY`` -- process-wide :class:`MetricsRegistry` (counters,
  gauges, histograms with fixed log-spaced bucket edges so cross-shard
  merges are exact).  Starts **disabled**: every instrumented hot path
  costs one branch until ``enable()`` is called.
* ``span()`` -- per-query trace spans (plan / compile / dispatch /
  decode), each carrying predicted cost next to measured wall time and
  words.
* ``record_drift()`` -- the predicted-vs-realised words ratio as a
  first-class metric feeding the calibration feedback story.
* ``SLOW_QUERIES`` -- threshold-gated ring buffer of slow span trees.
* ``dump()`` / ``export_prometheus()`` / ``export_jsonl()`` -- snapshot
  surfaces (also ``python benchmarks/run.py obs``).

Typical production setup::

    import repro.obs as obs
    obs.enable(slow_query_threshold_s=0.050)
    ... serve traffic ...
    print(obs.export_prometheus())
    tree = obs.last_trace()          # most recent request's span tree
    print(tree.format())
"""
from __future__ import annotations

import json

from repro.obs import trace as trace
from repro.obs.registry import (
    BUCKET_EDGES,
    Counter,
    Gauge,
    Histogram,
    HistogramState,
    MetricsRegistry,
    lint_prometheus,
)
from repro.obs.slowlog import SlowQueryLog
from repro.obs.trace import (
    NULL_SPAN,
    Span,
    current_span,
    merge_span_trees,
    span,
)

REGISTRY = MetricsRegistry(enabled=False)
SLOW_QUERIES = SlowQueryLog()

_LAST_TRACE: list = [None]

# Drift accounting: predicted words (plan cost model) vs measured words
# (executor ExecInfo) per backend.  The ratio histogram makes systematic
# model error visible; its per-series count IS the sample counter.
DRIFT_RATIO = REGISTRY.histogram(
    "repro_calibration_drift_ratio",
    "measured_words / predicted_words per query", ("backend",),
)
QUERY_WALL = REGISTRY.histogram(
    "repro_query_wall_seconds", "End-to-end query wall time", ("backend",),
)
QUERY_WORDS = REGISTRY.histogram(
    "repro_query_words_touched", "Measured words touched per query", ("backend",),
)

#: per-backend (wall, words, ratio) HistogramStates, cached so the hot
#: :func:`record_drift` takes the registry lock once per query instead of
#: once per family (cleared by :func:`reset` alongside the series).
_DRIFT_STATES: dict = {}


def _on_root(root: Span) -> None:
    _LAST_TRACE[0] = root
    SLOW_QUERIES.maybe_record(root)


trace.add_root_listener(_on_root)


def enable(slow_query_threshold_s: float | None = None) -> None:
    """Turn on metrics + tracing (and optionally set the slow-query bar)."""
    REGISTRY.enabled = True
    trace.enabled = True
    if slow_query_threshold_s is not None:
        SLOW_QUERIES.set_threshold(slow_query_threshold_s)


def disable() -> None:
    REGISTRY.enabled = False
    trace.enabled = False


def enabled() -> bool:
    return REGISTRY.enabled


def reset() -> None:
    """Zero metrics, clear the slow log and last trace (tests/benches)."""
    REGISTRY.reset()
    _DRIFT_STATES.clear()  # cached states died with their series
    SLOW_QUERIES.clear()
    _LAST_TRACE[0] = None


def counter(name: str, help: str = "", labels=()) -> Counter:
    return REGISTRY.counter(name, help, labels)


def gauge(name: str, help: str = "", labels=()) -> Gauge:
    return REGISTRY.gauge(name, help, labels)


def histogram(name: str, help: str = "", labels=()) -> Histogram:
    return REGISTRY.histogram(name, help, labels)


def record_drift(backend: str, predicted_words: float | None,
                 measured_words: float, wall_s: float) -> None:
    """One predicted-vs-realised observation (no-op when disabled)."""
    if not REGISTRY.enabled:
        return
    states = _DRIFT_STATES.get(backend)
    lock = REGISTRY._lock
    if states is None:
        key = (str(backend),)
        with lock:
            states = _DRIFT_STATES[backend] = tuple(
                fam._series.setdefault(key, HistogramState())
                for fam in (QUERY_WALL, QUERY_WORDS, DRIFT_RATIO)
            )
    wall_st, words_st, ratio_st = states
    with lock:
        wall_st.observe(wall_s)
        words_st.observe(measured_words)
        if predicted_words and predicted_words > 0:
            ratio_st.observe(measured_words / predicted_words)


def drift_samples() -> int:
    """Total predicted-vs-measured observations across backends."""
    return int(DRIFT_RATIO.merged().count)


def last_trace() -> Span | None:
    """The most recent completed root span tree (None if tracing off)."""
    return _LAST_TRACE[0]


def export_prometheus() -> str:
    return REGISTRY.export_prometheus()


def export_jsonl() -> str:
    return REGISTRY.export_jsonl()


def dump() -> dict:
    """One JSON-ready snapshot of the whole observability surface."""
    last = _LAST_TRACE[0]
    ratio = DRIFT_RATIO.merged()
    return {
        "enabled": REGISTRY.enabled,
        "metrics": REGISTRY.snapshot(),
        "drift": {
            "samples": drift_samples(),
            "ratio_p50": ratio.quantile(0.5),
            "ratio_p95": ratio.quantile(0.95),
        },
        "slow_queries": SLOW_QUERIES.entries(),
        "slow_query_threshold_s": SLOW_QUERIES.threshold_s,
        "last_trace": last.to_dict() if last is not None else None,
    }


def dump_json(indent: int = 2) -> str:
    return json.dumps(dump(), indent=indent, default=str)


__all__ = [
    "BUCKET_EDGES", "Counter", "DRIFT_RATIO", "Gauge", "Histogram",
    "HistogramState", "MetricsRegistry", "NULL_SPAN", "REGISTRY",
    "SLOW_QUERIES", "Span", "SlowQueryLog", "counter", "current_span",
    "disable", "drift_samples", "dump", "dump_json", "enable", "enabled",
    "export_jsonl", "export_prometheus", "gauge", "histogram",
    "last_trace", "lint_prometheus", "merge_span_trees", "record_drift",
    "reset", "span",
]
