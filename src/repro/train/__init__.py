from .optimizer import OptConfig, apply_updates, init_opt_state, schedule
from .step import TrainConfig, init_train_state, make_eval_step, make_loss_fn, make_train_step
