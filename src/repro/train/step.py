"""Train-step builder: loss, grads, microbatch accumulation, AdamW update.

The returned step is pure (state, batch) -> (state, metrics) and is jitted
by the caller with shardings + donation (see launch/train.py, launch/dryrun.py).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import chunked_ce_loss, forward

from .optimizer import OptConfig, apply_updates, init_opt_state


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    opt: OptConfig = OptConfig()
    remat: bool = False
    remat_policy: str = "full"  # full | dots (jax.checkpoint_policies.checkpoint_dots)
    microbatches: int = 1
    aux_coeff: float = 0.01
    loss_chunk: int = 1024


def init_train_state(model_cfg: ModelConfig, key, param_dtype=jnp.float32):
    from repro.models import init_params

    params = init_params(model_cfg, key, param_dtype)
    return {"params": params, "opt": init_opt_state(params)}


def make_loss_fn(model_cfg: ModelConfig, train_cfg: TrainConfig):
    def loss_fn(params, batch):
        h, _, aux = forward(
            params, model_cfg, batch, mode="train", remat=train_cfg.remat,
            remat_policy=train_cfg.remat_policy,
        )
        mask = batch.get("mask")
        loss = chunked_ce_loss(
            params, model_cfg, h, batch["labels"], mask, chunk=train_cfg.loss_chunk
        )
        total = loss + train_cfg.aux_coeff * aux
        return total, {"loss": loss, "aux_loss": aux}

    return loss_fn


def make_train_step(model_cfg: ModelConfig, train_cfg: TrainConfig):
    loss_fn = make_loss_fn(model_cfg, train_cfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def single(params, batch):
        (total, metrics), grads = grad_fn(params, batch)
        return grads, {**metrics, "total_loss": total}

    def accumulate(params, batch):
        """Split the global batch into microbatches and scan-accumulate.

        XLA overlaps microbatch i+1's compute with microbatch i's gradient
        reduce-scatter (the standard comm/compute overlap trick).
        """
        m = train_cfg.microbatches

        def resh(x):
            return x.reshape((m, x.shape[0] // m) + x.shape[1:])

        micro = jax.tree.map(resh, batch)

        def body(carry, mb):
            acc, met_acc = carry
            grads, metrics = single(params, mb)
            acc = jax.tree.map(jnp.add, acc, grads)
            met_acc = jax.tree.map(jnp.add, met_acc, metrics)
            return (acc, met_acc), 0

        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        zero_m = {"loss": 0.0, "aux_loss": 0.0, "total_loss": 0.0}
        (grads, mets), _ = jax.lax.scan(body, (zero_g, zero_m), micro)
        inv = 1.0 / m
        return jax.tree.map(lambda g: g * inv, grads), jax.tree.map(lambda x: x * inv, mets)

    def train_step(state, batch):
        if train_cfg.microbatches > 1:
            grads, metrics = accumulate(state["params"], batch)
        else:
            grads, metrics = single(state["params"], batch)
        new_params, new_opt, om = apply_updates(
            state["params"], grads, state["opt"], train_cfg.opt
        )
        return {"params": new_params, "opt": new_opt}, {**metrics, **om}

    return train_step


def make_eval_step(model_cfg: ModelConfig, train_cfg: TrainConfig):
    loss_fn = make_loss_fn(model_cfg, train_cfg)

    def eval_step(params, batch):
        _, metrics = loss_fn(params, batch)
        return metrics

    return eval_step
