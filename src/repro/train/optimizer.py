"""AdamW + cosine schedule + global-norm clipping (no external deps).

Optimizer state is a pytree shaped like the params (m, v) plus a step
counter, so it shards identically to the params under FSDP (ZeRO-3 for
free: the optimizer state inherits the parameter PartitionSpec).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = cfg.peak_lr * step / jnp.maximum(cfg.warmup_steps, 1)
    frac = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * frac))
    return jnp.where(step < cfg.warmup_steps, warm, cfg.peak_lr * cos)


def init_opt_state(params) -> dict:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {
        "m": zeros,
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def apply_updates(params, grads, opt_state, cfg: OptConfig):
    """One AdamW step; returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = schedule(cfg, step)
    bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
