"""Loop-aware HLO accounting.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE, so a
48-layer scan-over-layers model under-reports FLOPs and collective bytes by
~48x.  This module parses the post-optimisation HLO text, builds the
computation call graph (while bodies with static trip counts extracted from
their condition computations, fusions, calls), and accumulates with loop
multipliers:

  * dot FLOPs: 2 x |output| x contraction size per ``dot`` op
  * collective bytes by kind (all-reduce / all-gather / reduce-scatter /
    all-to-all / collective-permute), output-shape sized
  * an HBM-traffic proxy: sum of output bytes x 2 over non-trivial ops

Elementwise FLOPs are not counted (dots dominate the archs here; the
rglru/rwkv elementwise recurrences are noted as undercounted in
EXPERIMENTS.md).  All numbers are per device (the module is partitioned).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "f8e4m3fn": 1, "f8e5m2": 1,
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_TRIVIAL = ("parameter", "constant", "get-tuple-element", "tuple", "bitcast",
            "after-all", "iota")

_SHAPE_RE = re.compile(r"(\w+)\[([0-9,]*)\]")
_OP_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(\(?[^=]*?\)?)\s*([\w\-]+)\(")
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")


def _split_params(params: str) -> list[str]:
    """Split a parameter list on top-level commas (tuple types nest parens)."""
    out, depth, cur = [], 0, []
    for ch in params:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            out.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        out.append("".join(cur))
    return out


def _shape_info(sig: str):
    """All (dtype, dims) in a type signature; returns list and total bytes."""
    shapes = []
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        shapes.append((dt, dims, n))
    byts = sum(n * _DTYPE_BYTES[dt] for dt, _, n in shapes)
    return shapes, byts


class HloModule:
    def __init__(self, text: str):
        self.comps: dict[str, list[str]] = {}
        self.entry: str | None = None
        cur, buf = None, []
        for line in text.splitlines():
            m = _COMP_RE.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = m.group(1)
                self.comps[cur] = buf = [line]
                if line.strip().startswith("ENTRY"):
                    self.entry = cur
            elif cur is not None:
                buf.append(line)
                if line.strip() == "}":
                    cur = None
        if self.entry is None and self.comps:
            # entry is typically the last computation in the dump
            self.entry = list(self.comps)[-1]
        self._shapes_cache: dict[str, dict[str, str]] = {}

    # -- per-computation symbol table -----------------------------------
    def shapes(self, comp: str) -> dict[str, str]:
        if comp in self._shapes_cache:
            return self._shapes_cache[comp]
        table: dict[str, str] = {}
        lines = self.comps[comp]
        # parameters from the signature
        m = _COMP_RE.match(lines[0].strip().removeprefix("ENTRY "))
        if m:
            for part in _split_params(m.group(2)):
                part = part.strip()
                if ":" in part:
                    nm, ty = part.split(":", 1)
                    table[nm.strip().lstrip("%")] = ty.strip()
        for line in lines[1:]:
            om = _OP_RE.match(line)
            if om:
                table[om.group(1)] = om.group(2)
        self._shapes_cache[comp] = table
        return table

    def _trip_count(self, cond_comp: str) -> int:
        """Largest s32 constant in the condition computation (+fusions)."""
        best = 1
        seen = {cond_comp}
        stack = [cond_comp]
        while stack:
            c = stack.pop()
            for line in self.comps.get(c, []):
                for m in re.finditer(r"constant\((\d+)\)", line):
                    best = max(best, int(m.group(1)))
                cm = re.search(r"calls=%?([\w.\-]+)", line)
                if cm and cm.group(1) not in seen:
                    seen.add(cm.group(1))
                    stack.append(cm.group(1))
        return best

    # -- accounting -------------------------------------------------------
    def _edges(self) -> list[tuple[str, str, int]]:
        """(caller, callee, factor) edges of the computation call graph."""
        edges = []
        self.fusion_bodies: set[str] = set()
        for comp, lines in self.comps.items():
            for line in lines:
                om = _OP_RE.match(line)
                if om and om.group(3) in ("fusion", "reduce", "map", "sort",
                                          "reduce-window", "scatter", "select-and-scatter"):
                    fm = re.search(r"(?:calls|to_apply)=%?([\w.\-]+)", line)
                    if fm:
                        self.fusion_bodies.add(fm.group(1))
                wm = re.search(r"while\(.*condition=%?([\w.\-]+), body=%?([\w.\-]+)",
                               line)
                if wm:
                    cond, body = wm.groups()
                    trips = self._trip_count(cond)
                    edges.append((comp, body, trips))
                    edges.append((comp, cond, trips + 1))
                    continue
                for pat in (r"calls=%?([\w.\-]+)", r"to_apply=%?([\w.\-]+)",
                            r"true_computation=%?([\w.\-]+)",
                            r"false_computation=%?([\w.\-]+)",
                            r"branch_computations=\{%?([\w.\-]+)"):
                    for cm in re.finditer(pat, line):
                        edges.append((comp, cm.group(1), 1))
        return edges

    def analyze(self) -> dict:
        edges = self._edges()
        mult: dict[str, float] = defaultdict(float)
        mult[self.entry] = 1.0
        # fixpoint relaxation over the DAG (converges in <= depth passes)
        for _ in range(64):
            new: dict[str, float] = defaultdict(float)
            new[self.entry] = 1.0
            for caller, callee, f in edges:
                new[callee] += mult.get(caller, 0.0) * f
            if dict(new) == dict(mult):
                break
            mult = new

        flops = 0.0
        coll = {k: 0.0 for k in _COLLECTIVES}
        coll_counts = {k: 0.0 for k in _COLLECTIVES}
        traffic = 0.0
        for comp, m in mult.items():
            if m <= 0 or comp not in self.comps:
                continue
            table = self.shapes(comp)
            for line in self.comps[comp]:
                om = _OP_RE.match(line)
                if not om:
                    continue
                name, sig, op = om.groups()
                shapes, byts = _shape_info(sig)
                # fusion bodies execute in registers/VMEM: only the fusion
                # op's own output (counted in the caller) touches HBM
                if op not in _TRIVIAL and byts and comp not in self.fusion_bodies:
                    traffic += 2.0 * byts * m
                if op == "dot":
                    args = re.search(r"dot\(([^)]*)\)", line)
                    argstr = args.group(1) if args else ""
                    # modern XLA prints typed operands inline
                    # (dot(f32[64,64]{1,0} %x, ...)): first shape = lhs
                    lhs_shapes, _ = _shape_info(argstr)
                    if not lhs_shapes:  # bare %name operands: symbol table
                        lhs = argstr.split(",")[0].strip().lstrip("%")
                        lhs_shapes, _ = _shape_info(table.get(lhs, ""))
                    cdims = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
                    k = 1
                    if lhs_shapes and cdims:
                        dims = [int(x) for x in lhs_shapes[0][1].split(",") if x]
                        for ci in cdims.group(1).split(","):
                            if ci and int(ci) < len(dims):
                                k *= dims[int(ci)]
                    out_elems = sum(n for _, _, n in shapes)
                    flops += 2.0 * out_elems * k * m
                elif op.rstrip("-start") in _COLLECTIVES or op in _COLLECTIVES:
                    kind = op[:-6] if op.endswith("-start") else op
                    if kind in _COLLECTIVES:
                        coll[kind] += byts * m
                        coll_counts[kind] += m
        return {
            "dot_flops": flops,
            "collective_bytes": coll,
            "collective_total": sum(coll.values()),
            "collective_counts": coll_counts,
            "hbm_traffic_proxy": traffic,
            "n_computations": len(self.comps),
        }


def analyze_hlo(text: str) -> dict:
    return HloModule(text).analyze()
