"""Production train driver: sharded, checkpointed, fault-tolerant.

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Features exercised end-to-end (all testable on CPU with the reduced
configs; the same code paths drive the production mesh):
  * mesh + FSDP/TP shardings from launch/sharding.py
  * auto-resume from the newest checkpoint (crash recovery)
  * deterministic data stream keyed by (seed, step) -- restart replays
  * async checkpointing every --ckpt-every steps, atomic publish
  * preemption handling (SIGTERM -> final sync checkpoint)
  * straggler monitor on step wall-times
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.ckpt import CheckpointManager
from repro.configs import get_config
from repro.data import DataConfig, lm_batch
from repro.dist.context import ShardingRules, use_rules
from repro.ft import PreemptionHandler, StragglerMonitor
from repro.train import OptConfig, TrainConfig, init_train_state, make_train_step

from .mesh import make_host_mesh, make_production_mesh
from .sharding import batch_shardings, state_shardings


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--production-mesh", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--param-dtype", default="float32")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    mesh = make_production_mesh() if args.production_mesh else make_host_mesh()
    rules = ShardingRules(mesh, batch_shardable=args.batch % mesh.devices.size == 0)
    tc = TrainConfig(
        opt=OptConfig(peak_lr=args.lr, warmup_steps=10, total_steps=args.steps),
        remat=args.remat,
        microbatches=args.microbatches,
    )
    dc = DataConfig(vocab=cfg.vocab, batch=args.batch, seq=args.seq, seed=args.seed)
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    with use_rules(rules), mesh:
        state = init_train_state(cfg, jax.random.PRNGKey(args.seed), jnp.dtype(args.param_dtype))
        st_sh = state_shardings(state, mesh, cfg)
        state = jax.tree.map(jax.device_put, state, st_sh)
        start = 0
        if mgr and mgr.latest_step() is not None:
            start = mgr.latest_step()
            state = mgr.restore(start, state, st_sh)
            print(f"[resume] restored step {start} from {args.ckpt_dir}")

        step_fn = jax.jit(
            make_train_step(cfg, tc),
            in_shardings=(st_sh, batch_shardings(lm_batch(dc, 0), mesh, args.batch)),
            donate_argnums=0,
        )
        monitor = StragglerMonitor()
        preempt = PreemptionHandler()
        preempt.install()

        for step in range(start, args.steps):
            t0 = time.time()
            batch = lm_batch(dc, step)
            state, metrics = step_fn(state, batch)
            metrics = {k: float(v) for k, v in metrics.items()}
            dt = time.time() - t0
            ev = monitor.record(step, dt)
            if ev:
                print(f"[straggler] step {ev.step}: {ev.ratio:.1f}x EWMA -> mitigation hook")
            if step % 10 == 0 or step == args.steps - 1:
                print(
                    f"step {step:5d} loss {metrics['loss']:.4f} "
                    f"gnorm {metrics['grad_norm']:.3f} lr {metrics['lr']:.2e} {dt * 1e3:.0f} ms"
                )
            if mgr and (step + 1) % args.ckpt_every == 0:
                mgr.save(step + 1, state)
            if preempt.should_stop:
                print(f"[preempt] signal received; checkpointing at step {step + 1}")
                if mgr:
                    mgr.wait()
                    mgr.save(step + 1, state)
                    mgr.wait()
                break
        if mgr:
            mgr.wait()
            if (args.steps % args.ckpt_every) and not preempt.should_stop:
                mgr.save(args.steps, state)
                mgr.wait()
    print("[done]")


if __name__ == "__main__":
    main()
