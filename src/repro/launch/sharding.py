"""Sharding rules: params (FSDP x TP), optimizer state, inputs, caches.

Conventions (see DESIGN.md 6):
  * TP ('model' axis): attention q/kv projections and ffn on the feature
    dim; vocab on the embedding/lm-head when divisible.
  * FSDP (('pod','data') axes): the other matrix dim of every large param
    (ZeRO-3; optimizer state inherits the param spec).
  * Any dim that does not divide its assigned axes falls back to
    replicated -- rules are *best effort by construction* so every arch in
    the zoo shards without per-arch tables.
"""
from __future__ import annotations

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

FSDP = ("pod", "data")
TP = "model"


def _axes_size(mesh: Mesh, axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if axes is None:
        return 1
    if isinstance(axes, str):
        return sizes.get(axes, 1)
    return int(np.prod([sizes.get(a, 1) for a in axes]))


def _fit(mesh: Mesh, spec_entries, shape) -> P:
    """Drop assignments that do not divide; prune absent mesh axes."""
    names = set(mesh.axis_names)
    out = []
    for dim, entry in zip(shape, spec_entries):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        axes = tuple(a for a in axes if a in names)
        if not axes or dim % _axes_size(mesh, axes) != 0:
            out.append(None)
        else:
            out.append(axes if len(axes) > 1 else axes[0])
    return P(*out)


def _param_spec(path: str, shape, mesh: Mesh) -> P:
    nd = len(shape)
    fsdp = FSDP

    def fit(*entries):
        return _fit(mesh, entries, shape)

    if "embed" == path.split("//")[-1]:
        spec = _fit(mesh, (TP, fsdp), shape)
        if spec[0] is None:  # vocab not divisible: spread d_model over all axes
            return _fit(mesh, (None, ("pod", "data", "model")), shape)
        return spec
    if path.endswith("lm_head"):
        spec = _fit(mesh, (fsdp, TP), shape)
        if spec[1] is None:
            return _fit(mesh, (("pod", "data", "model"), None), shape)
        return spec
    last = path.split("//")[-1]
    # stacked block params have a leading layer dim -> prepend None
    lead = (None,) * (nd - 2)
    if last in ("wq", "wk", "wv", "w_gate", "w_up", "w_x", "w_y", "w_a", "w_i", "ck",
                "wr", "wg", "mix_A", "w_A"):
        return fit(*lead, fsdp, TP)
    if last in ("wo", "w_down", "w_o", "cv", "cr", "mix_B", "w_B"):
        return fit(*lead, TP, fsdp)
    if last == "router":
        return fit(*lead, fsdp, None)
    if last in ("conv_w",):
        return fit(*lead, None, TP)
    if last in ("lambda", "conv_b"):
        return fit(*lead, TP)
    if last == "frontend_proj":
        return fit(None, fsdp)
    if nd >= 1 and shape[-1] > 1024:  # misc vectors (norm scales etc.)
        return fit(*(None,) * (nd - 1), fsdp)
    return P(*(None,) * nd)


def _moe_param_spec(path: str, shape, mesh: Mesh) -> P | None:
    """MoE expert weights: [.., E, D, F] / [.., E, F, D]."""
    last = path.split("//")[-1]
    nd = len(shape)
    lead = (None,) * (nd - 3)
    if last in ("w_gate", "w_up") and nd >= 3:
        return _fit(mesh, (*lead, None, FSDP, TP), shape)
    if last == "w_down" and nd >= 3:
        return _fit(mesh, (*lead, None, TP, FSDP), shape)
    return None


def param_shardings(params, mesh: Mesh, cfg: ModelConfig):
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    out = []
    for path, leaf in flat:
        key = "//".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        spec = None
        if cfg.moe and ("ffn" in key) and len(leaf.shape) >= 3:
            spec = _moe_param_spec(key, leaf.shape, mesh)
        if spec is None:
            spec = _param_spec(key, leaf.shape, mesh)
        out.append(NamedSharding(mesh, spec))
    return jax.tree_util.tree_unflatten(jax.tree_util.tree_structure(params), out)


def state_shardings(state, mesh: Mesh, cfg: ModelConfig):
    ps = param_shardings(state["params"], mesh, cfg)
    return {
        "params": ps,
        "opt": {
            "m": ps,
            "v": ps,
            "step": NamedSharding(mesh, P()),
        },
    }


def batch_shardings(batch, mesh: Mesh, global_batch: int):
    dp = _axes_size(mesh, FSDP)
    baxes = tuple(a for a in FSDP if a in mesh.axis_names)
    b = baxes if (baxes and global_batch % dp == 0) else None

    def spec(leaf):
        return NamedSharding(mesh, P(b, *([None] * (leaf.ndim - 1))))

    return jax.tree.map(spec, batch)


def cache_shardings(cache, mesh: Mesh, cfg: ModelConfig, batch: int):
    """Decode caches: batch over data when divisible; KV sequence over TP
    (sequence-parallel decode -- this is how GQA kv_heads < TP stays legal)."""
    dp = _axes_size(mesh, FSDP)
    baxes = tuple(a for a in FSDP if a in mesh.axis_names)
    b = baxes if (baxes and batch % dp == 0) else None

    def spec(leaf):
        # leading dim is the stacked-layer dim
        if leaf.ndim == 5:  # kv cache [R, B, Sc, H, hd] or rwkv [R,B,H,dk,dv]
            sc = leaf.shape[2]
            third = TP if sc % _axes_size(mesh, TP) == 0 and sc > 1024 else None
            return NamedSharding(mesh, P(None, b, third, None, None))
        if leaf.ndim == 4:  # conv state [R, B, cw-1, W]
            return NamedSharding(mesh, P(None, b, None, None))
        if leaf.ndim == 3:  # cpos [R, B, Sc] or states [R, B, W/D]
            sc = leaf.shape[2]
            third = TP if sc % _axes_size(mesh, TP) == 0 and sc > 1024 else None
            return NamedSharding(mesh, P(None, b, third))
        return NamedSharding(mesh, P(*([None] * leaf.ndim)))

    return jax.tree.map(spec, cache)
