"""Serving driver: batched continuous-batching engine over the slot pool.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
        --requests 16 --slots 4 --max-new 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import init_params
from repro.serve import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, reduced=args.reduced)
    if cfg.encoder_only:
        raise SystemExit(f"{args.arch} is encoder-only: no decode serving")
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    engine = ServeEngine(cfg, params, batch_slots=args.slots, max_seq=args.max_seq)
    rng = np.random.default_rng(args.seed)
    reqs = [
        Request(rid=i, prompt=rng.integers(0, cfg.vocab, rng.integers(2, 9)).tolist(),
                max_new=args.max_new)
        for i in range(args.requests)
    ]
    t0 = time.time()
    done = engine.run_until_drained(reqs)
    dt = time.time() - t0
    total_tokens = sum(len(r.out) for r in done)
    print(
        f"served {len(done)} requests / {total_tokens} tokens in {dt:.2f}s "
        f"({total_tokens / max(dt, 1e-9):.1f} tok/s, {engine.step_count} engine steps)"
    )
    for r in done[:4]:
        print(f"  rid={r.rid} prompt={r.prompt[:4]}... out={r.out}")


if __name__ == "__main__":
    main()
