"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; ``pod`` is an outer
data-parallel axis by default (gradients all-reduce over pod x data) and
can alternatively run as 2 pipeline stages (dist/pipeline.py).

Defined as functions so importing this module never touches jax device
state (the dry-run must set XLA_FLAGS before any jax initialisation).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5: explicit axis types
    from jax.sharding import AxisType

    def _make_mesh(shape, axes) -> Mesh:
        return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))

except ImportError:  # older jax: Auto is the only behaviour

    def _make_mesh(shape, axes) -> Mesh:
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(data: int | None = None, model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / CPU examples)."""
    n = len(jax.devices())
    if data is None:
        data = n // model
    return _make_mesh((data, model), ("data", "model"))


def mesh_axis_sizes(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))
