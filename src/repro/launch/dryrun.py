import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves on placeholder devices that the distribution
config is coherent: shardings are accepted, the collective schedule builds,
and memory_analysis shows per-device fit.  cost_analysis + the HLO
collective scan feed benchmarks/roofline.py.

    PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x22b \
        --shape train_4k --mesh single --out artifacts/dryrun

(no flags = every runnable cell on both meshes; skips cells whose artifact
JSON already exists unless --force).
"""
import argparse  # noqa: E402
import json  # noqa: E402
import re  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402
from functools import partial  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs import ARCHS, cell_is_runnable, get_config, shape_cells  # noqa: E402
from repro.configs.base import ModelConfig  # noqa: E402
from repro.dist.context import ShardingRules, use_rules  # noqa: E402
from repro.models import decode_step, forward, init_cache, init_params  # noqa: E402
from repro.models.model import logits_from_hidden  # noqa: E402
from repro.train import OptConfig, TrainConfig, init_train_state, make_train_step  # noqa: E402

from .mesh import make_production_mesh, mesh_axis_sizes  # noqa: E402
from .sharding import (  # noqa: E402
    batch_shardings,
    cache_shardings,
    param_shardings,
    state_shardings,
)

PARAM_DTYPE = jnp.bfloat16

# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------


def batch_specs(cfg: ModelConfig, batch: int, seq: int, with_labels: bool) -> dict:
    sds = jax.ShapeDtypeStruct
    out: dict = {}
    if cfg.frontend == "audio":
        out["features"] = sds((batch, seq, cfg.frontend_dim), jnp.bfloat16)
        if with_labels:
            out["labels"] = sds((batch, seq), jnp.int32)
        return out
    s_text = seq - (cfg.frontend_tokens if cfg.frontend == "vision" else 0)
    if cfg.frontend == "vision":
        out["patches"] = sds((batch, cfg.frontend_tokens, cfg.frontend_dim), jnp.bfloat16)
    out["tokens"] = sds((batch, s_text), jnp.int32)
    if with_labels:
        out["labels"] = sds((batch, seq), jnp.int32)
        if cfg.frontend == "vision":
            out["mask"] = sds((batch, seq), jnp.float32)
    return out


def input_specs(arch: str, shape: str) -> dict:
    """Public entry: ShapeDtypeStructs for every model input of a cell."""
    cfg = get_config(arch)
    cell = shape_cells()[shape]
    return batch_specs(cfg, cell["global_batch"], cell["seq_len"], cell["kind"] == "train")


# ---------------------------------------------------------------------------
# step builders per cell kind
# ---------------------------------------------------------------------------


def _prefill_step(params, batch, *, cfg: ModelConfig):
    h, caches, _ = forward(params, cfg, batch, mode="prefill")
    if cfg.encoder_only:
        return logits_from_hidden(params, cfg, h), caches
    return logits_from_hidden(params, cfg, h[:, -1:]), caches


def build_cell(arch: str, shape: str, mesh):
    """Returns (fn, arg_sds, in_shardings, donate) for jit+lower."""
    cfg = get_config(arch)
    cell = shape_cells()[shape]
    b, s, kind = cell["global_batch"], cell["seq_len"], cell["kind"]
    rules = ShardingRules(
        mesh, seq_sharded=os.environ.get("DRYRUN_SEQ_SHARDED", "1") == "1"
    )

    if kind == "train":
        tc = TrainConfig(
            opt=OptConfig(),
            remat=True,
            remat_policy=os.environ.get("DRYRUN_REMAT_POLICY", "full"),
            loss_chunk=int(os.environ.get("DRYRUN_LOSS_CHUNK", "512")),
        )
        state_sds = jax.eval_shape(
            partial(init_train_state, cfg, param_dtype=PARAM_DTYPE), jax.random.PRNGKey(0)
        )
        bs = batch_specs(cfg, b, s, True)
        st_sh = state_shardings(state_sds, mesh, cfg)
        b_sh = batch_shardings(bs, mesh, b)
        fn = make_train_step(cfg, tc)
        return fn, (state_sds, bs), (st_sh, b_sh), (0,), rules

    if kind == "prefill":
        params_sds = jax.eval_shape(
            lambda k: init_params(cfg, k, PARAM_DTYPE), jax.random.PRNGKey(0)
        )
        bs = batch_specs(cfg, b, s, False)
        fn = partial(_prefill_step, cfg=cfg)
        return (
            fn,
            (params_sds, bs),
            (param_shardings(params_sds, mesh, cfg), batch_shardings(bs, mesh, b)),
            (),
            rules,
        )

    # decode: one new token against a cache of seq_len
    params_sds = jax.eval_shape(
        lambda k: init_params(cfg, k, PARAM_DTYPE), jax.random.PRNGKey(0)
    )
    cache_sds = jax.eval_shape(partial(init_cache, cfg, b, s, PARAM_DTYPE))
    tok_sds = jax.ShapeDtypeStruct((b, 1), jnp.int32)
    pos_sds = jax.ShapeDtypeStruct((), jnp.int32)
    fn = partial(decode_step, cfg=cfg)

    def step(params, caches, tokens, pos):
        return decode_step(params, cfg, caches, tokens, pos)

    shardings = (
        param_shardings(params_sds, mesh, cfg),
        cache_shardings(cache_sds, mesh, cfg, b),
        NamedSharding(mesh, P(None, None)),
        NamedSharding(mesh, P()),
    )
    return step, (params_sds, cache_sds, tok_sds, pos_sds), shardings, (1,), rules


# ---------------------------------------------------------------------------
# HLO collective accounting
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
}
_COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute",
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shapes_bytes(sig: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(sig):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved by each collective kind (output-shape sizes)."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\(?[^=]*?\)?)\s*(all-reduce|all-gather|"
                     r"reduce-scatter|all-to-all|collective-permute)(-start|-done)?\(", line)
        if not m:
            continue
        if m.group(3) == "-done":
            continue  # counted at -start
        sig, kind = m.group(1), m.group(2)
        out[kind] += _shapes_bytes(sig)
        counts[kind] += 1
    return {"bytes": out, "counts": counts, "total_bytes": sum(out.values())}


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def run_cell(arch: str, shape: str, mesh_kind: str, out_dir: str, force: bool = False):
    tag = f"{arch}__{shape}__{mesh_kind}"
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path) and not force:
        print(f"[skip-cached] {tag}")
        return json.load(open(path))
    ok, why = cell_is_runnable(arch, shape)
    if not ok:
        rec = {"arch": arch, "shape": shape, "mesh": mesh_kind, "status": "SKIP", "reason": why}
        json.dump(rec, open(path, "w"), indent=1)
        print(f"[skip] {tag}: {why}")
        return rec

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    t0 = time.time()
    try:
        fn, args, shardings, donate, rules = build_cell(arch, shape, mesh)
        with use_rules(rules):
            with mesh:
                jitted = jax.jit(fn, in_shardings=shardings, donate_argnums=donate)
                lowered = jitted.lower(*args)
                t_lower = time.time() - t0
                compiled = lowered.compile()
                t_compile = time.time() - t0 - t_lower
        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = collective_bytes(hlo)
        # loop-aware accounting: XLA cost_analysis counts while bodies once;
        # the scan-over-layers models need trip-count multiplication
        from .hlo_analysis import analyze_hlo

        loop_aware = analyze_hlo(hlo)
        mem_rec = {}
        if mem is not None:
            for attr in (
                "temp_size_in_bytes",
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "alias_size_in_bytes",
                "generated_code_size_in_bytes",
            ):
                mem_rec[attr] = int(getattr(mem, attr, 0) or 0)
        cost_rec = {}
        if cost:
            c = cost if isinstance(cost, dict) else cost[0]
            for k in ("flops", "bytes accessed", "transcendentals", "optimal_seconds"):
                if k in c:
                    cost_rec[k] = float(c[k])
        rec = {
            "arch": arch,
            "shape": shape,
            "mesh": mesh_kind,
            "status": "OK",
            "mesh_shape": dict(mesh_axis_sizes(mesh)),
            "n_devices": int(np.prod(mesh.devices.shape)),
            "lower_s": round(t_lower, 1),
            "compile_s": round(t_compile, 1),
            "memory_analysis": mem_rec,
            "cost_analysis": cost_rec,
            "collectives": coll,
            "loop_aware": loop_aware,
        }
        print(
            f"[ok] {tag}: compile {t_compile:.0f}s, "
            f"flops/dev {cost_rec.get('flops', 0):.3e}, "
            f"coll_bytes/dev {coll['total_bytes']:.3e}, "
            f"temp/dev {mem_rec.get('temp_size_in_bytes', 0) / 2**30:.2f} GiB"
        )
    except Exception as e:  # noqa: BLE001
        rec = {
            "arch": arch,
            "shape": shape,
            "mesh": mesh_kind,
            "status": "FAIL",
            "error": f"{type(e).__name__}: {e}",
            "traceback": traceback.format_exc()[-4000:],
        }
        print(f"[FAIL] {tag}: {type(e).__name__}: {str(e)[:300]}")
    os.makedirs(out_dir, exist_ok=True)
    json.dump(rec, open(path, "w"), indent=1)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCHS) + [None])
    ap.add_argument("--shape", default=None, choices=list(shape_cells()) + [None])
    ap.add_argument("--mesh", default=None, choices=["single", "multi", None])
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()
    archs = [args.arch] if args.arch else list(ARCHS)
    shapes = [args.shape] if args.shape else list(shape_cells())
    meshes = [args.mesh] if args.mesh else ["single", "multi"]
    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                rec = run_cell(arch, shape, mesh_kind, args.out, args.force)
                n_fail += rec.get("status") == "FAIL"
    print(f"done; failures: {n_fail}")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
