from .model import (
    chunked_ce_loss,
    decode_step,
    forward,
    init_cache,
    init_params,
    logits_from_hidden,
    param_count_exact,
)
