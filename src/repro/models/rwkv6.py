"""RWKV6 ("Finch") block: data-dependent-decay linear attention.

Math (per head, k-dim i, v-dim j):
    o_t = r_t . (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T ,   w_t = exp(-exp(d_t))  in (0,1)

Two interchangeable evaluation paths:
  * ``wkv_scan``   -- exact per-token lax.scan (oracle + decode step)
  * ``wkv_chunked``-- chunk-parallel matmul form (training path).  All decay
    factors appear as exp(differences of log-decay cumsums) <= 1, so it is
    stable for arbitrary decays; the [L, L, hd] decay tensor is materialised
    per chunk (chunk 32 keeps it small) and FLOPs stay linear in sequence.

TPU note: the chunked form is the MXU-friendly formulation (batched [L,hd]
matmuls); the paper's technique does not apply to this attention-free mixer
(DESIGN.md Arch-applicability).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.context import constrain

LORA_DIM = 32


def init_rwkv(key, cfg: ModelConfig, dtype):
    d, f = cfg.d_model, cfg.d_ff
    h, hd = cfg.n_heads, cfg.head_dim
    ks = jax.random.split(key, 12)

    def dense(k, fi, shape):
        return (jax.random.normal(k, shape, jnp.float32) / math.sqrt(fi)).astype(dtype)

    return {
        "ln_t": jnp.ones((d,), dtype),
        "mu_x": jnp.zeros((5, d), dtype),  # per-(w,k,v,r,g) static interpolation
        "mix_A": dense(ks[0], d, (d, 5 * LORA_DIM)),
        "mix_B": dense(ks[1], LORA_DIM, (5, LORA_DIM, d)),
        "w_bias": jnp.full((d,), -1.0, dtype),
        "w_A": dense(ks[2], d, (d, LORA_DIM * 2)),
        "w_B": dense(ks[3], LORA_DIM * 2, (LORA_DIM * 2, d)),
        "wr": dense(ks[4], d, (d, d)),
        "wk": dense(ks[5], d, (d, d)),
        "wv": dense(ks[6], d, (d, d)),
        "wg": dense(ks[7], d, (d, d)),
        "wo": dense(ks[8], d, (d, d)),
        "u": jnp.zeros((h, hd), dtype),
        "ln_x": jnp.ones((d,), dtype),
        # channel mix
        "ln_c": jnp.ones((d,), dtype),
        "mu_ck": jnp.zeros((d,), dtype),
        "mu_cr": jnp.zeros((d,), dtype),
        "ck": dense(ks[9], d, (d, f)),
        "cv": dense(ks[10], f, (f, d)),
        "cr": dense(ks[11], d, (d, d)),
    }


def _token_shift(x, prev):
    """shift(x)_t = x_{t-1}; position 0 takes ``prev`` (decode carry)."""
    shifted = jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)
    return shifted


def wkv_scan(r, k, v, logw, u, state):
    """Exact recurrence. r/k/v/logw: [B,S,H,hd]; u: [H,hd]; state: [B,H,hd,hd]."""

    def step(s, inp):
        rt, kt, vt, lwt = inp  # [B,H,hd]
        kv = kt[..., :, None] * vt[..., None, :]  # [B,H,hd_k,hd_v]
        ot = jnp.einsum("bhi,bhij->bhj", rt, s + u[None, :, :, None] * kv)
        s = jnp.exp(lwt)[..., :, None] * s + kv
        return s, ot

    xs = tuple(jnp.moveaxis(a, 1, 0) for a in (r, k, v, logw))
    state, out = jax.lax.scan(step, state, xs)
    return jnp.moveaxis(out, 0, 1).astype(r.dtype), state  # [B,S,H,hd_v]


def wkv_chunked(r, k, v, logw, u, state, chunk: int = 32):
    """Chunk-parallel form; matches wkv_scan (see tests/test_rwkv.py)."""
    b, s, h, hd = r.shape
    pad = (-s) % chunk
    if pad:
        z = lambda a: jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = z(r), z(k), z(v)
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n = r.shape[1] // chunk
    resh = lambda a: a.reshape(b, n, chunk, h, hd).transpose(1, 0, 3, 2, 4)
    rc, kc, vc, lwc = resh(r), resh(k), resh(v), resh(logw.astype(jnp.float32))
    # rc etc: [n, B, H, L, hd]

    def body(carry, inp):
        s0 = carry  # [B,H,hd,hd] fp32
        rt, kt, vt, lw = inp
        cs = jnp.cumsum(lw, axis=-2)  # [B,H,L,hd], inclusive
        cs_prev = cs - lw  # cs_{t-1}
        # inter-chunk: r_t exp(cs_{t-1}) @ S0
        r_dec = rt.astype(jnp.float32) * jnp.exp(cs_prev)
        o_inter = jnp.einsum("bhti,bhij->bhtj", r_dec, s0)
        # intra-chunk: decay tensor exp(cs_{t-1} - cs_s), s <= t-1 (else 0)
        diff = cs_prev[..., :, None, :] - cs[..., None, :, :]  # [B,H,t,s,hd]
        tri = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_), k=-1)
        # mask BEFORE exp: above-diagonal diffs are positive and would inf
        dec = jnp.exp(jnp.where(tri[None, None, :, :, None], diff, -jnp.inf))
        scores = jnp.einsum(
            "bhti,bhsi,bhtsi->bhts", rt.astype(jnp.float32), kt.astype(jnp.float32), dec
        )
        diag = jnp.einsum("bhti,bhti,hi->bht", rt.astype(jnp.float32),
                          kt.astype(jnp.float32), u.astype(jnp.float32))
        scores = scores + jnp.eye(chunk, dtype=jnp.float32)[None, None] * diag[..., None]
        o_intra = jnp.einsum("bhts,bhsj->bhtj", scores, vt.astype(jnp.float32))
        # state to next chunk: exp(cs_L) S0 + sum_s exp(cs_L - cs_s) k_s v_s^T
        cs_last = cs[..., -1:, :]
        k_dec = kt.astype(jnp.float32) * jnp.exp(cs_last - cs)
        s_new = jnp.exp(cs_last[..., 0, :])[..., :, None] * s0 + jnp.einsum(
            "bhsi,bhsj->bhij", k_dec, vt.astype(jnp.float32)
        )
        return s_new, (o_inter + o_intra)

    body = jax.checkpoint(body, prevent_cse=False)  # recompute chunk internals
    state, out = jax.lax.scan(body, state.astype(jnp.float32), (rc, kc, vc, lwc))
    out = out.transpose(1, 0, 3, 2, 4).reshape(b, n * chunk, h, hd)
    return out[:, :s].astype(r.dtype), state


def _group_norm(x, scale, eps):
    """Per-head normalisation of the wkv output (RWKV's GroupNorm)."""
    b, s, h, hd = x.shape
    xf = x.astype(jnp.float32)
    mean = xf.mean(-1, keepdims=True)
    var = ((xf - mean) ** 2).mean(-1, keepdims=True)
    out = (xf - mean) * jax.lax.rsqrt(var + eps)
    return (out.reshape(b, s, h * hd) * scale.astype(jnp.float32)).astype(x.dtype)


def time_mix(x, p, cfg: ModelConfig, state=None, shift_prev=None, chunked=True):
    """RWKV6 time mixing. state: [B,H,hd,hd] fp32; shift_prev: [B,D]."""
    b, s, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    xin = rms_norm_local(x, p["ln_t"], cfg.norm_eps)
    if shift_prev is None:
        shift_prev = jnp.zeros((b, d), xin.dtype)
    xx = _token_shift(xin, shift_prev) - xin
    xxx = xin + xx * p["mu_x"].astype(xin.dtype).sum(0) / 5.0
    m = jnp.tanh(xxx @ p["mix_A"]).reshape(b, s, 5, LORA_DIM)
    deltas = jnp.einsum("bsli,lid->bsld", m, p["mix_B"].astype(xin.dtype))
    mixed = [
        xin + xx * (p["mu_x"][i].astype(xin.dtype) + deltas[:, :, i, :]) for i in range(5)
    ]
    xw, xk, xv, xr, xg = mixed
    dlog = p["w_bias"].astype(jnp.float32) + (
        jnp.tanh(xw @ p["w_A"]) @ p["w_B"]
    ).astype(jnp.float32)
    logw = -jnp.exp(dlog)  # log decay, < 0
    r = (xr @ p["wr"]).reshape(b, s, h, hd)
    k = (xk @ p["wk"]).reshape(b, s, h, hd)
    v = (xv @ p["wv"]).reshape(b, s, h, hd)
    g = jax.nn.silu(xg @ p["wg"])
    logw = logw.reshape(b, s, h, hd)
    if state is None:
        state = jnp.zeros((b, h, hd, hd), jnp.float32)
    fn = wkv_chunked if (chunked and s > 1) else wkv_scan
    out, state = fn(r, k, v, logw, p["u"], state)
    out = _group_norm(out, p["ln_x"], cfg.norm_eps).astype(xin.dtype)
    out = (out * g) @ p["wo"]
    new_shift = xin[:, -1, :]
    return constrain(out, "batch", "seq", None), state, new_shift


def channel_mix(x, p, cfg: ModelConfig, shift_prev=None):
    b, s, d = x.shape
    xin = rms_norm_local(x, p["ln_c"], cfg.norm_eps)
    if shift_prev is None:
        shift_prev = jnp.zeros((b, d), xin.dtype)
    xx = _token_shift(xin, shift_prev) - xin
    xk = xin + xx * p["mu_ck"].astype(xin.dtype)
    xr = xin + xx * p["mu_cr"].astype(xin.dtype)
    kk = jnp.square(jax.nn.relu(xk @ p["ck"]))
    out = jax.nn.sigmoid(xr @ p["cr"]) * (kk @ p["cv"])
    return constrain(out, "batch", "seq", None), xin[:, -1, :]


def rms_norm_local(x, scale, eps):
    from .layers import rms_norm

    return rms_norm(x, scale, eps)
