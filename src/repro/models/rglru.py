"""RecurrentGemma / Griffin recurrent block: RG-LRU + causal temporal conv.

    h_t = a_t . h_{t-1} + sqrt(1 - a_t^2) . (i_t . xi_t)
    a_t = exp(-c * softplus(Lambda) * sigmoid(W_a xi_t))        (c = 8)

The diagonal linear recurrence is evaluated with ``jax.lax.associative_scan``
(log-depth, TPU-parallel) -- the natural TPU mapping of the paper-orthogonal
RG-LRU mixer.  Decode carries (h, conv window) state.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.context import constrain

_C = 8.0


def init_rglru(key, cfg: ModelConfig, dtype):
    d, w = cfg.d_model, cfg.rnn_width
    ks = jax.random.split(key, 6)

    def dense(k, fi, shape):
        return (jax.random.normal(k, shape, jnp.float32) / math.sqrt(fi)).astype(dtype)

    # Lambda init so a^c in (0.9, 0.999) at sigmoid ~ 0.5 (Griffin appendix)
    u = jax.random.uniform(ks[5], (w,), jnp.float32, 0.9**2, 0.999**2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _C))
    return {
        "ln": jnp.ones((d,), dtype),
        "w_x": dense(ks[0], d, (d, w)),
        "w_y": dense(ks[1], d, (d, w)),
        "conv_w": dense(ks[2], cfg.conv_width, (cfg.conv_width, w)),
        "conv_b": jnp.zeros((w,), dtype),
        "w_a": dense(ks[3], w, (w, w)),
        "w_i": dense(ks[4], w, (w, w)),
        "lambda": lam,
        "w_o": dense(ks[0], w, (w, d)),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv, width cw.  state: [B, cw-1, W] trailing inputs."""
    cw = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], cw - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(cw))
    new_state = xp[:, -(cw - 1) :, :]
    return out + b, new_state


def rglru_block(x, p, cfg: ModelConfig, state=None):
    """x: [B,S,D] -> (out [B,S,D], (h, conv) state)."""
    b, s, d = x.shape
    from .layers import rms_norm

    h_state, conv_state = state if state is not None else (None, None)
    xin = rms_norm(x, p["ln"], cfg.norm_eps)
    branch = xin @ p["w_x"]
    gate = jax.nn.gelu(xin @ p["w_y"])
    xi, conv_state = _causal_conv(branch, p["conv_w"], p["conv_b"], conv_state)
    xi = constrain(xi, "batch", None, "ff")

    r = jax.nn.sigmoid((xi @ p["w_a"]).astype(jnp.float32))
    ig = jax.nn.sigmoid((xi @ p["w_i"]).astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lambda"]) * r  # [B,S,W], < 0
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        ig * xi.astype(jnp.float32)
    )

    if h_state is None:
        h_state = jnp.zeros((b, xi.shape[-1]), jnp.float32)
    if s == 1:  # decode step
        h = a[:, 0] * h_state + gated[:, 0]
        hidden = h[:, None, :]
        new_h = h
    else:
        # prepend carry as position 0 contribution: h_0 = a_0 h_prev + b_0
        gated = gated.at[:, 0, :].add(a[:, 0] * h_state)

        def op(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2

        _, hidden = jax.lax.associative_scan(op, (a, gated), axis=1)
        new_h = hidden[:, -1, :]

    out = (hidden.astype(x.dtype) * gate) @ p["w_o"]
    return constrain(out, "batch", "seq", None), (new_h, conv_state)
