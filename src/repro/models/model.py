"""Unified multi-architecture LM.

A model is a sequence of *layer groups*; each group is (pattern, repeats)
and is executed with ``jax.lax.scan`` over stacked per-layer params -- HLO
size and compile time are O(period), not O(n_layers).  The same block code
serves train (no cache), prefill (emits caches) and decode (carries caches).

Block kinds: attn / local / bidir (attention + dense-or-MoE ffn),
rec (RG-LRU + ffn), rwkv (time mix + channel mix).
"""
from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.dist.context import constrain

from . import layers as L
from . import rglru as RG
from . import rwkv6 as RW

Params = Any

_ATTN_KINDS = ("attn", "local", "bidir")


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _init_block(key, kind: str, cfg: ModelConfig, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    if kind in _ATTN_KINDS:
        ffn = L.init_moe(k2, cfg, dtype) if cfg.moe else L.init_mlp(k2, cfg, dtype)
        return {"attn": L.init_attention(k1, cfg, dtype), "ffn": ffn}
    if kind == "rec":
        return {"rec": RG.init_rglru(k1, cfg, dtype), "ffn": L.init_mlp(k2, cfg, dtype)}
    if kind == "rwkv":
        return {"rwkv": RW.init_rwkv(k1, cfg, dtype)}
    raise ValueError(kind)


def init_params(cfg: ModelConfig, key, dtype=jnp.float32) -> Params:
    keys = jax.random.split(key, 8)
    d = cfg.d_model
    params: dict = {
        "embed": (
            jax.random.normal(keys[0], (cfg.vocab_padded, d), jnp.float32) * 0.02
        ).astype(dtype),
        "final_norm": jnp.ones((d,), dtype),
        "groups": [],
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (
            jax.random.normal(keys[1], (d, cfg.vocab_padded), jnp.float32) / math.sqrt(d)
        ).astype(dtype)
    if cfg.frontend != "none":
        params["frontend_proj"] = (
            jax.random.normal(keys[2], (cfg.frontend_dim, d), jnp.float32)
            / math.sqrt(cfg.frontend_dim)
        ).astype(dtype)
    gkey = keys[3]
    for pattern, reps in cfg.layer_groups():
        gkey, sub = jax.random.split(gkey)
        group = {}
        for i, kind in enumerate(pattern):
            sub, bk = jax.random.split(sub)
            # stack `reps` independently-initialised layers along axis 0
            bkeys = jax.random.split(bk, reps)
            stacked = jax.vmap(lambda kk: _init_block(kk, kind, cfg, dtype))(bkeys)
            group[f"b{i}"] = stacked
        params["groups"].append(group)
    return params


def param_count_exact(cfg: ModelConfig) -> int:
    shapes = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    )
    return int(sum(x.size for x in jax.tree.leaves(shapes)))


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------


def _cache_len(kind: str, cfg: ModelConfig, max_seq: int) -> int:
    if kind == "local" and cfg.window:
        return min(cfg.window, max_seq)
    return max_seq


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16):
    """Decode cache pytree, mirroring the group structure."""
    caches = []
    for pattern, reps in cfg.layer_groups():
        group = {}
        for i, kind in enumerate(pattern):
            if kind in _ATTN_KINDS:
                sc = _cache_len(kind, cfg, max_seq)
                group[f"b{i}"] = (
                    jnp.zeros((reps, batch, sc, cfg.n_kv_heads, cfg.head_dim), dtype),
                    jnp.zeros((reps, batch, sc, cfg.n_kv_heads, cfg.head_dim), dtype),
                    jnp.full((reps, batch, sc), -1, jnp.int32),
                )
            elif kind == "rec":
                group[f"b{i}"] = (
                    jnp.zeros((reps, batch, cfg.rnn_width), jnp.float32),
                    jnp.zeros((reps, batch, cfg.conv_width - 1, cfg.rnn_width), dtype),
                )
            elif kind == "rwkv":
                group[f"b{i}"] = (
                    jnp.zeros(
                        (reps, batch, cfg.n_heads, cfg.head_dim, cfg.head_dim), jnp.float32
                    ),
                    jnp.zeros((reps, batch, cfg.d_model), dtype),
                    jnp.zeros((reps, batch, cfg.d_model), dtype),
                )
        caches.append(group)
    return caches


# ---------------------------------------------------------------------------
# block application
# ---------------------------------------------------------------------------


def _apply_block(x, bp, kind, cfg, positions, cache=None, cache_pos=None, aux=0.0):
    if kind in _ATTN_KINDS:
        a_out, kv = L.attention(
            x, bp["attn"], cfg, kind, positions, kv_cache=cache, cache_pos=cache_pos
        )
        x = x + a_out
        if cfg.moe:
            f_out, a = L.moe(x, bp["ffn"], cfg)
            aux = aux + a
        else:
            f_out = L.mlp(x, bp["ffn"], cfg)
        return x + f_out, kv, aux
    if kind == "rec":
        r_out, st = RG.rglru_block(x, bp["rec"], cfg, state=cache)
        x = x + r_out
        return x + L.mlp(x, bp["ffn"], cfg), st, aux
    if kind == "rwkv":
        p = bp["rwkv"]
        wkv_state, shift_t, shift_c = cache if cache is not None else (None, None, None)
        t_out, wkv_state, shift_t = RW.time_mix(
            x, p, cfg, state=wkv_state, shift_prev=shift_t, chunked=x.shape[1] > 1
        )
        x = x + t_out
        c_out, shift_c = RW.channel_mix(x, p, cfg, shift_prev=shift_c)
        return x + c_out, (wkv_state, shift_t, shift_c), aux
    raise ValueError(kind)


def _prep_train_cache(kind, cfg, kv, max_seq):
    """Convert full-sequence block state into a decode cache slice (prefill)."""
    if kind in _ATTN_KINDS:
        k, v, pos = kv
        sc = _cache_len(kind, cfg, max_seq)
        s = k.shape[1]
        if s >= sc:
            # keep the last sc entries, rolled so that entry for position p
            # sits at index p % sc -- decode's ring indexing then lines up
            shift = s % sc
            return (
                jnp.roll(k[:, -sc:], shift, axis=1),
                jnp.roll(v[:, -sc:], shift, axis=1),
                jnp.roll(jnp.broadcast_to(pos, k.shape[:2])[:, -sc:], shift, axis=1),
            )
        pad = sc - s
        return (
            jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(jnp.broadcast_to(pos, k.shape[:2]), ((0, 0), (0, pad)), constant_values=-1),
        )
    return kv


# ---------------------------------------------------------------------------
# forward passes
# ---------------------------------------------------------------------------


def _embed_inputs(params, cfg: ModelConfig, batch: dict, dtype):
    """tokens (+ stub frontend features) -> initial hidden states [B,S,D]."""
    parts = []
    if cfg.frontend == "audio":
        x = batch["features"].astype(dtype) @ params["frontend_proj"]
        return x
    if cfg.frontend == "vision":
        patches = batch["patches"].astype(dtype) @ params["frontend_proj"]
        parts.append(patches)
    tok = L.embedding_lookup(params["embed"], batch["tokens"])
    if cfg.scale_embed:
        tok = tok * math.sqrt(cfg.d_model)
    parts.append(tok)
    return jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]


def forward(
    params,
    cfg: ModelConfig,
    batch: dict,
    *,
    mode: str = "train",  # train | prefill
    remat: bool = False,
    remat_policy: str = "full",
    compute_dtype=None,
    max_seq: int | None = None,
):
    """Full-sequence pass.  Returns (hidden [B,S,D], caches-or-None, aux)."""
    x = _embed_inputs(params, cfg, batch, compute_dtype or params["embed"].dtype)
    b, s, _ = x.shape
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
    x = constrain(x, "batch", "seq", None)
    max_seq = max_seq or s
    caches = [] if mode == "prefill" else None
    aux_total = jnp.zeros((), jnp.float32)

    for gi, (pattern, reps) in enumerate(cfg.layer_groups()):
        gp = params["groups"][gi]

        def body(carry, layer_params, _pattern=pattern):
            x, aux = carry
            cache_out = {}
            for i, kind in enumerate(_pattern):
                x, kv, aux = _apply_block(x, layer_params[f"b{i}"], kind, cfg, positions, aux=aux)
                if mode == "prefill":
                    cache_out[f"b{i}"] = _prep_train_cache(kind, cfg, kv, max_seq)
            return (x, aux), (cache_out if mode == "prefill" else 0)

        if remat:
            policy = (
                jax.checkpoint_policies.checkpoint_dots
                if remat_policy == "dots"
                else None
            )
            body = jax.checkpoint(body, prevent_cse=False, policy=policy)
        (x, aux_total), ys = jax.lax.scan(body, (x, aux_total), gp)
        if mode == "prefill":
            caches.append(ys)

    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, caches, aux_total


def _mask_pad_vocab(logits, cfg: ModelConfig):
    if cfg.vocab_padded == cfg.vocab:
        return logits
    col = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    return jnp.where(col < cfg.vocab, logits, L.NEG_INF)


def logits_from_hidden(params, cfg: ModelConfig, h):
    """Logits over the padded vocab; padded columns are masked to -inf
    (argmax/softmax then never select them).  Width = cfg.vocab_padded."""
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (h @ w).astype(jnp.float32)
    logits = L.softcap(logits, cfg.logit_softcap)
    return constrain(_mask_pad_vocab(logits, cfg), "batch", None, "vocab")


def chunked_ce_loss(params, cfg: ModelConfig, h, labels, mask=None, chunk: int = 1024):
    """Cross-entropy over the vocab without materialising [B,S,V] at once."""
    b, s, d = h.shape
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    chunk = min(chunk, s)
    n = s // chunk
    rem = s - n * chunk
    if mask is None:
        mask = jnp.ones((b, s), jnp.float32)

    def chunk_loss(hc, lc, mc):
        logits = L.softcap((hc @ w).astype(jnp.float32), cfg.logit_softcap)
        logits = constrain(_mask_pad_vocab(logits, cfg), "batch", None, "vocab")
        logz = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, lc[..., None], axis=-1)[..., 0]
        return jnp.sum((logz - ll) * mc), jnp.sum(mc)

    def body(carry, xs):
        tot, cnt = carry
        hc, lc, mc = xs
        l, c = chunk_loss(hc, lc, mc)
        return (tot + l, cnt + c), 0

    # save only the scan carry for backward; the fp32 logits of every chunk
    # would otherwise be stored as scan residuals (dominant loss-memory term)
    body = jax.checkpoint(body, prevent_cse=False)

    hc = h[:, : n * chunk].reshape(b, n, chunk, d).transpose(1, 0, 2, 3)
    lc = labels[:, : n * chunk].reshape(b, n, chunk).transpose(1, 0, 2)
    mc = mask[:, : n * chunk].reshape(b, n, chunk).transpose(1, 0, 2)
    (tot, cnt), _ = jax.lax.scan(body, (0.0, 0.0), (hc, lc, mc))
    if rem:
        l, c = chunk_loss(h[:, n * chunk :], labels[:, n * chunk :], mask[:, n * chunk :])
        tot, cnt = tot + l, cnt + c
    return tot / jnp.maximum(cnt, 1.0)


def decode_step(params, cfg: ModelConfig, caches, tokens, pos):
    """One decode step.  tokens: [B, 1]; pos: scalar or per-slot [B] int32.

    Returns (logits [B, 1, V], new caches).
    """
    x = L.embedding_lookup(params["embed"], tokens)
    if cfg.scale_embed:
        x = x * math.sqrt(cfg.d_model)
    b = tokens.shape[0]
    pos = jnp.asarray(pos, jnp.int32)
    positions = jnp.broadcast_to(
        pos[:, None] if pos.ndim else pos[None, None], (b, 1)
    ).astype(jnp.int32)
    x = constrain(x, "batch", None, None)
    new_caches = []
    for gi, (pattern, reps) in enumerate(cfg.layer_groups()):
        gp = params["groups"][gi]
        gc = caches[gi]

        def body(x, scanned, _pattern=pattern):
            layer_params, layer_cache = scanned
            cache_out = {}
            for i, kind in enumerate(_pattern):
                x, st, _ = _apply_block(
                    x,
                    layer_params[f"b{i}"],
                    kind,
                    cfg,
                    positions,
                    cache=layer_cache[f"b{i}"],
                    cache_pos=pos,
                )
                cache_out[f"b{i}"] = st
            return x, cache_out

        x, ys = jax.lax.scan(body, x, (gp, gc))
        new_caches.append(ys)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return logits_from_hidden(params, cfg, x), new_caches
