"""Shared neural layers: norms, RoPE, GQA attention (full / windowed /
bidirectional, logit softcap, qk-norm), gated MLP, and MoE with local
sort-based dispatch.

All functions are pure: ``params`` pytrees in, arrays out.  Sharding is
expressed through ``repro.dist.context.constrain`` with logical axis names,
so the same code runs unsharded in unit tests and SPMD-partitioned in the
dry-run/train paths.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.dist.context import constrain

Params = Any

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def _dense_init(key, fan_in, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) / math.sqrt(fan_in)).astype(dtype)


def init_attention(key, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    p = {
        "ln": jnp.ones((d,), dtype),
        "wq": _dense_init(ks[0], d, (d, cfg.q_dim), dtype),
        "wk": _dense_init(ks[1], d, (d, cfg.kv_dim), dtype),
        "wv": _dense_init(ks[2], d, (d, cfg.kv_dim), dtype),
        "wo": _dense_init(ks[3], cfg.q_dim, (cfg.q_dim, d), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.head_dim,), dtype)
        p["k_norm"] = jnp.ones((cfg.head_dim,), dtype)
    return p


def init_mlp(key, cfg: ModelConfig, dtype) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "ln": jnp.ones((d,), dtype),
        "w_gate": _dense_init(ks[0], d, (d, f), dtype),
        "w_up": _dense_init(ks[1], d, (d, f), dtype),
        "w_down": _dense_init(ks[2], f, (f, d), dtype),
    }


def init_moe(key, cfg: ModelConfig, dtype) -> Params:
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "ln": jnp.ones((d,), dtype),
        "router": _dense_init(ks[0], d, (d, e), jnp.float32),  # router kept fp32
        "w_gate": _dense_init(ks[1], d, (e, d, f), dtype),
        "w_up": _dense_init(ks[2], d, (e, d, f), dtype),
        "w_down": _dense_init(ks[3], f, (e, f, d), dtype),
    }


# ---------------------------------------------------------------------------
# basic ops
# ---------------------------------------------------------------------------


def rms_norm(x, scale, eps: float):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def softcap(x, cap: float):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


def act_fn(x, kind: str):
    return jax.nn.silu(x) if kind == "silu" else jax.nn.gelu(x)


def rope(x, positions, theta: float):
    """Rotary embedding; x: [B, S, H, hd], positions: [B, S] int32."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., None].astype(jnp.float32) * freq  # [B, S, half]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _attn_mask(pos_q, pos_kv, kind: str, window: int):
    """[B, Sq, Skv] boolean mask. pos_kv < 0 marks invalid cache slots."""
    valid = (pos_kv >= 0)[:, None, :]
    if kind == "bidir":
        return valid
    causal = pos_q[:, :, None] >= pos_kv[:, None, :]
    if kind == "local" and window:
        causal &= pos_q[:, :, None] - pos_kv[:, None, :] < window
    return causal & valid


def _sdpa(q, k, v, mask, cap: float):
    """q: [B,Sq,Hkv,G,hd]; k/v: [B,Skv,Hkv,hd]; mask: [B,Sq,Skv]."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", q, k, preferred_element_type=jnp.float32)
    scores = softcap(scores * scale, cap)
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bhgqk,bkhd->bqhgd", w, v)


def _sdpa_blocked(q, k, v, pos_q, pos_kv, kind, window, cap: float, kv_block: int = 1024):
    """Online-softmax attention, scanning KV blocks (long-sequence path).

    Bounds the transient score tensor to [B,H,G,Sq,kv_block] -- the jnp
    realisation of flash attention for the 32k/500k shapes.
    """
    b, sq, hkv, g, hd = q.shape
    skv = k.shape[1]
    nblk = skv // kv_block
    scale = 1.0 / math.sqrt(hd)

    def body(carry, blk):
        m, l, acc = carry
        kb, vb, pb = blk  # [B, C, Hkv, hd], [B, C, Hkv, hd], [B, C]
        s = jnp.einsum("bqhgd,bkhd->bhgqk", q, kb, preferred_element_type=jnp.float32)
        s = softcap(s * scale, cap)
        mask = _attn_mask(pos_q, pb, kind, window)
        s = jnp.where(mask[:, None, None, :, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(vb.dtype), vb
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    # recompute block internals in backward: without this the scan saves the
    # [.., Sq, kv_block] score tensors of every block as residuals
    body = jax.checkpoint(body, prevent_cse=False)

    kb = k.reshape(b, nblk, kv_block, hkv, hd).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nblk, kv_block, hkv, hd).transpose(1, 0, 2, 3, 4)
    pb = pos_kv.reshape(b, nblk, kv_block).transpose(1, 0, 2)
    m0 = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hkv, g, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, g, sq, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, pb))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.transpose(0, 3, 1, 2, 4).astype(v.dtype)  # [B,Sq,Hkv,G,hd]


# use online-softmax blocked attention from this sequence length up: the
# dense [B,H,G,S,S] fp32 score transient is the dominant train memory term
BLOCKED_ATTN_THRESHOLD = 4096


def attention(x, p, cfg: ModelConfig, kind: str, positions, kv_cache=None, cache_pos=None):
    """Self-attention sub-block.  Returns (out, new_kv) where new_kv is the
    (k, v) to cache: full for train/prefill, updated cache for decode."""
    b, s, d = x.shape
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    q = (h @ p["wq"]).reshape(b, s, cfg.n_heads, cfg.head_dim)
    k = (h @ p["wk"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    v = (h @ p["wv"]).reshape(b, s, cfg.n_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    g = cfg.n_heads // cfg.n_kv_heads
    # Head sharding for GQA: when kv_heads < TP degree but q_heads divide it,
    # repeat K/V to full heads for the *compute* (same FLOPs) so the score
    # tensor shards over 'model' on the head dim -- otherwise XLA replicates
    # the [B,H,G,S,S] transient (the dominant memory term; EXPERIMENTS.md Perf).
    from repro.dist.context import axis_size

    k_cacheable, v_cacheable = k, v  # pre-repeat (cache stores true kv heads)
    tp = axis_size("model")
    if (
        kv_cache is None
        and g > 1
        and cfg.n_kv_heads % tp != 0
        and cfg.n_heads % tp == 0
    ):
        k = constrain(jnp.repeat(k, g, axis=2), "batch", None, "heads", None)
        v = constrain(jnp.repeat(v, g, axis=2), "batch", None, "heads", None)
        qg = q.reshape(b, s, cfg.n_heads, 1, cfg.head_dim)
    else:
        qg = q.reshape(b, s, cfg.n_kv_heads, g, cfg.head_dim)
    qg = constrain(qg, "batch", None, "heads", None, None)

    if kv_cache is not None:  # decode: append then attend against the cache
        ck, cv, cpos = kv_cache  # [B, Sc, Hkv, hd] x2, [B, Sc] positions (-1 empty)
        slot = cache_pos % ck.shape[1]  # ring buffer (bounded for local layers)
        if jnp.ndim(cache_pos) == 0:
            # homogeneous batch position: dynamic-update-slice, which GSPMD
            # partitions natively even with the cache sequence dim sharded
            ck = jax.lax.dynamic_update_slice(ck, k, (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(cv, v, (0, slot, 0, 0))
            cpos = jax.lax.dynamic_update_slice(cpos, positions, (0, slot))
        else:
            # per-slot positions (serving engine): scatter writes
            rows = jnp.arange(b)
            ck = ck.at[rows, slot].set(k[:, 0])
            cv = cv.at[rows, slot].set(v[:, 0])
            cpos = cpos.at[rows, slot].set(positions[:, 0])
        ck = constrain(ck, "batch", "kv_seq", None, None)
        cv = constrain(cv, "batch", "kv_seq", None, None)
        mask = _attn_mask(positions, cpos, kind, cfg.window)
        out = _sdpa(qg, ck, cv, mask, cfg.attn_softcap)
        new_cache = (ck, cv, cpos)
    else:
        pos_kv = positions
        if s >= BLOCKED_ATTN_THRESHOLD:
            out = _sdpa_blocked(qg, k, v, positions, pos_kv, kind, cfg.window, cfg.attn_softcap)
        else:
            mask = _attn_mask(positions, pos_kv, kind, cfg.window)
            out = _sdpa(qg, k, v, mask, cfg.attn_softcap)
        new_cache = (k_cacheable, v_cacheable, pos_kv)
    out = out.reshape(b, s, cfg.q_dim)
    out = out @ p["wo"]
    return constrain(out, "batch", "seq", None), new_cache


def embedding_lookup(table, tokens):
    """Vocab-parallel embedding gather.

    With the table vocab-sharded over 'model', a plain jnp.take makes GSPMD
    replicate the [B,S,D] gather output ("involuntary full rematerialization").
    Instead each model shard gathers its local rows (out-of-range tokens
    masked to zero) and the partial outputs psum over 'model' -- the classic
    Megatron vocab-parallel embedding.  Falls back to jnp.take when no mesh
    is active or the vocab does not divide the TP degree.
    """
    from repro.dist.context import get_rules

    rules = get_rules()
    v = table.shape[0]
    if rules is None:
        return jnp.take(table, tokens, axis=0)
    from jax.sharding import PartitionSpec as P

    mesh = rules.mesh
    tp = rules.model_axis
    tp_size = dict(zip(mesh.axis_names, mesh.devices.shape)).get(tp, 1)
    if tp_size == 1 or v % tp_size != 0:
        return jnp.take(table, tokens, axis=0)
    batch_axes = tuple(a for a in rules.batch_axes if a in mesh.axis_names)
    dp = int(np.prod([dict(zip(mesh.axis_names, mesh.devices.shape))[a] for a in batch_axes])) if batch_axes else 1
    bspec = batch_axes if (batch_axes and tokens.shape[0] % dp == 0) else None
    rows = v // tp_size

    def local(tbl, tok):
        off = jax.lax.axis_index(tp) * rows
        idx = tok - off
        ok = (idx >= 0) & (idx < rows)
        local_rows = jnp.take(tbl, jnp.clip(idx, 0, rows - 1), axis=0)
        out = jnp.where(ok[..., None], local_rows, jnp.zeros_like(local_rows))
        return jax.lax.psum(out, tp)

    fn = jax.shard_map(
        local,
        mesh=mesh,
        in_specs=(P(tp, None), P(bspec, None)),
        out_specs=P(bspec, None, None),
        check_vma=False,
    )
    return fn(table, tokens)


# ---------------------------------------------------------------------------
# dense MLP and MoE
# ---------------------------------------------------------------------------


def mlp(x, p, cfg: ModelConfig):
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    gate = act_fn(h @ p["w_gate"], cfg.act)
    up = h @ p["w_up"]
    hidden = constrain(gate * up, "batch", None, "ff")
    return constrain(hidden @ p["w_down"], "batch", "seq", None)


def moe_dispatch_local(tokens, router, w_gate, w_up, w_down, cfg: ModelConfig, tp_axis=None):
    """Sort-based top-k dispatch with capacity, entirely shard-local.

    tokens: [T, D].  Routes each token to its top_k experts, packs tokens
    into [E, C, D] capacity buffers via a rank-within-expert computed from
    an argsort over expert ids (tokens past capacity are dropped, standard
    Switch-style), runs the expert GEMMs (ff dim TP-sharded when running
    under shard_map; ``tp_axis`` names the axis to psum partial down-proj
    sums over), and combines with router weights.
    """
    t, d = tokens.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = min(int(math.ceil(cfg.capacity_factor * t * k / e)), t)
    # router matmul in the compute dtype (casting the [T,D] tokens to f32
    # makes XLA hoist the convert above the dispatch gather and run the whole
    # expert GEMM chain in f32 -- 2x memory and FLOPs); softmax in f32
    router_logits = (tokens @ router.astype(tokens.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(router_logits, axis=-1)
    top_p, top_ids = jax.lax.top_k(probs, k)  # [T, k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    flat_ids = top_ids.reshape(-1)  # [T*k], slot-major per token
    order = jnp.argsort(flat_ids, stable=True)
    sorted_expert = flat_ids[order]
    # rank within expert: position among all (token, slot) pairs of that expert
    same = jnp.cumsum(jax.nn.one_hot(sorted_expert, e, dtype=jnp.int32), axis=0)
    rank_sorted = jnp.take_along_axis(same, sorted_expert[:, None], axis=1)[:, 0] - 1
    rank = jnp.zeros((t * k,), jnp.int32).at[order].set(rank_sorted)
    keep = rank < cap
    slot = jnp.where(keep, flat_ids * cap + rank, e * cap).reshape(t, k)

    # Fill the [E, C, D] capacity buffer by GATHER, not scatter: scatter the
    # cheap int32 token index per slot, then gather rows once.  k sequential
    # [E*C, D] scatter copies were the dominant MoE memory term (see
    # EXPERIMENTS.md Perf); the gather's backward is a single scatter-add.
    inv = jnp.full((e * cap + 1,), t, jnp.int32)  # sentinel -> zero row
    inv = inv.at[slot.reshape(-1)].set(jnp.arange(t * k, dtype=jnp.int32) // k)
    tok_pad = jnp.concatenate([tokens, jnp.zeros((1, d), tokens.dtype)], axis=0)
    buf = jnp.take(tok_pad, inv[: e * cap], axis=0).reshape(e, cap, d)

    gate = act_fn(jnp.einsum("ecd,edf->ecf", buf, w_gate), cfg.act)
    up = jnp.einsum("ecd,edf->ecf", buf, w_up)
    expert_out = jnp.einsum("ecf,efd->ecd", gate * up, w_down)
    if tp_axis is not None:  # partial sums over the TP-sharded ff dim
        expert_out = jax.lax.psum(expert_out, tp_axis)

    flat_out = jnp.concatenate(
        [expert_out.reshape(e * cap, d), jnp.zeros((1, d), expert_out.dtype)], axis=0
    )
    # combine with ONE [T,k,D] gather (backward = one scatter-add); a k-loop
    # of gathers left k live [E*C,D] gradient buffers (EXPERIMENTS.md Perf)
    gathered = jnp.take(flat_out, slot.reshape(-1), axis=0).reshape(t, k, d)
    out = jnp.einsum("tkd,tk->td", gathered, top_p.astype(expert_out.dtype))
    # load-balancing auxiliary loss (Switch-style)
    frac_tokens = jax.nn.one_hot(top_ids[:, 0], e, dtype=jnp.float32).mean(0)
    aux = e * jnp.sum(frac_tokens * probs.mean(0))
    return out, aux


def moe(x, p, cfg: ModelConfig):
    """MoE ffn.

    Distributed path: shard_map over the mesh -- tokens stay shard-local for
    the sort/dispatch (a global argsort under GSPMD would replicate the
    dispatch buffers), expert ffn weights are TP-sharded on the ff dim with
    a psum of the partial down-projections (Megatron-style TP within each
    expert; works for any n_experts vs TP degree, unlike EP).
    """
    from repro.dist.context import get_rules

    b, s, d = x.shape
    x = rms_norm(x, p["ln"], cfg.norm_eps)  # pre-norm (as in the dense mlp)
    rules = get_rules()
    if rules is None:
        tokens = x.reshape(b * s, d)
        out, aux = moe_dispatch_local(
            tokens, p["router"], p["w_gate"], p["w_up"], p["w_down"], cfg
        )
        return out.reshape(b, s, d), aux

    from jax.sharding import PartitionSpec as P

    mesh = rules.mesh
    batch_axes = tuple(a for a in rules.batch_axes if a in mesh.axis_names)
    tp = rules.model_axis
    tp_size = dict(zip(mesh.axis_names, mesh.devices.shape)).get(tp, 1)
    # tiny experts: TP-sharding moe_d_ff below one MXU tile per shard only
    # buys a psum -- replicate the expert weights instead (they are small)
    replicate_experts = cfg.moe_d_ff // max(tp_size, 1) < 128
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = int(np.prod([mesh_sizes[a] for a in batch_axes])) if batch_axes else 1
    batch_spec = batch_axes if (batch_axes and b % dp == 0) else None

    # expert-data-parallel: with replicated (tiny) experts, also shard the
    # sequence over 'model' so each TP shard routes its own token slice --
    # no psum, no redundant compute (falls back to replicated tokens when
    # the sequence does not divide, e.g. decode)
    seq_spec = tp if (replicate_experts and s % max(tp_size, 1) == 0) else None

    def local_fn(xl, router, wg, wu, wd):
        bl, sl, _ = xl.shape
        tokens = xl.reshape(bl * sl, d)
        eff_tp = None if replicate_experts else tp
        nc = cfg.moe_token_chunk
        if nc > 1 and (bl * sl) % nc == 0:
            # scan over token chunks: peak dispatch buffers shrink by nc
            # (capacity is enforced per chunk, as with expert parallelism)
            chunks = tokens.reshape(nc, (bl * sl) // nc, d)

            def body(carry, tc):
                oc, ac = moe_dispatch_local(tc, router, wg, wu, wd, cfg, tp_axis=eff_tp)
                return carry + ac, oc

            body = jax.checkpoint(body, prevent_cse=False)
            aux, out = jax.lax.scan(body, jnp.zeros((), jnp.float32), chunks)
            out = out.reshape(bl * sl, d)
            aux = aux / nc
        else:
            out, aux = moe_dispatch_local(tokens, router, wg, wu, wd, cfg, tp_axis=eff_tp)
        axes = tuple(
            a for a in ((batch_spec or ()) if isinstance(batch_spec, tuple)
                        else ((batch_spec,) if batch_spec else ()))
        ) + ((seq_spec,) if seq_spec else ())
        if axes:
            aux = jax.lax.pmean(aux, axes)
        return out.reshape(bl, sl, d), aux

    fn = jax.shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            P(batch_spec, seq_spec, None),
            P(None, None),
            P(None, None, None if replicate_experts else tp),
            P(None, None, None if replicate_experts else tp),
            P(None, None if replicate_experts else tp, None),
        ),
        out_specs=(P(batch_spec, seq_spec, None), P()),
        check_vma=False,
    )
    out, aux = fn(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return constrain(out, "batch", "seq", None), aux
