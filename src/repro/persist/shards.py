"""Per-shard snapshot files for `ShardedBitmapIndex`.

A sharded index persists as a directory::

    dir/
      sharded.json        # shard map: names, tile bounds, global geometry
      shard-0000.bmsnap   # one standalone snapshot per tile-range shard
      shard-0001.bmsnap
      ...

Each shard file is a complete, self-describing TileStore snapshot (it
carries its own ``shard`` metadata block), so a device can
:func:`load_shard` ONLY its own file -- the load path never gathers and
never touches another shard's bytes.  :func:`load_sharded` rebuilds the
full index from the shard map exactly the way
``ShardedTileStore.with_shards`` does after compaction: shard stores are
adopted as-is and bounds come straight from the map, no reclassification,
no concatenation.
"""
from __future__ import annotations

import json
from pathlib import Path

from . import snapshot

__all__ = ["save_sharded", "load_sharded", "load_shard", "shard_path"]

_MAP = "sharded.json"


def shard_path(dirpath, k: int) -> Path:
    return Path(dirpath) / f"shard-{k:04d}.bmsnap"


def save_sharded(obj, dirpath, *, names=None, extra: dict | None = None) -> dict:
    """Write one ``.bmsnap`` per shard plus the ``sharded.json`` map.

    ``obj`` is a ``ShardedBitmapIndex`` or a ``ShardedTileStore``.
    Returns the shard-map metadata.
    """
    store = obj
    if hasattr(obj, "store"):
        store = obj.store
        if names is None:
            names = tuple(obj.names)
    d = Path(dirpath)
    d.mkdir(parents=True, exist_ok=True)
    n_shards = store.n_shards
    for k, shard in enumerate(store.shards):
        snapshot.save(
            shard, shard_path(d, k), names=names,
            extra={"shard": {
                "id": k,
                "n_shards": n_shards,
                "tile_bounds": list(store.tile_bounds[k]),
                "global_r": int(store.r),
                "global_n_words": int(store.n_words),
            }},
        )
    meta = {
        "kind": "sharded",
        "n_shards": n_shards,
        "names": list(names) if names is not None else None,
        "tile_bounds": [list(b) for b in store.tile_bounds],
        "n_words": int(store.n_words),
        "r": int(store.r),
        "tile_words": int(store.tile_words),
    }
    if extra:
        for key in extra:
            if key in meta:
                raise ValueError(f"extra shard-map key {key!r} is reserved")
        meta.update(extra)
    (d / _MAP).write_text(json.dumps(meta, indent=2, sort_keys=True))
    return meta


def read_shard_map(dirpath) -> dict:
    return json.loads((Path(dirpath) / _MAP).read_text())


def load_shard(dirpath, k: int, *, to_device: bool = False,
               verify: bool = False):
    """One shard's TileStore (memmap-backed) -- what a single device loads.
    Returns ``(store, (t0, t1))`` with the shard's global tile bounds."""
    path = shard_path(dirpath, k)
    manifest = snapshot.read_manifest(path)
    store = snapshot.load(path, to_device=to_device, verify=verify,
                          manifest=manifest)
    return store, tuple(manifest["shard"]["tile_bounds"])


def load_sharded(dirpath, *, mesh=None, axis: str = "data",
                 to_device: bool = False, verify: bool = False):
    """Rebuild the full ``ShardedBitmapIndex`` from a snapshot directory.

    Every shard store is an independent memmap view over its own file;
    nothing is gathered or reclassified -- the shard map supplies the
    bounds and global geometry directly (mirroring ``with_shards``).
    """
    from repro.dist.query import ShardedBitmapIndex, ShardedTileStore

    d = Path(dirpath)
    meta = read_shard_map(d)
    shards = tuple(
        snapshot.load(shard_path(d, k), to_device=to_device, verify=verify)
        for k in range(meta["n_shards"])
    )
    store = ShardedTileStore(
        shards, tuple(tuple(b) for b in meta["tile_bounds"]),
        n_words=meta["n_words"], r=meta["r"], mesh=mesh, axis=axis,
    )
    names = meta["names"]
    if names is None:
        return store
    return ShardedBitmapIndex(store, tuple(names))
