"""Append-only write-ahead log of `StreamingIndex` mutation batches.

File layout: an 8-byte magic + u32 format version header, then records::

    u32 payload_len | u32 crc32(payload) | payload

    payload := u8 kind | u64 version | body
      kind 1 UPDATE      body: u64 m | i32 cols[m] | i64 pos[m] | u8 on[m]
      kind 2 APPEND      body: u64 n | u64 k | packbits(bool[n, k])
      kind 3 MATERIALIZE body: utf-8 JSON {"name":..., "query": <obj>}

Versions are monotone across the log's whole lifetime (they survive
checkpoint rotation), so "replay everything after snapshot version V" is
a single comparison per record.  Each record is guarded by its own
crc32 and length prefix: a crash mid-append leaves a short or corrupt
tail that :meth:`WriteAheadLog.scan` detects, and opening for append
truncates the file back to the last valid record -- replay never
surfaces a partial batch.

Queries are persisted via :func:`query_to_obj` / :func:`query_from_obj`,
a JSON codec over the frozen ``repro.query.expr`` dataclasses (the tree
structure is the serialization; ``Query.key()`` is not invertible).
"""
from __future__ import annotations

import json
import struct
import time as _time
import zlib
from pathlib import Path

import numpy as np

from repro.obs import REGISTRY as _OBS_REGISTRY

# WAL durability accounting (no-ops until ``repro.obs.enable()``): append
# latency is the write+flush(+fsync) critical path every mutation batch
# sits on before it applies.
_WAL_APPENDS = _OBS_REGISTRY.counter(
    "repro_wal_appends_total", "WAL records appended", ("fsync",),
)
_WAL_BYTES = _OBS_REGISTRY.counter(
    "repro_wal_bytes_total", "WAL bytes written (payload + framing)",
)
_WAL_APPEND_S = _OBS_REGISTRY.histogram(
    "repro_wal_append_seconds", "WAL append latency (write+flush+fsync)",
    ("fsync",),
)

__all__ = [
    "WriteAheadLog",
    "WalError",
    "UPDATE",
    "APPEND",
    "MATERIALIZE",
    "query_to_obj",
    "query_from_obj",
]

WAL_MAGIC = b"BMWAL001"
WAL_VERSION = 1
_HEADER = 12  # magic + u32 version

UPDATE, APPEND, MATERIALIZE = 1, 2, 3


class WalError(ValueError):
    """Raised on structural WAL corruption (not a truncated tail)."""


# -- query (de)serialization ------------------------------------------------

def query_to_obj(q):
    """JSON-serializable tree for one ``repro.query.expr.Query``."""
    from repro.query import expr as E

    def over(o):
        return None if o is None else [query_to_obj(m) for m in o]

    t = type(q)
    if t is E.Col:
        return {"op": "col", "name": q.name}
    if t is E.Threshold:
        return {"op": "threshold", "t": q.t, "over": over(q.over)}
    if t is E.Interval:
        return {"op": "interval", "lo": q.lo, "hi": q.hi, "over": over(q.over)}
    if t is E.Exactly:
        return {"op": "exactly", "k": q.k, "over": over(q.over)}
    if t is E.Parity:
        return {"op": "parity", "over": over(q.over)}
    if t is E.Majority:
        return {"op": "majority", "over": over(q.over)}
    if t is E.Sym:
        return {"op": "sym", "table": list(q.table), "over": over(q.over)}
    if t is E.Weighted:
        return {"op": "weighted", "weights": list(q.weights), "t": q.t,
                "over": over(q.over)}
    if t is E.And:
        return {"op": "and", "children": [query_to_obj(c) for c in q.children]}
    if t is E.Or:
        return {"op": "or", "children": [query_to_obj(c) for c in q.children]}
    if t is E.Not:
        return {"op": "not", "child": query_to_obj(q.child)}
    if t is E.AndNot:
        return {"op": "andnot", "keep": query_to_obj(q.keep),
                "drop": query_to_obj(q.drop)}
    raise TypeError(f"cannot serialize query node {t.__name__}")


def query_from_obj(obj):
    """Inverse of :func:`query_to_obj`."""
    from repro.query import expr as E

    def over(o):
        return None if o is None else tuple(query_from_obj(m) for m in o)

    op = obj["op"]
    if op == "col":
        return E.Col(obj["name"])
    if op == "threshold":
        return E.Threshold(obj["t"], over=over(obj["over"]))
    if op == "interval":
        return E.Interval(obj["lo"], obj["hi"], over=over(obj["over"]))
    if op == "exactly":
        return E.Exactly(obj["k"], over=over(obj["over"]))
    if op == "parity":
        return E.Parity(over=over(obj["over"]))
    if op == "majority":
        return E.Majority(over=over(obj["over"]))
    if op == "sym":
        return E.Sym(tuple(obj["table"]), over=over(obj["over"]))
    if op == "weighted":
        return E.Weighted(tuple(obj["weights"]), obj["t"],
                          over=over(obj["over"]))
    if op == "and":
        return E.And(*[query_from_obj(c) for c in obj["children"]])
    if op == "or":
        return E.Or(*[query_from_obj(c) for c in obj["children"]])
    if op == "not":
        return E.Not(query_from_obj(obj["child"]))
    if op == "andnot":
        return E.AndNot(query_from_obj(obj["keep"]), query_from_obj(obj["drop"]))
    raise WalError(f"unknown query op {op!r}")


# -- the log ----------------------------------------------------------------

class WriteAheadLog:
    """One append-only log file (conventionally ``wal.bmwal``).

    Opening scans existing records, truncates any invalid tail (the
    crash case) and positions the writer after the last valid record;
    ``last_version`` resumes from there.  ``append_*`` methods flush to
    the OS on every record; pass ``fsync=True`` for full durability at
    the cost of one fsync per append.
    """

    def __init__(self, path, *, fsync: bool = False):
        self.path = Path(path)
        self.fsync = bool(fsync)
        if not self.path.exists() or self.path.stat().st_size < _HEADER:
            with open(self.path, "wb") as f:
                f.write(WAL_MAGIC)
                f.write(np.uint32(WAL_VERSION).tobytes())
        valid_end, last_version, n = self.scan()
        if self.path.stat().st_size > valid_end:
            with open(self.path, "r+b") as f:
                f.truncate(valid_end)
        self.last_version = last_version
        self.records = n
        self._f = open(self.path, "ab")

    # -- scanning / replay -------------------------------------------------
    def scan(self) -> tuple:
        """(valid_end_offset, last_version, n_records) -- read-only pass
        that stops at the first truncated or corrupt record."""
        size = self.path.stat().st_size
        with open(self.path, "rb") as f:
            head = f.read(_HEADER)
            if head[:8] != WAL_MAGIC:
                raise WalError(f"{self.path}: not a bmwal file")
            if int(np.frombuffer(head[8:12], "<u4")[0]) != WAL_VERSION:
                raise WalError(f"{self.path}: unsupported WAL version")
            end, version, n = _HEADER, 0, 0
            while True:
                hdr = f.read(8)
                if len(hdr) < 8:
                    break
                plen, crc = struct.unpack("<II", hdr)
                if end + 8 + plen > size:
                    break  # truncated tail
                payload = f.read(plen)
                if len(payload) < plen or (zlib.crc32(payload) & 0xFFFFFFFF) != crc:
                    break  # corrupt tail
                v = struct.unpack("<Q", payload[1:9])[0]
                if v <= version:
                    break  # version went backwards: treat as tail damage
                version, n = v, n + 1
                end = f.tell()
        return end, version, n

    def replay(self, after_version: int = 0):
        """Yield decoded records with ``version > after_version`` as dicts.
        Stops cleanly at the first invalid record (crash tail)."""
        size = self.path.stat().st_size
        with open(self.path, "rb") as f:
            f.seek(_HEADER)
            while True:
                hdr = f.read(8)
                if len(hdr) < 8:
                    return
                plen, crc = struct.unpack("<II", hdr)
                if f.tell() + plen > size:
                    return
                payload = f.read(plen)
                if len(payload) < plen or (zlib.crc32(payload) & 0xFFFFFFFF) != crc:
                    return
                rec = self._decode(payload)
                if rec["version"] > after_version:
                    yield rec

    @staticmethod
    def _decode(payload: bytes) -> dict:
        kind = payload[0]
        version = struct.unpack("<Q", payload[1:9])[0]
        body = payload[9:]
        if kind == UPDATE:
            (m,) = struct.unpack("<Q", body[:8])
            o = 8
            cols = np.frombuffer(body, "<i4", m, o)
            o += 4 * m
            pos = np.frombuffer(body, "<i8", m, o)
            o += 8 * m
            on = np.frombuffer(body, "<u1", m, o).astype(bool)
            return {"kind": UPDATE, "version": version,
                    "cols": cols.astype(np.int64), "pos": pos.copy(), "on": on}
        if kind == APPEND:
            n, k = struct.unpack("<QQ", body[:16])
            packed = np.frombuffer(body, np.uint8, -1, 16)
            bits = np.unpackbits(packed, count=n * k, bitorder="little")
            return {"kind": APPEND, "version": version,
                    "bits": bits.reshape(int(n), int(k)).astype(bool)}
        if kind == MATERIALIZE:
            obj = json.loads(body.decode())
            return {"kind": MATERIALIZE, "version": version,
                    "name": obj["name"], "query": query_from_obj(obj["query"])}
        raise WalError(f"unknown WAL record kind {kind}")

    # -- appends -----------------------------------------------------------
    def _append(self, kind: int, body: bytes) -> int:
        _OBS = _OBS_REGISTRY
        t0 = _time.perf_counter() if _OBS.enabled else 0.0
        self.last_version += 1
        payload = struct.pack("<BQ", kind, self.last_version) + body
        self._f.write(struct.pack(
            "<II", len(payload), zlib.crc32(payload) & 0xFFFFFFFF
        ))
        self._f.write(payload)
        self._f.flush()
        if self.fsync:
            import os

            os.fsync(self._f.fileno())
        self.records += 1
        if _OBS.enabled:
            _WAL_APPENDS.inc(1, fsync=self.fsync)
            _WAL_BYTES.inc(len(payload) + 8)
            _WAL_APPEND_S.observe(
                _time.perf_counter() - t0, fsync=self.fsync
            )
        return self.last_version

    def append_update(self, cols, pos, on) -> int:
        cols = np.ascontiguousarray(cols, "<i4")
        pos = np.ascontiguousarray(pos, "<i8")
        on = np.ascontiguousarray(np.asarray(on, bool), "<u1")
        if not (cols.size == pos.size == on.size):
            raise ValueError("cols/pos/on must align")
        body = struct.pack("<Q", cols.size) + cols.tobytes() + pos.tobytes() \
            + on.tobytes()
        return self._append(UPDATE, body)

    def append_rows(self, bits) -> int:
        bits = np.ascontiguousarray(np.asarray(bits, bool))
        n, k = bits.shape
        body = struct.pack("<QQ", n, k) + np.packbits(
            bits.reshape(-1), bitorder="little"
        ).tobytes()
        return self._append(APPEND, body)

    def append_materialize(self, name: str, query) -> int:
        body = json.dumps(
            {"name": name, "query": query_to_obj(query)},
            sort_keys=True, separators=(",", ":"),
        ).encode()
        return self._append(MATERIALIZE, body)

    # -- lifecycle ---------------------------------------------------------
    def rotate(self) -> None:
        """Drop every logged record (they are folded into a snapshot) but
        keep the version counter monotone."""
        self._f.close()
        with open(self.path, "wb") as f:
            f.write(WAL_MAGIC)
            f.write(np.uint32(WAL_VERSION).tobytes())
        self.records = 0
        self._f = open(self.path, "ab")

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
