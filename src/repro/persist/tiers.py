"""`PagedTileStore`: a cold/warm-tier read view over a memmap-backed store.

A snapshot loaded with ``repro.persist.snapshot.load`` keeps every pack
host-resident as ``np.memmap`` views -- the OS pages bytes in on first
touch.  But the executor's all-dense fast path ships the WHOLE densified
dirty pack to the device on first use (``TileStore.dirty``), which
defeats paging the moment one query runs.  ``PagedTileStore`` closes
that hole:

  * it advertises ``paged = True``, which routes
    ``repro.storage.tiled.run_tiled_circuit`` through the per-tile
    ``gather_cells`` / ``gather_events`` path even for all-dense stores
    -- only the tiles a query's plan actually touches are read off the
    mapping and shipped to the device, per launch;
  * materialized tile words are kept in a host-side LRU cache (capacity
    in tiles), so repeated queries over a working set stop re-reading /
    re-decompressing the file;
  * metadata (classes, kinds, stats, cardinalities) passes straight
    through -- it is tiny and already resident.

Dense-path backends still work (``densify()`` delegates) but count as
``full_materializations`` in :meth:`cache_info` -- if that number is
nonzero the index is too dense-hot for paging and should be loaded with
``to_device=True`` instead.  Plan with ``tiled_fused`` (the planner does
so on its own whenever tile-skipping pays) to stay on the paged path.
"""
from __future__ import annotations

from collections import OrderedDict

import numpy as np

from repro.obs import REGISTRY as _OBS

__all__ = ["PagedTileStore"]

# Page-cache accounting mirrored onto the process-wide registry (no-ops
# until ``repro.obs.enable()``); the instance attributes below stay the
# exact-count source of truth for existing callers.
_PAGE_EVENTS = _OBS.counter(
    "repro_persist_page_events_total",
    "Paged tile-store cache events (hit / miss / eviction / densify)",
    ("event",),
)


class PagedTileStore:
    """LRU-paged read view satisfying the TileStore execution surface."""

    #: run_tiled_circuit checks this to avoid the whole-pack device path
    paged = True

    def __init__(self, base, *, capacity_tiles: int = 4096):
        self._base = base
        self._capacity = max(1, int(capacity_tiles))
        self._cache: OrderedDict[tuple, np.ndarray] = OrderedDict()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.full_materializations = 0

    # -- geometry / metadata passthrough -----------------------------------
    @property
    def n(self):
        return self._base.n

    @property
    def r(self):
        return self._base.r

    @property
    def n_words(self):
        return self._base.n_words

    @property
    def n_tiles(self):
        return self._base.n_tiles

    @property
    def tile_words(self):
        return self._base.tile_words

    @property
    def containers(self):
        return self._base.containers

    @property
    def classes_word(self):
        return self._base.classes_word

    @property
    def container_kinds(self):
        return self._base.container_kinds

    @property
    def storage_words_cell(self):
        return self._base.storage_words_cell

    @property
    def cardinalities(self):
        return self._base.cardinalities

    @property
    def densities(self):
        return self._base.densities

    @property
    def clean_fraction(self):
        return self._base.clean_fraction

    @property
    def dirty_words(self):
        return self._base.dirty_words

    def member_stats(self, slots=None):
        return self._base.member_stats(slots)

    def block_stats(self):
        return self._base.block_stats()

    # -- paged read path ---------------------------------------------------
    def gather_cells(self, cols, tiles) -> np.ndarray:
        """Tile materialisation through the LRU: cached (col, tile) cells
        are served from memory, misses read the mapping once and enter
        the cache."""
        cols = np.asarray(cols, np.int64)
        tiles = np.asarray(tiles, np.int64)
        out = np.empty((cols.size, self.tile_words), np.uint32)
        miss_rows = []
        evicted = 0
        for i, key in enumerate(zip(cols.tolist(), tiles.tolist())):
            got = self._cache.get(key)
            if got is not None:
                self._cache.move_to_end(key)
                out[i] = got
                self.hits += 1
            else:
                miss_rows.append(i)
                self.misses += 1
        if miss_rows:
            sel = np.asarray(miss_rows)
            fetched = self._base.gather_cells(cols[sel], tiles[sel])
            out[sel] = fetched
            for j, i in enumerate(miss_rows):
                key = (int(cols[i]), int(tiles[i]))
                self._cache[key] = fetched[j]
                if len(self._cache) > self._capacity:
                    self._cache.popitem(last=False)
                    self.evictions += 1
                    evicted += 1
        if _OBS.enabled:
            n_miss = len(miss_rows)
            if cols.size - n_miss:
                _PAGE_EVENTS.inc(cols.size - n_miss, event="hit")
            if n_miss:
                _PAGE_EVENTS.inc(n_miss, event="miss")
            if evicted:
                _PAGE_EVENTS.inc(evicted, event="eviction")
        return out

    def gather_events(self, cols, tiles):
        # event payloads ARE the compressed containers -- smaller than any
        # cached densification, so they read through uncached
        return self._base.gather_events(cols, tiles)

    # -- dense-path escape hatches (counted) -------------------------------
    def densify(self):
        self.full_materializations += 1
        _PAGE_EVENTS.inc(1, event="densify")
        return self._base.densify()

    def column(self, i: int):
        return self.densify()[int(i)]

    @property
    def dirty(self):
        self.full_materializations += 1
        return self._base.dirty

    @property
    def dirty_index(self):
        return self._base.dirty_index

    @property
    def _dirty_np(self):
        self.full_materializations += 1
        return self._base._dirty_np

    # -- accounting --------------------------------------------------------
    def cache_info(self) -> dict:
        return {
            "capacity_tiles": self._capacity,
            "cached_tiles": len(self._cache),
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "full_materializations": self.full_materializations,
        }
