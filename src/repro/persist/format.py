"""The `.bmsnap` on-disk snapshot framing: header, manifest, raw sections.

Layout (all integers little-endian)::

    [ 0..7 ]   magic  b"BMSNAP01"
    [ 8..11]   u32    format version (== 1)
    [12..19]   u64    manifest byte offset (a JSON footer)
    [20..23]   u32    manifest byte length
    [24..27]   u32    crc32 of the manifest bytes
    [28..63]   zeros  (reserved)
    [64.. ]    sections, each start aligned to 64 bytes
    [tail ]    manifest JSON (utf-8, sorted keys, canonical separators)

Every section is one raw little-endian C-order array; the manifest's
``sections`` table records ``name`` / ``dtype`` (numpy ``<u4``-style
codes) / ``shape`` / ``offset`` / ``nbytes`` / ``crc32`` per entry.
Writing the manifest as a footer keeps section offsets independent of
the (variable-length) metadata, so the writer is single-pass and
byte-deterministic -- the golden-fixture test in ``tests/test_persist.py``
holds the format to that.

The reader never copies: :func:`map_sections` returns array views over
one ``np.memmap`` of the whole file.  Checksums are therefore verified
only on request (``verify=True``) -- an eager full-file CRC pass would
defeat the lazy-paging point of the mmap load.

This framing is the Roaring portable-serialization idea (PAPERS.md:
arxiv 1709.07821) applied to the tile store: flat versioned arrays that
load without decoding.
"""
from __future__ import annotations

import json
import zlib
from pathlib import Path

import numpy as np

__all__ = [
    "MAGIC",
    "VERSION",
    "FormatError",
    "write_snapshot",
    "read_manifest",
    "map_sections",
    "verify_snapshot",
    "schema_digest",
]

MAGIC = b"BMSNAP01"
VERSION = 1
_ALIGN = 64
_HEADER = 64


class FormatError(ValueError):
    """Raised when a snapshot file fails structural validation."""


def schema_digest(names, r: int, tile_words: int) -> str:
    """Stable digest of the index schema: column names + geometry.

    Two snapshots with equal digests hold the same universe shape and
    column identity -- the WAL-replay compatibility check.
    """
    import hashlib

    payload = json.dumps(
        [list(names) if names is not None else None, int(r), int(tile_words)],
        separators=(",", ":"),
    ).encode()
    return hashlib.sha256(payload).hexdigest()[:16]


def _le(arr: np.ndarray) -> np.ndarray:
    """C-contiguous little-endian view/copy of ``arr``."""
    arr = np.ascontiguousarray(arr)
    if arr.dtype.byteorder == ">":
        arr = arr.astype(arr.dtype.newbyteorder("<"))
    return arr


def write_snapshot(path, sections, meta: dict) -> dict:
    """Write sections (an iterable of ``(name, ndarray)``) + metadata.

    ``meta`` lands in the manifest verbatim (it must be JSON-serializable
    and must not use the reserved keys ``format``/``version``/``sections``).
    Returns the manifest written.  The write goes to ``path + '.tmp'``
    first and is renamed into place, so a crashed save never leaves a
    half-written snapshot under the final name.
    """
    path = Path(path)
    entries = []
    offset = _HEADER
    arrays = []
    for name, arr in sections:
        arr = _le(arr)
        pad = (-offset) % _ALIGN
        offset += pad
        raw = arr.tobytes()
        entries.append({
            "name": name,
            "dtype": arr.dtype.str,
            "shape": list(arr.shape),
            "offset": offset,
            "nbytes": len(raw),
            "crc32": zlib.crc32(raw) & 0xFFFFFFFF,
        })
        arrays.append((pad, raw))
        offset += len(raw)
    manifest = {"format": "bmsnap", "version": VERSION, **meta,
                "sections": entries}
    mbytes = json.dumps(manifest, sort_keys=True,
                        separators=(",", ":")).encode()
    pad_tail = (-offset) % _ALIGN
    tmp = path.with_name(path.name + ".tmp")
    with open(tmp, "wb") as f:
        f.write(MAGIC)
        f.write(np.uint32(VERSION).tobytes())
        f.write(np.uint64(offset + pad_tail).tobytes())
        f.write(np.uint32(len(mbytes)).tobytes())
        f.write(np.uint32(zlib.crc32(mbytes) & 0xFFFFFFFF).tobytes())
        f.write(b"\x00" * (_HEADER - f.tell()))
        for pad, raw in arrays:
            f.write(b"\x00" * pad)
            f.write(raw)
        f.write(b"\x00" * pad_tail)
        f.write(mbytes)
        f.flush()
    tmp.replace(path)
    return manifest


def read_manifest(path) -> dict:
    """Parse + validate the header and return the manifest dict."""
    with open(path, "rb") as f:
        head = f.read(_HEADER)
        if len(head) < _HEADER or head[:8] != MAGIC:
            raise FormatError(f"{path}: not a bmsnap file")
        version = int(np.frombuffer(head[8:12], "<u4")[0])
        if version != VERSION:
            raise FormatError(
                f"{path}: format version {version} unsupported (have {VERSION})"
            )
        moff = int(np.frombuffer(head[12:20], "<u8")[0])
        mlen = int(np.frombuffer(head[20:24], "<u4")[0])
        mcrc = int(np.frombuffer(head[24:28], "<u4")[0])
        f.seek(moff)
        mbytes = f.read(mlen)
    if len(mbytes) != mlen or (zlib.crc32(mbytes) & 0xFFFFFFFF) != mcrc:
        raise FormatError(f"{path}: manifest truncated or corrupt")
    manifest = json.loads(mbytes)
    if manifest.get("format") != "bmsnap" or manifest.get("version") != VERSION:
        raise FormatError(f"{path}: manifest/header version mismatch")
    return manifest


def map_sections(path, manifest: dict | None = None, *,
                 verify: bool = False) -> dict:
    """``{name: ndarray}`` views over one ``np.memmap`` of the file.

    Zero-copy: every returned array is a reshaped slice of the mapping
    (read-only).  With ``verify=True`` each section's crc32 is checked --
    which touches every byte, so leave it off for lazy loads.
    """
    if manifest is None:
        manifest = read_manifest(path)
    buf = np.memmap(path, dtype=np.uint8, mode="r")
    out = {}
    for s in manifest["sections"]:
        off, nb = s["offset"], s["nbytes"]
        if off + nb > buf.size:
            raise FormatError(f"{path}: section {s['name']!r} out of bounds")
        raw = buf[off:off + nb]
        if verify and (zlib.crc32(raw.tobytes()) & 0xFFFFFFFF) != s["crc32"]:
            raise FormatError(f"{path}: section {s['name']!r} checksum mismatch")
        out[s["name"]] = raw.view(s["dtype"]).reshape(s["shape"])
    return out


def verify_snapshot(path) -> dict:
    """Full structural + checksum validation; returns the manifest."""
    manifest = read_manifest(path)
    map_sections(path, manifest, verify=True)
    return manifest
