"""Snapshot save/load for :class:`~repro.storage.TileStore` indexes.

``save`` serializes the store's *pack surface* -- the same store-wide
per-kind arrays ``TileStore.packs`` assembles for query execution -- so
saving costs one lazy pack assembly plus a sequential write, and loading
costs nothing but an ``np.memmap``: ``load`` hands the mapped sections to
``TileStore.from_arrays``, whose per-column payloads are slices of the
mapped packs.  No word is copied (or even read off disk) until a query
actually gathers it; ``to_device=True`` eagerly ships the dirty pack to
the accelerator instead for serving-path warm starts.

Legacy all-dense stores (``containers=False``) serialize under the very
same framing -- their sparse/run sections are just empty -- and load back
with the all-dense fast path intact (the device gather reads the mapped
dense pack directly).
"""
from __future__ import annotations

from pathlib import Path

import numpy as np

from repro.storage import TileStore

from .format import read_manifest, map_sections, schema_digest, write_snapshot

__all__ = ["save", "load", "load_index", "read_manifest"]

#: manifest/section layout of one TileStore (order is the on-disk order)
_SECTIONS = (
    "classes", "kinds", "cardinalities",
    "dense_index", "sparse_index", "run_index",
    "dense_pack", "sparse_bounds", "sparse_pack", "run_bounds", "run_pack",
)


def save(obj, path, *, names=None, extra: dict | None = None) -> dict:
    """Write ``obj`` (a TileStore, or anything with ``.store``/``.names``
    like a BitmapIndex) to ``path``.  Returns the manifest."""
    store = obj
    if not isinstance(obj, TileStore):
        store = obj.store
        if names is None:
            names = tuple(obj.names)
    packs = store.packs
    arrays = {
        "classes": store.classes_word,
        "kinds": store.container_kinds,
        "cardinalities": np.asarray(store.cardinalities, np.int64),
        **packs,
    }
    meta = {
        "kind": "tilestore",
        "r": int(store.r),
        "n_words": int(store.n_words),
        "tile_words": int(store.tile_words),
        "n_tiles": int(store.n_tiles),
        "n_columns": int(store.n),
        "containers": bool(store.containers),
        "names": list(names) if names is not None else None,
        "schema_digest": schema_digest(names, store.r, store.tile_words),
    }
    if extra:
        for k in extra:
            if k in meta or k in ("format", "version", "sections"):
                raise ValueError(f"extra manifest key {k!r} is reserved")
        meta.update(extra)
    return write_snapshot(path, [(n, arrays[n]) for n in _SECTIONS], meta)


def load(path, *, to_device: bool = False, verify: bool = False,
         manifest: dict | None = None) -> TileStore:
    """Reconstruct the TileStore at ``path`` over ``np.memmap`` views.

    The returned store's pack arrays alias the file: host-resident reads
    page lazily through the OS.  ``to_device=True`` additionally uploads
    the densified dirty pack to the default device right away (for
    compressed stores this materializes the containers first -- they are
    small by construction).  ``verify=True`` checks every section crc32
    before reconstruction.
    """
    if manifest is None:
        manifest = read_manifest(path)
    if manifest.get("kind") != "tilestore":
        raise ValueError(f"{path}: snapshot holds {manifest.get('kind')!r}, "
                         "not a tilestore")
    sections = map_sections(path, manifest, verify=verify)
    store = TileStore.from_arrays(
        sections,
        tile_words=manifest["tile_words"],
        n_words=manifest["n_words"],
        r=manifest["r"],
        containers=manifest["containers"],
    )
    if to_device:
        store.dirty  # noqa: B018 -- upload + cache the device dirty pack
    return store


def load_index(path, *, to_device: bool = False, verify: bool = False):
    """Reconstruct a :class:`~repro.query.BitmapIndex` (requires the
    snapshot to carry column names)."""
    from repro.query import BitmapIndex

    manifest = read_manifest(path)
    names = manifest.get("names")
    if names is None:
        raise ValueError(f"{path}: snapshot has no column names; use load()")
    store = load(path, to_device=to_device, verify=verify, manifest=manifest)
    return BitmapIndex(names=tuple(names), _store=store)


def snapshot_info(path) -> dict:
    """Manifest + file size, without mapping any section."""
    manifest = read_manifest(path)
    manifest["file_bytes"] = Path(path).stat().st_size
    return manifest
