"""`repro.persist`: versioned on-disk index format, WAL + snapshot recovery.

  * :mod:`~repro.persist.format` -- the ``.bmsnap`` framing: header,
    checksummed raw sections, JSON manifest footer;
  * :mod:`~repro.persist.snapshot` -- ``save``/``load`` of one TileStore /
    BitmapIndex with zero-copy ``np.memmap`` reconstruction;
  * :mod:`~repro.persist.shards` -- one file per tile-range shard for
    ``ShardedBitmapIndex`` (each device loads only its own);
  * :mod:`~repro.persist.wal` -- the ``.bmwal`` write-ahead log of
    streaming mutation batches (per-record CRC, monotone versions);
  * :mod:`~repro.persist.tiers` -- ``PagedTileStore``, the host-resident
    read tier that pages only plan-touched tiles onto the device.

High-level entry points live on the owning classes: ``BitmapIndex.save``
/ ``.load``, ``ShardedBitmapIndex.save`` / ``.load``, and
``StreamingIndex.checkpoint`` / ``.recover``.
"""
from .calibration import (
    CALIBRATION_FILE,
    ensure_calibration,
    load_calibration,
    save_calibration,
)
from .format import FormatError, read_manifest, schema_digest, verify_snapshot
from .shards import load_shard, load_sharded, read_shard_map, save_sharded
from .snapshot import load, load_index, save, snapshot_info
from .tiers import PagedTileStore
from .wal import WriteAheadLog, query_from_obj, query_to_obj

__all__ = [
    "CALIBRATION_FILE",
    "FormatError",
    "PagedTileStore",
    "WriteAheadLog",
    "ensure_calibration",
    "load_calibration",
    "save_calibration",
    "load",
    "load_index",
    "load_shard",
    "load_sharded",
    "query_from_obj",
    "query_to_obj",
    "read_manifest",
    "read_shard_map",
    "save",
    "save_sharded",
    "schema_digest",
    "snapshot_info",
    "verify_snapshot",
]
