"""Persisted planner calibration constants (``calibration.json``).

Calibration constants (``core.calibration.Calibration`` -- per-backend
words→µs roofline rates) are device properties, not index data, so they
live in their own small JSON artifact alongside snapshots rather than
inside the ``.bmsnap`` framing: a serving directory typically holds

    snapshot.bmsnap      the index
    wal.bmwal            the mutation log
    calibration.json     this device's measured planner constants

Constants are keyed by the jax backend name; loading a file measured on a
different device kind returns None (the caller re-measures) unless
``allow_mismatch`` is set.  Writes are tmp+rename atomic like every other
``repro.persist`` artifact.
"""
from __future__ import annotations

import json
import os
from pathlib import Path

from repro.core.calibration import Calibration, measure_calibration, set_calibration

__all__ = [
    "CALIBRATION_FILE",
    "save_calibration",
    "load_calibration",
    "ensure_calibration",
]

CALIBRATION_FILE = "calibration.json"


def _resolve(path) -> Path:
    p = Path(path)
    return p / CALIBRATION_FILE if p.is_dir() or not p.suffix else p


def save_calibration(calib: Calibration, path) -> Path:
    """Write constants as sorted-key JSON (atomic tmp+rename); ``path`` may
    be a directory (gets ``calibration.json``) or an explicit file."""
    target = _resolve(path)
    target.parent.mkdir(parents=True, exist_ok=True)
    tmp = target.with_suffix(target.suffix + ".tmp")
    tmp.write_text(json.dumps(calib.to_obj(), indent=2, sort_keys=True))
    os.replace(tmp, target)
    return target


def load_calibration(path, *, allow_mismatch: bool = False) -> Calibration | None:
    """Read persisted constants; None when absent, unreadable, or measured
    on a different device topology (stale constants are worse than none).

    Accepts the full topology signature (``cpux8``), the legacy bare
    backend name (files written before signatures carried device counts),
    and the portable ``identity`` calibration."""
    import jax

    from repro.core.calibration import device_signature

    target = _resolve(path)
    if not target.exists():
        return None
    try:
        obj = json.loads(target.read_text())
    except (OSError, ValueError):
        return None
    calib = Calibration.from_obj(obj)
    accepted = ("identity", jax.default_backend(), device_signature())
    if not allow_mismatch and calib.device not in accepted:
        return None
    if calib.device == jax.default_backend():
        # legacy bare-backend stamp: adopt the full signature so the
        # topology-staleness check doesn't immediately reset the constants
        calib.device = device_signature()
    return calib


def ensure_calibration(path, *, activate: bool = True, **measure_kw) -> Calibration:
    """Load persisted constants or measure-and-persist them on first use.

    The serving front-end's startup path: one call yields this device's
    constants (a ~1s measurement pass the first time, a JSON read after)
    and installs them as the process-active calibration so every
    subsequent plan is priced in microseconds.
    """
    calib = load_calibration(path)
    if calib is None:
        calib = measure_calibration(**measure_kw)
        save_calibration(calib, path)
    if activate:
        set_calibration(calib)
    return calib
