"""Microbatch pipeline parallelism over one mesh axis (GPipe schedule).

Stage ``s`` lives on device ``s`` of ``axis_name``; microbatches are
injected at device 0 and streamed one hop per step with ``ppermute``, so
``M`` microbatches through ``S`` stages take ``M + S - 1`` steps.  Stages
must be shape-preserving (activation in == activation out), which is the
usual transformer-block contract.

When the mesh axis does not match the stage count (e.g. a 1-device test
mesh) the schedule degenerates to a sequential scan over stages -- same
numerics, no overlap.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:  # jax >= 0.5 top-level export
    _shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - older jax
    from jax.experimental.shard_map import shard_map as _shard_map

__all__ = ["pipeline_forward"]


def _sequential(stage_fn, x, stage_params, n_stages: int):
    def body(carry, s):
        p_s = jax.tree.map(lambda a: a[s], stage_params)
        return jax.vmap(lambda mb: stage_fn(p_s, mb))(carry), None

    out, _ = jax.lax.scan(body, x, jnp.arange(n_stages))
    return out


def pipeline_forward(stage_fn, x, stage_params, mesh, axis_name: str = "pod"):
    """Run ``x: [M, ...]`` microbatches through ``S`` stacked stages.

    ``stage_params`` leaves have leading dim ``S``; ``stage_fn(params, mb)``
    applies one stage to one microbatch.  Returns ``[M, ...]`` outputs.
    """
    n_stages = jax.tree.leaves(stage_params)[0].shape[0]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if sizes.get(axis_name, 1) != n_stages:
        return _sequential(stage_fn, x, stage_params, n_stages)

    m = x.shape[0]
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def per_device(p, xs):
        w = jax.tree.map(lambda a: a[0], p)  # this device's stage
        idx = jax.lax.axis_index(axis_name)
        buf = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)

        def step(carry, t):
            buf, outs = carry
            x_in = jnp.where(idx == 0, xs[jnp.minimum(t, m - 1)], buf)
            y = stage_fn(w, x_in)
            o_idx = jnp.clip(t - (n_stages - 1), 0, m - 1)
            take = (idx == n_stages - 1) & (t >= n_stages - 1)
            outs = jnp.where(take, outs.at[o_idx].set(y), outs)
            return (jax.lax.ppermute(y, axis_name, perm), outs), None

        (_, outs), _ = jax.lax.scan(step, (buf, outs), jnp.arange(m + n_stages - 1))
        return outs[None]

    fn = _shard_map(
        per_device, mesh=mesh, in_specs=(P(axis_name), P()), out_specs=P(axis_name)
    )
    return fn(stage_params, x)[-1]
