"""Logical-axis sharding context.

Model code calls ``constrain(x, "batch", None, "heads", None)`` with *logical*
axis names; the active :class:`ShardingRules` (installed with ``use_rules``)
maps them to mesh axes and applies ``with_sharding_constraint``.  With no
rules installed every call is the identity, so the same code runs unsharded
in unit tests and SPMD-partitioned under a mesh.

Assignments that do not divide the dimension fall back to replicated --
rules are best effort by construction (same convention as launch/sharding).
"""
from __future__ import annotations

import dataclasses
import threading
from contextlib import contextmanager

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = ["ShardingRules", "use_rules", "get_rules", "constrain", "axis_size"]


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Maps logical axis names to mesh axes.

    ``batch`` spreads over ``batch_axes`` (data-parallel, possibly multi-axis
    e.g. ``("pod", "data")``) unless ``batch_shardable`` is off (uneven
    global batch); ``heads`` / ``ff`` / ``vocab`` / ``model`` over the
    tensor-parallel ``model_axis``; ``seq`` / ``kv_seq`` over ``seq_axis``
    (defaulting to the model axis) when ``seq_sharded`` is enabled.
    """

    mesh: Mesh
    batch_axes: tuple = ("data",)
    model_axis: str = "model"
    seq_axis: str | None = None
    batch_shardable: bool = True
    seq_sharded: bool = False

    def physical(self, logical: str | None):
        if logical is None:
            return None
        names = set(self.mesh.axis_names)
        if logical == "batch":
            if not self.batch_shardable:
                return None
            axes = tuple(a for a in self.batch_axes if a in names)
            return axes if axes else None
        if logical in ("heads", "ff", "vocab", "model", "feature"):
            return self.model_axis if self.model_axis in names else None
        if logical in ("seq", "kv_seq"):
            if not self.seq_sharded:
                return None
            axis = self.seq_axis or self.model_axis
            return axis if axis in names else None
        return None


_STATE = threading.local()


def get_rules() -> ShardingRules | None:
    return getattr(_STATE, "rules", None)


@contextmanager
def use_rules(rules: ShardingRules):
    prev = get_rules()
    _STATE.rules = rules
    try:
        yield rules
    finally:
        _STATE.rules = prev


def axis_size(axis: str) -> int:
    """Size of a mesh axis under the active rules (1 when unsharded)."""
    rules = get_rules()
    if rules is None:
        return 1
    return dict(zip(rules.mesh.axis_names, rules.mesh.devices.shape)).get(axis, 1)


def _axes_size(mesh: Mesh, axes) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if isinstance(axes, str):
        return sizes.get(axes, 1)
    return int(np.prod([sizes.get(a, 1) for a in axes]))


def constrain(x, *logical_axes):
    """Apply a sharding constraint expressed with logical axis names.

    Identity when no rules are installed.  Entries that do not divide their
    dimension are dropped (replicated) rather than erroring.
    """
    rules = get_rules()
    if rules is None:
        return x
    if len(logical_axes) != x.ndim:
        raise ValueError(f"{len(logical_axes)} axis names for rank-{x.ndim} array")
    entries = []
    for dim, logical in zip(x.shape, logical_axes):
        phys = rules.physical(logical)
        if phys is None or dim % _axes_size(rules.mesh, phys) != 0:
            entries.append(None)
        else:
            entries.append(phys)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, P(*entries)))
