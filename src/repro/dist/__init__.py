"""Distributed substrate: sharding context, gradient compression, pipeline,
and the row-sharded query engine.

``context`` carries the active :class:`ShardingRules` so model code can
express sharding with *logical* axis names (``batch``, ``heads``...) and run
unchanged both unsharded (unit tests) and SPMD-partitioned (train/serve).
``compression`` implements the int8 ring all-reduce with error feedback;
``pipeline`` the microbatch pipeline schedule over a mesh axis; ``query``
partitions a ``BitmapIndex``'s row space into per-device shards with
per-shard query planning (``BitmapIndex.shard(mesh)`` is the front door).
"""

from .context import ShardingRules, axis_size, constrain, get_rules, use_rules

# The sharded query engine re-exports are lazy (PEP 562): model/train code
# imports repro.dist.context at module level and must not drag the whole
# query/storage/planner stack in with it -- the dist -> query dependency
# only materialises when somebody actually reaches for the sharded engine.
_QUERY_EXPORTS = (
    "ShardedBitmapIndex",
    "ShardedPlan",
    "ShardedResult",
    "ShardedTileStore",
    "shard_boundaries",
)


def __getattr__(name):
    if name in _QUERY_EXPORTS:
        from . import query

        return getattr(query, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
