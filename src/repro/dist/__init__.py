"""Distributed substrate: sharding context, gradient compression, pipeline.

``context`` carries the active :class:`ShardingRules` so model code can
express sharding with *logical* axis names (``batch``, ``heads``...) and run
unchanged both unsharded (unit tests) and SPMD-partitioned (train/serve).
``compression`` implements the int8 ring all-reduce with error feedback;
``pipeline`` the microbatch pipeline schedule over a mesh axis.
"""

from .context import ShardingRules, axis_size, constrain, get_rules, use_rules
