"""Gradient compression: int8 ring all-reduce with error feedback.

The fp32 all-reduce moves ``2 (n-1)/n`` of the gradient bytes per device;
quantising each hop to int8 (per-tensor absmax scale) cuts the wire bytes
4x.  The quantisation bias is kept bounded across steps by error feedback:
the residual of each lossy reduction is added back into the next step's
gradient before compression (Karimireddy et al. style).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "quantize_int8",
    "dequantize_int8",
    "ErrorFeedback",
    "collective_bytes_saved",
]


def quantize_int8(x: jax.Array):
    """Per-tensor absmax int8 quantisation; returns (q, scale)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-30) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def _ring_allreduce_int8(x: jax.Array, axis_name: str, n: int) -> jax.Array:
    """All-reduce (sum) over ``axis_name`` with int8-quantised hops.

    Runs inside ``shard_map`` as the standard two-phase ring: a
    reduce-scatter (n-1 chunk hops, partial sums re-quantised per hop)
    followed by an all-gather in which each fully-reduced chunk is
    quantised ONCE by its owner and relayed verbatim -- so every device
    (owners included) decodes the *same* int8 payload and the result is
    bit-identical across the ring, which data-parallel training needs.
    Wire bytes per device: 2 (n-1)/n chunks of int8 = the fp32 psum's / 4.
    """
    perm = [(i, (i + 1) % n) for i in range(n)]
    shape = x.shape
    flat = x.reshape(-1)
    size = flat.shape[0]
    pad = (-size) % n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    chunks = flat.reshape(n, -1)
    idx = jax.lax.axis_index(axis_name)

    # reduce-scatter: at step s device i sends its running sum of chunk
    # (i - s) mod n; after n-1 steps device i owns chunk (i + 1) mod n
    def rs_step(chunks, s):
        send = jnp.take(chunks, (idx - s) % n, axis=0)
        q, scale = quantize_int8(send)
        q = jax.lax.ppermute(q, axis_name, perm)
        scale = jax.lax.ppermute(scale, axis_name, perm)
        return chunks.at[(idx - s - 1) % n].add(dequantize_int8(q, scale)), None

    chunks, _ = jax.lax.scan(rs_step, chunks, jnp.arange(n - 1))

    # all-gather: owner quantises its chunk once; the payload is forwarded
    # unchanged so every device writes identical decoded values
    own = (idx + 1) % n
    q, scale = quantize_int8(jnp.take(chunks, own, axis=0))
    chunks = chunks.at[own].set(dequantize_int8(q, scale))

    def ag_step(carry, s):
        chunks, q, scale = carry
        q = jax.lax.ppermute(q, axis_name, perm)
        scale = jax.lax.ppermute(scale, axis_name, perm)
        chunks = chunks.at[(idx - s) % n].set(dequantize_int8(q, scale))
        return (chunks, q, scale), None

    (chunks, _, _), _ = jax.lax.scan(ag_step, (chunks, q, scale), jnp.arange(n - 1))
    return chunks.reshape(-1)[:size].reshape(shape)


class ErrorFeedback:
    """Residual accumulator making lossy gradient reduction unbiased-ish.

    ``apply(grads, reduce_fn)`` adds the stored residual into ``grads``,
    runs the (lossy) ``reduce_fn``, and stores the new residual
    ``corrected - reduced`` so compression errors cancel over steps instead
    of compounding.
    """

    def __init__(self):
        self.residual = None

    def apply(self, grads, reduce_fn):
        if self.residual is None:
            self.residual = jax.tree.map(jnp.zeros_like, grads)
        corrected = jax.tree.map(jnp.add, grads, self.residual)
        reduced = reduce_fn(corrected)
        self.residual = jax.tree.map(jnp.subtract, corrected, reduced)
        return reduced


def collective_bytes_saved(n_elems: int, n_devices: int) -> dict:
    """Wire-byte accounting: fp32 psum ring vs int8 ring (per device)."""
    hops = 2 * (n_devices - 1) / n_devices  # reduce-scatter + all-gather
    fp32 = hops * n_elems * 4
    int8 = hops * n_elems * 1
    return {
        "fp32_psum_bytes": fp32,
        "int8_ring_bytes": int8,
        "saved_bytes": fp32 - int8,
    }
