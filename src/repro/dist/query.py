"""Row-sharded multi-device execution engine for BitmapIndex queries.

The paper's algorithms assume one machine; Roaring's container-per-chunk
design shows the row space is the natural unit of both compression and
parallelism, and threshold / symmetric functions are computed *pointwise*
per row position -- so a row-range shard of every column is a complete,
independent sub-problem whose result is again a bitmap shard.  That is
exactly what composes: sharded results feed back as sharded columns via
``add_column`` with no gather.

  * :class:`ShardedTileStore` partitions a :class:`~repro.storage.TileStore`
    into contiguous tile ranges, one per device shard.  Slicing shares the
    classified tiles and dirty words (no reclassification); each shard
    carries its own tile classes, dirty pack, offsets table and member
    statistics.
  * :class:`ShardedBitmapIndex` compiles ONE circuit per query shape
    (shared through the process-wide compiled cache) and plans PER SHARD:
    the planner's words-touched cost model runs on each shard's local
    statistics, so a mostly-clean shard takes ``tiled_fused`` while a dense
    shard takes the circuit path -- heterogeneous backends behind one
    ``execute`` call, each dispatched through the same
    :func:`repro.query.executors.run_plan` entrypoint.
  * When every shard's plan is dense-circuit-evaluable and a mesh is
    installed, the whole query runs as one ``shard_map`` over the
    device-sharded word axis (the SPMD fast path); otherwise shards run
    host-sequenced, each on its own representation.

An 8-device host-platform CPU mesh (``XLA_FLAGS=
--xla_force_host_platform_device_count=8``) exercises the full path in CI.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitmaps import WORD_DTYPE, packed_tail_mask
from repro.core.planner import Plan, plan_query
from repro.storage import TileStore

__all__ = [
    "ShardedTileStore",
    "ShardedBitmapIndex",
    "ShardedResult",
    "ShardedPlan",
    "shard_boundaries",
]

# Backends whose result is exactly "evaluate the compiled circuit" -- under
# the SPMD path the one shared circuit is evaluated in-place of any of them
# (bit-identical: every backend computes the same Boolean function).  The
# tile-skipping / host-list backends stay shard-local, and so do the
# scancount executors: they are chosen precisely when N is too large to
# tabulate a per-(N, T) circuit, so substituting circuit evaluation there
# would compile the very adder the plan is avoiding.
_SPMD_BACKENDS = frozenset(
    (
        "circuit", "fused", "ssum", "treeadd", "srtckt", "sopckt", "csvckt",
        "wide_or", "wide_and", "looped",
    )
)


def _shard_map():
    """The shard_map entrypoint across jax versions (None if unavailable)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map
    try:
        from jax.experimental.shard_map import shard_map

        return shard_map
    except ImportError:  # pragma: no cover
        return None


# Jitted shard_map runners cached by circuit STRUCTURE (+ mesh/axis), like
# kernels.threshold_ssum's structural jit cache: repeated queries -- the
# serving admission loop above all -- trace and compile once per circuit
# shape, never once per call.
_SPMD_RUNNERS: dict = {}
_SPMD_RUNNERS_CAP = 256


def _spmd_runner(circuit, mesh, axis: str, n: int, spmd):
    from jax.sharding import PartitionSpec as P

    from repro.kernels.threshold_ssum import circuit_structural_key

    key = (circuit_structural_key(circuit), mesh, axis, n)
    fn = _SPMD_RUNNERS.get(key)
    if fn is None:
        if len(_SPMD_RUNNERS) >= _SPMD_RUNNERS_CAP:
            _SPMD_RUNNERS.clear()

        def local(blk):
            outs = circuit.evaluate([blk[i] for i in range(n)])
            return jnp.stack([jnp.broadcast_to(o, blk.shape[1:]) for o in outs])

        fn = jax.jit(
            spmd(local, mesh=mesh, in_specs=P(None, axis), out_specs=P(None, axis))
        )
        _SPMD_RUNNERS[key] = fn
    return fn


def shard_boundaries(n_tiles: int, n_shards: int) -> tuple:
    """Contiguous tile ranges [(t0, t1), ...], as even as possible."""
    n_shards = max(1, min(int(n_shards), int(n_tiles)))
    base, extra = divmod(n_tiles, n_shards)
    bounds, t0 = [], 0
    for i in range(n_shards):
        t1 = t0 + base + (1 if i < extra else 0)
        bounds.append((t0, t1))
        t0 = t1
    return tuple(bounds)


class ShardedTileStore:
    """A TileStore partitioned into per-device row-range shards.

    Each shard is itself a :class:`~repro.storage.TileStore` over its tile
    range: its own classes, dirty pack, offsets table, and (lazily built)
    member statistics.  Stores stay immutable -- ``append`` / ``replace``
    return a new sharded store whose shards share the untouched columns.
    """

    def __init__(self, shards: tuple, tile_bounds: tuple, *, n_words: int,
                 r: int, mesh=None, axis: str = "data"):
        self.shards: tuple = tuple(shards)
        self.tile_bounds = tuple(tile_bounds)
        self.n_words = int(n_words)
        self.r = int(r)
        self.mesh = mesh
        self.axis = axis
        self.tile_words = self.shards[0].tile_words
        #: word offset of each shard's first word in the global row space
        self.word_offsets = tuple(t0 * self.tile_words for t0, _ in self.tile_bounds)
        self._dense_cache = None
        self._spmd_cache: dict = {}  # (mesh, axis) -> device-sharded dense

    @classmethod
    def from_store(cls, store: TileStore, *, n_shards: int | None = None,
                   mesh=None, axis: str = "data") -> "ShardedTileStore":
        if n_shards is None:
            n_shards = _axis_size(mesh, axis) if mesh is not None else 1
        bounds = shard_boundaries(store.n_tiles, n_shards)
        shards = tuple(store.slice_tiles(t0, t1) for t0, t1 in bounds)
        return cls(shards, bounds, n_words=store.n_words, r=store.r,
                   mesh=mesh, axis=axis)

    # -- accessors ---------------------------------------------------------
    @property
    def n(self) -> int:
        return self.shards[0].n

    @property
    def n_shards(self) -> int:
        return len(self.shards)

    def densify(self) -> jax.Array:
        """Global dense uint32[N, n_words] view (an explicit gather; cached
        -- the store is immutable)."""
        if self._dense_cache is None:
            self._dense_cache = jnp.concatenate(
                [s.densify() for s in self.shards], axis=1
            )
        return self._dense_cache

    def spmd_dense(self, mesh, axis: str) -> jax.Array:
        """Padded, device-sharded dense view for the shard_map path
        (cached per mesh/axis; columns stay resident across queries)."""
        key = (mesh, axis)
        got = self._spmd_cache.get(key)
        if got is None:
            from jax.sharding import NamedSharding, PartitionSpec as P

            s = _axis_size(mesh, axis)
            dense = self.densify()
            nw = dense.shape[1]
            w = -(-nw // s)  # equal per-device width
            if s * w != nw:
                dense = jnp.pad(dense, ((0, 0), (0, s * w - nw)))
            got = jax.device_put(dense, NamedSharding(mesh, P(None, axis)))
            self._spmd_cache[key] = got
        return got

    def member_stats(self, slots=None) -> tuple:
        """Per-shard planner statistics of a member subset."""
        return tuple(s.member_stats(slots) for s in self.shards)

    def with_shards(self, shards) -> "ShardedTileStore":
        """New sharded store with the shard stores swapped out -- the
        streaming engine's per-shard overlay/compaction constructor
        (``repro.stream``).  Accepts TileStore-shaped objects (e.g.
        ``OverlayStore`` read views); tile bounds are recomputed from the
        shards' own sizes, so growth in the LAST shard (``append_rows``
        extending the universe) is reflected without resharding.  Interior
        shards hold only whole tiles, so their boundaries cannot move."""
        shards = tuple(shards)
        if len(shards) != self.n_shards:
            raise ValueError(f"{len(shards)} shards for {self.n_shards}")
        bounds, t0 = [], 0
        for s in shards:
            bounds.append((t0, t0 + s.n_tiles))
            t0 = bounds[-1][1]
        off_words = bounds[-1][0] * self.tile_words
        return ShardedTileStore(
            shards, bounds,
            n_words=off_words + shards[-1].n_words,
            r=off_words * 32 + shards[-1].r,
            mesh=self.mesh, axis=self.axis,
        )

    # -- immutable updates -------------------------------------------------
    def split(self, packed) -> tuple:
        """Split a global packed row uint32[n_words] into per-shard parts."""
        row = jnp.asarray(packed, WORD_DTYPE)
        if row.shape != (self.n_words,):
            raise ValueError(f"expected shape ({self.n_words},), got {row.shape}")
        parts, off = [], list(self.word_offsets) + [self.n_words]
        for i in range(self.n_shards):
            parts.append(row[off[i] : off[i + 1]])
        return tuple(parts)

    def _as_parts(self, packed_or_parts) -> tuple:
        if isinstance(packed_or_parts, (tuple, list)):
            parts = tuple(packed_or_parts)
            if len(parts) != self.n_shards:
                raise ValueError(
                    f"{len(parts)} parts for {self.n_shards} shards"
                )
            return parts
        return self.split(packed_or_parts)

    def append(self, packed_or_parts) -> "ShardedTileStore":
        """New sharded store with one more column.  Accepts per-shard parts
        (a query result's shards -- NO gather) or a global packed row."""
        parts = self._as_parts(packed_or_parts)
        return ShardedTileStore(
            tuple(s.append(p) for s, p in zip(self.shards, parts)),
            self.tile_bounds, n_words=self.n_words, r=self.r,
            mesh=self.mesh, axis=self.axis,
        )

    def replace(self, i: int, packed_or_parts) -> "ShardedTileStore":
        """New sharded store with column ``i`` swapped (shard-wise)."""
        parts = self._as_parts(packed_or_parts)
        return ShardedTileStore(
            tuple(s.replace(i, p) for s, p in zip(self.shards, parts)),
            self.tile_bounds, n_words=self.n_words, r=self.r,
            mesh=self.mesh, axis=self.axis,
        )


@dataclasses.dataclass(frozen=True)
class ShardedResult:
    """A query result that never left its shards: one packed bitmap piece
    per shard (already tail-masked to the shard's universe slice).  Feed it
    straight back via ``ShardedBitmapIndex.add_column`` -- composing results
    is the whole point of keeping them bitmaps (1402.4466), and sharding
    preserves it because symmetric functions are pointwise per row."""

    shards: tuple  # uint32[local_words] per shard
    word_offsets: tuple
    n_words: int
    r: int

    def gather(self) -> jax.Array:
        """Materialise the global packed bitmap (the one explicit gather)."""
        return jnp.concatenate([jnp.asarray(s) for s in self.shards])


@dataclasses.dataclass(frozen=True)
class ShardedPlan:
    """Per-shard plans for one query (the heterogeneous-backend contract)."""

    plans: tuple  # core.planner.Plan per shard

    @property
    def backends(self) -> tuple:
        return tuple(p.algorithm for p in self.plans)

    @property
    def distinct(self) -> tuple:
        return tuple(sorted(set(self.backends)))

    @property
    def cost(self) -> float:
        return float(sum(p.cost or 0.0 for p in self.plans))


def _axis_size(mesh, axis: str) -> int:
    from repro.launch.mesh import mesh_axis_sizes

    sizes = mesh_axis_sizes(mesh)
    if axis not in sizes:
        raise ValueError(f"mesh has no axis {axis!r}; axes: {tuple(sizes)}")
    return int(sizes[axis])


class ShardedBitmapIndex:
    """A BitmapIndex whose row space lives in per-device shards.

    ``execute`` compiles ONE circuit (process-wide cache, shared with the
    unsharded engine) and runs a per-shard plan: every shard's backend is a
    shard-local function dispatched through ``run_plan``; with a mesh and
    all-dense plans the query instead runs as a single ``shard_map``.
    Results are :class:`ShardedResult`s and feed back via
    :meth:`add_column` without a gather.  Like ``BitmapIndex``, instances
    are immutable -- ``add_column`` / ``replace_column`` return a NEW index
    and stale references keep executing against their own schema.
    """

    def __init__(self, store: ShardedTileStore, names: tuple):
        self.store = store
        self._names = tuple(names)
        if len(self._names) != store.n:
            raise ValueError(f"{len(self._names)} names for {store.n} columns")
        self._slot = {name: i for i, name in enumerate(self._names)}
        self.r = store.r
        self.n_words = store.n_words
        #: merged info of the last execution (per-shard backends + accounting)
        self.last_info: dict | None = None

    @classmethod
    def from_index(cls, index, *, mesh=None, axis: str = "data",
                   n_shards: int | None = None) -> "ShardedBitmapIndex":
        store = ShardedTileStore.from_store(
            index.store, n_shards=n_shards, mesh=mesh, axis=axis
        )
        return cls(store, index.names)

    # -- accessors ---------------------------------------------------------
    @property
    def names(self) -> tuple:
        return self._names

    @property
    def n(self) -> int:
        return self.store.n

    @property
    def n_shards(self) -> int:
        return self.store.n_shards

    @property
    def mesh(self):
        return self.store.mesh

    def __contains__(self, name: str) -> bool:
        return name in self._slot

    def __getitem__(self, name: str):
        from repro.query.expr import Col

        if name not in self._slot:
            raise KeyError(f"unknown column {name!r}")
        return Col(name)

    def column(self, name: str) -> jax.Array:
        """Gathered dense view of one column (for host-side comparisons)."""
        if name not in self._slot:
            raise KeyError(f"unknown column {name!r}")
        i = self._slot[name]
        return jnp.concatenate([s.densify()[i] for s in self.store.shards])

    # -- immutable updates -------------------------------------------------
    def add_column(self, name: str, result) -> "ShardedBitmapIndex":
        """New index with a (virtual) column appended shard-wise.  ``result``
        is a :class:`ShardedResult`, per-shard parts, or a global packed row;
        sharded results are consumed with NO gather."""
        if name in self._slot:
            raise ValueError(f"column {name!r} already exists")
        parts = result.shards if isinstance(result, ShardedResult) else result
        return ShardedBitmapIndex(
            self.store.append(parts), self._names + (name,)
        )

    def replace_column(self, name: str, result) -> "ShardedBitmapIndex":
        """New index with one column's shards swapped; untouched columns
        share storage, stale references keep working."""
        if name not in self._slot:
            raise KeyError(f"unknown column {name!r}")
        parts = result.shards if isinstance(result, ShardedResult) else result
        return ShardedBitmapIndex(
            self.store.replace(self._slot[name], parts), self._names
        )

    # -- planning ----------------------------------------------------------
    def _member_slots(self, q):
        from repro.query.index import member_slots

        return member_slots(q, self._slot)

    def _bare_slots(self, q):
        from repro.query.index import bare_slots

        return bare_slots(q, self._slot)

    def plan(self, query) -> ShardedPlan:
        """Per-shard plans from each shard's LOCAL member statistics -- a
        mostly-clean shard gets ``tiled_fused`` while a dense shard gets the
        circuit path, behind the same query call."""
        from repro.query.expr import as_query
        from repro.query.index import _fused_available

        q = as_query(query)
        slots = self._member_slots(q)
        fused = _fused_available()
        return ShardedPlan(
            tuple(
                plan_query(q, self.n, stats=shard.member_stats(slots),
                           fused_available=fused)
                for shard in self.store.shards
            )
        )

    # -- execution ---------------------------------------------------------
    def execute(self, query, *, backend: str | None = None,
                block_words: int | None = None) -> ShardedResult:
        """Evaluate one expression across every shard.  Returns a
        :class:`ShardedResult` (per-shard packed bitmaps, tail-masked)."""
        from repro.query.expr import as_query

        q = as_query(query)
        outs = self._execute_circuit((q,), [q], backend, block_words)
        return outs[0]

    def execute_many(self, queries, *, backend: str | None = None,
                     block_words: int | None = None) -> list:
        """Evaluate independent queries: ONE multi-output circuit, one
        per-shard plan, one dirty-tile gather (tiled shards) or one
        evaluation sweep (dense shards) shared by all of them."""
        from repro.query.expr import as_query

        qs = [as_query(x) for x in queries]
        return self._execute_circuit(tuple(qs), qs, backend, block_words)

    # -- internals ---------------------------------------------------------
    def _circuit_fn(self, qs: tuple):
        from repro.query.index import circuit_for

        return lambda: circuit_for(qs, self.n, self._names)

    def _execute_circuit(self, qs: tuple, qlist, backend, block_words) -> list:
        import time as _time

        import repro.obs as _obs
        from repro.obs import trace as _trace

        active = _trace.enabled or _obs.REGISTRY.enabled
        t0 = _time.perf_counter() if active else 0.0
        with _trace.span(
            "execute_sharded", n_shards=self.n_shards, n_queries=len(qlist)
        ) as root:
            out = self._execute_circuit_inner(
                qs, qlist, backend, block_words
            )
            if active:
                self._observe(root, _time.perf_counter() - t0)
        return out

    def _observe(self, root, wall_s: float) -> None:
        """Predicted-vs-measured accounting for the whole sharded call."""
        import repro.obs as _obs

        info = self.last_info or {}
        measured = info.get("words_touched")
        plans = getattr(self, "_last_plans", None)
        costs = [
            p.cost for p in (plans.plans if plans else ())
            if getattr(p, "cost", None) is not None
        ]
        backends = sorted(set(info.get("backends", ())))
        label = backends[0] if len(backends) == 1 else "mixed"
        root.set(
            mode=info.get("mode"),
            backends=backends,
            predicted_words=sum(costs) if costs else None,
            measured_words=measured,
        )
        if measured is not None:
            _obs.record_drift(
                label, sum(costs) if costs else None, measured, wall_s
            )

    def _execute_circuit_inner(self, qs: tuple, qlist, backend, block_words) -> list:
        circ_fn = self._circuit_fn(qs)
        if backend is not None:
            plans = ShardedPlan(
                tuple(Plan(backend, "caller override") for _ in self.store.shards)
            )
        elif len(qlist) == 1:
            plans = self.plan(qlist[0])
        else:
            # multi-query: plan each shard once over all columns; any shard
            # whose stats favour skipping runs the whole batch tiled, the
            # rest evaluate the multi-output circuit (only circuit-family
            # backends can produce k outputs in one pass)
            from repro.query.index import _fused_available

            fused = _fused_available()
            shard_plans = []
            for shard in self.store.shards:
                p = plan_query(qlist[0], self.n, stats=shard.member_stats(None),
                               fused_available=fused)
                if p.algorithm != "tiled_fused":
                    p = Plan("fused" if fused else "circuit",
                             f"multi-query batch (shard plan was {p.algorithm})",
                             cost=p.cost, candidates=p.candidates)
                shard_plans.append(p)
            plans = ShardedPlan(tuple(shard_plans))
        self._last_plans = plans
        k = len(qlist)
        spmd = _shard_map()
        if (
            self.mesh is not None
            and spmd is not None
            and all(b in _SPMD_BACKENDS for b in plans.backends)
        ):
            stacked = self._run_spmd(circ_fn(), k, spmd)
            self.last_info = {
                "mode": "shard_map",
                "backends": plans.backends,
                "n_shards": self.n_shards,
            }
        else:
            stacked = self._run_per_shard(circ_fn, qlist, plans, block_words)
        results = []
        for j in range(k):
            results.append(
                ShardedResult(
                    shards=tuple(stacked[i][j] for i in range(self.n_shards)),
                    word_offsets=self.store.word_offsets,
                    n_words=self.n_words,
                    r=self.r,
                )
            )
        return results

    def _run_spmd(self, circuit, k: int, spmd) -> list:
        """One shard_map over the device-sharded word axis: every device
        evaluates the same compiled circuit on its local words (threshold /
        symmetric functions are pointwise per row position, so the split is
        exact).  Columns, the jitted runner, and the results all stay
        device-resident across calls (both caches are keyed structurally)."""
        mesh, axis = self.mesh, self.store.axis
        arr = self.store.spmd_dense(mesh, axis)
        fn = _spmd_runner(circuit, mesh, axis, self.n, spmd)
        out = fn(arr)[:, : self.n_words]
        # re-slice the global result at the store's real shard boundaries
        per_shard = []
        off = list(self.store.word_offsets) + [self.n_words]
        for i in range(self.n_shards):
            piece = out[:, off[i] : off[i + 1]]
            per_shard.append([self._mask_shard(piece[j], i) for j in range(k)])
        return per_shard

    def _run_per_shard(self, circ_fn, qlist, plans: ShardedPlan, block_words) -> list:
        """Heterogeneous path: each shard's plan dispatches through the one
        run_plan entrypoint against that shard's local representation."""
        from repro.obs import trace as _trace
        from repro.query.execinfo import merge_exec_infos
        from repro.query.executors import ShardContext, run_plan
        from repro.query.expr import Col
        from repro.query.index import _annotate_dispatch

        bare = self._bare_slots(qlist[0]) if len(qlist) == 1 else None
        colslot = (
            self._slot.get(qlist[0].name)
            if len(qlist) == 1 and type(qlist[0]) is Col
            else None
        )
        k = len(qlist)
        per_shard, infos = [], []
        for i, (shard, plan) in enumerate(zip(self.store.shards, plans.plans)):
            ctx = ShardContext(
                n=self.n,
                dense=shard.densify,
                store=lambda s=shard: s,
                circuit=circ_fn,
                bare=bare if k == 1 else None,
                column=colslot,
                block_words=block_words,
            )
            with _trace.span(
                "shard", shard=i, backend=getattr(plan, "algorithm", plan)
            ) as sp:
                out, info = run_plan(ctx, plan)
                if _trace.enabled and isinstance(info, dict):
                    _annotate_dispatch(sp, info)
            infos.append(info)
            if out.ndim == 1:
                out = out[None]
            # results stay device-resident; only the tiled path's internal
            # gather/scatter is host-orchestrated
            per_shard.append(
                [self._mask_shard(out[j], i) for j in range(k)]
            )
        # schema-driven merge (repro.query.execinfo): EVERY ExecInfo key is
        # folded by its registered rule -- counters sum, word-kind dicts add
        # key-wise, labels collect -- so a counter added to any backend can
        # never again be silently dropped on the sharded path
        self.last_info = {
            **merge_exec_infos(infos),
            "mode": "per_shard",
            "backends": plans.backends,
            "n_shards": self.n_shards,
            "per_shard": infos,
        }
        return per_shard

    def _mask_shard(self, out: jax.Array, i: int) -> jax.Array:
        """Tail-mask a shard's result to its slice of the universe."""
        shard = self.store.shards[i]
        mask = packed_tail_mask(shard.r, shard.n_words)
        return out if mask is None else jnp.bitwise_and(out, mask)

    def count(self, query, **kw) -> int:
        from repro.core.bitmaps import cardinality

        res = self.execute(query, **kw)
        return int(sum(int(cardinality(s)) for s in res.shards))

    # -- persistence -------------------------------------------------------
    def save(self, dirpath) -> dict:
        """Write one ``.bmsnap`` per shard plus the shard map
        (``repro.persist.shards``); returns the shard-map metadata.  Each
        device can later load ONLY its own file via
        :func:`repro.persist.load_shard`."""
        from repro.persist import save_sharded

        return save_sharded(self, dirpath)

    @classmethod
    def load(cls, dirpath, *, mesh=None, axis: str = "data",
             to_device: bool = False,
             verify: bool = False) -> "ShardedBitmapIndex":
        """Rebuild a saved sharded index, shard files mapped in place --
        no gather, no reclassification."""
        from repro.persist import load_sharded

        return load_sharded(dirpath, mesh=mesh, axis=axis,
                            to_device=to_device, verify=verify)
