"""repro: Threshold and Symmetric Functions over Bitmaps (Kaser & Lemire,
2014) as a production-grade multi-pod JAX/TPU framework.

Subpackages: core (the paper), storage (tiled hybrid column store),
query (expression language + BitmapIndex), kernels (Pallas), models
(10-arch zoo), train / serve / data / ckpt / ft (substrate), dist
(parallelism), configs (arch registry), launch (mesh / dryrun / train /
serve drivers).
"""

__version__ = "1.0.0"
