"""Pallas TPU kernels for the paper's bit-parallel hot spot.

threshold_ssum: fused sideways-sum threshold/symmetric circuit evaluation.
tiled_scan: single-scan tiled engine -- in-kernel container decode, one
block-unrolled dispatch over all residual groups, device event merge.
ops: jit wrappers (interpret=True off-TPU).  ref: pure-jnp oracles.
"""

from .ops import fused_interval, fused_symmetric, fused_threshold, fused_weighted_threshold
from .ref import symmetric_ref, threshold_ref
from .threshold_ssum import pick_block_words, threshold_pallas
from .tiled_scan import block_runner, clear_scan_runners, event_runner
