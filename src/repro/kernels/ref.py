"""Pure-jnp oracles for the kernels (SCANCOUNT-style vertical counters)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def _counts(bitmaps: jax.Array) -> jax.Array:
    """int32 per-position counts, shape [n_words, 32]."""
    bitmaps = jnp.asarray(bitmaps, jnp.uint32)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = (bitmaps[:, :, None] >> shifts) & jnp.uint32(1)
    return jnp.sum(bits.astype(jnp.int32), axis=0)


@partial(jax.jit, static_argnames=("t",))
def threshold_ref(bitmaps: jax.Array, t: int) -> jax.Array:
    """Oracle for the fused threshold kernel: counts >= T, packed."""
    c = _counts(bitmaps)
    shifts = jnp.arange(32, dtype=jnp.uint32)
    ge = (c >= t).astype(jnp.uint32)
    return jnp.sum(ge << shifts, axis=-1, dtype=jnp.uint32)


@partial(jax.jit, static_argnames=("truth",))
def symmetric_ref(bitmaps: jax.Array, truth: tuple) -> jax.Array:
    """Oracle for the fused symmetric kernel: truth[count], packed."""
    c = _counts(bitmaps)
    table = jnp.asarray(truth, jnp.uint32)
    val = table[c]
    shifts = jnp.arange(32, dtype=jnp.uint32)
    return jnp.sum(val << shifts, axis=-1, dtype=jnp.uint32)
