"""Jit'd public wrappers for the Pallas kernels.

On the CPU container the kernels run with interpret=True (the Pallas
interpreter executes the kernel body in Python); on TPU backends the same
call lowers through Mosaic.  ``INTERPRET`` auto-detects.

.. deprecated:: these wrappers are thin shims over ``repro.query``; prefer
   ``BitmapIndex.execute``, which plans the backend itself from TileStore
   statistics and lets fused queries compose (one kernel launch for a whole
   expression tree).  The shims keep their fused-kernel contract on dense
   data, but when the transient index's tile statistics favour skipping
   they route through the ``tiled_fused`` path -- same results, a fraction
   of the words touched.  The family emits ONE consolidated
   DeprecationWarning per process (``core.deprecation``).
"""
from __future__ import annotations

import jax

from repro.core.deprecation import warn_legacy_shim

from .threshold_ssum import INTERPRET, pick_block_words, threshold_pallas  # noqa: F401


def _execute_fused(name, bitmaps, expr, block_words=None):
    warn_legacy_shim(name)
    from repro.query import BitmapIndex

    idx = BitmapIndex(bitmaps)
    plan = idx.explain(expr)
    backend = "tiled_fused" if plan.algorithm == "tiled_fused" else "fused"
    return idx.execute(expr, backend=backend, block_words=block_words)


def fused_threshold(bitmaps: jax.Array, t: int, block_words: int | None = None) -> jax.Array:
    """Fused theta(T, .) over packed bitmaps uint32[N, n_words]."""
    from repro.query import Threshold

    return _execute_fused(
        "kernels.ops.fused_threshold", bitmaps, Threshold(t), block_words
    )


def fused_symmetric(bitmaps: jax.Array, truth, block_words: int | None = None) -> jax.Array:
    """Fused arbitrary symmetric function given truth[w] for w = 0..N."""
    from repro.query import Sym

    return _execute_fused(
        "kernels.ops.fused_symmetric", bitmaps, Sym(tuple(truth)), block_words
    )


def fused_interval(bitmaps: jax.Array, lo: int, hi: int) -> jax.Array:
    from repro.query import Interval

    return _execute_fused("kernels.ops.fused_interval", bitmaps, Interval(lo, hi))


def fused_weighted_threshold(bitmaps: jax.Array, weights, t: int) -> jax.Array:
    """Fused weighted threshold (binary weight decomposition, core/weighted)."""
    from repro.query import Weighted

    return _execute_fused(
        "kernels.ops.fused_weighted_threshold",
        bitmaps,
        Weighted(tuple(int(w) for w in weights), t),
    )
