"""Jit'd public wrappers for the Pallas kernels.

On the CPU container the kernels run with interpret=True (the Pallas
interpreter executes the kernel body in Python); on TPU backends the same
call lowers through Mosaic.  ``INTERPRET`` auto-detects.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .threshold_ssum import pick_block_words, threshold_pallas

INTERPRET = jax.default_backend() != "tpu"


def fused_threshold(bitmaps: jax.Array, t: int, block_words: int | None = None) -> jax.Array:
    """Fused theta(T, .) over packed bitmaps uint32[N, n_words]."""
    return threshold_pallas(bitmaps, t, block_words=block_words, interpret=INTERPRET)


def fused_symmetric(bitmaps: jax.Array, truth, block_words: int | None = None) -> jax.Array:
    """Fused arbitrary symmetric function given truth[w] for w = 0..N."""
    return threshold_pallas(
        bitmaps, None, truth=tuple(bool(x) for x in truth), block_words=block_words,
        interpret=INTERPRET,
    )


def fused_interval(bitmaps: jax.Array, lo: int, hi: int) -> jax.Array:
    n = bitmaps.shape[0]
    return fused_symmetric(bitmaps, tuple(lo <= w <= hi for w in range(n + 1)))


def fused_weighted_threshold(bitmaps: jax.Array, weights, t: int) -> jax.Array:
    """Fused weighted threshold (binary weight decomposition, core/weighted)."""
    return threshold_pallas(
        bitmaps, t, weights=tuple(int(w) for w in weights), interpret=INTERPRET
    )
