"""Jit'd public wrappers for the Pallas kernels.

On the CPU container the kernels run with interpret=True (the Pallas
interpreter executes the kernel body in Python); on TPU backends the same
call lowers through Mosaic.  ``INTERPRET`` auto-detects.

.. deprecated:: these wrappers are thin shims over ``repro.query`` with an
   explicit ``backend="fused"`` override; prefer ``BitmapIndex.execute``,
   which also picks the fused backend by itself on TPU and lets fused
   queries compose (one kernel launch for a whole expression tree).
"""
from __future__ import annotations

import jax

from .threshold_ssum import INTERPRET, pick_block_words, threshold_pallas  # noqa: F401


def fused_threshold(bitmaps: jax.Array, t: int, block_words: int | None = None) -> jax.Array:
    """Fused theta(T, .) over packed bitmaps uint32[N, n_words]."""
    from repro.query import Threshold, execute

    return execute(bitmaps, Threshold(t), backend="fused", block_words=block_words)


def fused_symmetric(bitmaps: jax.Array, truth, block_words: int | None = None) -> jax.Array:
    """Fused arbitrary symmetric function given truth[w] for w = 0..N."""
    from repro.query import Sym, execute

    return execute(bitmaps, Sym(tuple(truth)), backend="fused", block_words=block_words)


def fused_interval(bitmaps: jax.Array, lo: int, hi: int) -> jax.Array:
    from repro.query import Interval, execute

    return execute(bitmaps, Interval(lo, hi), backend="fused")


def fused_weighted_threshold(bitmaps: jax.Array, weights, t: int) -> jax.Array:
    """Fused weighted threshold (binary weight decomposition, core/weighted)."""
    from repro.query import Weighted, execute

    return execute(bitmaps, Weighted(tuple(int(w) for w in weights), t), backend="fused")
