"""Single-scan tiled execution: in-kernel container decode + launch collapse.

The legacy ``run_tiled_circuit`` pass dispatched one gather + one
``run_circuit_cached`` launch per structurally distinct residual circuit,
resolved compressed-only tiles with a *host* numpy event merge, and wrote
every partial result back into a host ``out`` array.  At small scale those
per-group launches and host round trips dominate wall time even when the
words-touched model says tiled execution should win.

This module collapses the whole case-3 workload into O(1) device
dispatches:

  * **Block stage** -- every tile that needs dense bit work is assigned to
    a fixed-size *block* of ``B`` tiles belonging to one residual group.
    A decode prologue materialises each residual-input cell directly from
    the store's device-resident container packs: dense cells are rows of
    the (sentinel-augmented) dense pack, sparse cells bit-scatter their
    uint16 position lists, run cells toggle-scatter their interval
    endpoints and fill with a branch-free prefix-XOR -- the device port of
    :func:`repro.storage.containers.rasterize_toggles`.  The blocks are
    then evaluated by ONE kernel: a block-unrolled ``lax.scan`` over
    (group id, block) pairs whose body ``lax.switch``-es into the right
    residual evaluator (XLA path, default off-TPU), or a Pallas grid
    kernel with a scalar-prefetched group-id vector (TPU path -- the grid
    auto-pipelines the block DMA, i.e. double-buffered HBM->VMEM).

  * **Event stage** -- tiles whose residual inputs are ALL sparse/run
    containers (and whose payload undercuts the dense gather) skip block
    decode entirely: their boundary events are sorted on device
    (``lax.sort``), per-input masks XOR-accumulated (associative scan),
    each segment's input combination mapped through stacked per-group
    truth-table LUTs, and value changes rasterized back to packed words
    -- the device port of
    :func:`repro.storage.containers.evaluate_event_tiles`, all groups in
    one dispatch.

  * **Output assembly** -- both stages scatter into one device-resident
    ``[k, n_tiles + 1, tile_words]`` buffer (slot ``n_tiles`` is a dummy
    target for padding lanes) seeded by broadcasting the per-tile
    constant-fold values, so unrestricted queries never round-trip
    through a host ``out`` array.

Carry-free scatter invariants (JAX has no XOR-scatter, so every scatter
below must be provably collision-free under ``.at[].add``):

  * sparse positions are sorted and distinct per cell -> distinct bits;
  * run containers store *maximal* intervals, so the 2i endpoints of a
    cell strictly increase -> distinct toggle positions;
  * the event stage only emits a toggle at the LAST event of each
    (row, position) run after the sort, so toggle positions are distinct
    per row (duplicate-position cancellation is resolved by comparing
    against the value *before* the run, found by a forward-fill of the
    run-start index).

Everything data-dependent is padded to power-of-two sizes by the plan
builder (``repro.storage.tiled``), so jit traces are shared across
queries that differ only in tile counts.
"""
from __future__ import annotations

from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import circuits as _ckt
from repro.obs import REGISTRY as _OBS

__all__ = [
    "block_runner",
    "event_runner",
    "clear_scan_runners",
    "next_pow2",
    "pad_to",
    "pick_tile_block",
]

_U32 = jnp.uint32

# dispatch accounting on the process registry (no-op until obs.enable()):
# launches per stage kind, words the decode prologue stages, and event
# toggles merged -- the device-side counterpart of ExecInfo's per-query
# numbers, aggregated process-wide across every store and query
_LAUNCHES = _OBS.counter(
    "repro_kernel_launches_total", "Device kernel dispatches", ("stage",),
)
_DECODE_WORDS = _OBS.counter(
    "repro_kernel_decode_words_total",
    "Dense-equivalent words staged by the in-kernel container decode",
)
_EVENT_TOGGLES = _OBS.counter(
    "repro_kernel_event_toggles_total",
    "Boundary toggles merged by the event stage",
)
# label keys pre-bound once: the launch loop incs these per dispatch
_LAUNCH_BLOCK = _LAUNCHES.bind(stage="block")
_LAUNCH_EVENT = _LAUNCHES.bind(stage="event")
_DECODE_WORDS_B = _DECODE_WORDS.bind()
_EVENT_TOGGLES_B = _EVENT_TOGGLES.bind()

#: test hook: evaluate the block stage through the Pallas grid kernel even
#: in interpret mode (CPU), pinning the grid kernel against the XLA scan.
FORCE_PALLAS_INTERPRET = False

# compiled stage runners, keyed by (stage, circuit structures, static dims).
# Shape variation within a key is handled by jax.jit's own cache; padding
# to powers of two bounds how many shapes each key sees.
_RUNNERS: OrderedDict = OrderedDict()
_RUNNERS_CAP = 256


def clear_scan_runners() -> None:
    """Drop the compiled stage runners (wired into clear_compiled_cache)."""
    _RUNNERS.clear()


def next_pow2(x: int) -> int:
    """Smallest power of two >= max(x, 1)."""
    return 1 << max(0, int(x) - 1).bit_length()


def pad_to(a: np.ndarray, size: int, fill) -> np.ndarray:
    """``a`` grown to ``size`` along axis 0, new entries = ``fill``."""
    out = np.full((size,) + a.shape[1:], fill, a.dtype)
    out[: a.shape[0]] = a
    return out


def pick_tile_block(tile_words: int, m_max: int, k_max: int,
                    max_group_tiles: int,
                    vmem_budget_bytes: int = 2 * 1024 * 1024) -> int:
    """Tiles per block: lane-sized (1024 words) but shrunk so one block's
    input+output rows fit the VMEM budget, and never wider than the
    largest group needs."""
    from repro.kernels.threshold_ssum import LANE_WORDS

    b = max(1, LANE_WORDS // tile_words)
    while b > 1 and (m_max + k_max) * b * tile_words * 8 > vmem_budget_bytes:
        b //= 2
    return max(1, min(b, next_pow2(max_group_tiles)))


def _bit(pos):
    """1 << (pos % 32) as uint32 (pos: non-negative int32 array)."""
    return _U32(1) << (pos % 32).astype(_U32)


def _prefix_xor_words(t):
    """Toggle masks uint32[rows, tw + 1] -> filled words uint32[rows, tw].

    Device port of the tail of ``rasterize_toggles``: prefix-XOR within
    each word by doubling shifts, then carry word parities across the row
    with an associative scan (column ``tw`` catches toggles at the span
    boundary and is dropped)."""
    for sh in (1, 2, 4, 8, 16):
        t = t ^ (t << _U32(sh))
    par = t >> _U32(31)
    cum = jax.lax.associative_scan(jnp.bitwise_xor, par, axis=1)
    fill = jnp.concatenate([jnp.zeros_like(cum[:, :1]), cum[:, :-1]], axis=1)
    t = t ^ (fill * _U32(0xFFFFFFFF))
    return t[:, :-1]


def _expand_base(base, tw):
    """Per-tile constant words [k, n_sel1] -> full buffer [k, n_sel1, tw]."""
    if base.ndim == 2:
        return jnp.broadcast_to(base[:, :, None], base.shape + (tw,))
    return base


def block_runner(circuits: tuple, m_max: int, k_max: int, tw: int,
                 use_pallas: bool, interpret: bool):
    """Compiled block stage for a tuple of residual circuits.

    Returns ``fn(base, gids, dense_pack1, cell_src, sparse_pack1, sp_take,
    sp_cell, sp_rows, run_pack1, rn_take, rn_cell, rn_rows, dst)`` where

    * ``base``: uint32[k, n_sel1] constant fill values (expanded in-kernel)
      or uint32[k, n_sel1, tw] (already-assembled buffer from a previous
      stage); returns the updated [k, n_sel1, tw] buffer;
    * ``gids``: int32[nb] residual-group id per block;
    * ``dense_pack1``: uint32[D + 2, tw] dense pack + zeros/ones sentinels;
    * ``cell_src``: int32[nb * m_max * B + 1] dense-pack row per block cell
      (compressed cells point at the zeros sentinel and are overwritten by
      the decode prologue; the trailing entry is the scatter dummy row);
    * ``sp_take``/``sp_cell``: sparse payload take-indices and decode-row
      ids; ``sp_rows``: block-cell row per decode row (dummy -> sentinel);
    * ``rn_take``/``rn_cell``/``rn_rows``: same for run intervals;
    * ``dst``: int32[nb * k_max * B] flat output cell per block lane.
    """
    from repro.kernels.threshold_ssum import circuit_structural_key

    key = (
        "block",
        tuple(circuit_structural_key(c) for c in circuits),
        m_max, k_max, tw, bool(use_pallas), bool(interpret),
    )
    fn = _RUNNERS.get(key)
    if fn is not None:
        _RUNNERS.move_to_end(key)
        return fn

    def _eval_block(g, x):
        btw = x.shape[-1]
        zeros = jnp.zeros((btw,), _U32)
        ones = jnp.full((btw,), 0xFFFFFFFF, _U32)

        def _branch(circ):
            def f(xb):
                rows = [xb[i] for i in range(circ.n_inputs)]
                outs = circ.evaluate(rows, zeros=zeros, ones=ones)
                outs = list(outs) + [zeros] * (k_max - len(outs))
                return jnp.stack(outs)

            return f

        return jax.lax.switch(g, [_branch(c) for c in circuits], x)

    def _pallas_eval(gids, x):
        from jax.experimental import pallas as pl
        from jax.experimental.pallas import tpu as pltpu

        nb, _, btw = x.shape

        def _kernel(gids_ref, in_ref, out_ref):
            g = gids_ref[pl.program_id(0)]
            out_ref[0] = _eval_block(g, in_ref[0])

        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(nb,),
            in_specs=[pl.BlockSpec((1, m_max, btw), lambda b, g: (b, 0, 0))],
            out_specs=pl.BlockSpec((1, k_max, btw), lambda b, g: (b, 0, 0)),
        )
        return pl.pallas_call(
            _kernel,
            grid_spec=grid_spec,
            out_shape=jax.ShapeDtypeStruct((nb, k_max, btw), _U32),
            interpret=interpret,
        )(gids, x)

    def run(base, gids, dense_pack1, cell_src,
            sparse_pack1, sp_take, sp_cell, sp_rows,
            run_pack1, rn_take, rn_cell, rn_rows, dst):
        base = _expand_base(base, tw)
        nb = gids.shape[0]
        B = (cell_src.shape[0] - 1) // (nb * m_max)
        btw = B * tw
        # decode prologue: every residual-input cell materialised into the
        # block buffer straight from the container packs
        blocks = dense_pack1[cell_src]  # [nb*m_max*B + 1, tw]
        ncs1 = sp_rows.shape[0]
        pos = sparse_pack1[sp_take].astype(jnp.int32)
        sw = (
            jnp.zeros((ncs1 * tw,), _U32)
            .at[sp_cell * tw + pos // 32]
            .add(_bit(pos))
            .reshape(ncs1, tw)
        )
        blocks = blocks.at[sp_rows].set(sw)
        ncr1 = rn_rows.shape[0]
        iv = run_pack1[rn_take].astype(jnp.int32)
        t = jnp.zeros((ncr1 * (tw + 1),), _U32)
        t = t.at[rn_cell * (tw + 1) + iv[:, 0] // 32].add(_bit(iv[:, 0]))
        t = t.at[rn_cell * (tw + 1) + iv[:, 1] // 32].add(_bit(iv[:, 1]))
        rw = _prefix_xor_words(t.reshape(ncr1, tw + 1))
        blocks = blocks.at[rn_rows].set(rw)
        x = blocks[:-1].reshape(nb, m_max, btw)
        if use_pallas:
            ys = _pallas_eval(gids, x)
        else:
            def body(carry, gx):
                g, xb = gx
                return carry, _eval_block(g, xb)

            _, ys = jax.lax.scan(body, None, (gids, x))
        out = base.reshape(-1, tw).at[dst].set(ys.reshape(-1, tw))
        return out.reshape(base.shape)

    jitted = jax.jit(run)

    def fn(base, gids, dense_pack1, cell_src, *rest):
        if _OBS.enabled:
            _LAUNCH_BLOCK.inc(1)
            _DECODE_WORDS_B.inc((cell_src.shape[0] - 1) * tw)
        return jitted(base, gids, dense_pack1, cell_src, *rest)

    if len(_RUNNERS) >= _RUNNERS_CAP:
        _RUNNERS.popitem(last=False)
    _RUNNERS[key] = fn
    return fn


def event_runner(k_max: int, mm: int, tw: int):
    """Compiled event stage: ``mm = 2 ** m_max`` is the stacked-LUT stride.

    ``fn(base, keys, mask, gid_row, lut, out_dst)``:

    * ``keys``: int32[e_pad] toggle sort keys, ``row * (tw * 32 + 2) +
      pos`` -- PRE-SORTED ascending at plan-build time (the merge order is
      pure store data, so the host sorts once per cached plan instead of
      the device sorting per query); pad entries carry the dummy row's
      key, which exceeds every real key;
    * ``mask``: uint32[e_pad] per-toggle wire bit (``1 << wire``), riding
      the same order as ``keys``; pad entries are 0 (XOR no-op);
    * ``gid_row``: int32[n_rows1] event-group ordinal per row (dummy rows
      point at the zero group appended to ``lut``);
    * ``lut``: uint8[(G + 1) * k_max * mm] stacked truth tables,
      ``lut[(g * k_max + j) * mm + combo]`` = output j of group g on input
      combination ``combo``; entry 0 of each table is the background
      (all-inputs-zero) value;
    * ``out_dst``: int32[k_max, n_rows1] flat output cell per (output
      slot, event row), dummies -> the buffer's dummy tile.
    """
    key = ("event", k_max, mm, tw)
    fn = _RUNNERS.get(key)
    if fn is not None:
        _RUNNERS.move_to_end(key)
        return fn

    stride = tw * 32 + 2

    def run(base, keys, mask, gid_row, lut, out_dst):
        base = _expand_base(base, tw)
        n_rows1 = gid_row.shape[0]
        e = keys.shape[0]
        xacc = jax.lax.associative_scan(jnp.bitwise_xor, mask)
        rows_s = keys // stride
        pos_s = keys % stride
        iota = jnp.arange(e, dtype=jnp.int32)
        prev_key = jnp.concatenate(
            [jnp.full((1,), -1, keys.dtype), keys[:-1]]
        )
        starts = rows_s != prev_key // stride
        firsts = keys != prev_key
        lasts = jnp.concatenate(
            [keys[1:] != keys[:-1], jnp.ones((1,), bool)]
        )
        pxa = jnp.concatenate([jnp.zeros((1,), _U32), xacc[:-1]])
        sidx = jax.lax.associative_scan(
            jnp.maximum, jnp.where(starts, iota, -1)
        )
        # combo of the segment each event closes = running XOR minus the
        # carry-in from before this row (forward-filled row-start lookup)
        combo = ((xacc ^ pxa[sidx]) & _U32(mm - 1)).astype(jnp.int32)
        fidx = jax.lax.associative_scan(
            jnp.maximum, jnp.where(firsts, iota, -1)
        )
        g_ev = gid_row[rows_s]
        base_flat = base.reshape(-1, tw)
        t_size = n_rows1 * (tw + 1) + 1
        for j in range(k_max):
            lb = (g_ev * k_max + j) * mm
            vals = lut[lb + combo]
            pv = jnp.concatenate([jnp.zeros((1,), lut.dtype), vals[:-1]])
            pv = jnp.where(starts, lut[lb], pv)  # row start -> background
            # duplicate toggles at one position cancel: only the LAST event
            # of a (row, pos) run may toggle, and only if the value changed
            # relative to before the run
            tog = lasts & (vals != pv[fidx])
            tidx = jnp.where(
                tog, rows_s * (tw + 1) + pos_s // 32, t_size - 1
            )
            tval = jnp.where(tog, _bit(pos_s), _U32(0))
            t = jnp.zeros((t_size,), _U32).at[tidx].add(tval)
            words = _prefix_xor_words(t[:-1].reshape(n_rows1, tw + 1))
            bg = lut[(gid_row * k_max + j) * mm].astype(bool)
            words = jnp.where(bg[:, None], ~words, words)
            base_flat = base_flat.at[out_dst[j]].set(words)
        return base_flat.reshape(base.shape)

    jitted = jax.jit(run)

    def fn(base, keys, *rest):
        if _OBS.enabled:
            _LAUNCH_EVENT.inc(1)
            _EVENT_TOGGLES_B.inc(keys.shape[0])
        return jitted(base, keys, *rest)

    if len(_RUNNERS) >= _RUNNERS_CAP:
        _RUNNERS.popitem(last=False)
    _RUNNERS[key] = fn
    return fn
