"""Fused sideways-sum threshold kernel (Pallas, TPU target).

The paper's circuit algorithms are "horizontal": W bits of every input are
combined into W output bits using ~5N bitwise ops (4.4.3).  Evaluated as
composed jnp ops, every intermediate bit-plane round-trips through HBM --
~5N extra bitmap reads/writes.  The fused kernel streams one
(N, block_words) tile of packed words HBM->VMEM, evaluates the whole
sideways-sum + comparator network on VMEM values (VPU bitwise ops over
uint32 lanes), and writes a single (block_words,) output tile.

HBM traffic drops from ~(1 + 2*5)x input bytes to ~(1 + 1/N)x -- the
arithmetic intensity of the circuit (~5 VPU ops / 4 B) stays memory-bound,
so traffic is the roofline term and the fusion is worth ~an order of
magnitude (see EXPERIMENTS.md Perf, kernel section).

Tiling: the word axis is split into ``block_words`` chunks (grid dim 0);
the full N axis rides along in VMEM because every level of the adder needs
all lanes of the previous level.  VMEM footprint ~= (N input rows + ~N/2
live intermediates) * block_words * 4 B; ``pick_block_words`` sizes the
block to a VMEM budget and keeps it a multiple of 1024 words (8 * 128
lanes * 32 bits = one packed VPU tile of bit positions).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core import circuits as _ckt

LANE_WORDS = 1024  # words per (8,128) int32 vreg tile

# On the CPU container the kernels run with interpret=True (the Pallas
# interpreter executes the kernel body in Python); on TPU backends the same
# call lowers through Mosaic.
INTERPRET = jax.default_backend() != "tpu"


def pick_block_words(n: int, n_words: int, vmem_budget_bytes: int = 4 * 1024 * 1024) -> int:
    """Largest lane-aligned block s.t. ~2N live rows fit in the VMEM budget."""
    live_rows = max(2 * n, 4)
    bw = vmem_budget_bytes // (live_rows * 4)
    bw = max(LANE_WORDS, (bw // LANE_WORDS) * LANE_WORDS)
    total = ((n_words + LANE_WORDS - 1) // LANE_WORDS) * LANE_WORDS
    return min(bw, total)


def _circuit_kernel(in_ref, out_ref, *, circuit: _ckt.Circuit, n: int):
    rows = [in_ref[i, :] for i in range(n)]
    outs = circuit.evaluate(
        rows,
        zeros=jnp.zeros_like(rows[0]),
        ones=jnp.full_like(rows[0], 0xFFFFFFFF),
    )
    for j, out in enumerate(outs):
        out_ref[j, :] = out


def run_circuit_pallas(
    bitmaps: jax.Array,
    circuit: _ckt.Circuit,
    *,
    block_words: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """Evaluate an arbitrary (multi-output) circuit fused in VMEM.

    bitmaps: uint32[N, n_words] with N == circuit.n_inputs.  Returns
    uint32[n_words] for a single-output circuit, uint32[k, n_words]
    otherwise -- the batched-query path writes every output per tile while
    the inputs are resident, so k queries cost one HBM sweep, not k.
    """
    bitmaps = jnp.asarray(bitmaps, jnp.uint32)
    n, n_words = bitmaps.shape
    if circuit.n_inputs != n:
        raise ValueError(f"circuit has {circuit.n_inputs} inputs, bitmaps {n}")
    k = len(circuit.outputs)
    if block_words is None:
        # budget VMEM for the k output rows of the batched-query path too,
        # not just the ~2N live input/intermediate rows
        block_words = pick_block_words(n + k, n_words)
    padded = pl.cdiv(n_words, block_words) * block_words
    if padded != n_words:
        bitmaps = jnp.pad(bitmaps, ((0, 0), (0, padded - n_words)))
    grid = (padded // block_words,)
    out = pl.pallas_call(
        functools.partial(_circuit_kernel, circuit=circuit, n=n),
        grid=grid,
        in_specs=[pl.BlockSpec((n, block_words), lambda i: (0, i))],
        out_specs=pl.BlockSpec((k, block_words), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((k, padded), jnp.uint32),
        interpret=interpret,
    )(bitmaps)
    out = out[:, :n_words]
    return out[0] if k == 1 else out


# ---------------------------------------------------------------------------
# Structural jit cache: the tiled executor runs many small *data-dependent*
# residual circuits (one per tile-class signature), so caching by Python
# function identity (as jax.jit does) would recompile every call.  Keying by
# the circuit's structure lets repeated signatures -- across tiles, queries,
# and indexes -- share one compiled kernel.
# ---------------------------------------------------------------------------

_CIRCUIT_RUNNERS: dict[tuple, object] = {}
_CIRCUIT_RUNNERS_CAP = 1024  # residual circuits are data-dependent; bound them


def clear_circuit_runners() -> None:
    """Drop the structural jit cache (wired into query.clear_compiled_cache)."""
    _CIRCUIT_RUNNERS.clear()


def circuit_structural_key(circuit: _ckt.Circuit) -> tuple:
    """Hashable identity of a gate DAG (used to cache compiled evaluators)."""
    return (circuit.n_inputs, tuple(circuit.ops), tuple(circuit.outputs))


def run_circuit_cached(
    bitmaps: jax.Array,
    circuit: _ckt.Circuit,
    *,
    block_words: int | None = None,
    interpret: bool = False,
    pallas: bool = True,
) -> jax.Array:
    """Evaluate ``circuit`` via a jitted runner cached by circuit structure.

    ``pallas=True`` lowers through :func:`run_circuit_pallas` (fused VMEM
    evaluation); otherwise the gate DAG is evaluated as straight-line jnp
    bitwise code under one jit.  Returns uint32[n_words] (single output) or
    uint32[k, n_words].
    """
    key = (circuit_structural_key(circuit), block_words, interpret, pallas)
    fn = _CIRCUIT_RUNNERS.get(key)
    if fn is None:
        if len(_CIRCUIT_RUNNERS) >= _CIRCUIT_RUNNERS_CAP:
            _CIRCUIT_RUNNERS.clear()
        if pallas:
            def run(bm, _c=circuit):
                return run_circuit_pallas(
                    bm, _c, block_words=block_words, interpret=interpret
                )
        else:
            def run(bm, _c=circuit):
                outs = _c.evaluate([bm[i] for i in range(bm.shape[0])])
                return outs[0] if len(outs) == 1 else jnp.stack(outs)
        fn = jax.jit(run)
        _CIRCUIT_RUNNERS[key] = fn
    return fn(bitmaps)


@functools.partial(
    jax.jit, static_argnames=("t", "block_words", "interpret", "kind", "truth", "weights")
)
def threshold_pallas(
    bitmaps: jax.Array,
    t: int | None = None,
    *,
    truth: tuple | None = None,
    weights: tuple | None = None,
    kind: str = "ssum",
    block_words: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    """theta(T, .) fused; ``truth`` selects an arbitrary symmetric function,
    ``weights`` a weighted threshold (binary-decomposed circuit).

    bitmaps: uint32[N, n_words].  Returns uint32[n_words].
    """
    bitmaps = jnp.asarray(bitmaps, jnp.uint32)
    n, n_words = bitmaps.shape
    if weights is not None:
        from repro.core.weighted import build_weighted_threshold_circuit

        assert t is not None and len(weights) == n
        circuit = build_weighted_threshold_circuit(list(weights), t)
    elif truth is not None:
        circuit = _ckt.build_symmetric_circuit(n, list(truth), kind)
    else:
        assert t is not None
        if t <= 0:
            return jnp.full((n_words,), 0xFFFFFFFF, jnp.uint32)
        if t > n:
            return jnp.zeros((n_words,), jnp.uint32)
        circuit = _ckt.build_threshold_circuit(n, t, kind)
    return run_circuit_pallas(
        bitmaps, circuit, block_words=block_words, interpret=interpret
    )
