"""Attention-mask composition over packed bitmaps + KV-tile skip lists.

The paper's machinery applied to serving: a decode step's attention mask is
the conjunction/threshold of several *criteria bitmaps* over KV positions
(causal validity, sliding window, same-document, not-padding, retrieval
votes...).  Masks are packed uint32 rows (32 KV positions/word), composed
with `core.threshold` / logical ops, and classified into clean/dirty tiles
by the storage engine (`repro.storage.TileStore`) -- all-zero tiles are
skipped entirely by a block-sparse attention consumer (the skip decision
is made host/launch side, the paper's EWAH fast-forward insight).

`head_vote_mask` is the threshold showcase: K heads (or retrieval scorers)
each nominate KV pages they consider important; a page is kept if >= T of
them agree -- exactly a T-occurrence query over vote bitmaps.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitmaps import n_words_for, pack, unpack
from repro.core.threshold import threshold
from repro.storage import TILE_ZERO, TileStore

__all__ = [
    "causal_mask_bitmap",
    "window_mask_bitmap",
    "document_mask_bitmap",
    "compose_masks_all",
    "head_vote_mask",
    "kv_tile_skiplist",
]


def causal_mask_bitmap(q_pos: int, kv_positions) -> jax.Array:
    """Packed mask over KV slots: kv position valid and <= q_pos."""
    kv = jnp.asarray(kv_positions)
    return pack((kv >= 0) & (kv <= q_pos))


def window_mask_bitmap(q_pos: int, kv_positions, window: int) -> jax.Array:
    kv = jnp.asarray(kv_positions)
    return pack((kv >= 0) & (q_pos - kv < window))


def document_mask_bitmap(doc_ids, q_doc: int) -> jax.Array:
    return pack(jnp.asarray(doc_ids) == q_doc)


def compose_masks_all(*masks) -> jax.Array:
    """AND of criteria = theta(N, .) over the stacked mask bitmaps."""
    stacked = jnp.stack(masks)
    return threshold(stacked, stacked.shape[0], "ssum")


def head_vote_mask(votes: jax.Array, t: int) -> jax.Array:
    """KV pages nominated by >= t of the per-head vote bitmaps
    (votes: uint32[n_heads, n_words])."""
    return threshold(votes, t, "fused")


def kv_tile_skiplist(mask_words: jax.Array, n_kv: int, tile_positions: int = 2048):
    """Classify a packed mask into KV tiles; returns (keep_tiles, info).

    keep_tiles: sorted indices of tiles with any live position -- the launch
    list for a block-sparse attention kernel; all-zero tiles are never read.
    """
    tile_words = max(1, tile_positions // 32)
    store = TileStore.from_packed(
        jnp.asarray(mask_words)[None, :], tile_words=tile_words
    )
    classes = store.classes_word[0]  # zero/one/dirty is all the skiplist needs
    keep = np.nonzero(classes != TILE_ZERO)[0]
    info = {
        "n_tiles": int(classes.size),
        "skipped_tiles": int((classes == TILE_ZERO).sum()),
        "skip_fraction": float((classes == TILE_ZERO).mean()),
    }
    return keep, info
