"""Serving layer: the model-decode slot engine and the query front-end.

  * :mod:`~repro.serve.engine` -- continuous-batching decode engine whose
    slot-selection state is a streaming bitmap index;
  * :mod:`~repro.serve.frontend` -- :class:`QueryServer`, the
    high-throughput multi-client query front-end: shape-bucketed
    micro-batching over ``execute_many``, semantic request deduplication,
    a version-keyed result cache invalidated by streaming version bumps,
    bounded-queue admission control, and planner-calibration feedback.
"""
from .engine import Request, ServeEngine
from .frontend import Overloaded, QueryServer, shape_bucket

__all__ = ["Request", "ServeEngine", "Overloaded", "QueryServer", "shape_bucket"]
