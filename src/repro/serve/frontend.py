"""`QueryServer`: a coalescing, caching multi-client query front-end.

The paper's closing argument -- threshold results "can be further
processed within a bitmap index" -- only pays off if the index serves many
such queries cheaply under real load.  The execution machinery is already
shaped for it (``execute_many`` batches independent queries into one
jitted call; PR 7's scan engine made steady-state queries dispatch-only),
but a per-query loop still pays planning, compile-cache probing and a full
execution per request.  This front-end turns that machinery into a
throughput engine:

  * **micro-batching** -- in-flight requests from any number of logical
    clients coalesce into *shape-bucketed* micro-batches, one
    ``execute_many`` call per bucket.  A bucket groups queries with the
    same structural skeleton and sorts them by canonical key, so a hot
    workload's recurring query mix produces recurring batch compositions
    and the compiled-circuit cache converges to compile-once-run-many
    (the same economics as stacking identical scan layers);
  * **request deduplication** -- identical in-flight queries (by
    *semantic* canonical key: member order, And/Or child order etc.
    normalised away) collapse to ONE execution fanned out to every
    waiter;
  * **result caching** -- completed results live in an LRU keyed by
    ``(canonical key, per-column version vector)``.  Version vectors come
    from :attr:`~repro.stream.StreamingIndex.column_versions`, so a
    mutation invalidates exactly the entries reading a touched column
    (materialized-view columns cascade); everything else keeps hitting.
    Materialized views + this cache are the server-side cache tier for
    repeated hot queries;
  * **admission control** -- the pending set is bounded; past the bound,
    :meth:`submit` sheds the request with an explicit :class:`Overloaded`
    signal instead of growing latency without bound;
  * **planner feedback** -- each micro-batch's measured wall time feeds
    the active words→µs calibration (``core.calibration``), and plans come
    through the per-store memo (``BitmapIndex.explain``), so steady-state
    requests skip planning entirely.

Two driving modes: :meth:`start` spawns a background batcher thread that
sleeps a coalescing window and dispatches (the serving deployment), while
:meth:`pump` processes one micro-batch synchronously (deterministic tests,
single-threaded embedding).  ``submit`` returns a
:class:`concurrent.futures.Future` either way.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict, defaultdict
from concurrent.futures import Future
from dataclasses import dataclass, field
from functools import lru_cache

import repro.obs as _obs
from repro.core.calibration import get_calibration
from repro.obs import trace as _trace
from repro.obs.registry import MetricsRegistry
from repro.query import plan_memo_info
from repro.query.expr import (
    And,
    AndNot,
    Col,
    Not,
    Or,
    Query,
    Weighted,
    _SymmetricLeaf,
    as_query,
    bind_members,
    canonical_key,
    column_refs,
)

__all__ = ["Overloaded", "QueryServer", "shape_bucket"]


# Serving lifecycle counter events (one labelled family, not nine names:
# merges across servers/shards stay a single schema).
_EVENT_NAMES = (
    "requests", "served", "cache_hits", "dedup_hits", "shed",
    "executed", "batches", "invalidations", "errors",
)

# Mirrors on the process-wide registry: no-ops until ``repro.obs.enable()``.
# The server also keeps its OWN always-enabled registry (``QueryServer.obs``)
# so ``info()`` counters and latency percentiles work regardless of the
# global observability switch.
_G_EVENTS = _obs.REGISTRY.counter(
    "repro_serve_events_total", "QueryServer lifecycle events", ("event",),
)
_G_BATCH = _obs.REGISTRY.counter(
    "repro_serve_batch_size_total", "Micro-batch occurrences by exact size",
    ("size",),
)
_G_QWAIT = _obs.REGISTRY.histogram(
    "repro_serve_queue_wait_seconds", "submit -> micro-batch dispatch wait",
)
_G_LAT = _obs.REGISTRY.histogram(
    "repro_serve_request_latency_seconds", "submit -> result resolution",
)


class Overloaded(RuntimeError):
    """Admission control rejected the request: the pending queue is full.

    Deliberate backpressure -- the client should retry later or against a
    replica; queueing it anyway would grow tail latency without bound."""


@lru_cache(maxsize=8192)
def _analyze(query, names: tuple):
    """Bind + canonicalise + support extraction, memoized.

    Pure in (query, schema): queries are frozen dataclasses, so a hot
    workload's recurring requests make ``submit`` a couple of dict probes
    instead of a tree walk."""
    q = bind_members(as_query(query), names)
    ckey = canonical_key(q)
    cols = column_refs(q)
    return q, ckey, frozenset(names) if cols is None else cols


def shape_bucket(q: Query) -> tuple:
    """The micro-batch bucket key: a query's structural skeleton.

    Member names and thresholds are dropped (two thresholds over different
    store subsets batch together); arity is kept (the compiled circuit's
    adder width follows it).  Queries in one bucket ride one
    ``execute_many`` call."""
    q = as_query(q)
    if type(q) is Col:
        return ("col",)
    if isinstance(q, _SymmetricLeaf):
        tag = type(q).__name__.lower()
        return (tag, None if q.over is None else len(q.over))
    if isinstance(q, Weighted):
        return ("weighted", None if q.over is None else len(q.over))
    if isinstance(q, (And, Or)):
        tag = "and" if isinstance(q, And) else "or"
        return (tag,) + tuple(shape_bucket(c) for c in q.children)
    if isinstance(q, Not):
        return ("not", shape_bucket(q.child))
    if isinstance(q, AndNot):
        return ("andnot", shape_bucket(q.keep), shape_bucket(q.drop))
    raise TypeError(f"unknown query node {type(q).__name__}")


@dataclass
class _Pending:
    """One distinct in-flight query and everyone waiting on it.

    ``futures`` holds ``(future, t_submit)`` pairs so resolution can
    observe each waiter's end-to-end latency; ``t_submit`` is the first
    waiter's enqueue time (the queue-wait clock)."""

    query: Query  # member-bound expression
    ckey: tuple
    backend: str | None
    cols: frozenset  # support column names (cache version vector domain)
    futures: list = field(default_factory=list)  # [(Future, t_submit), ...]
    t_submit: float = 0.0


class _ResultCache:
    """LRU of finished results keyed (canonical key, backend, version
    vector), with a column→keys reverse index for exact invalidation."""

    def __init__(self, cap: int):
        self.cap = int(cap)
        self._od: OrderedDict = OrderedDict()  # key -> (cols, result)
        self._by_col: dict = defaultdict(set)  # name -> set of keys

    def __len__(self) -> int:
        return len(self._od)

    def get(self, key):
        got = self._od.get(key)
        if got is None:
            return None
        self._od.move_to_end(key)
        return got[1]

    def put(self, key, cols, value) -> None:
        if key in self._od:
            self._od.move_to_end(key)
            return
        self._od[key] = (cols, value)
        for c in cols:
            self._by_col[c].add(key)
        while len(self._od) > self.cap:
            self._drop(next(iter(self._od)))

    def _drop(self, key) -> None:
        cols, _ = self._od.pop(key)
        for c in cols:
            keys = self._by_col.get(c)
            if keys is not None:
                keys.discard(key)
                if not keys:
                    del self._by_col[c]

    def invalidate(self, names) -> int:
        """Evict every entry reading any of ``names``; returns the count.
        (Version-vector keys make stale hits impossible regardless -- this
        reclaims the memory and feeds the invalidation counters.)"""
        stale = set()
        for n in names:
            stale |= self._by_col.get(n, set())
        for key in stale:
            self._drop(key)
        return len(stale)

    def clear(self) -> None:
        self._od.clear()
        self._by_col.clear()


class QueryServer:
    """Serve query expressions to many logical clients over one index.

    ``index`` is a :class:`~repro.stream.StreamingIndex` (mutations flow,
    cache invalidation is wired to its version bumps) or a plain
    :class:`~repro.query.BitmapIndex` (immutable: every cache entry lives
    until evicted).

    Parameters
    ----------
    max_pending:
        Admission bound on *distinct* in-flight queries; past it
        :meth:`submit` raises :class:`Overloaded` (deduped waiters on
        already-admitted queries are always accepted).
    max_batch:
        Most distinct queries one :meth:`pump` drains (micro-batch size
        cap; one pump may still dispatch several shape buckets).
    window:
        Batcher-thread coalescing window in seconds: after waking on a
        submission it sleeps this long so concurrent clients pile into the
        same micro-batch.
    cache_entries:
        Result-cache LRU capacity (0 disables result caching).
    backend:
        Default backend override passed to every execution (None: planner).
    calibration:
        A :class:`~repro.core.calibration.Calibration` to feed measured
        batch wall times back into (defaults to the process-active one, if
        installed).
    """

    def __init__(self, index, *, max_pending: int = 1024, max_batch: int = 64,
                 window: float = 0.002, cache_entries: int = 4096,
                 backend: str | None = None, calibration=None):
        from repro.stream import StreamingIndex

        self._streaming = isinstance(index, StreamingIndex)
        self._src = index
        self.max_pending = int(max_pending)
        self.max_batch = int(max_batch)
        self.window = float(window)
        self.backend = backend
        self.calibration = calibration if calibration is not None else get_calibration()
        self._cache = _ResultCache(cache_entries) if cache_entries else None
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._pending: OrderedDict = OrderedDict()  # (ckey, backend) -> _Pending
        self._inflight: dict = {}  # same keys, currently executing
        self._thread: threading.Thread | None = None
        self._stop = False
        #: the server's own always-enabled metrics registry: ``info()``
        #: counters and latency percentiles hold whether or not the
        #: process-wide ``repro.obs`` switch is on; every mutation is
        #: mirrored onto the global registry (a no-op when disabled)
        self.obs = MetricsRegistry(enabled=True)
        self._events = self.obs.counter(
            "repro_serve_events_total", "QueryServer lifecycle events",
            ("event",),
        )
        self._batch_hist = self.obs.counter(
            "repro_serve_batch_size_total",
            "Micro-batch occurrences by exact size", ("size",),
        )
        self._queue_wait = self.obs.histogram(
            "repro_serve_queue_wait_seconds",
            "submit -> micro-batch dispatch wait",
        )
        self._latency = self.obs.histogram(
            "repro_serve_request_latency_seconds",
            "submit -> result resolution",
        )
        if self._streaming:
            self._src.subscribe(self._on_version_bump)

    # -- index plumbing ----------------------------------------------------
    def _names(self) -> tuple:
        return tuple(self._src.names)

    def _index(self):
        """The executable index of NOW (overlay + refreshed views when
        streaming)."""
        return self._src.index() if self._streaming else self._src

    def _versions(self) -> dict:
        return self._src.column_versions if self._streaming else {}

    def _vkey(self, cols: frozenset, versions: dict) -> tuple:
        return tuple(sorted((c, versions.get(c, 0)) for c in cols))

    def _on_version_bump(self, version: int, names: frozenset) -> None:
        if self._cache is None:
            return
        with self._lock:
            self._count("invalidations", self._cache.invalidate(names))

    # -- metrics plumbing --------------------------------------------------
    def _count(self, event: str, n: int = 1) -> None:
        """One lifecycle event: server registry always, global mirror when
        observability is enabled."""
        self._events.inc(n, event=event)
        _G_EVENTS.inc(n, event=event)

    def _observe_latency(self, seconds: float) -> None:
        self._latency.observe(seconds)
        _G_LAT.observe(seconds)

    def _observe_queue_wait(self, seconds: float) -> None:
        self._queue_wait.observe(seconds)
        _G_QWAIT.observe(seconds)

    # -- client surface ----------------------------------------------------
    def submit(self, query, *, backend: str | None = None) -> Future:
        """Enqueue one query; returns a Future of the packed result bitmap.

        Fast paths resolve before any queueing: a result-cache hit
        completes immediately; a semantically identical in-flight query
        adds this caller to its waiter list.  Otherwise the query joins
        the pending set -- unless that set is full, in which case the
        request is shed with :class:`Overloaded`.
        """
        backend = backend or self.backend
        t_sub = time.perf_counter()
        try:
            q, ckey, cols = _analyze(query, self._names())
        except TypeError:  # unhashable query: skip the memo
            q = bind_members(as_query(query), self._names())
            ckey = canonical_key(q)
            cols = column_refs(q) or frozenset(self._names())
        fut: Future = Future()
        with self._lock:
            self._count("requests")
            if self._cache is not None:
                hit = self._cache.get((ckey, backend, self._vkey(cols, self._versions())))
                if hit is not None:
                    self._count("cache_hits")
                    self._count("served")
                    self._observe_latency(time.perf_counter() - t_sub)
                    fut.set_result(hit)
                    return fut
            key = (ckey, backend)
            inflight = self._pending.get(key) or self._inflight.get(key)
            if inflight is not None:
                self._count("dedup_hits")
                inflight.futures.append((fut, t_sub))
                return fut
            if len(self._pending) >= self.max_pending:
                self._count("shed")
                raise Overloaded(
                    f"pending queue full ({self.max_pending} distinct queries "
                    "in flight); retry later"
                )
            self._pending[key] = _Pending(
                query=q, ckey=ckey, backend=backend, cols=cols,
                futures=[(fut, t_sub)], t_submit=t_sub,
            )
            self._work.notify()
        return fut

    def serve_many(self, queries, *, backend: str | None = None,
                   timeout: float | None = 30.0) -> list:
        """Submit a batch and wait for all results (pumping inline when no
        batcher thread is running).  Convenience for synchronous callers."""
        futs = [self.submit(q, backend=backend) for q in queries]
        if self._thread is None:
            while any(not f.done() for f in futs):
                if self.pump() == 0 and any(not f.done() for f in futs):
                    raise RuntimeError("pending futures but nothing to pump")
        return [f.result(timeout=timeout) for f in futs]

    # -- dispatch ----------------------------------------------------------
    def pump(self) -> int:
        """Drain one micro-batch synchronously; returns requests served.

        Takes up to ``max_batch`` distinct pending queries (FIFO), groups
        them into shape buckets, and dispatches each bucket as ONE
        ``execute_many`` call.  The batcher thread calls this in a loop;
        tests and single-threaded embeddings call it directly.
        """
        with self._lock:
            take = []
            while self._pending and len(take) < self.max_batch:
                p = self._pending.popitem(last=False)[1]
                # stays dedup-visible while executing: late identical
                # submissions join the fan-out instead of re-running
                self._inflight[(p.ckey, p.backend)] = p
                take.append(p)
        if not take:
            return 0
        try:
            idx = self._index()
            versions = self._versions()
        except Exception as e:  # noqa: BLE001 - refresh/overlay failure
            self._fail(take, e)
            return 0
        buckets: dict = defaultdict(list)
        for p in take:
            buckets[(shape_bucket(p.query), p.backend)].append(p)
        served = 0
        for (_, backend), items in buckets.items():
            # deterministic batch composition: recurring hot sets hit the
            # compiled-circuit cache with the same key every time
            items.sort(key=lambda p: repr(p.ckey))
            served += self._dispatch(idx, versions, items, backend)
        return served

    def _fail(self, items, exc) -> None:
        """Retire ``items`` with ``exc`` (pops them from the in-flight map
        first so waiter lists are final when we resolve them)."""
        with self._lock:
            self._count("errors", len(items))
            futures = []
            for p in items:
                self._inflight.pop((p.ckey, p.backend), None)
                futures.extend(f for f, _t in p.futures)
        for f in futures:
            f.set_exception(exc)

    def _dispatch(self, idx, versions, items, backend) -> int:
        t0 = time.perf_counter()
        for p in items:
            self._observe_queue_wait(max(0.0, t0 - p.t_submit))
        try:
            with _trace.span(
                "serve_batch", batch=len(items),
                backend=backend if backend is not None else "planner",
            ):
                outs = idx.execute_many([p.query for p in items], backend=backend)
                outs = [
                    o.block_until_ready() if hasattr(o, "block_until_ready") else o
                    for o in outs
                ]
        except Exception as e:  # noqa: BLE001 - one bucket fails as a unit
            self._fail(items, e)
            return 0
        wall = time.perf_counter() - t0
        if self.calibration is not None and backend is None and hasattr(idx, "explain"):
            share = wall / len(items)
            for p in items:
                plan = idx.explain(p.query)  # memoized: a dict probe when hot
                self.calibration.observe(plan.algorithm, plan.cost, share)
        served = 0
        resolved = []
        with self._lock:
            self._count("batches")
            self._count("executed", len(items))
            self._batch_hist.inc(1, size=len(items))
            _G_BATCH.inc(1, size=len(items))
            for p, out in zip(items, outs):
                if self._cache is not None:
                    self._cache.put(
                        (p.ckey, p.backend, self._vkey(p.cols, versions)),
                        p.cols, out,
                    )
                # cache filled, THEN drop from the in-flight map: a racing
                # submit either joins the fan-out or hits the cache, never
                # re-executes; after the pop the waiter list is final
                self._inflight.pop((p.ckey, p.backend), None)
                resolved.append((list(p.futures), out))
                served += len(p.futures)
                self._count("served", len(p.futures))
        t_done = time.perf_counter()
        for futures, out in resolved:
            for f, t_sub in futures:
                f.set_result(out)
                self._observe_latency(max(0.0, t_done - t_sub))
        return served

    # -- batcher thread ----------------------------------------------------
    def start(self) -> "QueryServer":
        """Spawn the background batcher: wake on submissions, sleep the
        coalescing window, pump.  Idempotent; returns self for chaining."""
        if self._thread is not None:
            return self
        self._stop = False
        self._thread = threading.Thread(
            target=self._loop, name="query-server-batcher", daemon=True
        )
        self._thread.start()
        return self

    def _loop(self) -> None:
        while True:
            with self._work:
                while not self._pending and not self._stop:
                    self._work.wait(timeout=0.1)
                if self._stop and not self._pending:
                    return
            if self.window > 0:
                time.sleep(self.window)  # let concurrent clients pile in
            while self.pump():  # drain every accumulated micro-batch before
                pass            # sleeping another window

    def stop(self) -> None:
        """Drain remaining work and join the batcher thread."""
        if self._thread is None:
            return
        with self._work:
            self._stop = True
            self._work.notify_all()
        self._thread.join()
        self._thread = None
        while self.pump():  # anything submitted during shutdown
            pass

    def __enter__(self) -> "QueryServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- introspection -----------------------------------------------------
    def info(self) -> dict:
        """Serving counters: requests/served/cache_hits/dedup_hits/shed/
        executed/batches/invalidations/errors, the batch-size histogram,
        cache + pending occupancy, latency/queue-wait percentiles,
        plan-memo counters, and the calibration constants currently
        steering the planner.

        A view over the server's metrics registry (:attr:`obs`): the same
        numbers export as Prometheus text via ``server.obs``, and mirror
        onto the process-wide ``repro.obs.REGISTRY`` when enabled."""
        with self._lock:
            out = {e: int(self._events.value(event=e)) for e in _EVENT_NAMES}
            out["pending"] = len(self._pending)
            out["cache_entries"] = len(self._cache) if self._cache else 0
            out["batch_size_hist"] = dict(sorted(
                (int(key[0]), int(v))
                for key, v in self._batch_hist.series().items()
            ))
        lat, qw = self._latency.state(), self._queue_wait.state()
        out["latency"] = {
            "count": lat.count,
            "p50_s": lat.quantile(0.5),
            "p95_s": lat.quantile(0.95),
            "p99_s": lat.quantile(0.99),
        }
        out["queue_wait"] = {
            "count": qw.count,
            "p50_s": qw.quantile(0.5),
            "p95_s": qw.quantile(0.95),
            "p99_s": qw.quantile(0.99),
        }
        out["plan_memo"] = plan_memo_info()
        calib = self.calibration
        out["calibration"] = None if calib is None else {
            "device": calib.device,
            "backends": sorted(calib.us_per_kword),
            "samples": sum(calib.samples.values()),
        }
        return out
