"""Batched serving engine: continuous batching over a fixed slot pool.

Slot state is tracked as a *bitmap index* (one criteria column per
predicate over slot positions) and slot-selection queries (free slots,
slots near the length limit, admission picks) are query expressions
executed through ``repro.query`` -- the serving layer is a natural
bitmap-index consumer (requests x predicates), and composed selections
like "occupied AND NOT near the limit" stay single fused queries.

The device-side decode is the jitted ``decode_step`` from the model zoo;
prefill uses ``forward(mode='prefill')``.  Greedy sampling by default.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.bitmaps import from_positions, to_positions_np
from repro.models import decode_step, forward, init_cache
from repro.models.model import logits_from_hidden
from repro.query import And, BitmapIndex, Col, Not, Query


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 16
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, batch_slots: int = 8,
                 max_seq: int = 256, mesh=None):
        assert not cfg.encoder_only, "encoder-only archs have no decode step"
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_seq = max_seq
        #: optional device mesh: slot-selection queries then run through the
        #: row-sharded engine (repro.dist.query) -- the slot universe is
        #: split across devices and selections stay device-resident until
        #: the positions are read out
        self.mesh = mesh
        self.cache = init_cache(cfg, batch_slots, max_seq, jnp.float32)
        self.requests: list[Request | None] = [None] * batch_slots
        self.pos = np.zeros(batch_slots, np.int64)
        self._decode = jax.jit(partial(decode_step, cfg=cfg))
        self.step_count = 0
        self._slot_version = 0  # bumped whenever slot occupancy/positions move
        self._slot_cache: dict = {}
        self._slot_base = None  # (Sharded)BitmapIndex reused across versions

    # -- slot bitmap index -----------------------------------------------
    def slot_bitmap(self, predicate: Callable[[Request | None], bool]):
        """Packed bitmap of slots whose request satisfies ``predicate``."""
        idx = [i for i, r in enumerate(self.requests) if predicate(r)]
        return from_positions(idx, self.slots)

    def slot_index(self, near_limit_margin: int = 8) -> BitmapIndex:
        """Criteria columns over slot positions, ready for query expressions:
        ``occupied`` (a request holds the slot) and ``near_limit`` (its
        position is within ``near_limit_margin`` of the sequence cap).

        Cached per engine state version -- ``free_slots()`` sits in the
        admission inner loop, so rebuilding the index (and re-running its
        queries) only happens after a submit or decode step changed state.
        """
        key = (self._slot_version, near_limit_margin)
        cached = self._slot_cache.get(key)
        if cached is not None:
            return cached
        occ, near = [], []
        for i, r in enumerate(self.requests):
            if r is None:
                continue
            occ.append(i)
            if self.pos[i] >= self.max_seq - near_limit_margin:
                near.append(i)
        occ_bm = from_positions(occ, self.slots)
        near_bm = from_positions(near, self.slots)
        idx = self._slot_base
        if idx is None:
            # with a mesh, classify at word granularity so the slot universe
            # splits into as many row shards as it has words, then shard it
            idx = BitmapIndex.from_columns(
                {"occupied": occ_bm, "near_limit": near_bm}, r=self.slots,
                tile_words=1 if self.mesh is not None else 64,
            )
            if self.mesh is not None:
                idx = idx.shard(mesh=self.mesh)
        else:
            # indexes are immutable TileStore wrappers: swap only the masks
            # that actually moved, so a version bump that e.g. flips one
            # occupancy bit reclassifies one column and leaves the other's
            # tiles (and the shared dirty storage) untouched
            for name, bm in (("occupied", occ_bm), ("near_limit", near_bm)):
                if not np.array_equal(np.asarray(idx.column(name)), np.asarray(bm)):
                    idx = idx.replace_column(name, bm)
        self._slot_base = idx
        self._slot_cache = {key: idx}
        return idx

    def select_slots(self, query: Query) -> list[int]:
        """Slot ids matching a query expression over the criteria columns.
        Runs through the sharded engine when the engine holds a mesh (the
        result is gathered only here, where positions leave the device)."""
        out = self.slot_index().execute(query)
        if hasattr(out, "gather"):  # ShardedResult
            out = out.gather()
        return to_positions_np(out).tolist()

    def free_slots(self) -> list[int]:
        return self.select_slots(Not(Col("occupied")))

    def draining_slots(self) -> list[int]:
        """Occupied slots about to hit the length cap (eviction candidates)."""
        return self.select_slots(And(Col("occupied"), Col("near_limit")))

    # -- admission ---------------------------------------------------------
    def submit(self, req: Request) -> bool:
        free = self.free_slots()
        if not free:
            return False
        slot = free[0]
        self.requests[slot] = req
        toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
        # per-slot prefill: run the prompt through the model, splice the
        # resulting cache rows into this slot
        _, caches, _ = forward(
            self.params, self.cfg, {"tokens": toks}, mode="prefill", max_seq=self.max_seq
        )
        self.cache = jax.tree.map(
            lambda full, new: full.at[:, slot : slot + 1].set(new), self.cache, caches
        )
        self.pos[slot] = len(req.prompt)
        self._slot_version += 1
        return True

    # -- decode ------------------------------------------------------------
    def step(self):
        """One decode step for every active slot."""
        active = [i for i, r in enumerate(self.requests) if r is not None and not r.done]
        if not active:
            return []
        last = np.zeros((self.slots, 1), np.int32)
        for i in active:
            r = self.requests[i]
            seq = r.prompt + r.out
            last[i, 0] = seq[-1]
        pos = jnp.asarray(self.pos, jnp.int32)  # per-slot positions
        logits, self.cache = self._decode(
            self.params, caches=self.cache, tokens=jnp.asarray(last), pos=pos
        )
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        emitted = []
        for i in active:
            r = self.requests[i]
            r.out.append(int(nxt[i]))
            self.pos[i] += 1
            emitted.append((r.rid, int(nxt[i])))
            if len(r.out) >= r.max_new or self.pos[i] >= self.max_seq - 1:
                r.done = True
                self.requests[i] = None  # release slot
        self.step_count += 1
        self._slot_version += 1
        return emitted

    def run_until_drained(self, pending: list[Request], max_steps: int = 10_000):
        done: list[Request] = []
        live: dict[int, Request] = {}
        while (pending or live) and max_steps:
            max_steps -= 1
            while pending and self.free_slots():
                req = pending.pop(0)
                if self.submit(req):
                    live[req.rid] = req
            self.step()
            for rid, r in list(live.items()):
                if r.done:
                    done.append(r)
                    del live[rid]
        return done
