"""Batched serving engine: continuous batching over a fixed slot pool.

Slot state is tracked as a *streaming bitmap index* (one criteria column
per predicate over slot positions) and slot-selection queries (free
slots, slots near the length limit, admission picks) are query
expressions executed through ``repro.query`` -- the serving layer is a
natural bitmap-index consumer (requests x predicates), and composed
selections like "occupied AND NOT near the limit" stay single fused
queries.

Slot-state maintenance goes through ``repro.stream.StreamingIndex``: all
slot changes of one decode step (completions freeing slots, positions
crossing the near-limit margin) coalesce into a SINGLE batched delta
apply -- one ``_slot_version`` bump per step, never one column
reclassification per event.

The device-side decode is the jitted ``decode_step`` from the model zoo;
prefill uses ``forward(mode='prefill')``.  Greedy sampling by default.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

import repro.obs as _obs
from repro.configs.base import ModelConfig
from repro.core.bitmaps import from_positions, to_positions_np
from repro.models import decode_step, forward, init_cache
from repro.models.model import logits_from_hidden
from repro.query import And, BitmapIndex, Col, Not, Query
from repro.stream import StreamingIndex

# Engine-level accounting on the process-wide registry (no-ops until
# ``repro.obs.enable()``); slot-selection queries themselves report
# through the query-layer instrumentation.
_ADMISSIONS = _obs.REGISTRY.counter(
    "repro_engine_admissions_total", "Request admissions by outcome",
    ("outcome",),
)
_STEPS = _obs.REGISTRY.counter(
    "repro_engine_decode_steps_total", "Batched decode steps run",
)
_TOKENS = _obs.REGISTRY.counter(
    "repro_engine_tokens_emitted_total", "Tokens emitted across slots",
)
_OCCUPIED = _obs.REGISTRY.gauge(
    "repro_engine_occupied_slots", "Slots holding a live request",
)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new: int = 16
    out: list[int] = dataclasses.field(default_factory=list)
    done: bool = False


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, batch_slots: int = 8,
                 max_seq: int = 256, mesh=None):
        assert not cfg.encoder_only, "encoder-only archs have no decode step"
        self.cfg = cfg
        self.params = params
        self.slots = batch_slots
        self.max_seq = max_seq
        #: optional device mesh: slot-selection queries then run through the
        #: row-sharded engine (repro.dist.query) -- the slot universe is
        #: split across devices and selections stay device-resident until
        #: the positions are read out
        self.mesh = mesh
        self.cache = init_cache(cfg, batch_slots, max_seq, jnp.float32)
        self.requests: list[Request | None] = [None] * batch_slots
        self.pos = np.zeros(batch_slots, np.int64)
        self._decode = jax.jit(partial(decode_step, cfg=cfg))
        self.step_count = 0
        self._slot_version = 0  # bumped ONCE per submit / step that moved state
        self._near_margin = 8
        self._slot_stream: StreamingIndex | None = None
        self._occ_now: set = set()  # mirror of the index's occupied column
        self._near_now: set = set()  # mirror of the index's near_limit column

    # -- slot bitmap index -----------------------------------------------
    def slot_bitmap(self, predicate: Callable[[Request | None], bool]):
        """Packed bitmap of slots whose request satisfies ``predicate``."""
        idx = [i for i, r in enumerate(self.requests) if predicate(r)]
        return from_positions(idx, self.slots)

    def _slot_state(self, margin: int) -> tuple:
        occ, near = [], []
        for i, r in enumerate(self.requests):
            if r is None:
                continue
            occ.append(i)
            if self.pos[i] >= self.max_seq - margin:
                near.append(i)
        return occ, near

    def _build_slot_index(self, occ, near):
        # with a mesh, classify at word granularity so the slot universe
        # splits into as many row shards as it has words, then shard it
        idx = BitmapIndex.from_columns(
            {
                "occupied": from_positions(occ, self.slots),
                "near_limit": from_positions(near, self.slots),
            },
            r=self.slots,
            tile_words=1 if self.mesh is not None else 64,
        )
        if self.mesh is not None:
            idx = idx.shard(mesh=self.mesh)
        return idx

    def slot_index(self, near_limit_margin: int = 8):
        """Criteria columns over slot positions, ready for query expressions:
        ``occupied`` (a request holds the slot) and ``near_limit`` (its
        position is within ``near_limit_margin`` of the sequence cap).

        The default-margin index is a :class:`repro.stream.StreamingIndex`
        maintained by batched delta applies (one per submit / step) -- the
        slot columns are never reclassified column-wide, and under a mesh
        each delta routes to the owning row shard.  A non-default margin
        builds a transient index from the current state.
        """
        if near_limit_margin != self._near_margin:
            return self._build_slot_index(*self._slot_state(near_limit_margin))
        if self._slot_stream is None:
            occ, near = self._slot_state(self._near_margin)
            self._slot_stream = StreamingIndex(self._build_slot_index(occ, near))
            self._occ_now, self._near_now = set(occ), set(near)
        return self._slot_stream.index()

    def snapshot_slot_index(self, dirpath) -> dict:
        """Checkpoint the slot-state criteria index to ``dirpath`` via
        ``repro.persist``: snapshot + WAL, materialized selection views
        included.  A later engine (or replica) warm-starts from it with
        :meth:`warm_start_slot_index` instead of rebuilding."""
        self.slot_index()  # ensure the streaming index exists
        stream = self._slot_stream
        if stream.durable_dir is None:
            stream.attach_durable(dirpath)
        return stream.checkpoint()

    def warm_start_slot_index(self, dirpath) -> bool:
        """Adopt a checkpointed slot index (memmap load + WAL replay)
        instead of building one from live request state.  Returns False --
        leaving the engine to build fresh on first use -- when there is no
        usable snapshot or its slot universe doesn't match this engine."""
        from pathlib import Path

        if not (Path(dirpath) / "index.json").exists():
            return False
        stream = StreamingIndex.recover(dirpath, mesh=self.mesh)
        if stream.r != self.slots or not {"occupied", "near_limit"} <= set(
            stream.names
        ):
            return False
        self._slot_stream = stream
        # resync the change-detection mirrors from the recovered columns
        occ, near = [], []
        for name, acc in (("occupied", occ), ("near_limit", near)):
            out = stream.execute(Col(name))
            if hasattr(out, "gather"):
                out = out.gather()
            acc.extend(to_positions_np(out).tolist())
        self._occ_now, self._near_now = set(occ), set(near)
        return True

    def _commit_slot_state(self) -> None:
        """Fold EVERY slot change since the last commit -- completions,
        admissions, positions crossing the margin -- into one batched index
        update.  One call per submit / step; bumps ``_slot_version`` once."""
        self._slot_version += 1
        if self._slot_stream is None:
            return  # index not built yet; first slot_index() reads fresh state
        occ, near = self._slot_state(self._near_margin)
        occ, near = set(occ), set(near)
        sets: dict = {}
        clears: dict = {}
        for name, want, have in (
            ("occupied", occ, self._occ_now),
            ("near_limit", near, self._near_now),
        ):
            if want - have:
                sets[name] = sorted(want - have)
            if have - want:
                clears[name] = sorted(have - want)
        if sets or clears:
            self._slot_stream.update(sets=sets, clears=clears)
        self._occ_now, self._near_now = occ, near
        _OCCUPIED.set(len(occ))

    def select_slots(self, query: Query) -> list[int]:
        """Slot ids matching a query expression over the criteria columns.
        Runs through the sharded engine when the engine holds a mesh (the
        result is gathered only here, where positions leave the device)."""
        out = self.slot_index().execute(query)
        if hasattr(out, "gather"):  # ShardedResult
            out = out.gather()
        return to_positions_np(out).tolist()

    def free_slots(self) -> list[int]:
        return self.select_slots(Not(Col("occupied")))

    def draining_slots(self) -> list[int]:
        """Occupied slots about to hit the length cap (eviction candidates)."""
        return self.select_slots(And(Col("occupied"), Col("near_limit")))

    # -- admission ---------------------------------------------------------
    def submit(self, req: Request) -> bool:
        free = self.free_slots()
        if not free:
            _ADMISSIONS.inc(1, outcome="rejected")
            return False
        _ADMISSIONS.inc(1, outcome="admitted")
        slot = free[0]
        self.requests[slot] = req
        toks = jnp.asarray(req.prompt, jnp.int32)[None, :]
        # per-slot prefill: run the prompt through the model, splice the
        # resulting cache rows into this slot
        _, caches, _ = forward(
            self.params, self.cfg, {"tokens": toks}, mode="prefill", max_seq=self.max_seq
        )
        self.cache = jax.tree.map(
            lambda full, new: full.at[:, slot : slot + 1].set(new), self.cache, caches
        )
        self.pos[slot] = len(req.prompt)
        self._commit_slot_state()
        return True

    # -- decode ------------------------------------------------------------
    def step(self):
        """One decode step for every active slot."""
        active = [i for i, r in enumerate(self.requests) if r is not None and not r.done]
        if not active:
            return []
        last = np.zeros((self.slots, 1), np.int32)
        for i in active:
            r = self.requests[i]
            seq = r.prompt + r.out
            last[i, 0] = seq[-1]
        pos = jnp.asarray(self.pos, jnp.int32)  # per-slot positions
        logits, self.cache = self._decode(
            self.params, caches=self.cache, tokens=jnp.asarray(last), pos=pos
        )
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        emitted = []
        for i in active:
            r = self.requests[i]
            r.out.append(int(nxt[i]))
            self.pos[i] += 1
            emitted.append((r.rid, int(nxt[i])))
            if len(r.out) >= r.max_new or self.pos[i] >= self.max_seq - 1:
                r.done = True
                self.requests[i] = None  # release slot
        self.step_count += 1
        _STEPS.inc(1)
        _TOKENS.inc(len(emitted))
        # every slot change this step -- completions releasing slots and
        # positions crossing the near-limit margin -- lands as ONE batched
        # delta apply on the streaming slot index
        self._commit_slot_state()
        return emitted

    def run_until_drained(self, pending: list[Request], max_steps: int = 10_000):
        done: list[Request] = []
        live: dict[int, Request] = {}
        while (pending or live) and max_steps:
            max_steps -= 1
            while pending and self.free_slots():
                req = pending.pop(0)
                if self.submit(req):
                    live[req.rid] = req
            self.step()
            for rid, r in list(live.items()):
                if r.done:
                    done.append(r)
                    del live[rid]
        return done
