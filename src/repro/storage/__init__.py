"""`repro.storage`: the tiled hybrid storage engine.

The single home of tile classification and tile-skipping execution:

  * :class:`TileStore` -- tile-classified columns (all-zero / all-one /
    dirty / run), dirty tiles packed contiguously in one device array with
    an offsets table, per-column cardinality/density/runcount statistics
    computed once at build time.  ``BitmapIndex`` wraps one.
  * :func:`run_tiled_circuit` -- RBMRG clean/dirty skipping generalised
    from bare thresholds to arbitrary compiled circuits (the
    ``tiled_fused`` backend).
  * :func:`classify_tiles` / :func:`rbmrg_block_threshold` /
    :func:`runcount` -- the original block-RLE primitives (moved here from
    ``core/blockrle.py``, which is now a deprecated re-export shim).
"""

from .containers import (
    CONT_DENSE,
    CONT_NONE,
    CONT_RUN,
    CONT_SPARSE,
    CONTAINER_CROSSOVER,
    run_max_intervals,
    sparse_max_positions,
)
from .tiles import BlockStats, classify_tiles, rbmrg_block_threshold, runcount
from .tilestore import (
    TILE_DIRTY,
    TILE_ONE,
    TILE_RUN,
    TILE_ZERO,
    ColumnStats,
    MemberStats,
    TileStore,
)
from .tiled import run_tiled_circuit

__all__ = [
    "BlockStats",
    "classify_tiles",
    "rbmrg_block_threshold",
    "runcount",
    "TileStore",
    "ColumnStats",
    "MemberStats",
    "TILE_ZERO",
    "TILE_ONE",
    "TILE_DIRTY",
    "TILE_RUN",
    "CONT_NONE",
    "CONT_DENSE",
    "CONT_SPARSE",
    "CONT_RUN",
    "CONTAINER_CROSSOVER",
    "sparse_max_positions",
    "run_max_intervals",
    "run_tiled_circuit",
]
