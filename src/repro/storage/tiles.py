"""Tile classification primitives: the TPU-native adaptation of EWAH + RBMRG.

Word-granular RLE (EWAH marker words, iterator skipping) is data-dependent
pointer chasing -- hostile to a vector machine.  We keep the *insight*
(clean runs are processed in O(1), only dirty words do bit work) at tile
granularity:

  * a bitmap is split into tiles of ``tile_words`` uint32 words;
  * each tile is classified all-zero / all-one / dirty (and, in
    :class:`~repro.storage.TileStore`, single-transition *run* tiles are
    additionally tagged);
  * for a threshold query, per tile we know k = #all-one inputs and
    d = #dirty inputs, giving the paper's RBMRG 3-case split:
      1. T - k <= 0        -> output tile is all ones      (no bit work)
      2. T - k >  d        -> output tile is all zeros     (no bit work)
      3. otherwise          -> a (T-k)-threshold over the d dirty tiles

Case-3 tiles are gathered host-side into a dense batch and dispatched to
the compute backend -- the skipping decision is made *before* launch
instead of inside a serial scan, which is the TPU-legal way to realise
EWAH's fast-forwarding.

This module is the single home of tile classification (it moved here from
``core/blockrle.py``; that module is now a deprecated re-export shim).
:func:`rbmrg_block_threshold` is the original bare-threshold pruner; the
generalisation to arbitrary compiled circuits is
:func:`repro.storage.tiled.run_tiled_circuit`.
"""
from __future__ import annotations

import dataclasses

# NOTE: no repro.core imports at module level -- core/__init__ re-exports the
# blockrle shim, which imports this module; keeping tiles.py dependency-free
# lets `import repro.storage` work from either direction of that edge.
import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["BlockStats", "classify_tiles", "rbmrg_block_threshold", "runcount"]


@dataclasses.dataclass
class BlockStats:
    """Per-(bitmap, tile) classification. 0 = all-zero, 1 = all-one, 2 = dirty."""

    classes: np.ndarray  # uint8 [N, n_tiles]
    tile_words: int
    n_words: int

    @property
    def clean_fraction(self) -> float:
        return float((self.classes != 2).mean())


def classify_tiles(bitmaps, tile_words: int = 64) -> BlockStats:
    """Host-side tile classification (this is 'index build time' work)."""
    arr = np.asarray(jax.device_get(bitmaps), dtype=np.uint32)
    n, nw = arr.shape
    n_tiles = (nw + tile_words - 1) // tile_words
    pad = n_tiles * tile_words - nw
    if pad:
        arr = np.pad(arr, ((0, 0), (0, pad)))
    tiles = arr.reshape(n, n_tiles, tile_words)
    all_zero = (tiles == 0).all(axis=2)
    all_one = (tiles == 0xFFFFFFFF).all(axis=2)
    classes = np.full((n, n_tiles), 2, dtype=np.uint8)
    classes[all_zero] = 0
    classes[all_one] = 1
    return BlockStats(classes=classes, tile_words=tile_words, n_words=nw)


def runcount(bitmaps) -> int:
    """Paper's RUNCOUNT: total number of 0/1 runs across the collection."""
    arr = np.asarray(jax.device_get(bitmaps), dtype=np.uint32)
    bits = np.unpackbits(arr.view(np.uint8).reshape(arr.shape[0], -1), axis=1, bitorder="little")
    flips = (bits[:, 1:] != bits[:, :-1]).sum(axis=1) + 1
    return int(flips.sum())


def rbmrg_block_threshold(
    bitmaps, t: int, stats: BlockStats | None = None, tile_words: int = 64, algorithm: str = "ssum"
):
    """Threshold with RBMRG-style clean/dirty pruning at tile granularity.

    Returns (packed result uint32[n_words], info dict).  ``info`` reports how
    much bit-level work the pruning skipped -- the paper's Table 4 claim that
    run-aware merging does O(RUNCOUNT log N) instead of O(rN/W) work.

    This is the bare-threshold specialisation; arbitrary compiled circuits
    (Interval/Exactly/And/Or trees) get the same skipping through
    :func:`repro.storage.tiled.run_tiled_circuit`.
    """
    from repro.core.threshold import threshold as _threshold

    arr = np.asarray(jax.device_get(bitmaps), dtype=np.uint32)
    n, nw = arr.shape
    if stats is None:
        stats = classify_tiles(arr, tile_words)
    tw = stats.tile_words
    n_tiles = stats.classes.shape[1]
    k = (stats.classes == 1).sum(axis=0)  # all-one inputs per tile
    d = (stats.classes == 2).sum(axis=0)  # dirty inputs per tile

    out = np.zeros(n_tiles * tw, dtype=np.uint32)
    case1 = (t - k) <= 0
    case2 = (t - k) > d
    case3 = ~(case1 | case2)
    out_tiles = out.reshape(n_tiles, tw)
    out_tiles[case1] = 0xFFFFFFFF

    idx3 = np.nonzero(case3)[0]
    dirty_words_processed = 0
    if idx3.size:
        padded = np.pad(arr, ((0, 0), (0, n_tiles * tw - nw))).reshape(n, n_tiles, tw)
        # Bucket case-3 tiles by (#dirty, residual threshold) so each bucket is
        # one fixed-shape kernel launch (shape bucketing = our recompile-free
        # analogue of EWAH's per-run dispatch).
        buckets: dict[tuple[int, int], list[int]] = {}
        for ti in idx3:
            buckets.setdefault((int(d[ti]), int(t - k[ti])), []).append(int(ti))
        for (nd, tt), tis in buckets.items():
            gathered = np.empty((len(tis), nd, tw), dtype=np.uint32)
            for row, ti in enumerate(tis):
                sel = np.nonzero(stats.classes[:, ti] == 2)[0]
                gathered[row] = padded[sel, ti, :]
            dirty_words_processed += gathered.size
            if tt == 1:
                res = np.bitwise_or.reduce(gathered, axis=1)
            elif tt == nd:
                res = np.bitwise_and.reduce(gathered, axis=1)
            else:
                batched = jax.vmap(lambda g: _threshold(g, tt, algorithm))(jnp.asarray(gathered))
                res = np.asarray(jax.device_get(batched))
            for row, ti in enumerate(tis):
                out_tiles[ti] = res[row]
    info = {
        "n_tiles": n_tiles,
        "case1_tiles": int(case1.sum()),
        "case2_tiles": int(case2.sum()),
        "case3_tiles": int(case3.sum()),
        "dirty_words_processed": int(dirty_words_processed),
        "total_words": int(n * nw),
        "work_fraction": float(dirty_words_processed) / max(1, n * nw),
    }
    return jnp.asarray(out[:nw]), info
