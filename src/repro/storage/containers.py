"""Compressed tile containers: sparse position lists and run intervals.

The paper's premise is that threshold/symmetric queries stay cheap
*because* the operands are compressed bitmaps that can be combined without
full materialization; Roaring showed the winning realisation is a hybrid
of array ("sparse"), run and bitmap containers chosen per chunk.  This
module is that idea at our tile granularity:

  * a dirty tile whose popcount ``p`` is at or below
    :func:`sparse_max_positions` can be stored as a **sparse container**:
    the sorted in-tile bit positions as uint16 (``ceil(p/2)`` words
    instead of ``tile_words``);
  * a dirty tile with at most :func:`run_max_intervals` maximal 1-runs can
    be stored as a **run container**: (start, end) uint16 endpoint pairs,
    end exclusive (``i`` words for ``i`` intervals);
  * everything else stays a **dense container** -- the classic packed
    dirty-tile words.

Classification picks the cheapest eligible representation (ties prefer
run over sparse over dense).  Containers only exist for dirty tiles --
all-zero / all-one tiles remain pure metadata, exactly as before.

Execution does not have to densify: :func:`evaluate_event_tiles` runs an
arbitrary residual circuit (as its exact truth table) over the *boundary
events* of sparse/run inputs -- the MergeOpt/ScanCount view of the same
query -- and :func:`rasterize_toggles` turns the resulting output
intervals into packed words with a branch-free prefix-XOR, so the bit
work per tile scales with the container sizes, not the tile span.

Positions are tile-local, so uint16 works for any ``tile_words * 32 <=
65535`` (the default 64-word tile spans 2048 bits); larger tiles fall
back to dense containers (:func:`containers_supported`).
"""
from __future__ import annotations

import numpy as np

__all__ = [
    "CONT_NONE",
    "CONT_DENSE",
    "CONT_SPARSE",
    "CONT_RUN",
    "CONTAINER_CROSSOVER",
    "containers_supported",
    "sparse_max_positions",
    "run_max_intervals",
    "compress_tiles",
    "popcounts",
    "interval_counts",
    "sparse_from_words",
    "runs_from_words",
    "words_from_sparse",
    "words_from_runs",
    "rasterize_toggles",
    "evaluate_event_tiles",
    "concat_ranges",
]

# container kind of a tile (a refinement of the word-level DIRTY class;
# clean tiles are CONT_NONE -- they store nothing)
CONT_NONE, CONT_DENSE, CONT_SPARSE, CONT_RUN = 0, 1, 2, 3

#: the executor evaluates a residual tile container-natively (boundary
#: events instead of a densified gather) when the tile's compressed words
#: are at most this fraction of the dense gather ``m * tile_words``.  At
#: 1.0 the event path runs exactly when it reads fewer words than the
#: dense path would -- the planner prices the same split.
CONTAINER_CROSSOVER = 1.0


def containers_supported(tile_words: int) -> bool:
    """uint16 tile-local positions need span <= 65535 bits."""
    return int(tile_words) * 32 <= 0xFFFF


def sparse_max_positions(tile_words: int) -> int:
    """Sparse eligibility threshold on popcount.

    ``2 * tile_words`` uint16 positions occupy exactly ``tile_words``
    words -- the storage-parity point with a dense container (and the same
    span fraction as Roaring's 4096-of-65536 array-container bound).
    """
    return 2 * int(tile_words)


def run_max_intervals(tile_words: int) -> int:
    """Run eligibility threshold on the number of maximal 1-runs.

    ``tile_words // 2`` interval pairs occupy half a dense container, so a
    run container is never a regression even against sparse."""
    return max(1, int(tile_words) // 2)


if hasattr(np, "bitwise_count"):  # numpy >= 2.0

    def popcounts(tiles: np.ndarray) -> np.ndarray:
        """Per-row popcount of uint32[m, tile_words]."""
        return np.bitwise_count(tiles).sum(axis=1, dtype=np.int64)

else:
    _POP8 = np.array([bin(i).count("1") for i in range(256)], np.uint16)

    def popcounts(tiles: np.ndarray) -> np.ndarray:
        return (
            _POP8[tiles.view(np.uint8)]
            .reshape(tiles.shape[0], -1)
            .sum(axis=1, dtype=np.int64)
        )


def _rise_fall_masks(tiles: np.ndarray):
    """Bit masks of 0->1 ("rise") and 1->0 ("fall") transitions per tile.

    Transitions are tile-local: the bit before position 0 counts as 0, so
    a rise at bit p means a maximal 1-run starts at p, and a fall at p
    means one ended at p (exclusive).  A run reaching the tile's last bit
    has no fall mask bit -- its end is the span (handled by the caller).
    """
    prev = tiles << np.uint32(1)
    if tiles.shape[1] > 1:
        prev[:, 1:] |= tiles[:, :-1] >> np.uint32(31)
    rise = tiles & ~prev
    fall = ~tiles & prev
    return rise, fall


def interval_counts(tiles: np.ndarray) -> np.ndarray:
    """Number of maximal 1-runs per tile of uint32[m, tile_words]."""
    rise, _ = _rise_fall_masks(tiles)
    return popcounts(rise)


def _bit_positions(masks: np.ndarray):
    """(row, bit position) of every set bit, row-major sorted."""
    m = masks.shape[0]
    if m == 0:
        return np.zeros(0, np.int64), np.zeros(0, np.int64)
    bits = np.unpackbits(
        masks.view(np.uint8).reshape(m, -1), axis=1, bitorder="little"
    )
    return np.nonzero(bits)


def concat_ranges(starts: np.ndarray, stops: np.ndarray) -> np.ndarray:
    """Concatenated ``arange(starts[i], stops[i])`` -- the variable-length
    pack gather (sparse positions / run pairs of many tiles in one take)."""
    counts = (stops - starts).astype(np.int64)
    total = int(counts.sum())
    if total == 0:
        return np.zeros(0, np.int64)
    cum0 = np.concatenate([[0], np.cumsum(counts)[:-1]])
    return np.repeat(starts - cum0, counts) + np.arange(total)


def sparse_from_words(tiles: np.ndarray):
    """uint32[m, tw] -> (positions uint16[P], offsets int64[m + 1])."""
    rows, pos = _bit_positions(tiles)
    off = np.zeros(tiles.shape[0] + 1, np.int64)
    np.cumsum(np.bincount(rows, minlength=tiles.shape[0]), out=off[1:])
    return pos.astype(np.uint16), off


def runs_from_words(tiles: np.ndarray):
    """uint32[m, tw] -> (runs uint16[I, 2] (start, end-exclusive), offsets
    int64[m + 1] in interval units, tile order)."""
    m, tw = tiles.shape
    span = tw * 32
    rise, fall = _rise_fall_masks(tiles)
    srow, spos = _bit_positions(rise)
    frow, fpos = _bit_positions(fall)
    top = ((tiles[:, -1] >> np.uint32(31)) & 1).astype(np.int64)
    n_starts = np.bincount(srow, minlength=m)
    n_falls = np.bincount(frow, minlength=m)
    off = np.zeros(m + 1, np.int64)
    np.cumsum(n_starts, out=off[1:])
    ends = np.empty(len(spos), np.int64)
    if len(fpos):
        cum0 = np.concatenate([[0], np.cumsum(n_falls)[:-1]])
        ord_in_row = np.arange(len(fpos)) - cum0[frow]
        ends[off[frow] + ord_in_row] = fpos
    trow = np.nonzero(top)[0]
    if len(trow):
        ends[off[trow] + n_falls[trow]] = span
    runs = np.stack([spos, ends], axis=1).astype(np.uint16)
    return runs, off


def words_from_sparse(pos: np.ndarray, off: np.ndarray, tile_words: int
                      ) -> np.ndarray:
    """Inverse of :func:`sparse_from_words`: uint32[m, tile_words]."""
    m = len(off) - 1
    out = np.zeros((m, tile_words), np.uint32)
    if len(pos) == 0:
        return out
    rows = np.repeat(np.arange(m), np.diff(off))
    p = pos.astype(np.int64)
    flat = rows * tile_words + p // 32
    b = np.uint32(1) << (p % 32).astype(np.uint32)
    # positions are sorted per tile, so flat is globally non-decreasing
    fw, start = np.unique(flat, return_index=True)
    out.reshape(-1)[fw] = np.bitwise_or.reduceat(b, start)
    return out


def rasterize_toggles(rows: np.ndarray, bitpos: np.ndarray, m: int,
                      tile_words: int) -> np.ndarray:
    """Bits set between toggle pairs, as packed words uint32[m, tile_words].

    ``bitpos`` entries are in ``[0, span]`` (a toggle at ``span`` falls off
    the tile); duplicate toggles at one position cancel.  Branch-free:
    XOR-scatter the toggles, prefix-XOR within each word by doubling
    shifts, then carry the word parities across the row.
    """
    t = np.zeros((m, tile_words + 1), np.uint32)
    if len(rows):
        flat = rows.astype(np.int64) * (tile_words + 1) + bitpos // 32
        mask = np.uint32(1) << (bitpos % 32).astype(np.uint32)
        order = np.argsort(flat, kind="stable")
        fw, start = np.unique(flat[order], return_index=True)
        t.reshape(-1)[fw] = np.bitwise_xor.reduceat(mask[order], start)
    for sh in (1, 2, 4, 8, 16):
        t ^= t << np.uint32(sh)
    carry = np.bitwise_xor.accumulate((t >> np.uint32(31)).astype(np.uint8),
                                      axis=1)
    cin = np.zeros_like(carry)
    cin[:, 1:] = carry[:, :-1]
    t ^= cin.astype(np.uint32) * np.uint32(0xFFFFFFFF)
    return t[:, :tile_words]


def words_from_runs(runs: np.ndarray, off: np.ndarray, tile_words: int
                    ) -> np.ndarray:
    """Inverse of :func:`runs_from_words`: uint32[m, tile_words]."""
    m = len(off) - 1
    if len(runs) == 0:
        return np.zeros((m, tile_words), np.uint32)
    rows = np.repeat(np.arange(m), np.diff(off))
    return rasterize_toggles(
        np.concatenate([rows, rows]),
        np.concatenate([runs[:, 0].astype(np.int64),
                        runs[:, 1].astype(np.int64)]),
        m,
        tile_words,
    )


def compress_tiles(tiles: np.ndarray, tile_words: int, *,
                   containers: bool = True):
    """Classify + compress a batch of dirty-tile words.

    Returns ``(kinds, dense, spos, soff, runs, roff)`` where ``kinds`` is
    uint8[m] over {CONT_DENSE, CONT_SPARSE, CONT_RUN} and the pack arrays
    hold the per-kind payloads in tile order.  With ``containers=False``
    (or an unsupported tile span) every tile stays dense -- the legacy
    layout, byte-identical to the pre-container store.
    """
    tiles = np.ascontiguousarray(tiles, np.uint32)
    m = tiles.shape[0]
    kinds = np.full(m, CONT_DENSE, np.uint8)
    if containers and containers_supported(tile_words) and m:
        pc = popcounts(tiles)
        iv = interval_counts(tiles)
        cost_sparse = np.where(
            pc <= sparse_max_positions(tile_words), (pc + 1) // 2,
            np.iinfo(np.int64).max,
        )
        cost_run = np.where(
            iv <= run_max_intervals(tile_words), iv, np.iinfo(np.int64).max
        )
        kinds[cost_sparse <= tile_words] = CONT_SPARSE
        kinds[
            (cost_run <= tile_words)
            & (cost_run <= cost_sparse)
        ] = CONT_RUN
    dense = np.ascontiguousarray(tiles[kinds == CONT_DENSE])
    sp = kinds == CONT_SPARSE
    spos, soff = sparse_from_words(tiles[sp])
    rn = kinds == CONT_RUN
    runs, roff = runs_from_words(tiles[rn])
    return kinds, dense, spos, soff, runs, roff


def truth_table_bits(tt: int, n_inputs: int) -> np.ndarray:
    """A circuit output's exact truth table (bigint, bit a = f(combo a))
    as a bool lookup array of size ``2 ** n_inputs``."""
    size = 1 << n_inputs
    raw = tt.to_bytes(max(1, size // 8), "little")
    return np.unpackbits(
        np.frombuffer(raw, np.uint8), bitorder="little"
    )[:size].astype(bool)


def evaluate_event_tiles(rows: np.ndarray, bitpos: np.ndarray,
                         wires: np.ndarray, m: int, tile_words: int,
                         tables: tuple, n_inputs: int) -> np.ndarray:
    """Container-native residual evaluation over boundary events.

    Every sparse position and run interval of a tile's inputs becomes a
    pair of *events* -- bit positions where that input toggles.  Sorting
    the events of a tile and XOR-accumulating per-input masks yields the
    input combination of every segment between consecutive boundaries (the
    merge phase of MergeOpt, vectorised across all tiles at once); each
    output's exact truth table then maps combinations to values, and the
    value *changes* are toggles rasterized into packed words.

    ``rows``/``bitpos``/``wires``: one entry per event (output tile row in
    [0, m), position in [0, span], residual input index).  ``tables`` is
    the tuple of per-output truth-table bigints.  Returns
    uint32[len(tables), m, tile_words].
    """
    k = len(tables)
    out = np.empty((k, m, tile_words), np.uint32)
    order = np.lexsort((bitpos, rows))
    rows = rows[order]
    bitpos = bitpos[order]
    masks = np.uint32(1) << wires[order].astype(np.uint32)
    xacc = np.bitwise_xor.accumulate(masks) if len(masks) else masks
    # reset the accumulator at tile-group starts: combo = xacc ^ carry-in
    starts = np.nonzero(np.diff(rows, prepend=-1))[0]
    if len(rows):
        group_len = np.diff(np.append(starts, len(rows)))
        prev = np.where(starts > 0, xacc[np.maximum(starts - 1, 0)], 0)
        combo = xacc ^ np.repeat(prev, group_len).astype(np.uint32)
    else:
        combo = xacc
    for j, tt in enumerate(tables):
        lut = truth_table_bits(tt, n_inputs)
        background = bool(tt & 1)  # f(all inputs zero)
        vals = lut[combo]
        prevv = np.roll(vals, 1)
        prevv[starts] = background
        chg = vals != prevv
        words = rasterize_toggles(rows[chg], bitpos[chg], m, tile_words)
        out[j] = ~words if background else words
    return out
