"""`TileStore`: the hybrid tile-classified column store.

The single source of truth for column data in the query engine.  Each
column (a packed bitmap over the universe ``r``) is split into tiles of
``tile_words`` uint32 words and classified at build time:

  * ``TILE_ZERO`` (0)  -- every word 0
  * ``TILE_ONE``  (1)  -- every word 0xFFFFFFFF
  * ``TILE_DIRTY`` (2) -- anything else
  * ``TILE_RUN``  (3)  -- dirty, but a single 0/1 transition inside the
    tile (one run boundary).  Run tiles still carry their words in the
    dirty array (they need bit work when combined), but the tag feeds the
    planner's RUNCOUNT-style cost estimates.

Only dirty/run tiles store data: their words are packed contiguously in
ONE device array (``dirty``) with an offsets table (``dirty_index``)
mapping (column, tile) to a row of that array, so a tiled executor gathers
exactly the words it needs and clean tiles cost zero HBM traffic.
Per-column cardinality / density / runcount / clean-fraction statistics
are computed once here -- this is the paper's "index build time" work that
makes the planner data-aware without any per-query scanning.

Stores are immutable: ``append`` / ``replace`` return a new ``TileStore``
that shares nothing mutable with the old one, so stale references keep
working (the property ``BitmapIndex.add_column`` relies on).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitmaps import WORD_DTYPE, n_words_for, pack

from .tiles import BlockStats

__all__ = [
    "TILE_ZERO",
    "TILE_ONE",
    "TILE_DIRTY",
    "TILE_RUN",
    "ColumnStats",
    "MemberStats",
    "TileStore",
]

TILE_ZERO, TILE_ONE, TILE_DIRTY, TILE_RUN = 0, 1, 2, 3

def _signature_counts(cls: np.ndarray, *, return_inverse: bool = False):
    """Distinct per-tile class signatures of ``cls`` ([members, n_tiles]).

    Returns ``(signatures, counts)`` -- or ``(signatures, inverse)`` with
    ``return_inverse`` (the tiled executor's grouping).  Equivalent to
    ``np.unique(cls.T, axis=0)`` but via a void view over contiguous rows
    -- axis-unique's lexsort of object rows dominated planner and dispatch
    time on multi-thousand-tile stores."""
    rows = np.ascontiguousarray(cls.T)
    if rows.size == 0:
        return rows, np.zeros(0, np.int64)
    v = rows.view(np.dtype((np.void, rows.shape[1]))).ravel()
    uniq, second = np.unique(
        v, return_inverse=return_inverse, return_counts=not return_inverse
    )
    sigs = uniq.view(np.uint8).reshape(uniq.size, rows.shape[1])
    return sigs, second


if hasattr(np, "bitwise_count"):  # numpy >= 2.0
    def _popcount_words(row: np.ndarray) -> int:
        return int(np.bitwise_count(row).sum())
else:  # byte-table fallback for numpy 1.x
    _POP8 = np.array([bin(i).count("1") for i in range(256)], np.uint16)

    def _popcount_words(row: np.ndarray) -> int:
        return int(_POP8[row.view(np.uint8)].sum())


@dataclasses.dataclass(frozen=True)
class ColumnStats:
    """Build-time statistics of one column."""

    cardinality: int
    density: float
    runcount: int
    n_dirty_tiles: int  # DIRTY + RUN
    clean_fraction: float  # fraction of tiles that are ZERO/ONE


@dataclasses.dataclass(frozen=True)
class MemberStats:
    """Aggregate statistics of a member subset, consumed by the planner."""

    n: int
    n_words: int
    tile_words: int
    clean_fraction: float  # over (member, tile) pairs
    density: float  # mean member density
    dirty_words: int  # total words stored for the members' dirty tiles
    case3_tiles: int  # tiles where at least one member is dirty
    #: distinct tile-class signatures over the subset, as
    #: (tile_count, n_one, n_dirty) triples -- lets the planner price the
    #: tiled executor's per-signature dispatch overhead without specializing
    signatures: tuple = ()


@dataclasses.dataclass(frozen=True)
class _Column:
    """One classified column: per-tile word-level classes + dirty words.

    Word-level classification (all-zero / all-one / dirty) is all that
    execution and planning need and costs one vectorised comparison pass.
    The bit-level metadata (exact runcount, RUN tagging) needs an 8x
    ``unpackbits`` expansion, so the store computes it lazily on first
    access of ``classes`` / ``col_stats`` -- transient indexes built per
    query (the legacy shims) never pay for it.
    """

    classes: np.ndarray  # uint8 [n_tiles], word-level: ZERO/ONE/DIRTY only
    dirty: np.ndarray  # uint32 [n_dirty, tile_words], in tile order
    cardinality: int


def _classify_column(row: np.ndarray, tile_words: int) -> _Column:
    """Word-level classification of one padded column (uint32[n_tiles * tw])."""
    n_tiles = row.size // tile_words
    tiles = row.reshape(n_tiles, tile_words)
    all_zero = (tiles == 0).all(axis=1)
    all_one = (tiles == 0xFFFFFFFF).all(axis=1)
    classes = np.full(n_tiles, TILE_DIRTY, dtype=np.uint8)
    classes[all_zero] = TILE_ZERO
    classes[all_one] = TILE_ONE
    dirty = tiles[classes == TILE_DIRTY]
    return _Column(
        classes=classes,
        dirty=np.ascontiguousarray(dirty),
        cardinality=_popcount_words(row),
    )


def _classify_tile_words(words: np.ndarray) -> int:
    """Word-level class of one tile's words (ZERO / ONE / DIRTY)."""
    if not words.any():
        return TILE_ZERO
    if (words == 0xFFFFFFFF).all():
        return TILE_ONE
    return TILE_DIRTY


def _bit_stats(row: np.ndarray, classes: np.ndarray, tile_words: int, r: int):
    """Bit-level pass over one padded column: (runcount, run_mask)."""
    n_tiles = classes.size
    bits = np.unpackbits(row.view(np.uint8), bitorder="little")
    flips = bits[1:] != bits[:-1]
    rc = int(flips[: max(r - 1, 0)].sum()) + 1
    # transitions strictly inside each tile: positions [j*S, (j+1)*S - 2]
    span = tile_words * 32
    inner = np.concatenate([flips, [False]]).reshape(n_tiles, span)
    inner_counts = inner[:, : span - 1].sum(axis=1)
    run_mask = (classes >= TILE_DIRTY) & (inner_counts == 1)
    return rc, run_mask


class TileStore:
    """Tile-classified columns: classes + one packed dirty-tile array."""

    def __init__(self, columns: list, *, tile_words: int, n_words: int, r: int,
                 dense=None):
        self._cols: tuple = tuple(columns)
        self.tile_words = int(tile_words)
        self.n_words = int(n_words)
        self.r = int(r)
        self.n_tiles = (self.n_words + self.tile_words - 1) // self.tile_words
        # word-level classes [N, n_tiles]; dirty packing is assembled lazily
        # so append/replace stay O(changed column), not O(total dirty words)
        self._classes_word = (
            np.stack([c.classes for c in self._cols])
            if self._cols
            else np.zeros((0, self.n_tiles), np.uint8)
        )
        self._dirty_np_cache: np.ndarray | None = None
        self._dirty_index_cache: np.ndarray | None = None
        self._dirty_dev = None
        self._dense = dense  # optional cached jnp uint32[N, n_words]
        # bit-level metadata (RUN tags, runcounts): computed on first access
        self._refined_classes: np.ndarray | None = None
        self._col_stats: tuple | None = None
        # member_stats memo: stores are immutable, so the aggregate (incl.
        # the np.unique signature pass) per member subset never changes --
        # planners hit this once per (shard, subset), not once per query
        self._member_stats_cache: dict = {}

    def _assemble_dirty(self) -> None:
        if self._dirty_np_cache is not None:
            return
        counts = [c.dirty.shape[0] for c in self._cols]
        offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        index = np.full((len(self._cols), self.n_tiles), -1, np.int64)
        for i, c in enumerate(self._cols):
            index[i, c.classes >= TILE_DIRTY] = offsets[i] + np.arange(counts[i])
        self._dirty_index_cache = index
        self._dirty_np_cache = (
            np.concatenate([c.dirty for c in self._cols])
            if any(counts)
            else np.zeros((0, self.tile_words), np.uint32)
        )

    @property
    def dirty_index(self) -> np.ndarray:
        """int64[N, n_tiles]: row of ``dirty`` per (column, tile), -1 clean."""
        self._assemble_dirty()
        return self._dirty_index_cache

    @property
    def _dirty_np(self) -> np.ndarray:
        self._assemble_dirty()
        return self._dirty_np_cache

    # -- construction ------------------------------------------------------
    @classmethod
    def from_packed(cls, columns, *, tile_words: int = 64, r: int | None = None
                    ) -> "TileStore":
        """Build from packed bitmaps uint32[N, n_words] (device or host)."""
        dev = jnp.asarray(columns, WORD_DTYPE)
        arr = np.asarray(jax.device_get(dev), dtype=np.uint32)
        if arr.ndim != 2:
            raise ValueError(f"expected uint32[N, n_words], got shape {arr.shape}")
        n, nw = arr.shape
        r = int(r) if r is not None else nw * 32
        n_tiles = (nw + tile_words - 1) // tile_words
        padded = np.pad(arr, ((0, 0), (0, n_tiles * tile_words - nw)))
        cols = [_classify_column(padded[i], tile_words) for i in range(n)]
        return cls(cols, tile_words=tile_words, n_words=nw, r=r, dense=dev)

    @classmethod
    def from_dense(cls, bits, *, tile_words: int = 64) -> "TileStore":
        """Build from a dense boolean/int array [N, r]."""
        bits = jnp.asarray(bits)
        return cls.from_packed(pack(bits), tile_words=tile_words, r=bits.shape[-1])

    def _classify_row(self, packed_row) -> _Column:
        row = np.asarray(jax.device_get(jnp.asarray(packed_row, WORD_DTYPE)),
                         dtype=np.uint32)
        if row.shape != (self.n_words,):
            raise ValueError(f"expected shape ({self.n_words},), got {row.shape}")
        padded = np.pad(row, (0, self.n_tiles * self.tile_words - self.n_words))
        return _classify_column(padded, self.tile_words)

    def append(self, packed_row) -> "TileStore":
        """New store with one more column; only the new column is classified."""
        col = self._classify_row(packed_row)
        dense = None
        if self._dense is not None:
            dense = jnp.concatenate(
                [self._dense, jnp.asarray(packed_row, WORD_DTYPE)[None]], axis=0
            )
        return TileStore(list(self._cols) + [col], tile_words=self.tile_words,
                         n_words=self.n_words, r=self.r, dense=dense)

    def replace(self, i: int, packed_row) -> "TileStore":
        """New store with column ``i`` swapped; only its tiles are reclassified
        (the slot-mask update path: untouched columns keep their dirty rows)."""
        col = self._classify_row(packed_row)
        cols = list(self._cols)
        cols[int(i)] = col
        dense = None
        if self._dense is not None:
            dense = self._dense.at[int(i)].set(jnp.asarray(packed_row, WORD_DTYPE))
        return TileStore(cols, tile_words=self.tile_words, n_words=self.n_words,
                         r=self.r, dense=dense)

    def apply_tile_updates(self, updates: dict, *, r: int | None = None
                           ) -> "TileStore":
        """New store with individual tiles' words swapped -- the streaming
        compaction path (``repro.stream``).

        ``updates`` maps column slot -> {tile index -> uint32[tile_words]}
        (the tile's full new words, padding bits zero).  Only the touched
        tiles are reclassified and only the touched columns' dirty packs are
        respliced; untouched columns share their ``_Column`` (classes, dirty
        rows, stats) with this store, so the cost is O(touched columns'
        dirty rows), never a column- or store-wide reclassification like
        :meth:`replace` / :meth:`from_packed`.  Per-column cardinality is
        maintained by popcount deltas of the swapped tiles.

        ``r`` may *grow* the universe (``repro.stream``'s ``append_rows``):
        new tiles default to all-zero for every column, so only columns with
        set bits in the appended region need entries in ``updates``.
        """
        r_new = int(r) if r is not None else self.r
        if r_new < self.r:
            raise ValueError(f"universe cannot shrink ({self.r} -> {r_new})")
        nw_new = n_words_for(r_new)
        tw = self.tile_words
        n_tiles_new = (nw_new + tw - 1) // tw
        growth = n_tiles_new - self.n_tiles
        cols = []
        for i, old in enumerate(self._cols):
            upd = updates.get(i)
            if not upd and not growth:
                cols.append(old)  # shares classes/dirty/stats, immutable
                continue
            classes = np.concatenate(
                [old.classes, np.zeros(growth, np.uint8)]
            ) if growth else old.classes.copy()
            card = old.cardinality
            if upd:
                # position of each old tile's row in the old dirty pack
                old_pos = np.cumsum(old.classes >= TILE_DIRTY) - 1
                for t, words in upd.items():
                    t = int(t)
                    if not 0 <= t < n_tiles_new:
                        raise ValueError(f"tile {t} outside [0, {n_tiles_new})")
                    words = np.ascontiguousarray(words, dtype=np.uint32)
                    if words.shape != (tw,):
                        raise ValueError(
                            f"tile update must be uint32[{tw}], got {words.shape}"
                        )
                    card += _popcount_words(words)
                    if t < self.n_tiles:
                        oc = old.classes[t]
                        if oc == TILE_ONE:
                            card -= tw * 32
                        elif oc >= TILE_DIRTY:
                            card -= _popcount_words(old.dirty[old_pos[t]])
                    classes[t] = _classify_tile_words(words)
                dirty_t = np.nonzero(classes >= TILE_DIRTY)[0]
                dirty = np.empty((dirty_t.size, tw), np.uint32)
                is_upd = np.zeros(n_tiles_new, bool)
                is_upd[np.fromiter(upd, np.int64, len(upd))] = True
                from_base = ~is_upd[dirty_t]
                if from_base.any():
                    dirty[from_base] = old.dirty[old_pos[dirty_t[from_base]]]
                for t in dirty_t[~from_base].tolist():
                    dirty[np.searchsorted(dirty_t, t)] = upd[t]
                cols.append(_Column(classes=classes, dirty=dirty, cardinality=card))
            else:
                cols.append(_Column(classes=classes, dirty=old.dirty, cardinality=card))
        # dense view: dropped, rebuilt lazily from tiles on first densify()
        return TileStore(cols, tile_words=tw, n_words=nw_new, r=r_new)

    def with_tile_words(self, tile_words: int) -> "TileStore":
        """Reclassify the whole store at a different tile granularity."""
        if tile_words == self.tile_words:
            return self
        return TileStore.from_packed(self.densify(), tile_words=tile_words, r=self.r)

    def slice_tiles(self, t0: int, t1: int) -> "TileStore":
        """New store over the tile range [t0, t1) -- the row-space shard
        constructor.  Classes and dirty words are sliced, never recomputed,
        so carving S shards costs O(N * n_tiles) bookkeeping, not a
        reclassification pass; each shard carries its own offsets table and
        member statistics (built lazily like any other store)."""
        t0, t1 = int(t0), int(t1)
        if not 0 <= t0 < t1 <= self.n_tiles:
            raise ValueError(f"tile range [{t0}, {t1}) outside [0, {self.n_tiles})")
        tw = self.tile_words
        w0 = t0 * tw
        nw_local = min(self.n_words, t1 * tw) - w0
        r_local = min(self.r, t1 * tw * 32) - w0 * 32
        if r_local <= 0:
            raise ValueError(f"tile range [{t0}, {t1}) holds no bits of the universe")
        cols = []
        for c in self._cols:
            classes = np.ascontiguousarray(c.classes[t0:t1])
            p0 = int((c.classes[:t0] >= TILE_DIRTY).sum())
            nd = int((classes >= TILE_DIRTY).sum())
            dirty = np.ascontiguousarray(c.dirty[p0 : p0 + nd])
            card = _popcount_words(dirty) if dirty.size else 0
            card += int((classes == TILE_ONE).sum()) * tw * 32
            cols.append(_Column(classes=classes, dirty=dirty, cardinality=card))
        dense = None
        if self._dense is not None:
            dense = self._dense[:, w0 : w0 + nw_local]
        return TileStore(cols, tile_words=tw, n_words=nw_local, r=r_local,
                         dense=dense)

    @classmethod
    def concat_tiles(cls, stores, *, n_words: int | None = None,
                     r: int | None = None) -> "TileStore":
        """Inverse of :meth:`slice_tiles`: stitch tile-range stores back
        into one.  Classes and dirty words are concatenated per column --
        nothing is reclassified, the shards already hold the answer."""
        stores = list(stores)
        first = stores[0]
        tw = first.tile_words
        if any(s.tile_words != tw or s.n != first.n for s in stores):
            raise ValueError("stores must share tile_words and column count")
        if n_words is None:
            n_words = sum(s.n_words for s in stores)
        if r is None:
            r = sum(s.r for s in stores)
        cols = []
        for i in range(first.n):
            parts = [s._cols[i] for s in stores]
            cols.append(
                _Column(
                    classes=np.concatenate([p.classes for p in parts]),
                    dirty=np.concatenate([p.dirty for p in parts]),
                    cardinality=sum(p.cardinality for p in parts),
                )
            )
        dense = None
        if all(s._dense is not None for s in stores):
            dense = jnp.concatenate([s._dense for s in stores], axis=1)
        return cls(cols, tile_words=tw, n_words=n_words, r=r, dense=dense)

    # -- accessors ---------------------------------------------------------
    @property
    def n(self) -> int:
        return len(self._cols)

    @property
    def dirty(self) -> jax.Array:
        """The packed dirty-tile words, uint32[total_dirty, tile_words]."""
        if self._dirty_dev is None:
            self._dirty_dev = jnp.asarray(self._dirty_np)
        return self._dirty_dev

    @property
    def classes_word(self) -> np.ndarray:
        """Word-level classes (ZERO/ONE/DIRTY) -- all execution needs."""
        return self._classes_word

    @property
    def classes(self) -> np.ndarray:
        """Full classes incl. RUN tags (triggers the lazy bit-level pass)."""
        self._bit_refine()
        return self._refined_classes

    @property
    def col_stats(self) -> tuple:
        """Per-column :class:`ColumnStats` (triggers the lazy bit pass)."""
        self._bit_refine()
        return self._col_stats

    def _bit_refine(self) -> None:
        if self._col_stats is not None:
            return
        padded = self._padded_host()
        refined = self._classes_word.copy()
        stats = []
        for i, c in enumerate(self._cols):
            rc, run_mask = _bit_stats(
                padded[i], self._classes_word[i], self.tile_words, self.r
            )
            refined[i][run_mask] = TILE_RUN
            n_dirty = int((self._classes_word[i] >= TILE_DIRTY).sum())
            stats.append(
                ColumnStats(
                    cardinality=c.cardinality,
                    density=c.cardinality / max(self.r, 1),
                    runcount=rc,
                    n_dirty_tiles=n_dirty,
                    clean_fraction=1.0 - n_dirty / max(self.n_tiles, 1),
                )
            )
        self._refined_classes = refined
        self._col_stats = tuple(stats)

    def _padded_host(self) -> np.ndarray:
        """Host uint32[N, n_tiles * tile_words] reconstructed from tiles."""
        out = np.zeros((self.n, self.n_tiles, self.tile_words), np.uint32)
        out[self._classes_word == TILE_ONE] = 0xFFFFFFFF
        out[self._classes_word >= TILE_DIRTY] = self._dirty_np
        return out.reshape(self.n, -1)

    @property
    def cardinalities(self) -> tuple:
        return tuple(c.cardinality for c in self._cols)

    @property
    def densities(self) -> tuple:
        return tuple(c.cardinality / max(self.r, 1) for c in self._cols)

    @property
    def runcounts(self) -> tuple:
        return tuple(s.runcount for s in self.col_stats)

    @property
    def clean_fraction(self) -> float:
        """Fraction of (column, tile) pairs that are all-zero/all-one."""
        if self._classes_word.size == 0:
            return 1.0
        return float((self._classes_word <= TILE_ONE).mean())

    @property
    def dirty_words(self) -> int:
        return int((self._classes_word >= TILE_DIRTY).sum()) * self.tile_words

    def densify(self) -> jax.Array:
        """Dense uint32[N, n_words] view (cached) for dense-path backends."""
        if self._dense is None:
            self._dense = jnp.asarray(self._padded_host()[:, : self.n_words])
        return self._dense

    def column(self, i: int) -> jax.Array:
        return self.densify()[int(i)]

    def block_stats(self) -> BlockStats:
        """Legacy 3-class view (ZERO/ONE/DIRTY) for ``rbmrg_block``."""
        return BlockStats(classes=self._classes_word.copy(),
                          tile_words=self.tile_words, n_words=self.n_words)

    def member_stats(self, slots=None) -> MemberStats:
        """Planner-facing aggregate over a member subset (default: all).
        Cached per subset (the store is immutable)."""
        key = None if slots is None else tuple(slots)
        cached = self._member_stats_cache.get(key)
        if cached is not None:
            return cached
        idx = np.arange(self.n) if slots is None else np.asarray(list(key))
        if idx.size == 0:
            return MemberStats(0, self.n_words, self.tile_words, 1.0, 0.0, 0, 0)
        cls = self._classes_word[idx]
        dirty_tiles = int((cls >= TILE_DIRTY).sum())
        dens = [self._cols[i].cardinality / max(self.r, 1) for i in idx]
        sigs, counts = _signature_counts(cls)
        signatures = tuple(
            (int(cnt), int((sig == TILE_ONE).sum()), int((sig >= TILE_DIRTY).sum()))
            for sig, cnt in zip(sigs, counts)
        )
        stats = MemberStats(
            n=int(idx.size),
            n_words=self.n_words,
            tile_words=self.tile_words,
            clean_fraction=1.0 - dirty_tiles / max(cls.size, 1),
            density=float(np.mean(dens)),
            dirty_words=dirty_tiles * self.tile_words,
            case3_tiles=int(((cls >= TILE_DIRTY).any(axis=0)).sum()),
            signatures=signatures,
        )
        self._member_stats_cache[key] = stats
        return stats
