"""`TileStore`: the hybrid tile-classified column store.

The single source of truth for column data in the query engine.  Each
column (a packed bitmap over the universe ``r``) is split into tiles of
``tile_words`` uint32 words and classified at build time:

  * ``TILE_ZERO`` (0)  -- every word 0
  * ``TILE_ONE``  (1)  -- every word 0xFFFFFFFF
  * ``TILE_DIRTY`` (2) -- anything else
  * ``TILE_RUN``  (3)  -- dirty, but a single 0/1 transition inside the
    tile (one run boundary); a bit-level refinement computed lazily for
    the planner's RUNCOUNT-style estimates.

Dirty tiles additionally carry a **container kind** (``repro.storage.
containers``): low-popcount tiles are *sparse containers* (sorted uint16
bit positions), few-run tiles are *run containers* ((start, end) uint16
interval pairs), the rest are *dense containers* (the classic packed
words).  Each kind is packed contiguously per column -- and, store-wide,
in one array per kind with offset tables (``dense_index`` /
``sparse_index`` / ``run_index``) -- so a container-native executor reads
exactly the compressed payload of the tiles it needs, clean tiles cost
zero, and sparse/runny columns stop paying dense word costs in memory and
gather traffic.  ``containers=False`` keeps the legacy all-dense layout.

The legacy surface survives unchanged: ``dirty`` / ``dirty_index`` still
expose EVERY dirty tile as a densified row (assembled lazily, compressed
tiles decompressed on first access), so densify-first consumers keep
working while container-native ones (``run_tiled_circuit``) never force
the expansion.

Per-column cardinality / density / runcount / clean-fraction statistics
are computed once here -- this is the paper's "index build time" work that
makes the planner data-aware without any per-query scanning.

Stores are immutable: ``append`` / ``replace`` return a new ``TileStore``
that shares nothing mutable with the old one, so stale references keep
working (the property ``BitmapIndex.add_column`` relies on).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitmaps import WORD_DTYPE, n_words_for, pack

from .containers import (
    CONT_DENSE,
    CONT_NONE,
    CONT_RUN,
    CONT_SPARSE,
    compress_tiles,
    concat_ranges,
    containers_supported,
    words_from_runs,
    words_from_sparse,
)
from .tiles import BlockStats

__all__ = [
    "TILE_ZERO",
    "TILE_ONE",
    "TILE_DIRTY",
    "TILE_RUN",
    "ColumnStats",
    "MemberStats",
    "TileStore",
]

TILE_ZERO, TILE_ONE, TILE_DIRTY, TILE_RUN = 0, 1, 2, 3

def _signature_counts(cls: np.ndarray, *, return_inverse: bool = False):
    """Distinct per-tile class signatures of ``cls`` ([members, n_tiles]).

    Returns ``(signatures, counts)`` -- or ``(signatures, inverse)`` with
    ``return_inverse`` (the tiled executor's grouping).  Equivalent to
    ``np.unique(cls.T, axis=0)`` but via a void view over contiguous rows
    -- axis-unique's lexsort of object rows dominated planner and dispatch
    time on multi-thousand-tile stores."""
    rows = np.ascontiguousarray(cls.T)
    if rows.size == 0:
        return rows, np.zeros(0, np.int64)
    v = rows.view(np.dtype((np.void, rows.shape[1]))).ravel()
    uniq, second = np.unique(
        v, return_inverse=return_inverse, return_counts=not return_inverse
    )
    sigs = uniq.view(np.uint8).reshape(uniq.size, rows.shape[1])
    return sigs, second


if hasattr(np, "bitwise_count"):  # numpy >= 2.0
    def _popcount_words(row: np.ndarray) -> int:
        return int(np.bitwise_count(row).sum())
else:  # byte-table fallback for numpy 1.x
    _POP8 = np.array([bin(i).count("1") for i in range(256)], np.uint16)

    def _popcount_words(row: np.ndarray) -> int:
        return int(_POP8[row.view(np.uint8)].sum())


@dataclasses.dataclass(frozen=True)
class ColumnStats:
    """Build-time statistics of one column."""

    cardinality: int
    density: float
    runcount: int
    n_dirty_tiles: int  # DIRTY + RUN
    clean_fraction: float  # fraction of tiles that are ZERO/ONE


@dataclasses.dataclass(frozen=True)
class MemberStats:
    """Aggregate statistics of a member subset, consumed by the planner."""

    n: int
    n_words: int
    tile_words: int
    clean_fraction: float  # over (member, tile) pairs
    density: float  # mean member density
    dirty_words: int  # words a DENSE dirty pack would store for the members
    case3_tiles: int  # tiles where at least one member is dirty
    #: distinct tile-class signatures over the subset, as
    #: (tile_count, n_one, n_dirty) triples -- lets the planner price the
    #: tiled executor's per-signature dispatch overhead without specializing
    signatures: tuple = ()
    #: (dense, sparse, run) container counts over the subset's dirty tiles
    container_tiles: tuple = (0, 0, 0)
    #: words actually stored for the subset's dirty tiles (compressed;
    #: == dirty_words when every container is dense / containers are off)
    compressed_words: int = 0


@dataclasses.dataclass(frozen=True)
class _Column:
    """One classified column: per-tile word classes + container payloads.

    Word-level classification (all-zero / all-one / dirty) is all that
    execution and planning need and costs one vectorised comparison pass.
    Dirty tiles are compressed into per-kind packs in tile order (see
    ``repro.storage.containers``); the bit-level metadata (exact runcount,
    RUN tagging) still needs an 8x ``unpackbits`` expansion, so the store
    computes it lazily on first access of ``classes`` / ``col_stats``.
    """

    classes: np.ndarray  # uint8 [n_tiles], word-level: ZERO/ONE/DIRTY only
    kinds: np.ndarray  # uint8 [n_tiles], container kind (CONT_NONE clean)
    dense: np.ndarray  # uint32 [n_dense, tile_words], tile order
    spos: np.ndarray  # uint16 [sum p], sparse positions, tile order
    soff: np.ndarray  # int64 [n_sparse + 1]
    runs: np.ndarray  # uint16 [n_intervals, 2], (start, end), tile order
    roff: np.ndarray  # int64 [n_run + 1], interval-count offsets
    cardinality: int

    def dirty_words_dense(self, tile_words: int) -> np.ndarray:
        """EVERY dirty tile of this column densified, uint32[nd, tw]."""
        dk = self.kinds[self.classes >= TILE_DIRTY]
        out = np.empty((dk.size, tile_words), np.uint32)
        out[dk == CONT_DENSE] = self.dense
        if (dk == CONT_SPARSE).any():
            out[dk == CONT_SPARSE] = words_from_sparse(
                self.spos, self.soff, tile_words
            )
        if (dk == CONT_RUN).any():
            out[dk == CONT_RUN] = words_from_runs(self.runs, self.roff, tile_words)
        return out

    def storage_words(self, tile_words: int) -> int:
        """uint32-word-equivalents this column's containers occupy.

        Sparse tiles are charged per-tile ``ceil(p/2)`` (positions do not
        pool across tiles), matching ``TileStore.storage_words_cell`` --
        so census / member-stats / footprint metrics all agree."""
        sparse = int(((np.diff(self.soff) + 1) // 2).sum()) if len(self.soff) > 1 else 0
        return self.dense.shape[0] * tile_words + sparse + len(self.runs)


def _classify_column(row: np.ndarray, tile_words: int, *,
                     containers: bool = True) -> _Column:
    """Word-level classification + container compression of one padded
    column (uint32[n_tiles * tile_words])."""
    n_tiles = row.size // tile_words
    tiles = row.reshape(n_tiles, tile_words)
    all_zero = (tiles == 0).all(axis=1)
    all_one = (tiles == 0xFFFFFFFF).all(axis=1)
    classes = np.full(n_tiles, TILE_DIRTY, dtype=np.uint8)
    classes[all_zero] = TILE_ZERO
    classes[all_one] = TILE_ONE
    dirty_mask = classes == TILE_DIRTY
    ckinds, dense, spos, soff, runs, roff = compress_tiles(
        tiles[dirty_mask], tile_words, containers=containers
    )
    kinds = np.zeros(n_tiles, np.uint8)
    kinds[dirty_mask] = ckinds
    return _Column(
        classes=classes,
        kinds=kinds,
        dense=dense,
        spos=spos,
        soff=soff,
        runs=runs,
        roff=roff,
        cardinality=_popcount_words(row),
    )


def _classify_tile_words(words: np.ndarray) -> int:
    """Word-level class of one tile's words (ZERO / ONE / DIRTY)."""
    if not words.any():
        return TILE_ZERO
    if (words == 0xFFFFFFFF).all():
        return TILE_ONE
    return TILE_DIRTY


def _slice_column(c: _Column, t0: int, t1: int, tile_words: int) -> _Column:
    """Tile-range slice of one column's classes/kinds/packs -- nothing is
    reclassified, offsets are rebased."""
    classes = np.ascontiguousarray(c.classes[t0:t1])
    kinds = np.ascontiguousarray(c.kinds[t0:t1])
    d0 = int((c.kinds[:t0] == CONT_DENSE).sum())
    dn = int((kinds == CONT_DENSE).sum())
    dense = np.ascontiguousarray(c.dense[d0 : d0 + dn])
    s0 = int((c.kinds[:t0] == CONT_SPARSE).sum())
    sn = int((kinds == CONT_SPARSE).sum())
    soff = c.soff[s0 : s0 + sn + 1] - c.soff[s0]
    spos = np.ascontiguousarray(c.spos[c.soff[s0] : c.soff[s0 + sn]])
    r0 = int((c.kinds[:t0] == CONT_RUN).sum())
    rn = int((kinds == CONT_RUN).sum())
    roff = c.roff[r0 : r0 + rn + 1] - c.roff[r0]
    runs = np.ascontiguousarray(c.runs[c.roff[r0] : c.roff[r0 + rn]])
    card = _popcount_words(dense) if dense.size else 0
    card += int((classes == TILE_ONE).sum()) * tile_words * 32
    card += len(spos)
    if len(runs):
        card += int(
            (runs[:, 1].astype(np.int64) - runs[:, 0].astype(np.int64)).sum()
        )
    return _Column(classes=classes, kinds=kinds, dense=dense, spos=spos,
                   soff=soff, runs=runs, roff=roff, cardinality=card)


def _concat_columns(parts: list) -> _Column:
    """Inverse of :func:`_slice_column`: stitch tile-range columns."""
    soffs, shift = [parts[0].soff], parts[0].soff[-1]
    roffs, rshift = [parts[0].roff], parts[0].roff[-1]
    for p in parts[1:]:
        soffs.append(p.soff[1:] + shift)
        shift += p.soff[-1]
        roffs.append(p.roff[1:] + rshift)
        rshift += p.roff[-1]
    return _Column(
        classes=np.concatenate([p.classes for p in parts]),
        kinds=np.concatenate([p.kinds for p in parts]),
        dense=np.concatenate([p.dense for p in parts]),
        spos=np.concatenate([p.spos for p in parts]),
        soff=np.concatenate(soffs),
        runs=np.concatenate([p.runs for p in parts]),
        roff=np.concatenate(roffs),
        cardinality=sum(p.cardinality for p in parts),
    )


def _tile_cardinalities(c: _Column, tiles, tile_words: int) -> np.ndarray:
    """Popcount of the listed tiles, read from metadata/payloads only."""
    tiles = np.asarray(tiles, np.int64)
    out = np.zeros(tiles.size, np.int64)
    cls = c.classes[tiles]
    out[cls == TILE_ONE] = tile_words * 32
    kinds = c.kinds[tiles]
    dpos = np.cumsum(c.kinds == CONT_DENSE) - 1
    spos_ord = np.cumsum(c.kinds == CONT_SPARSE) - 1
    rpos = np.cumsum(c.kinds == CONT_RUN) - 1
    dn = kinds == CONT_DENSE
    if dn.any():
        if hasattr(np, "bitwise_count"):
            out[dn] = np.bitwise_count(c.dense[dpos[tiles[dn]]]).sum(
                axis=1, dtype=np.int64
            )
        else:
            out[dn] = [
                _popcount_words(c.dense[dpos[t]]) for t in tiles[dn]
            ]
    sp = kinds == CONT_SPARSE
    if sp.any():
        s = spos_ord[tiles[sp]]
        out[sp] = c.soff[s + 1] - c.soff[s]
    rn = kinds == CONT_RUN
    if rn.any():
        s = rpos[tiles[rn]]
        lens = c.runs[:, 1].astype(np.int64) - c.runs[:, 0].astype(np.int64)
        csum = np.concatenate([[0], np.cumsum(lens)])
        out[rn] = csum[c.roff[s + 1]] - csum[c.roff[s]]
    return out


def _bit_stats(row: np.ndarray, classes: np.ndarray, tile_words: int, r: int):
    """Bit-level pass over one padded column: (runcount, run_mask)."""
    n_tiles = classes.size
    bits = np.unpackbits(row.view(np.uint8), bitorder="little")
    flips = bits[1:] != bits[:-1]
    rc = int(flips[: max(r - 1, 0)].sum()) + 1
    # transitions strictly inside each tile: positions [j*S, (j+1)*S - 2]
    span = tile_words * 32
    inner = np.concatenate([flips, [False]]).reshape(n_tiles, span)
    inner_counts = inner[:, : span - 1].sum(axis=1)
    run_mask = (classes >= TILE_DIRTY) & (inner_counts == 1)
    return rc, run_mask


class TileStore:
    """Tile-classified columns: classes + per-kind packed container arrays."""

    def __init__(self, columns: list, *, tile_words: int, n_words: int, r: int,
                 dense=None, containers: bool = True):
        self._cols: tuple = tuple(columns)
        self.tile_words = int(tile_words)
        self.n_words = int(n_words)
        self.r = int(r)
        #: whether dirty tiles may be stored compressed (sparse/run);
        #: False keeps the legacy all-dense layout, and tile spans beyond
        #: uint16 positions force it off
        self.containers = bool(containers) and containers_supported(tile_words)
        self.n_tiles = (self.n_words + self.tile_words - 1) // self.tile_words
        # word-level classes [N, n_tiles]; packs are assembled lazily
        # so append/replace stay O(changed column), not O(total words)
        self._classes_word = (
            np.stack([c.classes for c in self._cols])
            if self._cols
            else np.zeros((0, self.n_tiles), np.uint8)
        )
        self._kinds_cache: np.ndarray | None = None
        self._dirty_np_cache: np.ndarray | None = None
        self._dirty_index_cache: np.ndarray | None = None
        self._dirty_dev = None
        self._packs: dict | None = None  # store-wide per-kind packs
        self._device_packs: tuple | None = None  # jnp pack mirrors + sentinels
        self._storage_words_cell: np.ndarray | None = None
        self._dense = dense  # optional cached jnp uint32[N, n_words]
        # bit-level metadata (RUN tags, runcounts): computed on first access
        self._refined_classes: np.ndarray | None = None
        self._col_stats: tuple | None = None
        # member_stats memo: stores are immutable, so the aggregate (incl.
        # the np.unique signature pass) per member subset never changes --
        # planners hit this once per (shard, subset), not once per query
        self._member_stats_cache: dict = {}

    # -- legacy densified dirty surface ------------------------------------
    def _assemble_dirty(self) -> None:
        """EVERY dirty tile as a dense row (compressed tiles decompressed)
        -- the densify-first consumers' view, assembled once on demand."""
        if self._dirty_np_cache is not None:
            return
        counts = [int((c.classes >= TILE_DIRTY).sum()) for c in self._cols]
        offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        index = np.full((len(self._cols), self.n_tiles), -1, np.int64)
        for i, c in enumerate(self._cols):
            index[i, c.classes >= TILE_DIRTY] = offsets[i] + np.arange(counts[i])
        self._dirty_index_cache = index
        self._dirty_np_cache = (
            np.concatenate(
                [c.dirty_words_dense(self.tile_words) for c in self._cols]
            )
            if any(counts)
            else np.zeros((0, self.tile_words), np.uint32)
        )

    @property
    def dirty_index(self) -> np.ndarray:
        """int64[N, n_tiles]: row of ``dirty`` per (column, tile), -1 clean."""
        self._assemble_dirty()
        return self._dirty_index_cache

    @property
    def _dirty_np(self) -> np.ndarray:
        self._assemble_dirty()
        return self._dirty_np_cache

    # -- container surface -------------------------------------------------
    @property
    def container_kinds(self) -> np.ndarray:
        """uint8[N, n_tiles]: CONT_NONE (clean) / CONT_DENSE / CONT_SPARSE /
        CONT_RUN per (column, tile)."""
        if self._kinds_cache is None:
            self._kinds_cache = (
                np.stack([c.kinds for c in self._cols])
                if self._cols
                else np.zeros((0, self.n_tiles), np.uint8)
            )
        return self._kinds_cache

    def _assemble_packs(self) -> None:
        """Store-wide per-kind packs + (column, tile) -> ordinal tables."""
        if self._packs is not None:
            return
        n = len(self._cols)
        kinds = self.container_kinds
        p: dict = {}
        for name, kind in (("dense", CONT_DENSE), ("sparse", CONT_SPARSE),
                           ("run", CONT_RUN)):
            counts = (kinds == kind).sum(axis=1)
            offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
            index = np.full((n, self.n_tiles), -1, np.int64)
            for i in range(n):
                index[i, kinds[i] == kind] = offsets[i] + np.arange(counts[i])
            p[f"{name}_index"] = index
        p["dense_pack"] = (
            np.concatenate([c.dense for c in self._cols])
            if n
            else np.zeros((0, self.tile_words), np.uint32)
        )
        soffs, shift = [np.zeros(1, np.int64)], 0
        for c in self._cols:
            soffs.append(c.soff[1:] + shift)
            shift += c.soff[-1]
        p["sparse_bounds"] = np.concatenate(soffs)
        p["sparse_pack"] = (
            np.concatenate([c.spos for c in self._cols])
            if n else np.zeros(0, np.uint16)
        )
        roffs, rshift = [np.zeros(1, np.int64)], 0
        for c in self._cols:
            roffs.append(c.roff[1:] + rshift)
            rshift += c.roff[-1]
        p["run_bounds"] = np.concatenate(roffs)
        p["run_pack"] = (
            np.concatenate([c.runs for c in self._cols])
            if n else np.zeros((0, 2), np.uint16)
        )
        self._packs = p

    @property
    def packs(self) -> dict:
        """The store-wide per-kind packs + ordinal tables (assembled lazily):
        ``dense_pack``/``sparse_pack``/``sparse_bounds``/``run_pack``/
        ``run_bounds`` and the int64[N, n_tiles] ``dense_index``/
        ``sparse_index``/``run_index`` tables.  This is the snapshot
        surface: ``repro.persist`` serializes exactly these arrays and
        :meth:`from_arrays` rebuilds the store from them."""
        self._assemble_packs()
        return self._packs

    def device_packs(self) -> tuple:
        """Device-resident pack mirrors for the single-scan engine
        (``repro.kernels.tiled_scan``), uploaded once per store and cached:

        * ``dense_pack1`` uint32[D + 2, tile_words] -- the dense pack plus
          an all-zeros sentinel row at ``D`` and an all-ones row at
          ``D + 1``, so clean cells gather by class without a branch;
        * ``sparse_pack1`` uint16[S + 1] -- one zero pad entry so padded
          gathers read a harmless position;
        * ``run_pack1`` uint16[R + 1, 2] -- one (0, 0) pad interval (an
          empty run toggles twice at bit 0: a no-op under prefix-xor).
        """
        if self._device_packs is None:
            import jax.numpy as jnp

            self._assemble_packs()
            p = self._packs
            tw = self.tile_words
            dense1 = np.concatenate([
                p["dense_pack"],
                np.zeros((1, tw), np.uint32),
                np.full((1, tw), 0xFFFFFFFF, np.uint32),
            ])
            sparse1 = np.concatenate([p["sparse_pack"],
                                      np.zeros(1, np.uint16)])
            run1 = np.concatenate([p["run_pack"],
                                   np.zeros((1, 2), np.uint16)])
            self._device_packs = (
                jnp.asarray(dense1), jnp.asarray(sparse1), jnp.asarray(run1)
            )
        return self._device_packs

    @property
    def storage_words_cell(self) -> np.ndarray:
        """int32[N, n_tiles]: uint32-word-equivalents stored per (column,
        tile) cell -- 0 clean, ``tile_words`` dense, ``ceil(p/2)`` sparse,
        ``i`` run.  The planner's container-aware pricing input."""
        if self._storage_words_cell is None:
            self._assemble_packs()
            kinds = self.container_kinds
            out = np.zeros(kinds.shape, np.int32)
            out[kinds == CONT_DENSE] = self.tile_words
            sp = kinds == CONT_SPARSE
            if sp.any():
                s = self._packs["sparse_index"][sp]
                b = self._packs["sparse_bounds"]
                out[sp] = (b[s + 1] - b[s] + 1) // 2
            rn = kinds == CONT_RUN
            if rn.any():
                s = self._packs["run_index"][rn]
                b = self._packs["run_bounds"]
                out[rn] = b[s + 1] - b[s]
            self._storage_words_cell = out
        return self._storage_words_cell

    def gather_cells(self, cols, tiles) -> np.ndarray:
        """Materialised words of arbitrary (column, tile) cells,
        uint32[M, tile_words] -- container-aware: dense cells are pack
        rows, sparse/run cells decompress, clean cells fill by class, and
        tiles past ``n_tiles`` read all-zero (the delta layer's growth
        convention).  THE tile materialisation primitive."""
        cols = np.asarray(cols, np.int64)
        tiles = np.asarray(tiles, np.int64)
        tw = self.tile_words
        out = np.zeros((cols.size, tw), np.uint32)
        inb = tiles < self.n_tiles
        if not inb.all():
            sel = np.nonzero(inb)[0]
            out[sel] = self.gather_cells(cols[sel], tiles[sel])
            return out
        self._assemble_packs()
        cls = self._classes_word[cols, tiles]
        out[cls == TILE_ONE] = 0xFFFFFFFF
        kinds = self.container_kinds[cols, tiles]
        dn = kinds == CONT_DENSE
        if dn.any():
            out[dn] = self._packs["dense_pack"][
                self._packs["dense_index"][cols[dn], tiles[dn]]
            ]
        sp = kinds == CONT_SPARSE
        if sp.any():
            s = self._packs["sparse_index"][cols[sp], tiles[sp]]
            b = self._packs["sparse_bounds"]
            take = concat_ranges(b[s], b[s + 1])
            off = np.concatenate([[0], np.cumsum(b[s + 1] - b[s])])
            out[sp] = words_from_sparse(self._packs["sparse_pack"][take], off, tw)
        rn = kinds == CONT_RUN
        if rn.any():
            s = self._packs["run_index"][cols[rn], tiles[rn]]
            b = self._packs["run_bounds"]
            take = concat_ranges(b[s], b[s + 1])
            off = np.concatenate([[0], np.cumsum(b[s + 1] - b[s])])
            out[rn] = words_from_runs(self._packs["run_pack"][take], off, tw)
        return out

    def gather_events(self, cols, tiles):
        """Boundary events of compressed (sparse/run) cells: every sparse
        position contributes toggles at ``p`` and ``p + 1``, every run
        interval at its endpoints.  Returns ``(cell, bitpos)`` arrays --
        ``cell`` indexes the input (col, tile) pair.  Cells must be
        SPARSE or RUN containers (the event path's precondition)."""
        cols = np.asarray(cols, np.int64)
        tiles = np.asarray(tiles, np.int64)
        self._assemble_packs()
        kinds = self.container_kinds[cols, tiles]
        out_cell, out_pos = [], []
        sp = kinds == CONT_SPARSE
        if sp.any():
            s = self._packs["sparse_index"][cols[sp], tiles[sp]]
            b = self._packs["sparse_bounds"]
            take = concat_ranges(b[s], b[s + 1])
            cell = np.repeat(np.nonzero(sp)[0], b[s + 1] - b[s])
            p = self._packs["sparse_pack"][take].astype(np.int64)
            out_cell += [cell, cell]
            out_pos += [p, p + 1]
        rn = kinds == CONT_RUN
        if rn.any():
            s = self._packs["run_index"][cols[rn], tiles[rn]]
            b = self._packs["run_bounds"]
            take = concat_ranges(b[s], b[s + 1])
            cell = np.repeat(np.nonzero(rn)[0], b[s + 1] - b[s])
            iv = self._packs["run_pack"][take].astype(np.int64)
            out_cell += [cell, cell]
            out_pos += [iv[:, 0], iv[:, 1]]
        if not out_cell:
            return np.zeros(0, np.int64), np.zeros(0, np.int64)
        return np.concatenate(out_cell), np.concatenate(out_pos)

    def container_census(self, slots=None) -> dict:
        """Per-kind tile counts + storage words of a member subset (default
        all columns) -- the "what is this data stored as" report."""
        idx = np.arange(self.n) if slots is None else np.asarray(list(slots))
        kinds = self.container_kinds[idx]
        cells = self.storage_words_cell[idx]
        return {
            "clean": int((kinds == CONT_NONE).sum()),
            "dense": int((kinds == CONT_DENSE).sum()),
            "sparse": int((kinds == CONT_SPARSE).sum()),
            "run": int((kinds == CONT_RUN).sum()),
            "storage_words": int(cells.sum()),
            "dense_equiv_words": int((kinds > CONT_NONE).sum()) * self.tile_words,
        }

    def storage_words(self) -> int:
        """Total uint32-word-equivalents the container packs occupy."""
        return sum(c.storage_words(self.tile_words) for c in self._cols)

    # -- construction ------------------------------------------------------
    @classmethod
    def from_packed(cls, columns, *, tile_words: int = 64, r: int | None = None,
                    containers: bool = True) -> "TileStore":
        """Build from packed bitmaps uint32[N, n_words] (device or host)."""
        dev = jnp.asarray(columns, WORD_DTYPE)
        arr = np.asarray(jax.device_get(dev), dtype=np.uint32)
        if arr.ndim != 2:
            raise ValueError(f"expected uint32[N, n_words], got shape {arr.shape}")
        n, nw = arr.shape
        r = int(r) if r is not None else nw * 32
        n_tiles = (nw + tile_words - 1) // tile_words
        padded = np.pad(arr, ((0, 0), (0, n_tiles * tile_words - nw)))
        enabled = bool(containers) and containers_supported(tile_words)
        cols = [
            _classify_column(padded[i], tile_words, containers=enabled)
            for i in range(n)
        ]
        return cls(cols, tile_words=tile_words, n_words=nw, r=r, dense=dev,
                   containers=enabled)

    @classmethod
    def from_dense(cls, bits, *, tile_words: int = 64,
                   containers: bool = True) -> "TileStore":
        """Build from a dense boolean/int array [N, r]."""
        bits = jnp.asarray(bits)
        return cls.from_packed(pack(bits), tile_words=tile_words,
                               r=bits.shape[-1], containers=containers)

    @classmethod
    def from_arrays(cls, arrays, *, tile_words: int, n_words: int, r: int,
                    containers: bool = True) -> "TileStore":
        """Trusted zero-copy constructor from the :attr:`packs` surface.

        ``arrays`` is a mapping holding ``classes`` / ``kinds`` (uint8
        [N, n_tiles]), ``cardinalities`` (int64 [N]) and the eight pack /
        ordinal-table arrays exactly as :attr:`packs` lays them out.  The
        arrays are adopted as-is (they may be read-only ``np.memmap``
        views over a snapshot file): per-column payloads become slices of
        the store-wide packs -- the per-column concatenation order of
        ``_assemble_packs`` guarantees contiguity -- so nothing larger
        than the offset rebases is copied.  Classification is NOT re-run;
        callers must hand back arrays a ``TileStore`` produced.
        """
        classes = np.asarray(arrays["classes"])
        kinds = np.asarray(arrays["kinds"])
        cards = np.asarray(arrays["cardinalities"], np.int64)
        if classes.ndim != 2 or classes.shape != kinds.shape:
            raise ValueError(
                f"classes/kinds must both be uint8[N, n_tiles], got "
                f"{classes.shape} vs {kinds.shape}"
            )
        n, n_tiles = classes.shape
        if n_tiles != (int(n_words) + int(tile_words) - 1) // int(tile_words):
            raise ValueError(
                f"{n_tiles} tiles inconsistent with n_words={n_words} at "
                f"tile_words={tile_words}"
            )
        if cards.shape != (n,):
            raise ValueError(f"expected {n} cardinalities, got {cards.shape}")
        dense_pack = arrays["dense_pack"]
        sparse_pack, sb = arrays["sparse_pack"], arrays["sparse_bounds"]
        run_pack, rb = arrays["run_pack"], arrays["run_bounds"]
        cols = []
        d0 = s0 = r0 = 0  # per-kind tile ordinals consumed so far
        for i in range(n):
            ki = kinds[i]
            dn = int((ki == CONT_DENSE).sum())
            sn = int((ki == CONT_SPARSE).sum())
            rn = int((ki == CONT_RUN).sum())
            cols.append(_Column(
                classes=classes[i],
                kinds=ki,
                dense=dense_pack[d0:d0 + dn],
                spos=sparse_pack[sb[s0]:sb[s0 + sn]],
                soff=np.asarray(sb[s0:s0 + sn + 1], np.int64) - sb[s0],
                runs=run_pack[rb[r0]:rb[r0 + rn]],
                roff=np.asarray(rb[r0:r0 + rn + 1], np.int64) - rb[r0],
                cardinality=int(cards[i]),
            ))
            d0 += dn
            s0 += sn
            r0 += rn
        if d0 != len(dense_pack) or sb[s0] != len(sparse_pack) \
                or rb[r0] != len(run_pack):
            raise ValueError("pack sizes inconsistent with the kind arrays")
        store = object.__new__(cls)
        store._cols = tuple(cols)
        store.tile_words = int(tile_words)
        store.n_words = int(n_words)
        store.r = int(r)
        store.containers = bool(containers) and containers_supported(tile_words)
        store.n_tiles = n_tiles
        store._classes_word = classes
        store._kinds_cache = kinds
        store._dirty_np_cache = None
        store._dirty_index_cache = None
        store._dirty_dev = None
        store._packs = {
            "dense_index": np.asarray(arrays["dense_index"]),
            "sparse_index": np.asarray(arrays["sparse_index"]),
            "run_index": np.asarray(arrays["run_index"]),
            "dense_pack": np.asarray(dense_pack),
            "sparse_pack": np.asarray(sparse_pack),
            "sparse_bounds": np.asarray(sb),
            "run_pack": np.asarray(run_pack),
            "run_bounds": np.asarray(rb),
        }
        store._storage_words_cell = None
        store._device_packs = None
        store._dense = None
        store._refined_classes = None
        store._col_stats = None
        store._member_stats_cache = {}
        if not (kinds > CONT_DENSE).any():
            # all-dense layout: the densified dirty pack IS the dense pack
            # (same per-column tile order), so the legacy device path reads
            # the memmap directly -- no assembly copy
            store._dirty_np_cache = store._packs["dense_pack"]
            store._dirty_index_cache = store._packs["dense_index"]
        return store

    def _classify_row(self, packed_row) -> _Column:
        row = np.asarray(jax.device_get(jnp.asarray(packed_row, WORD_DTYPE)),
                         dtype=np.uint32)
        if row.shape != (self.n_words,):
            raise ValueError(f"expected shape ({self.n_words},), got {row.shape}")
        padded = np.pad(row, (0, self.n_tiles * self.tile_words - self.n_words))
        return _classify_column(padded, self.tile_words,
                                containers=self.containers)

    def append(self, packed_row) -> "TileStore":
        """New store with one more column; only the new column is classified
        -- and compressed, so query results fed back as virtual columns are
        stored in container form, not as dense words."""
        col = self._classify_row(packed_row)
        dense = None
        if self._dense is not None:
            dense = jnp.concatenate(
                [self._dense, jnp.asarray(packed_row, WORD_DTYPE)[None]], axis=0
            )
        return TileStore(list(self._cols) + [col], tile_words=self.tile_words,
                         n_words=self.n_words, r=self.r, dense=dense,
                         containers=self.containers)

    def replace(self, i: int, packed_row) -> "TileStore":
        """New store with column ``i`` swapped; only its tiles are reclassified
        (the slot-mask update path: untouched columns keep their packs)."""
        col = self._classify_row(packed_row)
        cols = list(self._cols)
        cols[int(i)] = col
        dense = None
        if self._dense is not None:
            dense = self._dense.at[int(i)].set(jnp.asarray(packed_row, WORD_DTYPE))
        return TileStore(cols, tile_words=self.tile_words, n_words=self.n_words,
                         r=self.r, dense=dense, containers=self.containers)

    def apply_tile_updates(self, updates: dict, *, r: int | None = None
                           ) -> "TileStore":
        """New store with individual tiles' words swapped -- the streaming
        compaction path (``repro.stream``).

        ``updates`` maps column slot -> {tile index -> uint32[tile_words]}
        (the tile's full new words, padding bits zero).  Only the touched
        tiles are reclassified -- each into the CHEAPEST container for its
        new contents (a mutated sparse tile that filled up becomes dense,
        a cleared dense tile becomes sparse or vanishes) -- and only the
        touched columns' packs are respliced; untouched columns share
        their ``_Column`` (classes, packs, stats) with this store.
        Per-column cardinality is maintained by popcount deltas of the
        swapped tiles.

        ``r`` may *grow* the universe (``repro.stream``'s ``append_rows``):
        new tiles default to all-zero for every column, so only columns with
        set bits in the appended region need entries in ``updates``.
        """
        r_new = int(r) if r is not None else self.r
        if r_new < self.r:
            raise ValueError(f"universe cannot shrink ({self.r} -> {r_new})")
        nw_new = n_words_for(r_new)
        tw = self.tile_words
        n_tiles_new = (nw_new + tw - 1) // tw
        growth = n_tiles_new - self.n_tiles
        cols = []
        for i, old in enumerate(self._cols):
            upd = updates.get(i)
            if not upd and not growth:
                cols.append(old)  # shares classes/packs/stats, immutable
                continue
            if not upd:
                cols.append(
                    dataclasses.replace(
                        old,
                        classes=np.concatenate(
                            [old.classes, np.zeros(growth, np.uint8)]
                        ),
                        kinds=np.concatenate(
                            [old.kinds, np.zeros(growth, np.uint8)]
                        ),
                    )
                )
                continue
            cols.append(self._respliced_column(old, upd, n_tiles_new, growth))
        # dense view: dropped, rebuilt lazily from tiles on first densify()
        return TileStore(cols, tile_words=tw, n_words=nw_new, r=r_new,
                         containers=self.containers)

    def _respliced_column(self, old: _Column, upd: dict, n_tiles_new: int,
                          growth: int) -> _Column:
        """One touched column of :meth:`apply_tile_updates`: reclassify +
        recompress the updated tiles, splice untouched payload slices."""
        tw = self.tile_words
        classes = np.concatenate(
            [old.classes, np.zeros(growth, np.uint8)]
        ) if growth else old.classes.copy()
        ut = np.fromiter(upd, np.int64, len(upd))
        if ut.size and not ((0 <= ut) & (ut < n_tiles_new)).all():
            bad = ut[(ut < 0) | (ut >= n_tiles_new)][0]
            raise ValueError(f"tile {bad} outside [0, {n_tiles_new})")
        ut.sort()
        new_words = np.empty((ut.size, tw), np.uint32)
        for j, t in enumerate(ut.tolist()):
            w = np.ascontiguousarray(upd[t], dtype=np.uint32)
            if w.shape != (tw,):
                raise ValueError(
                    f"tile update must be uint32[{tw}], got {w.shape}"
                )
            new_words[j] = w
        # popcount-delta cardinality: new - old for every touched tile
        card = old.cardinality
        if hasattr(np, "bitwise_count"):
            card += int(np.bitwise_count(new_words).sum())
        else:
            card += _popcount_words(new_words)
        in_base = ut < self.n_tiles
        card -= int(_tile_cardinalities(old, ut[in_base], tw).sum())
        new_classes = np.fromiter(
            (_classify_tile_words(w) for w in new_words), np.uint8, ut.size
        )
        classes[ut] = new_classes
        nd_mask = new_classes >= TILE_DIRTY
        nkinds, ndense, nspos, nsoff, nruns, nroff = compress_tiles(
            new_words[nd_mask], tw, containers=self.containers
        )
        upd_dirty = ut[nd_mask]  # sorted tile ids of the compressed batch
        kinds = np.concatenate(
            [old.kinds, np.zeros(growth, np.uint8)]
        ) if growth else old.kinds.copy()
        kinds[ut] = 0
        kinds[upd_dirty] = nkinds
        # splice packs in tile order: updated tiles from the new batch,
        # untouched tiles from the old packs -- vectorised per kind (one
        # fancy index per source), never a per-tile Python pass
        old_dense_pos = np.cumsum(old.kinds == CONT_DENSE) - 1
        old_sparse_pos = np.cumsum(old.kinds == CONT_SPARSE) - 1
        old_run_pos = np.cumsum(old.kinds == CONT_RUN) - 1
        new_dense_pos = np.cumsum(nkinds == CONT_DENSE) - 1
        new_sparse_pos = np.cumsum(nkinds == CONT_SPARSE) - 1
        new_run_pos = np.cumsum(nkinds == CONT_RUN) - 1
        dirty_t = np.nonzero(classes >= TILE_DIRTY)[0]
        is_new = np.isin(dirty_t, upd_dirty)
        new_j = np.searchsorted(upd_dirty, dirty_t)  # valid where is_new

        dsel = kinds[dirty_t] == CONT_DENSE
        d_tiles, d_new = dirty_t[dsel], is_new[dsel]
        dense = np.empty((d_tiles.size, tw), np.uint32)
        if (~d_new).any():
            dense[~d_new] = old.dense[old_dense_pos[d_tiles[~d_new]]]
        if d_new.any():
            dense[d_new] = ndense[new_dense_pos[new_j[dsel][d_new]]]

        def splice_var(sel, old_pos, old_off, old_pack, new_pos, new_off,
                       new_pack, empty):
            tiles_k, from_new = dirty_t[sel], is_new[sel]
            counts = np.zeros(tiles_k.size, np.int64)
            o = old_pos[tiles_k[~from_new]] if (~from_new).any() else None
            if o is not None:
                counts[~from_new] = old_off[o + 1] - old_off[o]
            j = new_pos[new_j[sel][from_new]] if from_new.any() else None
            if j is not None:
                counts[from_new] = new_off[j + 1] - new_off[j]
            off = np.zeros(tiles_k.size + 1, np.int64)
            np.cumsum(counts, out=off[1:])
            pack = np.empty((int(off[-1]),) + empty.shape[1:], empty.dtype)
            if o is not None:
                pack[concat_ranges(off[:-1][~from_new], off[1:][~from_new])] = \
                    old_pack[concat_ranges(old_off[o], old_off[o + 1])]
            if j is not None:
                pack[concat_ranges(off[:-1][from_new], off[1:][from_new])] = \
                    new_pack[concat_ranges(new_off[j], new_off[j + 1])]
            return pack, off

        spos, soff = splice_var(
            kinds[dirty_t] == CONT_SPARSE, old_sparse_pos, old.soff, old.spos,
            new_sparse_pos, nsoff, nspos, np.zeros((0,), np.uint16),
        )
        runs, roff = splice_var(
            kinds[dirty_t] == CONT_RUN, old_run_pos, old.roff, old.runs,
            new_run_pos, nroff, nruns, np.zeros((0, 2), np.uint16),
        )
        return _Column(
            classes=classes,
            kinds=kinds,
            dense=dense,
            spos=spos,
            soff=soff,
            runs=runs,
            roff=roff,
            cardinality=card,
        )

    def with_tile_words(self, tile_words: int) -> "TileStore":
        """Reclassify the whole store at a different tile granularity."""
        if tile_words == self.tile_words:
            return self
        return TileStore.from_packed(self.densify(), tile_words=tile_words,
                                     r=self.r, containers=self.containers)

    def slice_tiles(self, t0: int, t1: int) -> "TileStore":
        """New store over the tile range [t0, t1) -- the row-space shard
        constructor.  Classes, kinds and container packs are sliced, never
        recomputed or reclassified, so carving S shards costs
        O(N * n_tiles) bookkeeping; each shard carries its own offset
        tables and member statistics (built lazily like any other store)."""
        t0, t1 = int(t0), int(t1)
        if not 0 <= t0 < t1 <= self.n_tiles:
            raise ValueError(f"tile range [{t0}, {t1}) outside [0, {self.n_tiles})")
        tw = self.tile_words
        w0 = t0 * tw
        nw_local = min(self.n_words, t1 * tw) - w0
        r_local = min(self.r, t1 * tw * 32) - w0 * 32
        if r_local <= 0:
            raise ValueError(f"tile range [{t0}, {t1}) holds no bits of the universe")
        cols = [_slice_column(c, t0, t1, tw) for c in self._cols]
        dense = None
        if self._dense is not None:
            dense = self._dense[:, w0 : w0 + nw_local]
        return TileStore(cols, tile_words=tw, n_words=nw_local, r=r_local,
                         dense=dense, containers=self.containers)

    @classmethod
    def concat_tiles(cls, stores, *, n_words: int | None = None,
                     r: int | None = None) -> "TileStore":
        """Inverse of :meth:`slice_tiles`: stitch tile-range stores back
        into one.  Classes and container packs are concatenated per column
        -- nothing is reclassified, the shards already hold the answer."""
        stores = list(stores)
        first = stores[0]
        tw = first.tile_words
        if any(s.tile_words != tw or s.n != first.n for s in stores):
            raise ValueError("stores must share tile_words and column count")
        if n_words is None:
            n_words = sum(s.n_words for s in stores)
        if r is None:
            r = sum(s.r for s in stores)
        cols = [
            _concat_columns([s._cols[i] for s in stores])
            for i in range(first.n)
        ]
        dense = None
        if all(s._dense is not None for s in stores):
            dense = jnp.concatenate([s._dense for s in stores], axis=1)
        return cls(cols, tile_words=tw, n_words=n_words, r=r, dense=dense,
                   containers=first.containers)

    # -- accessors ---------------------------------------------------------
    @property
    def n(self) -> int:
        return len(self._cols)

    @property
    def dirty(self) -> jax.Array:
        """The densified dirty-tile words, uint32[total_dirty, tile_words]
        (compressed containers expanded on first access)."""
        if self._dirty_dev is None:
            self._dirty_dev = jnp.asarray(self._dirty_np)
        return self._dirty_dev

    @property
    def classes_word(self) -> np.ndarray:
        """Word-level classes (ZERO/ONE/DIRTY) -- all execution needs."""
        return self._classes_word

    @property
    def classes(self) -> np.ndarray:
        """Full classes incl. RUN tags (triggers the lazy bit-level pass)."""
        self._bit_refine()
        return self._refined_classes

    @property
    def col_stats(self) -> tuple:
        """Per-column :class:`ColumnStats` (triggers the lazy bit pass)."""
        self._bit_refine()
        return self._col_stats

    def _bit_refine(self) -> None:
        if self._col_stats is not None:
            return
        padded = self._padded_host()
        refined = self._classes_word.copy()
        stats = []
        for i, c in enumerate(self._cols):
            rc, run_mask = _bit_stats(
                padded[i], self._classes_word[i], self.tile_words, self.r
            )
            refined[i][run_mask] = TILE_RUN
            n_dirty = int((self._classes_word[i] >= TILE_DIRTY).sum())
            stats.append(
                ColumnStats(
                    cardinality=c.cardinality,
                    density=c.cardinality / max(self.r, 1),
                    runcount=rc,
                    n_dirty_tiles=n_dirty,
                    clean_fraction=1.0 - n_dirty / max(self.n_tiles, 1),
                )
            )
        self._refined_classes = refined
        self._col_stats = tuple(stats)

    def _padded_host(self) -> np.ndarray:
        """Host uint32[N, n_tiles * tile_words] reconstructed from tiles."""
        out = np.zeros((self.n, self.n_tiles, self.tile_words), np.uint32)
        out[self._classes_word == TILE_ONE] = 0xFFFFFFFF
        out[self._classes_word >= TILE_DIRTY] = self._dirty_np
        return out.reshape(self.n, -1)

    @property
    def cardinalities(self) -> tuple:
        return tuple(c.cardinality for c in self._cols)

    @property
    def densities(self) -> tuple:
        return tuple(c.cardinality / max(self.r, 1) for c in self._cols)

    @property
    def runcounts(self) -> tuple:
        return tuple(s.runcount for s in self.col_stats)

    @property
    def clean_fraction(self) -> float:
        """Fraction of (column, tile) pairs that are all-zero/all-one."""
        if self._classes_word.size == 0:
            return 1.0
        return float((self._classes_word <= TILE_ONE).mean())

    @property
    def dirty_words(self) -> int:
        """Words a dense dirty pack would hold (the legacy metric; see
        :meth:`storage_words` for what the containers actually occupy)."""
        return int((self._classes_word >= TILE_DIRTY).sum()) * self.tile_words

    def densify(self) -> jax.Array:
        """Dense uint32[N, n_words] view (cached) for dense-path backends."""
        if self._dense is None:
            self._dense = jnp.asarray(self._padded_host()[:, : self.n_words])
        return self._dense

    def column(self, i: int) -> jax.Array:
        return self.densify()[int(i)]

    def block_stats(self) -> BlockStats:
        """Legacy 3-class view (ZERO/ONE/DIRTY) for ``rbmrg_block``."""
        return BlockStats(classes=self._classes_word.copy(),
                          tile_words=self.tile_words, n_words=self.n_words)

    def member_stats(self, slots=None) -> MemberStats:
        """Planner-facing aggregate over a member subset (default: all).
        Cached per subset (the store is immutable)."""
        key = None if slots is None else tuple(slots)
        cached = self._member_stats_cache.get(key)
        if cached is not None:
            return cached
        idx = np.arange(self.n) if slots is None else np.asarray(list(key))
        if idx.size == 0:
            return MemberStats(0, self.n_words, self.tile_words, 1.0, 0.0, 0, 0)
        cls = self._classes_word[idx]
        dirty_tiles = int((cls >= TILE_DIRTY).sum())
        dens = [self._cols[i].cardinality / max(self.r, 1) for i in idx]
        sigs, counts = _signature_counts(cls)
        signatures = tuple(
            (int(cnt), int((sig == TILE_ONE).sum()), int((sig >= TILE_DIRTY).sum()))
            for sig, cnt in zip(sigs, counts)
        )
        kinds = self.container_kinds[idx]
        stats = MemberStats(
            n=int(idx.size),
            n_words=self.n_words,
            tile_words=self.tile_words,
            clean_fraction=1.0 - dirty_tiles / max(cls.size, 1),
            density=float(np.mean(dens)),
            dirty_words=dirty_tiles * self.tile_words,
            case3_tiles=int(((cls >= TILE_DIRTY).any(axis=0)).sum()),
            signatures=signatures,
            container_tiles=(
                int((kinds == CONT_DENSE).sum()),
                int((kinds == CONT_SPARSE).sum()),
                int((kinds == CONT_RUN).sum()),
            ),
            compressed_words=int(self.storage_words_cell[idx].sum()),
        )
        self._member_stats_cache[key] = stats
        return stats
