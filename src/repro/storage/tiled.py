"""Tiled circuit execution: RBMRG clean/dirty skipping for ANY compiled query.

``rbmrg_block_threshold`` (tiles.py) applies the paper's 3-case split to a
bare threshold.  This module generalises it to arbitrary compiled circuits
(``Interval`` / ``Exactly`` / ``And`` / ``Or`` compositions, multi-output
batched queries), using :meth:`Circuit.specialize`:

  1. group tiles by their *class signature* -- the tuple of per-column
     classes (all-zero / all-one / dirty) restricted to the circuit's
     support.  Tiles with the same signature need the same residual work;
  2. partially evaluate the circuit per signature.  Outputs that fold to
     constants are the case-1/case-2 tiles: written directly, zero bit
     work, zero HBM traffic;
  3. for the rest, execute *container-natively*: tiles whose residual
     inputs are all sparse/run containers (and whose compressed payload
     undercuts the dense gather) are resolved by merging their boundary
     events against the residual's exact truth table -- the paper's
     MergeOpt/ScanCount algorithms re-expressed over compressed tiles --
     so the bit work scales with container sizes, not tile spans;
  4. the remaining tiles gather (decompressing on the fly, never
     store-wide) into one ``[n_dirty, m * tile_words]`` batch and dispatch
     one fused Pallas call per *structurally distinct residual circuit* --
     signatures whose residuals fold to the same gate DAG (for a bare
     threshold, any two signatures with equal (T - #ones, #dirty)) are
     merged into one launch, capping the signature explosion that made
     cf=0.5 workloads dispatch one kernel per signature.  Compiled
     evaluators are additionally cached by circuit structure, so recurring
     residuals share kernels across queries and stores.

The skipping decision is made before launch -- the TPU-legal realisation
of EWAH's fast-forwarding, now for every backend that compiles to a
circuit rather than only bare thresholds.
"""
from __future__ import annotations

import numpy as np

from repro.core.circuits import (
    CONST0,
    CONST1,
    _EXACT_CONST_MAX_INPUTS,
    _truth_table_masks,
    Circuit,
)

from .containers import (
    CONT_DENSE,
    CONT_RUN,
    CONT_SPARSE,
    CONTAINER_CROSSOVER,
    evaluate_event_tiles,
)
from .tilestore import TILE_ONE, TILE_ZERO, TileStore, _signature_counts

__all__ = ["run_tiled_circuit"]

# residual-circuit memo: (circuit structural key, signature bytes) -> result
# of Circuit.specialize.  Signatures recur heavily (clean-dominated data has
# a handful), so this makes per-query specialisation O(#distinct signatures).
_SPECIALIZE_MEMO: dict[tuple, tuple] = {}
_SPECIALIZE_MEMO_CAP = 4096

# beyond this many distinct signatures the data is effectively unclassifiable
# at this granularity; the overflow tiles run the dense support circuit.
# Shared with the planner's cost model so plans price the same split the
# executor actually runs.
from repro.core.planner import _MAX_EXACT_SIGNATURES as _MAX_SIGNATURES


def _residual_key(res: Circuit):
    """Merge key for residual circuits: the exact truth table when the
    support is small (two residuals compute the same function iff their
    tables match -- stronger than structural identity, so e.g. every
    bare-threshold signature with equal (T - #ones, #dirty) merges no
    matter where the folded constants sat in the adder), else the
    gate-order-independent Merkle key."""
    if res.n_inputs <= _EXACT_CONST_MAX_INPUTS:
        masks, zeros, ones = _truth_table_masks(res.n_inputs)
        return (res.n_inputs, tuple(res.evaluate(masks, zeros, ones)))
    return res.semantic_key()


def _specialize(circuit: Circuit, ckey: tuple, sig_bytes: bytes, assign: dict):
    """Memoised ``circuit.specialize`` + residual merge key.

    Returns (const_outputs, residual, kept_inputs, residual_key|None).
    """
    key = (ckey, sig_bytes)
    got = _SPECIALIZE_MEMO.get(key)
    if got is None:
        if len(_SPECIALIZE_MEMO) >= _SPECIALIZE_MEMO_CAP:
            _SPECIALIZE_MEMO.clear()
        const, res, kept = circuit.specialize(assign)
        got = (const, res, kept, None if res is None else _residual_key(res))
        _SPECIALIZE_MEMO[key] = got
    return got


def run_tiled_circuit(
    store: TileStore,
    circuit: Circuit,
    *,
    block_words: int | None = None,
    interpret: bool | None = None,
    pallas: bool = True,
    tiles=None,
):
    """Evaluate ``circuit`` over the store's columns with tile skipping.

    Returns ``(out, info)``: ``out`` is uint32[n_words] for a single-output
    circuit, uint32[k, n_words] otherwise; ``info`` reports the realised
    3-case split and the words actually gathered (the paper's Table 4
    work-skipped accounting, generalised).

    ``tiles`` restricts evaluation (and its signature specialisation /
    launch merging) to a subset of tile indices -- incremental maintenance
    work that re-runs a circuit only where inputs changed.  With it,
    ``out`` is a host ``uint32[k, len(tiles), tile_words]`` array (per
    selected tile, no tail clipping -- callers mask the partial final
    tile) and ``info["dirty_words_gathered"]`` counts only the restricted
    gather.  (``repro.stream``'s view refresh uses a leaner direct path --
    one support-residual circuit, no per-signature split -- because its
    pending tiles are typically uniformly dirty.)
    """
    import jax

    from repro.kernels.threshold_ssum import (
        INTERPRET,
        circuit_structural_key,
        run_circuit_cached,
    )

    if interpret is None:
        interpret = INTERPRET
    if circuit.n_inputs != store.n:
        raise ValueError(f"circuit has {circuit.n_inputs} inputs, store {store.n} columns")
    k = len(circuit.outputs)
    tw, n_tiles, nw = store.tile_words, store.n_tiles, store.n_words
    support = circuit.support()
    ckey = circuit_structural_key(circuit)

    restricted = tiles is not None
    sel = None
    if restricted:
        sel = np.asarray(tiles, dtype=np.int64)
        if sel.ndim != 1 or (sel.size and not
                             ((0 <= sel) & (sel < n_tiles)).all()):
            raise ValueError(f"tiles must be 1-D indices in [0, {n_tiles})")
    n_sel = int(sel.size) if restricted else n_tiles

    out = np.zeros((k, n_sel, tw), dtype=np.uint32)
    info = {
        "n_tiles": n_tiles,
        "selected_tiles": n_sel,
        "n_outputs": k,
        "signatures": 0,
        "residual_signatures": 0,  # signatures needing a residual kernel
        "const_tiles": 0,  # tiles where EVERY output folded to a constant
        "case3_tiles": 0,
        "dirty_words_gathered": 0,
        "total_words": int(store.n * nw),
        "launches": 0,
        "event_tiles": 0,  # case-3 tiles resolved container-natively
        "densified_tiles": 0,  # case-3 tiles resolved by a dense gather
        "compressed_words_gathered": 0,  # storage words read from containers
        "words_by_kind": {"dense": 0, "sparse": 0, "run": 0},
    }

    def _finish():
        info["work_fraction"] = info["dirty_words_gathered"] / max(
            1, info["total_words"]
        )
        if restricted:
            return out, info  # host [k, n_sel, tw], caller patches per tile
        result = out.reshape(k, -1)[:, :nw]
        return jax.numpy.asarray(result[0] if k == 1 else result), info

    if not support:
        # constant circuit: no data touched at all
        const, _res, _kept = circuit.specialize({})
        for j, cval in enumerate(const):
            out[j] = 0xFFFFFFFF if cval else 0
        info["const_tiles"] = n_sel
        return _finish()

    # word-level signature per tile over the support (RUN counts as dirty:
    # its words need bit work whenever the tile participates at all).  Under
    # a tile restriction, "tile" arrays below index positions within ``sel``
    # (the output buffer); ``sel`` maps them back to store tile ids.
    cls = store.classes_word[support]  # [s, n_tiles], ZERO/ONE/DIRTY
    if restricted:
        cls = cls[:, sel]
    sigs, inverse = _signature_counts(cls, return_inverse=True)
    info["signatures"] = int(sigs.shape[0])

    # most-populous signatures get exact specialisation; overflow tiles run
    # the dense support circuit (correct, just less skipping)
    order = np.argsort(-np.bincount(inverse, minlength=sigs.shape[0]))
    exact = set(order[:_MAX_SIGNATURES].tolist())

    # Pass 1: specialize per signature, write the constant-folded tiles, and
    # bucket the residual work by the residual circuit's STRUCTURE.  Distinct
    # signatures routinely fold to the same gate DAG (a bare threshold only
    # depends on (T - #ones, #dirty)), so merging them caps the launch count:
    # one gather + one kernel per structurally distinct residual, not one per
    # signature (the cf=0.5 regime went from 8 launches to ~3).
    overflow_tiles: list = []
    merged: dict[tuple, list] = {}  # (residual key, live outputs) -> work
    for s_id in range(sigs.shape[0]):
        tiles = np.nonzero(inverse == s_id)[0]
        if s_id not in exact:
            overflow_tiles.append(tiles)
            continue
        sig = sigs[s_id]
        assign = {i: CONST0 for i in range(store.n) if i not in support}
        for j, col in enumerate(support):
            if sig[j] == TILE_ZERO:
                assign[col] = CONST0
            elif sig[j] == TILE_ONE:
                assign[col] = CONST1
        const, res, kept, rkey = _specialize(circuit, ckey, sig.tobytes(), assign)
        for j, cval in enumerate(const):
            if cval is not None:
                out[j, tiles] = 0xFFFFFFFF if cval else 0
        if res is None:
            info["const_tiles"] += int(tiles.size)
            continue
        info["case3_tiles"] += int(tiles.size)
        info["residual_signatures"] += 1
        live = tuple(j for j, cval in enumerate(const) if cval is None)
        merged.setdefault((rkey, live), [res, []])[1].append((tiles, kept))

    # Pass 2: per merged group, split its case-3 tiles by representation.
    # Tiles whose residual inputs are ALL compressed containers (sparse /
    # run) -- and whose compressed payload undercuts the dense gather by
    # the crossover -- are evaluated container-natively: boundary events
    # merged position-list-style against the residual's exact truth table
    # (the paper's MergeOpt/ScanCount view of the same query).  The rest
    # densify per tile (sparse/run cells decompressed on the fly, never a
    # store-wide expansion) into one gather + one cached kernel per group.
    container_native = hasattr(store, "gather_events") and getattr(
        store, "container_kinds", None
    ) is not None
    ck = store.container_kinds if container_native else None
    swc = store.storage_words_cell if container_native else None
    # with no compressed tile anywhere (containers off, or purely dense
    # data) the legacy device-side gather path is byte-identical and keeps
    # the working set on-device -- no host round trip per query
    # paged stores (repro.persist.tiers) must never trigger the whole-pack
    # device upload: their point is touching only the gathered tiles
    all_dense = not getattr(store, "paged", False) and (
        not container_native or not (ck > CONT_DENSE).any()
    )
    for (rkey, live), (res, entries) in merged.items():
        m = res.n_inputs
        # exact truth tables exist for small residuals; _residual_key
        # computed them already (rkey = (n_inputs, per-output tables))
        tables = (
            rkey[1]
            if container_native and m <= _EXACT_CONST_MAX_INPUTS
            else None
        )
        ev_rows, ev_pos, ev_wires = [], [], []
        ev_out_tiles: list = []
        dense_out_tiles: list = []
        dense_gathers: list = []
        n_ev = 0
        for tiles, kept in entries:
            stiles = sel[tiles] if restricted else tiles
            kcols = np.asarray(kept, np.int64)
            if tables is not None:
                kinds_cell = ck[kcols[:, None], stiles[None, :]]
                comp = (kinds_cell == CONT_SPARSE) | (kinds_cell == CONT_RUN)
                cwords = swc[kcols[:, None], stiles[None, :]].sum(axis=0)
                ev_mask = comp.all(axis=0) & (
                    cwords <= CONTAINER_CROSSOVER * m * tw
                )
            else:
                ev_mask = np.zeros(tiles.size, bool)
            if ev_mask.any():
                et = stiles[ev_mask]
                ne = int(et.size)
                cell, pos = store.gather_events(
                    np.repeat(kcols, ne), np.tile(et, m)
                )
                ev_rows.append(n_ev + cell % ne)
                ev_pos.append(pos)
                ev_wires.append(cell // ne)
                ev_out_tiles.append(tiles[ev_mask])
                n_ev += ne
                sw_ev = swc[kcols[:, None], et[None, :]]
                ew = int(sw_ev.sum())
                info["compressed_words_gathered"] += ew
                info["dirty_words_gathered"] += ew
                kc_ev = kinds_cell[:, ev_mask]
                for kind, name in ((CONT_SPARSE, "sparse"), (CONT_RUN, "run")):
                    info["words_by_kind"][name] += int(
                        sw_ev[kc_ev == kind].sum()
                    )
            dmask = ~ev_mask
            if dmask.any():
                dt = stiles[dmask]
                nd = int(dt.size)
                # residual input order follows each signature's kept-column
                # order, so tiles from different signatures feed the same
                # kernel wires
                if all_dense:
                    # device path: index rows of the packed dirty array,
                    # gather on-device right before the kernel launch
                    dense_gathers.append(store.dirty_index[kept][:, dt])
                    if container_native:
                        info["words_by_kind"]["dense"] += m * nd * tw
                else:
                    cells = store.gather_cells(
                        np.repeat(kcols, nd), np.tile(dt, m)
                    )
                    sw_dt = swc[kcols[:, None], dt[None, :]]
                    kc_dt = ck[kcols[:, None], dt[None, :]]
                    for kind, name in (
                        (CONT_DENSE, "dense"),
                        (CONT_SPARSE, "sparse"),
                        (CONT_RUN, "run"),
                    ):
                        kw = int(sw_dt[kc_dt == kind].sum())
                        info["words_by_kind"][name] += kw
                        if kind != CONT_DENSE:
                            info["compressed_words_gathered"] += kw
                    dense_gathers.append(cells.reshape(m, nd * tw))
                dense_out_tiles.append(tiles[dmask])
        if n_ev:
            got = evaluate_event_tiles(
                np.concatenate(ev_rows),
                np.concatenate(ev_pos),
                np.concatenate(ev_wires),
                n_ev,
                tw,
                tables,
                m,
            )
            etiles = np.concatenate(ev_out_tiles)
            out[np.asarray(live)[:, None], etiles[None, :]] = got
            info["event_tiles"] += n_ev
        if dense_gathers:
            tiles = np.concatenate(dense_out_tiles)
            if all_dense:
                rows = np.concatenate(dense_gathers, axis=1)  # [m, nd]
                gathered = store.dirty[rows.reshape(-1)].reshape(m, -1)
            else:
                gathered = jax.numpy.asarray(
                    np.concatenate(dense_gathers, axis=1)
                )
            info["dirty_words_gathered"] += int(gathered.size)
            info["densified_tiles"] += int(tiles.size)
            info["launches"] += 1
            got = run_circuit_cached(
                gathered, res,
                block_words=block_words, interpret=interpret, pallas=pallas,
            )
            got = np.asarray(jax.device_get(got), dtype=np.uint32)
            if got.ndim == 1:
                got = got[None]
            out[np.asarray(live)[:, None], tiles[None, :]] = got.reshape(
                len(live), tiles.size, tw
            )

    if overflow_tiles:
        tiles = np.concatenate(overflow_tiles)
        # dense fallback: full support rows for these tiles, original circuit
        # specialised only on the non-support inputs
        assign = {i: CONST0 for i in range(store.n) if i not in support}
        sig_bytes = b"dense"
        const, res, kept, _rkey = _specialize(circuit, ckey, sig_bytes, assign)
        pad = n_tiles * tw - nw
        dense = np.asarray(jax.device_get(store.densify()), dtype=np.uint32)
        if pad:
            dense = np.pad(dense, ((0, 0), (0, pad)))
        dense = dense.reshape(store.n, n_tiles, tw)
        for j, cval in enumerate(const):
            if cval is not None:
                out[j, tiles] = 0xFFFFFFFF if cval else 0
        if res is not None:
            info["case3_tiles"] += int(tiles.size)
            gtiles = sel[tiles] if restricted else tiles
            gathered = dense[np.asarray(kept)[:, None], gtiles[None, :]].reshape(
                len(kept), -1
            )
            info["dirty_words_gathered"] += int(gathered.size)
            info["launches"] += 1
            got = run_circuit_cached(
                jax.numpy.asarray(gathered), res,
                block_words=block_words, interpret=interpret, pallas=pallas,
            )
            got = np.asarray(jax.device_get(got), dtype=np.uint32)
            if got.ndim == 1:
                got = got[None]
            live = [j for j, cval in enumerate(const) if cval is None]
            out[np.asarray(live)[:, None], tiles[None, :]] = got.reshape(
                len(live), tiles.size, tw
            )
        else:
            info["const_tiles"] += int(tiles.size)

    return _finish()
