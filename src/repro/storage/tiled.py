"""Tiled circuit execution: RBMRG clean/dirty skipping for ANY compiled query.

``rbmrg_block_threshold`` (tiles.py) applies the paper's 3-case split to a
bare threshold.  This module generalises it to arbitrary compiled circuits
(``Interval`` / ``Exactly`` / ``And`` / ``Or`` compositions, multi-output
batched queries), using :meth:`Circuit.specialize`:

  1. group tiles by their *class signature* -- the tuple of per-column
     classes (all-zero / all-one / dirty) restricted to the circuit's
     support.  Tiles with the same signature need the same residual work;
  2. partially evaluate the circuit per signature.  Outputs that fold to
     constants are the case-1/case-2 tiles: written directly, zero bit
     work, zero HBM traffic;
  3. signatures whose residuals fold to the same gate DAG (for a bare
     threshold, any two signatures with equal (T - #ones, #dirty)) are
     merged into one residual *group*, capping the signature explosion.

Case-3 execution then runs on one of two engines:

  * ``engine="scan"`` (default for pack-backed stores) -- the single-scan
    device engine of :mod:`repro.kernels.tiled_scan`: O(1) kernel
    dispatches per query.  An in-kernel decode prologue materialises
    sparse/run containers straight from the device-resident packs, one
    block-unrolled ``lax.scan`` (or a scalar-prefetched Pallas grid on
    TPU) dispatches every tile block to its residual evaluator by group
    id, all-compressed tiles are resolved by a device event merge, and
    the [k, n_tiles, tile_words] result is assembled on-device -- an
    unrestricted query never round-trips through a host ``out`` array.

  * ``engine="merge"`` -- the host event-merge path: per-group gathers +
    one ``run_circuit_cached`` launch per residual group, host numpy
    ``evaluate_event_tiles`` for all-compressed tiles.  This is the
    oracle the scan engine is differentially fuzzed against, and the
    fallback for stores without a pack surface (delta overlays) or with
    paged payloads (``repro.persist.tiers`` -- whose point is touching
    only the gathered tiles, never a whole-pack device upload).

The skipping decision is made before launch -- the TPU-legal realisation
of EWAH's fast-forwarding, now for every backend that compiles to a
circuit rather than only bare thresholds.
"""
from __future__ import annotations

import os
from collections import OrderedDict

import numpy as np

from repro.core.circuits import (
    CONST0,
    CONST1,
    _EXACT_CONST_MAX_INPUTS,
    _truth_table_masks,
    Circuit,
)

from .containers import (
    CONT_DENSE,
    CONT_RUN,
    CONT_SPARSE,
    CONTAINER_CROSSOVER,
    concat_ranges,
    evaluate_event_tiles,
    truth_table_bits,
)
from .tilestore import TILE_ONE, TILE_ZERO, TileStore, _signature_counts

__all__ = ["run_tiled_circuit"]

# residual-circuit memo: (circuit structural key, signature bytes) -> result
# of Circuit.specialize.  Signatures recur heavily (clean-dominated data has
# a handful), so this makes per-query specialisation O(#distinct signatures).
# LRU: mixed workloads (many indexes / query shapes sharing the process)
# must evict the coldest entry, not dump the whole memo at the cap.
_SPECIALIZE_MEMO: OrderedDict[tuple, tuple] = OrderedDict()
_SPECIALIZE_MEMO_CAP = 4096

# per-store LRU of prepared scan plans (device index arrays + jitted
# runners), keyed by (circuit, tile selection, execution flags); see
# run_tiled_circuit
_SCAN_PLAN_CACHE_CAP = 64

# beyond this many distinct signatures the data is effectively unclassifiable
# at this granularity; the overflow tiles run the dense support circuit.
# Shared with the planner's cost model so plans price the same split the
# executor actually runs.
from repro.core.planner import _MAX_EXACT_SIGNATURES as _MAX_SIGNATURES

# device event-merge cap on residual inputs: the stacked truth-table LUT
# strides at 2**m bytes per (group, output), so residuals wider than this
# take the block-decode path instead (the host oracle allows up to
# _EXACT_CONST_MAX_INPUTS because its LUT is per group, not stacked).
_EV_MAX_INPUTS = 12


def _residual_key(res: Circuit):
    """Merge key for residual circuits: the exact truth table when the
    support is small (two residuals compute the same function iff their
    tables match -- stronger than structural identity, so e.g. every
    bare-threshold signature with equal (T - #ones, #dirty) merges no
    matter where the folded constants sat in the adder), else the
    gate-order-independent Merkle key."""
    if res.n_inputs <= _EXACT_CONST_MAX_INPUTS:
        masks, zeros, ones = _truth_table_masks(res.n_inputs)
        return (res.n_inputs, tuple(res.evaluate(masks, zeros, ones)))
    return res.semantic_key()


def _specialize(circuit: Circuit, ckey: tuple, sig_bytes: bytes, assign: dict):
    """Memoised ``circuit.specialize`` + residual merge key (LRU-evicted).

    Returns (const_outputs, residual, kept_inputs, residual_key|None).
    """
    key = (ckey, sig_bytes)
    got = _SPECIALIZE_MEMO.get(key)
    if got is not None:
        _SPECIALIZE_MEMO.move_to_end(key)
        return got
    if len(_SPECIALIZE_MEMO) >= _SPECIALIZE_MEMO_CAP:
        _SPECIALIZE_MEMO.popitem(last=False)
    const, res, kept = circuit.specialize(assign)
    got = (const, res, kept, None if res is None else _residual_key(res))
    _SPECIALIZE_MEMO[key] = got
    return got


def _resolve_engine(store, engine: str | None) -> str:
    """Pick the case-3 execution engine for ``store``.

    The scan engine needs the store-wide pack surface device-resident
    (``device_packs``) and must never be used for paged stores -- their
    point is touching only the gathered tiles.  ``REPRO_TILED_ENGINE``
    overrides for debugging/benchmarks."""
    if engine is None:
        engine = os.environ.get("REPRO_TILED_ENGINE") or None
    if engine is None:
        engine = (
            "scan"
            if (
                not getattr(store, "paged", False)
                and hasattr(store, "device_packs")
                and getattr(store, "container_kinds", None) is not None
            )
            else "merge"
        )
    if engine not in ("scan", "merge"):
        raise ValueError(f"unknown tiled engine {engine!r}")
    return engine


def run_tiled_circuit(
    store: TileStore,
    circuit: Circuit,
    *,
    block_words: int | None = None,
    interpret: bool | None = None,
    pallas: bool = True,
    tiles=None,
    engine: str | None = None,
):
    """Evaluate ``circuit`` over the store's columns with tile skipping.

    Returns ``(out, info)``: ``out`` is uint32[n_words] for a single-output
    circuit, uint32[k, n_words] otherwise; ``info`` reports the realised
    3-case split and the words actually gathered (the paper's Table 4
    work-skipped accounting, generalised).  ``info["launches"]`` counts
    device kernel dispatches -- O(1) on the scan engine (one block-scan
    dispatch + at most one event-merge dispatch), one per residual group
    on the merge engine.

    ``tiles`` restricts evaluation (and its signature specialisation /
    launch merging) to a subset of tile indices -- incremental maintenance
    work that re-runs a circuit only where inputs changed.  With it,
    ``out`` is a host ``uint32[k, len(tiles), tile_words]`` array (per
    selected tile, no tail clipping -- callers mask the partial final
    tile) and ``info["dirty_words_gathered"]`` counts only the restricted
    gather.  (``repro.stream``'s view refresh uses a leaner direct path --
    one support-residual circuit, no per-signature split -- because its
    pending tiles are typically uniformly dirty.)

    ``engine`` selects the case-3 execution strategy (``"scan"`` /
    ``"merge"``, default auto -- see :func:`_resolve_engine`).
    """
    import jax

    from repro.kernels.threshold_ssum import (
        INTERPRET,
        circuit_structural_key,
    )
    from repro.query.execinfo import make_exec_info

    if interpret is None:
        interpret = INTERPRET
    if circuit.n_inputs != store.n:
        raise ValueError(f"circuit has {circuit.n_inputs} inputs, store {store.n} columns")
    k = len(circuit.outputs)
    tw, n_tiles, nw = store.tile_words, store.n_tiles, store.n_words
    support = circuit.support()
    ckey = circuit_structural_key(circuit)
    engine = _resolve_engine(store, engine)
    scan = engine == "scan"

    restricted = tiles is not None
    sel = None
    if restricted:
        sel = np.asarray(tiles, dtype=np.int64)
        if sel.ndim != 1 or (sel.size and not
                             ((0 <= sel) & (sel < n_tiles)).all()):
            raise ValueError(f"tiles must be 1-D indices in [0, {n_tiles})")
    n_sel = int(sel.size) if restricted else n_tiles

    if scan:
        # the scan plan -- signature grouping, specialisation, decode index
        # arrays, jitted runners -- is a pure function of (store, circuit,
        # tiles).  TileStore is immutable once built, so repeat queries
        # replay the cached plan: no host pass, no device_put of plan
        # arrays, just the O(1) kernel dispatches.
        from repro.kernels import tiled_scan

        pkey = (
            ckey, sel.tobytes() if restricted else None,
            bool(interpret), bool(pallas), tiled_scan.FORCE_PALLAS_INTERPRET,
        )
        cache = store.__dict__.setdefault("_scan_plan_cache", OrderedDict())
        hit = cache.get(pkey)
        if hit is not None:
            cache.move_to_end(pkey)
            plan, tmpl = hit
            return _execute_scan_plan(
                plan, {**tmpl, "words_by_kind": dict(tmpl["words_by_kind"])}
            )
    else:
        cache = pkey = None

    # per-tile constant fill values: the scan engine broadcasts these to
    # words on-device, the merge engine expands them into the host buffer
    base_vals = np.zeros((k, n_sel), dtype=np.uint32)
    # ExecInfo (repro.query.execinfo): the one schema every backend reports
    # in; see the schema module for per-key semantics and merge rules
    info = make_exec_info(
        "tiled_fused",
        n_tiles=n_tiles,
        selected_tiles=n_sel,
        n_outputs=k,
        engine=engine,
        total_words=int(store.n * nw),
    )

    def _finish_host(out):
        info["work_fraction"] = info["dirty_words_gathered"] / max(
            1, info["total_words"]
        )
        # roofline traffic term: gathered input words + written output words
        info["words_touched"] = info["dirty_words_gathered"] + k * nw
        if restricted:
            return out, info  # host [k, n_sel, tw], caller patches per tile
        result = out.reshape(k, -1)[:, :nw]
        return jax.numpy.asarray(result[0] if k == 1 else result), info

    if not support:
        # constant circuit: no data touched at all
        const, _res, _kept = circuit.specialize({})
        for j, cval in enumerate(const):
            base_vals[j] = 0xFFFFFFFF if cval else 0
        info["const_tiles"] = n_sel
        return _finish_host(np.repeat(base_vals[:, :, None], tw, axis=2))

    # word-level signature per tile over the support (RUN counts as dirty:
    # its words need bit work whenever the tile participates at all).  Under
    # a tile restriction, "tile" arrays below index positions within ``sel``
    # (the output buffer); ``sel`` maps them back to store tile ids.
    cls = store.classes_word[support]  # [s, n_tiles], ZERO/ONE/DIRTY
    if restricted:
        cls = cls[:, sel]
    sigs, inverse = _signature_counts(cls, return_inverse=True)
    info["signatures"] = int(sigs.shape[0])

    # most-populous signatures get exact specialisation; overflow tiles run
    # the dense support circuit (correct, just less skipping)
    order = np.argsort(-np.bincount(inverse, minlength=sigs.shape[0]))
    exact = set(order[:_MAX_SIGNATURES].tolist())

    # Pass 1: specialize per signature, record the constant-folded tiles,
    # and bucket the residual work by the residual circuit's STRUCTURE.
    # Distinct signatures routinely fold to the same gate DAG (a bare
    # threshold only depends on (T - #ones, #dirty)), so merging them caps
    # the group count: one residual evaluator per structurally distinct
    # residual, not one per signature.
    overflow_tiles: list = []
    merged: dict[tuple, list] = {}  # (residual key, live outputs) -> work
    for s_id in range(sigs.shape[0]):
        tiles = np.nonzero(inverse == s_id)[0]
        if s_id not in exact:
            overflow_tiles.append(tiles)
            continue
        sig = sigs[s_id]
        assign = {i: CONST0 for i in range(store.n) if i not in support}
        for j, col in enumerate(support):
            if sig[j] == TILE_ZERO:
                assign[col] = CONST0
            elif sig[j] == TILE_ONE:
                assign[col] = CONST1
        const, res, kept, rkey = _specialize(circuit, ckey, sig.tobytes(), assign)
        for j, cval in enumerate(const):
            if cval is not None:
                base_vals[j, tiles] = 0xFFFFFFFF if cval else 0
        if res is None:
            info["const_tiles"] += int(tiles.size)
            continue
        info["case3_tiles"] += int(tiles.size)
        info["residual_signatures"] += 1
        live = tuple(j for j, cval in enumerate(const) if cval is None)
        merged.setdefault((rkey, live), [res, []])[1].append((tiles, kept))

    # the overflow residual folds only the non-support inputs; its tiles may
    # feed clean cells into kept wires (the decode prologue / dense gather
    # fills those from class metadata).  On the scan engine it rides the
    # same single dispatch as every other group.
    if overflow_tiles:
        otiles = np.concatenate(overflow_tiles)
        assign = {i: CONST0 for i in range(store.n) if i not in support}
        const, res, kept, rkey = _specialize(circuit, ckey, b"dense", assign)
        for j, cval in enumerate(const):
            if cval is not None:
                base_vals[j, otiles] = 0xFFFFFFFF if cval else 0
        if res is None:
            info["const_tiles"] += int(otiles.size)
        else:
            info["case3_tiles"] += int(otiles.size)
            live = tuple(j for j, cval in enumerate(const) if cval is None)
            if scan:
                merged.setdefault((rkey, live), [res, []])[1].append(
                    (otiles, kept)
                )
            else:
                merged[("__overflow__", live)] = [
                    res, [(otiles, kept)], "overflow",
                ]

    if scan:
        return _run_scan_pass(
            store, merged, base_vals, info, sel, restricted,
            k, tw, nw, n_sel, interpret, pallas, cache, pkey,
        )
    return _run_merge_pass(
        store, merged, base_vals, info, sel, restricted,
        k, tw, n_sel, interpret, pallas, block_words, _finish_host,
    )


# ---------------------------------------------------------------------------
# scan engine: O(1) dispatches via repro.kernels.tiled_scan
# ---------------------------------------------------------------------------


def _execute_scan_plan(plan, info):
    """Dispatch a (possibly cached) scan plan: broadcast the constant base,
    run the O(1) staged kernels, clip the padded tail."""
    import jax
    import jax.numpy as jnp

    k, n_sel, tw, nw = plan["k"], plan["n_sel"], plan["tw"], plan["nw"]
    restricted = plan["restricted"]
    if not plan["stages"]:
        # constants only: no device work at all
        out = np.repeat(plan["base_vals"][:, :, None], tw, axis=2)
        if restricted:
            return out, info
        result = out.reshape(k, -1)[:, :nw]
        return jnp.asarray(result[0] if k == 1 else result), info
    buf = jnp.asarray(plan["bv"])
    for fn, args in plan["stages"]:
        buf = fn(buf, *args)
    if restricted:
        host = np.asarray(jax.device_get(buf), np.uint32)[:, :n_sel]
        return host, info
    # device-resident result: drop the dummy tile, clip the padded tail
    result = buf[:, :n_sel].reshape(k, n_sel * tw)[:, :nw]
    return (result[0] if k == 1 else result), info


def _run_scan_pass(store, merged, base_vals, info, sel, restricted,
                   k, tw, nw, n_sel, interpret, pallas, cache, pkey):
    import jax.numpy as jnp

    from repro.kernels import tiled_scan

    n_sel1 = n_sel + 1
    ck = store.container_kinds
    swc = store.storage_words_cell
    clsw = store.classes_word
    packs = store.packs
    d_index = packs["dense_index"]
    s_index, s_bounds = packs["sparse_index"], packs["sparse_bounds"]
    r_index, r_bounds = packs["run_index"], packs["run_bounds"]
    dense_pack1, sparse_pack1, run_pack1 = store.device_packs()
    D = int(dense_pack1.shape[0]) - 2  # zeros sentinel row; ones = D + 1
    S = int(sparse_pack1.shape[0]) - 1  # zero pad entry
    R = int(run_pack1.shape[0]) - 1
    dummy_out = n_sel  # flat [k, n_sel1] dummy cell: tile n_sel of output 0
    pow2, padv = tiled_scan.next_pow2, tiled_scan.pad_to

    # flatten merged groups; each group = one residual evaluator
    groups = []  # [res, live, tables|None, [(out_tiles, store_tiles, kcols)]]
    for (rkey, live), work in merged.items():
        res, entries = work[0], work[1]
        tables = (
            rkey[1]
            if isinstance(rkey, tuple) and res.n_inputs <= _EXACT_CONST_MAX_INPUTS
            else None
        )
        ents = [
            (t, sel[t] if restricted else t, np.asarray(kept, np.int64))
            for t, kept in entries
        ]
        groups.append([res, live, tables, ents])

    # ---- split each group's tiles: device event merge vs block decode ----
    stride = tw * 32 + 2
    n_ev = 0
    for g in groups:
        res, live, tables, ents = g
        m = res.n_inputs
        masks = []
        for _ot, stiles, kcols in ents:
            if tables is None or m > _EV_MAX_INPUTS or stiles.size == 0:
                masks.append(np.zeros(stiles.size, bool))
                continue
            kc = ck[kcols[:, None], stiles[None, :]]
            comp = (kc == CONT_SPARSE) | (kc == CONT_RUN)
            cw = swc[kcols[:, None], stiles[None, :]].sum(axis=0)
            masks.append(
                comp.all(axis=0) & (cw <= CONTAINER_CROSSOVER * m * tw)
            )
        g.append(masks)
        n_ev += sum(int(mk.sum()) for mk in masks)
    if n_ev and (pow2(n_ev) + 2) * stride >= 2**31:
        # event sort keys must fit int32; absurdly large event sets fall
        # back to block decode (correct, just denser staging)
        for g in groups:
            g[4] = [np.zeros_like(mk) for mk in g[4]]
        n_ev = 0

    bv = np.zeros((k, n_sel1), np.uint32)
    bv[:, :n_sel] = base_vals
    plan = {
        "bv": bv, "base_vals": base_vals, "stages": [],
        "k": k, "n_sel": n_sel, "tw": tw, "nw": nw, "restricted": restricted,
    }

    # ---- event stage: one dispatch for every all-compressed tile ---------
    if n_ev:
        s_pack = packs["sparse_pack"]
        r_pack = packs["run_pack"]
        pos_parts, row_parts, wire_parts = [], [], []
        gid_parts, out_parts = [], []
        ev_groups = []  # (m, tables, live)
        row0 = 0
        for res, live, tables, ents, masks in groups:
            m = res.n_inputs
            if not any(mk.any() for mk in masks):
                continue
            gidx = len(ev_groups)
            ev_groups.append((m, tables, live))
            for (otiles, stiles, kcols), mk in zip(ents, masks):
                if not mk.any():
                    continue
                et, ot = stiles[mk], otiles[mk]
                ne = int(et.size)
                rows = np.arange(row0, row0 + ne, dtype=np.int64)
                kc = ck[kcols[:, None], et[None, :]]  # [m, ne]
                wg = np.broadcast_to(kcols[:, None], kc.shape)
                tg = np.broadcast_to(et[None, :], kc.shape)
                rg = np.broadcast_to(rows[None, :], kc.shape)
                wireg = np.broadcast_to(
                    np.arange(m, dtype=np.int64)[:, None], kc.shape
                )
                for kind, idx_t, bnd, pack in (
                    (CONT_SPARSE, s_index, s_bounds, s_pack),
                    (CONT_RUN, r_index, r_bounds, r_pack),
                ):
                    cm = kc == kind
                    if not cm.any():
                        continue
                    s = idx_t[wg[cm], tg[cm]]
                    cnt = bnd[s + 1] - bnd[s]
                    take = concat_ranges(bnd[s], bnd[s + 1])
                    rowv = np.repeat(rg[cm], cnt)
                    wirev = np.repeat(wireg[cm], cnt)
                    if kind == CONT_SPARSE:
                        pp = pack[take].astype(np.int64)
                        pos_parts.append(np.concatenate([pp, pp + 1]))
                    else:
                        # [e, 2] intervals -> all starts, then all ends
                        pos_parts.append(
                            pack[take].astype(np.int64).T.reshape(-1)
                        )
                    row_parts.append(np.concatenate([rowv, rowv]))
                    wire_parts.append(np.concatenate([wirev, wirev]))
                sw_ev = swc[kcols[:, None], et[None, :]]
                ew = int(sw_ev.sum())
                info["compressed_words_gathered"] += ew
                info["dirty_words_gathered"] += ew
                for kind, name in ((CONT_SPARSE, "sparse"), (CONT_RUN, "run")):
                    info["words_by_kind"][name] += int(sw_ev[kc == kind].sum())
                info["event_tiles"] += ne
                gid_parts.append(np.full(ne, gidx, np.int64))
                out_parts.append((live, rows, ot))
                row0 += ne

        rows_pad = pow2(n_ev)
        n_rows1 = rows_pad + 1
        G = len(ev_groups)
        m_max_ev = max(m for m, _t, _l in ev_groups)
        mm = 1 << m_max_ev
        k_max_ev = max(len(l) for _m, _t, l in ev_groups)
        lut = np.zeros((G + 1, k_max_ev, mm), np.uint8)
        for gi, (m, tables, _live) in enumerate(ev_groups):
            for j, tt in enumerate(tables):
                lut[gi, j, : 1 << m] = truth_table_bits(tt, m)
        gid_row = np.full(n_rows1, G, np.int32)
        gid_row[:n_ev] = np.concatenate(gid_parts)
        out_dst = np.full((k_max_ev, n_rows1), dummy_out, np.int32)
        for live, rows, ot in out_parts:
            for j, oj in enumerate(live):
                out_dst[j, rows] = oj * n_sel1 + ot

        # toggle merge order is pure store data: sort once here (host,
        # cached with the plan) so the kernel never pays a device sort
        pos = np.concatenate(pos_parts)
        row = np.concatenate(row_parts)
        wire = np.concatenate(wire_parts)
        keys = row * stride + pos
        order = np.argsort(keys, kind="stable")
        e_pad = pow2(max(1, keys.size))
        keys_s = padv(
            keys[order].astype(np.int32), e_pad, rows_pad * stride
        )
        mask_s = padv(
            (1 << wire[order]).astype(np.uint32), e_pad, 0
        )
        fn = tiled_scan.event_runner(k_max_ev, mm, tw)
        plan["stages"].append((fn, (
            jnp.asarray(keys_s), jnp.asarray(mask_s),
            jnp.asarray(gid_row), jnp.asarray(lut.reshape(-1)),
            jnp.asarray(out_dst),
        )))
        info["launches"] += 1

    # ---- block stage: one dispatch for everything that needs dense work --
    bgroups = []  # (res, live, wg, tg, out_tiles)
    for res, live, _tables, ents, masks in groups:
        m = res.n_inputs
        wgs, tgs, ots = [], [], []
        for (otiles, stiles, kcols), mk in zip(ents, masks):
            dm = ~mk
            if not dm.any():
                continue
            dt = stiles[dm]
            wgs.append(np.broadcast_to(kcols[:, None], (m, dt.size)))
            tgs.append(np.broadcast_to(dt[None, :], (m, dt.size)))
            ots.append(otiles[dm])
        if ots:
            bgroups.append((
                res, live,
                np.concatenate(wgs, axis=1),
                np.concatenate(tgs, axis=1),
                np.concatenate(ots),
            ))

    if bgroups:
        circuits = tuple(b[0] for b in bgroups)
        m_max = max(c.n_inputs for c in circuits)
        k_max = max(len(b[1]) for b in bgroups)
        B = tiled_scan.pick_tile_block(
            tw, m_max, k_max, max(b[4].size for b in bgroups)
        )
        gids_p, src_p, dst_p = [], [], []
        spt_p, spc_p, spr_p = [], [], []
        rnt_p, rnc_p, rnr_p = [], [], []
        ncs = ncr = nb = 0
        for gidx, (res, live, wg, tg, ot) in enumerate(bgroups):
            m = res.n_inputs
            ng = int(ot.size)
            nb_g = -(-ng // B)
            kc = ck[wg, tg]  # [m, ng]
            cw = clsw[wg, tg]
            src = np.where(
                kc == CONT_DENSE, d_index[wg, tg],
                np.where(cw == TILE_ONE, D + 1, D),
            )
            srcp = np.full((m, nb_g * B), D, np.int64)
            srcp[:, :ng] = src
            full = np.full((m_max, nb_g * B), D, np.int64)
            full[:m] = srcp
            src_p.append(full.reshape(m_max, nb_g, B).transpose(1, 0, 2))
            for kind, idx_t, bnd, (take_p, cell_p, row_p), base_c in (
                (CONT_SPARSE, s_index, s_bounds, (spt_p, spc_p, spr_p), "s"),
                (CONT_RUN, r_index, r_bounds, (rnt_p, rnc_p, rnr_p), "r"),
            ):
                wi, ti = np.nonzero(kc == kind)
                if not wi.size:
                    continue
                flat = ((nb + ti // B) * m_max + wi) * B + ti % B
                s = idx_t[wg[wi, ti], tg[wi, ti]]
                cnt = bnd[s + 1] - bnd[s]
                take_p.append(concat_ranges(bnd[s], bnd[s + 1]))
                if base_c == "s":
                    cell_p.append(np.repeat(ncs + np.arange(s.size), cnt))
                    ncs += int(s.size)
                else:
                    cell_p.append(np.repeat(ncr + np.arange(s.size), cnt))
                    ncr += int(s.size)
                row_p.append(flat)
            tpos = np.arange(ng)
            dst_g = np.full((nb_g, k_max, B), dummy_out, np.int64)
            for j, oj in enumerate(live):
                dst_g[tpos // B, j, tpos % B] = oj * n_sel1 + ot
            dst_p.append(dst_g)
            gids_p.append(np.full(nb_g, gidx, np.int32))
            nb += nb_g
            sw_cells = swc[wg, tg]
            info["dirty_words_gathered"] += int(sw_cells.sum())
            for kind, name in (
                (CONT_DENSE, "dense"), (CONT_SPARSE, "sparse"),
                (CONT_RUN, "run"),
            ):
                kw = int(sw_cells[kc == kind].sum())
                info["words_by_kind"][name] += kw
                if kind != CONT_DENSE:
                    info["compressed_words_gathered"] += kw
            info["densified_tiles"] += ng
            info["decode_words"] += m * ng * tw

        nb_pad = pow2(nb)
        NBC = nb_pad * m_max * B
        if NBC + 1 >= 2**31:
            raise ValueError("tiled scan block plan exceeds int32 indexing")
        gids = padv(np.concatenate(gids_p), nb_pad, 0)
        cell_src = np.full(NBC + 1, D, np.int64)
        cell_src[: nb * m_max * B] = np.concatenate(src_p).reshape(-1)
        dst = np.full(nb_pad * k_max * B, dummy_out, np.int64)
        dst[: nb * k_max * B] = np.concatenate(dst_p).reshape(-1)

        def _decode(take_p, cell_p, row_p, nc, pad_take):
            t = np.concatenate(take_p) if take_p else np.zeros(0, np.int64)
            c = np.concatenate(cell_p) if cell_p else np.zeros(0, np.int64)
            rr = np.concatenate(row_p) if row_p else np.zeros(0, np.int64)
            nc1 = pow2(max(1, nc)) + 1
            size = pow2(max(1, t.size))
            return (
                jnp.asarray(padv(t.astype(np.int32), size, pad_take)),
                jnp.asarray(padv(c.astype(np.int32), size, nc1 - 1)),
                jnp.asarray(padv(rr.astype(np.int32), nc1, NBC)),
            )

        spt, spc, spr = _decode(spt_p, spc_p, spr_p, ncs, S)
        rnt, rnc, rnr = _decode(rnt_p, rnc_p, rnr_p, ncr, R)
        use_pallas = pallas and (
            not interpret or tiled_scan.FORCE_PALLAS_INTERPRET
        )
        fn = tiled_scan.block_runner(
            circuits, m_max, k_max, tw, use_pallas, interpret
        )
        plan["stages"].append((fn, (
            jnp.asarray(gids), dense_pack1,
            jnp.asarray(cell_src.astype(np.int32)),
            sparse_pack1, spt, spc, spr,
            run_pack1, rnt, rnc, rnr,
            jnp.asarray(dst.astype(np.int32)),
        )))
        info["launches"] += 1

    info["work_fraction"] = info["dirty_words_gathered"] / max(
        1, info["total_words"]
    )
    info["words_touched"] = info["dirty_words_gathered"] + k * nw
    cache[pkey] = (plan, {**info, "words_by_kind": dict(info["words_by_kind"])})
    while len(cache) > _SCAN_PLAN_CACHE_CAP:
        cache.popitem(last=False)
    return _execute_scan_plan(plan, info)


# ---------------------------------------------------------------------------
# merge engine: host event merge + one launch per residual group (oracle)
# ---------------------------------------------------------------------------


def _run_merge_pass(store, merged, base_vals, info, sel, restricted,
                    k, tw, n_sel, interpret, pallas, block_words,
                    _finish_host):
    import jax

    from repro.kernels.threshold_ssum import run_circuit_cached

    out = np.repeat(base_vals[:, :, None], tw, axis=2)

    # Per merged group, split its case-3 tiles by representation.  Tiles
    # whose residual inputs are ALL compressed containers (sparse / run)
    # -- and whose compressed payload undercuts the dense gather by the
    # crossover -- are evaluated container-natively: boundary events merged
    # position-list-style against the residual's exact truth table (the
    # paper's MergeOpt/ScanCount view of the same query).  The rest densify
    # per tile (sparse/run cells decompressed on the fly, never a
    # store-wide expansion) into one gather + one cached kernel per group.
    container_native = hasattr(store, "gather_events") and getattr(
        store, "container_kinds", None
    ) is not None
    ck = store.container_kinds if container_native else None
    swc = store.storage_words_cell if container_native else None
    # with no compressed tile anywhere (containers off, or purely dense
    # data) the legacy device-side gather path is byte-identical and keeps
    # the working set on-device -- no host round trip per query
    # paged stores (repro.persist.tiers) must never trigger the whole-pack
    # device upload: their point is touching only the gathered tiles
    all_dense = not getattr(store, "paged", False) and (
        not container_native or not (ck > CONT_DENSE).any()
    )
    for (rkey, live), work in merged.items():
        res, entries = work[0], work[1]
        overflow = len(work) > 2
        m = res.n_inputs
        # exact truth tables exist for small residuals; _residual_key
        # computed them already (rkey = (n_inputs, per-output tables))
        tables = (
            rkey[1]
            if not overflow
            and container_native
            and m <= _EXACT_CONST_MAX_INPUTS
            else None
        )
        ev_rows, ev_pos, ev_wires = [], [], []
        ev_out_tiles: list = []
        dense_out_tiles: list = []
        dense_gathers: list = []
        n_ev = 0
        for tiles, kept in entries:
            stiles = sel[tiles] if restricted else tiles
            kcols = np.asarray(kept, np.int64)
            if tables is not None:
                kinds_cell = ck[kcols[:, None], stiles[None, :]]
                comp = (kinds_cell == CONT_SPARSE) | (kinds_cell == CONT_RUN)
                cwords = swc[kcols[:, None], stiles[None, :]].sum(axis=0)
                ev_mask = comp.all(axis=0) & (
                    cwords <= CONTAINER_CROSSOVER * m * tw
                )
            else:
                ev_mask = np.zeros(tiles.size, bool)
            if ev_mask.any():
                et = stiles[ev_mask]
                ne = int(et.size)
                cell, pos = store.gather_events(
                    np.repeat(kcols, ne), np.tile(et, m)
                )
                ev_rows.append(n_ev + cell % ne)
                ev_pos.append(pos)
                ev_wires.append(cell // ne)
                ev_out_tiles.append(tiles[ev_mask])
                n_ev += ne
                sw_ev = swc[kcols[:, None], et[None, :]]
                ew = int(sw_ev.sum())
                info["compressed_words_gathered"] += ew
                info["dirty_words_gathered"] += ew
                kc_ev = kinds_cell[:, ev_mask]
                for kind, name in ((CONT_SPARSE, "sparse"), (CONT_RUN, "run")):
                    info["words_by_kind"][name] += int(
                        sw_ev[kc_ev == kind].sum()
                    )
            dmask = ~ev_mask
            if dmask.any():
                dt = stiles[dmask]
                nd = int(dt.size)
                # residual input order follows each signature's kept-column
                # order, so tiles from different signatures feed the same
                # kernel wires
                if overflow:
                    # dense fallback: full support rows for these tiles
                    dense = np.asarray(
                        jax.device_get(store.densify()), dtype=np.uint32
                    )
                    pad = store.n_tiles * tw - store.n_words
                    if pad:
                        dense = np.pad(dense, ((0, 0), (0, pad)))
                    dense = dense.reshape(store.n, store.n_tiles, tw)
                    cells = dense[kcols[:, None], dt[None, :]]
                    dense_gathers.append(cells.reshape(m, nd * tw))
                    # every overflow cell reads dense-expanded words
                    info["words_by_kind"]["dense"] += m * nd * tw
                elif all_dense:
                    # device path: index rows of the packed dirty array,
                    # gather on-device right before the kernel launch
                    dense_gathers.append(store.dirty_index[kept][:, dt])
                    # kind breakdown must not depend on the container
                    # surface being present: the device gather reads
                    # dense(-equivalent) words either way
                    info["words_by_kind"]["dense"] += m * nd * tw
                else:
                    cells = store.gather_cells(
                        np.repeat(kcols, nd), np.tile(dt, m)
                    )
                    if swc is not None:
                        sw_dt = swc[kcols[:, None], dt[None, :]]
                        kc_dt = ck[kcols[:, None], dt[None, :]]
                        for kind, name in (
                            (CONT_DENSE, "dense"),
                            (CONT_SPARSE, "sparse"),
                            (CONT_RUN, "run"),
                        ):
                            kw = int(sw_dt[kc_dt == kind].sum())
                            info["words_by_kind"][name] += kw
                            if kind != CONT_DENSE:
                                info["compressed_words_gathered"] += kw
                    else:
                        info["words_by_kind"]["dense"] += m * nd * tw
                    dense_gathers.append(cells.reshape(m, nd * tw))
                dense_out_tiles.append(tiles[dmask])
        if n_ev:
            got = evaluate_event_tiles(
                np.concatenate(ev_rows),
                np.concatenate(ev_pos),
                np.concatenate(ev_wires),
                n_ev,
                tw,
                tables,
                m,
            )
            etiles = np.concatenate(ev_out_tiles)
            out[np.asarray(live)[:, None], etiles[None, :]] = got
            info["event_tiles"] += n_ev
        if dense_gathers:
            tiles = np.concatenate(dense_out_tiles)
            if all_dense and not overflow:
                rows = np.concatenate(dense_gathers, axis=1)  # [m, nd]
                gathered = store.dirty[rows.reshape(-1)].reshape(m, -1)
            else:
                gathered = jax.numpy.asarray(
                    np.concatenate(dense_gathers, axis=1)
                )
            info["dirty_words_gathered"] += int(gathered.size)
            info["densified_tiles"] += int(tiles.size)
            info["launches"] += 1
            got = run_circuit_cached(
                gathered, res,
                block_words=block_words, interpret=interpret, pallas=pallas,
            )
            got = np.asarray(jax.device_get(got), dtype=np.uint32)
            if got.ndim == 1:
                got = got[None]
            out[np.asarray(live)[:, None], tiles[None, :]] = got.reshape(
                len(live), tiles.size, tw
            )

    return _finish_host(out)
