from .manager import CheckpointManager
