"""Checkpointing: sharded-logical npz + manifest, atomic, resumable, elastic.

Layout per step:
    <dir>/step_000123.tmp/   (written)  ->  <dir>/step_000123/  (renamed)
        arrays.npz           flattened {path: array} of the state pytree
        manifest.json        {step, time, paths, dtypes, shapes, extra}

Restore rebuilds the pytree and ``jax.device_put``s each leaf with the
*current* mesh's sharding -- a checkpoint written on one mesh restores onto
any other (elastic rescale), because arrays are stored logically, not
per-device.  Writes can run on a background thread (async checkpointing);
``wait()`` joins before the next save.  Retention keeps the newest k.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np

SEP = "//"


def _flatten(tree) -> dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat


def _unflatten_into(template, flat: dict):
    paths = jax.tree_util.tree_flatten_with_path(template)[0]
    treedef = jax.tree_util.tree_structure(template)
    leaves = []
    for path, _ in paths:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- write ---------------------------------------------------------
    def save(self, step: int, state, extra: dict | None = None):
        self.wait()
        flat = _flatten(state)
        host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}

        def _write():
            tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
            final = os.path.join(self.dir, f"step_{step:08d}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **host)
            manifest = {
                "step": step,
                "time": time.time(),
                "paths": sorted(host),
                "extra": extra or {},
            }
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic publish
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    # -- read ----------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                    out.append(int(name[5:]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int, template, shardings=None):
        """Rebuild the state pytree; ``shardings`` (same structure or a
        single sharding) re-lays leaves onto the current mesh."""
        path = os.path.join(self.dir, f"step_{step:08d}", "arrays.npz")
        with np.load(path) as z:
            flat = {k: z[k] for k in z.files}
        tree = _unflatten_into(template, flat)
        if shardings is not None:
            if jax.tree_util.tree_structure(shardings) == jax.tree_util.tree_structure(tree):
                tree = jax.tree.map(jax.device_put, tree, shardings)
            else:
                tree = jax.tree.map(lambda x: jax.device_put(x, shardings), tree)
        else:
            tree = jax.tree.map(jax.numpy.asarray, tree)
        return tree

    def manifest(self, step: int) -> dict:
        with open(os.path.join(self.dir, f"step_{step:08d}", "manifest.json")) as f:
            return json.load(f)
