from .paper_datasets import clustered_set, similarity_query, synthetic_dataset, uniform_set
from .pipeline import DataConfig, arch_batch, lm_batch, lm_batches
