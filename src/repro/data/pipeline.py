"""Data pipeline.

Two producers:
  * ``lm_batches`` -- synthetic-but-learnable token streams for the LM
    training examples/tests (Zipf unigram mixture + copy pattern so loss
    visibly falls), sharded by host.
  * ``arch_batch`` -- shape-correct random batches for any (arch x shape)
    cell, used by smoke tests and the dry-run input_specs.

Deterministic per (seed, step, host): a restart resumes the stream exactly
(fault-tolerance requirement -- see ft/).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    batch: int  # global batch
    seq: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0


def _rng_for(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng((cfg.seed, step, cfg.host_id))


def lm_batch(cfg: DataConfig, step: int) -> dict:
    """One host's shard of the global batch for a given step."""
    rng = _rng_for(cfg, step)
    local = cfg.batch // cfg.n_hosts
    # Zipf-ish unigram sample ...
    ranks = rng.zipf(1.3, size=(local, cfg.seq + 1)).astype(np.int64)
    toks = np.minimum(ranks, cfg.vocab - 1)
    # ... with embedded copy structure: second half repeats the first half
    half = (cfg.seq + 1) // 2
    toks[:, half : 2 * half] = toks[:, :half]
    tokens = toks[:, :-1].astype(np.int32)
    labels = toks[:, 1:].astype(np.int32)
    return {"tokens": jnp.asarray(tokens), "labels": jnp.asarray(labels)}


def lm_batches(cfg: DataConfig, start_step: int = 0) -> Iterator[dict]:
    step = start_step
    while True:
        yield lm_batch(cfg, step)
        step += 1


def arch_batch(cfg: ModelConfig, batch: int, seq: int, kind: str, seed: int = 0) -> dict:
    """Shape-correct random batch for an (arch x shape) cell (host memory)."""
    rng = np.random.default_rng(seed)
    out: dict = {}
    if cfg.frontend == "audio":
        out["features"] = jnp.asarray(
            rng.normal(size=(batch, seq, cfg.frontend_dim)).astype(np.float32)
        )
        out["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (batch, seq), dtype=np.int32))
        return out
    s_text = seq
    if cfg.frontend == "vision":
        s_text = seq - cfg.frontend_tokens
        out["patches"] = jnp.asarray(
            rng.normal(size=(batch, cfg.frontend_tokens, cfg.frontend_dim)).astype(np.float32)
        )
    out["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab, (batch, s_text), dtype=np.int32))
    out["labels"] = jnp.asarray(rng.integers(0, cfg.vocab, (batch, seq), dtype=np.int32))
    if cfg.frontend == "vision":
        mask = np.ones((batch, seq), np.float32)
        mask[:, : cfg.frontend_tokens] = 0.0  # no LM loss on patch positions
        out["mask"] = jnp.asarray(mask)
    return out
