"""The paper's synthetic bitmap datasets and similarity-query workloads (5.3, 5.4).

Generators mirror the paper exactly (scaled ranges available):
  * uniform   -- |B_i| = card elements drawn uniformly from [0, r)
  * clustered -- |B_i| elements in runs (Anh & Moffat-style clustered sets)
with the paper's three densities: dense r = 3 * card, moderate r = 100 * card,
sparse r = 1000 * card (paper used card = 10_000, seed 1111).

Similarity queries (5.4): pick a row id, select the N bitmaps whose sets
contain it; when fewer than N qualify, replicate bitmaps (the paper's
weighted-threshold trick); when more, take the first N.
"""
from __future__ import annotations

import numpy as np

from repro.core.bitmaps import from_positions


def uniform_set(rng: np.random.Generator, card: int, r: int) -> np.ndarray:
    return np.sort(rng.choice(r, size=min(card, r), replace=False))


def clustered_set(rng: np.random.Generator, card: int, r: int) -> np.ndarray:
    """Clustered generation following Anh & Moffat: recursively split the
    budget into runs of consecutive integers."""
    out: list[int] = []

    def fill(lo: int, hi: int, n: int):
        if n <= 0 or lo >= hi:
            return
        if n >= hi - lo:
            out.extend(range(lo, hi))
            return
        mid = int(rng.integers(lo, hi))
        left = int(rng.hypergeometric(mid - lo, hi - mid, n)) if hi > mid else n
        fill(lo, mid, left)
        fill(mid, hi, n - left)

    fill(0, r, card)
    return np.array(sorted(set(out)), dtype=np.int64)


def synthetic_dataset(
    kind: str = "uniform",
    density: str = "dense",
    n_bitmaps: int = 64,
    card: int = 10_000,
    seed: int = 1111,
):
    """Returns (packed uint32 [N, n_words] as numpy, r, position lists)."""
    import jax

    r = {"dense": 3 * card, "moderate": 100 * card, "sparse": 1000 * card}[density]
    rng = np.random.default_rng(seed)
    gen = uniform_set if kind == "uniform" else clustered_set
    lists = [gen(rng, card, r) for _ in range(n_bitmaps)]
    packed = np.stack([np.asarray(jax.device_get(from_positions(l, r))) for l in lists])
    return packed, r, lists


def similarity_query(lists: list[np.ndarray], n: int, rid: int | None = None, seed: int = 0):
    """Select N bitmap indices for a similarity query on ``rid`` (5.4)."""
    rng = np.random.default_rng(seed)
    if rid is None:
        rid = int(rng.integers(0, max(int(l[-1]) for l in lists if len(l)) + 1))
    hits = [i for i, l in enumerate(lists) if len(l) and np.searchsorted(l, rid) < len(l) and l[np.searchsorted(l, rid)] == rid]
    if not hits:
        hits = [int(rng.integers(0, len(lists)))]
    if len(hits) >= n:
        return hits[:n], rid
    # replicate (the paper's weighted-threshold trick)
    reps = [hits[i % len(hits)] for i in range(n)]
    return reps, rid
