"""internvl2-26b [vlm]: InternViT frontend (stub) + InternLM2-26B backbone.

48L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=92553 [arXiv:2404.16821; hf]
The vision frontend is a STUB per the assignment: ``input_specs`` provides
precomputed patch embeddings (InternViT-6B feature dim 3200) which a linear
projector maps into the LM stream.
"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=92553,
    layer_pattern=("attn",),
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    frontend="vision",
    frontend_tokens=256,
    frontend_dim=3200,
)

REDUCED = ModelConfig(
    name="internvl2-26b-reduced",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=512,
    layer_pattern=("attn",),
    tie_embeddings=False,
    frontend="vision",
    frontend_tokens=8,
    frontend_dim=48,
)
