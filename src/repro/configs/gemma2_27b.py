"""gemma2-27b [dense]: 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000; alternating local(4096)/global attention, attention softcap
50, final-logit softcap 30, GeGLU [arXiv:2408.00118; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab=256000,
    layer_pattern=("local", "attn"),
    window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    act="gelu",
    tie_embeddings=True,
    scale_embed=True,
)

REDUCED = ModelConfig(
    name="gemma2-27b-reduced",
    family="dense",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=192,
    vocab=512,
    layer_pattern=("local", "attn"),
    window=16,
    attn_softcap=50.0,
    logit_softcap=30.0,
    act="gelu",
    tie_embeddings=True,
    scale_embed=True,
)
