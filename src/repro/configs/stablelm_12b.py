"""stablelm-12b [dense]: 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352 [hf:stabilityai/stablelm-2-12b; hf].  Per-head qk layernorm."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-12b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=32,
    n_kv_heads=8,
    head_dim=160,
    d_ff=13824,
    vocab=100352,
    layer_pattern=("attn",),
    qk_norm=True,
    tie_embeddings=False,
)

REDUCED = ModelConfig(
    name="stablelm-12b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=512,
    layer_pattern=("attn",),
    qk_norm=True,
    tie_embeddings=False,
)
