"""Model / run configuration.

One ``ModelConfig`` per assigned architecture lives in ``configs/<id>.py``;
``configs.registry`` maps ``--arch`` ids to them.  ``reduced()`` returns the
CPU-smoke-test version of the same family (same code paths, tiny dims).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

# Sub-block kinds usable in a layer pattern:
#   attn    -- full causal self-attention (+ mlp)
#   local   -- sliding-window causal attention (+ mlp), window = cfg.window
#   bidir   -- bidirectional attention (encoder-only archs) (+ mlp)
#   rec     -- RG-LRU recurrent block (+ mlp)
#   rwkv    -- RWKV6 time-mix + channel-mix (its own ffn)
# MoE archs replace the dense mlp in attn/local blocks with the MoE ffn.


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab: int
    layer_pattern: Tuple[str, ...] = ("attn",)
    window: int = 0  # sliding window size for 'local' blocks (0 = unused)
    qk_norm: bool = False
    attn_softcap: float = 0.0  # 0 disables
    logit_softcap: float = 0.0
    rope_theta: float = 10_000.0
    # MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    capacity_factor: float = 1.25
    moe_token_chunk: int = 0  # >0: scan the MoE over token chunks (bounds
    #                           the peak [E,C,D] dispatch buffers)
    # structure
    encoder_only: bool = False
    frontend: str = "none"  # none | vision | audio (stubbed patch/frame embeddings)
    frontend_tokens: int = 0  # prepended stub-embedding positions (vlm)
    frontend_dim: int = 0  # raw feature dim of the stub frontend input
    tie_embeddings: bool = True
    scale_embed: bool = False  # gemma-style sqrt(d_model) embedding scaling
    act: str = "silu"
    norm_eps: float = 1e-5
    d_rnn: int = 0  # RG-LRU width (0 -> d_model)
    conv_width: int = 4

    # ---- derived ----
    @property
    def vocab_padded(self) -> int:
        """Vocab rounded up to a TP-friendly multiple (Megatron-style vocab
        padding).  Non-divisible vocabs otherwise force GSPMD to replicate
        the embedding/lm-head gradients (see EXPERIMENTS.md Perf)."""
        mult = 128
        return ((self.vocab + mult - 1) // mult) * mult

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    @property
    def rnn_width(self) -> int:
        return self.d_rnn or self.d_model

    @property
    def has_attention(self) -> bool:
        return any(k in ("attn", "local", "bidir") for k in self.layer_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """True if no block attends over unbounded context quadratically.

        Used for the long_500k skip rule: pure full-attention archs skip it.
        """
        return "attn" not in self.layer_pattern and "bidir" not in self.layer_pattern

    @property
    def moe(self) -> bool:
        return self.n_experts > 0

    def layer_groups(self) -> list[tuple[Tuple[str, ...], int]]:
        """Split n_layers into (pattern, repeats) groups for scan-over-layers."""
        p = len(self.layer_pattern)
        full, rem = divmod(self.n_layers, p)
        groups = []
        if full:
            groups.append((self.layer_pattern, full))
        if rem:
            groups.append((self.layer_pattern[:rem], 1))
        return groups

    def param_count(self) -> int:
        """Exact parameter count, derived from the real init via eval_shape."""
        from repro.models.model import param_count_exact

        return param_count_exact(self)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k of n_experts) for 6*N*D."""
        from repro.models.model import param_count_exact

        total = param_count_exact(self)
        if not self.moe:
            return total
        expert = 3 * self.d_model * self.moe_d_ff
        n_blocks = sum(
            reps * sum(1 for k in pat if k in ("attn", "local", "bidir"))
            for pat, reps in self.layer_groups()
        )
        inactive = n_blocks * (self.n_experts - self.top_k) * expert
        return total - inactive


def shape_cells() -> dict:
    """The four assigned input-shape sets (seq_len, global_batch, kind)."""
    return {
        "train_4k": dict(seq_len=4096, global_batch=256, kind="train"),
        "prefill_32k": dict(seq_len=32_768, global_batch=32, kind="prefill"),
        "decode_32k": dict(seq_len=32_768, global_batch=128, kind="decode"),
        "long_500k": dict(seq_len=524_288, global_batch=1, kind="decode"),
    }
