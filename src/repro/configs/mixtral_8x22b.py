"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768; 8 experts top-2, sliding-window attention
[arXiv:2401.04088; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=32768,
    layer_pattern=("local",),
    window=4096,
    n_experts=8,
    top_k=2,
    moe_d_ff=16384,
    moe_token_chunk=4,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
)

REDUCED = ModelConfig(
    name="mixtral-8x22b-reduced",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=512,
    layer_pattern=("local",),
    window=16,
    n_experts=4,
    top_k=2,
    moe_d_ff=64,
    moe_token_chunk=2,
    tie_embeddings=False,
)
