"""--arch registry: maps architecture ids to (full, reduced) configs."""
from __future__ import annotations

from . import (
    gemma2_27b,
    granite_moe_1b,
    hubert_xlarge,
    internlm2_20b,
    internvl2_26b,
    mixtral_8x22b,
    qwen3_1_7b,
    recurrentgemma_2b,
    rwkv6_3b,
    stablelm_12b,
)
from .base import ModelConfig, shape_cells

_MODULES = {
    "internvl2-26b": internvl2_26b,
    "stablelm-12b": stablelm_12b,
    "qwen3-1.7b": qwen3_1_7b,
    "internlm2-20b": internlm2_20b,
    "gemma2-27b": gemma2_27b,
    "recurrentgemma-2b": recurrentgemma_2b,
    "rwkv6-3b": rwkv6_3b,
    "mixtral-8x22b": mixtral_8x22b,
    "granite-moe-1b-a400m": granite_moe_1b,
    "hubert-xlarge": hubert_xlarge,
}

ARCHS = tuple(_MODULES)


def get_config(arch: str, reduced: bool = False) -> ModelConfig:
    mod = _MODULES[arch]
    return mod.REDUCED if reduced else mod.CONFIG


def cell_is_runnable(arch: str, shape: str) -> tuple[bool, str]:
    """Apply the skip rules from DESIGN.md (pure full-attention long_500k,
    encoder-only decode)."""
    cfg = get_config(arch)
    cell = shape_cells()[shape]
    if cfg.encoder_only and cell["kind"] == "decode":
        return False, "encoder-only arch has no decode step"
    if shape == "long_500k":
        # runs only when every block is sub-quadratic in context (SSM, RG-LRU,
        # windowed attention); any unbounded full-attention block disqualifies
        if "attn" in cfg.layer_pattern or "bidir" in cfg.layer_pattern:
            return False, (
                "long_500k needs sub-quadratic attention; arch has unbounded "
                "full-attention blocks"
            )
    return True, ""
