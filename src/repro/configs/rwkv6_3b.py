"""rwkv6-3b [ssm]: 32L d_model=2560 (attention-free) d_ff=8960 vocab=65536;
Finch data-dependent decay [arXiv:2404.05892; hf].  40 heads of 64."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    n_layers=32,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab=65536,
    layer_pattern=("rwkv",),
    tie_embeddings=False,
)

REDUCED = ModelConfig(
    name="rwkv6-3b-reduced",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab=512,
    layer_pattern=("rwkv",),
    tie_embeddings=False,
)
