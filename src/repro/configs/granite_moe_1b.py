"""granite-moe-1b-a400m [moe]: 24L d_model=1024 16H (GQA kv=8) expert
d_ff=512 vocab=49155; 32 experts top-8
[hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab=49155,
    layer_pattern=("attn",),
    n_experts=32,
    top_k=8,
    moe_d_ff=512,
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="granite-moe-1b-a400m-reduced",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=64,
    vocab=512,
    layer_pattern=("attn",),
    n_experts=8,
    top_k=4,
    moe_d_ff=64,
    tie_embeddings=True,
)
