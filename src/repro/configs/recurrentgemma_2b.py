"""recurrentgemma-2b [hybrid]: 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000; RG-LRU + local attention 1:2 (rec, rec, local-attn)
[arXiv:2402.19427; hf].  Window 2048, lru width = d_model."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab=256000,
    layer_pattern=("rec", "rec", "local"),
    window=2048,
    act="gelu",
    tie_embeddings=True,
    scale_embed=True,
    d_rnn=2560,
)

REDUCED = ModelConfig(
    name="recurrentgemma-2b-reduced",
    family="hybrid",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=16,
    d_ff=128,
    vocab=512,
    layer_pattern=("rec", "rec", "local"),
    window=16,
    act="gelu",
    tie_embeddings=True,
    scale_embed=True,
    d_rnn=64,
)
