"""internlm2-20b [dense]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544 [arXiv:2403.17297; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    family="dense",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=92544,
    layer_pattern=("attn",),
    rope_theta=1_000_000.0,
    tie_embeddings=False,
)

REDUCED = ModelConfig(
    name="internlm2-20b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=512,
    layer_pattern=("attn",),
    tie_embeddings=False,
)
