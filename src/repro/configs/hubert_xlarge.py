"""hubert-xlarge [audio]: 48L d_model=1280 16H (kv=16) d_ff=5120 vocab=504;
encoder-only transformer backbone [arXiv:2106.07447; unverified].
The conv waveform frontend is a STUB: ``input_specs`` provides precomputed
frame embeddings (feature dim 512); vocab is the masked-prediction
codebook.  No decode step (encoder-only)."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge",
    family="audio",
    n_layers=48,
    d_model=1280,
    n_heads=16,
    n_kv_heads=16,
    head_dim=80,
    d_ff=5120,
    vocab=504,
    layer_pattern=("bidir",),
    act="gelu",
    encoder_only=True,
    frontend="audio",
    frontend_dim=512,
    tie_embeddings=False,
)

REDUCED = ModelConfig(
    name="hubert-xlarge-reduced",
    family="audio",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab=64,
    layer_pattern=("bidir",),
    act="gelu",
    encoder_only=True,
    frontend="audio",
    frontend_dim=48,
    tie_embeddings=False,
)
