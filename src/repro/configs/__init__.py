from .base import ModelConfig, shape_cells
from .registry import ARCHS, cell_is_runnable, get_config
