"""qwen3-1.7b [dense]: 28L d_model=2048 16H (GQA kv=8) d_ff=6144
vocab=151936, qk_norm [hf:Qwen/Qwen3-1.7B; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-1.7b",
    family="dense",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=6144,
    vocab=151936,
    layer_pattern=("attn",),
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="qwen3-1.7b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab=512,
    layer_pattern=("attn",),
    qk_norm=True,
    tie_embeddings=True,
)
