"""Fault tolerance: straggler detection, preemption handling, restart logic.

At 1000+ nodes the failure modes are (a) slow hosts (stragglers), (b)
preemptions, (c) hard crashes.  The framework's contract:

  * crashes    -> the train loop is a pure function of (checkpoint, data
                  stream position); launch/train.py auto-resumes from the
                  newest checkpoint and the data pipeline is deterministic
                  per (seed, step), so a restart replays identically.
  * preemption -> SIGTERM/SIGINT triggers a final synchronous checkpoint
                  before exit (PreemptionHandler).
  * stragglers -> per-step wall-times feed an EWMA; a step slower than
                  ``threshold x`` the EWMA raises a mitigation event.  On a
                  real fleet the event handler re-slices the data shards
                  away from the slow host (elastic rescale via the
                  checkpoint reshard path) -- here the decision logic is
                  real and unit-tested, the actuation is a callback.
"""
from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable


@dataclasses.dataclass
class StragglerEvent:
    step: int
    step_time: float
    ewma: float
    ratio: float


class StragglerMonitor:
    def __init__(self, threshold: float = 2.5, alpha: float = 0.1, warmup: int = 5):
        self.threshold = threshold
        self.alpha = alpha
        self.warmup = warmup
        self.ewma: float | None = None
        self.count = 0
        self.events: list[StragglerEvent] = []

    def record(self, step: int, step_time: float) -> StragglerEvent | None:
        self.count += 1
        if self.ewma is None:
            self.ewma = step_time
            return None
        event = None
        if self.count > self.warmup and step_time > self.threshold * self.ewma:
            event = StragglerEvent(step, step_time, self.ewma, step_time / self.ewma)
            self.events.append(event)
            # do not fold outliers into the EWMA
            return event
        self.ewma = (1 - self.alpha) * self.ewma + self.alpha * step_time
        return event


class PreemptionHandler:
    """Installs SIGTERM/SIGINT hooks; the train loop polls ``should_stop``."""

    def __init__(self, on_preempt: Callable[[], None] | None = None):
        self.should_stop = False
        self._on_preempt = on_preempt
        self._installed = False

    def install(self):
        if self._installed:
            return

        def _handler(signum, frame):
            self.should_stop = True
            if self._on_preempt:
                self._on_preempt()

        try:
            signal.signal(signal.SIGTERM, _handler)
            signal.signal(signal.SIGINT, _handler)
            self._installed = True
        except ValueError:  # non-main thread (tests)
            pass


class Heartbeat:
    """Simple liveness tracking for a host set; dead hosts trigger elastic
    rescale (drop their data shards, reshard on the survivors)."""

    def __init__(self, hosts: int, timeout: float = 60.0):
        self.timeout = timeout
        self.last_seen = {h: time.time() for h in range(hosts)}

    def beat(self, host: int, now: float | None = None):
        self.last_seen[host] = now if now is not None else time.time()

    def dead_hosts(self, now: float | None = None) -> list[int]:
        now = now if now is not None else time.time()
        return [h for h, t in self.last_seen.items() if now - t > self.timeout]

    def surviving_shards(self, now: float | None = None) -> list[int]:
        dead = set(self.dead_hosts(now))
        return [h for h in self.last_seen if h not in dead]
