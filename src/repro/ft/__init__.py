from .monitor import Heartbeat, PreemptionHandler, StragglerEvent, StragglerMonitor
