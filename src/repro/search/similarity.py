"""Approximate string similarity search over tokenizer bitmap columns.

The paper frames threshold queries as T-occurrence queries -- the core of
approximate string/set similarity search.  :class:`SimilarityIndex` makes
that a first-class workload: a :class:`~repro.stream.StreamingIndex` whose
columns are q-gram (and optionally length and minhash-band) token bitmaps
over a string corpus, with

* **exact candidate generation** (:meth:`SimilarityIndex.candidates`):
  the Sarawagi-Kirpal threshold ``T = n_grams - k*q`` with the vacuous
  case handled correctly -- ``T <= 0`` means the q-gram filter can exclude
  NOTHING and yields the all-rows bitmap, never "shares >= 1 gram" (the
  historical ``max(1, T)`` clamp silently dropped every true match sharing
  zero grams with the query);
* **adaptive top-k** (:meth:`SimilarityIndex.topk`): start at the exact
  bound and relax stepwise (``T, T-q, T-2q, ...``), each step paying only
  the NEW candidate band -- ``theta(T_j) \\ theta(T_{j-1})`` -- with the
  intermediate bitmaps fed back into the index as columns
  (``add_column``), so verification work is strictly the per-step delta
  and the vacuous tail is a complement of what is already materialized;
* **incremental appends** (:meth:`SimilarityIndex.append`): new records
  ride ``StreamingIndex.append_rows``; newly-seen grams grow the
  vocabulary via ``add_data_column`` -- no rebuild.

Every execution goes through the planner (or an explicit ``backend=``
override), so candidate generation runs on any ``ALGORITHMS`` backend,
sharded or not, bit-identically.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.obs import REGISTRY as _OBS
from repro.obs import trace as _trace
from repro.query.expr import Col, Interval, Threshold
from repro.stream import StreamingIndex

from .tokenize import MinHashParams, band_buckets, minhash_signature, qgrams, sk_threshold

__all__ = [
    "Candidates",
    "Matches",
    "TopK",
    "SimilarityIndex",
    "build_qgram_index",
    "edit_distance",
]

#: backends that execute arbitrary circuits (vs bare thresholds only)
from repro.core.planner import CIRCUIT_BACKENDS  # noqa: E402

# -- observability (no-ops until repro.obs.enable()) ------------------------
_CANDIDATES = _OBS.counter(
    "repro_search_candidates_total", "Candidate rows generated", ("family",),
)
_VERIFICATIONS = _OBS.counter(
    "repro_search_verifications_total", "Edit-distance verifications run",
)
_RELAXATIONS = _OBS.counter(
    "repro_search_relaxations_total", "Top-k threshold relaxation steps",
)
_VACUOUS = _OBS.counter(
    "repro_search_vacuous_total", "Vacuous-threshold bypasses (T <= 0)",
)


def edit_distance(a: str, b: str, bound: int | None = None) -> int:
    """Levenshtein distance; with ``bound``, returns ``bound + 1`` as soon
    as the true distance provably exceeds it (banded early exit)."""
    if a == b:
        return 0
    if bound is not None and abs(len(a) - len(b)) > bound:
        return bound + 1
    dp = list(range(len(b) + 1))
    for i, ca in enumerate(a, 1):
        prev, dp[0] = dp[0], i
        best = dp[0]
        for j, cb in enumerate(b, 1):
            prev, dp[j] = dp[j], min(dp[j] + 1, dp[j - 1] + 1, prev + (ca != cb))
            best = min(best, dp[j])
        if bound is not None and best > bound:
            return bound + 1
    return dp[-1]


# ---------------------------------------------------------------------------
# Result records
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Candidates:
    """One candidate-generation answer (a host bitmap + its provenance)."""

    bitmap: np.ndarray  # packed uint32[n_words]
    ids: np.ndarray  # sorted row positions
    t: int  # the exact Sarawagi-Kirpal bound (may be <= 0)
    vacuous: bool  # T <= 0: the q-gram filter excluded nothing
    n_grams: int  # distinct q-grams of the query
    n_present: int  # of those, columns present in the index

    def __len__(self) -> int:
        return int(self.ids.size)


@dataclasses.dataclass(frozen=True)
class Matches:
    """Verified approximate matches (``search``)."""

    ids: np.ndarray
    distances: np.ndarray
    candidates: Candidates


@dataclasses.dataclass(frozen=True)
class TopK:
    """Adaptive top-k answer (``topk``)."""

    ids: np.ndarray
    distances: np.ndarray
    relaxations: int  # threshold bands executed/considered
    verified: int  # edit-distance computations spent
    vacuous: bool  # the loop had to fall through to the all-rows band


# ---------------------------------------------------------------------------
# The index
# ---------------------------------------------------------------------------


def _host_bitmap(res) -> np.ndarray:
    """Normalise an execute() result (device array or ShardedResult) to a
    host uint32 row."""
    import jax

    if hasattr(res, "gather"):
        res = res.gather()
    return np.asarray(jax.device_get(res), dtype=np.uint32)


def _positions(bitmap: np.ndarray) -> np.ndarray:
    bits = np.unpackbits(bitmap.view(np.uint8), bitorder="little")
    return np.nonzero(bits)[0]


class SimilarityIndex:
    """q-gram (+ length, + minhash-band) bitmap columns over a corpus."""

    GRAM = "g:"
    LEN = "len:"
    MH = "mh:"

    def __init__(self, strings, *, q: int = 2, lengths: bool = True,
                 minhash: MinHashParams | None = None, tile_words: int = 8,
                 n_shards: int | None = None):
        from repro.query import BitmapIndex

        self.q = int(q)
        self.lengths = bool(lengths)
        self.minhash = minhash
        self._strings: list[str] = [str(s) for s in strings]
        if not self._strings:
            raise ValueError("need at least one record to build an index")
        rows = [self._record_columns(s) for s in self._strings]
        names = sorted(set().union(*rows))
        slot = {nm: i for i, nm in enumerate(names)}
        dense = np.zeros((len(names), len(self._strings)), dtype=bool)
        for rid, cols in enumerate(rows):
            for nm in cols:
                dense[slot[nm], rid] = True
        base = BitmapIndex.from_dense(dense, names, tile_words=tile_words)
        if n_shards is not None:
            base = base.shard(n_shards=n_shards)
        self._stream = StreamingIndex(base)

    # -- tokenization ------------------------------------------------------
    def grams(self, s: str) -> frozenset:
        return qgrams(s, self.q)

    def _record_columns(self, s: str) -> set:
        cols = {self.GRAM + g for g in self.grams(s)}
        if self.lengths:
            cols.add(f"{self.LEN}{len(s)}")
        if self.minhash is not None:
            sig = minhash_signature(self.grams(s), self.minhash)
            cols.update(
                f"{self.MH}{band}:{bucket}"
                for band, bucket in enumerate(band_buckets(sig, self.minhash))
            )
        return cols

    # -- accessors ---------------------------------------------------------
    @property
    def stream(self) -> StreamingIndex:
        """The underlying streaming index (materialize/serve against it)."""
        return self._stream

    @property
    def index(self):
        """The queryable (Sharded)BitmapIndex snapshot, deltas overlaid."""
        return self._stream.index()

    @property
    def r(self) -> int:
        return len(self._strings)

    def __len__(self) -> int:
        return len(self._strings)

    def record(self, rid: int) -> str:
        return self._strings[rid]

    def _present_grams(self, s: str) -> tuple:
        """Gram column names of the query that exist in the vocabulary.

        A record can only share grams that some record contains, so
        counting over the present columns equals counting over all of the
        query's grams -- absent grams contribute zero everywhere."""
        return tuple(
            sorted(self.GRAM + g for g in self.grams(s) if self.GRAM + g in self._stream)
        )

    def posting_lists(self, s: str) -> list:
        """Sorted row-id lists of the query's present gram columns -- the
        integer-list view the host competitors (``core.listalgos``) merge."""
        idx = self.index
        return [
            _positions(_host_bitmap(idx.column(nm)))
            for nm in self._present_grams(s)
        ]

    # -- bitmap helpers ----------------------------------------------------
    def _n_words(self) -> int:
        return (self.r + 31) // 32

    def _all_rows(self) -> np.ndarray:
        out = np.full(self._n_words(), 0xFFFFFFFF, dtype=np.uint32)
        rem = self.r % 32
        if rem:
            out[-1] = np.uint32((1 << rem) - 1)
        return out

    def _empty(self) -> np.ndarray:
        return np.zeros(self._n_words(), dtype=np.uint32)

    def _pad_words(self, bm: np.ndarray) -> np.ndarray:
        """Grow a host bitmap to the store's word width (the store may hold
        trailing partial-tile words past ceil(r/32))."""
        want = getattr(self.index, "n_words", bm.size)
        if bm.size < want:
            bm = np.concatenate([bm, np.zeros(want - bm.size, np.uint32)])
        return bm

    # -- candidate generation (the bugfix surface) -------------------------
    def candidates(self, s: str, k: int, *, backend: str | None = None,
                   length_filter: bool = False) -> Candidates:
        """Rows that *can* be within edit distance ``k`` of ``s``, by the
        exact Sarawagi-Kirpal gram-count bound.

        ``T <= 0`` is the vacuous case: the filter excludes nothing and the
        answer is the ALL-ROWS bitmap (optionally cut down by the cheap
        length filter, which remains exact: ``|len(r) - len(s)| <= k`` is
        necessary for distance ``k``).  No clamping, ever."""
        grams = self._present_grams(s)
        n_grams = len(self.grams(s))
        t = sk_threshold(n_grams, self.q, k)
        with _trace.span("search_candidates", t=t, n_grams=n_grams) as sp:
            if t <= 0:
                _VACUOUS.inc(1)
                bm = self._all_rows()
                vacuous = True
            elif t > len(grams):
                # fewer present grams than the bound requires: no record can
                # reach T (absent grams occur in no record)
                bm = self._empty()
                vacuous = False
            else:
                res = self.index.execute(
                    Threshold(t, over=[Col(g) for g in grams]), backend=backend
                )
                bm = _host_bitmap(res)[: self._n_words()]
                vacuous = False
            if length_filter and self.lengths:
                bm = bm & self._length_filter(len(s), k, backend=backend)
            ids = _positions(bm)
            _CANDIDATES.inc(int(ids.size), family="qgram")
            if _trace.enabled:
                sp.set(vacuous=vacuous, n_candidates=int(ids.size))
        return Candidates(
            bitmap=bm, ids=ids, t=t, vacuous=vacuous,
            n_grams=n_grams, n_present=len(grams),
        )

    def _length_filter(self, qlen: int, k: int, *, backend: str | None = None) -> np.ndarray:
        """Bitmap of rows whose length is within ``k`` of ``qlen``."""
        cols = [
            f"{self.LEN}{L}"
            for L in range(max(0, qlen - k), qlen + k + 1)
            if f"{self.LEN}{L}" in self._stream
        ]
        if not cols:
            return self._empty()
        res = self.index.execute(
            Threshold(1, over=[Col(c) for c in cols]), backend=backend
        )
        return _host_bitmap(res)[: self._n_words()]

    def minhash_candidates(self, s: str, *, min_bands: int = 1,
                           backend: str | None = None) -> Candidates:
        """Rows sharing at least ``min_bands`` minhash bands with ``s``
        (Jaccard-style screening; probabilistic, unlike the q-gram bound)."""
        if self.minhash is None:
            raise ValueError("index built without a minhash column family")
        sig = minhash_signature(self.grams(s), self.minhash)
        cols = [
            f"{self.MH}{band}:{bucket}"
            for band, bucket in enumerate(band_buckets(sig, self.minhash))
            if f"{self.MH}{band}:{bucket}" in self._stream
        ]
        if len(cols) < min_bands:
            bm = self._empty()
        else:
            res = self.index.execute(
                Threshold(min_bands, over=[Col(c) for c in cols]), backend=backend
            )
            bm = _host_bitmap(res)[: self._n_words()]
        ids = _positions(bm)
        _CANDIDATES.inc(int(ids.size), family="minhash")
        return Candidates(
            bitmap=bm, ids=ids, t=min_bands, vacuous=False,
            n_grams=self.minhash.bands, n_present=len(cols),
        )

    # -- verified search ---------------------------------------------------
    def search(self, s: str, k: int, *, backend: str | None = None,
               length_filter: bool = False) -> Matches:
        """All records within edit distance ``k``: candidates, then exact
        verification on candidates only (the paper's screening pattern)."""
        cand = self.candidates(s, k, backend=backend, length_filter=length_filter)
        with _trace.span("search_verify", n=len(cand)):
            _VERIFICATIONS.inc(len(cand))
            hits = [
                (rid, d)
                for rid in cand.ids.tolist()
                if (d := edit_distance(s, self._strings[rid], bound=k)) <= k
            ]
        ids = np.array([r for r, _ in hits], dtype=np.int64)
        return Matches(
            ids=ids,
            distances=np.array([d for _, d in hits], dtype=np.int64),
            candidates=cand,
        )

    # -- adaptive top-k ----------------------------------------------------
    def topk(self, s: str, k: int, *, backend: str | None = None,
             max_edits: int | None = None) -> TopK:
        """The ``k`` nearest records by edit distance (ties broken by row
        id), found by stepwise threshold relaxation.

        Step ``j`` (edit budget ``j``) uses ``T_j = n_grams - j*q``.  The
        candidate sets are nested (``theta(T_j)`` grows as ``T`` falls), so
        each step verifies only the NEW band: on circuit backends the band
        is one ``Interval(max(T_j, 0), T_{j-1} - 1)`` execution; on
        bare-threshold backends it is ``theta(T_j)`` minus the previous
        step's materialized bitmap.  Either way the intermediate result is
        fed back into the index as a column (``add_column``) for the next
        step to build on.  When ``T_j <= 0`` the filter is vacuous and the
        final band is the complement of everything already materialized --
        at that point every row has been verified and the answer is exact
        unconditionally.

        Guarantee: a record within distance ``j`` shares ``>= T_j`` grams,
        so once ``k`` verified records have distance ``<= j``, no unseen
        record can displace them -- the loop stops with the exact top-k.
        """
        if k < 1:
            raise ValueError(f"k must be >= 1, got {k}")
        grams = self._present_grams(s)
        gram_cols = [Col(g) for g in grams]
        n_grams = len(self.grams(s))
        n_present = len(grams)
        circuit = backend is None or backend in CIRCUIT_BACKENDS
        # add_column feedback needs a solid base: overlay stores (pending
        # appends) are read views and refuse schema growth
        self._stream.compact(force=True)
        idx = self.index
        verified: dict[int, int] = {}
        seen = self._empty()  # union of all bands materialized so far
        hi_next = n_present  # highest count not yet covered by a band
        relaxations = 0
        hit_vacuous = False
        with _trace.span("search_topk", k=k, n_grams=n_grams) as root:
            j = 0
            while True:
                t = sk_threshold(n_grams, self.q, j)
                band, theta = self._relax_band(
                    idx, gram_cols, t, hi_next, seen, circuit, backend,
                )
                if band is not None:
                    relaxations += 1
                    _RELAXATIONS.inc(1)
                    if t <= 0:
                        hit_vacuous = True
                        _VACUOUS.inc(1)
                    new_ids = _positions(band)
                    with _trace.span("search_verify", n=int(new_ids.size), t=t):
                        _VERIFICATIONS.inc(int(new_ids.size))
                        for rid in new_ids.tolist():
                            verified[rid] = edit_distance(s, self._strings[rid])
                    seen = seen | band
                    if theta is not None and t >= 1:
                        # feed the materialized intermediate back as a column:
                        # the next relaxation (and any caller) composes with it
                        idx = idx.add_column(
                            f"_cand:{t}", self._pad_words(theta)
                        )
                        hi_next = max(t, 1) - 1
                    elif t <= 0:
                        hi_next = -1
                if t <= 0:
                    # every row is verified: the sort below is globally exact
                    break
                matches = [(d, rid) for rid, d in verified.items() if d <= j]
                if len(matches) >= k:
                    break
                if max_edits is not None and j >= max_edits:
                    break
                j += 1
            if t <= 0:
                ranked = sorted((d, rid) for rid, d in verified.items())
            else:
                ranked = sorted((d, rid) for rid, d in verified.items() if d <= j)
            ranked = ranked[:k]
            if _trace.enabled:
                root.set(relaxations=relaxations, verified=len(verified),
                         vacuous=hit_vacuous)
        return TopK(
            ids=np.array([rid for _, rid in ranked], dtype=np.int64),
            distances=np.array([d for d, _ in ranked], dtype=np.int64),
            relaxations=relaxations,
            verified=len(verified),
            vacuous=hit_vacuous,
        )

    def _relax_band(self, idx, gram_cols, t: int, hi_next: int,
                    seen: np.ndarray, circuit: bool, backend):
        """One relaxation band: (band bitmap | None when empty, theta(t)
        bitmap | None).  ``hi_next`` is the highest shared-gram count not
        yet claimed by an earlier band (-1: nothing left)."""
        n_present = len(gram_cols)
        if hi_next < 0:
            return None, None
        if t > n_present:
            # the bound exceeds what any record can share: provably empty,
            # nothing to execute
            return None, None
        if not gram_cols:
            # no query gram exists in the vocabulary: counts are all zero
            if t >= 1:
                return None, None
            return self._all_rows() & ~seen, None
        if t <= 0:
            # vacuous: the complement of everything already materialized
            return self._all_rows() & ~seen, None
        if circuit:
            lo = t
            q = (
                Threshold(lo, over=gram_cols)
                if hi_next >= n_present
                else Interval(lo, hi_next, over=gram_cols)
            )
            band = _host_bitmap(idx.execute(q, backend=backend))[: self._n_words()]
            return band, seen | band
        # the degenerate reductions only express theta(1) / theta(N); other
        # relaxation steps fall back to the planner's choice
        use = backend
        if (backend == "wide_or" and t != 1) or (
            backend == "wide_and" and t != n_present
        ):
            use = None
        theta = _host_bitmap(
            idx.execute(Threshold(t, over=gram_cols), backend=use)
        )[: self._n_words()]
        return theta & ~seen, theta

    # -- incremental appends -----------------------------------------------
    def append(self, strings) -> tuple:
        """Append new records; newly-seen tokens grow the vocabulary as
        fresh all-zero columns first (``StreamingIndex.add_data_column``),
        then the rows ride one ``append_rows`` batch.  Returns the appended
        (start, stop) row range."""
        new = [str(s) for s in strings]
        if not new:
            return (self.r, self.r)
        rows = [self._record_columns(s) for s in new]
        for nm in sorted(set().union(*rows)):
            if nm not in self._stream:
                self._stream.add_data_column(nm)
        bits = {
            nm: np.array([nm in cols for cols in rows], dtype=bool)
            for nm in set().union(*rows)
        }
        start, stop = self._stream.append_rows(bits)
        self._strings.extend(new)
        return (start, stop)


def build_qgram_index(strings, q: int = 2, *, lengths: bool = True,
                      minhash: MinHashParams | None = None,
                      tile_words: int = 8,
                      n_shards: int | None = None) -> SimilarityIndex:
    """Build a :class:`SimilarityIndex` over ``strings`` (q-gram columns,
    plus length columns and optionally a minhash-band family)."""
    return SimilarityIndex(
        strings, q=q, lengths=lengths, minhash=minhash,
        tile_words=tile_words, n_shards=n_shards,
    )
