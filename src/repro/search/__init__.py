"""`repro.search`: similarity search and windowed analytics workloads.

The paper's threshold queries ARE T-occurrence queries -- the engine of
approximate string/set similarity search -- and its symmetric-function
counts are the natural windowed-analytics primitive.  This package turns
both into first-class scenarios on the query/stream stack:

* :func:`build_qgram_index` / :class:`SimilarityIndex` -- q-gram (+
  length, + minhash-band) tokenizer columns over a string corpus, exact
  Sarawagi-Kirpal candidate generation (vacuous ``T <= 0`` handled
  correctly: the all-rows bitmap, never a clamp), verified
  :meth:`~SimilarityIndex.search` and adaptive
  :meth:`~SimilarityIndex.topk` with stepwise threshold relaxation;
* :class:`WindowedStream` -- sliding-window / time-decayed counts as
  materialized streaming views over an append-heavy event row space,
  with a :class:`WindowRetentionPolicy` retiring expired rows.

Quickstart::

    from repro.search import build_qgram_index

    idx = build_qgram_index(["chateau margaux 1982", ...], q=2)
    idx.search("chateau margeaux 1982", k=1)   # all matches within k
    idx.topk("margo", k=5)                     # 5 nearest, adaptive T
"""
from .similarity import (
    Candidates,
    Matches,
    SimilarityIndex,
    TopK,
    build_qgram_index,
    edit_distance,
)
from .tokenize import (
    MinHashParams,
    band_buckets,
    minhash_signature,
    qgrams,
    sk_threshold,
    token_hashes,
)
from .window import WindowedStream, WindowRetentionPolicy

__all__ = [
    "Candidates",
    "Matches",
    "MinHashParams",
    "SimilarityIndex",
    "TopK",
    "WindowRetentionPolicy",
    "WindowedStream",
    "band_buckets",
    "build_qgram_index",
    "edit_distance",
    "minhash_signature",
    "qgrams",
    "sk_threshold",
    "token_hashes",
]
