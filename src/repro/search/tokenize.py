"""Tokenizer column families: q-grams and minhash bands.

A similarity index is "just" a bitmap index whose columns are tokens: one
bitmap per q-gram (records containing that gram) and/or one bitmap per
(minhash band, bucket) pair (records whose band signature hashes there).
Everything downstream -- candidate generation, adaptive top-k, windowed
counts -- is then threshold/symmetric queries over those columns, which is
exactly how the paper frames T-occurrence queries (section 1: approximate
string/set similarity search as the home application).

The q-gram side follows Ferro et al. / Sarawagi & Kirpal: strings are
sentinel-padded with ``#``/``$`` so a string of length L yields L + q - 1
gram *positions*.  Columns are set-valued (a bitmap either contains the
record or not), so the threshold bound must be stated over DISTINCT grams
-- see :func:`sk_threshold` for the exact form and its vacuous case.

Minhash is the standard banding scheme over 64-bit token hashes: ``H``
hash functions grouped into ``bands`` bands of ``H // bands`` rows; two
sets with Jaccard similarity ``s`` share any given band with probability
``s ** rows_per_band``.  Hashing is content-stable (blake2b, fixed seeds),
never Python ``hash`` -- signatures must not depend on PYTHONHASHSEED.
"""
from __future__ import annotations

import dataclasses
import hashlib

import numpy as np

__all__ = [
    "qgrams",
    "sk_threshold",
    "MinHashParams",
    "token_hashes",
    "minhash_signature",
    "band_buckets",
]

#: sentinel characters padding string ends (Ferro et al. section 5)
PAD_START = "#"
PAD_END = "$"

# Mersenne prime 2^61 - 1: the classic universal-hash modulus -- products
# of 61-bit values fit python ints exactly and numpy uint64 after reduction
_MERSENNE = (1 << 61) - 1


def qgrams(s: str, q: int = 2) -> frozenset:
    """The DISTINCT q-grams of ``s`` with sentinel padding.

    Padding guarantees ``len(s) + q - 1`` gram *positions*; the returned
    set collapses repeats (a bitmap column is set-valued), so its size can
    be smaller -- thresholds over these columns must use the set size, not
    the positional count (:func:`sk_threshold`).
    """
    if q < 1:
        raise ValueError(f"q must be >= 1, got {q}")
    padded = PAD_START * (q - 1) + s + PAD_END * (q - 1)
    return frozenset(padded[i : i + q] for i in range(len(padded) - q + 1))


def sk_threshold(n_grams: int, q: int, k: int) -> int:
    """The Sarawagi-Kirpal q-gram count bound for edit distance ``k``.

    A record within edit distance ``k`` of the query shares at least

        ``T = n_grams - k * q``

    of the query's ``n_grams`` distinct q-grams: one edit rewrites at most
    ``q`` gram positions, so it can remove at most ``q`` distinct grams
    from the intersection.  (For gram *multisets* the same bound reads
    ``|s| + q - 1 - k*q``; bitmap columns are sets, so the set form is the
    one that is actually exact here.)

    **The bound can be non-positive** -- short strings, large edit budgets
    -- and then the filter is VACUOUS: sharing zero grams is consistent
    with being within distance ``k``, so every record is a candidate.
    Callers must treat ``T <= 0`` as "no filter" (all rows).  Clamping to
    ``max(1, T)`` instead silently drops every true match that shares no
    gram with the query -- the false-negative bug this module exists to
    bury.  This function deliberately returns the raw, possibly
    non-positive value.
    """
    return int(n_grams) - int(k) * int(q)


# ---------------------------------------------------------------------------
# Minhash banding
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MinHashParams:
    """Shape of the minhash-band column family.

    ``n_hashes`` minwise hash functions split into ``bands`` bands of
    ``n_hashes // bands`` rows each; every band hashes to one of
    ``buckets`` buckets, giving ``bands * buckets`` columns.
    """

    n_hashes: int = 16
    bands: int = 4
    buckets: int = 32
    seed: int = 0

    def __post_init__(self):
        if self.n_hashes % self.bands:
            raise ValueError(
                f"n_hashes ({self.n_hashes}) must divide into bands ({self.bands})"
            )

    @property
    def rows_per_band(self) -> int:
        return self.n_hashes // self.bands


def token_hashes(tokens) -> np.ndarray:
    """Stable uint64 content hashes of a token iterable (sorted, distinct)."""
    out = {
        int.from_bytes(
            hashlib.blake2b(str(t).encode("utf-8"), digest_size=8).digest(), "little"
        )
        for t in tokens
    }
    return np.fromiter(out, dtype=np.uint64, count=len(out))


def _hash_coeffs(n_hashes: int, seed: int) -> tuple:
    rng = np.random.default_rng(seed)
    a = rng.integers(1, _MERSENNE, size=n_hashes, dtype=np.int64)
    b = rng.integers(0, _MERSENNE, size=n_hashes, dtype=np.int64)
    return a, b


def minhash_signature(tokens, params: MinHashParams) -> np.ndarray:
    """uint64[n_hashes] minwise signature of a token set.

    ``h_i(x) = (a_i * x + b_i) mod (2^61 - 1)`` over the token content
    hashes; an empty token set gets the all-max sentinel signature (it can
    never collide with a non-empty one).
    """
    xs = token_hashes(tokens)
    if xs.size == 0:
        return np.full(params.n_hashes, np.iinfo(np.uint64).max, dtype=np.uint64)
    a, b = _hash_coeffs(params.n_hashes, params.seed)
    # exact 61-bit universal hash via python ints (object dtype keeps the
    # products exact; shapes are tiny -- |tokens| x n_hashes)
    xo = xs.astype(object)[:, None]
    hv = (a.astype(object)[None, :] * xo + b.astype(object)[None, :]) % _MERSENNE
    return np.min(hv, axis=0).astype(np.uint64)


def band_buckets(signature: np.ndarray, params: MinHashParams) -> tuple:
    """Per-band bucket ids of a signature: ``tuple[int]`` of length
    ``params.bands``, each in ``[0, params.buckets)``."""
    sig = np.asarray(signature, dtype=np.uint64)
    if sig.shape != (params.n_hashes,):
        raise ValueError(f"signature shape {sig.shape} != ({params.n_hashes},)")
    rows = params.rows_per_band
    out = []
    for band in range(params.bands):
        chunk = sig[band * rows : (band + 1) * rows]
        digest = hashlib.blake2b(chunk.tobytes(), digest_size=8).digest()
        out.append(int.from_bytes(digest, "little") % params.buckets)
    return tuple(out)
