"""Sliding-window and time-decayed analytics as streaming bitmap views.

The motivating query family ("products on sale in 2-10 stores over the
last hour") is a threshold query over an APPEND-HEAVY row space: every
event (a product going on sale at a store) is a row, attribute columns
mark which series the event belongs to, and a ``__live__`` column marks
rows still inside the window.  :class:`WindowedStream` wires that onto
:class:`~repro.stream.StreamingIndex`:

* **append-only ingest** -- each event batch is one ``append_rows`` call;
  the universe only ever grows at the tail (no resharding, no rebuild);
* **expiry is a mutation, not a rebuild** -- :meth:`advance` clears the
  expired rows' bits in one batched ``update``, so a materialized window
  count (:meth:`watch`) refreshes tile-granularly: the refresh touches
  only the tiles the expiry/append batch touched, with the words-touched
  accounting exposed via :meth:`refresh_info` (asserted in tests and
  ``benchmarks/search_bench.py`` against the touched-tiles bound);
* **retention compaction** -- expired rows accumulate as dead all-zero
  row slots; a :class:`WindowRetentionPolicy` decides when to retire
  them, which is the ONLY operation that rewrites the row space;
* **time decay** -- :meth:`decayed_count` folds an exponential decay
  over the live rows of one series (half-life weighting), reading the
  bitmap for membership and host timestamps for weights.
"""
from __future__ import annotations

import dataclasses
from collections import deque

import numpy as np

from repro.obs import REGISTRY as _OBS
from repro.obs import trace as _trace
from repro.query.expr import And, Col, as_query
from repro.stream import CompactionPolicy, StreamingIndex

__all__ = ["WindowRetentionPolicy", "WindowedStream"]

_EVENTS = _OBS.counter(
    "repro_search_window_events_total", "Events ingested into windowed streams",
)
_EXPIRED = _OBS.counter(
    "repro_search_window_expired_total", "Events expired out of the window",
)
_RETIRES = _OBS.counter(
    "repro_search_window_retires_total", "Row-space retention compactions",
)


@dataclasses.dataclass(frozen=True)
class WindowRetentionPolicy(CompactionPolicy):
    """When expired row slots are physically retired.

    Expiry only CLEARS bits -- cheap, tile-granular -- leaving dead
    all-zero rows behind.  Those are harmless to correctness (they match
    no query through ``__live__``) but grow the universe forever, so once
    ``dead / total`` exceeds ``max_dead_ratio`` (and at least
    ``min_dead_rows`` are dead) the stream rewrites the row space with
    only live rows.  Inherits the delta-compaction knobs of
    :class:`~repro.stream.CompactionPolicy`.
    """

    min_dead_rows: int = 4096
    max_dead_ratio: float = 0.5

    def should_retire(self, dead_rows: int, total_rows: int) -> bool:
        if dead_rows < self.min_dead_rows:
            return False
        return dead_rows >= self.max_dead_ratio * max(total_rows, 1)


class WindowedStream:
    """Events over named series columns, windowed by timestamp."""

    LIVE = "__live__"

    def __init__(self, columns, *, window: float, tile_words: int = 8,
                 policy: WindowRetentionPolicy | None = None,
                 now: float = 0.0):
        names = tuple(str(c) for c in columns)
        if not names:
            raise ValueError("need at least one series column")
        if self.LIVE in names:
            raise ValueError(f"{self.LIVE!r} is reserved")
        self.window = float(window)
        if self.window <= 0:
            raise ValueError(f"window must be > 0, got {window}")
        self.policy = policy or WindowRetentionPolicy()
        self._columns = names
        self.now = float(now)
        #: (ts, row, cols) per live event, append order == timestamp order
        self._events: deque = deque()
        self._dead_rows = 0
        self._watches: dict[str, object] = {}
        self._stream = self._seed_stream(tile_words)

    def _seed_stream(self, tile_words: int) -> StreamingIndex:
        # the universe cannot be empty, so seed with one all-zero word of
        # row slots; they are never live, so they never match anything
        dense = np.zeros((len(self._columns) + 1, 32), dtype=bool)
        self._dead_rows = 32
        return StreamingIndex.from_dense(
            dense, self._columns + (self.LIVE,), tile_words=tile_words,
            policy=self.policy,
        )

    # -- accessors ---------------------------------------------------------
    @property
    def stream(self) -> StreamingIndex:
        return self._stream

    @property
    def columns(self) -> tuple:
        return self._columns

    @property
    def live_events(self) -> int:
        return len(self._events)

    @property
    def dead_rows(self) -> int:
        return self._dead_rows

    @property
    def total_rows(self) -> int:
        return self._stream.r

    # -- ingest ------------------------------------------------------------
    def append(self, events, *, now: float | None = None) -> tuple:
        """Ingest a batch of ``(timestamp, columns)`` events (one row
        each); timestamps must be non-decreasing across the stream's life.
        Advances the clock to ``now`` (default: the batch's last
        timestamp) and expires accordingly.  Returns the (start, stop)
        row range of the batch."""
        batch = [(float(ts), tuple(str(c) for c in cols)) for ts, cols in events]
        if not batch:
            if now is not None:
                self.advance(now)
            return (self.total_rows, self.total_rows)
        last_ts = self._events[-1][0] if self._events else self.now
        if any(b[0] < last_ts for b in batch) or any(
            b2[0] < b1[0] for b1, b2 in zip(batch, batch[1:])
        ):
            raise ValueError("event timestamps must be non-decreasing")
        k = len(batch)
        bits = {self.LIVE: np.ones(k, dtype=bool)}
        for name in {c for _, cols in batch for c in cols}:
            if name not in self._columns:
                raise KeyError(
                    f"unknown series column {name!r}; stream has "
                    f"{self._columns[:8]}..."
                )
            bits[name] = np.array([name in cols for _, cols in batch], bool)
        with _trace.span("window_append", n_events=k):
            start, stop = self._stream.append_rows(bits)
        _EVENTS.inc(k)
        for (ts, cols), row in zip(batch, range(start, stop)):
            self._events.append((ts, row, cols))
        self.advance(batch[-1][0] if now is None else now)
        return (start, stop)

    # -- expiry ------------------------------------------------------------
    def advance(self, now: float) -> int:
        """Move the clock forward; expire events older than ``now -
        window`` by clearing their bits in ONE batched update.  Returns
        the number of events expired."""
        if now < self.now:
            raise ValueError(f"clock cannot move backwards ({now} < {self.now})")
        self.now = float(now)
        horizon = self.now - self.window
        expired = []
        while self._events and self._events[0][0] <= horizon:
            expired.append(self._events.popleft())
        if expired:
            clears: dict[str, list] = {self.LIVE: []}
            for ts, row, cols in expired:
                clears[self.LIVE].append(row)
                for c in cols:
                    clears.setdefault(c, []).append(row)
            with _trace.span("window_expire", n_events=len(expired)):
                self._stream.update(clears=clears)
            _EXPIRED.inc(len(expired))
            self._dead_rows += len(expired)
        if self.policy.auto and self.policy.should_retire(
            self._dead_rows, self.total_rows
        ):
            self.retire()
        return len(expired)

    def retire(self) -> int:
        """Rewrite the row space with only live events (the retention
        compaction).  Watches are re-registered over the new rows; row
        ids change, so callers must not hold onto old positions.  Returns
        the number of dead row slots dropped."""
        dropped = self._dead_rows
        with _trace.span("window_retire", dead_rows=dropped,
                         live=len(self._events)):
            _RETIRES.inc(1)
            events = list(self._events)
            tile_words = self._stream.tile_words
            watches = {
                name: self._watches[name] for name in self._watches
            }
            self._events.clear()
            self._stream = self._seed_stream(tile_words)
            if events:
                # re-ingest live events with fresh row ids (one batch)
                k = len(events)
                bits = {self.LIVE: np.ones(k, dtype=bool)}
                for name in {c for _, _, cols in events for c in cols}:
                    bits[name] = np.array(
                        [name in cols for _, _, cols in events], bool
                    )
                start, _ = self._stream.append_rows(bits)
                for (ts, _, cols), row in zip(events, range(start, start + k)):
                    self._events.append((ts, row, cols))
            for name, query in watches.items():
                self._watches[name] = query
                self._stream.materialize(name, And(as_query(query), Col(self.LIVE)))
        return dropped

    # -- windowed queries --------------------------------------------------
    def watch(self, name: str, query) -> None:
        """Materialize ``query AND __live__`` as a maintained view column:
        its count stays fresh under append/expiry with tile-granular
        refresh work (:meth:`refresh_info`), never a rebuild."""
        q = as_query(query)
        self._watches[name] = q
        self._stream.materialize(name, And(q, Col(self.LIVE)))

    def count(self, name_or_query) -> int:
        """Current in-window count: a watched name reads the maintained
        cardinality (no execution); an ad-hoc query executes over
        ``query AND __live__``."""
        if isinstance(name_or_query, str) and name_or_query in self._watches:
            return self._stream.count(Col(name_or_query))
        q = as_query(name_or_query)
        return self._stream.count(And(q, Col(self.LIVE)))

    def ids(self, name_or_query) -> np.ndarray:
        """Row positions currently matching (watched views included)."""
        import jax

        if isinstance(name_or_query, str) and name_or_query in self._watches:
            q = Col(name_or_query)
        else:
            q = And(as_query(name_or_query), Col(self.LIVE))
        res = self._stream.execute(q)
        if hasattr(res, "gather"):
            res = res.gather()
        words = np.asarray(jax.device_get(res), np.uint32)
        bits = np.unpackbits(words.view(np.uint8), bitorder="little")
        return np.nonzero(bits)[0]

    def refresh_info(self, name: str) -> dict | None:
        """Words-touched accounting of the watch's last refresh (the
        no-rebuild evidence: bounded by touched tiles, not the universe)."""
        self._stream.refresh()
        return self._stream.view_info(name)

    def decayed_count(self, query, *, half_life: float,
                      now: float | None = None) -> float:
        """Exponentially time-decayed count of live rows matching
        ``query``: each contributes ``2 ** (-(now - ts) / half_life)``.
        Membership comes from the bitmap, weights from host timestamps."""
        if half_life <= 0:
            raise ValueError(f"half_life must be > 0, got {half_life}")
        t = self.now if now is None else float(now)
        rows = set(self.ids(query).tolist())
        if not rows:
            return 0.0
        return float(
            sum(
                2.0 ** (-(t - ts) / half_life)
                for ts, row, _ in self._events
                if row in rows
            )
        )
