"""Query expression trees.

Leaves are symmetric Boolean functions of a set of columns (default: every
column of the index) or references to a single named column; combinators
are the paper's bitmap primitives AND / OR / NOT / ANDNOT.  Expressions are
immutable, hashable-by-structure values: ``q.key()`` is the *query shape*
used to key the compiled-circuit cache, and never contains data.

Sub-queries compose freely: any expression can appear where a column is
expected (``Threshold(2, over=("a", And("b", "c")))``) because a gate
output is just another input bit to the sideways-sum adder.

Python operators are overloaded for fluency::

    Interval(2, 10) & ~Threshold(15)       # And(Interval(2,10), Not(Threshold(15)))
    Col("a") | Col("b")                    # Or(Col("a"), Col("b"))
    Threshold(2) - Col("returns")          # AndNot(Threshold(2), Col("returns"))
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

__all__ = [
    "Query",
    "Col",
    "Threshold",
    "Interval",
    "Exactly",
    "Parity",
    "Majority",
    "Weighted",
    "Sym",
    "And",
    "Or",
    "Not",
    "AndNot",
    "as_query",
    "bind_members",
    "canonical_key",
    "column_refs",
]


@dataclasses.dataclass(frozen=True)
class Query:
    """Base class: operator overloads + structural cache key."""

    def key(self) -> tuple:
        raise NotImplementedError

    def __and__(self, other) -> "And":
        return And(self, other)

    def __or__(self, other) -> "Or":
        return Or(self, other)

    def __invert__(self) -> "Not":
        return Not(self)

    def __sub__(self, other) -> "AndNot":
        return AndNot(self, other)


def as_query(x) -> Query:
    """Coerce a column name into :class:`Col`; pass queries through."""
    if isinstance(x, Query):
        return x
    if isinstance(x, str):
        return Col(x)
    raise TypeError(f"expected Query or column name, got {type(x).__name__}: {x!r}")


def _norm_over(over) -> tuple | None:
    if over is None:
        return None
    if isinstance(over, (str, Query)):
        over = (over,)
    out = tuple(as_query(x) for x in over)
    if not out:
        raise ValueError("`over` must name at least one column or sub-query")
    return out


def _over_key(over: tuple | None) -> tuple | None:
    return None if over is None else tuple(q.key() for q in over)


# ---------------------------------------------------------------------------
# Leaves
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Col(Query):
    """A named column of the index (base or virtual)."""

    name: str

    def key(self) -> tuple:
        return ("col", self.name)


@dataclasses.dataclass(frozen=True)
class _SymmetricLeaf(Query):
    """Shared machinery: a symmetric function over a member set."""

    over: tuple | None = None

    def __post_init__(self):
        object.__setattr__(self, "over", _norm_over(self.over))

    def truth(self, n: int) -> tuple:
        """Truth table on Hamming weights 0..n; n = number of members."""
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class Threshold(_SymmetricLeaf):
    """At least ``t`` of the members are set (theta(T, .), paper 2.3)."""

    t: int = 1

    def __init__(self, t: int, over=None):
        object.__setattr__(self, "t", int(t))
        object.__setattr__(self, "over", _norm_over(over))

    def truth(self, n: int) -> tuple:
        return tuple(w >= self.t for w in range(n + 1))

    def key(self) -> tuple:
        return ("threshold", self.t, _over_key(self.over))


@dataclasses.dataclass(frozen=True)
class Interval(_SymmetricLeaf):
    """Member count within [lo, hi] ('on sale in 2 to 10 stores').

    An empty interval (lo > hi) is the constant-false query.
    """

    lo: int = 0
    hi: int = 0

    def __init__(self, lo: int, hi: int, over=None):
        object.__setattr__(self, "lo", int(lo))
        object.__setattr__(self, "hi", int(hi))
        object.__setattr__(self, "over", _norm_over(over))

    def truth(self, n: int) -> tuple:
        return tuple(self.lo <= w <= self.hi for w in range(n + 1))

    def key(self) -> tuple:
        return ("interval", self.lo, self.hi, _over_key(self.over))


@dataclasses.dataclass(frozen=True)
class Exactly(_SymmetricLeaf):
    """Member count == k (the paper's delta function)."""

    k: int = 0

    def __init__(self, k: int, over=None):
        object.__setattr__(self, "k", int(k))
        object.__setattr__(self, "over", _norm_over(over))

    def truth(self, n: int) -> tuple:
        return tuple(w == self.k for w in range(n + 1))

    def key(self) -> tuple:
        return ("exactly", self.k, _over_key(self.over))


@dataclasses.dataclass(frozen=True)
class Parity(_SymmetricLeaf):
    """Odd member count (wide XOR = weight bit z0)."""

    def truth(self, n: int) -> tuple:
        return tuple(w % 2 == 1 for w in range(n + 1))

    def key(self) -> tuple:
        return ("parity", _over_key(self.over))


@dataclasses.dataclass(frozen=True)
class Majority(_SymmetricLeaf):
    """More than half the members set: theta(ceil(n/2))."""

    def truth(self, n: int) -> tuple:
        t = (n + 1) // 2
        return tuple(w >= t for w in range(n + 1))

    def key(self) -> tuple:
        return ("majority", _over_key(self.over))


@dataclasses.dataclass(frozen=True)
class Sym(_SymmetricLeaf):
    """Arbitrary symmetric function given by its weight truth table.

    ``table`` must have exactly n_members + 1 entries at execution time.
    """

    table: tuple = ()

    def __init__(self, table: Sequence, over=None):
        object.__setattr__(self, "table", tuple(bool(x) for x in table))
        object.__setattr__(self, "over", _norm_over(over))

    def truth(self, n: int) -> tuple:
        if len(self.table) != n + 1:
            raise ValueError(
                f"Sym truth table has {len(self.table)} entries for {n} members "
                f"(needs {n + 1})"
            )
        return self.table

    def key(self) -> tuple:
        return ("sym", self.table, _over_key(self.over))


@dataclasses.dataclass(frozen=True)
class Weighted(Query):
    """sum_i w_i b_i >= t over the members (binary weight decomposition)."""

    weights: tuple = ()
    t: int = 1
    over: tuple | None = None

    def __init__(self, weights: Sequence[int], t: int, over=None):
        ws = tuple(int(w) for w in weights)
        if any(w < 0 for w in ws):
            raise ValueError("weights must be non-negative integers")
        object.__setattr__(self, "weights", ws)
        object.__setattr__(self, "t", int(t))
        object.__setattr__(self, "over", _norm_over(over))

    def key(self) -> tuple:
        return ("weighted", self.weights, self.t, _over_key(self.over))


# ---------------------------------------------------------------------------
# Combinators
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class And(Query):
    children: tuple = ()

    def __init__(self, *children):
        if not children:
            raise ValueError("And() needs at least one child")
        object.__setattr__(self, "children", tuple(as_query(c) for c in children))

    def key(self) -> tuple:
        return ("and",) + tuple(c.key() for c in self.children)


@dataclasses.dataclass(frozen=True)
class Or(Query):
    children: tuple = ()

    def __init__(self, *children):
        if not children:
            raise ValueError("Or() needs at least one child")
        object.__setattr__(self, "children", tuple(as_query(c) for c in children))

    def key(self) -> tuple:
        return ("or",) + tuple(c.key() for c in self.children)


@dataclasses.dataclass(frozen=True)
class Not(Query):
    child: Query = None  # type: ignore[assignment]

    def __init__(self, child):
        object.__setattr__(self, "child", as_query(child))

    def key(self) -> tuple:
        return ("not", self.child.key())


@dataclasses.dataclass(frozen=True)
class AndNot(Query):
    """keep AND NOT drop -- the paper's ANDNOT primitive."""

    keep: Query = None  # type: ignore[assignment]
    drop: Query = None  # type: ignore[assignment]

    def __init__(self, keep, drop):
        object.__setattr__(self, "keep", as_query(keep))
        object.__setattr__(self, "drop", as_query(drop))

    def key(self) -> tuple:
        return ("andnot", self.keep.key(), self.drop.key())


def _sorted_keys(keys) -> tuple:
    # keys are heterogeneous nested tuples (ints, strs, None); repr gives a
    # total, deterministic order where tuple comparison would raise
    return tuple(sorted(keys, key=repr))


def canonical_key(q: Query) -> tuple:
    """A *semantic* cache key: equal for queries that provably compute the
    same bitmap, stricter than :meth:`Query.key` (which is structural).

    Normalisations applied recursively:

      * symmetric-function leaves sort their member keys (a symmetric
        function cannot depend on member order);
      * :class:`Weighted` sorts (member, weight) pairs together;
      * :class:`And` / :class:`Or` flatten same-operator children, sort and
        deduplicate them (idempotence), and collapse the single-child case;
      * double negation cancels.

    This is the key the serving tier's result cache and in-flight request
    deduplication use (``repro.serve.frontend``): two clients asking
    ``Threshold(2, over=("a", "b"))`` and ``Threshold(2, over=("b", "a"))``
    share one execution and one cache entry.  Implicit ``over=None`` member
    sets are kept as ``None`` -- resolve them first with
    :func:`bind_members` when the key must be schema-stable.
    """

    def over_key(over):
        return None if over is None else _sorted_keys(canonical_key(m) for m in over)

    q = as_query(q)
    if type(q) is Col:
        return ("col", q.name)
    if isinstance(q, Threshold):
        return ("threshold", q.t, over_key(q.over))
    if isinstance(q, Interval):
        return ("interval", q.lo, q.hi, over_key(q.over))
    if isinstance(q, Exactly):
        return ("exactly", q.k, over_key(q.over))
    if isinstance(q, Parity):
        return ("parity", over_key(q.over))
    if isinstance(q, Majority):
        return ("majority", over_key(q.over))
    if isinstance(q, Sym):
        return ("sym", q.table, over_key(q.over))
    if isinstance(q, Weighted):
        if q.over is None:
            return ("weighted", q.weights, q.t, None)
        pairs = sorted(
            zip((canonical_key(m) for m in q.over), q.weights),
            key=lambda kw: repr(kw[0]),
        )
        return (
            "weighted",
            tuple(w for _, w in pairs),
            q.t,
            tuple(k for k, _ in pairs),
        )
    if isinstance(q, (And, Or)):
        tag = "and" if isinstance(q, And) else "or"
        parts = []
        for c in q.children:
            k = canonical_key(c)
            if k[0] == tag:  # flatten And(And(a,b),c) -> And(a,b,c)
                parts.extend(k[1:])
            else:
                parts.append(k)
        parts = _sorted_keys(set(parts))
        if len(parts) == 1:
            return parts[0]
        return (tag,) + parts
    if isinstance(q, Not):
        k = canonical_key(q.child)
        if k[0] == "not":
            return k[1]
        return ("not", k)
    if isinstance(q, AndNot):
        return ("andnot", canonical_key(q.keep), canonical_key(q.drop))
    raise TypeError(f"unknown query node {type(q).__name__}")


def column_refs(q: Query) -> frozenset | None:
    """The set of column names a query reads, or ``None`` when any leaf has
    an implicit ``over=None`` member set (meaning "every column at execution
    time" -- the caller must :func:`bind_members` first to resolve it).
    Used by the serving tier to build per-column cache version vectors."""
    names: set = set()

    def walk(x: Query) -> bool:
        if type(x) is Col:
            names.add(x.name)
            return True
        if isinstance(x, (_SymmetricLeaf, Weighted)):
            if x.over is None:
                return False
            return all(walk(m) for m in x.over)
        if isinstance(x, (And, Or)):
            return all(walk(c) for c in x.children)
        if isinstance(x, Not):
            return walk(x.child)
        if isinstance(x, AndNot):
            return walk(x.keep) and walk(x.drop)
        raise TypeError(f"unknown query node {type(x).__name__}")

    return frozenset(names) if walk(as_query(q)) else None


def bind_members(q: Query, names) -> Query:
    """Resolve every implicit ``over=None`` member set to the explicit
    column tuple ``names``, recursively.

    ``over=None`` means "every column of the index at execution time" --
    correct for ad-hoc queries, wrong for a *registered* one: a streaming
    materialized view must keep meaning what it meant when registered,
    even after new (view) columns join the schema.  Explicit member sets
    pass through untouched.
    """
    cols = tuple(Col(str(x)) for x in names)

    def bind(x: Query) -> Query:
        if isinstance(x, _SymmetricLeaf):
            over = cols if x.over is None else tuple(bind(m) for m in x.over)
            if isinstance(x, Threshold):
                return Threshold(x.t, over)
            if isinstance(x, Interval):
                return Interval(x.lo, x.hi, over)
            if isinstance(x, Exactly):
                return Exactly(x.k, over)
            if isinstance(x, Parity):
                return Parity(over)
            if isinstance(x, Majority):
                return Majority(over)
            if isinstance(x, Sym):
                return Sym(x.table, over)
            raise TypeError(f"unknown symmetric leaf {type(x).__name__}")
        if isinstance(x, Weighted):
            over = cols if x.over is None else tuple(bind(m) for m in x.over)
            return Weighted(x.weights, x.t, over)
        if isinstance(x, And):
            return And(*(bind(c) for c in x.children))
        if isinstance(x, Or):
            return Or(*(bind(c) for c in x.children))
        if isinstance(x, Not):
            return Not(bind(x.child))
        if isinstance(x, AndNot):
            return AndNot(bind(x.keep), bind(x.drop))
        return x  # Col

    return bind(as_query(q))



