"""Compile query expression trees into one shared Boolean circuit.

The whole point of compiling the *tree* instead of executing node by node:
every symmetric leaf over the same member set shares ONE sideways-sum adder
(memoised here, then CSE'd again by ``Circuit.optimized``), and combinators
are single gates.  ``And(Interval(2, 10), Not(Threshold(15)))`` costs one
adder plus two comparators plus two gates -- not three separate kernel
launches with intermediate bitmaps round-tripping through HBM.

Sub-queries are ordinary circuit nodes, so they can feed *into* adders:
``Threshold(2, over=("a", And("b", "c"), Interval(1, 2)))`` counts a gate
output as one vote.  Multi-query compilation (``execute_many``) simply adds
more outputs to the same circuit.

The compiled circuit is also what the storage engine's tiled executor
consumes: ``repro.storage.run_tiled_circuit`` partially evaluates it per
tile-class signature (``Circuit.specialize``), so a multi-output circuit
means all batched queries share ONE dirty-tile gather, and ``.support()``
(the inputs actually reachable from the outputs) bounds the signature
space to the columns the queries really read.
"""
from __future__ import annotations

from typing import Sequence

from repro.core import circuits as _ckt
from repro.core.weighted import emit_weighted_ge

from .expr import (
    And,
    AndNot,
    Col,
    Not,
    Or,
    Parity,
    Query,
    Threshold,
    Weighted,
    _SymmetricLeaf,
)

__all__ = ["build_query_circuit"]


def _truth_runs(truth: Sequence[bool]):
    """Contiguous true-runs [(lo, hi)] of a weight truth table."""
    runs = []
    w = 0
    n = len(truth) - 1
    while w <= n:
        if truth[w]:
            lo = w
            while w + 1 <= n and truth[w + 1]:
                w += 1
            runs.append((lo, w))
        w += 1
    return runs


class _Builder:
    def __init__(self, n_inputs: int, names: Sequence[str]):
        if len(set(names)) != len(names):
            raise ValueError("duplicate column names")
        if len(names) != n_inputs:
            raise ValueError(f"{len(names)} names for {n_inputs} columns")
        self.c = _ckt.Circuit(n_inputs, [], [])
        self.slot = {name: i for i, name in enumerate(names)}
        self._expr_memo: dict[tuple, int] = {}
        self._weight_memo: dict[tuple, list] = {}

    def weight_bits(self, member_ids: tuple) -> list:
        """Sideways-sum weight bits, shared across every leaf over the same
        member set (the core reuse win of whole-tree compilation)."""
        bits = self._weight_memo.get(member_ids)
        if bits is None:
            bits = _ckt.sideways_sum_bits(self.c, list(member_ids))
            self._weight_memo[member_ids] = bits
        return bits

    def members(self, over: tuple | None) -> tuple:
        if over is None:
            return tuple(range(self.c.n_inputs))
        return tuple(self.emit(q) for q in over)

    def emit(self, q: Query) -> int:
        key = q.key()
        got = self._expr_memo.get(key)
        if got is not None:
            return got
        out = self._emit(q)
        self._expr_memo[key] = out
        return out

    def _emit(self, q: Query) -> int:
        c = self.c
        if isinstance(q, Col):
            try:
                return self.slot[q.name]
            except KeyError:
                raise KeyError(
                    f"unknown column {q.name!r}; index has {sorted(self.slot)[:8]}..."
                ) from None
        if isinstance(q, And):
            return c.wide_and([self.emit(x) for x in q.children])
        if isinstance(q, Or):
            return c.wide_or([self.emit(x) for x in q.children])
        if isinstance(q, Not):
            inner = self.emit(q.child)
            if inner == _ckt.CONST0:
                return _ckt.CONST1
            if inner == _ckt.CONST1:
                return _ckt.CONST0
            return c.NOT(inner)
        if isinstance(q, AndNot):
            return c.ANDNOT(self.emit(q.keep), self.emit(q.drop))
        if isinstance(q, Weighted):
            return emit_weighted_ge(c, list(self.members(q.over)), q.weights, q.t)
        if isinstance(q, _SymmetricLeaf):
            return self._emit_symmetric(q)
        raise TypeError(f"cannot compile {type(q).__name__}")

    def _emit_symmetric(self, q: _SymmetricLeaf) -> int:
        c = self.c
        ids = self.members(q.over)
        n = len(ids)
        truth = q.truth(n)
        if not any(truth):
            return _ckt.CONST0
        if all(truth):
            return _ckt.CONST1
        if isinstance(q, Parity):
            return self.weight_bits(ids)[0]
        # thresholds at the degenerate ends need no adder at all
        if isinstance(q, Threshold):
            if q.t == 1:
                return c.wide_or(list(ids))
            if q.t == n:
                return c.wide_and(list(ids))
        bits = self.weight_bits(ids)
        terms = []
        for lo, hi in _truth_runs(truth):
            ge_lo = _ckt.ge_const(c, bits, lo)
            if hi >= n:
                terms.append(ge_lo)
            else:
                ge_hi1 = _ckt.ge_const(c, bits, hi + 1)
                terms.append(c.ANDNOT(ge_lo, ge_hi1))
        return c.wide_or(terms)


def build_query_circuit(
    queries: Sequence[Query], n_inputs: int, names: Sequence[str]
) -> _ckt.Circuit:
    """Compile one or more queries into a single optimised multi-output
    circuit over the index columns (input i = column ``names[i]``)."""
    b = _Builder(n_inputs, names)
    b.c.outputs = [b.emit(q) for q in queries]
    return b.c.optimized()
