"""Typed ExecInfo: ONE executor-accounting schema shared by every backend.

Before this module each backend reported a different ad-hoc dict (or
``None``), and the sharded path merged by hand-picked keys -- a new
counter added to the tiled executor was silently dropped at 8 shards.
Now:

* every backend returns an ExecInfo built by :func:`make_exec_info`,
  which fills defaults for every schema key and REJECTS unknown keys
  (adding a counter forces a schema entry, and the schema entry defines
  how it merges);
* :func:`merge_exec_infos` folds per-shard infos by schema -- summable
  counters add, nested word-kind dicts add key-wise, labels collect,
  ratios are recomputed from the merged numerators/denominators.  No
  key present in a shard info can be dropped by the merge.

The schema is the paper's words-touched accounting (Table 4's case
split, generalised to containers) plus dispatch costs: ``launches``
prices kernel dispatch, ``words_touched`` is the roofline traffic term
(gathered input words + written output words) that the planner's
``Plan.cost`` predicts and :mod:`repro.obs` compares against.
"""
from __future__ import annotations

__all__ = ["EXEC_INFO_SCHEMA", "make_exec_info", "merge_exec_infos"]

# merge kinds: how each key folds across shards
_SUM = "sum"            # integer counter: adds
_MAX = "max"            # per-query shape (same on every shard): max
_LABEL = "label"        # string tag: scalar if unanimous, sorted list else
_DICT_SUM = "dict_sum"  # {category: counter}: key-wise addition
_RATIO = "ratio"        # recomputed from merged fields (numerator, denominator)

EXEC_INFO_SCHEMA: dict[str, tuple] = {
    "backend": (_LABEL, ""),
    "engine": (_LABEL, ""),
    "n_tiles": (_SUM, 0),
    "selected_tiles": (_SUM, 0),
    "n_outputs": (_MAX, 1),
    "signatures": (_SUM, 0),
    "residual_signatures": (_SUM, 0),
    "const_tiles": (_SUM, 0),
    "case3_tiles": (_SUM, 0),
    "event_tiles": (_SUM, 0),
    "densified_tiles": (_SUM, 0),
    "dirty_words_gathered": (_SUM, 0),
    "compressed_words_gathered": (_SUM, 0),
    "decode_words": (_SUM, 0),
    "total_words": (_SUM, 0),
    "words_touched": (_SUM, 0),
    "launches": (_SUM, 0),
    "words_by_kind": (_DICT_SUM, {"dense": 0, "sparse": 0, "run": 0}),
    "work_fraction": (_RATIO, ("dirty_words_gathered", "total_words")),
}


def _default(kind: str, dflt):
    if kind == _DICT_SUM:
        return dict(dflt)
    if kind == _RATIO:
        return 0.0
    return dflt


def make_exec_info(backend: str, **fields) -> dict:
    """A full ExecInfo dict: every schema key present, defaults filled.

    Unknown keys raise -- the schema is the single registration point, so
    a counter can never exist without a defined merge rule.
    """
    unknown = set(fields) - set(EXEC_INFO_SCHEMA)
    if unknown:
        raise KeyError(
            f"unknown ExecInfo keys {sorted(unknown)}; add them to "
            "EXEC_INFO_SCHEMA with a merge rule first"
        )
    info = {
        key: _default(kind, dflt)
        for key, (kind, dflt) in EXEC_INFO_SCHEMA.items()
    }
    info["backend"] = backend
    for key, val in fields.items():
        kind = EXEC_INFO_SCHEMA[key][0]
        if kind == _DICT_SUM:
            info[key].update(val)
        else:
            info[key] = val
    return info


def merge_exec_infos(infos) -> dict:
    """Fold shard-local ExecInfos into one, by schema -- never by key list.

    Associative and commutative for every numeric field (plain integer
    addition / max), so shard order and grouping cannot change the
    result.  Keys outside the schema present in any input raise rather
    than silently vanish.
    """
    infos = [i for i in infos if i is not None]
    if not infos:
        return make_exec_info("")
    for i in infos:
        unknown = set(i) - set(EXEC_INFO_SCHEMA)
        if unknown:
            raise KeyError(
                f"ExecInfo with unregistered keys {sorted(unknown)}; "
                "the schema must know how to merge every key"
            )
    out = {}
    for key, (kind, dflt) in EXEC_INFO_SCHEMA.items():
        vals = [i[key] for i in infos if key in i]
        if kind == _SUM:
            out[key] = sum(vals) if vals else dflt
        elif kind == _MAX:
            out[key] = max(vals) if vals else dflt
        elif kind == _LABEL:
            uniq = sorted({v for v in vals if v})
            out[key] = uniq[0] if len(uniq) == 1 else uniq
        elif kind == _DICT_SUM:
            acc = dict(dflt)
            for v in vals:
                for k2, n in v.items():
                    acc[k2] = acc.get(k2, 0) + n
            out[key] = acc
    for key, (kind, dflt) in EXEC_INFO_SCHEMA.items():
        if kind == _RATIO:
            num, den = dflt
            out[key] = out[num] / max(1, out[den])
    return out
