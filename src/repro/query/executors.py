"""Executable backends for threshold plans, behind ONE dispatch point.

Every algorithm name the planner can emit resolves here (the seed repo's
planner produced ``wide_or`` / ``rbmrg_block`` / ``dsk`` names that
``threshold()`` rejected -- now each is a runnable executor):

  * device circuit family  -- scancount, scancount_streaming, looped,
    csvckt, ssum, treeadd, srtckt, sopckt (straight-line XLA bitwise code)
  * fused                  -- the Pallas kernel (interpret mode off-TPU)
  * tiled_fused            -- the storage engine's tile-skipping executor:
    clean tiles resolve as constants before launch, only dirty tiles are
    gathered into the fused kernel (repro.storage.run_tiled_circuit)
  * wide_or / wide_and     -- the T=1 / T=N degenerate reductions
  * rbmrg_block            -- tile-level clean/dirty pruning, bare
    thresholds only (repro.storage.tiles; tiled_fused generalises it)
  * dsk                    -- DivideSkip over host position lists, for the
    paper's sparse, T~N regime where pruning beats bit-parallel work

Backends are *shard-local* functions: they see one :class:`ShardContext`
(the tile store, dense view, compiled circuit and bare-threshold shape of
one row-range of the index) and never touch device placement themselves.
:func:`run_plan` is the single entrypoint that dispatches a plan against a
context -- ``BitmapIndex`` builds one context for its whole row space, the
sharded engine (``repro.dist.query``) builds one per device shard and can
hand each shard a different plan.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitmaps import WORD_DTYPE, from_positions, to_positions_np
from repro.core.planner import CIRCUIT_BACKENDS
from repro.query.execinfo import make_exec_info

__all__ = [
    "THRESHOLD_BACKENDS",
    "ShardContext",
    "run_plan",
    "run_threshold_backend",
]

_DEVICE_ALGOS = (
    "scancount", "scancount_streaming", "looped", "csvckt",
    "ssum", "treeadd", "srtckt", "sopckt",
)

THRESHOLD_BACKENDS = _DEVICE_ALGOS + (
    "fused", "tiled_fused", "wide_or", "wide_and", "rbmrg_block", "dsk",
)


@partial(jax.jit, static_argnames=("t", "algorithm"))
def _device_threshold(bitmaps: jax.Array, t: int, algorithm: str) -> jax.Array:
    from repro.core.threshold import (
        _circuit_threshold,
        _csvckt,
        _looped,
        _scancount,
        _scancount_streaming,
    )

    if algorithm == "scancount":
        return _scancount(bitmaps, t)
    if algorithm == "scancount_streaming":
        return _scancount_streaming(bitmaps, t)
    if algorithm == "looped":
        return _looped(bitmaps, t)
    if algorithm == "csvckt":
        return _csvckt(bitmaps, t)
    return _circuit_threshold(bitmaps, t, algorithm)


@jax.jit
def _wide_or(bitmaps: jax.Array) -> jax.Array:
    return jnp.bitwise_or.reduce(bitmaps, axis=0)


@jax.jit
def _wide_and(bitmaps: jax.Array) -> jax.Array:
    # jnp.bitwise_and.reduce rejects uint32 (its -1 init overflows); De Morgan
    return jnp.bitwise_not(jnp.bitwise_or.reduce(jnp.bitwise_not(bitmaps), axis=0))


def _dsk_threshold(bitmaps: jax.Array, t: int) -> jax.Array:
    """Host DivideSkip over per-bitmap sorted position lists."""
    from repro.core.listalgos import dsk

    arr = np.asarray(jax.device_get(bitmaps), dtype=np.uint32)
    r = arr.shape[1] * 32
    lists = [to_positions_np(row) for row in arr]
    return from_positions(dsk(lists, t, r), r)


@dataclasses.dataclass
class ShardContext:
    """Everything a shard-local backend needs to execute one plan.

    A *shard* is a row-range of the universe: the whole index on a single
    device, or one device's tile range under ``repro.dist.query``.  Data
    accessors are thunks so a backend only pays for the representation it
    reads -- ``tiled_fused`` builds the tile store, dense backends pull the
    packed view, and neither forces the other.
    """

    n: int  # columns in the shard (same for every shard of an index)
    dense: Callable  # () -> uint32[n, local_words] packed dense view
    store: Callable | None = None  # () -> TileStore (tile-classified shard)
    circuit: Callable | None = None  # () -> compiled Circuit (shared, cached)
    bare: tuple | None = None  # (member slots | None, T) for bare thresholds
    column: int | None = None  # slot for 'column' plans
    block_words: int | None = None
    #: tiled case-3 engine override: "scan" (single-dispatch device engine)
    #: / "merge" (host event-merge oracle) / None (auto per store)
    tiled_engine: str | None = None

    def member_rows(self) -> jax.Array:
        """Dense rows of the bare-threshold member subset."""
        rows = self.dense()
        slots = self.bare[0]
        if slots is not None:
            rows = rows[jnp.asarray(list(slots))]
        return rows


def _dense_exec_info(backend: str, engine: str, n_rows: int, out,
                     launches: int = 1) -> dict:
    """ExecInfo for a backend that reads every member row densely.

    ``words_touched`` is the roofline traffic term: N input rows read plus
    each output row written, all at the shard's word width.
    """
    k = 1 if out.ndim == 1 else out.shape[0]
    nw = int(out.shape[-1])
    total = n_rows * nw + k * nw
    return make_exec_info(
        backend,
        engine=engine,
        n_outputs=k,
        total_words=total,
        words_touched=total,
        dirty_words_gathered=n_rows * nw,
        words_by_kind={"dense": n_rows * nw},
        launches=launches,
        work_fraction=1.0,
    )


def run_plan(ctx: ShardContext, plan):
    """THE executor entrypoint: run one plan against one shard's data.

    ``plan`` is a ``core.planner.Plan`` or a backend name.  Returns
    ``(packed result, info)`` -- ``info`` is an ExecInfo
    (:mod:`repro.query.execinfo`): the tiled executor's case-split
    accounting when it ran, a dense-traffic accounting for every other
    backend.  Every backend resolves through here; callers own device
    placement, backends own compute.
    """
    alg = getattr(plan, "algorithm", plan)
    if alg == "column":
        if ctx.column is None:
            raise ValueError("'column' plan without a column slot in the context")
        out = ctx.dense()[ctx.column]
        nw = int(out.shape[-1])
        return out, make_exec_info(
            "column", engine="view", total_words=nw, words_touched=nw,
            words_by_kind={"dense": nw}, launches=0, work_fraction=1.0,
        )
    if alg == "tiled_fused":
        if ctx.store is None or ctx.circuit is None:
            raise ValueError("'tiled_fused' needs a tile store and a compiled circuit")
        from repro.storage import run_tiled_circuit

        out, info = run_tiled_circuit(
            ctx.store(), ctx.circuit(), block_words=ctx.block_words,
            engine=ctx.tiled_engine,
        )
        return out, info
    if alg in THRESHOLD_BACKENDS and ctx.bare is not None:
        rows = ctx.member_rows()
        out = run_threshold_backend(
            rows, ctx.bare[1], alg, block_words=ctx.block_words
        )
        engine = "host" if alg == "dsk" else "dense"
        return out, _dense_exec_info(alg, engine, int(rows.shape[0]), out)
    if alg in CIRCUIT_BACKENDS:
        from repro.kernels.threshold_ssum import INTERPRET, run_circuit_cached

        if ctx.circuit is None:
            raise ValueError(f"backend {alg!r} needs a compiled circuit in the context")
        rows = ctx.dense()
        out = run_circuit_cached(
            rows,
            ctx.circuit(),
            block_words=ctx.block_words,
            interpret=INTERPRET,
            pallas=alg == "fused",
        )
        return out, _dense_exec_info(alg, "dense", int(rows.shape[0]), out)
    if alg in THRESHOLD_BACKENDS:
        raise ValueError(
            f"backend {alg!r} only executes bare Threshold queries; "
            "use 'circuit', 'fused' or 'tiled_fused' for composite expressions"
        )
    raise ValueError(f"unknown backend {alg!r}")


def run_threshold_backend(
    bitmaps: jax.Array, t: int, backend: str, *, block_words: int | None = None
) -> jax.Array:
    """theta(T, .) over packed uint32[N, n_words] via a named backend.

    T must be a static Python int (circuits are tabulated per (N, T)).
    T <= 0 and T > N short-circuit before backend dispatch.
    """
    if not isinstance(t, int):
        raise TypeError("T must be a static Python int (circuits are tabulated per (N,T))")
    bitmaps = jnp.asarray(bitmaps, WORD_DTYPE)
    if bitmaps.ndim != 2:
        raise ValueError(f"expected uint32[N, n_words], got shape {bitmaps.shape}")
    n = bitmaps.shape[0]
    if t <= 0:
        return jnp.full_like(bitmaps[0], 0xFFFFFFFF)
    if t > n:
        return jnp.zeros_like(bitmaps[0])
    if backend == "wide_or":
        if t != 1:
            raise ValueError(f"wide_or computes theta(1, .); got T={t}")
        return _wide_or(bitmaps)
    if backend == "wide_and":
        if t != n:
            raise ValueError(f"wide_and computes theta(N, .); got T={t}, N={n}")
        return _wide_and(bitmaps)
    if backend == "rbmrg_block":
        from repro.storage import rbmrg_block_threshold

        out, _info = rbmrg_block_threshold(bitmaps, t)
        return out
    if backend == "tiled_fused":
        from repro.core.circuits import build_threshold_circuit
        from repro.storage import TileStore, run_tiled_circuit

        store = TileStore.from_packed(bitmaps)
        circ = build_threshold_circuit(n, t, "ssum")
        out, _info = run_tiled_circuit(store, circ, block_words=block_words)
        return out
    if backend == "dsk":
        return _dsk_threshold(bitmaps, t)
    if backend == "fused":
        from repro.kernels.threshold_ssum import INTERPRET, threshold_pallas

        return threshold_pallas(bitmaps, t, block_words=block_words, interpret=INTERPRET)
    if backend in _DEVICE_ALGOS:
        return _device_threshold(bitmaps, t, backend)
    raise ValueError(f"unknown algorithm {backend!r}; valid: {THRESHOLD_BACKENDS}")
