"""`BitmapIndex`: a TileStore + statistics + planner-driven execution.

The index wraps a :class:`repro.storage.TileStore` -- the tile-classified
hybrid column store is the native representation; the dense
``uint32[N, n_words]`` view is materialised (and cached) only for backends
that need it (``store.densify()``).  Per-column cardinality / density /
runcount / clean-fraction statistics are computed once at build time by
the store, so the planner is *always* data-aware:

  * :meth:`execute` plans a query expression (``core.planner.plan_query``
    with real member-subset tile statistics) and routes it -- clean-heavy
    data to the tile-skipping ``tiled_fused`` executor, bare thresholds to
    the specialised backends, everything else through ONE compiled circuit;
  * :meth:`execute_many` compiles independent circuit-family queries into a
    single multi-output circuit; on the tiled path all queries share one
    dirty-tile gather;
  * results are packed bitmaps (tail-masked to the universe size), so they
    can be fed back in as virtual columns with :meth:`add_column` -- the
    paper's "the result ... can be further processed within a bitmap index".

Indexes are immutable: :meth:`add_column` / :meth:`replace_column` return a
NEW index sharing the untouched columns' storage, so stale references keep
planning and executing correctly against their own schema.

Compiled circuits are cached per process by (query shape, column names);
their jitted evaluators are cached by circuit *structure* underneath
(``kernels.threshold_ssum.run_circuit_cached``).  Data never enters either
key, so every index with the same schema shares both layers.
"""
from __future__ import annotations

import dataclasses
import math
import time as _time
import weakref
from collections import OrderedDict

import jax
import jax.numpy as jnp
import numpy as np

import repro.obs as _obs
from repro.core.bitmaps import WORD_DTYPE, cardinality, pack, packed_tail_mask
from repro.core.planner import CIRCUIT_BACKENDS, Plan, plan_query
from repro.obs import trace as _trace
from repro.storage import TileStore, run_tiled_circuit

from .compile import build_query_circuit
from .expr import Col, Query, Threshold, as_query, canonical_key
from .executors import ShardContext, run_plan

__all__ = [
    "BitmapIndex",
    "IndexStats",
    "execute",
    "circuit_for",
    "compiled_cache_info",
    "clear_compiled_cache",
    "plan_memo_info",
]

# ---------------------------------------------------------------------------
# Per-process compiled-circuit cache.  Two layers: query shape -> Circuit
# here, circuit structure -> jitted evaluator in kernels.threshold_ssum
# (run_circuit_cached) -- so query shapes that compile to the same gate DAG
# also share one compiled evaluator.
# ---------------------------------------------------------------------------

_CIRCUITS: dict[tuple, object] = {}  # (qkeys, names) -> Circuit
_CACHE_INFO = {"hits": 0, "misses": 0}

# bare thresholds whose backend is itself a circuit join multi-query batches
_BATCHABLE = CIRCUIT_BACKENDS + ("ssum", "treeadd", "srtckt", "sopckt")


def compiled_cache_info() -> dict:
    """Hits/misses/size of the per-process compiled-circuit cache."""
    return {"size": len(_CIRCUITS), **_CACHE_INFO}


def clear_compiled_cache() -> None:
    from repro.kernels.threshold_ssum import clear_circuit_runners
    from repro.kernels.tiled_scan import clear_scan_runners

    _CIRCUITS.clear()
    clear_circuit_runners()
    clear_scan_runners()
    _CACHE_INFO["hits"] = 0
    _CACHE_INFO["misses"] = 0
    _PLAN_MEMOS.clear()
    _PLAN_MEMO_INFO["hits"] = 0
    _PLAN_MEMO_INFO["misses"] = 0


# ---------------------------------------------------------------------------
# Plan memoization.  Hot serving paths ask the same questions of the same
# store forever; memoize ``explain``'s answer per store (weakly -- a dropped
# store drops its memo) keyed by the SEMANTIC query key and a coarse bucket
# of the member statistics.  The bucket deliberately quantises (5% clean
# fraction, decade density, pow2 dirty words): stats that land in one
# bucket get one plan, trading exactness the planner never had for a
# dict-lookup fast path that skips cost-model evaluation entirely.
# ---------------------------------------------------------------------------

_PLAN_MEMO_CAP = 512  # per store
_PLAN_MEMOS: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()
_PLAN_MEMO_INFO = {"hits": 0, "misses": 0}


def plan_memo_info() -> dict:
    """Process-wide hit/miss counters + live size of the per-store plan
    memo (surfaced by ``QueryServer.info()`` and benchmark artifacts)."""
    return {
        "stores": len(_PLAN_MEMOS),
        "entries": sum(len(v) for v in _PLAN_MEMOS.values()),
        **_PLAN_MEMO_INFO,
    }


def _stats_bucket(stats) -> tuple:
    """Quantise member statistics so equivalent stores share plan entries."""
    dens = float(stats.density)
    dens_band = 99 if dens <= 0 else min(12, max(0, int(-math.log10(max(dens, 1e-12)))))
    return (
        stats.n,
        stats.n_words,
        stats.tile_words,
        int(round(stats.clean_fraction * 20)),
        dens_band,
        int(stats.dirty_words).bit_length(),
        int(getattr(stats, "compressed_words", 0) or 0).bit_length(),
    )


def _plan_memo_for(store) -> OrderedDict:
    memo = _PLAN_MEMOS.get(store)
    if memo is None:
        memo = _PLAN_MEMOS[store] = OrderedDict()
    return memo


def _fused_available() -> bool:
    return jax.default_backend() == "tpu"


def member_slots(q: Query, slot: dict):
    """Column slots a bare-threshold query actually reads (None: all).
    Shared by the single-device and sharded engines -- slots index any
    shard's rows identically."""
    if type(q) is Threshold and q.over is not None and all(
        type(m) is Col for m in q.over
    ):
        for m in q.over:
            if m.name not in slot:
                raise KeyError(
                    f"unknown column {m.name!r}; index has {sorted(slot)[:8]}..."
                )
        return [slot[m.name] for m in q.over]
    return None


def bare_slots(q: Query, slot: dict):
    """(member slots | None, t) when q is a Threshold over plain columns
    (None slots: every column), else None."""
    if type(q) is not Threshold:
        return None
    if q.over is None:
        return None, q.t
    slots = member_slots(q, slot)
    if slots is None:
        return None
    return tuple(slots), q.t


def circuit_for(qs: tuple, n: int, names: tuple):
    """The (process-cached) multi-output circuit compiling ``qs`` over a
    schema.  Module-level so the sharded engine (``repro.dist.query``)
    compiles ONE circuit per query shape and shares it across every shard
    -- per-shard *plans* differ, the circuit never does."""
    key = (tuple(q.key() for q in qs), tuple(names))
    circ = _CIRCUITS.get(key)
    if circ is not None:
        _CACHE_INFO["hits"] += 1
        if _trace.enabled:
            # steady-state hit: annotate the open span instead of paying a
            # zero-duration child span per request
            _trace.current_span().set(compile_cache="hit")
        return circ
    _CACHE_INFO["misses"] += 1
    with _trace.span("compile", cache="miss") as sp:
        circ = build_query_circuit(qs, n, names)
        sp.set(n_outputs=len(getattr(circ, "outputs", ())) or len(qs))
    _CIRCUITS[key] = circ
    return circ


def _annotate_dispatch(sp, info: dict) -> None:
    """Copy an ExecInfo's dispatch + decode accounting onto the span tree:
    the dispatch span carries the engine / launch / case-split numbers, a
    child ``decode`` span the container-decode traffic (decode happens
    inside the kernel, so its span carries words rather than wall time).
    Backends that never decode containers (dense / host paths) carry their
    word accounting directly on the dispatch span instead of an all-zero
    decode child."""
    sp.set(
        engine=info.get("engine"),
        launches=info.get("launches"),
        case3_tiles=info.get("case3_tiles"),
        const_tiles=info.get("const_tiles"),
        event_tiles=info.get("event_tiles"),
        measured_words=info.get("words_touched"),
    )
    if info.get("backend") != "tiled_fused":
        sp.set(
            dirty_words_gathered=info.get("dirty_words_gathered"),
            words_by_kind=dict(info.get("words_by_kind") or {}),
        )
        return
    with _trace.span("decode") as dec:
        dec.set(
            decode_words=info.get("decode_words"),
            densified_tiles=info.get("densified_tiles"),
            compressed_words_gathered=info.get("compressed_words_gathered"),
            dirty_words_gathered=info.get("dirty_words_gathered"),
            words_by_kind=dict(info.get("words_by_kind") or {}),
        )


# ---------------------------------------------------------------------------
# Statistics
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class IndexStats:
    """Per-index statistics (computed at TileStore build time, free to read)."""

    n: int
    n_words: int
    r: int
    cardinalities: tuple
    densities: tuple
    density: float  # mean over columns
    clean_fraction: float  # fraction of (column, tile) pairs that are clean
    tile_words: int
    clean_fractions: tuple = ()  # per column
    runcounts: tuple = ()  # per column (paper's RUNCOUNT)
    dirty_words: int = 0  # words a dense dirty pack would store
    #: (dense, sparse, run) container tile counts across the index
    container_tiles: tuple = (0, 0, 0)
    #: words the container packs actually occupy (<= dirty_words)
    compressed_words: int = 0


# ---------------------------------------------------------------------------
# The index
# ---------------------------------------------------------------------------


class BitmapIndex:
    """A queryable collection of named packed bitmaps over one universe."""

    def __init__(self, columns=None, names=None, *, r: int | None = None,
                 tile_words: int = 64, containers: bool = True,
                 _store: TileStore | None = None):
        # classification is deferred to first `store` access: a transient
        # index executed with an explicit backend override never pays the
        # device_get + tile-classification pass
        if _store is not None:
            self._store_cache: TileStore | None = _store
            self._pending = None
            n, n_words, self.r = _store.n, _store.n_words, _store.r
        else:
            cols = jnp.asarray(columns, WORD_DTYPE)
            if cols.ndim != 2:
                raise ValueError(f"expected uint32[N, n_words], got shape {cols.shape}")
            n, n_words = cols.shape
            self._store_cache = None
            self._pending = cols
            self.r = int(r) if r is not None else n_words * 32
        self._tile_words = int(tile_words)
        self._containers = bool(containers)
        self._n, self._n_words = int(n), int(n_words)
        if names is None:
            names = tuple(f"c{i}" for i in range(n))
        else:
            names = tuple(str(x) for x in names)
            if len(names) != n:
                raise ValueError(f"{len(names)} names for {n} columns")
            if len(set(names)) != n:
                raise ValueError("duplicate column names")
        self._names = names
        self._slot = {name: i for i, name in enumerate(names)}
        if self.r > n_words * 32 or self.r <= 0:
            raise ValueError(f"universe size {self.r} does not fit {n_words} words")
        self._stats_cache: dict[int, IndexStats] = {}
        #: info dict of the last tiled execution (words gathered, case split)
        self.last_info: dict | None = None

    # -- construction ------------------------------------------------------
    @classmethod
    def from_dense(cls, bits, names=None, *, tile_words: int = 64,
                   containers: bool = True) -> "BitmapIndex":
        """Build from a dense boolean/int array [N, r]."""
        bits = jnp.asarray(bits)
        return cls(pack(bits), names, r=bits.shape[-1], tile_words=tile_words,
                   containers=containers)

    @classmethod
    def from_columns(cls, columns: dict, *, r: int | None = None,
                     tile_words: int = 64) -> "BitmapIndex":
        """Build from a {name: packed uint32[n_words]} mapping."""
        if not columns:
            raise ValueError("need at least one column")
        names = tuple(columns)
        stacked = jnp.stack([jnp.asarray(columns[k], WORD_DTYPE) for k in names])
        return cls(stacked, names, r=r, tile_words=tile_words)

    # -- basic accessors ---------------------------------------------------
    @property
    def store(self) -> TileStore:
        """The underlying tile-classified column store (built on demand)."""
        if self._store_cache is None:
            self._store_cache = TileStore.from_packed(
                self._pending, tile_words=self._tile_words, r=self.r,
                containers=self._containers,
            )
            self._pending = None
        return self._store_cache

    @property
    def columns(self) -> jax.Array:
        """Dense uint32[N, n_words] view (materialised from tiles, cached)."""
        if self._store_cache is None:
            return self._pending
        return self._store_cache.densify()

    @property
    def names(self) -> tuple:
        return self._names

    @property
    def n(self) -> int:
        return self._n

    @property
    def n_words(self) -> int:
        return self._n_words

    def __len__(self) -> int:
        return self.n

    def __contains__(self, name: str) -> bool:
        return name in self._slot

    def __getitem__(self, name: str) -> Col:
        """Sugar: ``idx["a"] & ~idx["b"]`` builds an expression."""
        if name not in self._slot:
            raise KeyError(f"unknown column {name!r}")
        return Col(name)

    def column(self, name: str) -> jax.Array:
        if name not in self._slot:
            raise KeyError(
                f"unknown column {name!r}; index has {sorted(self._slot)[:8]}..."
            )
        return self.store.column(self._slot[name])

    def add_column(self, name: str, packed) -> "BitmapIndex":
        """Return a NEW index with a (virtual) column appended -- e.g. a
        previous query result.  Only the new column is classified; untouched
        columns share storage with this index, which keeps working."""
        if name in self._slot:
            raise ValueError(f"column {name!r} already exists")
        return BitmapIndex(
            names=self._names + (name,), _store=self.store.append(packed)
        )

    def replace_column(self, name: str, packed) -> "BitmapIndex":
        """Return a NEW index with one column's data swapped; only that
        column's tiles are reclassified (the slot-mask update path)."""
        if name not in self._slot:
            raise KeyError(f"unknown column {name!r}")
        return BitmapIndex(
            names=self._names, _store=self.store.replace(self._slot[name], packed)
        )

    # -- sharding ----------------------------------------------------------
    def shard(self, mesh=None, axis: str = "data", n_shards: int | None = None):
        """Partition the row space across devices: a
        :class:`repro.dist.query.ShardedBitmapIndex` whose shards are
        contiguous tile ranges, each with its own tile classes, dirty pack
        and member statistics.  ``execute`` there compiles ONE circuit and
        plans PER SHARD.  With ``mesh=None`` the shards run host-sequenced
        (still per-shard-planned); with a mesh, homogeneous dense plans run
        as one ``shard_map``."""
        from repro.dist.query import ShardedBitmapIndex

        return ShardedBitmapIndex.from_index(
            self, mesh=mesh, axis=axis, n_shards=n_shards
        )

    @classmethod
    def from_sharded(cls, sharded) -> "BitmapIndex":
        """Gather a :class:`repro.dist.query.ShardedBitmapIndex` back into a
        single-device index (the explicit, paid-for gather -- query results
        never need it, they feed back shard-wise via ``add_column``).  The
        shards' tile classifications are stitched, not recomputed."""
        store = TileStore.concat_tiles(
            sharded.store.shards, n_words=sharded.n_words, r=sharded.r
        )
        return cls(names=sharded.names, _store=store)

    # -- persistence -------------------------------------------------------
    def save(self, path) -> dict:
        """Write a ``.bmsnap`` snapshot (``repro.persist``); returns the
        manifest.  ``BitmapIndex.load(path)`` reconstructs the index over
        ``np.memmap`` views -- no rebuild, no classification pass."""
        from repro.persist import save

        return save(self, path)

    @classmethod
    def load(cls, path, *, to_device: bool = False,
             verify: bool = False) -> "BitmapIndex":
        """Reconstruct a saved index; see :func:`repro.persist.load_index`."""
        from repro.persist import load_index

        return load_index(path, to_device=to_device, verify=verify)

    # -- statistics --------------------------------------------------------
    def stats(self, tile_words: int | None = None, refresh: bool = False) -> IndexStats:
        """Planner statistics at the requested tile granularity.

        Statistics at the store's native granularity are free (computed at
        build time); other granularities reclassify once and are cached PER
        ``tile_words`` -- ``stats(tile_words=128)`` after ``stats(tile_words=64)``
        no longer returns stats computed at the wrong granularity.
        """
        tw = int(tile_words) if tile_words is not None else self.store.tile_words
        cached = self._stats_cache.get(tw)
        if cached is not None and not refresh:
            return cached
        store = self.store.with_tile_words(tw)
        dens = store.densities
        census = store.container_census()
        st = IndexStats(
            n=store.n,
            n_words=store.n_words,
            r=self.r,
            cardinalities=store.cardinalities,
            densities=dens,
            density=float(np.mean(dens)) if dens else 0.0,
            clean_fraction=store.clean_fraction,
            tile_words=tw,
            clean_fractions=tuple(s.clean_fraction for s in store.col_stats),
            runcounts=store.runcounts,
            dirty_words=store.dirty_words,
            container_tiles=(census["dense"], census["sparse"], census["run"]),
            compressed_words=census["storage_words"],
        )
        self._stats_cache[tw] = st
        return st

    # -- planning ----------------------------------------------------------
    def _member_slots(self, q: Query):
        """Column slots a bare-threshold query actually reads (None: all)."""
        return member_slots(q, self._slot)

    def explain(self, query, *, memo: bool = True) -> Plan:
        """The plan :meth:`execute` would run.  Plans carry ``cost`` (the
        estimated words touched) and ``candidates`` (per-backend estimates)
        computed from the member subset's real tile statistics, plus
        ``cost_us``/``candidates_us`` when a planner calibration is
        installed (``core.calibration``).

        Answers are memoized per store, keyed by the query's *semantic* key
        and a coarse bucket of the member statistics, so hot serving paths
        skip planning entirely; ``plan.memo`` reports "hit"/"miss" and
        :func:`plan_memo_info` the process-wide counters.  ``memo=False``
        bypasses (and does not populate) the memo."""
        q = as_query(query)
        with _trace.span("plan") as sp:
            plan = self._explain(q, memo)
            if _trace.enabled:
                sp.set(
                    algorithm=plan.algorithm,
                    memo=plan.memo,
                    predicted_words=plan.cost,
                    predicted_us=plan.cost_us,
                    candidates=plan.candidates or (),
                )
        return plan

    def _explain(self, q: Query, memo: bool) -> Plan:
        stats = self.store.member_stats(self._member_slots(q))
        if not memo:
            return plan_query(
                q, self.n, stats=stats, fused_available=_fused_available()
            )
        from repro.core.calibration import calibration_generation

        key = (
            canonical_key(q),
            _stats_bucket(stats),
            _fused_available(),
            calibration_generation(),
        )
        lru = _plan_memo_for(self.store)
        cached = lru.get(key)
        if cached is not None:
            lru.move_to_end(key)
            _PLAN_MEMO_INFO["hits"] += 1
            return dataclasses.replace(cached, memo="hit")
        _PLAN_MEMO_INFO["misses"] += 1
        plan = plan_query(
            q, self.n, stats=stats, fused_available=_fused_available()
        )
        plan.memo = "miss"
        lru[key] = plan
        while len(lru) > _PLAN_MEMO_CAP:
            lru.popitem(last=False)
        return plan

    # -- execution ---------------------------------------------------------
    def execute(self, query, *, backend: str | None = None,
                block_words: int | None = None) -> jax.Array:
        """Evaluate one expression; returns a packed (tail-masked) bitmap.

        With :mod:`repro.obs` enabled, each call produces a span tree
        (plan / compile / dispatch / decode) carrying the plan's predicted
        words next to the executor's measured words, and records one
        calibration-drift observation."""
        q = as_query(query)
        active = _trace.enabled or _obs.REGISTRY.enabled
        t0 = _time.perf_counter() if active else 0.0
        with _trace.span("execute") as root:
            plan = Plan(backend, "caller override") if backend else self.explain(q)
            out = self._mask(self._run(q, plan.algorithm, block_words))
            if active:
                self._observe(root, plan, self.last_info,
                              _time.perf_counter() - t0)
        return out

    def _observe(self, root, plan, info, wall_s: float) -> None:
        """Annotate the root span with predicted vs measured words and feed
        the drift metric (called with obs tracing or metrics enabled)."""
        measured = (
            info.get("words_touched") if isinstance(info, dict) else None
        )
        if _trace.enabled:
            root.set(
                backend=plan.algorithm,
                predicted_words=plan.cost,
                predicted_us=plan.cost_us,
                measured_words=measured,
            )
        _obs.record_drift(
            str(plan.algorithm), plan.cost,
            measured if measured is not None else 0, wall_s,
        )

    def execute_many(self, queries, *, backend: str | None = None,
                     block_words: int | None = None) -> list:
        """Evaluate independent queries; circuit-family ones are compiled
        into a single multi-output circuit.  On the tiled path every query
        shares ONE dirty-tile gather; on the dense path, one jitted call."""
        qs = [as_query(q) for q in queries]
        active = _trace.enabled or _obs.REGISTRY.enabled
        t0 = _time.perf_counter() if active else 0.0
        with _trace.span("execute_many", n_queries=len(qs)) as root:
            plans = [
                Plan(backend, "caller override") if backend else self.explain(q)
                for q in qs
            ]
            algs = [p.algorithm for p in plans]
            batch: list[int] = []
            # an explicit non-circuit backend override is honoured per query;
            # batching only applies when the circuit family does the work
            if backend is None or backend in CIRCUIT_BACKENDS:
                for i, (q, alg) in enumerate(zip(qs, algs)):
                    if alg in CIRCUIT_BACKENDS or (
                        alg in _BATCHABLE and self._bare_threshold(q) is not None
                    ):
                        batch.append(i)
            results: dict[int, jax.Array] = {}
            if len(batch) > 1:
                tiled = backend == "tiled_fused" or (
                    backend is None and all(algs[i] == "tiled_fused" for i in batch)
                )
                if tiled:
                    tdisp = _time.perf_counter() if active else 0.0
                    with _trace.span(
                        "dispatch", backend="tiled_fused", batched=len(batch)
                    ) as sp:
                        circ = self._circuit_for(tuple(qs[i] for i in batch))
                        stacked, info = run_tiled_circuit(
                            self.store, circ, block_words=block_words
                        )
                        if _trace.enabled:
                            _annotate_dispatch(sp, info)
                    self.last_info = info
                    if active:
                        # one drift sample for the shared gather: the batch's
                        # summed prediction vs the one realised gather
                        bc = [plans[i].cost for i in batch]
                        pred = (
                            sum(c for c in bc if c is not None)
                            if any(c is not None for c in bc) else None
                        )
                        _obs.record_drift(
                            "tiled_fused", pred, info["words_touched"],
                            _time.perf_counter() - tdisp,
                        )
                else:
                    cbackend = backend or ("fused" if _fused_available() else "circuit")
                    with _trace.span(
                        "dispatch", backend=cbackend, batched=len(batch)
                    ):
                        stacked = self._dense_eval(
                            tuple(qs[i] for i in batch), cbackend, block_words
                        )
                if stacked.ndim == 1:
                    stacked = stacked[None]
                for j, i in enumerate(batch):
                    results[i] = stacked[j]
            else:
                batch = []
            for i, (q, alg) in enumerate(zip(qs, algs)):
                if i not in results:
                    tq = _time.perf_counter() if active else 0.0
                    results[i] = self._run(q, alg, block_words)
                    if active:
                        inf = self.last_info
                        m = (
                            inf.get("words_touched")
                            if isinstance(inf, dict) else None
                        )
                        _obs.record_drift(
                            str(alg), plans[i].cost, m or 0,
                            _time.perf_counter() - tq,
                        )
            if _trace.enabled:
                costs = [p.cost for p in plans if p.cost is not None]
                info = self.last_info
                root.set(
                    backends=sorted(set(map(str, algs))),
                    predicted_words=sum(costs) if costs else None,
                    measured_words=(
                        info.get("words_touched")
                        if isinstance(info, dict) else None
                    ),
                )
        return [self._mask(results[i]) for i in range(len(qs))]

    def count(self, query, **kw) -> int:
        """Cardinality of the query result."""
        return int(cardinality(self.execute(query, **kw)))

    # -- internals ---------------------------------------------------------
    def _bare_slots(self, q: Query):
        """(member slots | None, t) when q is a bare threshold, else None."""
        return bare_slots(q, self._slot)

    def _bare_threshold(self, q: Query):
        """(rows, t) when q is a Threshold over plain columns, else None."""
        bare = self._bare_slots(q)
        if bare is None:
            return None
        slots, t = bare
        rows = self.columns
        if slots is not None:
            rows = rows[jnp.asarray(slots)]
        return rows, t

    def _shard_ctx(self, q: Query, block_words) -> ShardContext:
        """This index's whole row space as one executor shard."""
        return ShardContext(
            n=self.n,
            dense=lambda: self.columns,
            store=lambda: self.store,
            circuit=lambda: self._circuit_for((q,)),
            bare=self._bare_slots(q),
            column=self._slot[q.name] if type(q) is Col else None,
            block_words=block_words,
        )

    def _run(self, q: Query, alg: str, block_words) -> jax.Array:
        try:
            with _trace.span("dispatch", backend=alg) as sp:
                out, info = run_plan(self._shard_ctx(q, block_words), alg)
                if _trace.enabled and isinstance(info, dict):
                    _annotate_dispatch(sp, info)
        except ValueError as e:
            if "only executes bare Threshold" in str(e):
                raise ValueError(
                    f"backend {alg!r} only executes bare Threshold queries; "
                    f"use 'circuit', 'fused' or 'tiled_fused' for {type(q).__name__}"
                ) from None
            raise
        if info is not None:
            self.last_info = info
        return out

    def _circuit_for(self, qs: tuple):
        """The (cached) multi-output circuit compiling ``qs`` over this schema."""
        return circuit_for(qs, self.n, self._names)

    def _dense_eval(self, qs: tuple, backend: str, block_words) -> jax.Array:
        """Compile ``qs`` and evaluate over the dense column view."""
        from repro.kernels.threshold_ssum import INTERPRET, run_circuit_cached

        return run_circuit_cached(
            self.columns,
            self._circuit_for(qs),
            block_words=block_words,
            interpret=INTERPRET,
            pallas=backend == "fused",
        )

    def _mask(self, out: jax.Array) -> jax.Array:
        mask = packed_tail_mask(self.r, self.n_words)
        return out if mask is None else jnp.bitwise_and(out, mask)


def execute(bitmaps, query, *, r: int | None = None, backend: str | None = None,
            block_words: int | None = None) -> jax.Array:
    """One-shot functional form: execute ``query`` over packed bitmaps.

    Builds a transient default-named :class:`BitmapIndex` (so the data gets
    tile-classified and the planner routes clean-heavy inputs through the
    tiled path); the compiled cache is keyed by schema, so repeated calls
    with the same shape reuse compilations.  Kept as the substrate for the
    legacy free-function shims (``core.threshold.threshold`` etc.).
    """
    idx = BitmapIndex(bitmaps, r=r)
    return idx.execute(query, backend=backend, block_words=block_words)
