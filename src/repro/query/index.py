"""`BitmapIndex`: packed columns + statistics + planner-driven execution.

The index owns the data (``uint32[N, n_words]``, one row per named column),
its statistics (per-column density, clean-tile fraction, cardinality --
index-build-time work, computed on request by :meth:`BitmapIndex.stats` and
then consulted by the planner), and execution:

  * :meth:`execute` plans a query expression (``core.planner.plan_query``)
    and routes it -- bare thresholds to the specialised backends, everything
    else through ONE compiled circuit;
  * :meth:`execute_many` compiles independent circuit-family queries into a
    single multi-output circuit evaluated in one jitted call;
  * results are packed bitmaps (tail-masked to the universe size), so they
    can be fed back in as virtual columns with :meth:`add_column` -- the
    paper's "the result ... can be further processed within a bitmap index".

Compiled circuits and their jitted evaluators live in a per-process cache
keyed by (query shape, column names, backend, block size); data never enters
the key, so every index with the same schema shares the cache.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitmaps import WORD_DTYPE, cardinality, pack, tail_mask
from repro.core.planner import CIRCUIT_BACKENDS, Plan, plan_query

from .compile import build_query_circuit
from .expr import Col, Query, Threshold, as_query
from .executors import THRESHOLD_BACKENDS, run_threshold_backend

__all__ = [
    "BitmapIndex",
    "IndexStats",
    "execute",
    "compiled_cache_info",
    "clear_compiled_cache",
]

# ---------------------------------------------------------------------------
# Per-process compiled-circuit / jit cache
# ---------------------------------------------------------------------------

_COMPILED: dict[tuple, object] = {}
_CACHE_INFO = {"hits": 0, "misses": 0}

# bare thresholds whose backend is itself a circuit join multi-query batches
_BATCHABLE = CIRCUIT_BACKENDS + ("ssum", "treeadd", "srtckt", "sopckt")


def compiled_cache_info() -> dict:
    """Hits/misses/size of the per-process compiled-circuit cache."""
    return {"size": len(_COMPILED), **_CACHE_INFO}


def clear_compiled_cache() -> None:
    _COMPILED.clear()
    _CACHE_INFO["hits"] = 0
    _CACHE_INFO["misses"] = 0


def _fused_available() -> bool:
    return jax.default_backend() == "tpu"


# ---------------------------------------------------------------------------
# Statistics
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class IndexStats:
    """Cheap per-index statistics feeding the planner's decision rules."""

    n: int
    n_words: int
    r: int
    cardinalities: tuple
    densities: tuple
    density: float  # mean over columns
    clean_fraction: float  # fraction of (column, tile) pairs that are runs
    tile_words: int


# ---------------------------------------------------------------------------
# The index
# ---------------------------------------------------------------------------


class BitmapIndex:
    """A queryable collection of named packed bitmaps over one universe."""

    def __init__(self, columns, names=None, *, r: int | None = None):
        cols = jnp.asarray(columns, WORD_DTYPE)
        if cols.ndim != 2:
            raise ValueError(f"expected uint32[N, n_words], got shape {cols.shape}")
        n, n_words = cols.shape
        if names is None:
            names = tuple(f"c{i}" for i in range(n))
        else:
            names = tuple(str(x) for x in names)
            if len(names) != n:
                raise ValueError(f"{len(names)} names for {n} columns")
            if len(set(names)) != n:
                raise ValueError("duplicate column names")
        self._columns = cols
        self._names = names
        self._slot = {name: i for i, name in enumerate(names)}
        self.r = int(r) if r is not None else n_words * 32
        if self.r > n_words * 32 or self.r <= 0:
            raise ValueError(f"universe size {self.r} does not fit {n_words} words")
        self._stats: IndexStats | None = None

    # -- construction ------------------------------------------------------
    @classmethod
    def from_dense(cls, bits, names=None) -> "BitmapIndex":
        """Build from a dense boolean/int array [N, r]."""
        bits = jnp.asarray(bits)
        return cls(pack(bits), names, r=bits.shape[-1])

    @classmethod
    def from_columns(cls, columns: dict, *, r: int | None = None) -> "BitmapIndex":
        """Build from a {name: packed uint32[n_words]} mapping."""
        if not columns:
            raise ValueError("need at least one column")
        names = tuple(columns)
        stacked = jnp.stack([jnp.asarray(columns[k], WORD_DTYPE) for k in names])
        return cls(stacked, names, r=r)

    # -- basic accessors ---------------------------------------------------
    @property
    def columns(self) -> jax.Array:
        return self._columns

    @property
    def names(self) -> tuple:
        return self._names

    @property
    def n(self) -> int:
        return self._columns.shape[0]

    @property
    def n_words(self) -> int:
        return self._columns.shape[1]

    def __len__(self) -> int:
        return self.n

    def __contains__(self, name: str) -> bool:
        return name in self._slot

    def __getitem__(self, name: str) -> Col:
        """Sugar: ``idx["a"] & ~idx["b"]`` builds an expression."""
        if name not in self._slot:
            raise KeyError(f"unknown column {name!r}")
        return Col(name)

    def column(self, name: str) -> jax.Array:
        if name not in self._slot:
            raise KeyError(
                f"unknown column {name!r}; index has {sorted(self._slot)[:8]}..."
            )
        return self._columns[self._slot[name]]

    def add_column(self, name: str, packed) -> "BitmapIndex":
        """Append a (virtual) column -- e.g. a previous query result."""
        if name in self._slot:
            raise ValueError(f"column {name!r} already exists")
        row = jnp.asarray(packed, WORD_DTYPE)
        if row.shape != (self.n_words,):
            raise ValueError(f"expected shape ({self.n_words},), got {row.shape}")
        self._columns = jnp.concatenate([self._columns, row[None]], axis=0)
        self._names = self._names + (name,)
        self._slot[name] = len(self._names) - 1
        self._stats = None
        return self

    # -- statistics --------------------------------------------------------
    def stats(self, tile_words: int = 64, refresh: bool = False) -> IndexStats:
        """Compute (and cache) planner statistics.

        This is index-build-time work (one host pass over the data); the
        planner only uses data-aware rules (RBMRG, DSK) after it has run.
        """
        if self._stats is not None and not refresh:
            return self._stats
        from repro.core.blockrle import classify_tiles

        cards = tuple(int(x) for x in np.asarray(cardinality(self._columns)))
        dens = tuple(c / self.r for c in cards)
        stats = classify_tiles(self._columns, tile_words=tile_words)
        self._stats = IndexStats(
            n=self.n,
            n_words=self.n_words,
            r=self.r,
            cardinalities=cards,
            densities=dens,
            density=float(np.mean(dens)) if dens else 0.0,
            clean_fraction=stats.clean_fraction,
            tile_words=tile_words,
        )
        return self._stats

    # -- planning ----------------------------------------------------------
    def explain(self, query) -> Plan:
        """The plan :meth:`execute` would run (stats-aware once computed)."""
        st = self._stats
        return plan_query(
            as_query(query),
            self.n,
            density=st.density if st else None,
            clean_fraction=st.clean_fraction if st else None,
            fused_available=_fused_available(),
        )

    # -- execution ---------------------------------------------------------
    def execute(self, query, *, backend: str | None = None,
                block_words: int | None = None) -> jax.Array:
        """Evaluate one expression; returns a packed (tail-masked) bitmap."""
        q = as_query(query)
        plan = Plan(backend, "caller override") if backend else self.explain(q)
        return self._mask(self._run(q, plan.algorithm, block_words))

    def execute_many(self, queries, *, backend: str | None = None,
                     block_words: int | None = None) -> list:
        """Evaluate independent queries; circuit-family ones are compiled
        into a single multi-output circuit and run as ONE jitted call."""
        qs = [as_query(q) for q in queries]
        algs = [backend or self.explain(q).algorithm for q in qs]
        batch: list[int] = []
        # an explicit non-circuit backend override is honoured per query;
        # batching only applies when the circuit family does the work
        if backend is None or backend in CIRCUIT_BACKENDS:
            for i, (q, alg) in enumerate(zip(qs, algs)):
                if alg in CIRCUIT_BACKENDS or (
                    alg in _BATCHABLE and self._bare_threshold(q) is not None
                ):
                    batch.append(i)
        results: dict[int, jax.Array] = {}
        if len(batch) > 1:
            cbackend = backend or ("fused" if _fused_available() else "circuit")
            fn = self._compiled(tuple(qs[i] for i in batch), cbackend, block_words)
            stacked = fn(self._columns)
            if stacked.ndim == 1:
                stacked = stacked[None]
            for j, i in enumerate(batch):
                results[i] = stacked[j]
        else:
            batch = []
        for i, (q, alg) in enumerate(zip(qs, algs)):
            if i not in results:
                results[i] = self._run(q, alg, block_words)
        return [self._mask(results[i]) for i in range(len(qs))]

    def count(self, query, **kw) -> int:
        """Cardinality of the query result."""
        return int(cardinality(self.execute(query, **kw)))

    # -- internals ---------------------------------------------------------
    def _bare_threshold(self, q: Query):
        """(rows, t) when q is a Threshold over plain columns, else None."""
        if type(q) is not Threshold:
            return None
        if q.over is None:
            return self._columns, q.t
        if not all(type(m) is Col for m in q.over):
            return None
        for m in q.over:
            if m.name not in self._slot:
                raise KeyError(
                    f"unknown column {m.name!r}; index has {sorted(self._slot)[:8]}..."
                )
        slots = [self._slot[m.name] for m in q.over]
        return self._columns[jnp.asarray(slots)], q.t

    def _run(self, q: Query, alg: str, block_words) -> jax.Array:
        if alg == "column":
            return self.column(q.name)
        if alg in THRESHOLD_BACKENDS:
            bare = self._bare_threshold(q)
            if bare is None:
                if alg in CIRCUIT_BACKENDS:  # "fused" doubles as both
                    return self._compiled((q,), alg, block_words)(self._columns)
                raise ValueError(
                    f"backend {alg!r} only executes bare Threshold queries; "
                    f"use 'circuit' or 'fused' for {type(q).__name__}"
                )
            rows, t = bare
            return run_threshold_backend(rows, t, alg, block_words=block_words)
        if alg in CIRCUIT_BACKENDS:
            return self._compiled((q,), alg, block_words)(self._columns)
        raise ValueError(f"unknown backend {alg!r}")

    def _compiled(self, qs: tuple, backend: str, block_words):
        key = (tuple(q.key() for q in qs), self._names, backend, block_words)
        fn = _COMPILED.get(key)
        if fn is not None:
            _CACHE_INFO["hits"] += 1
            return fn
        _CACHE_INFO["misses"] += 1
        circ = build_query_circuit(qs, self.n, self._names)
        if backend == "fused":
            from repro.kernels.threshold_ssum import INTERPRET, run_circuit_pallas

            def run(bm, _c=circ):
                return run_circuit_pallas(
                    bm, _c, block_words=block_words, interpret=INTERPRET
                )

        else:

            def run(bm, _c=circ):
                outs = _c.evaluate([bm[i] for i in range(bm.shape[0])])
                return outs[0] if len(outs) == 1 else jnp.stack(outs)

        fn = jax.jit(run)
        _COMPILED[key] = fn
        return fn

    def _mask(self, out: jax.Array) -> jax.Array:
        if self.r >= self.n_words * 32:
            return out
        mask = np.zeros(self.n_words, dtype=np.uint32)
        full = self.r // 32
        mask[:full] = 0xFFFFFFFF
        if self.r % 32:
            mask[full] = tail_mask(self.r)
        return jnp.bitwise_and(out, jnp.asarray(mask))


def execute(bitmaps, query, *, r: int | None = None, backend: str | None = None,
            block_words: int | None = None) -> jax.Array:
    """One-shot functional form: execute ``query`` over packed bitmaps.

    Builds a transient default-named :class:`BitmapIndex`; the compiled
    cache is keyed by schema, so repeated calls with the same shape reuse
    compilations.  Kept as the substrate for the legacy free-function shims
    (``core.threshold.threshold`` etc.).
    """
    idx = BitmapIndex(bitmaps, r=r)
    return idx.execute(query, backend=backend, block_words=block_words)
