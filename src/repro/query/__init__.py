"""Declarative, composable queries over a bitmap index.

The paper's closing observation -- "the result of our computation is again a
bitmap which can be further processed within a bitmap index" -- promoted to
the API: queries are expression trees built from symmetric-function leaves
(:class:`Threshold`, :class:`Interval`, :class:`Exactly`, :class:`Parity`,
:class:`Majority`, :class:`Weighted`, :class:`Sym`), named columns
(:class:`Col`), and boolean combinators (:class:`And`, :class:`Or`,
:class:`Not`, :class:`AndNot`), executed against a :class:`BitmapIndex`::

    idx = BitmapIndex.from_dense(on_sale, names=store_names)
    hot = idx.execute(And(Interval(2, 10), Not(Threshold(15))))

Execution is planner-driven (``core.planner``): a whole expression tree
compiles into ONE shared Boolean circuit (sub-queries share the sideways-sum
adder via CSE) evaluated by XLA, the fused Pallas kernel, or -- when the
member columns' tile statistics favour skipping -- the storage engine's
``tiled_fused`` executor (``repro.storage``), which resolves clean tiles as
constants before launch and gathers only dirty tiles.  Bare thresholds
route to the specialised backends (wide OR/AND, LOOPED, streaming
scancount, block-RLE pruning, host list algorithms) the paper recommends.
The index itself wraps a :class:`repro.storage.TileStore`, so statistics
exist from the moment it is built.  Compiled circuits and their jitted
evaluators live in a per-process cache keyed by (query shape, column
names, backend, block size).
"""

from .expr import (
    And,
    AndNot,
    Col,
    Exactly,
    Interval,
    Majority,
    Not,
    Or,
    Parity,
    Query,
    Sym,
    Threshold,
    Weighted,
    bind_members,
    canonical_key,
    column_refs,
)
from .compile import build_query_circuit
from .executors import (
    THRESHOLD_BACKENDS,
    ShardContext,
    run_plan,
    run_threshold_backend,
)
from .index import (
    BitmapIndex,
    IndexStats,
    circuit_for,
    clear_compiled_cache,
    compiled_cache_info,
    execute,
    plan_memo_info,
)

__all__ = [
    "Query",
    "Col",
    "Threshold",
    "Interval",
    "Exactly",
    "Parity",
    "Majority",
    "Weighted",
    "Sym",
    "And",
    "Or",
    "Not",
    "AndNot",
    "BitmapIndex",
    "IndexStats",
    "execute",
    "circuit_for",
    "build_query_circuit",
    "run_plan",
    "ShardContext",
    "run_threshold_backend",
    "THRESHOLD_BACKENDS",
    "compiled_cache_info",
    "clear_compiled_cache",
    "plan_memo_info",
    "bind_members",
    "canonical_key",
    "column_refs",
]
