"""`StreamingIndex`: an updatable bitmap index with incrementally-maintained
query results.

The paper's headline property -- a threshold/symmetric result *is again a
bitmap which can be further processed within a bitmap index* -- only pays
off in a serving system if the index absorbs writes without rebuilds.
``StreamingIndex`` wraps an immutable :class:`~repro.query.BitmapIndex`
(or a :class:`~repro.dist.query.ShardedBitmapIndex`) and adds:

  * **mutations**: ``set_bits`` / ``clear_bits`` / batched ``update`` /
    row-space ``append_rows`` accumulate in per-shard
    :class:`~repro.stream.delta.DeltaStore` buffers -- the base store is
    never touched, so every stale reference keeps working;
  * **overlay reads**: queries run against an
    :class:`~repro.stream.overlay.OverlayStore` view, so every planner
    backend answers ``base ⊕ delta`` bit-identically to a from-scratch
    rebuild (the oracle property ``tests/test_stream.py`` enforces for
    every ``ALGORITHMS`` entry);
  * **tile-granular compaction**: :meth:`compact` folds the delta into a
    new base via ``TileStore.apply_tile_updates`` -- only touched tiles
    reclassify, cardinality moves by popcount deltas -- auto-triggered by
    a :class:`CompactionPolicy` size/ratio threshold;
  * **materialized views**: :meth:`materialize` registers a query whose
    result lives as a real index column, refreshed by re-running its
    support-specialised compiled circuit (``circuit_for`` +
    ``Circuit.specialize``, both process-cached) ONLY over tiles whose
    input columns changed, with counts maintained by per-tile popcount
    deltas.  ``view_info(name)["words_touched"]`` reports the refresh
    work, asserted in tests to scale with the mutation, not the universe.

Under a sharded base, every mutation routes to the owning row shard's
delta, refresh and compaction run per shard, and nothing ever gathers.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.bitmaps import cardinality
from repro.obs import REGISTRY as _OBS
from repro.query.expr import Col, Query, as_query, bind_members
from repro.query.index import BitmapIndex, circuit_for

from .delta import DeltaStore, base_tile_batch
from .overlay import OverlayStore

__all__ = ["CompactionPolicy", "MaterializedView", "StreamingIndex"]

# Streaming-path accounting on the process-wide registry (no-ops until
# ``repro.obs.enable()``).  Mutation batches, view refresh work and
# compactions are the three knobs the overlay cost story turns on.
_MUTATIONS = _OBS.counter(
    "repro_stream_mutations_total", "Mutation batches applied", ("kind",),
)
_MUTATED_POSITIONS = _OBS.counter(
    "repro_stream_mutated_positions_total", "Individual bit mutations applied",
)
_REFRESHES = _OBS.counter(
    "repro_stream_view_refreshes_total", "Materialized-view tile refreshes",
)
_REFRESH_WORDS = _OBS.counter(
    "repro_stream_view_refresh_words_total",
    "Words touched refreshing materialized views",
)
_COMPACTIONS = _OBS.counter(
    "repro_stream_compactions_total", "Delta-into-base compactions",
)
_COMPACTED_WORDS = _OBS.histogram(
    "repro_stream_compaction_delta_words", "Delta words folded per compaction",
)


@dataclasses.dataclass(frozen=True)
class CompactionPolicy:
    """When :meth:`StreamingIndex.compact` fires automatically.

    The delta is folded into the base once its buffered words exceed
    ``max(min_delta_words, max_delta_ratio * base_working_set)`` where the
    base working set is the base store's dirty words plus one output pass
    -- i.e. compaction triggers when overlay bookkeeping starts to rival
    the work a query actually does.  ``auto=False`` leaves compaction
    fully manual.
    """

    min_delta_words: int = 4096
    max_delta_ratio: float = 0.25
    auto: bool = True

    def should_compact(self, delta_words: int, base_words: int) -> bool:
        if delta_words <= 0:
            return False
        return delta_words >= max(
            self.min_delta_words, self.max_delta_ratio * base_words
        )


@dataclasses.dataclass
class MaterializedView:
    """A registered query kept fresh as a real index column."""

    name: str
    query: Query
    slot: int
    support: frozenset  # column slots the compiled circuit actually reads
    cardinality: int
    #: support-order input slots + the circuit specialised to them (every
    #: non-support input folded to CONST0) -- the refresh evaluator
    kept: tuple = ()
    residual: object = None  # None when the query folded to a constant
    const: int | None = None  # that constant, when it did
    pending: set = dataclasses.field(default_factory=set)  # global tile ids
    last_refresh_info: dict | None = None


class StreamingIndex:
    """An updatable view over a (Sharded)BitmapIndex plus delta buffers."""

    def __init__(self, index, *, policy: CompactionPolicy | None = None,
                 durable_dir=None):
        from repro.dist.query import ShardedBitmapIndex

        self.policy = policy or CompactionPolicy()
        self._sharded = isinstance(index, ShardedBitmapIndex)
        self._base = index
        self._names = tuple(index.names)
        self._slot = {name: i for i, name in enumerate(self._names)}
        self._views: dict[str, MaterializedView] = {}
        self._version = 0
        self._overlay_cache: tuple | None = None  # (version, index)
        self.compactions = 0
        #: per-column mutation versions: the index version at which each
        #: column's *contents* last changed (compaction bumps the index
        #: version but changes no contents, so column versions hold still).
        #: A materialized view's version bumps when any support column is
        #: mutated -- at mutation time, not at its lazy refresh -- so a
        #: version vector read after a bump never covers stale view bits.
        self._col_versions: dict[str, int] = {n: 0 for n in self._names}
        #: invalidation subscribers: fn(version, frozenset[column names])
        #: called once per mutation batch with every column whose contents
        #: changed (views cascaded).  The serving result cache tier hangs
        #: its invalidation off this.
        self._subscribers: list = []
        #: durability state: a WAL every mutation batch appends to before
        #: applying, plus the directory checkpoints land in.  ``None``
        #: keeps the index purely in-memory (the default).
        self._wal = None
        self._dir = None
        self._replaying = False  # True while recover() re-applies the log
        self._reset_deltas()
        if durable_dir is not None:
            self.attach_durable(durable_dir)

    def attach_durable(self, path) -> None:
        """Start logging every mutation batch to ``path/wal.bmwal``.

        A directory with no checkpoint yet gets one immediately, so
        recovery always has a base snapshot to replay the WAL against."""
        from pathlib import Path

        from repro.persist.wal import WriteAheadLog

        self._dir = Path(path)
        self._dir.mkdir(parents=True, exist_ok=True)
        self._wal = WriteAheadLog(self._dir / "wal.bmwal")
        if not (self._dir / "index.json").exists():
            self.checkpoint()

    # -- construction ------------------------------------------------------
    @classmethod
    def from_dense(cls, bits, names=None, *, tile_words: int = 64,
                   policy: CompactionPolicy | None = None) -> "StreamingIndex":
        return cls(BitmapIndex.from_dense(bits, names, tile_words=tile_words),
                   policy=policy)

    @classmethod
    def from_columns(cls, columns: dict, *, r: int | None = None,
                     tile_words: int = 64,
                     policy: CompactionPolicy | None = None) -> "StreamingIndex":
        return cls(
            BitmapIndex.from_columns(columns, r=r, tile_words=tile_words),
            policy=policy,
        )

    def _reset_deltas(self) -> None:
        if self._sharded:
            self._deltas = [DeltaStore(s) for s in self._base.store.shards]
        else:
            self._deltas = [DeltaStore(self._base.store)]

    # -- accessors ---------------------------------------------------------
    @property
    def names(self) -> tuple:
        return self._names

    @property
    def n(self) -> int:
        return len(self._names)

    @property
    def is_sharded(self) -> bool:
        return self._sharded

    @property
    def tile_words(self) -> int:
        return self._deltas[0].tile_words

    @property
    def r(self) -> int:
        if self._sharded:
            return self._bit_offsets()[-1] + self._deltas[-1].r
        return self._deltas[0].r

    @property
    def delta_words(self) -> int:
        return sum(d.delta_words for d in self._deltas)

    @property
    def views(self) -> tuple:
        return tuple(self._views)

    def __contains__(self, name: str) -> bool:
        return name in self._slot

    def __getitem__(self, name: str) -> Col:
        if name not in self._slot:
            raise KeyError(f"unknown column {name!r}")
        return Col(name)

    def delta_stats(self) -> dict:
        return {
            "patched_tiles": sum(d.patched_tiles for d in self._deltas),
            "delta_words": self.delta_words,
            "compactions": self.compactions,
            "pending_view_tiles": sum(len(v.pending) for v in self._views.values()),
        }

    # -- shard routing -----------------------------------------------------
    def _bit_offsets(self) -> list:
        if not self._sharded:
            return [0]
        return [w * 32 for w in self._base.store.word_offsets]

    def _tile_offsets(self) -> list:
        """Global tile id of each shard's first tile (growth-aware)."""
        offs, t0 = [], 0
        for d in self._deltas:
            offs.append(t0)
            t0 += d.n_tiles
        return offs

    def _route_index(self, pos: np.ndarray) -> list:
        """[(shard, selector into the batch)] for global bit positions."""
        if not self._sharded:
            return [(0, np.arange(pos.size))]
        offs = np.asarray(self._bit_offsets())
        shard_of = np.searchsorted(offs, pos, side="right") - 1
        return [
            (int(s), np.nonzero(shard_of == s)[0])
            for s in np.unique(shard_of).tolist()
        ]

    # -- mutations ---------------------------------------------------------
    def _data_slot(self, name: str) -> int:
        if name not in self._slot:
            raise KeyError(f"unknown column {name!r}; index has {sorted(self._slot)[:8]}...")
        if name in self._views:
            raise ValueError(
                f"column {name!r} is a materialized view; mutate its inputs instead"
            )
        return self._slot[name]

    def set_bits(self, name: str, positions) -> None:
        self.update(sets={name: positions})

    def clear_bits(self, name: str, positions) -> None:
        self.update(clears={name: positions})

    def update(self, sets: dict | None = None, clears: dict | None = None) -> None:
        """Apply a batch of set/clear mutations as ONE index update (one
        version bump, one auto-compaction check) -- the serving engine's
        per-step path.  The whole batch flattens into a single vectorised
        ``DeltaStore.apply_batch`` per owning shard; set masks apply before
        clear masks."""
        parts = []  # (slot, positions, on)
        for mapping, on in ((sets, True), (clears, False)):
            for name, positions in (mapping or {}).items():
                slot = self._data_slot(name)
                pos = np.atleast_1d(np.asarray(positions, dtype=np.int64))
                if pos.size:
                    parts.append((slot, pos, on))
        if not parts:
            return
        sizes = [p.size for _, p, _ in parts]
        cols = np.repeat(np.asarray([s for s, _, _ in parts], np.int64), sizes)
        pos = np.concatenate([p for _, p, _ in parts])
        on = np.repeat(np.asarray([o for _, _, o in parts], bool), sizes)
        if self._wal is not None and not self._replaying:
            self._wal.append_update(cols, pos, on)
        self._apply_update_arrays(cols, pos, on)

    def _apply_update_arrays(self, cols: np.ndarray, pos: np.ndarray,
                             on: np.ndarray) -> None:
        """Route one validated (cols, pos, on) batch to the owning shards
        -- the shared tail of :meth:`update` and WAL replay."""
        if _OBS.enabled:
            _MUTATIONS.inc(1, kind="update")
            _MUTATED_POSITIONS.inc(int(pos.size))
        touched: dict[int, set] = {}
        toffs = self._tile_offsets()
        boffs = self._bit_offsets()
        for shard, sel in self._route_index(pos):
            per_col = self._deltas[shard].apply_batch(
                cols[sel], pos[sel] - boffs[shard], on[sel]
            )
            for slot, tiles in per_col.items():
                touched.setdefault(slot, set()).update(
                    toffs[shard] + t for t in tiles
                )
        if touched:
            self._after_mutation(touched)

    def append_rows(self, bits) -> tuple:
        """Append new row positions (products) to the universe: dense bool
        ``[n_data_columns, k]`` in column-name order (materialized views
        excluded -- their appended bits are computed, not supplied), or a
        ``{name: bits}`` mapping (absent columns default to all-zero).
        Under sharding the appended range extends the LAST shard -- no
        resharding, no gather.  Returns the appended global row range
        ``(start, stop)`` so callers (``repro.search`` record appends,
        windowed event streams) can address the new rows."""
        start = self.r
        data_slots = [
            i for i, nm in enumerate(self._names) if nm not in self._views
        ]
        if isinstance(bits, dict):
            k = None
            for v in bits.values():
                k = np.atleast_1d(np.asarray(v)).shape[-1]
                break
            if k is None:
                return (start, start)
            arr = np.zeros((self.n, k), bool)
            for name, row in bits.items():
                arr[self._data_slot(name)] = np.asarray(row, bool)
        else:
            given = np.asarray(bits, bool)
            if given.ndim != 2 or given.shape[0] != len(data_slots):
                raise ValueError(
                    f"expected bool[{len(data_slots)}, k] over the data "
                    f"columns, got {given.shape}"
                )
            arr = np.zeros((self.n, given.shape[1]), bool)
            arr[data_slots] = given
        if self._wal is not None and not self._replaying:
            # log only the data-column rows: the view columns' appended
            # bits are recomputed on replay exactly like they were live
            self._wal.append_rows(arr[data_slots])
        if _OBS.enabled:
            _MUTATIONS.inc(1, kind="append")
            _MUTATED_POSITIONS.inc(int(arr.sum()))
        toffs = self._tile_offsets()
        shard = len(self._deltas) - 1
        tiles = self._deltas[shard].append_rows(arr)
        gtiles = {toffs[shard] + t for t in tiles}
        # every column's consumers see the appended range change -- and so
        # does EVERY view, support or not: a view whose query folded to a
        # constant (empty circuit support) still owes its constant over the
        # new rows
        self._after_mutation(
            {slot: set(gtiles) for slot in range(self.n)}, appended=gtiles
        )
        return (start, start + arr.shape[1])

    def add_data_column(self, name: str, packed=None) -> None:
        """Grow the schema with a new data column (default all-zero).

        Token vocabularies grow as records append (``repro.search``: a new
        string brings never-seen q-grams), so the column axis must be able
        to grow without a rebuild, exactly like the row axis.  The delta is
        compacted first -- column growth lands in the base store, whose
        ``add_column`` shares every untouched column's storage -- and only
        the new column is classified.  Refused on a durable index: the WAL
        format has no schema-growth record, so replay could not reproduce
        the column (checkpoint-then-recover would silently diverge).
        """
        if name in self._slot:
            raise ValueError(f"column {name!r} already exists")
        if self._wal is not None:
            raise RuntimeError(
                "add_data_column is not supported on a durable index: the "
                "WAL cannot replay schema growth; checkpoint into a fresh "
                "index instead"
            )
        self.refresh()
        self.compact(force=True)
        if packed is None:
            packed = np.zeros(self._base.n_words, np.uint32)
        _MUTATIONS.inc(1, kind="add_column")
        self._base = self._base.add_column(name, packed)
        self._names = tuple(self._base.names)
        self._slot = {n: i for i, n in enumerate(self._names)}
        self._reset_deltas()
        self._overlay_cache = None
        self._version += 1
        self._col_versions[name] = self._version
        self._notify(frozenset((name,)))

    def _after_mutation(self, touched: dict, appended: set | None = None) -> None:
        self._version += 1
        for view in self._views.values():
            for slot, tiles in touched.items():
                if slot in view.support:
                    view.pending.update(tiles)
            if appended:
                view.pending.update(appended)
        # column-version bookkeeping + invalidation fan-out: the mutated
        # columns change now, and every view (transitively) reading one of
        # them WILL change at its next refresh -- bump both at mutation
        # time so version vectors read later are never stale
        changed = set(touched)
        for _ in range(len(self._views) + 1):
            grew = {
                v.slot
                for v in self._views.values()
                if v.slot not in changed and (appended or v.support & changed)
            }
            if not grew:
                break
            changed |= grew
        for slot in changed:
            self._col_versions[self._names[slot]] = self._version
        self._notify(frozenset(self._names[s] for s in changed))
        if self.policy.auto:
            base_words = self._base_working_words()
            if self.policy.should_compact(self.delta_words, base_words):
                self.compact()

    # -- version / invalidation surface ------------------------------------
    @property
    def version(self) -> int:
        """Monotone index version (one bump per mutation batch / refresh /
        compaction)."""
        return self._version

    @property
    def column_versions(self) -> dict:
        """{name: version its contents last changed} -- the serving cache
        tier's key material."""
        return dict(self._col_versions)

    def column_version(self, name: str) -> int:
        if name not in self._slot:
            raise KeyError(f"unknown column {name!r}")
        return self._col_versions.get(name, 0)

    def subscribe(self, fn) -> None:
        """Register ``fn(version, touched_names)`` to run after every
        mutation batch; ``touched_names`` is a frozenset of every column
        whose contents changed, materialized views cascaded in."""
        self._subscribers.append(fn)

    def unsubscribe(self, fn) -> None:
        self._subscribers.remove(fn)

    def _notify(self, names: frozenset) -> None:
        if not names:
            return
        for fn in list(self._subscribers):
            fn(self._version, names)

    def _base_working_words(self) -> int:
        if self._sharded:
            return sum(s.dirty_words + s.n_words for s in self._base.store.shards)
        return self._base.store.dirty_words + self._base.store.n_words

    # -- overlay read path -------------------------------------------------
    def index(self):
        """The queryable (Sharded)BitmapIndex over ``base ⊕ delta``, with
        every materialized view refreshed.  Cached per mutation version."""
        self.refresh()
        return self._overlay_index()

    def _overlay_index(self):
        if all(d.empty for d in self._deltas):
            return self._base
        if self._overlay_cache is not None and self._overlay_cache[0] == self._version:
            return self._overlay_cache[1]
        if self._sharded:
            from repro.dist.query import ShardedBitmapIndex

            shards = tuple(
                s if d.empty else OverlayStore(s, d)
                for s, d in zip(self._base.store.shards, self._deltas)
            )
            idx = ShardedBitmapIndex(
                self._base.store.with_shards(shards), self._names
            )
        else:
            idx = BitmapIndex(
                names=self._names,
                _store=OverlayStore(self._base.store, self._deltas[0]),
            )
        self._overlay_cache = (self._version, idx)
        return idx

    # -- queries -----------------------------------------------------------
    def execute(self, query, **kw):
        return self.index().execute(query, **kw)

    def execute_many(self, queries, **kw):
        return self.index().execute_many(queries, **kw)

    def explain(self, query):
        """The plan (unsharded) or per-shard plans (sharded) the next
        execute would run, computed from the OVERLAID statistics."""
        idx = self.index()
        return idx.plan(query) if self._sharded else idx.explain(query)

    def column(self, name: str):
        return self.index().column(name)

    def count(self, query) -> int:
        """Result cardinality; a bare view column reads the incrementally
        maintained count -- no execution, no popcount."""
        q = as_query(query)
        if type(q) is Col and q.name in self._views:
            self.refresh()
            return self._views[q.name].cardinality
        idx = self.index()
        return int(idx.count(q))

    # -- materialized views ------------------------------------------------
    def materialize(self, name: str, query) -> MaterializedView:
        """Register ``query`` as a maintained result column ``name``.

        The result is computed once and added as a real column of the base
        index (the delta is compacted first so the new column's tile
        classification lands in the base).  From then on, every mutation of
        a column in the query's support marks the touched tiles, and the
        next read refreshes ONLY those tiles by re-running the compiled
        circuit over them.
        """
        if name in self._slot:
            raise ValueError(f"column {name!r} already exists")
        # implicit "all columns" member sets bind to the columns of NOW:
        # the view must keep meaning what it meant when registered, even
        # after more (view) columns join the schema
        q = bind_members(as_query(query), self._names)
        _MUTATIONS.inc(1, kind="materialize")
        if self._wal is not None and not self._replaying:
            self._wal.append_materialize(name, q)
        self.refresh()
        self.compact(force=True)
        res = self._base.execute(q)
        if self._sharded:
            card = sum(int(cardinality(s)) for s in res.shards)
        else:
            card = int(cardinality(res))
        self._base = self._base.add_column(name, res)
        self._names = tuple(self._base.names)
        self._slot = {n: i for i, n in enumerate(self._names)}
        self._reset_deltas()
        circ = circuit_for((q,), self.n, self._names)
        support = circ.support()
        from repro.core.circuits import CONST0

        const, residual, kept = circ.specialize(
            {i: CONST0 for i in range(self.n) if i not in support}
        )
        view = MaterializedView(
            name=name,
            query=q,
            slot=self._slot[name],
            support=frozenset(support),
            cardinality=card,
            kept=tuple(kept),
            residual=residual,
            const=const[0],
        )
        self._views[name] = view
        self._version += 1
        self._col_versions[name] = self._version  # the column just appeared
        self._notify(frozenset((name,)))
        return view

    def view_info(self, name: str) -> dict | None:
        """tiles_refreshed / words_touched accounting of the last refresh."""
        return self._views[name].last_refresh_info

    def refresh(self) -> None:
        """Bring every materialized view up to date (tile-granular)."""
        if not self._views:
            return
        for _ in range(len(self._views) + 1):
            dirty = [v for v in self._views.values() if v.pending]
            if not dirty:
                return
            for view in dirty:
                self._refresh_view(view)
        raise RuntimeError("materialized views failed to converge")  # pragma: no cover

    def _gather_support_tiles(self, shard: int, kept: tuple,
                              tiles: np.ndarray) -> np.ndarray:
        """Current (base ⊕ delta) words of the support columns restricted to
        ``tiles`` -- uint32[s, T, tile_words], one vectorised base pass plus
        the delta's patched-tile overrides."""
        d = self._deltas[shard]
        tw = d.tile_words
        s, T = len(kept), int(tiles.size)
        cc = np.repeat(np.asarray(kept, np.int64), T)
        tt = np.tile(tiles, s)
        arr = base_tile_batch(d.base, cc, tt).reshape(s, T, tw)
        tlist = tiles.tolist()
        for j, c in enumerate(kept):
            tmap = d._tiles.get(c)
            if tmap:
                for i, t in enumerate(tlist):
                    got = tmap.get(t)
                    if got is not None:
                        arr[j, i] = got
        return arr

    def _refresh_view(self, view: MaterializedView) -> None:
        """Re-run the view's support-specialised circuit over ONLY the
        pending tiles (per owning shard) and patch the results into the
        view column's delta; counts move by per-tile popcount deltas."""
        import jax

        from repro.kernels.threshold_ssum import INTERPRET, run_circuit_cached

        tiles = np.asarray(sorted(view.pending), dtype=np.int64)
        view.pending.clear()
        toffs = self._tile_offsets()
        words_touched = 0
        gathered = 0
        delta_card = 0
        refreshed_tiles = set()
        for shard, (t0, d) in enumerate(zip(toffs, self._deltas)):
            local = tiles[(tiles >= t0) & (tiles < t0 + d.n_tiles)] - t0
            if local.size == 0:
                continue
            tw = d.tile_words
            if view.residual is None:
                out = np.full((local.size, tw), 0xFFFFFFFF if view.const else 0,
                              np.uint32)
            else:
                arr = self._gather_support_tiles(shard, view.kept, local)
                gathered += arr.size
                words_touched += arr.size
                # off-TPU the straight-line jnp evaluator beats
                # interpret-mode Pallas on these small tile batches
                got = run_circuit_cached(
                    jax.numpy.asarray(arr.reshape(len(view.kept), -1)),
                    view.residual,
                    pallas=not INTERPRET,
                    interpret=INTERPRET,
                )
                out = np.array(jax.device_get(got), np.uint32).reshape(
                    local.size, tw
                )
            words_touched += local.size * tw
            span = tw * 32
            for li, t in enumerate(local.tolist()):
                # the universe may end inside this tile: a truth table with
                # f(0)=1 would otherwise set padding bits past r, corrupting
                # the popcount-delta count
                end = d.r - t * span
                if end < span:
                    w = out[li]
                    fw, rem = end // 32, end % 32
                    if rem:
                        w[fw] &= np.uint32((1 << rem) - 1)
                        w[fw + 1 :] = 0
                    else:
                        w[fw:] = 0
                delta_card += d.patch_tile(view.slot, int(t), out[li])
            refreshed_tiles.update((t0 + local).tolist())
        view.cardinality += delta_card
        if _OBS.enabled:
            _REFRESHES.inc(1)
            _REFRESH_WORDS.inc(int(words_touched))
        view.last_refresh_info = {
            "tiles_refreshed": int(tiles.size),
            "words_gathered": int(gathered),
            "words_touched": int(words_touched),
            "cardinality_delta": int(delta_card),
        }
        self._version += 1
        # a view is an input to any later view that references it
        for other in self._views.values():
            if other is not view and view.slot in other.support:
                other.pending.update(refreshed_tiles)

    # -- compaction --------------------------------------------------------
    def compact(self, force: bool = True) -> bool:
        """Fold the delta into a new base store, tile-granularly.

        Only touched tiles reclassify (``TileStore.apply_tile_updates``);
        under sharding each shard compacts its own delta locally.  Returns
        True when a merge actually happened.  ``force=False`` applies the
        :class:`CompactionPolicy` threshold instead of compacting
        unconditionally.
        """
        self.refresh()
        if all(d.empty for d in self._deltas):
            return False
        if not force and not self.policy.should_compact(
            self.delta_words, self._base_working_words()
        ):
            return False
        if _OBS.enabled:
            _COMPACTIONS.inc(1)
            _COMPACTED_WORDS.observe(float(self.delta_words))
        if self._sharded:
            from repro.dist.query import ShardedBitmapIndex

            shards = tuple(
                s if d.empty else s.apply_tile_updates(d.updates(), r=d.r)
                for s, d in zip(self._base.store.shards, self._deltas)
            )
            self._base = ShardedBitmapIndex(
                self._base.store.with_shards(shards), self._names
            )
        else:
            store = self._base.store.apply_tile_updates(
                self._deltas[0].updates(), r=self._deltas[0].r
            )
            self._base = BitmapIndex(names=self._names, _store=store)
        self._reset_deltas()
        self._overlay_cache = None
        self._version += 1
        self.compactions += 1
        return True

    # -- durability (repro.persist) ----------------------------------------
    @property
    def durable_dir(self):
        return self._dir

    @property
    def wal_version(self) -> int:
        """Version of the last logged mutation batch (0 when not durable)."""
        return self._wal.last_version if self._wal is not None else 0

    def checkpoint(self) -> dict:
        """Fold the delta and write a fresh snapshot + rotate the WAL.

        After the checkpoint the directory alone reproduces the index:
        the snapshot holds every column (materialized views included, as
        real columns), ``index.json`` holds the view definitions and the
        WAL version the snapshot covers, and the WAL is emptied (its
        version counter stays monotone so later records sort after the
        snapshot).  Requires ``durable_dir``."""
        import json

        if self._dir is None:
            raise RuntimeError(
                "checkpoint() needs a durable index: pass durable_dir= to "
                "StreamingIndex"
            )
        from repro.persist import save, save_sharded
        from repro.persist.wal import query_to_obj

        self.refresh()
        self.compact(force=True)
        views_meta = [
            {"name": v.name, "query": query_to_obj(v.query)}
            for v in self._views.values()  # registration order
        ]
        meta = {
            "sharded": self._sharded,
            "wal_version": int(self._wal.last_version),
            "names": list(self._names),
            "views": views_meta,
        }
        extra = {"wal_version": meta["wal_version"], "views": views_meta}
        if self._sharded:
            save_sharded(self._base, self._dir, extra=extra)
        else:
            save(self._base, self._dir / "snapshot.bmsnap", extra=extra)
        (self._dir / "index.json").write_text(
            json.dumps(meta, indent=2, sort_keys=True)
        )
        self._wal.rotate()
        return meta

    @classmethod
    def recover(cls, path, *, policy: CompactionPolicy | None = None,
                mesh=None) -> "StreamingIndex":
        """Rebuild a durable index from its directory: load the snapshot
        (memmap, no copy), re-register the materialized views from the
        manifest, then replay every WAL record after the snapshot's
        version.  A torn record at the log's tail (the crash case) is
        truncated away; the recovered index answers bit-identically to
        the never-crashed one up to the last intact batch."""
        import json
        from pathlib import Path

        from repro.persist import load_index, load_sharded
        from repro.persist.wal import (
            APPEND,
            MATERIALIZE,
            UPDATE,
            WriteAheadLog,
            query_from_obj,
        )

        d = Path(path)
        meta = json.loads((d / "index.json").read_text())
        if meta["sharded"]:
            base = load_sharded(d, mesh=mesh)
        else:
            base = load_index(d / "snapshot.bmsnap")
        self = cls(base, policy=policy)
        self._dir = d
        self._rebuild_views(
            [(v["name"], query_from_obj(v["query"])) for v in meta["views"]]
        )
        wal = WriteAheadLog(d / "wal.bmwal")
        snap_version = int(meta["wal_version"])
        # the rotated log restarts empty; keep new appends sorting after
        # the snapshot even then
        wal.last_version = max(wal.last_version, snap_version)
        self._replaying = True
        try:
            for rec in wal.replay(after_version=snap_version):
                if rec["kind"] == UPDATE:
                    self._apply_update_arrays(rec["cols"], rec["pos"], rec["on"])
                elif rec["kind"] == APPEND:
                    self.append_rows(rec["bits"])
                elif rec["kind"] == MATERIALIZE:
                    self.materialize(rec["name"], rec["query"])
        finally:
            self._replaying = False
        self._wal = wal
        return self

    def _rebuild_views(self, pairs) -> None:
        """Re-register checkpointed views WITHOUT re-executing them: the
        snapshot already holds each view as a real column (bits and
        cardinality), only the refresh machinery (support + specialised
        circuit) needs rebuilding."""
        from repro.core.circuits import CONST0

        for name, q in pairs:
            if name not in self._slot:  # pragma: no cover - corrupt manifest
                raise ValueError(f"view {name!r} missing from snapshot schema")
            slot = self._slot[name]
            if self._sharded:
                card = sum(int(s.cardinalities[slot])
                           for s in self._base.store.shards)
            else:
                card = int(self._base.store.cardinalities[slot])
            circ = circuit_for((q,), self.n, self._names)
            support = circ.support()
            const, residual, kept = circ.specialize(
                {i: CONST0 for i in range(self.n) if i not in support}
            )
            self._views[name] = MaterializedView(
                name=name,
                query=q,
                slot=slot,
                support=frozenset(support),
                cardinality=card,
                kept=tuple(kept),
                residual=residual,
                const=const[0],
            )
