"""`repro.stream`: the streaming update engine.

Makes the bitmap index updatable without rebuilds and keeps registered
query results fresh incrementally:

  * :class:`DeltaStore` -- sparse per-column set/clear tile buffers plus
    row-space ``append_rows``, overlaid on an immutable base
    :class:`~repro.storage.TileStore`;
  * :class:`~repro.stream.overlay.OverlayStore` -- the TileStore-shaped
    read view every executor backend answers ``base ⊕ delta`` through;
  * :class:`StreamingIndex` -- mutation API, planner-driven overlay
    queries, tile-granular compaction (:class:`CompactionPolicy`,
    ``TileStore.apply_tile_updates``) and materialized views refreshed
    only over mutated tiles;
  * sharded bases route every mutation to the owning row shard and
    compact per shard -- nothing gathers.
"""

from .delta import DeltaStore
from .index import CompactionPolicy, MaterializedView, StreamingIndex
from .overlay import OverlayStore

__all__ = [
    "DeltaStore",
    "OverlayStore",
    "StreamingIndex",
    "CompactionPolicy",
    "MaterializedView",
]
