"""`DeltaStore`: sparse per-column mutation buffers over one `TileStore`.

The base store is immutable (the property every stale ``BitmapIndex``
reference relies on), so mutations accumulate HERE: each touched tile is
buffered as its full patched words (base tile ⊕ the set/clear bits so
far).  Storing patched words rather than separate set/clear masks makes
the ordering semantics trivial -- a later ``clear`` of a bit a previous
``set`` turned on simply lands in the same buffered tile -- and makes the
overlay read path (``repro.stream.overlay``) a pure array substitution:
patched tiles replace their base tiles in gathers, everything else reads
the base store untouched.

``append_rows`` extends the *row space* (the universe ``r``): appended
bits land in the base store's partial final tile and/or brand-new tiles,
which are just more buffered tiles -- tiles past the base store's range
read as all-zero, exactly what an un-appended column holds there.

A ``DeltaStore`` is deliberately shard-local: under a
``ShardedBitmapIndex`` the streaming engine keeps one per shard and routes
each mutation to the owning shard, so compaction and overlay construction
never cross shard boundaries (and never gather).
"""
from __future__ import annotations

import numpy as np

from repro.core.bitmaps import n_words_for
from repro.storage import TileStore
from repro.storage.tilestore import _popcount_words

__all__ = ["DeltaStore", "base_tile_batch"]


def base_tile_batch(base: TileStore, cols: np.ndarray, tiles: np.ndarray
                    ) -> np.ndarray:
    """Base-store words for (col, tile) cells, uint32[M, tile_words].

    THE one reconstruction of a tile's words (all-zero / all-one /
    container payload, all-zero past the base range) -- the delta's
    copy-on-write materialisation, the overlay's cardinality deltas and
    the view refresh gather all read through here.  Container-aware:
    sparse/run tiles decompress individually, never store-wide.
    """
    return base.gather_cells(np.asarray(cols, np.int64),
                             np.asarray(tiles, np.int64))


class DeltaStore:
    """Sparse tile-granular mutations overlaid on a base :class:`TileStore`."""

    def __init__(self, base: TileStore):
        self.base = base
        self.tile_words = base.tile_words
        self.span = base.tile_words * 32  # bits per tile
        self.n = base.n
        #: current universe size; grows with :meth:`append_rows`
        self.r = base.r
        #: column slot -> {tile index -> patched uint32[tile_words]}
        self._tiles: dict[int, dict[int, np.ndarray]] = {}

    # -- current geometry --------------------------------------------------
    @property
    def n_words(self) -> int:
        return n_words_for(self.r)

    @property
    def n_tiles(self) -> int:
        return (self.n_words + self.tile_words - 1) // self.tile_words

    @property
    def empty(self) -> bool:
        return not self._tiles and self.r == self.base.r

    @property
    def patched_tiles(self) -> int:
        """Distinct (column, tile) pairs buffered."""
        return sum(len(t) for t in self._tiles.values())

    @property
    def delta_words(self) -> int:
        """uint32 words buffered (the compaction-policy pressure metric)."""
        return self.patched_tiles * self.tile_words

    # -- tile access -------------------------------------------------------
    def base_tile(self, col: int, t: int) -> np.ndarray:
        """The base store's words for tile ``t`` (all-zero past its range)."""
        return base_tile_batch(self.base, [col], [t])[0]

    def tile(self, col: int, t: int) -> np.ndarray:
        """Current (base ⊕ delta) words of one tile -- NOT a live buffer."""
        got = self._tiles.get(col, {}).get(t)
        return got.copy() if got is not None else self.base_tile(col, t)

    def patch_tile(self, col: int, t: int, words: np.ndarray) -> int:
        """Replace one tile's words outright (the materialized-view refresh
        write path).  Returns the popcount delta vs the previous current
        words -- the per-tile increment that keeps view counts exact."""
        words = np.ascontiguousarray(words, dtype=np.uint32)
        if words.shape != (self.tile_words,):
            raise ValueError(f"expected uint32[{self.tile_words}], got {words.shape}")
        before = _popcount_words(self.tile(col, t))
        self._tiles.setdefault(col, {})[t] = words
        return _popcount_words(words) - before

    # -- mutations ---------------------------------------------------------
    def _positions(self, positions) -> np.ndarray:
        pos = np.atleast_1d(np.asarray(positions, dtype=np.int64))
        if pos.size and not ((0 <= pos) & (pos < self.r)).all():
            bad = pos[(pos < 0) | (pos >= self.r)][0]
            raise ValueError(f"bit position {bad} outside universe [0, {self.r})")
        return pos

    def set_bits(self, col: int, positions) -> list:
        """Set bits of one column; returns the touched tile indices."""
        return self._mutate(col, positions, set_=True)

    def clear_bits(self, col: int, positions) -> list:
        """Clear bits of one column; returns the touched tile indices."""
        return self._mutate(col, positions, set_=False)

    def _materialize_cells(self, cols: np.ndarray, tiles: np.ndarray) -> None:
        """Ensure every (col, tile) cell has a buffered patch target --
        missing cells' base words fetched in one vectorised pass."""
        missing = [
            (c, t)
            for c, t in zip(np.asarray(cols).tolist(), np.asarray(tiles).tolist())
            if t not in self._tiles.get(c, ())
        ]
        if not missing:
            return
        arr = base_tile_batch(
            self.base, [c for c, _ in missing], [t for _, t in missing]
        )
        for i, (c, t) in enumerate(missing):
            self._tiles.setdefault(c, {})[t] = arr[i]  # disjoint row views

    def _mutate(self, col: int, positions, *, set_: bool) -> list:
        if not 0 <= col < self.n:
            raise ValueError(f"column slot {col} outside [0, {self.n})")
        pos = self._positions(positions)
        if pos.size == 0:
            return []
        tiles = pos // self.span
        uniq = np.unique(tiles)
        self._materialize_cells(np.full(uniq.size, col, np.int64), uniq)
        tmap = self._tiles[col]
        # one vectorised bit apply across every touched tile: fold the
        # per-position bit masks into one OR-mask per touched word
        # (reduceat over the sorted flat word index -- ufunc.at is an
        # order of magnitude slower on large batches), then apply
        stacked = np.stack([tmap[t] for t in uniq.tolist()])
        rows = np.searchsorted(uniq, tiles)
        local = pos - tiles * self.span
        flat = rows * self.tile_words + (local // 32)
        b = np.uint32(1) << (local % 32).astype(np.uint32)
        order = np.argsort(flat, kind="stable")
        flat_w, start = np.unique(flat[order], return_index=True)
        masks = np.bitwise_or.reduceat(b[order], start)
        view = stacked.reshape(-1)
        if set_:
            view[flat_w] |= masks
        else:
            view[flat_w] &= ~masks
        for i, t in enumerate(uniq.tolist()):
            tmap[t] = stacked[i]
        return [int(t) for t in uniq.tolist()]

    _KEY_SHIFT = 40  # (col << 40) | tile packs a (col, tile) cell id

    def apply_batch(self, cols, pos, on) -> dict:
        """Apply a batch of single-bit updates across MANY columns in one
        vectorised pass: ``on[i]`` sets bit ``pos[i]`` of column
        ``cols[i]``, else clears it.  Set masks apply before clear masks
        (the documented ``update(sets=..., clears=...)`` semantics).
        Returns {column -> sorted touched tile list}.

        One lexsort of the batch replaces the per-column ``_mutate``
        pipeline -- the serving engine's step batches and the benchmark's
        update streams spend their time here.
        """
        cols = np.atleast_1d(np.asarray(cols, dtype=np.int64))
        pos = self._positions(pos)
        on = np.atleast_1d(np.asarray(on, dtype=bool))
        if not (cols.size == pos.size == on.size):
            raise ValueError("cols/pos/on must align")
        if cols.size == 0:
            return {}
        if not ((0 <= cols) & (cols < self.n)).all():
            raise ValueError(f"column slot outside [0, {self.n})")
        tiles = pos // self.span
        key = (cols << self._KEY_SHIFT) | tiles
        uniq = np.unique(key)
        ucols = (uniq >> self._KEY_SHIFT).astype(np.int64)
        utiles = (uniq & ((1 << self._KEY_SHIFT) - 1)).astype(np.int64)
        touched: dict = {}
        self._materialize_cells(ucols, utiles)
        for c, t in zip(ucols.tolist(), utiles.tolist()):
            touched.setdefault(c, []).append(t)
        stacked = np.stack(
            [self._tiles[int(c)][int(t)] for c, t in zip(ucols, utiles)]
        )
        rows = np.searchsorted(uniq, key)
        local = pos - tiles * self.span
        flat = rows * self.tile_words + (local // 32)
        b = np.uint32(1) << (local % 32).astype(np.uint32)
        view = stacked.reshape(-1)
        for mask_sel, set_ in ((on, True), (~on, False)):
            if not mask_sel.any():
                continue
            f = flat[mask_sel]
            bb = b[mask_sel]
            order = np.argsort(f, kind="stable")
            fw, start = np.unique(f[order], return_index=True)
            masks = np.bitwise_or.reduceat(bb[order], start)
            if set_:
                view[fw] |= masks
            else:
                view[fw] &= ~masks
        for i, (c, t) in enumerate(zip(ucols.tolist(), utiles.tolist())):
            self._tiles[c][t] = stacked[i]
        return touched

    def append_rows(self, bits: np.ndarray) -> list:
        """Grow the universe by ``bits.shape[1]`` positions (dense bool
        ``[n, k]``, one row per column).  Returns every tile index
        overlapping the appended range -- they all changed for every
        column's consumers, even where the new bits are zero."""
        bits = np.asarray(bits)
        if bits.ndim != 2 or bits.shape[0] != self.n:
            raise ValueError(f"expected bool[{self.n}, k], got shape {bits.shape}")
        k = bits.shape[1]
        if k == 0:
            return []
        old_r = self.r
        self.r = old_r + k
        for col in range(self.n):
            on = np.nonzero(bits[col])[0]
            if on.size:
                self._mutate(col, old_r + on, set_=True)
        t0, t1 = old_r // self.span, (self.r - 1) // self.span
        return list(range(int(t0), int(t1) + 1))

    # -- aggregate views ---------------------------------------------------
    def updates(self) -> dict:
        """The buffered tiles as ``TileStore.apply_tile_updates`` input."""
        return {c: dict(t) for c, t in self._tiles.items() if t}

    def card_delta(self, col: int) -> int:
        """Column cardinality change vs the base store."""
        tmap = self._tiles.get(col)
        if not tmap:
            return 0
        return sum(
            _popcount_words(w) - _popcount_words(self.base_tile(col, t))
            for t, w in tmap.items()
        )

    def snapshot(self) -> dict:
        """Immutable view of the buffered tiles: {col: {tile: words}}.
        Mutations never write into captured word arrays (every batch
        stacks-copies and rebinds), so shallow dict copies freeze the
        state -- what :class:`~repro.stream.overlay.OverlayStore` reads."""
        return {c: dict(t) for c, t in self._tiles.items() if t}
