"""`OverlayStore`: a TileStore-shaped read view of ``base ⊕ delta``.

Every executor in ``repro.query.executors`` reads a shard through a
``ShardContext`` whose data accessors come from a store: the tiled path
gathers ``store.dirty[store.dirty_index[...]]`` guided by
``store.classes_word``, the dense paths pull ``store.densify()``, and the
planner prices both from ``store.member_stats``.  ``OverlayStore``
implements exactly that surface over an immutable base :class:`TileStore`
plus a :class:`~repro.stream.delta.DeltaStore` -- so a streaming index
answers EVERY backend (tiled, circuit, fused, wide OR/AND, scancount,
dsk, ...) bit-identically to a from-scratch rebuild, without merging:

  * ``classes_word`` is the base classification with ONLY the patched
    tiles reclassified (a clean tile a delta bit landed in stops masking
    as a constant; a dirty tile cleared to all-zero starts to);
  * ``dirty`` is the base packed dirty array with the patched tiles'
    words appended at the end; ``dirty_index`` redirects patched tiles
    there, so tiled gathers read patched words and never stale base rows;
  * ``densify()`` scatters the patched tiles into the (cached) base dense
    view in one device op;
  * ``member_stats`` / ``cardinalities`` fold the delta's popcount deltas
    in, so the planner prices the overlaid data, not the stale base.

Construction is O(metadata + patched tiles); nothing is respliced.  Cold
paths that genuinely need a merged store (bit-level RUN stats,
reclassification at another granularity) fall back to :meth:`solid` --
``base.apply_tile_updates(...)``, the same tile-granular merge compaction
adopts.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.bitmaps import n_words_for
from repro.storage import (
    CONT_DENSE,
    CONT_NONE,
    CONT_RUN,
    CONT_SPARSE,
    TILE_DIRTY,
    TILE_ONE,
    TILE_ZERO,
    MemberStats,
    TileStore,
)
from repro.storage.tiles import BlockStats
from repro.storage.tilestore import _popcount_words, _signature_counts

from .delta import DeltaStore, base_tile_batch

__all__ = ["OverlayStore"]


class OverlayStore:
    """Read-only TileStore-duck-typed view of a base store plus a delta."""

    def __init__(self, base: TileStore, delta: DeltaStore):
        if delta.base is not base:
            raise ValueError("delta was recorded against a different base store")
        self.base = base
        # SNAPSHOT the delta at construction: every surface of this view
        # (tiled gathers, dense view, cardinalities, solid()) must describe
        # the same instant, or a stale index reference would answer
        # backend-dependently after later mutations
        self._patched = delta.snapshot()
        self.tile_words = tw = base.tile_words
        self.r = delta.r
        self.n_words = n_words_for(self.r)
        self.n_tiles = (self.n_words + tw - 1) // tw
        n = base.n

        classes = np.zeros((n, self.n_tiles), np.uint8)
        classes[:, : base.n_tiles] = base.classes_word
        index = np.full((n, self.n_tiles), -1, np.int64)
        index[:, : base.n_tiles] = base.dirty_index
        base_nd = base._dirty_np.shape[0]
        # flatten the snapshot's patched tiles into ONE vectorised pass --
        # classification, class scatter, dirty redirection
        pc, pt, words = [], [], []
        for col, tmap in self._patched.items():
            pc.extend([col] * len(tmap))
            pt.extend(tmap.keys())
            words.extend(tmap.values())
        if pc:
            pcols = np.asarray(pc, np.int64)
            ptiles = np.asarray(pt, np.int64)
            pwords = np.stack(words)  # [P, tw]
            any_set = pwords.any(axis=1)
            all_one = (pwords == 0xFFFFFFFF).all(axis=1)
            cls = np.where(
                all_one, TILE_ONE, np.where(any_set, TILE_DIRTY, TILE_ZERO)
            ).astype(np.uint8)
            classes[pcols, ptiles] = cls
            dirty = cls >= TILE_DIRTY
            idx_vals = np.full(pcols.size, -1, np.int64)
            idx_vals[dirty] = base_nd + np.arange(int(dirty.sum()))
            index[pcols, ptiles] = idx_vals
            self._extra = np.ascontiguousarray(pwords[dirty])
        else:
            pcols = ptiles = np.zeros(0, np.int64)
            cls = np.zeros(0, np.uint8)
            self._extra = np.zeros((0, tw), np.uint32)
        self._pcols, self._ptiles, self._pcls = pcols, ptiles, cls
        self._classes_word = classes
        self._dirty_index = index
        self._dirty_np_cache: np.ndarray | None = None
        self._dirty_dev = None
        self._dense = None
        self._solid_cache: TileStore | None = None
        self._member_stats_cache: dict = {}
        self._card_cache: tuple | None = None
        self._kinds_cache: np.ndarray | None = None
        self._swc_cache: np.ndarray | None = None
        self._patch_pos_cache: np.ndarray | None = None

    # -- geometry / identity ----------------------------------------------
    @property
    def n(self) -> int:
        return self.base.n

    # -- tile-path surface (what run_tiled_circuit reads) ------------------
    @property
    def classes_word(self) -> np.ndarray:
        return self._classes_word

    @property
    def dirty_index(self) -> np.ndarray:
        return self._dirty_index

    @property
    def _dirty_np(self) -> np.ndarray:
        if self._dirty_np_cache is None:
            self._dirty_np_cache = (
                np.concatenate([self.base._dirty_np, self._extra])
                if self._extra.size
                else self.base._dirty_np
            )
        return self._dirty_np_cache

    @property
    def dirty(self) -> jax.Array:
        if self._dirty_dev is None:
            if self._extra.size:
                self._dirty_dev = jnp.concatenate(
                    [self.base.dirty, jnp.asarray(self._extra)]
                )
            else:
                self._dirty_dev = self.base.dirty
        return self._dirty_dev

    # -- container surface (what the container-native executor reads) -----
    @property
    def container_kinds(self) -> np.ndarray:
        """Base container kinds with patched tiles as dense containers
        (patched words are raw; compaction re-compresses them)."""
        if self._kinds_cache is None:
            kinds = np.zeros((self.n, self.n_tiles), np.uint8)
            kinds[:, : self.base.n_tiles] = self.base.container_kinds
            if self._pcols.size:
                kinds[self._pcols, self._ptiles] = np.where(
                    self._pcls >= TILE_DIRTY, CONT_DENSE, CONT_NONE
                ).astype(np.uint8)
            self._kinds_cache = kinds
        return self._kinds_cache

    @property
    def storage_words_cell(self) -> np.ndarray:
        if self._swc_cache is None:
            swc = np.zeros((self.n, self.n_tiles), np.int32)
            swc[:, : self.base.n_tiles] = self.base.storage_words_cell
            if self._pcols.size:
                swc[self._pcols, self._ptiles] = np.where(
                    self._pcls >= TILE_DIRTY, self.tile_words, 0
                )
            self._swc_cache = swc
        return self._swc_cache

    @property
    def _patch_pos(self) -> np.ndarray:
        """int64[n, n_tiles]: row of ``_extra`` per patched-dirty cell."""
        if self._patch_pos_cache is None:
            pp = np.full((self.n, self.n_tiles), -1, np.int64)
            dirty = self._pcls >= TILE_DIRTY
            if dirty.any():
                pp[self._pcols[dirty], self._ptiles[dirty]] = np.arange(
                    int(dirty.sum())
                )
            self._patch_pos_cache = pp
        return self._patch_pos_cache

    def gather_cells(self, cols, tiles) -> np.ndarray:
        """Materialised (base ⊕ delta) words of arbitrary cells -- patched
        tiles from the overlay buffer, the rest straight off the base's
        container packs (decompressed per cell, never store-wide)."""
        cols = np.asarray(cols, np.int64)
        tiles = np.asarray(tiles, np.int64)
        out = np.zeros((cols.size, self.tile_words), np.uint32)
        inb = tiles < self.n_tiles
        if not inb.all():
            sel = np.nonzero(inb)[0]
            out[sel] = self.gather_cells(cols[sel], tiles[sel])
            return out
        cls = self._classes_word[cols, tiles]
        out[cls == TILE_ONE] = 0xFFFFFFFF
        pp = self._patch_pos[cols, tiles]
        hit = pp >= 0
        if hit.any():
            out[hit] = self._extra[pp[hit]]
        rest = (cls >= TILE_DIRTY) & ~hit
        if rest.any():
            out[rest] = self.base.gather_cells(cols[rest], tiles[rest])
        return out

    def gather_events(self, cols, tiles):
        """Boundary events of compressed cells.  Patched tiles are never
        sparse/run containers (see :attr:`container_kinds`), so every
        requested cell lives in the base packs."""
        return self.base.gather_events(cols, tiles)

    # -- dense-path surface ------------------------------------------------
    def densify(self) -> jax.Array:
        """Dense view with the patched tiles scattered in.

        Built host-side from the base tiles (vectorised row scatter into
        the padded ``[n, n_tiles, tile_words]`` layout, one upload) --
        device-side scatters recompile per delta shape, which dominated
        wall time for large deltas.  Cached per overlay.
        """
        if self._dense is not None:
            return self._dense
        tw = self.tile_words
        padded = np.zeros((self.n, self.n_tiles, tw), np.uint32)
        bt = self.base.n_tiles
        pbase = padded[:, :bt]
        pbase[self.base.classes_word == TILE_ONE] = 0xFFFFFFFF
        pbase[self.base.classes_word >= TILE_DIRTY] = self.base._dirty_np
        for col, tmap in self._patched.items():
            ts = np.fromiter(tmap, np.int64, len(tmap))
            padded[col, ts] = np.stack(list(tmap.values()))
        self._dense = jnp.asarray(
            padded.reshape(self.n, -1)[:, : self.n_words]
        )
        return self._dense

    def column(self, i: int) -> jax.Array:
        return self.densify()[int(i)]

    # -- planner surface ---------------------------------------------------
    @property
    def cardinalities(self) -> tuple:
        if self._card_cache is None:
            deltas = {}
            for col, tmap in self._patched.items():
                ts = list(tmap)
                patched = np.stack([tmap[t] for t in ts])
                basew = base_tile_batch(self.base, [col] * len(ts), ts)
                deltas[col] = _popcount_words(patched) - _popcount_words(basew)
            self._card_cache = tuple(
                c + deltas.get(i, 0)
                for i, c in enumerate(self.base.cardinalities)
            )
        return self._card_cache

    @property
    def densities(self) -> tuple:
        return tuple(c / max(self.r, 1) for c in self.cardinalities)

    @property
    def clean_fraction(self) -> float:
        if self._classes_word.size == 0:
            return 1.0
        return float((self._classes_word <= TILE_ONE).mean())

    @property
    def dirty_words(self) -> int:
        return int((self._classes_word >= TILE_DIRTY).sum()) * self.tile_words

    def member_stats(self, slots=None) -> MemberStats:
        """Same aggregate `TileStore.member_stats` computes, over the
        overlaid classes and cardinalities (cached per subset)."""
        key = None if slots is None else tuple(slots)
        cached = self._member_stats_cache.get(key)
        if cached is not None:
            return cached
        idx = np.arange(self.n) if slots is None else np.asarray(list(key))
        if idx.size == 0:
            return MemberStats(0, self.n_words, self.tile_words, 1.0, 0.0, 0, 0)
        cls = self._classes_word[idx]
        dirty_tiles = int((cls >= TILE_DIRTY).sum())
        cards = self.cardinalities
        dens = [cards[i] / max(self.r, 1) for i in idx]
        sigs, counts = _signature_counts(cls)
        signatures = tuple(
            (int(cnt), int((sig == TILE_ONE).sum()), int((sig >= TILE_DIRTY).sum()))
            for sig, cnt in zip(sigs, counts)
        )
        kinds = self.container_kinds[idx]
        stats = MemberStats(
            n=int(idx.size),
            n_words=self.n_words,
            tile_words=self.tile_words,
            clean_fraction=1.0 - dirty_tiles / max(cls.size, 1),
            density=float(np.mean(dens)),
            dirty_words=dirty_tiles * self.tile_words,
            case3_tiles=int(((cls >= TILE_DIRTY).any(axis=0)).sum()),
            signatures=signatures,
            container_tiles=(
                int((kinds == CONT_DENSE).sum()),
                int((kinds == CONT_SPARSE).sum()),
                int((kinds == CONT_RUN).sum()),
            ),
            compressed_words=int(self.storage_words_cell[idx].sum()),
        )
        self._member_stats_cache[key] = stats
        return stats

    def block_stats(self) -> BlockStats:
        return BlockStats(
            classes=self._classes_word.copy(),
            tile_words=self.tile_words,
            n_words=self.n_words,
        )

    # -- cold paths: fall back to the merged store -------------------------
    def solid(self) -> TileStore:
        """The merged (base ⊕ snapshot) TileStore -- what compaction would
        have adopted at this view's instant; built lazily, tile-granularly,
        and cached."""
        if self._solid_cache is None:
            self._solid_cache = self.base.apply_tile_updates(
                {c: dict(t) for c, t in self._patched.items()}, r=self.r
            )
        return self._solid_cache

    @property
    def col_stats(self) -> tuple:
        return self.solid().col_stats

    @property
    def runcounts(self) -> tuple:
        return self.solid().runcounts

    @property
    def classes(self) -> np.ndarray:
        return self.solid().classes

    def with_tile_words(self, tile_words: int) -> "TileStore":
        return self.solid().with_tile_words(tile_words)

    # -- mutations are the streaming engine's job --------------------------
    def append(self, packed_row):
        raise TypeError(
            "OverlayStore is a read view; mutate through StreamingIndex "
            "(set_bits/clear_bits/append_rows) or compact() first"
        )

    replace = append
    slice_tiles = append
