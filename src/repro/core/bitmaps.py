"""Packed-bitmap primitives.

A bitmap over ``r`` positions is stored as ``uint32`` words, LSB-first:
bit ``i`` lives at word ``i // 32``, bit position ``i % 32``.  A *batch* of
N bitmaps is a ``uint32[N, n_words]`` array.  On TPU each 32-bit lane op
processes 8x128 lanes at once, so one VPU op handles 32_768 bitmap
positions -- this is the paper's W (machine word) scaled to the vector unit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

WORD_BITS = 32
WORD_DTYPE = jnp.uint32

__all__ = [
    "WORD_BITS",
    "WORD_DTYPE",
    "n_words_for",
    "pack",
    "unpack",
    "popcount",
    "cardinality",
    "bitmap_and",
    "bitmap_or",
    "bitmap_xor",
    "bitmap_andnot",
    "bitmap_not",
    "tail_mask",
    "packed_tail_mask",
    "from_positions",
    "to_positions_np",
    "density",
]


def n_words_for(r: int) -> int:
    """Number of 32-bit words needed for ``r`` bit positions."""
    return (int(r) + WORD_BITS - 1) // WORD_BITS


def tail_mask(r: int) -> int:
    """Mask of valid bits in the final word for universe size ``r``."""
    rem = int(r) % WORD_BITS
    return 0xFFFFFFFF if rem == 0 else (1 << rem) - 1


@functools.lru_cache(maxsize=256)
def packed_tail_mask(r: int, n_words: int) -> jax.Array:
    """Per-word mask uint32[n_words] keeping only bits below ``r``.

    ``None`` when no masking is needed (``r`` fills every word) so callers
    can skip the AND entirely.  Cached: (r, n_words) pairs recur per index
    and per shard, and the mask never changes.
    """
    r, n_words = int(r), int(n_words)
    if r >= n_words * WORD_BITS:
        return None
    mask = np.zeros(n_words, dtype=np.uint32)
    full = r // WORD_BITS
    mask[:full] = 0xFFFFFFFF
    if r % WORD_BITS:
        mask[full] = tail_mask(r)
    return jnp.asarray(mask)


def pack(bits: jax.Array) -> jax.Array:
    """Pack a boolean/int array ``[..., r]`` into ``uint32[..., ceil(r/32)]``."""
    bits = jnp.asarray(bits)
    r = bits.shape[-1]
    nw = n_words_for(r)
    pad = nw * WORD_BITS - r
    if pad:
        bits = jnp.pad(bits, [(0, 0)] * (bits.ndim - 1) + [(0, pad)])
    bits = bits.astype(jnp.uint32).reshape(bits.shape[:-1] + (nw, WORD_BITS))
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    return jnp.sum(bits << shifts, axis=-1, dtype=jnp.uint32)


def unpack(words: jax.Array, r: int | None = None) -> jax.Array:
    """Unpack ``uint32[..., n_words]`` into boolean ``[..., r]``."""
    words = jnp.asarray(words, dtype=WORD_DTYPE)
    shifts = jnp.arange(WORD_BITS, dtype=jnp.uint32)
    bits = (words[..., None] >> shifts) & jnp.uint32(1)
    bits = bits.reshape(words.shape[:-1] + (words.shape[-1] * WORD_BITS,))
    if r is not None:
        bits = bits[..., :r]
    return bits.astype(jnp.bool_)


def popcount(words: jax.Array) -> jax.Array:
    """Per-word population count (int32)."""
    return jax.lax.population_count(jnp.asarray(words, WORD_DTYPE)).astype(jnp.int32)


def cardinality(words: jax.Array) -> jax.Array:
    """Number of ones in each bitmap (sum over the word axis)."""
    return jnp.sum(popcount(words), axis=-1)


def bitmap_and(a, b):
    return jnp.bitwise_and(a, b)


def bitmap_or(a, b):
    return jnp.bitwise_or(a, b)


def bitmap_xor(a, b):
    return jnp.bitwise_xor(a, b)


def bitmap_andnot(a, b):
    """a AND (NOT b) -- the paper's ANDNOT primitive."""
    return jnp.bitwise_and(a, jnp.bitwise_not(b))


def bitmap_not(a, r: int | None = None):
    """Bitwise complement; masks the invalid tail bits when ``r`` is given."""
    out = jnp.bitwise_not(jnp.asarray(a, WORD_DTYPE))
    if r is not None:
        nw = out.shape[-1]
        mask = np.full(nw, 0xFFFFFFFF, dtype=np.uint32)
        mask[-1] = tail_mask(r)
        out = jnp.bitwise_and(out, jnp.asarray(mask))
    return out


def from_positions(positions, r: int) -> jax.Array:
    """Build a packed bitmap from a (host) list/array of set positions."""
    pos = np.asarray(positions, dtype=np.int64)
    nw = n_words_for(r)
    out = np.zeros(nw, dtype=np.uint32)
    if pos.size:
        np.bitwise_or.at(out, pos // WORD_BITS, np.uint32(1) << (pos % WORD_BITS).astype(np.uint32))
    return jnp.asarray(out)


def to_positions_np(words) -> np.ndarray:
    """Host-side: sorted array of set positions in a packed bitmap."""
    w = np.asarray(jax.device_get(words), dtype=np.uint32)
    bits = np.unpackbits(w.view(np.uint8), bitorder="little")
    return np.nonzero(bits)[0]


def density(words, r: int) -> jax.Array:
    return cardinality(words) / r
