"""Algorithm selection -- the paper's 5.10 decision rules as a planner.

Given (N, T) and cheap data statistics (density, clean-tile fraction),
choose the algorithm a query engine should run.  The recommendations
encode the paper's conclusions:

  * T == 1 / T == N        -> wide OR / wide AND
  * many clean runs        -> RBMRG (block variant here)
  * very small T           -> LOOPED
  * T close to N, sparse   -> pruning algorithms (host-side DSK)
  * otherwise              -> SSUM ('if one does not know much about the
                               data ... the adder circuits are safe bets')
"""
from __future__ import annotations

import dataclasses

__all__ = ["Plan", "plan_threshold"]


@dataclasses.dataclass
class Plan:
    algorithm: str
    rationale: str


def plan_threshold(
    n: int,
    t: int,
    *,
    density: float | None = None,
    clean_fraction: float | None = None,
    on_device: bool = True,
) -> Plan:
    if t <= 1:
        return Plan("wide_or", "T<=1 is a wide OR (paper 2.3)")
    if t >= n:
        return Plan("wide_and", "T=N is a wide AND (paper 2.3)")
    if clean_fraction is not None and clean_fraction > 0.5:
        return Plan(
            "rbmrg_block",
            f"{clean_fraction:.0%} of tiles are clean runs; run-aware merge "
            "does O(RUNCOUNT log N) work (paper 4.1, 5.10)",
        )
    if t <= 3:
        return Plan("looped", "T very small: LOOPED is O(NT) ops and wins (paper 5.10)")
    if not on_device and density is not None and density < 1e-3 and t >= 0.9 * n:
        return Plan(
            "dsk",
            "sparse data with T~N: pruning algorithms win on the host (paper 5.8.3)",
        )
    return Plan("fused", "default: sideways-sum adder, fused kernel (paper 5.10 + ours)")
