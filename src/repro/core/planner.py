"""Algorithm selection -- the paper's 5.10 decision rules as a planner.

Given a query (or bare (N, T)) and cheap data statistics (density,
clean-tile fraction), choose the backend a query engine should run.  Every
plan names a *runnable executor*: bare-threshold names resolve through
``repro.query.executors.run_threshold_backend`` (equivalently the
``threshold()`` shim) and circuit names through ``BitmapIndex``'s compiled
cache.  The recommendations encode the paper's conclusions:

  * T == 1 / T == N        -> wide OR / wide AND (paper 2.3)
  * many clean runs        -> RBMRG (tile-level block variant here)
  * very small T           -> LOOPED
  * T close to N, sparse   -> pruning algorithms (host-side DSK)
  * otherwise              -> SSUM ('if one does not know much about the
                               data ... the adder circuits are safe bets'),
                               as the fused Pallas kernel on TPU, as the
                               XLA-compiled circuit elsewhere

Composite expressions and non-threshold symmetric leaves always compile to
one shared circuit ('circuit' or 'fused'), because the whole tree costs a
single adder pass there -- leaf-at-a-time execution cannot win.
"""
from __future__ import annotations

import dataclasses

__all__ = ["Plan", "plan_threshold", "plan_query", "CIRCUIT_BACKENDS"]

# Backends executed by compiling the (whole) expression into one circuit.
CIRCUIT_BACKENDS = ("circuit", "fused")


@dataclasses.dataclass
class Plan:
    algorithm: str
    rationale: str


def plan_threshold(
    n: int,
    t: int,
    *,
    density: float | None = None,
    clean_fraction: float | None = None,
    on_device: bool = True,
    fused_available: bool = True,
) -> Plan:
    """Pick the executor for theta(T, .) over N bitmaps."""
    if t <= 1:
        return Plan("wide_or", "T<=1 is a wide OR (paper 2.3)")
    if t >= n:
        return Plan("wide_and", "T=N is a wide AND (paper 2.3)")
    if clean_fraction is not None and clean_fraction > 0.5:
        return Plan(
            "rbmrg_block",
            f"{clean_fraction:.0%} of tiles are clean runs; run-aware merge "
            "does O(RUNCOUNT log N) work (paper 4.1, 5.10)",
        )
    if n >= 2048:
        return Plan(
            "scancount_streaming",
            "N huge: per-(N,T) circuit tabulation is infeasible; streaming "
            "counters keep an O(chunk x r) working set (paper section 6)",
        )
    if t <= 3:
        return Plan("looped", "T very small: LOOPED is O(NT) ops and wins (paper 5.10)")
    if not on_device and density is not None and density < 1e-3 and t >= 0.9 * n:
        return Plan(
            "dsk",
            "sparse data with T~N: pruning algorithms win on the host (paper 5.8.3)",
        )
    if fused_available:
        return Plan("fused", "default: sideways-sum adder, fused kernel (paper 5.10 + ours)")
    return Plan("ssum", "default: sideways-sum adder circuit via XLA (paper 5.10)")


def _bare_threshold_members(query):
    """If ``query`` is a Threshold over plain columns (or all columns),
    return its member count resolver; else None."""
    from repro.query.expr import Col, Threshold

    if type(query) is not Threshold:
        return None
    if query.over is not None and not all(type(m) is Col for m in query.over):
        return None
    return (lambda n: n) if query.over is None else (lambda n: len(query.over))


def plan_query(
    query,
    n: int,
    *,
    density: float | None = None,
    clean_fraction: float | None = None,
    on_device: bool = True,
    fused_available: bool = True,
) -> Plan:
    """Pick the executor for a query expression over an N-column index."""
    from repro.query.expr import Col, Weighted, as_query

    q = as_query(query)
    if type(q) is Col:
        return Plan("column", "bare column reference: fetch, no compute")
    members = _bare_threshold_members(q)
    if members is not None:
        return plan_threshold(
            members(n),
            q.t,
            density=density,
            clean_fraction=clean_fraction,
            on_device=on_device,
            fused_available=fused_available,
        )
    backend = "fused" if fused_available else "circuit"
    if type(q) is Weighted:
        return Plan(
            backend,
            "weighted threshold: binary weight decomposition circuit "
            "(O(log max_w) adders instead of replication; beyond-paper)",
        )
    return Plan(
        backend,
        "symmetric/composite expression: one compiled circuit, sub-queries "
        "share the sideways-sum adder via CSE (paper 4.4 + query layer)",
    )
