"""Algorithm selection -- the paper's 5.10 decision rules as a cost-model planner.

Given a query (or bare (N, T)) and data statistics, choose the backend a
query engine should run and attach an estimated cost.  Statistics come in
two strengths:

  * scalar ``density`` / ``clean_fraction`` kwargs -- the legacy
    index-wide-mean interface, driving the paper's rule thresholds exactly
    as published (kept for direct callers and old tests);
  * a ``stats`` object (``repro.storage.MemberStats``, duck-typed) -- real
    per-column tile statistics of the *member subset* of the query,
    computed once at ``TileStore`` build time.  With it the planner runs a
    words-touched cost model: every candidate backend gets an estimate of
    the uint32 words it moves through the memory system, and the
    tile-skipping backend (``tiled_fused``) is chosen when the words it
    gathers (only dirty tiles) undercut the dense sweep.

Every plan names a *runnable executor*: bare-threshold names resolve
through ``repro.query.executors.run_threshold_backend`` and circuit names
through ``BitmapIndex``'s compiled cache.  The recommendations encode the
paper's conclusions:

  * T == 1 / T == N        -> wide OR / wide AND (paper 2.3)
  * many clean tiles       -> tiled_fused (stats-aware; the RBMRG
                              generalisation) or rbmrg_block (scalar rule)
  * very small T           -> LOOPED
  * T close to N, sparse   -> pruning algorithms (host-side DSK)
  * otherwise              -> SSUM ('if one does not know much about the
                               data ... the adder circuits are safe bets'),
                               as the fused Pallas kernel on TPU, as the
                               XLA-compiled circuit elsewhere

Composite expressions and non-threshold symmetric leaves compile to one
shared circuit ('circuit' / 'fused' / 'tiled_fused'), because the whole
tree costs a single adder pass there -- leaf-at-a-time execution cannot win.
"""
from __future__ import annotations

import dataclasses

from repro.core.calibration import get_calibration

__all__ = [
    "Plan",
    "plan_threshold",
    "plan_query",
    "estimate_words_touched",
    "CIRCUIT_BACKENDS",
]

# Backends executed by compiling the (whole) expression into one circuit.
CIRCUIT_BACKENDS = ("circuit", "fused", "tiled_fused")

# tiled execution wins when its gathered words undercut the dense sweep by
# at least this factor (covers the host-side gather/scatter bookkeeping)
_TILED_ADVANTAGE = 0.5

# words-equivalent fixed cost of one device dispatch (trace/launch
# overhead).  The single-scan engine (repro.kernels.tiled_scan) collapses
# per-residual-group launches into at most two dispatches per query (one
# event merge + one block scan), so this prices dispatches, not groups --
# the per-group cost that remains (a lax.switch branch, block padding to
# the group boundary) is priced separately by _GROUP_OVERHEAD_WORDS.
# BENCH_query.json historically showed tiled_fused 5-16x slower on wall
# time than fused at clean_fraction <= 0.5 when 8 signatures meant 8
# launches; with the collapse the dispatch term shrinks, and the
# _TILED_ADVANTAGE gate plus the group/decode terms keep the planner off
# tiled in dirty-dominated regimes.
_LAUNCH_OVERHEAD_WORDS = 256.0

# words-equivalent cost of one residual group riding the single scan:
# its lax.switch branch and the padding of its tile count to whole blocks.
_GROUP_OVERHEAD_WORDS = 64.0

# the in-kernel decode prologue stages every compressed cell as dense
# words in VMEM before the residual evaluates, so a compressed gather's
# effective cost is its payload *plus* a slice of the staging work; the
# model inflates the compression ratio by this factor (capped at the
# dense-equivalent -- decode never costs more than having stored dense).
_DECODE_WORDS_FACTOR = 2.0

# the tiled executor specializes at most this many signatures exactly;
# overflow tiles fall back to a dense gather of the full member support,
# and the estimate must price that.  This is the CANONICAL constant --
# storage/tiled imports it, so the cost model and the executor cannot
# diverge on the exact-vs-overflow split.
_MAX_EXACT_SIGNATURES = 64


@dataclasses.dataclass
class Plan:
    algorithm: str
    rationale: str
    cost: float | None = None  # estimated words touched (None: no estimate)
    candidates: tuple = ()  # ((backend, estimated words touched), ...)
    #: calibrated microsecond estimates (``core.calibration``); None / empty
    #: when no calibration is installed or a backend has no constant
    cost_us: float | None = None
    candidates_us: tuple = ()  # ((backend, estimated µs), ...) sorted by µs
    #: "hit" / "miss" when the plan came through the per-store plan memo
    #: (``BitmapIndex.explain``); None for direct planner calls
    memo: str | None = None


def _attach_us(p: Plan) -> Plan:
    """Price the plan and its candidate list in calibrated microseconds
    when a calibration is installed; a no-op otherwise."""
    calib = get_calibration()
    if calib is None:
        return p
    cands = [
        (b, calib.cost_us(b, w))
        for b, w in p.candidates
        if calib.cost_us(b, w) is not None
    ]
    p.candidates_us = tuple(sorted(cands, key=lambda kv: kv[1]))
    p.cost_us = calib.cost_us(p.algorithm, p.cost)
    return p


def estimate_words_touched(
    backend: str,
    n: int,
    t: int | None = None,
    *,
    n_words: int = 1,
    stats=None,
    density: float | None = None,
) -> float | None:
    """Estimated uint32 words moved through HBM for one execution.

    The unit is words read+written per query; ``n_words = 1`` gives a
    per-output-word figure.  ``stats`` (a ``MemberStats``-shaped object)
    enables the data-dependent estimates; without it those return None.
    The model is deliberately coarse -- it ranks backends, it does not
    predict wall time.
    """
    nw = float(n_words)
    t_known = t is not None  # None: not a bare threshold (composite circuit)
    t = int(t) if t is not None else max(1, n // 2)
    dense = n * nw
    if backend in ("wide_or", "wide_and"):
        return dense + nw
    if backend == "looped":
        # T counter bitmaps updated per input: ~2NT reads+writes
        return 2.0 * n * min(t, n) * nw
    if backend in ("ssum", "treeadd", "srtckt", "csvckt", "circuit"):
        # ~5N gates, every intermediate round-trips through HBM under XLA
        return dense + 2 * 5 * dense
    if backend in ("scancount", "scancount_streaming"):
        # 32 counter lanes per word, read+write per chunk pass
        return dense + 64 * nw
    if backend == "fused":
        return dense + nw
    if backend == "tiled_fused":
        if stats is None:
            return None
        n_tiles = max(1, int(nw) // max(1, stats.tile_words))
        # container compression ratio of the member subset: the executor
        # gathers sparse/run tiles as their compressed payloads (or
        # evaluates them event-natively), so the words it moves scale with
        # the stored container sizes, not the dense dirty pack.  1.0 when
        # every container is dense / containers are off -- estimates are
        # monotone in container size and never exceed the dense-pack model.
        compressed = getattr(stats, "compressed_words", 0) or stats.dirty_words
        ratio = compressed / stats.dirty_words if stats.dirty_words else 1.0
        sigs = getattr(stats, "signatures", ())
        if sigs:
            # Per-signature model: a signature launches a residual kernel only
            # when the circuit cannot fold it constant; for a bare threshold
            # that is exactly 0 < T - #ones <= #dirty (RBMRG case 3).  Without
            # a known T, any signature with dirty members may launch.  Launch
            # groups are counted after the executor's structural merge: bare
            # thresholds with equal (T - #ones, #dirty) share one kernel.
            gathered = 0
            groups = set()
            # mirror the executor: only the most populous signatures get
            # exact specialization; overflow tiles skip constant folding
            # and run the dense support residual as one extra group
            exact = sorted(sigs, key=lambda s: -s[0])[:_MAX_EXACT_SIGNATURES]
            overflow_tiles = sum(cnt for cnt, _, _ in sigs) - sum(
                cnt for cnt, _, _ in exact
            )
            for cnt, ones, dirty in exact:
                if t_known:
                    tt = t - ones
                    if tt <= 0 or tt > dirty:
                        continue  # case 1/2: folds constant, no gather
                    groups.add((tt, dirty))
                else:
                    if dirty == 0:
                        continue
                    groups.add(dirty)
                gathered += cnt * dirty * stats.tile_words
            n_groups = len(groups)
            if overflow_tiles:
                # overflow rides the same block scan as every other group;
                # the decode prologue sentinel-fills its clean cells, so
                # only the overflow tiles' dirty cells are gathered
                gathered += (
                    sum(cnt * dirty for cnt, _ones, dirty in sigs)
                    - sum(cnt * dirty for cnt, _ones, dirty in exact)
                ) * stats.tile_words
                n_groups += 1
            # compressed tiles gather less, but the decode prologue stages
            # them back to dense words in VMEM -- price payload + staging,
            # never more than the dense-equivalent gather
            eff_ratio = min(1.0, ratio * _DECODE_WORDS_FACTOR)
            gathered = gathered * eff_ratio
            # the scan engine dispatches at most twice per query (event
            # merge + block scan), regardless of group count
            launches = min(2, n_groups) if n_groups else 0
            return (
                float(gathered) + nw + n_tiles
                + _LAUNCH_OVERHEAD_WORDS * launches
                + _GROUP_OVERHEAD_WORDS * n_groups
            )
        # no signature stats: gathered (compressed) words + one output pass
        # + per-tile bookkeeping (the legacy coarse estimate)
        return float(compressed) + nw + n_tiles
    if backend == "rbmrg_block":
        if stats is None:
            return None
        return float(stats.dirty_words) + nw + 2 * (nw / max(1, stats.tile_words))
    if backend == "dsk":
        if density is None:
            return None
        # host position lists: ~32 positions per dense word at this density
        return 32.0 * density * dense
    return None


def _candidates(n, t, *, n_words, stats, density):
    names = ("tiled_fused", "fused", "ssum", "looped", "scancount_streaming")
    out = []
    for name in names:
        est = estimate_words_touched(
            name, n, t, n_words=n_words, stats=stats, density=density
        )
        if est is not None:
            out.append((name, est))
    return tuple(sorted(out, key=lambda kv: kv[1]))


def plan_threshold(
    n: int,
    t: int,
    *,
    density: float | None = None,
    clean_fraction: float | None = None,
    on_device: bool = True,
    fused_available: bool = True,
    stats=None,
    n_words: int = 1,
) -> Plan:
    """Pick the executor for theta(T, .) over N bitmaps."""
    if stats is not None:
        n_words = stats.n_words
        if density is None:
            density = stats.density
    cands = _candidates(n, t, n_words=n_words, stats=stats, density=density)

    def plan(alg, why):
        cost = estimate_words_touched(
            alg, n, t, n_words=n_words, stats=stats, density=density
        )
        return _attach_us(Plan(alg, why, cost=cost, candidates=cands))

    if t <= 1:
        return plan("wide_or", "T<=1 is a wide OR (paper 2.3)")
    if t >= n:
        return plan("wide_and", "T=N is a wide AND (paper 2.3)")
    if stats is not None:
        tiled = estimate_words_touched("tiled_fused", n, t, n_words=n_words, stats=stats)
        # compare against the dense memory FLOOR (N reads + 1 write), not the
        # XLA-roundtrip estimate: skipping must pay off even vs a perfect sweep
        dense = estimate_words_touched("fused", n, t, n_words=n_words)
        if tiled is not None and tiled < _TILED_ADVANTAGE * dense:
            return plan(
                "tiled_fused",
                f"member columns are {stats.clean_fraction:.0%} clean tiles: "
                f"gather ~{int(tiled)} words vs ~{int(dense)} dense "
                "(paper 4.1 skipping, tile-classified store)",
            )
    elif clean_fraction is not None and clean_fraction > 0.5:
        return plan(
            "rbmrg_block",
            f"{clean_fraction:.0%} of tiles are clean runs; run-aware merge "
            "does O(RUNCOUNT log N) work (paper 4.1, 5.10)",
        )
    if n >= 2048:
        return plan(
            "scancount_streaming",
            "N huge: per-(N,T) circuit tabulation is infeasible; streaming "
            "counters keep an O(chunk x r) working set (paper section 6)",
        )
    if not on_device and density is not None and density < 1e-3 and t >= 0.9 * n:
        return plan(
            "dsk",
            "sparse data with T~N: pruning algorithms win on the host (paper 5.8.3)",
        )
    if stats is not None and cands:
        # cost-model path: the plan honors its own candidate ranking.
        # (Previously this fell through to the scalar-rule ssum/fused
        # default, picking ssum at ~10x the priced cost of fused whenever
        # the fused kernel wasn't flagged "available" -- but the fused
        # backend is runnable everywhere: Pallas on TPU, interpret/XLA
        # elsewhere, and BENCH_query wall times track the estimates.)
        # tiled_fused stays behind the _TILED_ADVANTAGE gate above -- its
        # estimate omits host gather/scatter bookkeeping, so it must win
        # by a margin, not by a hair.
        eligible = [kv for kv in cands if kv[0] != "tiled_fused"]
        if eligible:
            calib = get_calibration()
            ranked = (
                [(b, calib.cost_us(b, w)) for b, w in eligible]
                if calib is not None
                and all(calib.cost_us(b, w) is not None for b, w in eligible)
                else None
            )
            if ranked is not None:
                # calibrated path: rank by measured µs, not raw words --
                # the per-backend exchange rate is exactly what the words
                # model cannot know (host lists vs fused kernel vs XLA)
                best, cost_us = min(ranked, key=lambda kv: kv[1])
                return plan(
                    best,
                    f"min-cost candidate: ~{int(cost_us)}us calibrated "
                    f"({calib.device} words->us constants over member tile "
                    "statistics)",
                )
            best, cost = min(eligible, key=lambda kv: kv[1])
            return plan(
                best,
                f"min-cost candidate: ~{int(cost)} words touched "
                "(cost model over member tile statistics)",
            )
    if t <= 3:
        return plan("looped", "T very small: LOOPED is O(NT) ops and wins (paper 5.10)")
    if fused_available:
        return plan("fused", "default: sideways-sum adder, fused kernel (paper 5.10 + ours)")
    return plan("ssum", "default: sideways-sum adder circuit via XLA (paper 5.10)")


def _bare_threshold_members(query):
    """If ``query`` is a Threshold over plain columns (or all columns),
    return its member count resolver; else None."""
    from repro.query.expr import Col, Threshold

    if type(query) is not Threshold:
        return None
    if query.over is not None and not all(type(m) is Col for m in query.over):
        return None
    return (lambda n: n) if query.over is None else (lambda n: len(query.over))


def plan_query(
    query,
    n: int,
    *,
    density: float | None = None,
    clean_fraction: float | None = None,
    on_device: bool = True,
    fused_available: bool = True,
    stats=None,
    n_words: int = 1,
) -> Plan:
    """Pick the executor for a query expression over an N-column index."""
    from repro.query.expr import Col, Weighted, as_query

    q = as_query(query)
    if type(q) is Col:
        return _attach_us(Plan(
            "column", "bare column reference: fetch, no compute",
            cost=float(stats.n_words if stats is not None else n_words),
        ))
    members = _bare_threshold_members(q)
    if members is not None:
        return plan_threshold(
            members(n),
            q.t,
            density=density,
            clean_fraction=clean_fraction,
            on_device=on_device,
            fused_available=fused_available,
            stats=stats,
            n_words=n_words,
        )
    backend = "fused" if fused_available else "circuit"
    if stats is not None:
        n_words = stats.n_words
        tiled = estimate_words_touched("tiled_fused", n, None, n_words=n_words, stats=stats)
        dense = estimate_words_touched("fused", n, None, n_words=n_words)
        if tiled is not None and tiled < _TILED_ADVANTAGE * dense:
            return _attach_us(Plan(
                "tiled_fused",
                f"member columns are {stats.clean_fraction:.0%} clean tiles; the "
                "whole compiled circuit gets RBMRG case-skipping per tile "
                "(storage engine generalisation of paper 4.1)",
                cost=tiled,
                candidates=_candidates(n, None, n_words=n_words, stats=stats,
                                       density=density),
            ))
    cost = estimate_words_touched(backend, n, None, n_words=n_words)
    if type(q) is Weighted:
        return _attach_us(Plan(
            backend,
            "weighted threshold: binary weight decomposition circuit "
            "(O(log max_w) adders instead of replication; beyond-paper)",
            cost=cost,
        ))
    return _attach_us(Plan(
        backend,
        "symmetric/composite expression: one compiled circuit, sub-queries "
        "share the sideways-sum adder via CSE (paper 4.4 + query layer)",
        cost=cost,
    ))
