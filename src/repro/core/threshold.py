"""Threshold functions over packed bitmaps -- the paper's core contribution.

Every algorithm takes ``bitmaps: uint32[N, n_words]`` and a threshold ``T``
(static Python int) and returns the packed result ``uint32[n_words]`` whose
bit i is set iff at least T of the N input bitmaps have bit i set.

Algorithms (paper section in parentheses):
  * scancount   -- counter array over positions (4.2); also our oracle
  * looped      -- O(NT) bit-parallel dynamic program (4.5, Algorithm 3)
  * ssum        -- sideways-sum adder circuit (4.4.3)
  * treeadd     -- tree-of-adders circuit (4.4.2)
  * srtckt      -- Batcher sorting network (4.4.1)
  * sopckt      -- sum-of-products circuit (4.4), tiny N/T only
  * csvckt      -- carry-save vertical counter (4.5.1, Algorithm 4)
  * fused       -- Pallas kernel evaluating the ssum circuit in VMEM
                   (our TPU-native beyond-paper implementation)

All the circuit algorithms are evaluated as straight-line jnp bitwise code
(XLA = the paper's byte-code backend).  T is static: the paper tabulates
circuits per (N, T); we let `jax.jit` re-trace per (N, T) which is the same
tabulation realised through the XLA compile cache.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import circuits as _ckt
from .bitmaps import WORD_DTYPE

__all__ = ["threshold", "hamming_weight_words", "ALGORITHMS"]


# ---------------------------------------------------------------------------
# SCANCOUNT (4.2) -- the oracle: per-position counters
# ---------------------------------------------------------------------------


def _scancount(bitmaps: jax.Array, t: int) -> jax.Array:
    n = bitmaps.shape[0]
    # counter dtype chosen like the paper's byte/short/int switch
    if n < 128:
        cdt = jnp.int8
    elif n < (1 << 15):
        cdt = jnp.int16
    else:
        cdt = jnp.int32
    shifts = jnp.arange(32, dtype=jnp.uint32)
    bits = ((bitmaps[:, :, None] >> shifts) & jnp.uint32(1)).astype(cdt)
    counts = jnp.sum(bits, axis=0, dtype=cdt if n < 128 else jnp.int32)
    ge = counts >= jnp.asarray(t, counts.dtype)
    return jnp.sum(ge.astype(jnp.uint32) << shifts, axis=-1, dtype=jnp.uint32)


# ---------------------------------------------------------------------------
# LOOPED (4.5, Algorithm 3): C_j |= C_{j-1} & B_i
# ---------------------------------------------------------------------------


def _looped(bitmaps: jax.Array, t: int) -> jax.Array:
    n = bitmaps.shape[0]
    cs = [jnp.zeros_like(bitmaps[0]) for _ in range(t + 1)]  # cs[1..t]
    cs[1] = bitmaps[0]
    for i in range(1, n):
        b = bitmaps[i]
        for j in range(min(t, i + 1), 1, -1):
            cs[j] = cs[j] | (cs[j - 1] & b)
        cs[1] = cs[1] | b
    return cs[t]


# ---------------------------------------------------------------------------
# Circuit-based algorithms: build DAG at trace time, evaluate with jnp
# ---------------------------------------------------------------------------


def _circuit_threshold(bitmaps: jax.Array, t: int, kind: str) -> jax.Array:
    n = bitmaps.shape[0]
    circ = _ckt.build_threshold_circuit(n, t, kind)
    ins = [bitmaps[i] for i in range(n)]
    (out,) = circ.evaluate(ins)
    return out


def hamming_weight_words(bitmaps: jax.Array, kind: str = "ssum") -> list:
    """Vertical counter: list of packed weight-bit planes, LSB first."""
    n = bitmaps.shape[0]
    circ = _ckt.build_weight_circuit(n, kind)
    return circ.evaluate([bitmaps[i] for i in range(n)])


# ---------------------------------------------------------------------------
# CSVCKT (4.5.1, Algorithm 4): carry-save redundant vertical counter
# ---------------------------------------------------------------------------


def _csvckt(bitmaps: jax.Array, t: int) -> jax.Array:
    n = bitmaps.shape[0]
    zero = jnp.zeros_like(bitmaps[0])
    ndigits = 1 + int(np.floor(np.log2(2 * n)))
    c1 = [zero] * ndigits  # first bit of each redundant digit
    c2 = [zero] * ndigits  # second bit
    time = 0
    for i in range(n):
        c = bitmaps[i]
        time += 1
        x = (time & -time).bit_length() - 1  # number of trailing zeros of time
        for p in range(min(x, ndigits)):
            a, b = c1[p], c2[p]
            c1[p] = zero
            s = a ^ b
            c2[p] = s ^ c
            c = (a & b) | (c & s)
        # remaining carry parks in the next digit's (guaranteed-free) slot
        nxt = min(x, ndigits - 1)
        c1[nxt] = c1[nxt] | c
    # convert redundant encoding to binary
    v = []
    cin = zero
    for i in range(ndigits):
        a, b = c1[i], c2[i]
        s = a ^ b
        v.append(s ^ cin)
        cin = (a & b) | (cin & s)
    v.append(cin)
    # compare against T: add -T (two's complement over ndigits+1 bits) and
    # inspect the sign bit (paper: "subtract T and check the sign")
    width = len(v)
    neg_t = (-t) & ((1 << width) - 1)
    cin = zero
    out = []
    for i in range(width):
        a = v[i]
        if (neg_t >> i) & 1:
            s = ~a
            out.append(s ^ cin)
            cin = a | (cin & s)
        else:
            s = a
            out.append(s ^ cin)
            cin = cin & s
    return ~out[width - 1]  # sign bit clear => count - T >= 0


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

def _scancount_streaming(bitmaps: jax.Array, t: int, chunk: int = 128) -> jax.Array:
    """SCANCOUNT with a lax.scan over input chunks: O(r) counter state and
    O(chunk * r) working set regardless of N -- the answer to the paper's
    6 question ("would there be applications where N = 1,000,000?"): the
    circuit family is infeasible there, streaming counters are not."""
    n, nw = bitmaps.shape
    pad = (-n) % chunk
    if pad:
        bitmaps = jnp.concatenate([bitmaps, jnp.zeros((pad, nw), WORD_DTYPE)])
    shifts = jnp.arange(32, dtype=jnp.uint32)

    def body(counts, blk):
        bits = ((blk[:, :, None] >> shifts) & jnp.uint32(1)).astype(jnp.int32)
        return counts + bits.sum(0), 0

    counts0 = jnp.zeros((nw, 32), jnp.int32)
    counts, _ = jax.lax.scan(body, counts0, bitmaps.reshape(-1, chunk, nw))
    return jnp.sum((counts >= t).astype(jnp.uint32) << shifts, axis=-1, dtype=jnp.uint32)


# Every name is a runnable executor (the seed's planner emitted wide_or /
# rbmrg_block / dsk names that threshold() rejected; no longer).
ALGORITHMS = (
    "scancount", "scancount_streaming", "looped", "ssum", "treeadd", "srtckt",
    "sopckt", "csvckt", "fused", "tiled_fused", "wide_or", "wide_and",
    "rbmrg_block", "dsk",
)


def threshold(bitmaps: jax.Array, t: int, algorithm: str = "ssum") -> jax.Array:
    """theta(T, {B_1..B_N}) over packed bitmaps; returns a packed bitmap.

    T=1 is a wide OR and T=N a wide AND (the paper's degenerate cases);
    those short-circuit for every algorithm except the explicit circuits.

    .. deprecated:: prefer the query layer --
       ``repro.query.BitmapIndex.execute(Threshold(t))`` plans the backend
       from data statistics and composes with other queries; the string
       ``algorithm=`` argument survives as an explicit backend override.
       This shim delegates to ``repro.query.executors.run_threshold_backend``.
    """
    from repro.query.executors import run_threshold_backend

    return run_threshold_backend(bitmaps, t, algorithm)


def weighted_threshold(
    bitmaps: jax.Array, weights: Sequence[int], t: int, algorithm: str = "ssum"
) -> jax.Array:
    """Weighted threshold via input replication (paper 2.3).

    Integer weight w_i means bitmap i is replicated w_i times.  Practical
    only for small weights, exactly as the paper notes.
    """
    reps = []
    for i, w in enumerate(weights):
        if w < 0:
            raise ValueError("weights must be non-negative integers")
        reps.extend([i] * int(w))
    if not reps:
        raise ValueError("all weights zero")
    expanded = jnp.take(bitmaps, jnp.asarray(reps), axis=0)
    return threshold(expanded, t, algorithm)
