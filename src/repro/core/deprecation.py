"""One consolidated DeprecationWarning for the legacy free-function shims.

``kernels.ops.fused_*`` and ``core.symmetric.*`` predate the query layer;
they now execute through ``repro.query.execute`` (which builds a transient
``BitmapIndex`` on a TileStore, so the planner routes clean-heavy data
through the tiled path automatically).  Rather than one warning per call
-- these shims sit in loops -- the whole family emits a single
DeprecationWarning per process, naming the replacement.
"""
from __future__ import annotations

import warnings

_warned = False


def warn_legacy_shim(name: str) -> None:
    """Emit the family-wide DeprecationWarning once per process."""
    global _warned
    if _warned:
        return
    _warned = True
    warnings.warn(
        f"{name} (and the other kernels.ops.fused_* / core.symmetric.* "
        "free functions) is a deprecated shim over repro.query; use "
        "BitmapIndex.execute, which plans from TileStore statistics and "
        "routes clean-heavy data through the tiled_fused backend. "
        "This warning is emitted once for the whole shim family.",
        DeprecationWarning,
        stacklevel=3,
    )


def reset_legacy_shim_warning() -> None:
    """Re-arm the once-per-process warning (for tests)."""
    global _warned
    _warned = False
