"""Boolean-circuit construction for threshold / symmetric functions.

Builds the paper's gate DAGs (Tree adder = TREEADD, sideways sum = SSUM,
Batcher sorting network = SRTCKT, sum-of-products = SOPCKT) with the exact
adder decomposition used in the paper (Algorithm 4 / Appendix B):

    half adder:  s = a ^ b                 (1 gate)
                 c = a & b                 (1 gate)
    full adder:  s  = a ^ b               (1 gate)
                 s2 = s ^ cin             (1 gate)
                 c  = (a & b) | (cin & s)  (3 gates)

so HA = 2 gates and FA = 5 gates, and the *sum* XOR of the last adder is
removable by dead-code elimination when the low weight bit is unused --
which is what makes our op counts reproduce the paper's Tables 6-8
(e.g. the tree adder's c(2^k) = 7N - 5 log2 N - 7 and the sideways sum's
s(N) = 2, 9, 26, 63, 140 for N = 2..32, plus the comparator).

The circuit is "compiled" by evaluating the DAG over uint32 word arrays
with jnp bitwise ops -- XLA plays the role of the paper's straight-line
byte-code backend, and XLA buffer assignment plays register allocation.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Callable, Sequence

import jax.numpy as jnp

# Node encoding: each gate is a tuple (op, a, b) where a/b are node ids.
# Special ids: CONST0 = -1, CONST1 = -2. Inputs are nodes with op == "in".
CONST0 = -1
CONST1 = -2

_BINOPS = ("and", "or", "xor", "andnot")


@dataclasses.dataclass
class Circuit:
    """A gate DAG over ``n_inputs`` inputs with a list of output node ids."""

    n_inputs: int
    ops: list  # list of (op, a, b); node id = n_inputs + index
    outputs: list  # node ids

    def node(self, op: str, a: int, b: int) -> int:
        self.ops.append((op, a, b))
        return self.n_inputs + len(self.ops) - 1

    # -- builders -------------------------------------------------------
    def AND(self, a, b):
        return self.node("and", a, b)

    def OR(self, a, b):
        return self.node("or", a, b)

    def XOR(self, a, b):
        return self.node("xor", a, b)

    def ANDNOT(self, a, b):
        """a AND NOT b (counts as a single 2-input op, as in the paper)."""
        return self.node("andnot", a, b)

    def NOT(self, a):
        # Realised as CONST1 ANDNOT: (1 & ~a). Counted as one op.
        return self.node("andnot", CONST1, a)

    def half_adder(self, a, b):
        s = self.XOR(a, b)
        c = self.AND(a, b)
        return s, c

    def full_adder(self, a, b, cin):
        s1 = self.XOR(a, b)
        s = self.XOR(s1, cin)
        c = self.OR(self.AND(a, b), self.AND(cin, s1))
        return s, c

    def wide_or(self, xs: Sequence[int]) -> int:
        xs = [x for x in xs]
        if not xs:
            return CONST0
        acc = xs[0]
        for x in xs[1:]:
            acc = self.OR(acc, x)
        return acc

    def wide_and(self, xs: Sequence[int]) -> int:
        xs = [x for x in xs]
        if not xs:
            return CONST1
        acc = xs[0]
        for x in xs[1:]:
            acc = self.AND(acc, x)
        return acc

    # -- accounting ------------------------------------------------------
    def gate_count(self) -> int:
        return len(self.ops)

    # -- optimisation ----------------------------------------------------
    def optimized(self, comp_folds: bool = False) -> "Circuit":
        """Constant folding + CSE + dead-code elimination (paper 4.4.5).

        ``comp_folds`` additionally tracks complements (nodes built as
        ``NOT x``) and folds ``x AND NOT x -> 0`` etc.  It is used by
        :meth:`specialize` so residual tile circuits collapse to constants
        in the RBMRG case-2 regime; it is off by default to keep the gate
        counts of the paper's reference constructions untouched.
        """
        new_ops: list = []
        remap: dict[int, int] = {}
        cse: dict[tuple, int] = {}
        comp: dict[int, int] = {}  # node -> its complement (both directions)

        def resolve(i: int) -> int:
            if i < 0 or i < self.n_inputs:
                return i
            return remap[i]

        for idx, (op, a, b) in enumerate(self.ops):
            nid = self.n_inputs + idx
            a, b = resolve(a), resolve(b)
            folded = _fold(op, a, b)
            if folded is None and comp_folds:
                folded = _fold_complement(op, a, b, comp)
            if folded is not None:
                remap[nid] = folded
                continue
            # canonicalise commutative ops for CSE
            key_a, key_b = (a, b)
            if op in ("and", "or", "xor") and key_b < key_a:
                key_a, key_b = key_b, key_a
            key = (op, key_a, key_b)
            if key in cse:
                remap[nid] = cse[key]
                continue
            new_ops.append((op, a, b))
            out_id = self.n_inputs + len(new_ops) - 1
            remap[nid] = out_id
            cse[key] = out_id
            if comp_folds:
                # NOT is realised as (1 ANDNOT x) or (1 XOR x)
                if (op == "andnot" and a == CONST1) or (op == "xor" and key_a == CONST1):
                    other = b if op == "andnot" else key_b
                    comp[out_id] = other
                    comp[other] = out_id

        outputs = [resolve(o) for o in self.outputs]
        pruned = Circuit(self.n_inputs, new_ops, outputs)
        return pruned._dce()

    def _dce(self) -> "Circuit":
        live = set(o for o in self.outputs if o >= self.n_inputs)
        order = sorted(live, reverse=True)
        seen = set(live)
        # walk backwards marking fan-in
        stack = list(order)
        while stack:
            nid = stack.pop()
            op, a, b = self.ops[nid - self.n_inputs]
            for x in (a, b):
                if x >= self.n_inputs and x not in seen:
                    seen.add(x)
                    stack.append(x)
        keep = sorted(seen)
        remap = {old: self.n_inputs + i for i, old in enumerate(keep)}

        def rm(i):
            return remap.get(i, i) if i >= self.n_inputs else i

        new_ops = [
            (op, rm(a), rm(b)) for old in keep for (op, a, b) in [self.ops[old - self.n_inputs]]
        ]
        return Circuit(self.n_inputs, new_ops, [rm(o) for o in self.outputs])

    # -- partial evaluation ----------------------------------------------
    def support(self) -> list:
        """Input ids actually reachable from the outputs (post-DCE inputs)."""
        live = set()
        seen = set(o for o in self.outputs if o >= self.n_inputs)
        stack = list(seen)
        for o in self.outputs:
            if 0 <= o < self.n_inputs:
                live.add(o)
        while stack:
            nid = stack.pop()
            op, a, b = self.ops[nid - self.n_inputs]
            for x in (a, b):
                if 0 <= x < self.n_inputs:
                    live.add(x)
                elif x >= self.n_inputs and x not in seen:
                    seen.add(x)
                    stack.append(x)
        return sorted(live)

    def specialize(self, assign: dict):
        """Partially evaluate with ``assign``: input id -> CONST0/CONST1.

        Returns ``(const_outputs, residual, kept_inputs)`` where
        ``const_outputs[j]`` is 0/1 when output j folded to a constant (else
        None), ``residual`` is an optimised circuit over the unassigned
        inputs computing the non-constant outputs (None if every output is
        constant), and ``kept_inputs`` lists the original ids of the
        residual's inputs in order.  This is the tile-skipping engine: with
        all-zero/all-one tiles assigned as constants, constant outputs are
        the RBMRG case-1/2 tiles and the residual circuit is the case-3
        dirty-resolution work.
        """
        for i, v in assign.items():
            if not 0 <= i < self.n_inputs or v not in (CONST0, CONST1):
                raise ValueError(f"bad assignment {i} -> {v}")
        kept = [i for i in range(self.n_inputs) if i not in assign]
        imap = {old: new for new, old in enumerate(kept)}

        sub = Circuit(len(kept), [], [])
        # node-id shift: gates keep their order, ids move with n_inputs delta
        shift = sub.n_inputs - self.n_inputs

        def remap(i):
            if i < 0:  # CONST0 / CONST1
                return i
            if i < self.n_inputs:
                return assign[i] if i in assign else imap[i]
            return i + shift

        for op, a, b in self.ops:
            sub.node(op, remap(a), remap(b))
        sub.outputs = [remap(o) for o in self.outputs]
        opt = sub.optimized(comp_folds=True)
        const = [
            (0 if o == CONST0 else 1) if o in (CONST0, CONST1) else None
            for o in opt.outputs
        ]
        live = [j for j, c in enumerate(const) if c is None]
        if not live:
            return const, None, kept
        residual = Circuit(opt.n_inputs, opt.ops, [opt.outputs[j] for j in live])._dce()
        # Exact semantic constancy (folding can miss e.g. z1 OR z2 == 1 inside
        # an adder): evaluate the whole truth table at once over bigint masks.
        # Only for small support -- larger residuals are real case-3 work.
        if 1 <= residual.n_inputs <= _EXACT_CONST_MAX_INPUTS:
            outs = residual.evaluate(*_truth_table_masks(residual.n_inputs))
            full = (1 << (1 << residual.n_inputs)) - 1
            for j, v in zip(live, outs):
                if v == 0:
                    const[j] = 0
                elif v == full:
                    const[j] = 1
            still = [j for j in live if const[j] is None]
            if not still:
                return const, None, kept
            if len(still) != len(live):
                pos = {j: i for i, j in enumerate(live)}
                residual = Circuit(
                    residual.n_inputs, residual.ops,
                    [residual.outputs[pos[j]] for j in still],
                )._dce()
        return const, residual, kept

    def semantic_key(self) -> tuple:
        """Gate-order-independent identity of the computed function(s).

        A Merkle hash over the DAG: each node's digest is built from its op
        and its operands' digests (sorted for commutative ops), so two
        circuits that encode the same expression DAG with different gate
        orderings -- e.g. residuals of :meth:`specialize` under different
        constant assignments that fold to the same shape -- get the same
        key.  The tiled executor merges such residuals into one kernel
        launch.  ``n_inputs`` is part of the key because callers gather one
        data row per declared input, read or not.
        """
        import hashlib

        digests: dict[int, bytes] = {}

        def key_of(i: int) -> bytes:
            if i == CONST0:
                return b"0"
            if i == CONST1:
                return b"1"
            if i < self.n_inputs:
                return b"i%d" % i
            return digests[i]

        for idx, (op, a, b) in enumerate(self.ops):
            ka, kb = key_of(a), key_of(b)
            if op in ("and", "or", "xor") and kb < ka:
                ka, kb = kb, ka
            digests[self.n_inputs + idx] = hashlib.md5(
                b"%s(%s,%s)" % (op.encode(), ka, kb)
            ).digest()
        return (self.n_inputs, tuple(key_of(o) for o in self.outputs))

    # -- evaluation -------------------------------------------------------
    def evaluate(self, inputs: Sequence, zeros=None, ones=None):
        """Evaluate the DAG over word arrays (or Python ints for testing)."""
        if zeros is None:
            zeros = jnp.zeros_like(inputs[0])
        if ones is None:
            ones = jnp.full_like(inputs[0], 0xFFFFFFFF)
        vals: dict[int, object] = {}

        def get(i):
            if i == CONST0:
                return zeros
            if i == CONST1:
                return ones
            if i < self.n_inputs:
                return inputs[i]
            return vals[i]

        for idx, (op, a, b) in enumerate(self.ops):
            va, vb = get(a), get(b)
            if op == "and":
                out = va & vb
            elif op == "or":
                out = va | vb
            elif op == "xor":
                out = va ^ vb
            elif op == "andnot":
                out = va & ~vb
            else:  # pragma: no cover
                raise ValueError(op)
            vals[self.n_inputs + idx] = out
        return [get(o) for o in self.outputs]


def _fold(op, a, b):
    """Constant folding / unary-gate elimination rules (paper 4.4.5)."""
    if op == "and":
        if a == CONST0 or b == CONST0:
            return CONST0
        if a == CONST1:
            return b
        if b == CONST1:
            return a
        if a == b:
            return a
    elif op == "or":
        if a == CONST1 or b == CONST1:
            return CONST1
        if a == CONST0:
            return b
        if b == CONST0:
            return a
        if a == b:
            return a
    elif op == "xor":
        if a == CONST0:
            return b
        if b == CONST0:
            return a
        if a == b:
            return CONST0
    elif op == "andnot":  # a & ~b
        if a == CONST0 or b == CONST1 or a == b:
            return CONST0
        if b == CONST0:
            return a
    return None


# specialize(): exact constancy detection is exponential in the residual
# support, so it is capped; 2^16-bit ints are ~8 KB, still cheap per gate.
_EXACT_CONST_MAX_INPUTS = 16


def _truth_table_masks(d: int):
    """(inputs, zeros, ones) for evaluating a d-input circuit over its whole
    truth table at once: input j's mask has bit a set iff (a >> j) & 1."""
    size = 1 << d
    full = (1 << size) - 1
    masks = []
    for j in range(d):
        half = 1 << j  # table entries per half-period
        seg = ((1 << half) - 1) << half  # one period: half zeros, half ones
        rep = full // ((1 << (2 * half)) - 1) if 2 * half < size else 1
        masks.append(seg * rep)
    return masks, 0, full


def _fold_complement(op, a, b, comp: dict):
    """Folds enabled by knowing b == NOT a (see Circuit.optimized)."""
    if comp.get(a) != b:
        return None
    if op == "and":
        return CONST0
    if op in ("or", "xor"):
        return CONST1
    if op == "andnot":  # a & ~(~a) = a
        return a
    return None


# ---------------------------------------------------------------------------
# Hamming-weight circuits
# ---------------------------------------------------------------------------


def sideways_sum_bits(c: Circuit, bits: Sequence[int]) -> list:
    """Knuth's sideways sum (paper 4.4.3, Fig. 2).

    Each level chains full adders (the sum bit of one adder feeds the next
    adder's carry-in), reducing m same-weight bits to one output bit z_x and
    ~m/2 bits of double weight.  Returns weight bits [z0, z1, ...] (LSB first).
    """
    zs = []
    level = list(bits)
    while level:
        if len(level) == 1:
            zs.append(level[0])
            level = []
            continue
        carries = []
        s = level[0]
        i = 1
        while i < len(level):
            if i + 1 < len(level):
                s, cy = c.full_adder(s, level[i], level[i + 1])
                i += 2
            else:
                s, cy = c.half_adder(s, level[i])
                i += 1
            carries.append(cy)
        zs.append(s)
        level = carries
    return zs


def tree_adder_bits(c: Circuit, bits: Sequence[int]) -> list:
    """Tree of ripple-carry adders (paper 4.4.2, Fig. 1).

    Pads the input count to a power of two with constant zeros; the
    constant-propagation pass removes the padding gates afterwards.
    Returns weight bits LSB-first.
    """
    n = len(bits)
    size = 1 << max(1, math.ceil(math.log2(max(n, 2))))
    padded = list(bits) + [CONST0] * (size - n)
    # numbers are (bit-list LSB-first, max-value) pairs; value-range tracking
    # suppresses carry bits that are provably zero (so the gate counts track
    # the true maximum sum for non-power-of-two N, matching paper Table 8)
    numbers = [([b], 0 if b == CONST0 else 1) for b in padded]
    while len(numbers) > 1:
        nxt = []
        for i in range(0, len(numbers), 2):
            (a, amax), (b, bmax) = numbers[i], numbers[i + 1]
            if len(a) < len(b):
                a, b = b, a
            b = b + [CONST0] * (len(a) - len(b))
            nxt.append((_ripple_add(c, a, b, amax + bmax), amax + bmax))
        numbers = nxt
    out_bits, out_max = numbers[0]
    need = max(1, out_max.bit_length())
    return out_bits[:need]


def _ripple_add(c: Circuit, xs: list, ys: list, maxv: int) -> list:
    assert len(xs) == len(ys)
    out = []
    s, carry = c.half_adder(xs[0], ys[0])
    out.append(s)
    for a, b in zip(xs[1:], ys[1:]):
        s, carry = c.full_adder(a, b, carry)
        out.append(s)
    if maxv >= (1 << len(xs)):
        out.append(carry)
    else:
        out.append(CONST0)
    return out


# ---------------------------------------------------------------------------
# >= T comparator against a constant (paper 4.4.2's prefix_match circuit)
# ---------------------------------------------------------------------------


def ge_const(c: Circuit, weight_bits: Sequence[int], t: int) -> int:
    """Return node computing (binary number ``weight_bits``) >= t.

    Implements the paper's optimised constant comparator: with a = t - 1,
    result = OR over zero-positions j of a of (prefix_match(j) & b_j) where
    prefix_match(j) = AND of b_k over k > j with a_k = 1, shared incrementally.
    """
    n = len(weight_bits)
    if t <= 0:
        return CONST1
    if t >= (1 << n) + 1:
        return CONST0
    a = t - 1
    if a >= (1 << n):
        return CONST0
    terms = []
    prefix = None  # AND of b_k at one-positions seen so far (left to right)
    for j in range(n - 1, -1, -1):
        bit_a = (a >> j) & 1
        bj = weight_bits[j]
        if bit_a == 0:
            if prefix is None:
                terms.append(bj)
            else:
                terms.append(c.AND(prefix, bj))
        else:
            prefix = bj if prefix is None else c.AND(prefix, bj)
    return c.wide_or(terms)


def le_const(c: Circuit, weight_bits: Sequence[int], t: int) -> int:
    """weight <= t as NOT(weight >= t+1); used for interval functions."""
    ge = ge_const(c, weight_bits, t + 1)
    return c.NOT(ge) if ge >= 0 else (CONST1 if ge == CONST0 else CONST0)


# ---------------------------------------------------------------------------
# Batcher odd-even sorting network (SRTCKT)
# ---------------------------------------------------------------------------


def _batcher_pairs(n: int):
    """Comparator pairs of Batcher's odd-even mergesort on n wires."""
    pairs = []
    p = 1
    while p < n:
        k = p
        while k >= 1:
            for j in range(k % p, n - k, 2 * k):
                for i in range(0, k):
                    if (i + j) // (2 * p) == (i + j + k) // (2 * p):
                        pairs.append((i + j, i + j + k))
            k //= 2
        p *= 2
    return pairs


def sorter_outputs(c: Circuit, bits: Sequence[int]) -> list:
    """Sorting network outputs, descending (ones first).

    Output wire ``T-1`` is then exactly the T-threshold (paper 4.4.1).
    """
    wires = list(bits)
    n = len(wires)
    size = 1 << max(1, math.ceil(math.log2(max(n, 2))))
    wires = wires + [CONST0] * (size - n)

    def comp(i, j):
        hi = c.OR(wires[i], wires[j])
        lo = c.AND(wires[i], wires[j])
        wires[i], wires[j] = hi, lo

    for i, j in _batcher_pairs(len(wires)):
        comp(i, j)
    return wires[:n]


# ---------------------------------------------------------------------------
# Top-level circuit constructors
# ---------------------------------------------------------------------------


def build_threshold_circuit(n: int, t: int, kind: str) -> Circuit:
    """Build an optimised circuit computing theta(t, N inputs).

    kind in {"ssum", "treeadd", "srtckt", "sopckt"}.
    """
    c = Circuit(n, [], [])
    ins = list(range(n))
    if t <= 0:
        c.outputs = [CONST1]
        return c
    if t > n:
        c.outputs = [CONST0]
        return c
    if t == 1 and kind != "sopckt":
        c.outputs = [c.wide_or(ins)]
        return c.optimized()
    if t == n and kind != "sopckt":
        c.outputs = [c.wide_and(ins)]
        return c.optimized()
    if kind == "ssum":
        out = ge_const(c, sideways_sum_bits(c, ins), t)
    elif kind == "treeadd":
        out = ge_const(c, tree_adder_bits(c, ins), t)
    elif kind == "srtckt":
        out = sorter_outputs(c, ins)[t - 1]
    elif kind == "sopckt":
        import itertools

        terms = [c.wide_and(list(combo)) for combo in itertools.combinations(ins, t)]
        out = c.wide_or(terms)
    else:  # pragma: no cover
        raise ValueError(kind)
    c.outputs = [out]
    return c.optimized()


def build_weight_circuit(n: int, kind: str = "ssum") -> Circuit:
    """Circuit whose outputs are the Hamming-weight bits (LSB first)."""
    c = Circuit(n, [], [])
    ins = list(range(n))
    bits = sideways_sum_bits(c, ins) if kind == "ssum" else tree_adder_bits(c, ins)
    c.outputs = list(bits)
    return c.optimized()


def build_symmetric_circuit(n: int, truth: Sequence[bool], kind: str = "ssum") -> Circuit:
    """Circuit for an arbitrary symmetric function given by its value on
    each Hamming weight 0..n (paper 2.2 / 4.4: synthesise from weight bits)."""
    assert len(truth) == n + 1
    c = Circuit(n, [], [])
    bits = sideways_sum_bits(c, list(range(n))) if kind == "ssum" else tree_adder_bits(
        c, list(range(n))
    )
    nb = len(bits)
    # Sum-of-products over the weight bits, with a tiny optimisation: merge
    # contiguous true-runs [lo, hi] into interval tests (>=lo AND NOT >=hi+1).
    runs = []
    w = 0
    while w <= n:
        if truth[w]:
            lo = w
            while w + 1 <= n and truth[w + 1]:
                w += 1
            runs.append((lo, w))
        w += 1
    terms = []
    for lo, hi in runs:
        ge_lo = ge_const(c, bits, lo)
        if hi >= n:
            terms.append(ge_lo)
        else:
            ge_hi1 = ge_const(c, bits, hi + 1)
            terms.append(c.ANDNOT(ge_lo, ge_hi1))
    c.outputs = [c.wide_or(terms)]
    return c.optimized()


def build_interval_circuit(n: int, lo: int, hi: int, kind: str = "ssum") -> Circuit:
    truth = [lo <= w <= hi for w in range(n + 1)]
    return build_symmetric_circuit(n, truth, kind)


# Reference formulas from the paper, used by tests/benchmarks --------------


def paper_tree_adder_gates(n_pow2: int) -> int:
    """c(2^k) = 7N - 5 log2 N - 7 (paper 4.4.2)."""
    k = int(math.log2(n_pow2))
    assert 1 << k == n_pow2
    return 7 * n_pow2 - 5 * k - 7


def looped_op_count(n: int, t: int) -> int:
    """2NT - N - T^2 + T - 1 binary ops (paper 4.5)."""
    return 2 * n * t - n - t * t + t - 1
