"""Per-backend words→microseconds calibration (feedback-calibrated planner).

The planner's cost model (``core.planner.estimate_words_touched``) prices
every candidate backend in *words moved through the memory system* -- a
unit that ranks backends on one device but says nothing about wall time,
and whose per-backend exchange rate differs across devices (a word moved
by the fused Pallas kernel costs different nanoseconds than a word moved
by the host-side DSK lists or the XLA circuit family).

A :class:`Calibration` closes that loop: it holds measured per-backend
roofline constants

    ``cost_us(backend, words) = dispatch_us[backend]
                                + words * us_per_kword[backend] / 1024``

obtained either from a one-off measurement pass
(:func:`measure_calibration` -- tiny timed executions per backend on a
synthetic index) or fed back from real executions as they happen
(:meth:`Calibration.observe`, an EWMA -- the serving front-end calls it
after every micro-batch).  When a calibration is installed
(:func:`set_calibration`), ``plan_threshold`` ranks its min-cost
candidates by calibrated microseconds instead of raw words, and every
:class:`~repro.core.planner.Plan` carries both scales (``cost`` /
``candidates`` in words, ``cost_us`` / ``candidates_us`` in µs).

Constants persist as JSON next to snapshots (``repro.persist.calibration``)
so a restarted server skips the measurement pass.
"""
from __future__ import annotations

import dataclasses
import time

__all__ = [
    "Calibration",
    "device_signature",
    "get_calibration",
    "set_calibration",
    "clear_calibration",
    "measure_calibration",
]

#: backends the measurement pass times by default: the device circuit
#: family's representatives plus the specialised paths the planner
#: actually emits on serving-shaped data
DEFAULT_BACKENDS = (
    "fused",
    "ssum",
    "tiled_fused",
    "looped",
    "scancount_streaming",
    "wide_or",
    "wide_and",
)

# observations are EWMA-blended with this weight (recent executions
# dominate after ~1/alpha samples)
_EWMA_ALPHA = 0.2

# a single observation can be wildly off (GC pause, first-call compile);
# clamp each observed constant to this band around the running value
_OBS_CLAMP = 8.0

#: device strings exempt from topology-staleness checks: "identity" is the
#: synthetic uniform calibration (device-independent by construction) and
#: "unknown" is the blank default a caller fills by observation
_PORTABLE_DEVICES = ("identity", "unknown")


def device_signature() -> str:
    """The current execution topology: ``<backend>x<device_count>``.

    A constant measured on one topology is meaningless on another (8-device
    sharded dispatch amortises differently than single-device; TPU words/µs
    says nothing about CPU), so calibrations are stamped with this
    signature and reset when it no longer matches -- the EWMA alone never
    recovers from a swap because :meth:`Calibration.observe` clamps each
    sample to a band around the dead running value.
    """
    import jax

    return f"{jax.default_backend()}x{jax.device_count()}"


@dataclasses.dataclass
class Calibration:
    """Measured per-backend roofline constants for one device.

    ``us_per_kword`` maps backend name to microseconds per 1024 words
    touched; ``dispatch_us`` is the fixed per-execution launch/trace cost.
    Unknown backends have no opinion (``cost_us`` returns None) so the
    planner falls back to the words model for them.
    """

    device: str = "unknown"
    us_per_kword: dict = dataclasses.field(default_factory=dict)
    dispatch_us: dict = dataclasses.field(default_factory=dict)
    samples: dict = dataclasses.field(default_factory=dict)

    def cost_us(self, backend: str, words: float | None) -> float | None:
        """Calibrated microsecond estimate; None without a constant or a
        words estimate.  Strictly monotone in ``words`` for any backend --
        calibration rescales the words model per backend, it never inverts
        the within-backend ordering."""
        k = self.us_per_kword.get(backend)
        if k is None or words is None:
            return None
        return self.dispatch_us.get(backend, 0.0) + float(words) * k / 1024.0

    def is_stale(self, signature: str | None = None) -> bool:
        """True when the constants were recorded on a different topology
        than the current one (portable devices are never stale)."""
        if self.device in _PORTABLE_DEVICES:
            return False
        return self.device != (signature or device_signature())

    def reset_for_device(self, signature: str | None = None) -> None:
        """Drop constants recorded on another topology and re-stamp.

        The EWMA cannot decay its way out of a device swap: each observation
        is clamped to within ``_OBS_CLAMP`` of the running value, so a
        constant that is 1000x wrong on the new topology keeps steering the
        planner essentially forever.  A topology change therefore resets to
        a blank slate; the first observation per backend re-admits at the
        observed rate, and the planner falls back to the words model until
        then."""
        self.device = signature or device_signature()
        self.us_per_kword.clear()
        self.dispatch_us.clear()
        self.samples.clear()

    def observe(self, backend: str, words: float | None, seconds: float) -> None:
        """Fold one measured execution back into the constants (EWMA).

        ``words`` is the plan's estimate for the execution (``Plan.cost``);
        the dispatch floor is attributed first and the remainder prices the
        per-word rate.  Unknown backends are admitted at the observed rate.
        A calibration recorded on a different topology is reset first --
        dead constants must not anchor the clamp band (see
        :meth:`reset_for_device`).
        """
        if words is None or words <= 0 or seconds <= 0:
            return
        if self.is_stale():
            self.reset_for_device()
            if self is _ACTIVE:
                _bump_generation()
        us = seconds * 1e6
        disp = self.dispatch_us.get(backend, 0.0)
        k_obs = max(us - disp, us * 0.1) * 1024.0 / float(words)
        k_old = self.us_per_kword.get(backend)
        if k_old is None:
            self.us_per_kword[backend] = k_obs
        else:
            k_obs = min(max(k_obs, k_old / _OBS_CLAMP), k_old * _OBS_CLAMP)
            self.us_per_kword[backend] = (
                (1.0 - _EWMA_ALPHA) * k_old + _EWMA_ALPHA * k_obs
            )
        self.samples[backend] = int(self.samples.get(backend, 0)) + 1

    # -- (de)serialisation -------------------------------------------------
    def to_obj(self) -> dict:
        return {
            "device": self.device,
            "us_per_kword": {k: float(v) for k, v in sorted(self.us_per_kword.items())},
            "dispatch_us": {k: float(v) for k, v in sorted(self.dispatch_us.items())},
            "samples": {k: int(v) for k, v in sorted(self.samples.items())},
        }

    @classmethod
    def from_obj(cls, obj: dict) -> "Calibration":
        return cls(
            device=str(obj.get("device", "unknown")),
            us_per_kword={str(k): float(v) for k, v in obj.get("us_per_kword", {}).items()},
            dispatch_us={str(k): float(v) for k, v in obj.get("dispatch_us", {}).items()},
            samples={str(k): int(v) for k, v in obj.get("samples", {}).items()},
        )

    @classmethod
    def identity(cls, backends=DEFAULT_BACKENDS, *, us_per_kword: float = 1.0) -> "Calibration":
        """A uniform calibration: every backend pays the same rate, so
        calibrated ranking coincides with the words-touched ranking (the
        regression anchor in tests)."""
        return cls(
            device="identity",
            us_per_kword={b: float(us_per_kword) for b in backends},
        )


# ---------------------------------------------------------------------------
# Active-calibration registry (what the planner consults)
# ---------------------------------------------------------------------------

_ACTIVE: Calibration | None = None
_GENERATION = 0  # bumped on install; plan memos key on it


def _bump_generation() -> None:
    global _GENERATION
    _GENERATION += 1


def get_calibration() -> Calibration | None:
    """The installed calibration, topology-checked: constants recorded on
    a device signature that no longer matches are reset (and the plan-memo
    generation bumped) before the planner can price with them."""
    if _ACTIVE is not None and _ACTIVE.is_stale():
        _ACTIVE.reset_for_device()
        _bump_generation()
    return _ACTIVE


def calibration_generation() -> int:
    """Monotone counter bumped by :func:`set_calibration` -- cache keys
    that embed calibrated prices (the plan memo) include it, so swapping
    constants invalidates stale plans without touching the caches."""
    return _GENERATION


def set_calibration(calib: Calibration | None) -> None:
    global _ACTIVE, _GENERATION
    _ACTIVE = calib
    _GENERATION += 1


def clear_calibration() -> None:
    set_calibration(None)


# ---------------------------------------------------------------------------
# Measurement pass
# ---------------------------------------------------------------------------


def measure_calibration(
    backends=DEFAULT_BACKENDS,
    *,
    n: int = 16,
    n_words: int = 2048,
    repeats: int = 3,
    seed: int = 0,
) -> Calibration:
    """Time each backend on a small synthetic index and derive constants.

    One warm-up execution per backend absorbs compilation, then the median
    of ``repeats`` timed runs prices the words the planner's own model says
    the backend touches -- the constant is exactly the words→µs exchange
    rate that makes ``Plan.cost`` comparable across backends on THIS
    device.  Runs in ~a second on CPU at the default shape.
    """
    import jax
    import numpy as np

    from repro.core.planner import estimate_words_touched
    from repro.query import BitmapIndex, Threshold

    rng = np.random.default_rng(seed)
    # mixed-density columns so the tiled path has real dirty tiles to price
    bits = rng.random((n, n_words * 32)) < rng.uniform(0.05, 0.5, (n, 1))
    bits[: max(1, n // 4), : (n_words * 16)] = False  # some clean territory
    idx = BitmapIndex.from_dense(bits)
    stats = idx.store.member_stats(None)
    calib = Calibration(device=device_signature())
    for backend in backends:
        t = {"wide_or": 1, "wide_and": n}.get(backend, max(2, n // 2))
        q = Threshold(t)
        words = estimate_words_touched(
            backend, n, t, n_words=n_words, stats=stats, density=stats.density
        )
        if words is None:
            continue
        try:
            jax.block_until_ready(idx.execute(q, backend=backend))  # warm-up
            times = []
            for _ in range(repeats):
                t0 = time.perf_counter()
                jax.block_until_ready(idx.execute(q, backend=backend))
                times.append(time.perf_counter() - t0)
        except Exception:  # noqa: BLE001 -- a backend missing on this device
            continue
        med = sorted(times)[len(times) // 2]
        calib.us_per_kword[backend] = med * 1e6 * 1024.0 / float(words)
        calib.samples[backend] = repeats
    return calib
