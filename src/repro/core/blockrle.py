"""Deprecated shim: block-RLE tile primitives moved to ``repro.storage``.

Tile classification is now owned by the storage engine
(:mod:`repro.storage.tiles` for the raw primitives,
:class:`repro.storage.TileStore` for the index-native hybrid layout), so
the clean/dirty skipping decision is shared by every backend instead of
being an ``rbmrg_block``-only side channel.  Import from ``repro.storage``;
this module re-exports for backwards compatibility only.
"""
from __future__ import annotations

from repro.storage.tiles import (  # noqa: F401
    BlockStats,
    classify_tiles,
    rbmrg_block_threshold,
    runcount,
)

__all__ = ["BlockStats", "classify_tiles", "rbmrg_block_threshold", "runcount"]
