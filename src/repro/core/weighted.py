"""Weighted threshold functions over bitmaps.

The paper (2.3) handles integer weights by replicating input i w_i times
and notes "this approach may be practical if weights are small.  Otherwise,
the resulting threshold query may be impractically wide."

Beyond-paper contribution: **binary weight decomposition**.  Write each
weight w_i = sum_j 2^j * w_ij.  The weighted count is

    sum_i w_i b_i = sum_j 2^j * (count of set inputs with bit j of weight)

so we feed, for each j, the inputs whose weight has bit j into a sideways
sum, then combine the per-level Hamming-weight digits with a shift-add:
total circuit size O(sum_j s(|level_j|) + log-width adders) -- logarithmic
in max(w) instead of linear (replication costs s(sum_i w_i) gates).

Example: N=64 inputs with weights up to 1000.  Replication would build a
~64000-input adder (~5 * 64000 = 320k gates); decomposition builds 10
64-input sideways sums plus shift-adds (~10 * 5 * 64 + overhead ~= 4k gates),
an ~80x reduction, still yielding a bitmap.
"""
from __future__ import annotations

from typing import Sequence

import jax

from . import circuits as C

__all__ = ["build_weighted_threshold_circuit", "emit_weighted_ge",
           "weighted_threshold_decomposed", "replication_gate_cost",
           "decomposed_gate_cost"]


def emit_weighted_ge(c: C.Circuit, member_ids: Sequence[int], weights: Sequence[int],
                     t: int) -> int:
    """Emit gates computing sum_i w_i b_i >= t over existing circuit nodes.

    ``member_ids`` may be inputs or gate outputs (sub-queries), so weighted
    thresholds compose inside larger query circuits.  Returns the output
    node id.
    """
    if len(member_ids) != len(weights):
        raise ValueError(f"{len(weights)} weights for {len(member_ids)} members")
    total = sum(weights)
    if t <= 0:
        return C.CONST1
    if t > total:
        return C.CONST0
    wmax = max(weights)
    levels = wmax.bit_length()
    # per-bit-level Hamming weights (LSB-first digit vectors)
    acc_bits: list = []  # binary number, LSB first, accumulating shifted sums
    acc_max = 0
    for j in range(levels):
        members = [m for m, w in zip(member_ids, weights) if (w >> j) & 1]
        if not members:
            continue
        digits = C.sideways_sum_bits(c, members)  # weight of this level
        shifted = [C.CONST0] * j + digits  # x 2^j
        level_max = len(members) << j
        if not acc_bits:
            acc_bits, acc_max = shifted, level_max
        else:
            width = max(len(acc_bits), len(shifted))
            a = acc_bits + [C.CONST0] * (width - len(acc_bits))
            b = shifted + [C.CONST0] * (width - len(shifted))
            acc_max = acc_max + level_max
            acc_bits = C._ripple_add(c, a, b, acc_max)
            acc_bits = acc_bits[: max(1, acc_max.bit_length())]
    return C.ge_const(c, acc_bits, t)


def build_weighted_threshold_circuit(weights: Sequence[int], t: int) -> C.Circuit:
    """Circuit over N inputs computing sum_i w_i b_i >= t."""
    n = len(weights)
    c = C.Circuit(n, [], [])
    c.outputs = [emit_weighted_ge(c, list(range(n)), weights, t)]
    return c.optimized()


def weighted_threshold_decomposed(bitmaps: jax.Array, weights: tuple, t: int) -> jax.Array:
    """Evaluate the decomposed weighted threshold over packed bitmaps.

    .. deprecated:: shim over ``repro.query`` (``Weighted(weights, t)``
       through the compiled-circuit cache); prefer ``BitmapIndex.execute``.
    """
    from repro.query import Weighted, execute

    return execute(bitmaps, Weighted(tuple(int(w) for w in weights), int(t)))


def replication_gate_cost(weights: Sequence[int], t: int) -> int:
    """Gate count of the paper's replication approach (for comparison)."""
    n_rep = sum(weights)
    return C.build_threshold_circuit(n_rep, t, "ssum").gate_count()


def decomposed_gate_cost(weights: Sequence[int], t: int) -> int:
    return build_weighted_threshold_circuit(list(weights), t).gate_count()
