"""Weighted threshold functions over bitmaps.

The paper (2.3) handles integer weights by replicating input i w_i times
and notes "this approach may be practical if weights are small.  Otherwise,
the resulting threshold query may be impractically wide."

Beyond-paper contribution: **binary weight decomposition**.  Write each
weight w_i = sum_j 2^j * w_ij.  The weighted count is

    sum_i w_i b_i = sum_j 2^j * (count of set inputs with bit j of weight)

so we feed, for each j, the inputs whose weight has bit j into a sideways
sum, then combine the per-level Hamming-weight digits with a shift-add:
total circuit size O(sum_j s(|level_j|) + log-width adders) -- logarithmic
in max(w) instead of linear (replication costs s(sum_i w_i) gates).

Example: N=64 inputs with weights up to 1000.  Replication would build a
~64000-input adder (~5 * 64000 = 320k gates); decomposition builds 10
64-input sideways sums plus shift-adds (~10 * 5 * 64 + overhead ~= 4k gates),
an ~80x reduction, still yielding a bitmap.
"""
from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp

from . import circuits as C
from .bitmaps import WORD_DTYPE

__all__ = ["build_weighted_threshold_circuit", "weighted_threshold_decomposed",
           "replication_gate_cost", "decomposed_gate_cost"]


def build_weighted_threshold_circuit(weights: Sequence[int], t: int) -> C.Circuit:
    """Circuit over N inputs computing sum_i w_i b_i >= t."""
    n = len(weights)
    wmax = max(weights)
    total = sum(weights)
    c = C.Circuit(n, [], [])
    if t <= 0:
        c.outputs = [C.CONST1]
        return c
    if t > total:
        c.outputs = [C.CONST0]
        return c
    levels = wmax.bit_length()
    # per-bit-level Hamming weights (LSB-first digit vectors)
    acc_bits: list = []  # binary number, LSB first, accumulating shifted sums
    acc_max = 0
    for j in range(levels):
        members = [i for i in range(n) if (weights[i] >> j) & 1]
        if not members:
            continue
        digits = C.sideways_sum_bits(c, members)  # weight of this level
        shifted = [C.CONST0] * j + digits  # x 2^j
        level_max = len(members) << j
        if not acc_bits:
            acc_bits, acc_max = shifted, level_max
        else:
            width = max(len(acc_bits), len(shifted))
            a = acc_bits + [C.CONST0] * (width - len(acc_bits))
            b = shifted + [C.CONST0] * (width - len(shifted))
            acc_max = acc_max + level_max
            acc_bits = C._ripple_add(c, a, b, acc_max)
            acc_bits = acc_bits[: max(1, acc_max.bit_length())]
    out = C.ge_const(c, acc_bits, t)
    c.outputs = [out]
    return c.optimized()


@partial(jax.jit, static_argnames=("weights", "t"))
def weighted_threshold_decomposed(bitmaps: jax.Array, weights: tuple, t: int) -> jax.Array:
    """Evaluate the decomposed weighted threshold over packed bitmaps."""
    bitmaps = jnp.asarray(bitmaps, WORD_DTYPE)
    circ = build_weighted_threshold_circuit(list(weights), t)
    (out,) = circ.evaluate([bitmaps[i] for i in range(bitmaps.shape[0])])
    return out


def replication_gate_cost(weights: Sequence[int], t: int) -> int:
    """Gate count of the paper's replication approach (for comparison)."""
    n_rep = sum(weights)
    return C.build_threshold_circuit(n_rep, t, "ssum").gate_count()


def decomposed_gate_cost(weights: Sequence[int], t: int) -> int:
    return build_weighted_threshold_circuit(list(weights), t).gate_count()
