"""Core: threshold and symmetric functions over packed bitmaps (the paper's
contribution), plus the block-RLE adaptation and host-side list baselines."""

from .bitmaps import (
    WORD_BITS,
    bitmap_and,
    bitmap_andnot,
    bitmap_not,
    bitmap_or,
    bitmap_xor,
    cardinality,
    density,
    from_positions,
    n_words_for,
    pack,
    popcount,
    tail_mask,
    to_positions_np,
    unpack,
)
from .blockrle import BlockStats, classify_tiles, rbmrg_block_threshold, runcount
from .circuits import (
    Circuit,
    build_interval_circuit,
    build_symmetric_circuit,
    build_threshold_circuit,
    build_weight_circuit,
    looped_op_count,
    paper_tree_adder_gates,
)
from .planner import Plan, plan_query, plan_threshold
from .symmetric import exactly, interval, majority, parity, symmetric
from .threshold import ALGORITHMS, hamming_weight_words, threshold, weighted_threshold
from .bytecode import ByteCode, Interpreter, compile_circuit
from .weighted import (
    build_weighted_threshold_circuit,
    decomposed_gate_cost,
    emit_weighted_ge,
    replication_gate_cost,
    weighted_threshold_decomposed,
)
