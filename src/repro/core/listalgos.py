"""Sorted-integer-list T-occurrence baselines (paper 4.3 and the 'w' family).

These are the state-of-the-art competitors the paper benchmarks against
(ScanCount, MergeOpt, MergeSkip, DivideSkip of Li et al. / Sarawagi &
Kirpal) plus the paper's own 'w'-style algorithms (WSORT, HASHCNT, W2CTI).

Heap-based skipping is serial, data-dependent pointer chasing with no TPU
analogue (see DESIGN.md), so these run on the host in NumPy.  They exist
(a) because the paper implements its competitors, and (b) as ground truth
for benchmark parity: `benchmarks/table10_workload.py` races them against
the bitmap algorithms exactly like the paper's 5.9 workload.
"""
from __future__ import annotations

import heapq
from collections import Counter

import numpy as np

__all__ = ["wheap", "wsort", "hashcnt", "w2cti", "mgopt", "wmgsk", "dsk", "scancount_np"]


def scancount_np(lists: list[np.ndarray], t: int, r: int) -> np.ndarray:
    counts = np.zeros(r, dtype=np.int32)
    for l in lists:
        counts[l] += 1
    return np.nonzero(counts >= t)[0]


def wsort(lists: list[np.ndarray], t: int, r: int) -> np.ndarray:
    """Concatenate, sort, emit values repeated >= T times (paper 4.2.1)."""
    if not lists:
        return np.empty(0, dtype=np.int64)
    allv = np.sort(np.concatenate(lists))
    vals, cnt = np.unique(allv, return_counts=True)
    return vals[cnt >= t]


def hashcnt(lists: list[np.ndarray], t: int, r: int) -> np.ndarray:
    c: Counter = Counter()
    for l in lists:
        c.update(l.tolist())
    return np.array(sorted(v for v, k in c.items() if k >= t), dtype=np.int64)


def wheap(lists: list[np.ndarray], t: int, r: int) -> np.ndarray:
    """N-way heap merge counting duplicates (Sarawagi & Kirpal)."""
    heap = [(int(l[0]), i, 0) for i, l in enumerate(lists) if len(l)]
    heapq.heapify(heap)
    out = []
    cur, cnt = None, 0
    while heap:
        v, i, j = heapq.heappop(heap)
        if v == cur:
            cnt += 1
        else:
            if cur is not None and cnt >= t:
                out.append(cur)
            cur, cnt = v, 1
        if j + 1 < len(lists[i]):
            heapq.heappush(heap, (int(lists[i][j + 1]), i, j + 1))
    if cur is not None and cnt >= t:
        out.append(cur)
    return np.array(out, dtype=np.int64)


def w2cti(lists: list[np.ndarray], t: int, r: int) -> np.ndarray:
    """Mergeable value+counter arrays with pruning during the merge (4.2.2)."""
    order = sorted(range(len(lists)), key=lambda i: len(lists[i]))
    n = len(lists)
    acc_v = lists[order[0]].astype(np.int64)
    acc_c = np.ones_like(acc_v)
    for step, idx in enumerate(order[1:], start=1):
        remaining = n - step - 1  # inputs left after this merge
        nv = lists[idx].astype(np.int64)
        merged_v = np.union1d(acc_v, nv)
        c = np.zeros_like(merged_v)
        c[np.searchsorted(merged_v, acc_v)] += acc_c
        c[np.searchsorted(merged_v, nv)] += 1
        # prune during merge: drop items that cannot reach T
        keep = c + remaining >= t
        acc_v, acc_c = merged_v[keep], c[keep]
    return acc_v[acc_c >= t]


def _find_geq(lst: np.ndarray, pos: int, val: int) -> int:
    """Doubling (galloping) search for the first index with lst[i] >= val."""
    n = len(lst)
    if pos >= n or lst[pos] >= val:
        return pos
    step = 1
    lo = pos
    while pos + step < n and lst[pos + step] < val:
        lo = pos + step
        step *= 2
    return int(np.searchsorted(lst[lo : min(n, pos + step) + 1], val) + lo)


def mgopt(lists: list[np.ndarray], t: int, r: int) -> np.ndarray:
    """MergeOpt (Sarawagi & Kirpal): set aside the T-1 largest lists."""
    return _divide(lists, t, n_long=t - 1)


def dsk(lists: list[np.ndarray], t: int, r: int, mu: float = 0.05) -> np.ndarray:
    """DivideSkip (Li et al.): L largest set aside, L = T/(mu log2 M + 1)."""
    if t <= 1:
        return wheap(lists, t, r)
    m = max(max((len(l) for l in lists), default=2), 2)
    n_long = int(t / (mu * np.log2(m) + 1))
    n_long = min(max(n_long, 0), t - 1)
    return _divide(lists, t, n_long=n_long)


def _divide(lists: list[np.ndarray], t: int, n_long: int) -> np.ndarray:
    order = sorted(range(len(lists)), key=lambda i: -len(lists[i]))
    long_ids = order[:n_long]
    short_ids = order[n_long:]
    longs = [lists[i] for i in long_ids]
    shorts = [lists[i] for i in short_ids]
    need = t - n_long  # occurrences that must come from the short lists
    # heap-merge the short lists, keep items occurring >= max(1, need - ...)
    cand = wheap(shorts, max(1, need), 10**18) if shorts else np.empty(0, np.int64)
    # recount candidate occurrences in short lists (wheap returned >=max(1,need))
    out = []
    pos = [0] * len(longs)
    for v in cand:
        cnt = 0
        for s in shorts:
            j = np.searchsorted(s, v)
            if j < len(s) and s[j] == v:
                cnt += 1
        for li, l in enumerate(longs):
            pos[li] = _find_geq(l, pos[li], int(v))
            if pos[li] < len(l) and l[pos[li]] == v:
                cnt += 1
        if cnt >= t:
            out.append(int(v))
    return np.array(out, dtype=np.int64)


def wmgsk(lists: list[np.ndarray], t: int, r: int) -> np.ndarray:
    """MergeSkip (Li et al.): pop T-1 extra items and gallop past them."""
    heap = [(int(l[0]), i, 0) for i, l in enumerate(lists) if len(l)]
    heapq.heapify(heap)
    out = []
    while heap:
        v = heap[0][0]
        same = []
        while heap and heap[0][0] == v:
            same.append(heapq.heappop(heap))
        if len(same) >= t:
            out.append(v)
            for _, i, j in same:
                if j + 1 < len(lists[i]):
                    heapq.heappush(heap, (int(lists[i][j + 1]), i, j + 1))
        else:
            # pop T-1-|same| additional smallest items; all skip to the new top
            extra = []
            while heap and len(same) + len(extra) < t - 1:
                extra.append(heapq.heappop(heap))
            nxt = heap[0][0] if heap else None
            for _, i, j in same + extra:
                if nxt is None:
                    continue
                jj = _find_geq(lists[i], j, nxt)
                if jj < len(lists[i]):
                    heapq.heappush(heap, (int(lists[i][jj]), i, jj))
    return np.array(out, dtype=np.int64)
