"""Byte-code compilation of circuits (the paper's 4.4.4 third approach).

The paper compiles a gate DAG into straight-line byte code (AND / OR / XOR /
ANDNOT / RECLAIM) executed by a trivial interpreter, with a last-use
analysis so intermediate bitmaps are reclaimed eagerly -- their answer to
the NP-hard Register Sufficiency problem.

We reproduce that layer faithfully (it is also how our register-pressure
claims for the Pallas kernel are justified): ``compile_circuit`` does the
topological ordering + last-use analysis and assigns *register slots*;
``Interpreter.run`` executes over uint32 word arrays (or Python ints).
``peak_registers`` is the live-set bound the paper's Table 3 notes
("register-allocation techniques would usually be able to share space").
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .circuits import CONST0, CONST1, Circuit

__all__ = ["ByteCode", "compile_circuit", "Interpreter"]

_OPS = {"and": 0, "or": 1, "xor": 2, "andnot": 3}


@dataclasses.dataclass
class ByteCode:
    """(op, dst_reg, a_reg, b_reg) quadruples; negative regs = specials."""

    n_inputs: int
    n_registers: int
    instructions: list  # (opcode, dst, a, b); a/b: >=0 reg, -1 const0, -2 const1,
    #                     -(3+i) input i
    output_reg: int
    peak_registers: int


def compile_circuit(circ: Circuit) -> ByteCode:
    n_in = circ.n_inputs
    n_gates = len(circ.ops)
    # last use of every gate value (inputs/constants live throughout)
    last_use = {}
    for idx, (op, a, b) in enumerate(circ.ops):
        for x in (a, b):
            if x >= n_in:
                last_use[x] = idx
    for o in circ.outputs:
        if o >= n_in:
            last_use[o] = n_gates  # outputs live to the end

    free: list[int] = []
    reg_of: dict[int, int] = {}
    n_regs = 0
    peak = 0
    instrs = []

    def src(x: int) -> int:
        if x == CONST0:
            return -1
        if x == CONST1:
            return -2
        if x < n_in:
            return -(3 + x)
        return reg_of[x]

    for idx, (op, a, b) in enumerate(circ.ops):
        sa, sb = src(a), src(b)
        # reclaim operands whose last use is this instruction BEFORE
        # allocating dst, so dst can reuse the slot (in-place style)
        for x in (a, b):
            if x >= n_in and last_use.get(x) == idx:
                free.append(reg_of.pop(x))
        if free:
            dst = free.pop()
        else:
            dst = n_regs
            n_regs += 1
        reg_of[n_in + idx] = dst
        peak = max(peak, len(reg_of))
        instrs.append((_OPS[op], dst, sa, sb))
    out = circ.outputs[0]
    out_reg = src(out)
    return ByteCode(n_in, n_regs, instrs, out_reg, peak)


class Interpreter:
    """Trivial straight-line interpreter over numpy uint32 word arrays."""

    def run(self, bc: ByteCode, inputs: Sequence[np.ndarray]) -> np.ndarray:
        nw = len(np.atleast_1d(inputs[0]))
        regs = [None] * bc.n_registers
        zero = np.zeros(nw, np.uint32)
        ones = np.full(nw, 0xFFFFFFFF, np.uint32)

        def val(s):
            if s == -1:
                return zero
            if s == -2:
                return ones
            if s <= -3:
                return np.asarray(inputs[-s - 3], np.uint32)
            return regs[s]

        for opcode, dst, a, b in bc.instructions:
            va, vb = val(a), val(b)
            if opcode == 0:
                regs[dst] = va & vb
            elif opcode == 1:
                regs[dst] = va | vb
            elif opcode == 2:
                regs[dst] = va ^ vb
            else:
                regs[dst] = va & ~vb
        return val(bc.output_reg)
