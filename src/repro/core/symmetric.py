"""Arbitrary symmetric Boolean functions over packed bitmaps (paper 2.2/4.4.1).

A symmetric function is determined by its value on each Hamming weight
0..N.  We synthesise it from the weight bits of the sideways-sum circuit,
merging contiguous true-runs into interval tests (>=lo ANDNOT >=hi+1),
exactly the construction sketched in 4.4.1.

Positions beyond ``r`` (the tail of the last word) have weight 0; when the
function is true at weight 0 the caller-visible result is masked with
``tail_mask`` so the packed result stays canonical.
"""
from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import circuits as _ckt
from .bitmaps import WORD_DTYPE, tail_mask

__all__ = ["symmetric", "exactly", "interval", "parity", "majority"]


def _mask_tail(words: jax.Array, r: int | None) -> jax.Array:
    if r is None:
        return words
    nw = words.shape[-1]
    mask = np.full(nw, 0xFFFFFFFF, dtype=np.uint32)
    mask[-1] = tail_mask(r)
    return jnp.bitwise_and(words, jnp.asarray(mask))


@partial(jax.jit, static_argnames=("truth", "r"))
def symmetric(bitmaps: jax.Array, truth: tuple, r: int | None = None) -> jax.Array:
    """Apply the symmetric function given by ``truth[w]`` for weight w=0..N."""
    bitmaps = jnp.asarray(bitmaps, WORD_DTYPE)
    n = bitmaps.shape[0]
    if len(truth) != n + 1:
        raise ValueError(f"truth table needs {n + 1} entries, got {len(truth)}")
    circ = _ckt.build_symmetric_circuit(n, list(truth))
    (out,) = circ.evaluate([bitmaps[i] for i in range(n)])
    return _mask_tail(out, r)


def exactly(bitmaps, k: int, r: int | None = None):
    """The paper's 'delta' function: weight == k exactly."""
    n = bitmaps.shape[0]
    return symmetric(bitmaps, tuple(w == k for w in range(n + 1)), r)


def interval(bitmaps, lo: int, hi: int, r: int | None = None):
    """Weight within [lo, hi] (e.g. 'on sale in 2 to 10 stores')."""
    n = bitmaps.shape[0]
    return symmetric(bitmaps, tuple(lo <= w <= hi for w in range(n + 1)), r)


def parity(bitmaps, r: int | None = None):
    """Wide XOR == z0 of the sideways sum; synthesised directly."""
    bitmaps = jnp.asarray(bitmaps, WORD_DTYPE)
    n = bitmaps.shape[0]
    circ = _ckt.Circuit(n, [], [])
    bits = _ckt.sideways_sum_bits(circ, list(range(n)))
    circ.outputs = [bits[0]]
    circ = circ.optimized()
    (out,) = circ.evaluate([bitmaps[i] for i in range(n)])
    return _mask_tail(out, r)


def majority(bitmaps, r: int | None = None):
    """theta(ceil(N/2)) -- the majority function."""
    from .threshold import threshold

    n = bitmaps.shape[0]
    return threshold(bitmaps, (n + 1) // 2)
