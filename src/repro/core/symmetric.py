"""Arbitrary symmetric Boolean functions over packed bitmaps (paper 2.2/4.4.1).

A symmetric function is determined by its value on each Hamming weight
0..N.  We synthesise it from the weight bits of the sideways-sum circuit,
merging contiguous true-runs into interval tests (>=lo ANDNOT >=hi+1),
exactly the construction sketched in 4.4.1.

Positions beyond ``r`` (the tail of the last word) have weight 0; when the
function is true at weight 0 the caller-visible result is masked with
``tail_mask`` so the packed result stays canonical.

.. deprecated:: these free functions are thin shims over ``repro.query``
   (``Sym`` / ``Exactly`` / ``Interval`` / ``Parity`` / ``Majority``
   expressions executed through the compiled-circuit cache).  Prefer
   ``BitmapIndex.execute`` -- expressions compose, share adders, batch,
   and (because the index is TileStore-backed) get tile skipping on
   clean-heavy data.  The shims emit ONE consolidated DeprecationWarning
   per process (``core.deprecation``).
"""
from __future__ import annotations

from typing import Sequence

import jax

from .deprecation import warn_legacy_shim

__all__ = ["symmetric", "exactly", "interval", "parity", "majority"]


def _execute(name, bitmaps, expr, r):
    warn_legacy_shim(name)
    from repro.query import execute

    return execute(bitmaps, expr, r=r)


def symmetric(bitmaps, truth: Sequence, r: int | None = None) -> jax.Array:
    """Apply the symmetric function given by ``truth[w]`` for weight w=0..N."""
    from repro.query import Sym

    return _execute("core.symmetric.symmetric", bitmaps, Sym(tuple(truth)), r)


def exactly(bitmaps, k: int, r: int | None = None):
    """The paper's 'delta' function: weight == k exactly."""
    from repro.query import Exactly

    return _execute("core.symmetric.exactly", bitmaps, Exactly(k), r)


def interval(bitmaps, lo: int, hi: int, r: int | None = None):
    """Weight within [lo, hi] (e.g. 'on sale in 2 to 10 stores')."""
    from repro.query import Interval

    return _execute("core.symmetric.interval", bitmaps, Interval(lo, hi), r)


def parity(bitmaps, r: int | None = None):
    """Wide XOR == z0 of the sideways sum; synthesised directly."""
    from repro.query import Parity

    return _execute("core.symmetric.parity", bitmaps, Parity(), r)


def majority(bitmaps, r: int | None = None):
    """theta(ceil(N/2)) -- the majority function.

    ``r`` is honoured (the seed accepted it but never masked the tail,
    unlike every other symmetric helper).
    """
    from repro.query import Majority

    return _execute("core.symmetric.majority", bitmaps, Majority(), r)
