"""Block-RLE (EWAH/RBMRG adaptation): pruning correctness + work accounting.

The primitives live in ``repro.storage`` (tile classification is owned by
the storage engine); ``repro.core.blockrle`` remains as a re-export shim,
whose compatibility is covered by test_legacy_blockrle_shim below.
"""
import jax.numpy as jnp
import numpy as np

from repro.core.bitmaps import pack, unpack
from repro.core.threshold import threshold
from repro.storage import classify_tiles, rbmrg_block_threshold, runcount


def _clustered(n, r, seed=0, lo=8000, hi=40000):
    """Bitmaps with runs much longer than a tile (EWAH-friendly data)."""
    rng = np.random.default_rng(seed)
    bits = np.zeros((n, r), bool)
    for i in range(n):
        pos = 0
        while pos < r:
            run = int(rng.integers(lo, hi))
            val = rng.random() < 0.4
            bits[i, pos : pos + run] = val
            pos += run
    return bits


def test_rbmrg_block_matches_threshold():
    r = 64 * 32 * 40  # 40 tiles of 64 words
    bits = _clustered(9, r, seed=1)
    bm = pack(jnp.asarray(bits))
    for t in (1, 2, 4, 8, 9):
        out, info = rbmrg_block_threshold(bm, t, tile_words=64)
        expect = np.asarray(unpack(threshold(bm, t, "scancount"), r))
        np.testing.assert_array_equal(np.asarray(unpack(out, r)), expect, err_msg=f"t={t}")


def test_pruning_skips_clean_work():
    r = 64 * 32 * 64
    bits = _clustered(8, r, seed=2)
    bm = pack(jnp.asarray(bits))
    _, info = rbmrg_block_threshold(bm, 4, tile_words=64)
    # clustered data must prune a large majority of the word-level work
    assert info["work_fraction"] < 0.5, info
    assert info["case1_tiles"] + info["case2_tiles"] + info["case3_tiles"] == info["n_tiles"]


def test_dense_random_data_prunes_nothing():
    rng = np.random.default_rng(3)
    bits = rng.random((6, 64 * 32 * 8)) < 0.5
    bm = pack(jnp.asarray(bits))
    out, info = rbmrg_block_threshold(bm, 3, tile_words=64)
    assert info["case3_tiles"] == info["n_tiles"]  # nothing clean to skip
    expect = np.asarray(unpack(threshold(bm, 3, "scancount"), bits.shape[1]))
    np.testing.assert_array_equal(np.asarray(unpack(out, bits.shape[1])), expect)


def test_classify_tiles_and_runcount():
    r = 64 * 32 * 4
    bits = np.zeros((2, r), bool)
    bits[0, : r // 2] = True  # one long run
    bm = pack(jnp.asarray(bits))
    stats = classify_tiles(bm, tile_words=64)
    assert stats.classes[0, 0] == 1 and stats.classes[0, -1] == 0
    assert stats.classes[1].tolist() == [0, 0, 0, 0]
    # RUNCOUNT: bitmap0 has 2 runs, bitmap1 has 1
    assert runcount(bm) == 3
    assert stats.clean_fraction == 1.0


def _oracle(bm, t, r):
    return np.asarray(unpack(threshold(bm, t, "scancount"), r))


def test_case1_all_one_short_circuit():
    """Tiles with T - k <= 0 resolve to all-ones with zero dirty work."""
    nw = 64 * 3
    r = nw * 32
    bm = jnp.concatenate(
        [
            jnp.full((4, nw), 0xFFFFFFFF, jnp.uint32),  # k = 4 everywhere
            jnp.asarray(
                np.random.default_rng(0).integers(0, 2**32, (2, nw), dtype=np.uint32)
            ),
        ]
    )
    out, info = rbmrg_block_threshold(bm, 3, tile_words=64)  # T=3 <= k
    assert info["case1_tiles"] == info["n_tiles"]
    assert info["dirty_words_processed"] == 0
    np.testing.assert_array_equal(np.asarray(unpack(out, r)), _oracle(bm, 3, r))
    assert np.asarray(unpack(out, r)).all()


def test_case2_all_zero_short_circuit():
    """Tiles with T - k > d resolve to all-zeros with zero dirty work."""
    nw = 64 * 2
    r = nw * 32
    bm = jnp.concatenate(
        [
            jnp.zeros((5, nw), jnp.uint32),
            jnp.asarray(
                np.random.default_rng(1).integers(0, 2**32, (2, nw), dtype=np.uint32)
            ),
        ]
    )
    out, info = rbmrg_block_threshold(bm, 3, tile_words=64)  # d=2 < T-k=3
    assert info["case2_tiles"] == info["n_tiles"]
    assert info["dirty_words_processed"] == 0
    np.testing.assert_array_equal(np.asarray(unpack(out, r)), _oracle(bm, 3, r))
    assert not np.asarray(unpack(out, r)).any()


def test_partial_final_tile():
    """n_words not a tile multiple: the padded final tile stays correct."""
    nw = 64 * 2 + 17  # r % (32 * tile_words) != 0
    r = nw * 32 - 5  # and r not a word multiple either
    bits = _clustered(7, r, seed=9, lo=300, hi=4000)
    bm = pack(jnp.asarray(bits))
    assert bm.shape[1] == nw
    for t in (1, 3, 7):
        out, info = rbmrg_block_threshold(bm, t, tile_words=64)
        np.testing.assert_array_equal(
            np.asarray(unpack(out, r)), _oracle(bm, t, r), err_msg=f"t={t}"
        )
    stats = classify_tiles(bm, tile_words=64)
    assert stats.classes.shape[1] == 3  # ceil(145 / 64)


def test_runcount_alternating_and_degenerate():
    r = 64 * 32
    alternating = np.zeros((1, r), bool)
    alternating[0, ::2] = True  # 0101... -> r runs
    assert runcount(pack(jnp.asarray(alternating))) == r
    assert runcount(jnp.zeros((1, 64), jnp.uint32)) == 1  # constant: one run
    assert runcount(jnp.full((1, 64), 0xFFFFFFFF, jnp.uint32)) == 1
    half = np.zeros((1, r), bool)
    half[0, : r // 2] = True
    assert runcount(pack(jnp.asarray(half))) == 2
    # collections sum per-bitmap counts
    both = np.vstack([alternating, half])
    assert runcount(pack(jnp.asarray(both))) == r + 2


def test_legacy_blockrle_shim():
    """core.blockrle re-exports the storage implementations unchanged."""
    from repro.core import blockrle as legacy
    from repro.storage import tiles as storage_tiles

    assert legacy.classify_tiles is storage_tiles.classify_tiles
    assert legacy.rbmrg_block_threshold is storage_tiles.rbmrg_block_threshold
    assert legacy.runcount is storage_tiles.runcount
    assert legacy.BlockStats is storage_tiles.BlockStats


def test_extreme_case_all_clean():
    """The paper's extreme example (4.1): every bitmap entirely 0s or 1s ->
    O(N log N) work, zero dirty words."""
    nw = 64 * 16
    bm = jnp.concatenate(
        [jnp.zeros((3, nw), jnp.uint32), jnp.full((5, nw), 0xFFFFFFFF, jnp.uint32)]
    )
    out, info = rbmrg_block_threshold(bm, 4, tile_words=64)
    assert info["dirty_words_processed"] == 0
    assert np.asarray(unpack(out, nw * 32)).all()  # 5 >= 4
    out2, info2 = rbmrg_block_threshold(bm, 6, tile_words=64)
    assert not np.asarray(unpack(out2, nw * 32)).any()  # 5 < 6
