"""Golden snapshot fixture: a deterministic index + its committed bytes.

``golden.bmsnap`` is the format-stability contract: the writer must keep
producing these exact bytes for this exact input, and every reader
version must keep loading them bit-identically.  The recipe below is
pure arithmetic (no RNG) so the fixture regenerates byte-identically on
any platform.

Regenerate (only on a deliberate, versioned format change):

    PYTHONPATH=src python tests/data/make_golden.py
"""
import os

import numpy as np

TILE_WORDS = 8
NAMES = ("alpha", "beta", "gamma", "delta", "epsilon", "zeta")
FIXTURE = os.path.join(os.path.dirname(__file__), "golden.bmsnap")


def golden_bits() -> np.ndarray:
    """6 columns x 1297 positions covering every container kind: all-one,
    all-zero, sparse, run, dense, mixed -- with a partial final tile."""
    r = TILE_WORDS * 32 * 5 + 17
    bits = np.zeros((len(NAMES), r), bool)
    bits[0, :] = True  # all-one -> TILE_ONE everywhere
    # bits[1] stays zero -> TILE_ZERO everywhere
    bits[2, ::37] = True  # sparse containers
    bits[3, 100:800] = True  # run containers
    bits[4] = (np.arange(r) * 2654435761 % 97) < 48  # dense tiles
    bits[5, : r // 2] = (np.arange(r // 2) % 3) == 0  # mixed kinds
    return bits


def golden_index():
    from repro.query import BitmapIndex

    return BitmapIndex.from_dense(
        golden_bits(), NAMES, tile_words=TILE_WORDS, containers=True
    )


def write(path: str = FIXTURE) -> str:
    from repro import persist

    persist.save(golden_index(), path)
    return path


if __name__ == "__main__":
    print("wrote", write(), f"({os.path.getsize(FIXTURE)} bytes)")
