"""Serving engine: continuous batching, slot bitmaps, batched == unbatched."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import decode_step, forward, init_cache, init_params
from repro.query import Col
from repro.serve import Request, ServeEngine

CFG = get_config("qwen3-1.7b", reduced=True)
PARAMS = init_params(CFG, jax.random.PRNGKey(0))


def _greedy_unbatched(prompt, max_new):
    toks = jnp.asarray(prompt, jnp.int32)[None, :]
    _, caches, _ = forward(PARAMS, CFG, {"tokens": toks}, mode="prefill", max_seq=64)
    out = []
    cur = toks[:, -1:]
    pos = len(prompt)
    for _ in range(max_new):
        logits, caches = decode_step(PARAMS, CFG, caches, cur, jnp.int32(pos))
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
        out.append(int(cur[0, 0]))
        pos += 1
    return out


def test_batched_matches_unbatched():
    prompts = [[1, 2, 3], [9, 8, 7, 6], [5]]
    expected = [_greedy_unbatched(p, 4) for p in prompts]
    eng = ServeEngine(CFG, PARAMS, batch_slots=4, max_seq=64)
    reqs = [Request(rid=i, prompt=p, max_new=4) for i, p in enumerate(prompts)]
    done = {r.rid: r for r in eng.run_until_drained(reqs)}
    for i, exp in enumerate(expected):
        assert done[i].out == exp, (i, done[i].out, exp)


def test_continuous_batching_reuses_slots():
    eng = ServeEngine(CFG, PARAMS, batch_slots=2, max_seq=64)
    reqs = [Request(rid=i, prompt=[i + 1, 2], max_new=3) for i in range(5)]
    done = eng.run_until_drained(reqs)
    assert sorted(r.rid for r in done) == [0, 1, 2, 3, 4]
    # 5 requests through 2 slots: steps must exceed one wave but stay bounded
    assert 9 <= eng.step_count <= 20


def test_slot_bitmap_queries():
    eng = ServeEngine(CFG, PARAMS, batch_slots=4, max_seq=64)
    assert eng.free_slots() == [0, 1, 2, 3]
    eng.submit(Request(rid=0, prompt=[1, 2], max_new=2))
    assert eng.free_slots() == [1, 2, 3]


@pytest.mark.parametrize("arch", ["recurrentgemma-2b", "mixtral-8x22b", "rwkv6-3b"])
def test_engine_across_mixer_families(arch):
    """Continuous batching through ring-KV (local), MoE and recurrent-state
    decode paths; batched outputs must match unbatched greedy decode."""
    import dataclasses

    from repro.configs import get_config as _gc

    cfg = _gc(arch, reduced=True)
    if cfg.moe:
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    params = init_params(cfg, jax.random.PRNGKey(3))
    prompts = [[1, 2, 3], [7, 5]]

    def unbatched(prompt, max_new):
        toks = jnp.asarray(prompt, jnp.int32)[None, :]
        _, caches, _ = forward(params, cfg, {"tokens": toks}, mode="prefill", max_seq=64)
        out, cur, pos = [], toks[:, -1:], len(prompt)
        for _ in range(max_new):
            logits, caches = decode_step(params, cfg, caches, cur, jnp.int32(pos))
            cur = jnp.argmax(logits, -1).astype(jnp.int32)
            out.append(int(cur[0, 0]))
            pos += 1
        return out

    expected = [unbatched(p, 3) for p in prompts]
    eng = ServeEngine(cfg, params, batch_slots=2, max_seq=64)
    done = {r.rid: r for r in eng.run_until_drained(
        [Request(rid=i, prompt=p, max_new=3) for i, p in enumerate(prompts)])}
    for i, exp in enumerate(expected):
        assert done[i].out == exp, (arch, i, done[i].out, exp)


def test_step_coalesces_slot_updates_into_one_version():
    """Completions + admissions land as ONE batched index update per event
    batch: a step retiring several requests at once bumps ``_slot_version``
    exactly once (the streaming slot index absorbs all changes in a single
    delta apply), and the index answers queries consistently afterwards."""
    eng = ServeEngine(CFG, PARAMS, batch_slots=4, max_seq=64)
    for i in range(3):
        assert eng.submit(Request(rid=i, prompt=[i + 1, 2], max_new=1))
    assert eng.free_slots() == [3]
    v0 = eng._slot_version
    eng.step()  # all three requests complete in this one step
    assert eng._slot_version == v0 + 1, "step must apply one batched update"
    assert eng.free_slots() == [0, 1, 2, 3]
    # the slot index is a StreamingIndex-maintained overlay, not a rebuild
    from repro.stream import StreamingIndex

    assert isinstance(eng._slot_stream, StreamingIndex)


def test_slot_queries_track_near_limit_margin():
    """Positions crossing the margin flip ``near_limit`` through the same
    batched path; draining_slots sees them without a rebuild."""
    eng = ServeEngine(CFG, PARAMS, batch_slots=2, max_seq=16)
    assert eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new=12))
    assert eng.draining_slots() == []
    v0 = eng._slot_version
    for _ in range(6):  # pos 3 -> 9 >= 16 - 8
        eng.step()
    assert eng._slot_version == v0 + 6
    assert eng.draining_slots() == [0]
    # non-default margins build a transient index from current state
    assert eng.slot_index(near_limit_margin=16).count(Col("near_limit")) == 1


def test_encoder_only_rejected():
    hcfg = get_config("hubert-xlarge", reduced=True)
    with pytest.raises(AssertionError):
        ServeEngine(hcfg, PARAMS, batch_slots=1, max_seq=16)
