"""Bitmap mask composition for serving: correctness + tile skipping."""
import jax.numpy as jnp
import numpy as np

from repro.core.bitmaps import unpack
from repro.serve.masks import (
    causal_mask_bitmap,
    compose_masks_all,
    document_mask_bitmap,
    head_vote_mask,
    kv_tile_skiplist,
    window_mask_bitmap,
)


def test_composed_mask_matches_dense_logic():
    rng = np.random.default_rng(0)
    n_kv = 300
    kv_pos = rng.permutation(n_kv).astype(np.int32)
    kv_pos[5] = -1  # empty slot
    doc = rng.integers(0, 3, n_kv).astype(np.int32)
    q_pos, window, q_doc = 200, 64, 1

    m = compose_masks_all(
        causal_mask_bitmap(q_pos, kv_pos),
        window_mask_bitmap(q_pos, kv_pos, window),
        document_mask_bitmap(doc, q_doc),
    )
    got = np.asarray(unpack(m, n_kv))
    expect = (
        (kv_pos >= 0) & (kv_pos <= q_pos) & (q_pos - kv_pos < window) & (doc == q_doc)
    )
    np.testing.assert_array_equal(got, expect)


def test_head_vote_threshold():
    rng = np.random.default_rng(1)
    n_pages = 256
    votes_bool = rng.random((8, n_pages)) < 0.2
    from repro.core.bitmaps import pack

    votes = pack(jnp.asarray(votes_bool))
    kept = np.asarray(unpack(head_vote_mask(votes, 3), n_pages))
    np.testing.assert_array_equal(kept, votes_bool.sum(0) >= 3)


def test_kv_tile_skiplist_skips_dead_tiles():
    n_kv = 32 * 64 * 8  # 8 tiles of 2048 positions
    live = np.zeros(n_kv, bool)
    live[:2048] = True          # tile 0 fully live
    live[3 * 2048 + 17] = True  # tile 3 one bit
    from repro.core.bitmaps import pack

    mask = pack(jnp.asarray(live))
    keep, info = kv_tile_skiplist(mask, n_kv, tile_positions=2048)
    assert keep.tolist() == [0, 3]
    assert info["skipped_tiles"] == 6
    assert 0.74 < info["skip_fraction"] <= 0.76
