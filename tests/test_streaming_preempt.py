"""Streaming SCANCOUNT (huge-N) + end-to-end preemption handling."""
import os
import signal
import subprocess
import sys
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bitmaps import pack, unpack
from repro.core.threshold import threshold
from repro.kernels.ops import fused_weighted_threshold
from repro.core.weighted import weighted_threshold_decomposed


def test_streaming_scancount_matches_at_large_n():
    """The paper's 6 future-work question: N in the thousands+ is where the
    circuit family stops scaling; the streaming counter does not care."""
    rng = np.random.default_rng(0)
    n, r = 2048, 200
    bits = rng.random((n, r)) < 0.01
    bm = pack(jnp.asarray(bits))
    counts = bits.sum(0)
    for t in (2, 10, 25):
        got = np.asarray(unpack(threshold(bm, t, "scancount_streaming"), r))
        np.testing.assert_array_equal(got, counts >= t)


def test_streaming_matches_all_small_n():
    rng = np.random.default_rng(1)
    bits = rng.random((37, 500)) < 0.3
    bm = pack(jnp.asarray(bits))
    for t in (1, 5, 19, 37):
        a = np.asarray(threshold(bm, t, "scancount_streaming"))
        b = np.asarray(threshold(bm, t, "ssum"))
        np.testing.assert_array_equal(a, b)


def test_fused_weighted_kernel_matches_decomposed():
    rng = np.random.default_rng(2)
    bits = rng.random((9, 300)) < 0.4
    bm = pack(jnp.asarray(bits))
    w = tuple(int(x) for x in rng.integers(1, 30, 9))
    for t in (3, sum(w) // 2, sum(w) - 1):
        a = np.asarray(fused_weighted_threshold(bm, w, t))
        b = np.asarray(weighted_threshold_decomposed(bm, w, t))
        np.testing.assert_array_equal(a, b)


def test_preemption_sigterm_checkpoints_and_resumes(tmp_path):
    """Send SIGTERM to a live training run: it must checkpoint and exit
    cleanly; a relaunch must resume from the preemption checkpoint."""
    env = {**os.environ, "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src")}
    args = [
        sys.executable, "-u", "-m", "repro.launch.train",
        "--arch", "qwen3-1.7b", "--reduced", "--batch", "2", "--seq", "16",
        "--steps", "100000", "--ckpt-dir", str(tmp_path), "--ckpt-every", "100000",
    ]
    proc = subprocess.Popen(args, env=env, stdout=subprocess.PIPE,
                            stderr=subprocess.PIPE, text=True)
    # wait until training has actually stepped (first log line), then preempt
    deadline = time.time() + 300
    line = ""
    while time.time() < deadline:
        line = proc.stdout.readline()
        if line.startswith("step"):
            break
    assert line.startswith("step"), "training never started"
    time.sleep(1.0)
    proc.send_signal(signal.SIGTERM)
    out, err = proc.communicate(timeout=300)
    assert proc.returncode == 0, err[-2000:]
    assert "[preempt]" in out, out[-2000:]
    ckpts = [d for d in os.listdir(tmp_path) if d.startswith("step_")]
    assert ckpts, "no preemption checkpoint written"
    # resume past the preemption point
    res = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "qwen3-1.7b",
         "--reduced", "--batch", "2", "--seq", "16",
         "--steps", str(int(ckpts[0][5:]) + 3),
         "--ckpt-dir", str(tmp_path), "--ckpt-every", "100000"],
        env=env, capture_output=True, text=True, timeout=300,
    )
    assert res.returncode == 0, res.stderr[-2000:]
    assert "[resume] restored step" in res.stdout, res.stdout
