"""Circuit construction: gate counts against the paper's published tables."""
import pytest

from repro.core import circuits as C


def test_weight_circuit_sizes_match_paper():
    # sideways sum s(N) (4.4.3) and tree adder c(N) = 7N - 5 log2 N - 7 (4.4.2)
    expect_ssum = {2: 2, 4: 9, 8: 26, 16: 63, 32: 140}
    for n, e in expect_ssum.items():
        assert C.build_weight_circuit(n, "ssum").gate_count() == e
    for n in (2, 4, 8, 16, 32):
        assert C.build_weight_circuit(n, "treeadd").gate_count() == C.paper_tree_adder_gates(n)


@pytest.mark.parametrize(
    "n,t,ssum_expected",
    # Table 8 columns 'S. Sum' -- our construction reproduces them EXACTLY
    [(43, 30, 192), (85, 12, 398), (120, 105, 580), (323, 14, 1586),
     (329, 138, 1620), (330, 324, 1623), (786, 481, 3905), (786, 776, 3899)],
)
def test_table8_ssum_exact(n, t, ssum_expected):
    assert C.build_threshold_circuit(n, t, "ssum").gate_count() == ssum_expected


@pytest.mark.parametrize(
    "n,t,tree_expected",
    [(43, 30, 272), (85, 12, 562), (120, 105, 806), (323, 14, 2226),
     (329, 138, 2272), (330, 324, 2275)],
)
def test_table8_tree_within_tolerance(n, t, tree_expected):
    """Our value-range constant propagation is slightly stronger than the
    paper's padding construction, so tree counts come out <= the published
    numbers (within 1%).  See DESIGN.md."""
    got = C.build_threshold_circuit(n, t, "treeadd").gate_count()
    assert got <= tree_expected
    assert got >= tree_expected - max(8, 0.01 * tree_expected)


def test_table7_ssum_threshold_counts():
    # Table 7 'Add' column (sideways sum + optimised comparator + DCE)
    expect = {(4, 2): 9, (4, 3): 11, (5, 2): 12, (5, 3): 14}
    for (n, t), e in expect.items():
        assert C.build_threshold_circuit(n, t, "ssum").gate_count() == e
    # sorter matches for N=4 (optimal cases)
    assert C.build_threshold_circuit(4, 2, "srtckt").gate_count() == 7
    assert C.build_threshold_circuit(4, 3, "srtckt").gate_count() == 7


def test_looped_op_count_formula():
    # 2NT - N - T^2 + T - 1 (4.5); Table 7 'Loop' column spot checks
    assert C.looped_op_count(4, 3) == 13
    assert C.looped_op_count(5, 4) == 22
    assert C.looped_op_count(5, 2) == 12


def test_circuit_evaluation_python_ints():
    """Evaluate circuits over Python ints (64 parallel bit lanes)."""
    import numpy as np

    rng = np.random.default_rng(3)
    n = 9
    words = [int(rng.integers(0, 2**63)) for _ in range(n)]
    for t in (1, 3, 5, 9):
        circ = C.build_threshold_circuit(n, t, "ssum")
        (out,) = circ.evaluate(words, zeros=0, ones=(1 << 64) - 1)
        for bit in range(64):
            cnt = sum((w >> bit) & 1 for w in words)
            assert ((out >> bit) & 1) == (cnt >= t)


def test_tabulation_padding_rule():
    """A circuit for (N, T) answers (N', T') via padding (4.4.5): pad with
    zeros and all-ones bitmaps."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core.bitmaps import pack, unpack
    from repro.core.threshold import threshold

    rng = np.random.default_rng(7)
    bits = rng.random((10, 100)) < 0.4
    bm = pack(jnp.asarray(bits))
    # want theta(7, 10 inputs); use a 16-input circuit with T=8:
    # add 1 all-ones bitmap (raises threshold by 1) and 5 all-zero bitmaps
    ones = jnp.full((1, bm.shape[1]), 0xFFFFFFFF, jnp.uint32)
    zeros = jnp.zeros((5, bm.shape[1]), jnp.uint32)
    padded = jnp.concatenate([bm, ones, zeros], axis=0)
    got = np.asarray(unpack(threshold(padded, 8, "ssum"), 100))
    expect = bits.sum(0) >= 7
    np.testing.assert_array_equal(got, expect)
