"""Property-based tests (hypothesis) for the system's invariants.

The deterministic randomized oracle suite lives in test_oracle_properties.py
and does not need hypothesis; this module adds fuzzing on top when the
dependency is available.
"""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.bitmaps import bitmap_not, pack, unpack
from repro.core.symmetric import exactly, interval, parity, symmetric
from repro.core.threshold import threshold

SETTINGS = dict(max_examples=25, deadline=None)


@st.composite
def bitmap_batch(draw, max_n=10, max_r=200):
    n = draw(st.integers(2, max_n))
    r = draw(st.integers(1, max_r))
    seed = draw(st.integers(0, 2**31 - 1))
    density = draw(st.floats(0.0, 1.0))
    rng = np.random.default_rng(seed)
    bits = rng.random((n, r)) < density
    return bits, pack(jnp.asarray(bits)), n, r


@given(bitmap_batch(), st.data())
@settings(**SETTINGS)
def test_permutation_symmetry(batch, data):
    """Symmetric functions are invariant under input permutation (2.2)."""
    bits, bm, n, r = batch
    t = data.draw(st.integers(1, n))
    perm = data.draw(st.permutations(range(n)))
    base = np.asarray(threshold(bm, t, "ssum"))
    permuted = np.asarray(threshold(bm[jnp.asarray(perm)], t, "ssum"))
    np.testing.assert_array_equal(base, permuted)


@given(bitmap_batch())
@settings(**SETTINGS)
def test_monotone_in_t(batch):
    """theta(T+1) implies theta(T): result bitmaps are nested (2.3)."""
    bits, bm, n, r = batch
    prev = np.asarray(unpack(threshold(bm, 1), r))
    for t in range(2, n + 1):
        cur = np.asarray(unpack(threshold(bm, t), r))
        assert not np.any(cur & ~prev), f"t={t} not nested"
        prev = cur


@given(bitmap_batch(), st.data())
@settings(**SETTINGS)
def test_complement_identity(batch, data):
    """NOT theta(T, B) == theta(N-T+1, {NOT b}) (the paper's 2.3 identity)."""
    bits, bm, n, r = batch
    t = data.draw(st.integers(1, n))
    lhs = ~np.asarray(unpack(threshold(bm, t), r))
    rhs = np.asarray(unpack(threshold(bitmap_not(bm, r), n - t + 1), r))
    np.testing.assert_array_equal(lhs, rhs)


@given(bitmap_batch(), st.data())
@settings(**SETTINGS)
def test_exact_and_interval_consistency(batch, data):
    """delta(k) == theta(k) ANDNOT theta(k+1); interval = union of deltas."""
    bits, bm, n, r = batch
    k = data.draw(st.integers(0, n))
    counts = bits.sum(0)
    np.testing.assert_array_equal(
        np.asarray(unpack(exactly(bm, k, r=r), r)), counts == k
    )
    lo = data.draw(st.integers(0, n))
    hi = data.draw(st.integers(lo, n))
    np.testing.assert_array_equal(
        np.asarray(unpack(interval(bm, lo, hi, r=r), r)), (counts >= lo) & (counts <= hi)
    )


@given(bitmap_batch())
@settings(**SETTINGS)
def test_parity_is_xor(batch):
    bits, bm, n, r = batch
    expect = bits.sum(0) % 2 == 1
    np.testing.assert_array_equal(np.asarray(unpack(parity(bm, r=r), r)), expect)


@given(bitmap_batch(), st.data())
@settings(**SETTINGS)
def test_arbitrary_symmetric_truth_table(batch, data):
    bits, bm, n, r = batch
    truth = tuple(data.draw(st.booleans()) for _ in range(n + 1))
    counts = bits.sum(0)
    expect = np.array([truth[c] for c in counts])
    np.testing.assert_array_equal(
        np.asarray(unpack(symmetric(bm, truth, r=r), r)), expect
    )


@given(st.integers(1, 400), st.integers(0, 2**31 - 1), st.floats(0, 1))
@settings(**SETTINGS)
def test_pack_unpack_roundtrip(r, seed, density):
    rng = np.random.default_rng(seed)
    bits = rng.random(r) < density
    assert np.array_equal(np.asarray(unpack(pack(jnp.asarray(bits)), r)), bits)
