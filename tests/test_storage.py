"""Storage engine: TileStore classification/layout, compressed containers
(sparse + run) round trips and crossover edges, tiled execution vs the
scancount oracle, planner cost model, stats-cache fix, shim deprecation."""
import warnings

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bitmaps import pack, unpack
from repro.core.circuits import build_interval_circuit, build_threshold_circuit
from repro.core.threshold import ALGORITHMS
from repro.query import And, BitmapIndex, Col, Interval, Not, Parity, Threshold
from repro.storage import (
    CONT_DENSE,
    CONT_NONE,
    CONT_RUN,
    CONT_SPARSE,
    TILE_DIRTY,
    TILE_ONE,
    TILE_RUN,
    TILE_ZERO,
    TileStore,
    run_max_intervals,
    run_tiled_circuit,
    sparse_max_positions,
)

TW = 64
SPAN = TW * 32  # bit positions per tile


def _tiled_bits(n, n_tiles, clean_fraction, seed=0, tail_bits=0):
    """Columns whose tiles are all-zero/all-one with prob clean_fraction."""
    rng = np.random.default_rng(seed)
    r = n_tiles * SPAN + tail_bits
    bits = np.zeros((n, r), bool)
    total = n_tiles + (1 if tail_bits else 0)
    for i in range(n):
        for tj in range(total):
            lo, hi = tj * SPAN, min((tj + 1) * SPAN, r)
            u = rng.random()
            if u < clean_fraction / 2:
                pass  # all-zero
            elif u < clean_fraction:
                bits[i, lo:hi] = True
            else:
                bits[i, lo:hi] = rng.random(hi - lo) < 0.4
    return bits


# ---------------------------------------------------------------------------
# TileStore layout + classification
# ---------------------------------------------------------------------------


def test_tile_classes_and_dirty_packing():
    r = 4 * SPAN
    bits = np.zeros((3, r), bool)
    bits[0, :SPAN] = True              # tile 0: all-one
    bits[1, SPAN : SPAN + 100] = True  # tile 1: run (single transition)
    bits[2] = np.random.default_rng(0).random(r) < 0.5  # all dirty
    store = TileStore.from_packed(pack(jnp.asarray(bits)), tile_words=TW, r=r)
    assert store.classes[0].tolist() == [TILE_ONE, TILE_ZERO, TILE_ZERO, TILE_ZERO]
    assert store.classes[1].tolist() == [TILE_ZERO, TILE_RUN, TILE_ZERO, TILE_ZERO]
    assert (store.classes[2] == TILE_DIRTY).all()
    # dirty array holds exactly the dirty/run tiles; offsets point into it
    assert store.dirty.shape == (1 + 4, TW)
    assert store.dirty_index[1, 1] >= 0 and store.dirty_index[0, 0] == -1
    np.testing.assert_array_equal(np.asarray(store.densify()), np.asarray(pack(jnp.asarray(bits))))
    # per-column build-time stats
    assert store.col_stats[0].cardinality == SPAN
    assert store.col_stats[0].runcount == 2
    assert store.col_stats[1].runcount == 3
    assert store.col_stats[2].n_dirty_tiles == 4


def test_partial_final_tile_is_conservative_and_correct():
    r = 2 * SPAN + 777  # final tile partial
    bits = np.ones((2, r), bool)
    store = TileStore.from_packed(pack(jnp.asarray(bits)), tile_words=TW, r=r)
    assert store.n_tiles == 3
    assert (store.classes[:, :2] == TILE_ONE).all()
    # padded words are zero, so an all-ones partial tile classifies dirty/run
    assert (store.classes[:, 2] >= TILE_DIRTY).all()
    np.testing.assert_array_equal(np.asarray(store.densify()), np.asarray(pack(jnp.asarray(bits))))


def test_append_replace_share_and_reclassify():
    bits = _tiled_bits(4, 6, 0.5, seed=1)
    bm = np.asarray(pack(jnp.asarray(bits)))
    store = TileStore.from_packed(bm)
    grown = store.append(bm[0])
    assert grown.n == 5 and store.n == 4
    np.testing.assert_array_equal(grown.classes[4], store.classes[0])
    swapped = grown.replace(2, np.zeros(store.n_words, np.uint32))
    assert (swapped.classes[2] == TILE_ZERO).all()
    assert swapped.col_stats[2].cardinality == 0
    np.testing.assert_array_equal(
        np.asarray(swapped.densify())[[0, 1, 3, 4]], np.asarray(grown.densify())[[0, 1, 3, 4]]
    )


def test_apply_tile_updates_is_tile_granular():
    """Only touched tiles reclassify; untouched columns share _Column
    objects outright and cardinality moves by popcount deltas."""
    bits = _tiled_bits(4, 6, 0.5, seed=9, tail_bits=77)
    store = TileStore.from_packed(np.asarray(pack(jnp.asarray(bits))))
    tw = store.tile_words
    new_tile = np.zeros(tw, np.uint32)
    new_tile[:3] = 0xFFFFFFFF
    updated = store.apply_tile_updates({1: {2: new_tile}})
    # untouched columns are shared, not copied
    for i in (0, 2, 3):
        assert updated._cols[i] is store._cols[i]
    dense = np.asarray(updated.densify())
    base = np.asarray(store.densify())
    np.testing.assert_array_equal(dense[[0, 2, 3]], base[[0, 2, 3]])
    np.testing.assert_array_equal(dense[1, 2 * tw : 3 * tw], new_tile)
    np.testing.assert_array_equal(dense[1, : 2 * tw], base[1, : 2 * tw])
    old_tile_pop = int(np.unpackbits(
        base[1, 2 * tw : 3 * tw].view(np.uint8)).sum())
    assert updated.cardinalities[1] == store.cardinalities[1] - old_tile_pop + 96


def test_apply_tile_updates_class_transitions_and_growth():
    bits = _tiled_bits(2, 4, 0.0, seed=10)
    store = TileStore.from_packed(np.asarray(pack(jnp.asarray(bits))))
    tw = store.tile_words
    zeros = np.zeros(tw, np.uint32)
    ones = np.full(tw, 0xFFFFFFFF, np.uint32)
    updated = store.apply_tile_updates({0: {0: zeros, 1: ones}})
    assert updated.classes_word[0, 0] == TILE_ZERO
    assert updated.classes_word[0, 1] == TILE_ONE
    assert updated.dirty_index[0, 0] == -1 and updated.dirty_index[0, 1] == -1
    # universe growth: new tiles default all-zero everywhere
    grown = store.apply_tile_updates({}, r=store.r + 3 * SPAN)
    assert grown.n_tiles == store.n_tiles + 3
    assert (grown.classes_word[:, store.n_tiles :] == TILE_ZERO).all()
    np.testing.assert_array_equal(
        np.asarray(grown.densify())[:, : store.n_words], np.asarray(store.densify())
    )
    assert grown.cardinalities == store.cardinalities
    with pytest.raises(ValueError):
        store.apply_tile_updates({}, r=store.r - 1)  # no shrinking
    with pytest.raises(ValueError):
        store.apply_tile_updates({0: {99: zeros}})  # tile out of range


def test_run_tiled_circuit_restricted_to_tiles():
    bits = _tiled_bits(5, 8, 0.6, seed=11, tail_bits=33)
    store = TileStore.from_packed(np.asarray(pack(jnp.asarray(bits))))
    circ = build_threshold_circuit(5, 2, "ssum")
    full, info_full = run_tiled_circuit(store, circ)
    sel = np.array([0, 3, store.n_tiles - 1])
    sub, info = run_tiled_circuit(store, circ, tiles=sel)
    assert sub.shape == (1, sel.size, store.tile_words)
    assert info["dirty_words_gathered"] <= info_full["dirty_words_gathered"]
    padded = np.zeros(store.n_tiles * store.tile_words, np.uint32)
    padded[: store.n_words] = np.asarray(full)
    padded = padded.reshape(store.n_tiles, store.tile_words)
    for li, t in enumerate(sel.tolist()):
        np.testing.assert_array_equal(sub[0, li], padded[t])


def test_member_stats_per_subset_not_index_mean():
    n_tiles = 8
    clean = np.zeros((1, n_tiles * SPAN), bool)  # fully clean column
    dirty = np.random.default_rng(3).random((1, n_tiles * SPAN)) < 0.5
    store = TileStore.from_packed(pack(jnp.asarray(np.vstack([clean, dirty]))))
    assert store.member_stats([0]).clean_fraction == 1.0
    assert store.member_stats([1]).clean_fraction == 0.0
    assert 0.0 < store.member_stats(None).clean_fraction < 1.0
    assert store.member_stats([0]).dirty_words == 0


# ---------------------------------------------------------------------------
# Compressed containers (sparse + run)
# ---------------------------------------------------------------------------


def _store_of(bits, r=None, containers=True, tile_words=TW):
    return TileStore.from_packed(
        pack(jnp.asarray(bits)), tile_words=tile_words,
        r=r if r is not None else bits.shape[1], containers=containers,
    )


def test_container_classification_crossover_edges():
    """Kind choice at the exact thresholds: popcount == sparse_max is still
    sparse, one more scattered bit tips dense; 1- and 2-interval tiles are
    run containers; run-ineligible interval counts fall through."""
    r = SPAN
    smax = sparse_max_positions(TW)  # 128 positions at TW=64
    rmax = run_max_intervals(TW)
    rows = []
    rng = np.random.default_rng(0)
    at = np.zeros(r, bool)
    at[rng.choice(np.arange(0, r, 2), smax, replace=False)] = True  # no runs>1bit
    rows.append(at)  # popcount exactly at the threshold -> sparse
    over = np.zeros(r, bool)
    over[rng.choice(np.arange(0, r, 2), smax + 1, replace=False)] = True
    rows.append(over)  # one past the threshold, many intervals -> dense
    single = np.zeros(r, bool)
    single[300:2000] = True
    rows.append(single)  # one interval -> run
    double = np.zeros(r, bool)
    double[10:800] = True
    double[1200:1900] = True
    rows.append(double)  # two intervals -> run
    toothy = np.zeros(r, bool)
    toothy[: (rmax + 1) * 2 : 2] = True  # rmax+1 intervals, tiny popcount
    rows.append(toothy)  # run-ineligible but sparse-eligible -> sparse
    store = _store_of(np.stack(rows))
    kinds = store.container_kinds[:, 0]
    assert kinds.tolist() == [
        CONT_SPARSE, CONT_DENSE, CONT_RUN, CONT_RUN, CONT_SPARSE
    ]
    # the decompressed store is bit-identical to the input
    np.testing.assert_array_equal(
        np.asarray(store.densify()), np.asarray(pack(jnp.asarray(np.stack(rows))))
    )
    # storage accounting: sparse = ceil(p/2) words, run = 1 word / interval
    cells = store.storage_words_cell[:, 0]
    assert cells[0] == (smax + 1) // 2 and cells[1] == TW
    assert cells[2] == 1 and cells[3] == 2
    assert cells[4] == (rmax + 2) // 2  # rmax + 1 positions, sparse-coded


def test_container_roundtrip_and_densify_parity():
    """Container and legacy stores densify identically on mixed data with a
    partial final tile; compressed storage never exceeds the dense pack."""
    bits = _tiled_bits(6, 6, 0.5, seed=31, tail_bits=123)
    sparse_rows = np.zeros((2, bits.shape[1]), bool)
    sparse_rows[0, ::997] = True
    sparse_rows[1, 100:5000] = True
    bits = np.vstack([bits, sparse_rows])
    store = _store_of(bits)
    legacy = _store_of(bits, containers=False)
    assert store.containers and not legacy.containers
    np.testing.assert_array_equal(
        np.asarray(store.densify()), np.asarray(legacy.densify())
    )
    assert store.cardinalities == legacy.cardinalities
    assert store.storage_words() <= legacy.storage_words()
    assert (legacy.container_kinds[legacy.classes_word >= TILE_DIRTY]
            == CONT_DENSE).all()
    # the legacy densified-dirty surface still covers every dirty tile
    np.testing.assert_array_equal(
        np.asarray(store.dirty), np.asarray(legacy.dirty)
    )
    # slicing preserves container packs without reclassifying
    sliced = store.slice_tiles(1, 4)
    np.testing.assert_array_equal(
        np.asarray(sliced.densify()),
        np.asarray(store.densify())[:, TW : 4 * TW],
    )
    np.testing.assert_array_equal(
        sliced.container_kinds, store.container_kinds[:, 1:4]
    )
    back = TileStore.concat_tiles(
        [store.slice_tiles(0, 1), sliced, store.slice_tiles(4, store.n_tiles)],
        n_words=store.n_words, r=store.r,
    )
    np.testing.assert_array_equal(
        np.asarray(back.densify()), np.asarray(store.densify())
    )
    np.testing.assert_array_equal(back.container_kinds, store.container_kinds)


def test_apply_tile_updates_reclassifies_containers():
    """Compaction picks the cheapest container per touched tile: a sparse
    tile mutated dense flips kind, clearing it back flips it back."""
    r = 4 * SPAN
    bits = np.zeros((2, r), bool)
    bits[0, ::1009] = True  # sparse everywhere
    bits[1] = np.random.default_rng(5).random(r) < 0.5
    store = _store_of(bits)
    assert store.container_kinds[0, 1] == CONT_SPARSE
    dense_tile = np.asarray(
        pack(jnp.asarray(np.random.default_rng(6).random(SPAN) < 0.5))
    ).astype(np.uint32)
    upd = store.apply_tile_updates({0: {1: dense_tile}})
    assert upd.container_kinds[0, 1] == CONT_DENSE
    np.testing.assert_array_equal(
        np.asarray(upd.densify())[0, TW : 2 * TW], dense_tile
    )
    sparse_tile = np.zeros(TW, np.uint32)
    sparse_tile[3] = 0b1001
    back = upd.apply_tile_updates({0: {1: sparse_tile}})
    assert back.container_kinds[0, 1] == CONT_SPARSE
    run_tile = np.zeros(TW, np.uint32)
    run_tile[:20] = 0xFFFFFFFF
    runb = back.apply_tile_updates({0: {1: run_tile}})
    assert runb.container_kinds[0, 1] == CONT_RUN
    cleared = runb.apply_tile_updates({0: {1: np.zeros(TW, np.uint32)}})
    assert cleared.container_kinds[0, 1] == CONT_NONE
    assert cleared.classes_word[0, 1] == TILE_ZERO
    # cardinality tracked by popcount deltas through every transition
    assert cleared.cardinalities[0] == store.cardinalities[0] - int(
        bits[0, SPAN : 2 * SPAN].sum()
    )


def test_query_results_stored_as_containers():
    """add_column compresses results: the paper's 'the result is again a
    bitmap which can be further processed' loop stays compressed."""
    bits = np.zeros((4, 4 * SPAN), bool)
    bits[0, ::501] = True
    bits[1, ::703] = True
    bits[2, 100:200] = True
    bits[3, SPAN:] = True
    idx = BitmapIndex.from_dense(jnp.asarray(bits))
    res = idx.execute(Threshold(2))
    idx2 = idx.add_column("hot", res)
    kinds = idx2.store.container_kinds[-1]
    dirty = idx2.store.classes_word[-1] >= TILE_DIRTY
    assert dirty.any()
    assert (kinds[dirty] != CONT_DENSE).any()  # stored compressed
    np.testing.assert_array_equal(
        np.asarray(idx2.column("hot")), np.asarray(res)
    )


def test_container_native_execution_differential():
    """Deterministic mirror of tests/test_containers_fuzz.py: mixed column
    kinds, every ALGORITHMS backend on bare thresholds plus circuit-family
    on a composite, container vs legacy vs sharded -- all bit-identical to
    the numpy oracle."""
    rng = np.random.default_rng(17)
    span8 = 8 * 32
    n, r = 5, 4 * span8 + 37
    bits = np.zeros((n, r), bool)
    bits[0, ::131] = True  # sparse
    bits[1, 40:500] = True  # runny
    bits[2] = rng.random(r) < 0.5  # dense
    bits[3, :span8] = True  # clean tile + zeros
    bits[4, ::2] = True  # toothy (run-ineligible, sparse-ineligible)
    counts = bits.sum(0)
    variants = []
    for containers in (True, False):
        idx = BitmapIndex.from_dense(
            jnp.asarray(bits), tile_words=8, containers=containers
        )
        variants += [(containers, False, idx), (containers, True, idx.shard(n_shards=3))]
    for t in (1, 2, n):
        expect = counts >= t
        for containers, sharded, idx in variants:
            for alg in ALGORITHMS:
                if (alg == "wide_or") != (t == 1) and alg == "wide_or":
                    continue
                if alg == "wide_and" and t != n:
                    continue
                res = idx.execute(Threshold(t), backend=alg)
                got = res.gather() if sharded else res
                np.testing.assert_array_equal(
                    np.asarray(unpack(got, r)), expect,
                    err_msg=f"alg={alg} t={t} containers={containers} sharded={sharded}",
                )
    q = And(Interval(2, 4), Not(Col("c1"))) | Parity(over=(Col("c0"), Col("c2")))
    expect = ((counts >= 2) & (counts <= 4) & ~bits[1]) | (
        bits[0] ^ bits[2]
    )
    for containers, sharded, idx in variants:
        for backend in (None, "circuit", "tiled_fused"):
            res = idx.execute(q, backend=backend)
            got = res.gather() if sharded else res
            np.testing.assert_array_equal(
                np.asarray(unpack(got, r)), expect,
                err_msg=f"composite containers={containers} sharded={sharded} {backend}",
            )


def test_event_path_engages_and_reduces_words():
    """On sparse data the executor resolves tiles container-natively (no
    densified gather) and touches far fewer words than the legacy store."""
    rng = np.random.default_rng(23)
    n, n_tiles = 6, 16
    r = n_tiles * SPAN
    bits = rng.random((n, r)) < (20 / SPAN)  # ~20 bits per tile per column
    circ = build_threshold_circuit(n, 1, "ssum")
    store = _store_of(bits)
    legacy = _store_of(bits, containers=False)
    out, info = run_tiled_circuit(store, circ)
    out2, info2 = run_tiled_circuit(legacy, circ)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))
    assert info["event_tiles"] > 0
    assert info["compressed_words_gathered"] > 0
    assert info2["event_tiles"] == 0
    assert info["dirty_words_gathered"] * 4 <= info2["dirty_words_gathered"], (
        info["dirty_words_gathered"], info2["dirty_words_gathered"]
    )
    assert info["words_by_kind"]["sparse"] > 0


def _mixed_bits(seed=29):
    """Deterministic dense/sparse/run/all-zero/all-one/partial-tile mix."""
    rng = np.random.default_rng(seed)
    span8 = 8 * 32
    n, r = 6, 5 * span8 + 41  # partial final tile
    bits = np.zeros((n, r), bool)
    bits[0, ::97] = True  # sparse everywhere
    bits[1, 30:700] = True  # one long run
    bits[2] = rng.random(r) < 0.5  # dense noise
    bits[3, :span8] = True  # all-one tile, zeros elsewhere
    bits[4, ::2] = True  # toothy: dirty but container-ineligible
    bits[5, span8 : 2 * span8] = rng.random(span8) < 0.1  # sparse island
    return bits, r


def test_scan_engine_matches_merge_oracle_deterministic():
    """Deterministic mirror of the fuzz suite's engine differential: the
    single-scan device engine (in-kernel container decode, O(1) dispatch)
    is bit-identical to the host event-merge oracle on dense/sparse/run/
    clean/partial-tile mixes, {containers, legacy} x {full, restricted},
    single- and multi-output circuits -- and launches at most twice."""
    bits, r = _mixed_bits()
    n = bits.shape[0]
    counts = bits.sum(0)
    circs = [
        (build_threshold_circuit(n, 2, "ssum"), counts >= 2),
        (build_interval_circuit(n, 2, 4), (counts >= 2) & (counts <= 4)),
    ]
    for containers in (True, False):
        store = _store_of(bits, containers=containers, tile_words=8)
        for circ, expect in circs:
            out_s, info_s = run_tiled_circuit(store, circ, engine="scan")
            out_m, info_m = run_tiled_circuit(store, circ, engine="merge")
            np.testing.assert_array_equal(
                np.asarray(out_s), np.asarray(out_m),
                err_msg=f"containers={containers}",
            )
            np.testing.assert_array_equal(np.asarray(unpack(out_s, r)), expect)
            assert info_s["engine"] == "scan" and info_m["engine"] == "merge"
            assert info_s["launches"] <= 2, info_s
            # consistent per-kind accounting on BOTH engines (legacy
            # stores used to report zeroed breakdowns on the device path)
            for info in (info_s, info_m):
                if info["densified_tiles"] or info["event_tiles"]:
                    assert sum(info["words_by_kind"].values()) > 0, info
            # restricted-tiles (view-refresh) parity, host [k, n_sel, tw]
            tiles = np.asarray([0, 2, store.n_tiles - 1])
            got_s, ri = run_tiled_circuit(
                store, circ, tiles=tiles, engine="scan"
            )
            got_m, _ = run_tiled_circuit(
                store, circ, tiles=tiles, engine="merge"
            )
            np.testing.assert_array_equal(got_s, got_m)
            assert ri["launches"] <= 2


def test_scan_engine_single_dispatch_multi_residual():
    """A batched multi-query circuit over clean-mixed data produces many
    structurally distinct residual groups; the seed path launched once per
    group, the scan engine at most twice total."""
    bits = _tiled_bits(8, 12, 0.5, seed=3)
    r = bits.shape[1]
    counts = bits.sum(0)
    idx = BitmapIndex.from_dense(jnp.asarray(bits))
    res = idx.execute_many(
        [Threshold(2), Threshold(5), Interval(3, 6)], backend="tiled_fused"
    )
    np.testing.assert_array_equal(np.asarray(unpack(res[0], r)), counts >= 2)
    np.testing.assert_array_equal(np.asarray(unpack(res[1], r)), counts >= 5)
    np.testing.assert_array_equal(
        np.asarray(unpack(res[2], r)), (counts >= 3) & (counts <= 6)
    )
    info = idx.last_info
    assert info["engine"] == "scan"
    assert info["residual_signatures"] >= 2  # genuinely multi-group
    assert info["launches"] <= 2, info
    # the merge oracle on the same workload launches once per group
    import os

    os.environ["REPRO_TILED_ENGINE"] = "merge"
    try:
        idx.execute_many(
            [Threshold(2), Threshold(5), Interval(3, 6)],
            backend="tiled_fused",
        )
    finally:
        del os.environ["REPRO_TILED_ENGINE"]
    assert idx.last_info["launches"] >= info["launches"]


def test_scan_engine_pallas_grid_parity():
    """FORCE_PALLAS_INTERPRET pins the scalar-prefetched Pallas grid kernel
    (the TPU path) against the XLA scan on CPU."""
    from repro.kernels import tiled_scan

    bits, r = _mixed_bits(seed=31)
    n = bits.shape[0]
    store = _store_of(bits, containers=True, tile_words=8)
    circ = build_threshold_circuit(n, 3, "ssum")
    out_xla, _ = run_tiled_circuit(store, circ, engine="scan")
    tiled_scan.FORCE_PALLAS_INTERPRET = True
    tiled_scan.clear_scan_runners()
    try:
        out_pl, _ = run_tiled_circuit(store, circ, engine="scan")
    finally:
        tiled_scan.FORCE_PALLAS_INTERPRET = False
        tiled_scan.clear_scan_runners()
    np.testing.assert_array_equal(np.asarray(out_xla), np.asarray(out_pl))


def test_specialize_memo_is_lru():
    """The residual memo evicts oldest-used entries one at a time (not a
    wholesale clear), and a hit refreshes recency."""
    from repro.storage import tiled

    memo = tiled._SPECIALIZE_MEMO
    saved = dict(memo)
    saved_order = list(memo)
    try:
        memo.clear()
        for i in range(4):
            memo[("c", bytes([i]))] = (None, None, None, None)
        old_cap, tiled._SPECIALIZE_MEMO_CAP = tiled._SPECIALIZE_MEMO_CAP, 4
        try:
            # a hit moves ("c", b"\x00") to the back...
            tiled._specialize_hit = memo.get(("c", b"\x00"))
            memo.move_to_end(("c", b"\x00"))
            bits = _tiled_bits(3, 2, 0.0, seed=5)
            store = _store_of(bits)
            circ = build_threshold_circuit(3, 2, "ssum")
            run_tiled_circuit(store, circ)
            # ...so the eviction (cap 4) drops ("c", b"\x01"), not the
            # refreshed entry and not the whole memo
            assert ("c", b"\x00") in memo
            assert ("c", b"\x01") not in memo
            assert len(memo) >= 3
        finally:
            tiled._SPECIALIZE_MEMO_CAP = old_cap
    finally:
        memo.clear()
        for k in saved_order:
            memo[k] = saved[k]


# ---------------------------------------------------------------------------
# Tiled execution vs oracle
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("clean_fraction", [0.0, 0.9, 1.0])
def test_tiled_circuit_threshold_matches_oracle(clean_fraction):
    n = 9
    bits = _tiled_bits(n, 5, clean_fraction, seed=7, tail_bits=500)
    r = bits.shape[1]
    counts = bits.sum(0)
    store = TileStore.from_packed(pack(jnp.asarray(bits)), r=r)
    for t in (1, 3, n - 1, n):
        circ = build_threshold_circuit(n, t, "ssum")
        out, info = run_tiled_circuit(store, circ)
        np.testing.assert_array_equal(
            np.asarray(unpack(out, r)), counts >= t, err_msg=f"cf={clean_fraction} t={t}"
        )
    if clean_fraction == 1.0:
        assert info["dirty_words_gathered"] <= store.tile_words * store.n_tiles


def test_tiled_circuit_multi_output_shares_gather():
    n = 8
    bits = _tiled_bits(n, 6, 0.8, seed=11)
    r = bits.shape[1]
    counts = bits.sum(0)
    c1 = build_threshold_circuit(n, 3, "ssum")
    c2 = build_interval_circuit(n, 2, 5)
    # one multi-output circuit: merge manually via the query layer instead
    idx = BitmapIndex.from_dense(jnp.asarray(bits))
    res = idx.execute_many([Threshold(3), Interval(2, 5)], backend="tiled_fused")
    np.testing.assert_array_equal(np.asarray(unpack(res[0], r)), counts >= 3)
    np.testing.assert_array_equal(
        np.asarray(unpack(res[1], r)), (counts >= 2) & (counts <= 5)
    )
    # the batch shared ONE tile gather (k outputs, one info record)
    assert idx.last_info["n_outputs"] == 2
    single, _ = run_tiled_circuit(idx.store, c1)
    both_words = idx.last_info["dirty_words_gathered"]
    _, info1 = run_tiled_circuit(idx.store, c1)
    _, info2 = run_tiled_circuit(idx.store, c2)
    assert both_words <= info1["dirty_words_gathered"] + info2["dirty_words_gathered"]


def test_tiled_composite_gets_skipping():
    """Interval/And/Not compositions -- not just bare thresholds -- skip."""
    n = 6
    bits = _tiled_bits(n, 10, 0.95, seed=13)
    r = bits.shape[1]
    counts = bits.sum(0)
    idx = BitmapIndex.from_dense(jnp.asarray(bits))
    q = And(Interval(2, 4), Not(Col("c0")))
    expect = (counts >= 2) & (counts <= 4) & ~bits[0]
    out = idx.execute(q, backend="tiled_fused")
    np.testing.assert_array_equal(np.asarray(unpack(out, r)), expect)
    assert idx.last_info["work_fraction"] < 0.5, idx.last_info
    # and the planner chooses the tiled path by itself on this data
    plan = idx.explain(q)
    assert plan.algorithm == "tiled_fused", plan
    assert plan.cost is not None and plan.cost < n * idx.n_words


def test_planner_cost_model_per_member_subset():
    """Thresholds over a clean subset plan tiled even when the index-wide
    mean is dirty (the per-column-stats requirement)."""
    n_tiles = 8
    clean = _tiled_bits(4, n_tiles, 1.0, seed=17)
    dirty = _tiled_bits(4, n_tiles, 0.0, seed=18)
    bits = np.vstack([clean, dirty])
    idx = BitmapIndex.from_dense(jnp.asarray(bits))
    clean_cols = tuple(f"c{i}" for i in range(4))
    dirty_cols = tuple(f"c{i}" for i in range(4, 8))
    assert idx.explain(Threshold(2, over=clean_cols)).algorithm == "tiled_fused"
    assert idx.explain(Threshold(2, over=dirty_cols)).algorithm != "tiled_fused"
    # candidates carry per-backend words-touched estimates
    plan = idx.explain(Threshold(2, over=clean_cols))
    names = [name for name, _ in plan.candidates]
    assert "tiled_fused" in names and "fused" in names
    counts = clean.sum(0)
    out = idx.execute(Threshold(2, over=clean_cols))
    np.testing.assert_array_equal(np.asarray(unpack(out, bits.shape[1])), counts >= 2)


# ---------------------------------------------------------------------------
# Satellite regressions
# ---------------------------------------------------------------------------


def test_stats_cache_respects_tile_words():
    """stats(tile_words=128) after stats(tile_words=64) must not return the
    64-word-granularity numbers (the seed's cache ignored the argument)."""
    # one 64-word all-one tile next to one dirty tile: at 128-word tiles the
    # pair merges into a single dirty tile, so clean_fraction must change
    bits = np.zeros((1, 2 * SPAN), bool)
    bits[0, :SPAN] = True
    bits[0, SPAN::3] = True
    idx = BitmapIndex.from_dense(jnp.asarray(bits))
    s64 = idx.stats(tile_words=64)
    s128 = idx.stats(tile_words=128)
    assert s64.tile_words == 64 and s128.tile_words == 128
    assert s64.clean_fraction == 0.5
    assert s128.clean_fraction == 0.0
    assert idx.stats(tile_words=64) is s64  # still cached, per granularity
    assert idx.stats(tile_words=128) is s128


def test_single_consolidated_shim_deprecation_warning():
    """The whole fused_*/symmetric shim family warns once per process."""
    from repro.core.deprecation import reset_legacy_shim_warning
    from repro.core.symmetric import interval, parity
    from repro.kernels.ops import fused_interval, fused_threshold

    bits = np.random.default_rng(5).random((6, 200)) < 0.4
    bm = pack(jnp.asarray(bits))
    reset_legacy_shim_warning()
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        fused_threshold(bm, 2)
        interval(bm, 1, 3)
        parity(bm)
        fused_interval(bm, 1, 3)
    ours = [
        w for w in caught
        if issubclass(w.category, DeprecationWarning)
        and "deprecated shim" in str(w.message)
    ]
    assert len(ours) == 1, [str(w.message) for w in caught]


def test_shims_route_through_tiled_path_on_clean_data():
    from repro.core.deprecation import reset_legacy_shim_warning
    from repro.kernels.ops import fused_threshold

    bits = _tiled_bits(5, 8, 1.0, seed=23)
    counts = bits.sum(0)
    reset_legacy_shim_warning()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")
        out = fused_threshold(pack(jnp.asarray(bits)), 2)
    np.testing.assert_array_equal(np.asarray(unpack(out, bits.shape[1])), counts >= 2)
