"""Threshold algorithms: cross-equivalence and degenerate cases."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bitmaps import pack, unpack
from repro.core.threshold import threshold, weighted_threshold

ALGOS = ("scancount", "looped", "ssum", "treeadd", "srtckt", "csvckt", "fused")


def _mk(n, r, density, seed=0):
    rng = np.random.default_rng(seed)
    bits = rng.random((n, r)) < density
    return bits, pack(jnp.asarray(bits))


@pytest.mark.parametrize("n,r,density", [(2, 40, 0.5), (5, 100, 0.3), (8, 64, 0.1),
                                         (16, 257, 0.7), (33, 1000, 0.05)])
def test_all_algorithms_agree(n, r, density):
    bits, bm = _mk(n, r, density)
    counts = bits.sum(0)
    for t in sorted({1, 2, 3, n // 2, n - 1, n}):
        if t < 1:
            continue
        expect = counts >= t
        for alg in ALGOS:
            got = np.asarray(unpack(threshold(bm, t, alg), r))
            np.testing.assert_array_equal(got, expect, err_msg=f"{alg} t={t}")


def test_degenerate_thresholds():
    bits, bm = _mk(6, 90, 0.4)
    # T <= 0 -> all ones; T > N -> all zeros
    assert np.asarray(unpack(threshold(bm, 0), 90)).all()
    assert not np.asarray(unpack(threshold(bm, 7), 90)).any()
    # T=1 == OR, T=N == AND
    np.testing.assert_array_equal(
        np.asarray(unpack(threshold(bm, 1), 90)), bits.any(0)
    )
    np.testing.assert_array_equal(
        np.asarray(unpack(threshold(bm, 6), 90)), bits.all(0)
    )


def test_sopckt_small():
    bits, bm = _mk(5, 70, 0.5)
    counts = bits.sum(0)
    for t in (1, 2, 3):
        got = np.asarray(unpack(threshold(bm, t, "sopckt"), 70))
        np.testing.assert_array_equal(got, counts >= t)


def test_weighted_threshold_replication():
    bits, bm = _mk(3, 50, 0.5)
    w = [2, 1, 3]
    wcounts = (bits * np.array(w)[:, None]).sum(0)
    for t in (2, 3, 5):
        got = np.asarray(unpack(weighted_threshold(bm, w, t), 50))
        np.testing.assert_array_equal(got, wcounts >= t)


def test_static_t_required():
    _, bm = _mk(4, 32, 0.5)
    with pytest.raises((TypeError, ValueError)):
        threshold(bm, jnp.int32(2))  # type: ignore[arg-type]
