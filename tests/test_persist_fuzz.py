"""Differential fuzzing of the persistence subsystem.

Hypothesis drives random column mixes (the ``test_containers_fuzz``
generators: dense, sparse, runny, all-zero, all-one, partial final tile)
through save -> load -> query and asserts bit-identity against the
in-memory original:

  * every ``ALGORITHMS`` backend on bare thresholds over loaded
    (memmap-backed) stores, container-enabled AND legacy all-dense,
  * sharded snapshot directories vs the unsharded index,
  * StreamingIndex checkpoint/recover with random mutation batches,
    checkpointing at a random point (pre- and post-compaction states),
  * crash recovery: the WAL truncated at a random byte offset must
    recover exactly the surviving prefix of mutation batches.

``importorskip``-gated like ``test_properties.py``; the deterministic
mirror lives in ``test_persist.py``.
"""
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro import persist  # noqa: E402
from repro.core.bitmaps import unpack  # noqa: E402
from repro.core.threshold import ALGORITHMS  # noqa: E402
from repro.query import BitmapIndex  # noqa: E402
from repro.query.expr import Col, Interval, Threshold  # noqa: E402
from repro.stream import CompactionPolicy, StreamingIndex  # noqa: E402

SETTINGS = dict(max_examples=10, deadline=None)
TW = 8
SPAN = TW * 32

COLUMN_KINDS = ("dense", "sparse", "runny", "all_zero", "all_one", "mixed")


def _column(rng, kind, r):
    bits = np.zeros(r, bool)
    if kind == "all_one":
        bits[:] = True
    elif kind == "dense":
        bits[:] = rng.random(r) < 0.5
    elif kind == "sparse":
        k = int(rng.integers(1, max(2, r // 64)))
        bits[rng.choice(r, min(k, r), replace=False)] = True
    elif kind == "runny":
        for _ in range(int(rng.integers(1, 5))):
            a = int(rng.integers(0, r))
            b = int(rng.integers(a + 1, r + 1))
            bits[a:b] = True
    elif kind == "mixed":
        for t0 in range(0, r, SPAN):
            bits[t0 : t0 + SPAN] = _column(
                rng, COLUMN_KINDS[int(rng.integers(0, 4))], min(SPAN, r - t0)
            )
    return bits


@st.composite
def column_mix(draw, max_n=6, max_tiles=4):
    n = draw(st.integers(2, max_n))
    n_tiles = draw(st.integers(1, max_tiles))
    tail = draw(st.sampled_from([0, 1, 37, SPAN // 2]))
    seed = draw(st.integers(0, 2**31 - 1))
    kinds = draw(st.lists(st.sampled_from(COLUMN_KINDS), min_size=n, max_size=n))
    r = n_tiles * SPAN + tail
    rng = np.random.default_rng(seed)
    bits = np.stack([_column(rng, k, r) for k in kinds])
    return bits, kinds


def _result_bits(res, r):
    got = res.gather() if hasattr(res, "gather") else res
    return np.asarray(unpack(got, r))


@given(column_mix(), st.booleans(), st.data())
@settings(**SETTINGS)
def test_loaded_store_every_algorithm(tmp_path_factory, mix, containers, data):
    """save -> load -> every backend answers bit-identically to the
    in-memory index, for container-enabled and legacy stores."""
    bits, _ = mix
    n, r = bits.shape
    t = data.draw(st.integers(1, n))
    d = tmp_path_factory.mktemp("fuzz")
    names = [f"c{i}" for i in range(n)]
    idx = BitmapIndex.from_dense(bits, names, tile_words=TW,
                                 containers=containers)
    persist.save(idx, d / "x.bmsnap")
    loaded = persist.load_index(d / "x.bmsnap", verify=True)
    q = Threshold(t)
    expect = bits.sum(0) >= t
    for alg in ALGORITHMS:
        if alg == "wide_or" and t != 1:
            continue
        if alg == "wide_and" and t != n:
            continue
        got = _result_bits(loaded.execute(q, backend=alg), r)
        np.testing.assert_array_equal(
            got, expect, err_msg=f"containers={containers} alg={alg} t={t}")


@given(column_mix(), st.booleans(), st.data())
@settings(**SETTINGS)
def test_sharded_snapshot_differential(tmp_path_factory, mix, containers,
                                       data):
    bits, _ = mix
    n, r = bits.shape
    t = data.draw(st.integers(1, n))
    names = [f"c{i}" for i in range(n)]
    idx = BitmapIndex.from_dense(bits, names, tile_words=TW,
                                 containers=containers)
    sh = idx.shard(n_shards=min(3, idx.store.n_tiles))
    d = tmp_path_factory.mktemp("fuzz") / "sharded"
    sh.save(d)
    back = type(sh).load(d)
    expect = bits.sum(0) >= t
    for q in (Threshold(t), Interval(1, max(1, n - 1))):
        a = _result_bits(idx.execute(q), r)
        b = _result_bits(back.execute(q), r)
        np.testing.assert_array_equal(a, b, err_msg=f"q={q.key()}")
    np.testing.assert_array_equal(
        _result_bits(back.execute(Threshold(t)), r), expect)


@st.composite
def mutation_batches(draw, n, r, max_batches=4):
    batches = []
    for _ in range(draw(st.integers(1, max_batches))):
        seed = draw(st.integers(0, 2**31 - 1))
        k = draw(st.integers(1, 16))
        rng = np.random.default_rng(seed)
        cols = rng.integers(0, n, k)
        pos = rng.integers(0, r, k)
        on = rng.random(k) < 0.5
        # last-write-wins dedup so batched apply == sequential replay
        last = {int(c) * r + int(p): i for i, (c, p) in enumerate(zip(cols, pos))}
        sel = np.asarray(sorted(last.values()))
        batches.append((cols[sel], pos[sel], on[sel]))
    return batches


def _apply(stream, names, batch):
    cols, pos, on = batch
    sets = {names[c]: pos[on & (cols == c)]
            for c in np.unique(cols[on])}
    clears = {names[c]: pos[~on & (cols == c)]
              for c in np.unique(cols[~on])}
    stream.update(sets=sets or None, clears=clears or None)


@given(column_mix(max_n=4, max_tiles=3), st.data())
@settings(**SETTINGS)
def test_stream_recover_differential(tmp_path_factory, mix, data):
    """Random mutation batches, checkpoint at a random point (pre/post
    compaction), recover: the recovered index matches a live reference
    that saw every batch."""
    bits, _ = mix
    n, r = bits.shape
    names = [f"c{i}" for i in range(n)]
    batches = data.draw(mutation_batches(n, r))
    ckpt_after = data.draw(st.integers(0, len(batches)))
    compact_before_ckpt = data.draw(st.booleans())
    d = tmp_path_factory.mktemp("fuzz") / "durable"

    idx = BitmapIndex.from_dense(bits, names, tile_words=TW)
    s = StreamingIndex(idx, policy=CompactionPolicy(auto=False),
                       durable_dir=d)
    s.materialize("mid", Interval(1, max(1, n - 1)))
    ref = StreamingIndex(BitmapIndex.from_dense(bits, names, tile_words=TW),
                         policy=CompactionPolicy(auto=False))
    ref.materialize("mid", Interval(1, max(1, n - 1)))
    for i, b in enumerate(batches):
        _apply(s, names, b)
        _apply(ref, names, b)
        if i + 1 == ckpt_after:
            if compact_before_ckpt:
                s.compact()
            s.checkpoint()
    rec = StreamingIndex.recover(d)
    assert rec.wal_version == s.wal_version
    for q in (Threshold(max(1, n // 2)), Col("mid")):
        np.testing.assert_array_equal(
            _result_bits(ref.execute(q), r), _result_bits(rec.execute(q), r),
            err_msg=f"q={q!r} ckpt_after={ckpt_after}")
    assert rec.count("mid") == ref.count("mid")


@given(column_mix(max_n=3, max_tiles=2), st.data())
@settings(**SETTINGS)
def test_wal_random_truncation_recovers_prefix(tmp_path_factory, mix, data):
    """Chop the WAL at a random offset: recovery must replay exactly the
    surviving record prefix -- never a torn half-batch, never an error."""
    bits, _ = mix
    n, r = bits.shape
    names = [f"c{i}" for i in range(n)]
    batches = data.draw(mutation_batches(n, r, max_batches=3))
    d = tmp_path_factory.mktemp("fuzz") / "durable"
    idx = BitmapIndex.from_dense(bits, names, tile_words=TW)
    s = StreamingIndex(idx, policy=CompactionPolicy(auto=False),
                       durable_dir=d)
    for b in batches:
        _apply(s, names, b)
    wal_path = d / "wal.bmwal"
    raw = wal_path.read_bytes()
    cut = data.draw(st.integers(12, len(raw)))  # >= WAL header
    wal_path.write_bytes(raw[:cut])
    surviving = persist.WriteAheadLog(wal_path).records
    wal_path.write_bytes(raw[:cut])  # undo the opener's tail truncation

    rec = StreamingIndex.recover(d)
    ref = StreamingIndex(BitmapIndex.from_dense(bits, names, tile_words=TW),
                         policy=CompactionPolicy(auto=False))
    for b in batches[:surviving]:
        _apply(ref, names, b)
    q = Threshold(max(1, n // 2))
    np.testing.assert_array_equal(
        _result_bits(ref.execute(q), r), _result_bits(rec.execute(q), r),
        err_msg=f"cut={cut} surviving={surviving}/{len(batches)}")
