"""repro.persist: snapshot format, WAL recovery, paging, golden fixture.

Deterministic coverage of the on-disk subsystem (the hypothesis mirror
lives in ``test_persist_fuzz.py``):

  * format framing: magic/version/crc validation, corrupt-tail rejection
  * snapshot round trips are bit-identical and byte-deterministic, for
    container-enabled AND legacy all-dense stores, and loads are
    zero-copy (memmap-backed pack views)
  * per-shard files round trip through ShardedBitmapIndex without gather
  * WAL: versions stay monotone across rotation, truncation (crash)
    recovers the valid prefix, recover() replays to the live state
  * PagedTileStore answers bit-identically while keeping packs host-side
  * the committed golden snapshot keeps loading AND regenerating
    byte-identically (format-stability contract)
  * ServeEngine warm-starts its slot index from a checkpoint
"""
import importlib.util
import os
from pathlib import Path

import numpy as np
import pytest

from repro import persist
from repro.core.bitmaps import unpack
from repro.persist.wal import _HEADER as _WAL_HEADER
from repro.query import And, BitmapIndex, Col, Interval, Not, Threshold
from repro.query.expr import (
    AndNot,
    Exactly,
    Majority,
    Or,
    Parity,
    Sym,
    Weighted,
)
from repro.stream import CompactionPolicy, StreamingIndex

TW = 8
SPAN = TW * 32

_golden_spec = importlib.util.spec_from_file_location(
    "make_golden", Path(__file__).parent / "data" / "make_golden.py"
)
make_golden = importlib.util.module_from_spec(_golden_spec)
_golden_spec.loader.exec_module(make_golden)


def _mixed_bits(n=6, n_tiles=5, tail=17, seed=0):
    """Columns covering every container kind, partial final tile."""
    r = n_tiles * SPAN + tail
    rng = np.random.default_rng(seed)
    bits = np.zeros((n, r), bool)
    bits[0, :] = True
    bits[2, rng.choice(r, r // 40, replace=False)] = True
    bits[3, r // 8 : r // 2] = True
    bits[4 % n] = rng.random(r) < 0.4
    if n > 5:
        bits[5, : r // 3] = rng.random(r // 3) < 0.6
    return bits


def _index(bits, containers=True):
    names = [f"c{i}" for i in range(bits.shape[0])]
    return BitmapIndex.from_dense(bits, names, tile_words=TW,
                                  containers=containers)


def _assert_same_index(a, b):
    assert tuple(a.names) == tuple(b.names)
    sa, sb = a.store, b.store
    assert (sa.r, sa.n_words, sa.tile_words, sa.n) == (sb.r, sb.n_words,
                                                       sb.tile_words, sb.n)
    np.testing.assert_array_equal(sa.classes_word, sb.classes_word)
    np.testing.assert_array_equal(sa.container_kinds, sb.container_kinds)
    np.testing.assert_array_equal(np.asarray(sa.cardinalities),
                                  np.asarray(sb.cardinalities))
    np.testing.assert_array_equal(np.asarray(sa.densify()),
                                  np.asarray(sb.densify()))


# -- format framing --------------------------------------------------------

def test_rejects_bad_magic_and_version(tmp_path):
    p = tmp_path / "x.bmsnap"
    persist.save(_index(_mixed_bits()), p)
    raw = bytearray(p.read_bytes())
    (tmp_path / "bad_magic.bmsnap").write_bytes(b"NOTMAGIC" + raw[8:])
    with pytest.raises(persist.FormatError):
        persist.read_manifest(tmp_path / "bad_magic.bmsnap")
    bad_ver = bytearray(raw)
    bad_ver[8:12] = (99).to_bytes(4, "little")
    (tmp_path / "bad_ver.bmsnap").write_bytes(bad_ver)
    with pytest.raises(persist.FormatError):
        persist.read_manifest(tmp_path / "bad_ver.bmsnap")


def test_rejects_truncation_and_section_corruption(tmp_path):
    p = tmp_path / "x.bmsnap"
    persist.save(_index(_mixed_bits()), p)
    raw = p.read_bytes()
    (tmp_path / "trunc.bmsnap").write_bytes(raw[: len(raw) // 2])
    with pytest.raises(persist.FormatError):
        persist.read_manifest(tmp_path / "trunc.bmsnap")
    # flip one byte inside the first section: manifest still reads, but
    # verify_snapshot catches the crc mismatch
    manifest = persist.read_manifest(p)
    off = manifest["sections"][0]["offset"]
    corrupt = bytearray(raw)
    corrupt[off] ^= 0xFF
    (tmp_path / "corrupt.bmsnap").write_bytes(corrupt)
    persist.read_manifest(tmp_path / "corrupt.bmsnap")  # framing intact
    with pytest.raises(persist.FormatError):
        persist.verify_snapshot(tmp_path / "corrupt.bmsnap")


def test_snapshot_info(tmp_path):
    p = tmp_path / "x.bmsnap"
    idx = _index(_mixed_bits())
    persist.save(idx, p)
    info = persist.snapshot_info(p)
    assert info["kind"] == "tilestore"
    assert info["n_columns"] == 6
    assert info["names"] == list(idx.names)
    assert info["file_bytes"] == os.path.getsize(p)
    assert info["schema_digest"] == persist.schema_digest(
        tuple(idx.names), idx.store.r, idx.store.tile_words)


# -- snapshot round trips --------------------------------------------------

@pytest.mark.parametrize("containers", [True, False])
def test_round_trip_bit_identical(tmp_path, containers):
    bits = _mixed_bits(seed=3)
    idx = _index(bits, containers=containers)
    p = tmp_path / "x.bmsnap"
    persist.save(idx, p)
    loaded = persist.load_index(p, verify=True)
    _assert_same_index(idx, loaded)
    for q in (Threshold(2), Interval(1, 3), Parity(),
              And(Col("c0"), Not(Col("c2")))):
        np.testing.assert_array_equal(np.asarray(idx.execute(q)),
                                      np.asarray(loaded.execute(q)))


def test_save_is_byte_deterministic(tmp_path):
    idx = _index(_mixed_bits(seed=5))
    p1, p2, p3 = (tmp_path / f"{i}.bmsnap" for i in range(3))
    persist.save(idx, p1)
    persist.save(idx, p2)
    assert p1.read_bytes() == p2.read_bytes()
    # save(load(x)) reproduces x: the writer is a fixed point over loads
    persist.save(persist.load_index(p1), p3)
    assert p3.read_bytes() == p1.read_bytes()


def test_load_is_zero_copy(tmp_path):
    p = tmp_path / "x.bmsnap"
    persist.save(_index(_mixed_bits()), p)
    store = persist.load(p)
    import mmap

    for name in ("dense_pack", "sparse_pack", "run_pack"):
        arr = store.packs[name]
        assert not arr.flags.owndata, name
        base = arr
        while not isinstance(base, (np.memmap, mmap.mmap)):
            base = base.base
            assert base is not None, name  # chain must end in the mapping


def test_load_to_device_and_bare_store(tmp_path):
    bits = _mixed_bits(seed=7)
    store = _index(bits).store
    p = tmp_path / "bare.bmsnap"
    persist.save(store, p)  # no names: loads as a store, not an index
    loaded = persist.load(p, to_device=True)
    np.testing.assert_array_equal(np.asarray(store.densify()),
                                  np.asarray(loaded.densify()))
    with pytest.raises(ValueError):
        persist.load_index(p)


def test_extra_meta_keys_reserved(tmp_path):
    idx = _index(_mixed_bits())
    with pytest.raises(ValueError):
        persist.save(idx, tmp_path / "x.bmsnap", extra={"r": 1})


# -- per-shard files -------------------------------------------------------

def test_sharded_round_trip(tmp_path):
    pytest.importorskip("jax")
    from repro.dist.query import ShardedBitmapIndex

    bits = _mixed_bits(n=5, n_tiles=6, tail=0, seed=11)
    idx = _index(bits)
    sh = ShardedBitmapIndex.from_index(idx)
    d = tmp_path / "sharded"
    sh.save(d)
    m = persist.read_shard_map(d)
    assert m["n_shards"] >= 1
    assert sorted(x.name for x in d.glob("shard-*.bmsnap")) == [
        f"shard-{k:04d}.bmsnap" for k in range(m["n_shards"])]
    back = ShardedBitmapIndex.load(d)
    for q in (Threshold(2), And(Col("c0"), Col("c4"))):
        np.testing.assert_array_equal(
            np.asarray(idx.execute(q)),
            np.asarray(back.execute(q).gather()))
    # one shard loads alone, with its tile bounds
    store0, bounds = persist.load_shard(d, 0)
    assert len(bounds) == 2 and bounds[0] == 0
    assert store0.n == 5


# -- WAL ------------------------------------------------------------------

def test_wal_versions_survive_rotation(tmp_path):
    p = tmp_path / "wal.bmwal"
    with persist.WriteAheadLog(p) as wal:
        v1 = wal.append_update([0], [3], [True])
        v2 = wal.append_rows(np.ones((1, 4), bool))
        assert (v1, v2) == (1, 2)
        wal.rotate()
        assert wal.records == 0
        v3 = wal.append_materialize("m", Threshold(2))
        assert v3 == 3  # monotone across rotation
    with persist.WriteAheadLog(p) as wal2:
        recs = list(wal2.replay())
        assert [r["version"] for r in recs] == [3]
        assert recs[0]["name"] == "m"


def test_wal_truncated_tail_is_dropped(tmp_path):
    p = tmp_path / "wal.bmwal"
    with persist.WriteAheadLog(p) as wal:
        wal.append_update([0, 1], [3, 9], [True, False])
        wal.append_update([2], [5], [True])
    raw = p.read_bytes()
    # chop the last record mid-payload
    (p).write_bytes(raw[:-3])
    with persist.WriteAheadLog(p) as wal:
        assert wal.records == 1
        assert wal.last_version == 1
        recs = list(wal.replay())
        assert len(recs) == 1
        np.testing.assert_array_equal(recs[0]["cols"], [0, 1])
    # corrupt crc of the surviving record -> empty log, header intact
    raw = p.read_bytes()
    flip = bytearray(raw)
    flip[_WAL_HEADER + 8] ^= 0xFF
    p.write_bytes(flip)
    with persist.WriteAheadLog(p) as wal:
        assert wal.records == 0 and wal.last_version == 0


def test_query_codec_round_trips_every_node():
    qs = [
        Threshold(2), Threshold(1, over=(Col("a"), Col("b"))),
        Interval(1, 3), Exactly(2), Parity(), Majority(),
        Sym((False, True, True, False)),
        Weighted((1, 2, 3), 4),
        And(Col("a"), Col("b")), Or(Col("a"), Parity()),
        Not(Col("a")), AndNot(Col("a"), Col("b")),
    ]
    for q in qs:
        assert persist.query_from_obj(persist.query_to_obj(q)) == q


# -- StreamingIndex durability --------------------------------------------

def _stream(bits, tmp_path=None, **kw):
    names = [f"c{i}" for i in range(bits.shape[0])]
    idx = BitmapIndex.from_dense(bits, names, tile_words=TW)
    return StreamingIndex(idx, policy=CompactionPolicy(auto=False),
                          durable_dir=tmp_path, **kw)


def test_stream_checkpoint_recover_round_trip(tmp_path):
    bits = _mixed_bits(seed=13)
    d = tmp_path / "durable"
    s = _stream(bits, d)
    s.materialize("hot", Interval(2, 4))
    s.update(sets={"c1": [5, 77]}, clears={"c0": [3]})
    s.checkpoint()
    s.update(sets={"c2": [200]}, clears={"c1": [5]})  # WAL-only tail
    rec = StreamingIndex.recover(d)
    assert rec.wal_version == s.wal_version
    assert tuple(rec.names) == tuple(s.names)
    assert [v for v in rec.views] == [v for v in s.views]
    for q in (Threshold(2), Col("hot"), Interval(1, 3)):
        np.testing.assert_array_equal(np.asarray(s.execute(q)),
                                      np.asarray(rec.execute(q)))
    assert rec.count("hot") == s.count("hot")
    # recovered instance keeps logging: mutate both, recover again
    for t in (s, rec):
        t.update(sets={"c3": [9]})
    rec2 = StreamingIndex.recover(d)
    np.testing.assert_array_equal(np.asarray(s.execute(Threshold(2))),
                                  np.asarray(rec2.execute(Threshold(2))))


def test_stream_crash_recovery_truncated_wal(tmp_path):
    bits = _mixed_bits(seed=17)
    d = tmp_path / "durable"
    s = _stream(bits, d)
    s.checkpoint()
    s.update(sets={"c1": [10]})
    s.update(sets={"c2": [20]})
    # crash: last WAL record torn mid-write
    wal_path = d / "wal.bmwal"
    raw = wal_path.read_bytes()
    wal_path.write_bytes(raw[:-5])
    rec = StreamingIndex.recover(d)
    # reference: snapshot + ONLY the first update
    ref = _stream(bits)
    ref.update(sets={"c1": [10]})
    np.testing.assert_array_equal(np.asarray(ref.execute(Threshold(1))),
                                  np.asarray(rec.execute(Threshold(1))))
    np.testing.assert_array_equal(np.asarray(ref.execute(Col("c2"))),
                                  np.asarray(rec.execute(Col("c2"))))


def test_stream_append_rows_recovers(tmp_path):
    bits = _mixed_bits(seed=19)
    d = tmp_path / "durable"
    s = _stream(bits, d)
    s.checkpoint()
    extra = np.zeros((bits.shape[0], 40), bool)  # 40 new row positions
    extra[0, ::3] = True
    extra[2, 5] = True
    s.append_rows(extra)
    rec = StreamingIndex.recover(d)
    assert rec.r == s.r
    np.testing.assert_array_equal(np.asarray(s.execute(Threshold(2))),
                                  np.asarray(rec.execute(Threshold(2))))


def test_stream_checkpoint_folds_wal(tmp_path):
    bits = _mixed_bits(seed=23)
    d = tmp_path / "durable"
    s = _stream(bits, d)
    s.update(sets={"c0": [1]})
    v = s.wal_version
    s.checkpoint()
    assert os.path.getsize(d / "wal.bmwal") == _WAL_HEADER  # rotated empty
    rec = StreamingIndex.recover(d)
    assert rec.wal_version == v  # counter survives the rotation
    np.testing.assert_array_equal(np.asarray(s.execute(Threshold(1))),
                                  np.asarray(rec.execute(Threshold(1))))


def test_stream_sharded_durability(tmp_path):
    pytest.importorskip("jax")
    bits = _mixed_bits(n=4, n_tiles=6, tail=0, seed=29)
    names = [f"c{i}" for i in range(4)]
    idx = BitmapIndex.from_dense(bits, names, tile_words=TW).shard()
    d = tmp_path / "durable"
    s = StreamingIndex(idx, policy=CompactionPolicy(auto=False),
                       durable_dir=d)
    s.materialize("pair", Interval(2, 3))
    s.update(sets={"c1": [44]})
    s.checkpoint()
    s.update(clears={"c1": [44]})
    rec = StreamingIndex.recover(d)
    assert (d / "sharded.json").exists()
    for q in (Threshold(2), Col("pair")):
        a, b = s.execute(q), rec.execute(q)
        a = a.gather() if hasattr(a, "gather") else a
        b = b.gather() if hasattr(b, "gather") else b
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -- paged tier ------------------------------------------------------------

def test_paged_store_bit_identical(tmp_path):
    bits = _mixed_bits(seed=31)
    idx = _index(bits)
    p = tmp_path / "x.bmsnap"
    persist.save(idx, p)
    base = persist.load(p)
    paged = persist.PagedTileStore(base, capacity_tiles=4)
    pidx = BitmapIndex(names=tuple(idx.names), _store=paged)
    for q in (Threshold(2), Interval(1, 4), Parity()):
        np.testing.assert_array_equal(np.asarray(idx.execute(q)),
                                      np.asarray(pidx.execute(q)))
    assert len(paged._cache) <= 4  # capacity respected


def test_paged_cache_counters(tmp_path):
    rng = np.random.default_rng(37)
    bits = rng.random((4, 6 * SPAN)) < 0.3  # dense dirty tiles
    idx = _index(bits)
    p = tmp_path / "x.bmsnap"
    persist.save(idx, p)
    paged = persist.PagedTileStore(persist.load(p), capacity_tiles=64)
    pidx = BitmapIndex(names=tuple(idx.names), _store=paged)
    np.testing.assert_array_equal(
        np.asarray(idx.execute(Threshold(2), backend="tiled_fused")),
        np.asarray(pidx.execute(Threshold(2), backend="tiled_fused")))
    i1 = paged.cache_info()
    assert i1["misses"] > 0
    # same member tiles: served from cache
    pidx.execute(Threshold(3), backend="tiled_fused")
    i2 = paged.cache_info()
    assert i2["hits"] > i1["hits"]
    assert i2["full_materializations"] == 0


# -- golden fixture --------------------------------------------------------

def test_golden_fixture_loads_and_queries():
    idx = persist.load_index(make_golden.FIXTURE, verify=True)
    bits = make_golden.golden_bits()
    r = bits.shape[1]
    assert tuple(idx.names) == make_golden.NAMES
    assert idx.store.r == r
    dense = np.stack([np.asarray(unpack(idx.store.column(i), r))
                      for i in range(len(make_golden.NAMES))]).astype(bool)
    np.testing.assert_array_equal(dense, bits)
    for q, exp in (
        (Threshold(2), bits.sum(0) >= 2),
        (Interval(1, 3), (bits.sum(0) >= 1) & (bits.sum(0) <= 3)),
        (And(Col("alpha"), Not(Col("delta"))), bits[0] & ~bits[3]),
    ):
        got = np.asarray(unpack(idx.execute(q), r)).astype(bool)
        np.testing.assert_array_equal(got, exp)


def test_golden_fixture_bytes_are_stable(tmp_path):
    """The writer still produces the committed bytes for the fixed recipe
    -- any drift is a format change and must bump the version."""
    regen = tmp_path / "regen.bmsnap"
    make_golden.write(str(regen))
    assert regen.read_bytes() == Path(make_golden.FIXTURE).read_bytes()


# -- serve warm start ------------------------------------------------------

def test_serve_engine_warm_start(tmp_path):
    jax = pytest.importorskip("jax")
    from repro.configs import get_config
    from repro.models import init_params
    from repro.serve import Request, ServeEngine

    cfg = get_config("qwen3-1.7b", reduced=True)
    params = init_params(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, batch_slots=4, max_seq=64)
    for i in range(3):
        assert eng.submit(Request(rid=i, prompt=[i + 1, 2], max_new=2))
    eng.step()
    d = tmp_path / "slots"
    eng.snapshot_slot_index(d)
    eng.step()  # completes all three -> WAL-only tail frees the slots
    assert eng.free_slots() == [0, 1, 2, 3]

    eng2 = ServeEngine(cfg, params, batch_slots=4, max_seq=64)
    assert eng2.warm_start_slot_index(d)
    assert eng2.free_slots() == eng.free_slots()
    assert eng2.draining_slots() == eng.draining_slots()
    assert eng2._occ_now == eng._occ_now
    # universe mismatch refuses cleanly
    eng3 = ServeEngine(cfg, params, batch_slots=8, max_seq=64)
    assert not eng3.warm_start_slot_index(d)
    assert not eng3.warm_start_slot_index(tmp_path / "nope")
