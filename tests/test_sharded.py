"""Row-sharded execution engine (repro.dist.query).

Oracle parity: every backend on a ShardedTileStore must be bit-identical
to the unsharded TileStore result -- including a shard with ZERO dirty
tiles and a partial final tile in the last shard.  Mesh-dependent paths
(shard_map, sharded serve slot selection) run in-process when 8 XLA
devices exist (the CI tier1-sharded job forces them) and always via a
subprocess with XLA_FLAGS set, like test_dist.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bitmaps import unpack
from repro.core.threshold import ALGORITHMS
from repro.query import And, BitmapIndex, Col, Interval, Not, Threshold

TILE_BITS = 64 * 32
N_SHARDS = 8
TILES_PER_SHARD = 2

ENV = {**os.environ, "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
       "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src")}


def _run(script: str):
    res = subprocess.run(
        [sys.executable, "-c", script], env=ENV, capture_output=True, text=True,
        timeout=600,
    )
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr[-3000:]}"
    return res.stdout


def _mixed_bits(n=10, seed=0, tail_bits=700):
    """Row space of 8 shards (~2 tiles each) + a partial final tile.

    Shard 0 is ALL-ZERO (zero dirty tiles); shards 1-3 are clean-heavy
    (cf >= 0.9); shards 4-7 are dense (cf = 0.0); the tail lands in the
    last shard's final, partial tile.  Tiles are mapped to shards with the
    engine's own boundary function so the layout matches exactly.
    """
    from repro.dist.query import shard_boundaries

    rng = np.random.default_rng(seed)
    n_tiles = N_SHARDS * TILES_PER_SHARD
    r = n_tiles * TILE_BITS + tail_bits
    total_tiles = n_tiles + 1  # the tail occupies one extra, partial tile
    bounds = shard_boundaries(total_tiles, N_SHARDS)
    shard_of = {}
    for s, (t0, t1) in enumerate(bounds):
        for tj in range(t0, t1):
            shard_of[tj] = s
    bits = np.zeros((n, r), bool)
    for i in range(n):
        for tj in range(total_tiles):
            lo, hi = tj * TILE_BITS, min((tj + 1) * TILE_BITS, r)
            shard = shard_of[tj]
            if shard == 0:
                continue  # zero-dirty shard
            if shard < 4:  # clean-heavy: mostly all-zero / all-one tiles
                u = rng.random()
                if u < 0.5:
                    pass
                elif u < 0.95:
                    bits[i, lo:hi] = True
                else:
                    bits[i, lo:hi] = rng.random(hi - lo) < 0.35
            else:  # dense
                bits[i, lo:hi] = rng.random(hi - lo) < 0.35
    return bits


@pytest.fixture(scope="module")
def mixed_index():
    bits = _mixed_bits()
    idx = BitmapIndex.from_dense(jnp.asarray(bits))
    return bits, idx, idx.shard(n_shards=N_SHARDS)


def test_shard_layout(mixed_index):
    bits, idx, sidx = mixed_index
    assert sidx.n_shards == N_SHARDS
    # partial final tile lives in the last shard
    last = sidx.store.shards[-1]
    assert last.n_words < last.n_tiles * last.tile_words
    assert last.r < last.n_words * 32
    # shard 0 has zero dirty tiles
    assert sidx.store.shards[0].dirty_words == 0
    # word offsets tile the global row space exactly
    offs = list(sidx.store.word_offsets) + [idx.n_words]
    assert offs[0] == 0 and all(a < b for a, b in zip(offs, offs[1:]))


def test_every_backend_sharded_matches_unsharded_oracle(mixed_index):
    """Satellite: each ALGORITHMS backend, forced on every shard, must be
    bit-identical to the same backend on the unsharded TileStore index."""
    bits, idx, sidx = mixed_index
    n, r = bits.shape[0], bits.shape[1]
    counts = bits.sum(0)
    for alg in ALGORITHMS:
        t = {"wide_or": 1, "wide_and": n, "sopckt": 2}.get(alg, 4)
        q = Threshold(t)
        want = np.asarray(idx.execute(q, backend=alg))
        got = np.asarray(sidx.execute(q, backend=alg).gather())
        np.testing.assert_array_equal(got, want, err_msg=f"sharded {alg}")
        np.testing.assert_array_equal(
            np.asarray(unpack(jnp.asarray(got), r)), counts >= t,
            err_msg=f"{alg} vs scancount oracle",
        )


def test_mixed_density_heterogeneous_plan(mixed_index):
    """Acceptance: half-clean/half-dense shards produce >= 2 distinct
    per-shard backends and execute bit-identically to the unsharded oracle."""
    bits, idx, sidx = mixed_index
    q = Threshold(4)
    plan = sidx.plan(q)
    assert len(plan.distinct) >= 2, plan.backends
    assert "tiled_fused" in plan.distinct, plan.backends
    got = np.asarray(sidx.execute(q).gather())
    np.testing.assert_array_equal(got, np.asarray(idx.execute(q, backend="ssum")))
    info = sidx.last_info
    assert info["mode"] == "per_shard"
    assert info["backends"] == plan.backends
    # the clean shards actually skipped: far fewer words gathered than dense
    assert info["dirty_words_gathered"] < bits.shape[0] * idx.n_words


def test_composite_query_sharded(mixed_index):
    bits, idx, sidx = mixed_index
    q = And(Interval(2, 6), Not(Threshold(9)))
    got = np.asarray(sidx.execute(q).gather())
    np.testing.assert_array_equal(got, np.asarray(idx.execute(q, backend="circuit")))


def test_execute_many_sharded(mixed_index):
    bits, idx, sidx = mixed_index
    qs = [Threshold(2), Threshold(8), Interval(1, 3)]
    got = sidx.execute_many(qs)
    for q, res in zip(qs, got):
        np.testing.assert_array_equal(
            np.asarray(res.gather()),
            np.asarray(idx.execute(q, backend="circuit")),
            err_msg=str(q),
        )


def test_add_column_shard_wise_no_gather(mixed_index):
    """Results feed back as sharded columns; stale references keep working."""
    bits, idx, sidx = mixed_index
    res = sidx.execute(Threshold(4))
    sidx2 = sidx.add_column("hot", res)
    assert "hot" in sidx2 and "hot" not in sidx
    assert sidx2.n == sidx.n + 1
    q = And(Col("hot"), Threshold(2))
    idx2 = idx.add_column("hot", idx.execute(Threshold(4), backend="ssum"))
    np.testing.assert_array_equal(
        np.asarray(sidx2.execute(q).gather()),
        np.asarray(idx2.execute(q, backend="circuit")),
    )
    # the old sharded index still executes against its own schema
    np.testing.assert_array_equal(
        np.asarray(sidx.execute(Threshold(4)).gather()),
        np.asarray(idx.execute(Threshold(4), backend="ssum")),
    )


def test_replace_column_immutable(mixed_index):
    bits, idx, sidx = mixed_index
    flipped = ~np.asarray(unpack(jnp.asarray(idx.column("c0")), bits.shape[1]))
    from repro.core.bitmaps import pack

    new = pack(jnp.asarray(flipped[None]))[0]
    sidx2 = sidx.replace_column("c0", sidx.store.split(new))
    got0 = np.asarray(sidx.column("c0"))
    got1 = np.asarray(sidx2.column("c0"))
    assert not np.array_equal(got0, got1)
    np.testing.assert_array_equal(got0, np.asarray(idx.column("c0")))


def test_from_sharded_round_trip(mixed_index):
    bits, idx, sidx = mixed_index
    back = BitmapIndex.from_sharded(sidx)
    assert back.names == idx.names and back.r == idx.r
    np.testing.assert_array_equal(np.asarray(back.columns), np.asarray(idx.columns))


def test_single_shard_degenerates_to_unsharded(mixed_index):
    bits, idx, sidx = mixed_index
    s1 = idx.shard(n_shards=1)
    assert s1.n_shards == 1
    got = np.asarray(s1.execute(Threshold(4)).gather())
    np.testing.assert_array_equal(got, np.asarray(idx.execute(Threshold(4), backend="ssum")))


# -- mesh paths (8 XLA devices: in-process under the CI sharded job) --------

needs_mesh = pytest.mark.skipif(
    len(jax.devices()) < 8, reason="needs 8 XLA devices (tier1-sharded job)"
)


@needs_mesh
def test_shard_map_path_in_process():
    from repro.launch.mesh import make_host_mesh

    bits = np.random.default_rng(5).random((10, 16 * TILE_BITS + 300)) < 0.3
    idx = BitmapIndex.from_dense(jnp.asarray(bits))
    mesh = make_host_mesh(data=8, model=1)
    sidx = idx.shard(mesh=mesh)
    q = And(Interval(2, 6), Not(Threshold(9)))
    res = sidx.execute(q)
    assert sidx.last_info["mode"] == "shard_map"
    np.testing.assert_array_equal(
        np.asarray(res.gather()), np.asarray(idx.execute(q, backend="circuit"))
    )


def test_shard_map_acceptance_subprocess():
    """Always-on acceptance check on a real 8-device host platform: one
    compiled circuit under shard_map, heterogeneous per-shard plans on
    mixed-density data, bit-identical to the unsharded oracle."""
    _run(
        """
import numpy as np, jax, jax.numpy as jnp
assert len(jax.devices()) == 8
from repro.launch.mesh import make_host_mesh
from repro.query import BitmapIndex, Threshold
TILE_BITS = 64 * 32
rng = np.random.default_rng(0)
n, n_tiles = 10, 16
r = n_tiles * TILE_BITS + 700
bits = np.zeros((n, r), bool)
for i in range(n):
    for tj in range(n_tiles + 1):
        lo, hi = tj * TILE_BITS, min((tj + 1) * TILE_BITS, r)
        if tj < n_tiles // 2:
            bits[i, lo:hi] = rng.random(hi - lo) < 0.35
        else:
            u = rng.random()
            if u < 0.475:
                pass
            elif u < 0.95:
                bits[i, lo:hi] = True
            else:
                bits[i, lo:hi] = rng.random(hi - lo) < 0.35
idx = BitmapIndex.from_dense(jnp.asarray(bits))
mesh = make_host_mesh(data=8, model=1)
sidx = idx.shard(mesh=mesh)
assert sidx.n_shards == 8

# heterogeneous plan on mixed density data
plan = sidx.plan(Threshold(5))
assert len(plan.distinct) >= 2, plan.backends
got = np.asarray(sidx.execute(Threshold(5)).gather())
want = np.asarray(idx.execute(Threshold(5), backend="ssum"))
assert np.array_equal(got, want)

# dense-everywhere query runs as ONE shard_map
dense_idx = BitmapIndex.from_dense(jnp.asarray(
    np.random.default_rng(1).random((8, 8 * TILE_BITS)) < 0.4))
sdense = dense_idx.shard(mesh=mesh)
res = sdense.execute(Threshold(4))
assert sdense.last_info["mode"] == "shard_map", sdense.last_info
assert np.array_equal(np.asarray(res.gather()),
                      np.asarray(dense_idx.execute(Threshold(4), backend="ssum")))
print("sharded acceptance OK")
"""
    )


def test_serve_engine_sharded_slots_subprocess():
    """Serve slot selection through the sharded path on an 8-device mesh."""
    _run(
        """
import jax, numpy as np
from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.models import init_params
from repro.serve import Request, ServeEngine

assert len(jax.devices()) == 8
cfg = get_config("qwen3-1.7b", reduced=True)
params = init_params(cfg, jax.random.PRNGKey(0))
mesh = make_host_mesh(data=8, model=1)
eng = ServeEngine(cfg, params, batch_slots=256, max_seq=32, mesh=mesh)
from repro.dist.query import ShardedBitmapIndex
sidx = eng.slot_index()
assert isinstance(sidx, ShardedBitmapIndex), type(sidx)
assert sidx.n_shards == 8, sidx.n_shards
assert eng.free_slots() == list(range(256))
assert eng.submit(Request(rid=0, prompt=[1, 2], max_new=2))
assert eng.free_slots() == list(range(1, 256))
assert eng.draining_slots() == []
print("sharded serve OK")
"""
    )
