"""Differential fuzzing of the compressed-container storage engine.

Hypothesis drives random expression trees (Threshold / Interval / Parity /
Weighted composed with ``& | ~ -``) over random column mixes (dense,
sparse, runny, all-zero, all-one, partial final tile) and asserts that
every execution path is bit-identical to the numpy scancount oracle:

  * every backend in ``ALGORITHMS`` on bare thresholds,
  * every circuit-family backend on composite trees,
  * container-enabled vs legacy (all-dense) stores,
  * sharded vs unsharded indexes.

``importorskip``-gated like ``test_properties.py`` -- the deterministic
mirror of the core property lives in ``test_storage.py`` so environments
without hypothesis still cover it.
"""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.bitmaps import unpack  # noqa: E402
from repro.core.threshold import ALGORITHMS  # noqa: E402
from repro.query import BitmapIndex  # noqa: E402
from repro.query.expr import (  # noqa: E402
    And,
    AndNot,
    Col,
    Interval,
    Not,
    Or,
    Parity,
    Threshold,
    Weighted,
)

SETTINGS = dict(max_examples=20, deadline=None)
TW = 8  # small tiles keep universes tiny; containers behave identically
SPAN = TW * 32

COLUMN_KINDS = ("dense", "sparse", "runny", "all_zero", "all_one", "mixed")


def _column(rng, kind, r):
    bits = np.zeros(r, bool)
    if kind == "all_one":
        bits[:] = True
    elif kind == "dense":
        bits[:] = rng.random(r) < 0.5
    elif kind == "sparse":
        k = int(rng.integers(1, max(2, r // 64)))
        bits[rng.choice(r, min(k, r), replace=False)] = True
    elif kind == "runny":
        for _ in range(int(rng.integers(1, 5))):
            a = int(rng.integers(0, r))
            b = int(rng.integers(a + 1, r + 1))
            bits[a:b] = True
    elif kind == "mixed":
        for t0 in range(0, r, SPAN):
            bits[t0 : t0 + SPAN] = _column(
                rng, COLUMN_KINDS[int(rng.integers(0, 4))], min(SPAN, r - t0)
            )
    return bits


@st.composite
def column_mix(draw, max_n=6, max_tiles=4):
    n = draw(st.integers(2, max_n))
    n_tiles = draw(st.integers(1, max_tiles))
    tail = draw(st.sampled_from([0, 1, 37, SPAN // 2]))  # partial final tile
    seed = draw(st.integers(0, 2**31 - 1))
    kinds = draw(st.lists(st.sampled_from(COLUMN_KINDS), min_size=n, max_size=n))
    r = n_tiles * SPAN + tail
    rng = np.random.default_rng(seed)
    bits = np.stack([_column(rng, k, r) for k in kinds])
    return bits, kinds


@st.composite
def expression(draw, n, depth=2):
    """A random query tree over columns c0..c{n-1}."""
    if depth == 0 or draw(st.booleans()):
        over = None
        if draw(st.booleans()):
            k = draw(st.integers(1, n))
            over = tuple(
                Col(f"c{i}")
                for i in draw(
                    st.permutations(range(n)).map(lambda p: sorted(p[:k]))
                )
            )
        m = len(over) if over is not None else n
        leaf = draw(st.sampled_from(["threshold", "interval", "parity", "weighted"]))
        if leaf == "threshold":
            return Threshold(draw(st.integers(0, m + 1)), over=over)
        if leaf == "interval":
            lo = draw(st.integers(0, m))
            return Interval(lo, draw(st.integers(lo, m + 1)), over=over)
        if leaf == "parity":
            return Parity(over=over)
        ws = tuple(draw(st.integers(0, 4)) for _ in range(m))
        if not any(ws):
            ws = (1,) + ws[1:]
        return Weighted(ws, draw(st.integers(1, sum(ws) + 1)), over=over)
    op = draw(st.sampled_from(["and", "or", "not", "andnot"]))
    a = draw(expression(n, depth - 1))
    if op == "not":
        return ~a
    b = draw(expression(n, depth - 1))
    return {"and": a & b, "or": a | b, "andnot": a - b}[op]


def oracle(q, bits):
    """Numpy scancount evaluation of a query tree over dense bits [n, r]."""
    def members(over):
        if over is None:
            return bits
        return np.stack([oracle(m, bits) for m in over])

    if isinstance(q, Col):
        return bits[int(q.name[1:])]
    if isinstance(q, Threshold):
        return members(q.over).sum(0) >= q.t
    if isinstance(q, Interval):
        c = members(q.over).sum(0)
        return (c >= q.lo) & (c <= q.hi)
    if isinstance(q, Parity):
        return members(q.over).sum(0) % 2 == 1
    if isinstance(q, Weighted):
        m = members(q.over)
        return (m * np.asarray(q.weights)[:, None]).sum(0) >= q.t
    if isinstance(q, And):
        out = oracle(q.children[0], bits)
        for c in q.children[1:]:
            out = out & oracle(c, bits)
        return out
    if isinstance(q, Or):
        out = oracle(q.children[0], bits)
        for c in q.children[1:]:
            out = out | oracle(c, bits)
        return out
    if isinstance(q, Not):
        return ~oracle(q.child, bits)
    if isinstance(q, AndNot):
        return oracle(q.keep, bits) & ~oracle(q.drop, bits)
    raise TypeError(type(q))


def _indexes(bits):
    """(label, index) pairs: container-enabled + legacy, each unsharded
    and row-sharded."""
    n = bits.shape[0]
    out = []
    for label, containers in (("containers", True), ("legacy", False)):
        idx = BitmapIndex.from_dense(
            jnp.asarray(bits), tile_words=TW, containers=containers
        )
        out.append((label, idx))
        out.append(
            (f"{label}-sharded", idx.shard(n_shards=min(3, idx.store.n_tiles)))
        )
    return out


def _result_bits(res, r):
    got = res.gather() if hasattr(res, "gather") else res
    return np.asarray(unpack(got, r))


@given(column_mix(), st.data())
@settings(**SETTINGS)
def test_expression_trees_differential(mix, data):
    """Random trees: circuit-family backends + the planner's own choice are
    bit-identical to the oracle on every store/shard variant."""
    bits, _kinds = mix
    n, r = bits.shape
    q = data.draw(expression(n))
    expect = oracle(q, bits)
    for label, idx in _indexes(bits):
        for backend in (None, "circuit", "tiled_fused"):
            got = _result_bits(idx.execute(q, backend=backend), r)
            np.testing.assert_array_equal(
                got, expect, err_msg=f"{label} backend={backend} q={q.key()}"
            )


@given(column_mix(), st.data())
@settings(**SETTINGS)
def test_every_algorithm_bare_threshold_differential(mix, data):
    """Bare thresholds: EVERY ``ALGORITHMS`` backend against the oracle on
    container-enabled stores, sharded and unsharded."""
    bits, _kinds = mix
    n, r = bits.shape
    t = data.draw(st.integers(1, n))
    expect = bits.sum(0) >= t
    q = Threshold(t)
    for label, idx in _indexes(bits):
        if label.startswith("legacy"):
            continue  # legacy parity is covered by the tree test above
        for alg in ALGORITHMS:
            if alg == "wide_or" and t != 1:
                continue
            if alg == "wide_and" and t != n:
                continue
            got = _result_bits(idx.execute(q, backend=alg), r)
            np.testing.assert_array_equal(
                got, expect, err_msg=f"{label} alg={alg} t={t} n={n}"
            )


@given(column_mix(), st.data())
@settings(**SETTINGS)
def test_scan_engine_differential(mix, data):
    """The single-scan device engine (in-kernel container decode) is
    bit-identical to the host event-merge oracle engine and the scancount
    oracle on every store variant -- {containers, legacy} x {sharded,
    unsharded} -- and on restricted-tiles (view-refresh) evaluation."""
    import os

    from repro.query.index import circuit_for
    from repro.storage.tiled import run_tiled_circuit

    bits, _kinds = mix
    n, r = bits.shape
    q = data.draw(expression(n))
    expect = oracle(q, bits)
    for label, idx in _indexes(bits):
        for engine in ("scan", "merge"):
            os.environ["REPRO_TILED_ENGINE"] = engine
            try:
                got = _result_bits(idx.execute(q, backend="tiled_fused"), r)
            finally:
                del os.environ["REPRO_TILED_ENGINE"]
            np.testing.assert_array_equal(
                got, expect, err_msg=f"{label} engine={engine} q={q.key()}"
            )
        store = getattr(idx, "store", None)
        if store is None or not hasattr(store, "classes_word"):
            continue  # sharded wrapper: full-path parity asserted above
        circ = circuit_for((q,), n, tuple(f"c{i}" for i in range(n)))
        nt = store.n_tiles
        tiles = np.asarray(
            sorted(
                data.draw(
                    st.sets(st.integers(0, nt - 1), min_size=1, max_size=nt)
                )
            )
        )
        out_s, info_s = run_tiled_circuit(
            store, circ, tiles=tiles, engine="scan"
        )
        out_m, _ = run_tiled_circuit(store, circ, tiles=tiles, engine="merge")
        np.testing.assert_array_equal(
            np.asarray(out_s), np.asarray(out_m),
            err_msg=f"{label} restricted tiles={tiles.tolist()} q={q.key()}",
        )
        assert info_s["launches"] <= 2, info_s


@given(column_mix())
@settings(**SETTINGS)
def test_container_store_roundtrip(mix):
    """The container store densifies back to exactly the input bits, and
    its cardinalities match, whatever the column mix."""
    bits, _kinds = mix
    idx = BitmapIndex.from_dense(jnp.asarray(bits), tile_words=TW)
    legacy = BitmapIndex.from_dense(
        jnp.asarray(bits), tile_words=TW, containers=False
    )
    np.testing.assert_array_equal(
        np.asarray(idx.store.densify()), np.asarray(legacy.store.densify())
    )
    assert idx.store.cardinalities == tuple(bits.sum(1))
    # compressed storage never exceeds the dense dirty pack
    assert idx.store.storage_words() <= legacy.store.storage_words()
