"""repro.search: similarity search + windowed analytics.

The contracts under test:

* the Sarawagi-Kirpal candidate threshold is EXACT, including the vacuous
  ``T <= 0`` case -- the historical ``max(1, T)`` clamp silently dropped
  true matches sharing zero q-grams with the query (the headline
  regression here);
* ``topk`` returns exactly the brute-force edit-distance top-k, bit-
  identically on every ``ALGORITHMS`` backend, sharded and unsharded;
* appends (records AND new vocabulary) never require a rebuild;
* windowed counts stay correct under append/expiry with tile-granular
  refresh work, and retention compaction preserves the live state.
"""
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import repro.obs as obs
from repro.core.threshold import ALGORITHMS
from repro.query.expr import Col, Threshold
from repro.search import (
    MinHashParams,
    WindowedStream,
    WindowRetentionPolicy,
    band_buckets,
    build_qgram_index,
    edit_distance,
    minhash_signature,
    qgrams,
    sk_threshold,
)

RNG = np.random.default_rng(42)
ALPHA = list("abcdef")


def _corpus(n, lo=3, hi=9, seed=3):
    rng = np.random.default_rng(seed)
    return ["".join(rng.choice(ALPHA, size=rng.integers(lo, hi))) for _ in range(n)]


def _brute_topk(strings, q, k):
    return sorted((edit_distance(q, s), i) for i, s in enumerate(strings))[:k]


# ---------------------------------------------------------------------------
# Tokenization
# ---------------------------------------------------------------------------


class TestTokenize:
    def test_qgrams_padding_and_distinctness(self):
        assert qgrams("ab", 2) == {"#a", "ab", "b$"}
        # repeats collapse: "aaa" has positions #a,aa,aa,a$ but 3 DISTINCT
        assert qgrams("aaa", 2) == {"#a", "aa", "a$"}
        assert qgrams("", 2) == {"#$"}
        with pytest.raises(ValueError):
            qgrams("x", 0)

    def test_sk_threshold_is_raw(self):
        # the bound must come back unclamped: T <= 0 IS the vacuous signal
        assert sk_threshold(11, 2, 1) == 9
        assert sk_threshold(3, 2, 2) == -1
        assert sk_threshold(3, 3, 1) == 0

    def test_minhash_stable_and_shaped(self):
        p = MinHashParams(n_hashes=8, bands=4, buckets=16)
        s1 = minhash_signature(qgrams("hello"), p)
        s2 = minhash_signature(qgrams("hello"), p)
        assert s1.shape == (8,) and (s1 == s2).all()
        b = band_buckets(s1, p)
        assert len(b) == 4 and all(0 <= x < 16 for x in b)
        # identical token sets share every band; empty set is the sentinel
        assert band_buckets(minhash_signature(qgrams("hello"), p), p) == b
        empty = minhash_signature((), p)
        assert (empty == np.iinfo(np.uint64).max).all()
        with pytest.raises(ValueError):
            MinHashParams(n_hashes=7, bands=4)


# ---------------------------------------------------------------------------
# Candidate generation -- the vacuous-threshold regression
# ---------------------------------------------------------------------------


class TestCandidates:
    def test_vacuous_threshold_candidates_all_rows(self):
        """THE bug: 'qz' is within distance 2 of 'zq' but shares ZERO
        bigrams with it.  T = 3 - 3*2 <= 0 means the gram filter excludes
        nothing; the old max(1, T) clamp dropped the true match."""
        corpus = _corpus(40) + ["qz"]
        idx = build_qgram_index(corpus, q=2)
        q = "zq"
        assert not (qgrams(q) & qgrams("qz"))  # zero shared grams
        cand = idx.candidates(q, k=3)
        assert cand.t <= 0 and cand.vacuous
        assert len(cand) == len(corpus)  # all rows, no exclusion
        hits = idx.search(q, k=3)
        assert len(corpus) - 1 in hits.ids.tolist()
        # the clamped filter (>= 1 shared gram) provably misses the match
        clamped = idx.candidates(q, k=0)  # T = n_grams > 0: real filter
        assert len(corpus) - 1 not in clamped.ids.tolist()

    def test_threshold_exactness_vs_gram_counting(self):
        corpus = _corpus(60, seed=9)
        idx = build_qgram_index(corpus, q=2)
        q, k = corpus[7][:-1] + "x", 1
        cand = idx.candidates(q, k)
        grams = qgrams(q)
        assert cand.t == len(grams) - k * 2
        want = [
            i for i, s in enumerate(corpus)
            if len(grams & qgrams(s)) >= cand.t
        ]
        assert cand.ids.tolist() == want
        # screening is sound: every true match is a candidate
        for i, s in enumerate(corpus):
            if edit_distance(q, s) <= k:
                assert i in want

    def test_more_required_than_present_grams_is_empty(self):
        idx = build_qgram_index(["aaaa", "bbbb"], q=2)
        cand = idx.candidates("zxq", k=0)  # no gram exists in the index
        assert not cand.vacuous and len(cand) == 0

    def test_length_filter_keeps_exactness(self):
        corpus = _corpus(50, seed=4) + ["abcdef"]
        idx = build_qgram_index(corpus, q=2)
        q, k = "abcdxf", 1
        plain = idx.candidates(q, k)
        filtered = idx.candidates(q, k, length_filter=True)
        assert set(filtered.ids.tolist()) <= set(plain.ids.tolist())
        # no true match lost: |len(r)-len(q)| <= k is necessary
        for i, s in enumerate(corpus):
            if edit_distance(q, s) <= k:
                assert i in filtered.ids.tolist()

    def test_vacuous_with_length_filter_cuts_rows(self):
        corpus = ["a", "ab", "abcdefgh", "x"]
        idx = build_qgram_index(corpus, q=2)
        cand = idx.candidates("ab", k=2, length_filter=True)
        assert cand.vacuous
        ids = set(cand.ids.tolist())
        assert {0, 1, 3} <= ids and 2 not in ids  # |8 - 2| > 2

    def test_minhash_candidates_hit_identical_record(self):
        corpus = _corpus(30, seed=5) + ["hello"]
        p = MinHashParams(n_hashes=8, bands=4, buckets=64)
        idx = build_qgram_index(corpus, q=2, minhash=p)
        cand = idx.minhash_candidates("hello", min_bands=4)
        assert len(corpus) - 1 in cand.ids.tolist()
        with pytest.raises(ValueError):
            build_qgram_index(corpus, q=2).minhash_candidates("hello")

    def test_posting_lists_match_gram_membership(self):
        corpus = _corpus(25, seed=6)
        idx = build_qgram_index(corpus, q=2)
        q = corpus[3]
        lists = idx.posting_lists(q)
        grams = sorted(g for g in qgrams(q))
        assert len(lists) == len([g for g in grams])
        for g, lst in zip(grams, lists):
            want = [i for i, s in enumerate(corpus) if g in qgrams(s)]
            assert lst.tolist() == want


# ---------------------------------------------------------------------------
# Adaptive top-k: oracle parity on every backend, sharded and unsharded
# ---------------------------------------------------------------------------


class TestTopK:
    CORPUS = _corpus(36, seed=12) + ["hello", "hellp", "zq"]

    @pytest.mark.parametrize("n_shards", [None, 3], ids=["unsharded", "sharded"])
    @pytest.mark.parametrize("backend", (None,) + ALGORITHMS,
                             ids=lambda b: b or "planner")
    def test_oracle_parity_every_backend(self, backend, n_shards):
        idx = build_qgram_index(self.CORPUS, q=2, n_shards=n_shards)
        for q, k in (("hello", 3), ("zq", 5)):
            tk = idx.topk(q, k, backend=backend)
            got = list(zip(tk.distances.tolist(), tk.ids.tolist()))
            assert got == _brute_topk(self.CORPUS, q, k), (q, backend)
            assert len(tk.ids) == k

    def test_vacuous_topk_regression(self):
        """Short query, k larger than any non-vacuous band can supply:
        the loop must fall through to the all-rows band and stay exact."""
        corpus = _corpus(20, seed=8) + ["qz"]
        idx = build_qgram_index(corpus, q=2)
        tk = idx.topk("zq", k=len(corpus))
        assert tk.vacuous
        got = list(zip(tk.distances.tolist(), tk.ids.tolist()))
        assert got == _brute_topk(corpus, "zq", len(corpus))

    def test_relaxation_verifies_only_bands(self):
        corpus = _corpus(200, seed=13) + ["hello", "hellp"]
        idx = build_qgram_index(corpus, q=2)
        tk = idx.topk("hello", 2)
        assert tk.distances.tolist() == [0, 1]
        # the whole point of the band loop: nowhere near the full corpus
        assert tk.verified < len(corpus) // 2
        assert tk.relaxations >= 1 and not tk.vacuous

    def test_max_edits_bounds_the_loop(self):
        corpus = _corpus(15, seed=14)
        idx = build_qgram_index(corpus, q=2)
        tk = idx.topk("zzzzzzzz", k=10, max_edits=1)
        assert all(d <= 1 for d in tk.distances.tolist())

    def test_k_validation(self):
        idx = build_qgram_index(["ab"], q=2)
        with pytest.raises(ValueError):
            idx.topk("ab", 0)


# ---------------------------------------------------------------------------
# Incremental appends (rows AND vocabulary)
# ---------------------------------------------------------------------------


class TestAppend:
    @pytest.mark.parametrize("n_shards", [None, 2], ids=["unsharded", "sharded"])
    def test_append_with_new_grams(self, n_shards):
        corpus = _corpus(30, seed=21)
        idx = build_qgram_index(corpus, q=2, n_shards=n_shards)
        extra = ["zzzyx", corpus[0]]  # never-seen grams + a duplicate
        start, stop = idx.append(extra)
        assert (start, stop) == (30, 32)
        assert idx.r == 32 and idx.record(30) == "zzzyx"
        full = corpus + extra
        m = idx.search("zzzyx", k=1)
        assert 30 in m.ids.tolist()
        for q, k in (("zzzyx", 2), (corpus[0], 3)):
            tk = idx.topk(q, k)
            got = list(zip(tk.distances.tolist(), tk.ids.tolist()))
            assert got == _brute_topk(full, q, k)

    def test_empty_append_is_noop(self):
        idx = build_qgram_index(["abc"], q=2)
        assert idx.append([]) == (1, 1)
        assert idx.r == 1


# ---------------------------------------------------------------------------
# Windowed analytics
# ---------------------------------------------------------------------------


class TestWindow:
    def _brute(self, events, now, window, lo, hi, cols):
        live = [cs for ts, cs in events if ts > now - window]
        return sum(1 for cs in live if lo <= len(set(cs) & set(cols)) <= hi)

    def test_counts_track_expiry(self):
        stores = [f"store:{i}" for i in range(6)]
        ws = WindowedStream(stores, window=100.0,
                            policy=WindowRetentionPolicy(auto=False))
        ws.watch("hot", Threshold(2, over=[Col(s) for s in stores]))
        rng = np.random.default_rng(31)
        events, t = [], 0.0
        for _ in range(40):
            t += float(rng.uniform(1, 10))
            cols = list(rng.choice(stores, size=rng.integers(1, 5), replace=False))
            events.append((t, cols))
        ws.append(events)
        live = [(ts, cs) for ts, cs in events if ts > ws.now - 100.0]
        want = sum(1 for _, cs in live if len(cs) >= 2)
        assert ws.count("hot") == want
        # march the clock; the maintained count must track the brute force
        for now in (t + 20, t + 60, t + 101):
            ws.advance(now)
            want = sum(
                1 for ts, cs in events if ts > now - 100.0 and len(cs) >= 2
            )
            assert ws.count("hot") == want, now
        assert ws.count("hot") == 0 and ws.live_events == 0

    def test_refresh_is_tile_granular(self):
        """Words touched refreshing the window view are bounded by the
        TOUCHED tiles (support + output), never the whole universe."""
        stores = [f"s{i}" for i in range(3)]
        ws = WindowedStream(stores, window=1e6, tile_words=8,
                            policy=WindowRetentionPolicy(auto=False))
        ws.watch("any", Threshold(1, over=[Col(s) for s in stores]))
        # bulk history makes the universe much larger than one tile
        ws.append([(float(i), ["s0"]) for i in range(4000)])
        assert ws.count("any") == 4000
        ws.append([(4000.0, ["s1", "s2"])])
        info = ws.refresh_info("any")
        sup = 1 + len(stores)  # support columns gathered + output written
        tile_words = ws.stream.tile_words
        assert info["words_touched"] <= info["tiles_refreshed"] * tile_words * (sup + 1)
        # the single-event batch touches O(1) tiles, not the universe
        n_tiles = (ws.total_rows + tile_words * 32 - 1) // (tile_words * 32)
        assert info["tiles_refreshed"] <= 2 < n_tiles

    def test_retention_retires_dead_rows(self):
        stores = ["a", "b"]
        ws = WindowedStream(
            stores, window=10.0,
            policy=WindowRetentionPolicy(auto=False, min_dead_rows=1,
                                         max_dead_ratio=0.0),
        )
        ws.watch("either", Threshold(1, over=[Col("a"), Col("b")]))
        ws.append([(float(i), ["a"] if i % 2 else ["b"]) for i in range(50)])
        ws.advance(50.0)  # events with ts <= 40 expired
        live_before = ws.live_events
        count_before = ws.count("either")
        rows_before = ws.total_rows
        assert ws.dead_rows > 0
        dropped = ws.retire()
        assert dropped > 0 and ws.total_rows < rows_before
        assert ws.live_events == live_before
        assert ws.count("either") == count_before
        # stream keeps working after the rewrite
        ws.append([(50.0, ["a", "b"])])
        assert ws.count("either") == count_before + 1

    def test_auto_retention_policy_fires(self):
        ws = WindowedStream(
            ["x"], window=5.0,
            policy=WindowRetentionPolicy(min_dead_rows=64, max_dead_ratio=0.3),
        )
        for i in range(300):
            ws.append([(float(i), ["x"])])
        # most rows expired along the way; the policy must have retired some
        assert ws.dead_rows < 300
        assert ws.count(Col("x")) == ws.live_events

    def test_decayed_count(self):
        ws = WindowedStream(["x", "y"], window=1000.0)
        ws.append([(0.0, ["x"]), (10.0, ["x", "y"]), (20.0, ["y"])])
        got = ws.decayed_count(Col("x"), half_life=10.0, now=20.0)
        assert got == pytest.approx(2.0 ** -2 + 2.0 ** -1)
        assert ws.decayed_count(Col("y"), half_life=10.0, now=20.0) == \
            pytest.approx(2.0 ** -1 + 1.0)

    def test_validation(self):
        with pytest.raises(ValueError):
            WindowedStream([], window=10)
        with pytest.raises(ValueError):
            WindowedStream(["a"], window=0)
        ws = WindowedStream(["a"], window=10)
        with pytest.raises(KeyError):
            ws.append([(0.0, ["nope"])])
        with pytest.raises(ValueError):
            ws.append([(5.0, ["a"]), (1.0, ["a"])])
        ws.append([(5.0, ["a"])])
        with pytest.raises(ValueError):
            ws.advance(1.0)


# ---------------------------------------------------------------------------
# Observability wiring
# ---------------------------------------------------------------------------


class TestObs:
    def test_search_counters_and_spans(self):
        idx = build_qgram_index(_corpus(20, seed=33) + ["qz"], q=2)
        obs.reset()
        obs.enable()
        try:
            idx.search("zq", k=3)
            idx.topk("zq", k=2)
            snap = obs.REGISTRY.snapshot()
            assert snap["repro_search_candidates_total"]["samples"]["qgram"] > 0
            assert snap["repro_search_verifications_total"]["samples"][""] > 0
            assert snap["repro_search_relaxations_total"]["samples"][""] > 0
            assert snap["repro_search_vacuous_total"]["samples"][""] >= 2
            tree = obs.last_trace()
            assert tree is not None and tree.name == "search_topk"
            child_names = {c.name for c in tree.children}
            assert "search_verify" in child_names
        finally:
            obs.disable()
            obs.reset()

    def test_window_counters(self):
        obs.reset()
        obs.enable()
        try:
            ws = WindowedStream(["a"], window=5.0)
            ws.append([(0.0, ["a"]), (1.0, ["a"])])
            ws.advance(10.0)
            snap = obs.REGISTRY.snapshot()
            assert snap["repro_search_window_events_total"]["samples"][""] == 2
            assert snap["repro_search_window_expired_total"]["samples"][""] == 2
        finally:
            obs.disable()
            obs.reset()


# ---------------------------------------------------------------------------
# The example must run clean (no deprecated shim, vacuous demo included)
# ---------------------------------------------------------------------------


def test_example_runs_without_deprecation_warnings():
    root = Path(__file__).resolve().parents[1]
    proc = subprocess.run(
        [sys.executable, "-W", "error::DeprecationWarning",
         str(root / "examples" / "similarity_search.py")],
        capture_output=True, text=True, timeout=600,
        env={**os.environ, "PYTHONPATH": str(root / "src")},
    )
    assert proc.returncode == 0, proc.stderr
    assert "vacuous case OK" in proc.stdout
