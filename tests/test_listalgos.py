"""Integer-list baselines (WHEAP/MGOPT/WMGSK/DSK/WSORT/...): vs scancount."""
import numpy as np
import pytest

from repro.core import listalgos as LA


def _lists(n, r, card, seed=0):
    rng = np.random.default_rng(seed)
    return [np.sort(rng.choice(r, size=rng.integers(1, card), replace=False)) for _ in range(n)]


ALGOS = [LA.wheap, LA.wsort, LA.hashcnt, LA.w2cti, LA.mgopt, LA.wmgsk, LA.dsk]


@pytest.mark.parametrize("algo", ALGOS, ids=lambda f: f.__name__)
@pytest.mark.parametrize("n,r,card", [(5, 500, 200), (12, 2000, 400), (8, 300, 290)])
def test_against_scancount(algo, n, r, card):
    lists = _lists(n, r, card, seed=n)
    for t in sorted({2, 3, n // 2, n - 1}):
        expect = LA.scancount_np(lists, t, r)
        got = algo(lists, t, r)
        np.testing.assert_array_equal(np.asarray(got), expect, err_msg=f"{algo.__name__} t={t}")


def test_skewed_lists_dsk_mgopt():
    """Pruning algorithms with very skewed list sizes (their favoured case)."""
    rng = np.random.default_rng(11)
    r = 5000
    lists = [np.sort(rng.choice(r, size=s, replace=False)) for s in (4000, 3500, 20, 15, 10)]
    for t in (4, 5):
        expect = LA.scancount_np(lists, t, r)
        np.testing.assert_array_equal(LA.mgopt(lists, t, r), expect)
        np.testing.assert_array_equal(LA.dsk(lists, t, r), expect)
        np.testing.assert_array_equal(LA.wmgsk(lists, t, r), expect)


def test_matches_bitmap_threshold():
    import jax.numpy as jnp

    from repro.core.bitmaps import from_positions, to_positions_np
    from repro.core.threshold import threshold

    lists = _lists(7, 800, 300, seed=5)
    bm = jnp.stack([from_positions(l, 800) for l in lists])
    for t in (2, 4, 6):
        got_bitmap = to_positions_np(threshold(bm, t, "ssum"))
        expect = LA.scancount_np(lists, t, 800)
        np.testing.assert_array_equal(got_bitmap, expect)
