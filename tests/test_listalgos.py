"""Integer-list baselines (WHEAP/MGOPT/WMGSK/DSK/WSORT/...): vs scancount."""
import numpy as np
import pytest

from repro.core import listalgos as LA


def _lists(n, r, card, seed=0):
    rng = np.random.default_rng(seed)
    return [np.sort(rng.choice(r, size=rng.integers(1, card), replace=False)) for _ in range(n)]


ALGOS = [LA.wheap, LA.wsort, LA.hashcnt, LA.w2cti, LA.mgopt, LA.wmgsk, LA.dsk]


@pytest.mark.parametrize("algo", ALGOS, ids=lambda f: f.__name__)
@pytest.mark.parametrize("n,r,card", [(5, 500, 200), (12, 2000, 400), (8, 300, 290)])
def test_against_scancount(algo, n, r, card):
    lists = _lists(n, r, card, seed=n)
    for t in sorted({2, 3, n // 2, n - 1}):
        expect = LA.scancount_np(lists, t, r)
        got = algo(lists, t, r)
        np.testing.assert_array_equal(np.asarray(got), expect, err_msg=f"{algo.__name__} t={t}")


def test_skewed_lists_dsk_mgopt():
    """Pruning algorithms with very skewed list sizes (their favoured case)."""
    rng = np.random.default_rng(11)
    r = 5000
    lists = [np.sort(rng.choice(r, size=s, replace=False)) for s in (4000, 3500, 20, 15, 10)]
    for t in (4, 5):
        expect = LA.scancount_np(lists, t, r)
        np.testing.assert_array_equal(LA.mgopt(lists, t, r), expect)
        np.testing.assert_array_equal(LA.dsk(lists, t, r), expect)
        np.testing.assert_array_equal(LA.wmgsk(lists, t, r), expect)


@pytest.mark.parametrize("algo", [LA.wheap, LA.wsort, LA.hashcnt, LA.w2cti,
                                  LA.mgopt, LA.dsk], ids=lambda f: f.__name__)
def test_differential_fuzz(algo):
    """Random list families vs the scancount oracle, hammering the edges
    the similarity-search candidate generator actually produces: t=1
    (union), t=N (intersection), t>N (constant-empty), empty posting
    lists mixed in, and the single-list family.  t >= 1 only -- t<=0 is
    the vacuous case handled ABOVE the list merge, not inside it."""
    rng = np.random.default_rng(hash(algo.__name__) % 2**32)
    for trial in range(25):
        r = int(rng.integers(1, 400))
        n = int(rng.integers(1, 10))
        lists = []
        for _ in range(n):
            size = int(rng.integers(0, max(r // 2, 1) + 1))
            lists.append(np.sort(rng.choice(r, size=size, replace=False)))
        ts = {1, n, n + 1, n + 3, int(rng.integers(1, n + 2))}
        for t in sorted(ts):
            expect = LA.scancount_np(lists, t, r)
            got = np.asarray(algo(lists, t, r))
            np.testing.assert_array_equal(
                got, expect,
                err_msg=f"{algo.__name__} trial={trial} n={n} r={r} t={t}",
            )


@pytest.mark.parametrize("algo", [LA.wheap, LA.wsort, LA.hashcnt, LA.w2cti,
                                  LA.mgopt, LA.dsk], ids=lambda f: f.__name__)
def test_single_list_and_all_empty(algo):
    rng = np.random.default_rng(7)
    r = 64
    one = [np.sort(rng.choice(r, size=9, replace=False))]
    np.testing.assert_array_equal(np.asarray(algo(one, 1, r)), one[0])
    assert np.asarray(algo(one, 2, r)).size == 0  # t > N
    empties = [np.array([], dtype=np.int64)] * 3
    for t in (1, 3, 5):
        assert np.asarray(algo(empties, t, r)).size == 0


def test_matches_bitmap_threshold():
    import jax.numpy as jnp

    from repro.core.bitmaps import from_positions, to_positions_np
    from repro.core.threshold import threshold

    lists = _lists(7, 800, 300, seed=5)
    bm = jnp.stack([from_positions(l, 800) for l in lists])
    for t in (2, 4, 6):
        got_bitmap = to_positions_np(threshold(bm, t, "ssum"))
        expect = LA.scancount_np(lists, t, 800)
        np.testing.assert_array_equal(got_bitmap, expect)
