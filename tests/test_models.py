"""Per-arch smoke tests (reduced configs): one forward + one train step on
CPU, asserting output shapes and no NaNs -- as required by the assignment."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.data import arch_batch
from repro.models import decode_step, forward, init_cache, init_params
from repro.models.model import logits_from_hidden
from repro.train import OptConfig, TrainConfig, init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step_smoke(arch):
    cfg = get_config(arch, reduced=True)
    B, S = 2, 32
    batch = arch_batch(cfg, B, S, "train", seed=1)
    params = init_params(cfg, KEY)
    h, _, aux = forward(params, cfg, batch)
    assert h.shape == (B, S, cfg.d_model)
    assert bool(jnp.isfinite(h).all()), "NaN/Inf in hidden states"
    logits = logits_from_hidden(params, cfg, h)
    assert logits.shape == (B, S, cfg.vocab_padded)

    tc = TrainConfig(opt=OptConfig(peak_lr=1e-3, warmup_steps=2, total_steps=10))
    state = init_train_state(cfg, KEY)
    step = jax.jit(make_train_step(cfg, tc))
    state, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    delta = jax.tree.leaves(
        jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), state["params"], params)
    )
    assert max(delta) > 0


@pytest.mark.parametrize("arch", [a for a in ARCHS if not get_config(a).encoder_only])
def test_prefill_decode_consistency(arch):
    """Decode continuing a prefill must match the full forward pass."""
    cfg = get_config(arch, reduced=True)
    if cfg.moe:
        cfg = dataclasses.replace(cfg, capacity_factor=float(cfg.n_experts))
    B, S, MAX = 2, 24, 32
    params = init_params(cfg, KEY)
    batch = arch_batch(cfg, B, S, "train", seed=2)
    batch.pop("labels", None)
    batch.pop("mask", None)
    h_full, _, _ = forward(params, cfg, batch, mode="prefill", max_seq=MAX)
    full_logits = logits_from_hidden(params, cfg, h_full)
    s_tot = h_full.shape[1]
    batch_p = dict(batch)
    batch_p["tokens"] = batch["tokens"][:, :-1]
    _, caches, _ = forward(params, cfg, batch_p, mode="prefill", max_seq=MAX)
    logits_d, _ = decode_step(
        params, cfg, caches, batch["tokens"][:, -1:], jnp.int32(s_tot - 1)
    )
    np.testing.assert_allclose(
        np.asarray(logits_d[:, 0]), np.asarray(full_logits[:, -1]), atol=2e-3, rtol=1e-2
    )


@pytest.mark.parametrize("arch", [a for a in ARCHS if not get_config(a).encoder_only])
def test_multi_step_decode(arch):
    cfg = get_config(arch, reduced=True)
    B = 2
    params = init_params(cfg, KEY)
    cache = init_cache(cfg, B, 48, jnp.float32)
    tok = jnp.ones((B, 1), jnp.int32)
    for pos in range(4):
        logits, cache = decode_step(params, cfg, cache, tok, jnp.int32(pos))
        assert bool(jnp.isfinite(logits).all())
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


def test_local_window_masks_out_far_context():
    """A 'local' layer must not attend past its window."""
    from repro.models.layers import _attn_mask

    pos = jnp.arange(20)[None, :]
    m = _attn_mask(pos, pos, "local", 4)
    m = np.asarray(m[0])
    assert m[10, 10] and m[10, 7] and not m[10, 6] and not m[10, 11]
    mc = np.asarray(_attn_mask(pos, pos, "attn", 0)[0])
    assert mc[10, 0] and not mc[10, 11]
    mb = np.asarray(_attn_mask(pos, pos, "bidir", 0)[0])
    assert mb.all()


def test_blocked_attention_matches_plain():
    from repro.models.layers import _sdpa, _sdpa_blocked, _attn_mask

    rng = np.random.default_rng(0)
    B, S, Hkv, G, hd = 1, 256, 2, 2, 16
    q = jnp.asarray(rng.normal(size=(B, S, Hkv, G, hd)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(B, S, Hkv, hd)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(B, S, Hkv, hd)).astype(np.float32))
    pos = jnp.arange(S)[None, :]
    for kind, window, cap in [("attn", 0, 0.0), ("local", 64, 0.0), ("attn", 0, 30.0)]:
        mask = _attn_mask(pos, pos, kind, window)
        plain = _sdpa(q, k, v, mask, cap)
        blocked = _sdpa_blocked(q, k, v, pos, pos, kind, window, cap, kv_block=64)
        np.testing.assert_allclose(
            np.asarray(plain), np.asarray(blocked), atol=2e-5, rtol=1e-4
        )


def test_rwkv_chunked_matches_scan():
    from repro.models.rwkv6 import wkv_chunked, wkv_scan

    rng = np.random.default_rng(0)
    B, S, H, hd = 2, 70, 3, 8
    r, k, v = (jnp.asarray(rng.normal(size=(B, S, H, hd)).astype(np.float32)) for _ in range(3))
    logw = -jnp.exp(jnp.asarray(rng.normal(size=(B, S, H, hd)).astype(np.float32)))
    u = jnp.asarray(rng.normal(size=(H, hd)).astype(np.float32))
    s0 = jnp.asarray(rng.normal(size=(B, H, hd, hd)).astype(np.float32))
    o1, st1 = wkv_scan(r, k, v, logw, u, s0)
    o2, st2 = wkv_chunked(r, k, v, logw, u, s0, chunk=32)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=1e-3, rtol=1e-3)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2), atol=1e-3, rtol=1e-3)


def test_rglru_associative_scan_matches_loop():
    """RG-LRU recurrence via associative_scan == sequential reference."""
    rng = np.random.default_rng(1)
    B, S, W = 2, 17, 8
    a = jnp.asarray(rng.uniform(0.5, 0.99, (B, S, W)).astype(np.float32))
    bb = jnp.asarray(rng.normal(size=(B, S, W)).astype(np.float32))

    def op(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, h_scan = jax.lax.associative_scan(op, (a, bb), axis=1)
    h_ref = []
    h = jnp.zeros((B, W))
    for t in range(S):
        h = a[:, t] * h + bb[:, t]
        h_ref.append(h)
    np.testing.assert_allclose(
        np.asarray(h_scan), np.stack([np.asarray(x) for x in h_ref], 1), atol=1e-5
    )


def test_moe_capacity_drops_tokens():
    """Capacity factor 1.0 with adversarial routing must drop tokens
    (Switch-style) without NaNs."""
    cfg = dataclasses.replace(get_config("granite-moe-1b-a400m", reduced=True),
                              capacity_factor=0.5)
    params = init_params(cfg, KEY)
    batch = arch_batch(cfg, 2, 32, "train", seed=3)
    h, _, aux = forward(params, cfg, batch)
    assert bool(jnp.isfinite(h).all())
    assert np.isfinite(float(aux))


def test_param_count_exact_reasonable():
    from repro.models import param_count_exact

    full = get_config("qwen3-1.7b")
    n = param_count_exact(full)
    assert 1.4e9 < n < 2.4e9, n  # ~1.7B class
    mix = param_count_exact(get_config("mixtral-8x22b"))
    assert 1.2e11 < mix < 1.6e11, mix  # ~141B total
    active = get_config("mixtral-8x22b").active_param_count()
    assert 3.0e10 < active < 4.5e10, active  # ~39B active
