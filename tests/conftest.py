import os
import sys

# src layout import path (tests also run as `PYTHONPATH=src pytest tests/`)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# NOTE: do NOT set xla_force_host_platform_device_count here -- smoke tests
# and benches must see 1 device (multi-device tests spawn subprocesses).
