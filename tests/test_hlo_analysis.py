"""Loop-aware HLO analyzer: dot flops x trip counts, collective accounting."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_analysis import analyze_hlo


def test_scan_trip_count_multiplies_dot_flops():
    def f(x):
        def body(c, _):
            return jnp.tanh(c @ c), None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    hlo = jax.jit(f).lower(jnp.ones((64, 64))).compile().as_text()
    r = analyze_hlo(hlo)
    assert r["dot_flops"] == 7 * 2 * 64**3


def test_nested_scans_multiply():
    def g(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ ci, None
            ci, _ = jax.lax.scan(inner, c, None, length=3)
            return jnp.tanh(ci @ ci), None
        out, _ = jax.lax.scan(outer, x, None, length=5)
        return out @ x

    hlo = jax.jit(g).lower(jnp.ones((32, 32))).compile().as_text()
    r = analyze_hlo(hlo)
    assert r["dot_flops"] == (5 * 3 + 5 + 1) * 2 * 32**3


def test_traffic_excludes_fusion_bodies():
    def f(x):
        return jnp.tanh(x * 2.0 + 1.0).sum()

    hlo = jax.jit(f).lower(jnp.ones((256, 256))).compile().as_text()
    r = analyze_hlo(hlo)
    # elementwise chain fuses: traffic should be O(tensor), not O(ops x tensor)
    assert r["hbm_traffic_proxy"] < 12 * 256 * 256 * 4


def test_cost_analysis_undercounts_vs_loop_aware():
    """Documents WHY the analyzer exists: XLA counts scan bodies once."""
    def f(x):
        def body(c, _):
            return c @ c, None
        out, _ = jax.lax.scan(body, x, None, length=16)
        return out

    compiled = jax.jit(f).lower(jnp.ones((48, 48))).compile()
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # jax <= 0.4.x returns one dict per program
        ca = ca[0] if ca else {}
    xla_flops = ca.get("flops", 0.0)
    la = analyze_hlo(compiled.as_text())
    assert la["dot_flops"] == 16 * 2 * 48**3
    assert xla_flops < la["dot_flops"] / 4  # XLA undercounts


def test_collectives_in_loops(tmp_path):
    import subprocess
    import sys
    import os

    script = """
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.launch.hlo_analysis import analyze_hlo
from repro.launch.mesh import make_host_mesh
mesh = make_host_mesh(data=8, model=1)

def f(x):
    def body(c, _):
        return jax.lax.psum(c, "data") * 0.125, None
    out, _ = jax.lax.scan(body, x, None, length=5)
    return out

try:  # jax >= 0.5
    _shard_map, _kw = jax.shard_map, {"check_vma": False}
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map
    _kw = {"check_rep": False}
g = jax.jit(_shard_map(f, mesh=mesh, in_specs=P(None), out_specs=P(None), **_kw))
hlo = g.lower(jnp.ones((1024,))).compile().as_text()
r = analyze_hlo(hlo)
assert r["collective_counts"]["all-reduce"] == 5, r["collective_counts"]
assert r["collective_bytes"]["all-reduce"] == 5 * 1024 * 4, r["collective_bytes"]
print("OK")
"""
    env = {**os.environ, "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
           "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src")}
    res = subprocess.run([sys.executable, "-c", script], env=env, capture_output=True,
                         text=True, timeout=300)
    assert res.returncode == 0, res.stderr[-2000:]
