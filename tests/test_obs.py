"""Unified observability layer (repro.obs): metrics, spans, drift, slow log.

The acceptance bar:

  * a traced execution yields a plan / compile / dispatch (/ decode) span
    tree whose ``measured_words`` equals the executor's own ExecInfo
    accounting -- on EVERY backend, sharded and unsharded;
  * histogram merges are exact and associative (the fixed shared bucket
    edges are what make the cross-shard fold lossless);
  * the serving front-end's counters survive concurrent threaded clients
    with no lost increments, on both the server registry and the global
    mirror;
  * disabled mode mutates NOTHING: zero registry samples, no trace, no
    drift -- the hot path pays one branch;
  * the merged 8-shard ExecInfo equals the per-shard sum by schema;
  * the Prometheus exposition passes the scrape lint.
"""
from __future__ import annotations

import json
import threading

import numpy as np
import pytest

import repro.obs as obs
from repro.core.bitmaps import unpack
from repro.core.threshold import ALGORITHMS
from repro.dist.query import ShardedBitmapIndex
from repro.obs import trace
from repro.obs.registry import HistogramState, MetricsRegistry, lint_prometheus
from repro.obs.slowlog import SlowQueryLog
from repro.query import (
    And,
    BitmapIndex,
    Col,
    Interval,
    Not,
    Threshold,
    clear_compiled_cache,
)
from repro.query.execinfo import EXEC_INFO_SCHEMA, make_exec_info, merge_exec_infos
from repro.serve import QueryServer

N = 10
TILE_BITS = 64 * 32
R = 8 * TILE_BITS + 700  # 8 full tiles + a partial one


def _bits(seed=0, density=0.3):
    rng = np.random.default_rng(seed)
    bits = rng.random((N, R)) < density
    bits[: N // 3, : R // 2] = False  # clean territory for the tiled path
    return bits


def _t_for(alg: str) -> int:
    return {"wide_or": 1, "wide_and": N, "sopckt": 2}.get(alg, 4)


@pytest.fixture(scope="module")
def data():
    bits = _bits()
    return bits, bits.sum(0)


@pytest.fixture(scope="module")
def idx(data):
    bits, _ = data
    return BitmapIndex.from_dense(bits, names=[f"s{i}" for i in range(N)])


@pytest.fixture(autouse=True)
def _obs_clean():
    obs.disable()
    obs.reset()
    yield
    obs.disable()
    obs.reset()


# -- span words == executor words, every backend -----------------------------

def test_span_words_match_exec_info_every_backend(idx, data):
    _, counts = data
    for alg in ALGORITHMS:
        t = _t_for(alg)
        obs.enable()
        got = np.asarray(unpack(idx.execute(Threshold(t), backend=alg), idx.r))
        obs.disable()
        np.testing.assert_array_equal(got, counts >= t, err_msg=alg)
        root = obs.last_trace()
        assert root is not None and root.name == "execute", alg
        assert root.attrs["measured_words"] == idx.last_info["words_touched"], alg
        disp = root.find("dispatch")
        assert disp is not None and disp.attrs["backend"] == alg
        assert disp.attrs["measured_words"] == idx.last_info["words_touched"]
        obs.reset()


def test_span_words_match_exec_info_every_backend_sharded(idx, data):
    _, counts = data
    sidx = ShardedBitmapIndex.from_index(idx, n_shards=4)
    for alg in ALGORITHMS:
        t = _t_for(alg)
        obs.enable()
        res = sidx.execute(Threshold(t), backend=alg)
        obs.disable()
        got = np.asarray(unpack(res.gather(), sidx.r))
        np.testing.assert_array_equal(got, counts >= t, err_msg=alg)
        root = obs.last_trace()
        assert root is not None and root.name == "execute_sharded", alg
        merged = sidx.last_info
        assert root.attrs["measured_words"] == merged["words_touched"], alg
        shard_spans = [s for s in root.iter() if s.name == "shard"]
        assert len(shard_spans) == 4
        assert (
            sum(s.attrs["measured_words"] for s in shard_spans)
            == merged["words_touched"]
        ), alg
        obs.reset()


def test_planner_routed_trace_has_plan_and_predicted_words(idx):
    obs.enable()
    idx.execute(Interval(2, 8))
    obs.disable()
    root = obs.last_trace()
    plan_sp = root.find("plan")
    assert plan_sp is not None
    assert plan_sp.attrs["algorithm"] == root.attrs["backend"]
    assert plan_sp.attrs["predicted_words"] == root.attrs["predicted_words"]
    assert root.attrs["measured_words"] == idx.last_info["words_touched"]
    # the formatted tree is the docs surface: every span line renders
    text = root.format()
    assert "execute" in text and "plan" in text and "dispatch" in text


def test_compile_span_on_miss_hit_annotates_parent(idx):
    clear_compiled_cache()
    obs.enable()
    idx.execute(Interval(3, 7), backend="circuit")
    first = obs.last_trace()
    idx.execute(Interval(3, 7), backend="circuit")
    second = obs.last_trace()
    obs.disable()
    comp = first.find("compile")
    assert comp is not None and comp.attrs["cache"] == "miss"
    # steady state: no zero-duration child span, the hit rides the
    # enclosing dispatch span as an attribute
    assert second.find("compile") is None
    assert second.find("dispatch").attrs.get("compile_cache") == "hit"
    clear_compiled_cache()


def test_decode_span_only_on_tiled_path(idx):
    obs.enable()
    idx.execute(Threshold(4), backend="tiled_fused")
    tiled_root = obs.last_trace()
    idx.execute(Threshold(4), backend="fused")
    dense_root = obs.last_trace()
    obs.disable()
    dec = tiled_root.find("decode")
    assert dec is not None
    assert isinstance(dec.attrs["words_by_kind"], dict)
    # dense backends decode nothing: word accounting rides the dispatch span
    assert dense_root.find("decode") is None
    disp = dense_root.find("dispatch")
    assert disp.attrs["words_by_kind"].get("dense", 0) > 0


def test_acceptance_traced_server_request_full_span_tree():
    """ISSUE 9 acceptance: ONE traced QueryServer request produces a span
    tree with plan / compile / dispatch / decode spans, predicted AND
    measured words populated."""
    rng = np.random.default_rng(9)
    bits = rng.random((12, R)) < 0.25
    bits[:, : R * 7 // 8] = False  # mostly clean: planner routes tiled_fused
    idx2 = BitmapIndex.from_dense(bits, names=[f"store{i}" for i in range(12)])
    assert idx2.explain(Interval(2, 10)).algorithm == "tiled_fused"
    clear_compiled_cache()
    obs.enable()
    server = QueryServer(idx2, window=0)
    fut = server.submit(Interval(2, 10))  # the abstract's query
    while server.pump():
        pass
    fut.result(0)
    obs.disable()
    root = obs.last_trace()
    assert root is not None and root.name == "serve_batch"
    for name in ("execute_many", "plan", "compile", "dispatch", "decode"):
        assert root.find(name) is not None, name
    plan_sp = root.find("plan")
    assert plan_sp.attrs["predicted_words"] is not None
    disp = root.find("dispatch")
    assert disp.attrs["backend"] == "tiled_fused"
    assert disp.attrs["measured_words"] and disp.attrs["measured_words"] > 0
    em = root.find("execute_many")
    assert em.attrs["predicted_words"] is not None
    assert em.attrs["measured_words"] and em.attrs["measured_words"] > 0
    assert obs.drift_samples() >= 1
    clear_compiled_cache()


# -- histogram merge: exact + associative ------------------------------------

def test_histogram_merge_exact_and_associative():
    rng = np.random.default_rng(7)
    parts = []
    for _ in range(3):
        st = HistogramState()
        for v in 10.0 ** rng.uniform(-7.5, 9.5, 200):
            st.observe(float(v))
        parts.append(st)
    a, b, c = parts
    left = a.merge(b).merge(c)
    right = a.merge(b.merge(c))
    assert left.counts == right.counts
    assert left.count == right.count == 600
    assert left.sum == pytest.approx(right.sum)
    # merging equals observing everything into one state (bucket-exactly)
    one = HistogramState()
    for st in parts:
        one.counts = [x + y for x, y in zip(one.counts, st.counts)]
        one.sum += st.sum
        one.count += st.count
    assert left.counts == one.counts
    for q in (0.5, 0.95, 0.99):
        assert np.isfinite(left.quantile(q))


def test_exec_info_merge_associative():
    rng = np.random.default_rng(3)
    infos = [
        make_exec_info(
            "tiled_fused",
            engine="scan",
            words_touched=int(rng.integers(1, 10_000)),
            launches=int(rng.integers(1, 5)),
            decode_words=int(rng.integers(0, 500)),
            words_by_kind={"dense": int(rng.integers(0, 99)), "run": 3},
        )
        for _ in range(3)
    ]
    a, b, c = infos
    left = merge_exec_infos([merge_exec_infos([a, b]), c])
    right = merge_exec_infos([a, merge_exec_infos([b, c])])
    assert left == right
    assert left["words_touched"] == sum(i["words_touched"] for i in infos)


def test_exec_info_schema_sum_at_8_shards(idx):
    """Regression: the merged 8-shard ExecInfo covers the full schema and
    every summable counter equals the per-shard sum (nothing dropped)."""
    sidx = ShardedBitmapIndex.from_index(idx, n_shards=8)
    obs.enable()
    res = sidx.execute(Threshold(4))
    obs.disable()
    merged = sidx.last_info
    assert set(EXEC_INFO_SCHEMA) <= set(merged)
    root = obs.last_trace()
    shard_spans = [s for s in root.iter() if s.name == "shard"]
    assert len(shard_spans) == 8
    for key in ("measured_words", "launches"):
        skey = "words_touched" if key == "measured_words" else key
        assert (
            sum(s.attrs[key] or 0 for s in shard_spans) == merged[skey]
        ), key
    # and the result is still the oracle's
    got = np.asarray(unpack(res.gather(), sidx.r))
    ref = np.asarray(unpack(idx.execute(Threshold(4)), idx.r))
    np.testing.assert_array_equal(got, ref)


# -- threaded serving front-end: no lost increments --------------------------

def test_threaded_server_counts_survive_concurrency(idx):
    obs.enable()
    pool = [Interval(2, 6), Threshold(2, over=("s0", "s3", "s6")),
            And(Col("s1"), Not(Col("s2")))]
    n_clients, per_client = 4, 25
    with QueryServer(idx, window=0.001) as server:
        def client(ci):
            futs = [
                server.submit(pool[(ci + j) % len(pool)])
                for j in range(per_client)
            ]
            for f in futs:
                f.result(30)

        threads = [
            threading.Thread(target=client, args=(ci,))
            for ci in range(n_clients)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        info = server.info()
    obs.disable()
    total = n_clients * per_client
    assert info["requests"] == total
    # every request resolves through the latency histogram exactly once
    assert info["latency"]["count"] == total
    assert np.isfinite(info["latency"]["p99_s"])
    # the global mirror saw the same increments (no lost updates)
    g = obs.REGISTRY.counter("repro_serve_events_total")
    assert int(g.value(event="requests")) == total


# -- disabled mode: zero mutations -------------------------------------------

def test_disabled_mode_mutates_nothing(idx):
    # warm every lazy import + registration the measured calls would do
    obs.enable()
    idx.execute(Interval(2, 8))
    with QueryServer(idx, window=0) as server:
        server.serve_many([Threshold(3)])
    obs.disable()
    obs.reset()
    before = json.dumps(obs.REGISTRY.snapshot(), sort_keys=True, default=str)
    for _ in range(5):
        idx.execute(Interval(2, 8))
        idx.execute(Threshold(4), backend="tiled_fused")
    after = json.dumps(obs.REGISTRY.snapshot(), sort_keys=True, default=str)
    assert before == after
    assert obs.last_trace() is None
    assert obs.drift_samples() == 0
    assert trace.span("anything") is trace.NULL_SPAN
    assert trace.current_span() is trace.NULL_SPAN


# -- drift accounting ---------------------------------------------------------

def test_drift_samples_accumulate_over_100_queries(idx):
    obs.enable()
    for i in range(100):
        idx.execute(Threshold(2 + (i % 5)))
    n = obs.drift_samples()
    obs.disable()
    assert n >= 100
    d = obs.dump()["drift"]
    assert d["samples"] == n
    assert np.isfinite(d["ratio_p50"])


# -- slow-query log -----------------------------------------------------------

def test_slow_query_log_threshold_and_ring(idx):
    obs.enable(slow_query_threshold_s=0.0)  # record everything
    idx.execute(Interval(2, 8))
    assert len(obs.SLOW_QUERIES.entries()) >= 1
    entry = obs.SLOW_QUERIES.entries()[-1]
    assert entry["span"]["name"] == "execute"
    assert "algorithm" in entry["plan"]
    obs.SLOW_QUERIES.set_threshold(999.0)
    obs.SLOW_QUERIES.clear()
    idx.execute(Interval(2, 8))
    assert obs.SLOW_QUERIES.entries() == []
    obs.disable()
    # ring bound: capacity caps retention, dropped counts the overwrites
    log = SlowQueryLog(threshold_s=0.0, capacity=4)
    for i in range(6):
        sp = trace.Span(f"q{i}")
        sp.wall_s = 1.0
        log.maybe_record(sp)
    assert len(log.entries()) == 4
    assert log.dropped == 2


# -- export surfaces ----------------------------------------------------------

def test_prometheus_export_lints_clean_and_jsonl_parses(idx):
    obs.enable()
    for i in range(10):
        idx.execute(Threshold(2 + (i % 4)))
    with QueryServer(idx, window=0) as server:
        server.serve_many([Interval(2, 6), Threshold(3)])
    prom = obs.export_prometheus()
    problems = lint_prometheus(prom)
    obs.disable()
    assert problems == []
    assert "repro_query_wall_seconds" in prom
    for line in obs.export_jsonl().strip().splitlines():
        fam = json.loads(line)
        assert {"name", "type", "samples"} <= set(fam)
    snap = obs.dump()
    assert snap["drift"]["samples"] >= 10
    assert snap["last_trace"] is not None


def test_registry_isolated_instances_and_reset():
    reg = MetricsRegistry(enabled=True)
    c = reg.counter("x_total", "", ("k",))
    bound = c.bind(k="a")
    bound.inc(2)
    c.inc(1, k="b")
    assert c.value(k="a") == 2 and c.value(k="b") == 1
    h = reg.histogram("h_seconds")
    h.observe(0.25)
    assert h.state().count == 1
    reg.reset()
    assert c.value(k="a") == 0 and h.state().count == 0
    bound.inc(3)  # bound handles survive reset and recreate their series
    assert c.value(k="a") == 3
    reg.enabled = False
    bound.inc(5)
    c.inc(5, k="b")
    h.observe(1.0)
    assert c.value(k="a") == 3 and c.value(k="b") == 0 and h.state().count == 0
