"""End-to-end system tests: the paper's workload through the public API,
and the full train driver with crash/resume."""
import subprocess
import sys
import os

import jax.numpy as jnp
import numpy as np

from repro.core import plan_threshold, rbmrg_block_threshold, threshold, unpack
from repro.storage import TileStore
from repro.data.paper_datasets import similarity_query, synthetic_dataset


def test_similarity_query_end_to_end():
    """The paper's motivating workload: items meeting >= T of N criteria,
    answered three ways (oracle, circuit, planner) with identical results."""
    packed, r, lists = synthetic_dataset("clustered", "dense", n_bitmaps=32, card=800, seed=3)
    sel, rid = similarity_query(lists, n=16, rid=int(lists[0][0]), seed=1)
    bm = jnp.asarray(packed[sel])
    t = 6
    oracle = np.asarray(unpack(threshold(bm, t, "scancount"), r))
    circuit = np.asarray(unpack(threshold(bm, t, "fused"), r))
    np.testing.assert_array_equal(oracle, circuit)
    # the query item itself must qualify (it is in every selected bitmap)
    assert oracle[rid]
    # planner route with tile stats from the storage engine
    stats = TileStore.from_packed(bm).block_stats()
    plan = plan_threshold(16, t, clean_fraction=stats.clean_fraction)
    if plan.algorithm == "rbmrg_block":
        out, info = rbmrg_block_threshold(bm, t, stats=stats)
        np.testing.assert_array_equal(np.asarray(unpack(out, r)), oracle)
        assert info["work_fraction"] <= 1.0
    # result is a bitmap: compose with a further AND (bitmap-index property)
    mask = threshold(bm, 1, "ssum")
    composed = jnp.bitwise_and(threshold(bm, t, "ssum"), mask)
    assert np.asarray(unpack(composed, r)).sum() == oracle.sum()


def test_train_driver_cli_with_resume(tmp_path):
    """Run the real launch/train.py CLI: train, 'crash', resume."""
    env = {**os.environ, "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src")}
    args = [
        sys.executable, "-m", "repro.launch.train",
        "--arch", "qwen3-1.7b", "--reduced", "--batch", "4", "--seq", "32",
        "--ckpt-dir", str(tmp_path), "--ckpt-every", "5",
    ]
    r1 = subprocess.run(args + ["--steps", "5"], env=env, capture_output=True, text=True,
                        timeout=600)
    assert r1.returncode == 0, r1.stderr[-2000:]
    r2 = subprocess.run(args + ["--steps", "10"], env=env, capture_output=True, text=True,
                        timeout=600)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "[resume] restored step 5" in r2.stdout, r2.stdout


def test_serve_driver_cli():
    env = {**os.environ, "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src")}
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "qwen3-1.7b", "--reduced",
         "--requests", "6", "--slots", "3", "--max-new", "4"],
        env=env, capture_output=True, text=True, timeout=600,
    )
    assert r.returncode == 0, r.stderr[-2000:]
    assert "served 6 requests" in r.stdout
