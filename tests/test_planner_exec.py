"""Every plan the planner can emit names a runnable executor (ISSUE fix:
the seed's planner returned wide_or/wide_and/rbmrg_block/dsk which
threshold() rejected)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bitmaps import pack, unpack
from repro.core.planner import plan_query, plan_threshold
from repro.core.threshold import ALGORITHMS, threshold
from repro.query import Interval, Threshold, execute


def _mk(n, r, density, seed=0):
    rng = np.random.default_rng(seed)
    bits = rng.random((n, r)) < density
    return bits, pack(jnp.asarray(bits))


# (n, t, planner kwargs, data density) covering every reachable branch
SCENARIOS = [
    (8, 1, {}, 0.3, "wide_or"),
    (8, 8, {}, 0.3, "wide_and"),
    (16, 2, {}, 0.3, "looped"),
    (16, 8, {"clean_fraction": 0.9}, 0.02, "rbmrg_block"),
    (16, 15, {"density": 1e-4, "on_device": False}, 0.005, "dsk"),
    (16, 8, {}, 0.3, "fused"),
    (16, 8, {"fused_available": False}, 0.3, "ssum"),
    (2500, 700, {}, 0.3, "scancount_streaming"),
]


@pytest.mark.parametrize("n,t,kw,density,expected_alg", SCENARIOS)
def test_every_reachable_plan_executes(n, t, kw, density, expected_alg):
    plan = plan_threshold(n, t, **kw)
    assert plan.algorithm == expected_alg, plan
    assert plan.algorithm in ALGORITHMS
    bits, bm = _mk(n, 300, density, seed=n * 31 + t)
    got = np.asarray(unpack(threshold(bm, t, plan.algorithm), 300))
    np.testing.assert_array_equal(got, bits.sum(0) >= t, err_msg=plan.algorithm)


def test_all_algorithm_names_are_executable():
    """threshold() accepts every name in ALGORITHMS (no planner orphan)."""
    bits, bm = _mk(6, 200, 0.3, seed=5)
    counts = bits.sum(0)
    for alg in ALGORITHMS:
        t = {"wide_or": 1, "wide_and": 6}.get(alg, 3)
        got = np.asarray(unpack(threshold(bm, t, alg), 200))
        np.testing.assert_array_equal(got, counts >= t, err_msg=alg)


def test_wide_reductions_validate_t():
    _, bm = _mk(6, 100, 0.5)
    with pytest.raises(ValueError):
        threshold(bm, 3, "wide_or")
    with pytest.raises(ValueError):
        threshold(bm, 3, "wide_and")


TILE_BITS = 64 * 32


def _clean_fraction_bits(n, clean_fraction, seed, n_tiles=5, tail_bits=700):
    """Columns with ~clean_fraction all-zero/all-one tiles + a partial tile."""
    rng = np.random.default_rng(seed)
    r = n_tiles * TILE_BITS + tail_bits
    bits = np.zeros((n, r), bool)
    for i in range(n):
        for tj in range(n_tiles + 1):
            lo, hi = tj * TILE_BITS, min((tj + 1) * TILE_BITS, r)
            u = rng.random()
            if u < clean_fraction / 2:
                pass
            elif u < clean_fraction:
                bits[i, lo:hi] = True
            else:
                bits[i, lo:hi] = rng.random(hi - lo) < 0.35
    return bits


@pytest.mark.parametrize("clean_fraction", [0.0, 0.9, 1.0])
def test_all_backends_execute_against_tilestore_index(clean_fraction):
    """Acceptance: every ALGORITHMS backend runs against a TileStore-backed
    index and matches the oracle -- at clean fractions 0.0/0.9/1.0 and with
    a partial final tile."""
    from repro.query import BitmapIndex

    n = 10
    bits = _clean_fraction_bits(n, clean_fraction, seed=int(clean_fraction * 10) + 2)
    r = bits.shape[1]
    counts = bits.sum(0)
    idx = BitmapIndex.from_dense(jnp.asarray(bits))
    assert idx.store.n_tiles * idx.store.tile_words > idx.n_words  # partial tile
    for alg in ALGORITHMS:
        t = {"wide_or": 1, "wide_and": n, "sopckt": 2}.get(alg, 4)
        got = np.asarray(unpack(idx.execute(Threshold(t), backend=alg), r))
        np.testing.assert_array_equal(
            got, counts >= t, err_msg=f"{alg} cf={clean_fraction}"
        )


def test_planner_emits_tiled_fused_on_clean_data():
    from repro.query import BitmapIndex

    bits = _clean_fraction_bits(8, 0.95, seed=5)
    idx = BitmapIndex.from_dense(jnp.asarray(bits))
    plan = idx.explain(Threshold(4))
    assert plan.algorithm == "tiled_fused", plan
    assert plan.cost is not None
    dense = dict(plan.candidates).get("fused")
    assert dense is not None and plan.cost < dense
    counts = bits.sum(0)
    got = np.asarray(unpack(idx.execute(Threshold(4)), bits.shape[1]))
    np.testing.assert_array_equal(got, counts >= 4)
    # words-touched accounting from the actual run
    assert idx.last_info is not None
    assert idx.last_info["dirty_words_gathered"] < idx.n * idx.n_words


def test_planner_prices_signature_dispatch_overhead():
    """Regression (BENCH_query.json): tiled_fused was 5-16x slower on wall
    time than fused at clean_fraction <= 0.5 despite touching fewer words,
    because every specialization signature was a separate launch.  The cost
    model now prices launch groups, so the planner must NOT pick tiled_fused
    at cf=0.0 / cf=0.5 and must still pick it on clean-dominated data."""
    from repro.query import BitmapIndex

    n, n_tiles = 8, 8
    for cf, expect_tiled in ((0.0, False), (0.5, False), (0.95, True)):
        bits = _bench_clean_fraction_bits(n, n_tiles, cf, seed=int(cf * 100) + 1)
        idx = BitmapIndex.from_dense(jnp.asarray(bits))
        plan = idx.explain(Threshold(n // 2))
        if expect_tiled:
            assert plan.algorithm == "tiled_fused", (cf, plan)
        else:
            assert plan.algorithm != "tiled_fused", (cf, plan)
            # with the fused kernel available the dense sweep must win
            stats = idx.store.member_stats(None)
            from repro.core.planner import plan_threshold

            p = plan_threshold(n, n // 2, stats=stats, fused_available=True)
            assert p.algorithm == "fused", (cf, p)
        # the estimate includes per-launch overhead: visible in candidates
        cands = dict(plan.candidates)
        assert "tiled_fused" in cands


def _bench_clean_fraction_bits(n, n_tiles, clean_fraction, seed=0, span=64 * 32):
    """The query_bench generator (duplicated: benchmarks/ is not a package)."""
    rng = np.random.default_rng(seed)
    bits = np.zeros((n, n_tiles * span), bool)
    for i in range(n):
        for tj in range(n_tiles):
            u = rng.random()
            lo, hi = tj * span, (tj + 1) * span
            if u < clean_fraction / 2:
                pass
            elif u < clean_fraction:
                bits[i, lo:hi] = True
            else:
                bits[i, lo:hi] = rng.random(span) < 0.35
    return bits


def test_container_cost_monotone_and_bounded_by_dense():
    """Container-aware pricing: tiled_fused estimates grow monotonically
    with container size and never exceed the same store's dense-pack
    estimate (ratio == 1.0 when every container is dense)."""
    from repro.core.planner import estimate_words_touched
    from repro.storage import TileStore

    n, n_tiles, span = 4, 8, 64 * 32
    prev = None
    for bits_per_tile in (1, 8, 32, 64, 120, 1024):
        rng = np.random.default_rng(bits_per_tile)
        bits = np.zeros((n, n_tiles * span), bool)
        for i in range(n):
            for t in range(n_tiles):
                bits[i, t * span + rng.choice(span, bits_per_tile,
                                              replace=False)] = True
        store = TileStore.from_packed(pack(jnp.asarray(bits)))
        legacy = TileStore.from_packed(pack(jnp.asarray(bits)),
                                       containers=False)
        stats = store.member_stats(None)
        est = estimate_words_touched(
            "tiled_fused", n, 1, n_words=stats.n_words, stats=stats
        )
        dense_est = estimate_words_touched(
            "tiled_fused", n, 1, n_words=stats.n_words,
            stats=legacy.member_stats(None),
        )
        assert est is not None and est <= dense_est, (bits_per_tile, est, dense_est)
        assert stats.compressed_words <= stats.dirty_words
        if prev is not None:
            assert est >= prev, (bits_per_tile, est, prev)
        prev = est
    # fully dense: the container store prices exactly like the legacy one
    assert est == dense_est


def test_bench_words_touched_never_exceed_dense_estimate():
    """BENCH_query.json regression guard (like the cf<=0.5 tiled_fused bug
    fixed in PR 3): recorded words-touched for the tiled/container paths
    must never exceed the dense-store estimate for the same query, the
    density <= 1e-3 sweep points must show the >= 4x container reduction,
    and density 0.5 must show no regression."""
    import json
    import pathlib

    path = pathlib.Path(__file__).resolve().parent.parent / "BENCH_query.json"
    if not path.exists():
        pytest.skip("no BENCH_query.json checked in")
    data = json.loads(path.read_text())
    rows = data.get("sparsity_sweep")
    if not rows:
        pytest.skip("BENCH_query.json predates the sparsity sweep")
    for row in rows:
        assert row["words_touched"] <= row["dense_words"], row["density"]
        assert row["words_touched"] <= row["words_touched_legacy"], row["density"]
        assert row["memory_words"] <= row["memory_words_legacy"], row["density"]
        if row["density"] <= 1e-3:
            assert row["reduction"] >= 4.0, row
        if row["density"] >= 0.5:
            assert row["words_touched"] == row["words_touched_legacy"], row
            assert row["memory_words"] == row["memory_words_legacy"], row
    for row in data.get("clean_fraction_sweep", []):
        tiled = row["backends"]["tiled_fused"]["words_touched"]
        dense = row["backends"]["fused"]["words_touched"]
        assert tiled <= dense, row["clean_fraction"]


def test_collapsed_launch_pricing_and_realised_counters():
    """Regression for the single-scan engine recalibration: the launch
    overhead now prices at most two dispatches (plus per-group switch
    overhead and a decode-staging factor), and that must NOT re-admit
    tiled_fused at cf <= 0.5 on the scalar threshold path -- while the
    plan-predicted words ordering still matches the realised ``info``
    counters on the scan path."""
    from repro.core.planner import estimate_words_touched
    from repro.query import BitmapIndex

    n, n_tiles = 8, 8
    realised = {}
    predicted = {}
    for cf in (0.0, 0.5, 0.95):
        bits = _bench_clean_fraction_bits(n, n_tiles, cf, seed=int(cf * 100) + 1)
        idx = BitmapIndex.from_dense(jnp.asarray(bits))
        stats = idx.store.member_stats(None)
        plan = plan_threshold(n, n // 2, stats=stats, fused_available=True)
        if cf <= 0.5:
            assert plan.algorithm != "tiled_fused", (cf, plan)
        else:
            assert plan.algorithm == "tiled_fused", (cf, plan)
        predicted[cf] = estimate_words_touched(
            "tiled_fused", n, n // 2, n_words=stats.n_words, stats=stats
        )
        idx.execute(Threshold(n // 2), backend="tiled_fused")
        info = idx.last_info
        assert info["engine"] == "scan"
        assert info["launches"] <= 2, (cf, info)
        realised[cf] = info["dirty_words_gathered"]
    # cheaper predictions must correspond to fewer realised words
    assert predicted[0.95] < predicted[0.5] < predicted[0.0]
    assert realised[0.95] < realised[0.5] < realised[0.0]


def test_plan_query_names_resolve():
    """plan_query outputs execute directly through the query layer."""
    bits, bm = _mk(10, 300, 0.3, seed=9)
    counts = bits.sum(0)
    cases = [
        (Threshold(1), counts >= 1),
        (Threshold(5), counts >= 5),
        (Interval(2, 6), (counts >= 2) & (counts <= 6)),
        (Interval(2, 6) & ~Threshold(8), (counts >= 2) & (counts <= 6) & ~(counts >= 8)),
    ]
    for q, expect in cases:
        plan = plan_query(q, 10)
        got = np.asarray(unpack(execute(bm, q), 300))
        np.testing.assert_array_equal(got, expect, err_msg=f"{q} via {plan.algorithm}")


def test_planner_picks_min_cost_candidate():
    """Regression: with member statistics in hand, the plan used to fall
    through to the scalar default -- selecting ssum (45056 cost words at
    clean_fraction <= 0.5) while its own candidate list priced fused at
    4608 whenever the fused kernel wasn't flagged available.  The stats
    path must end by picking the min-cost runnable candidate (the fused
    backend runs everywhere: Pallas on TPU, interpret/XLA elsewhere),
    with tiled_fused still owned by the advantage gate."""
    from repro.query import BitmapIndex

    n, n_tiles = 8, 8
    for cf in (0.0, 0.5, 0.95):
        bits = _bench_clean_fraction_bits(n, n_tiles, cf, seed=int(cf * 100) + 1)
        idx = BitmapIndex.from_dense(jnp.asarray(bits))
        stats = idx.store.member_stats(None)
        for fused_available in (True, False):
            p = plan_threshold(n, n // 2, stats=stats,
                               fused_available=fused_available)
            cands = dict(p.candidates)
            non_tiled = {k: v for k, v in cands.items() if k != "tiled_fused"}
            if p.algorithm != "tiled_fused":
                best = min(non_tiled, key=non_tiled.get)
                assert p.algorithm == best, (cf, fused_available, p, non_tiled)
                assert p.cost == non_tiled[best]
        # the chosen plan executes bit-identically to the oracle backend
        got = np.asarray(idx.execute(Threshold(n // 2)))
        ref = np.asarray(idx.execute(Threshold(n // 2), backend="scancount"))
        np.testing.assert_array_equal(got, ref, err_msg=f"cf={cf}")
    # the concrete regression: cf=0.5 with fused "unavailable" picks fused
    # by cost, never the 10x-priced ssum fallback
    bits = _bench_clean_fraction_bits(n, n_tiles, 0.5, seed=51)
    idx = BitmapIndex.from_dense(jnp.asarray(bits))
    p = plan_threshold(n, n // 2, stats=idx.store.member_stats(None),
                       fused_available=False)
    assert p.algorithm == "fused", p
    # scalar path (no stats) keeps the documented default rules
    assert plan_threshold(16, 8, fused_available=False).algorithm == "ssum"


# ---------------------------------------------------------------------------
# Feedback-calibrated planner (core.calibration)
# ---------------------------------------------------------------------------


@pytest.fixture()
def _no_calibration():
    """Tests below install calibrations; never leak one into other tests."""
    from repro.core.calibration import clear_calibration

    clear_calibration()
    yield
    clear_calibration()


def test_identity_calibration_never_inverts_words_ranking(_no_calibration):
    """Regression anchor: a uniform words->us rate must reproduce the raw
    words-touched ranking exactly -- same chosen backend, and every
    candidate's calibrated price a fixed rescale of its words price -- at
    clean fractions 0.0 / 0.5 / 0.95."""
    from repro.core.calibration import Calibration, clear_calibration, set_calibration
    from repro.query import BitmapIndex

    n, n_tiles = 8, 8
    for cf in (0.0, 0.5, 0.95):
        bits = _bench_clean_fraction_bits(n, n_tiles, cf, seed=int(cf * 100) + 1)
        idx = BitmapIndex.from_dense(jnp.asarray(bits))
        stats = idx.store.member_stats(None)
        clear_calibration()
        base = plan_threshold(n, n // 2, stats=stats, fused_available=True)
        assert base.cost_us is None and base.candidates_us == ()

        set_calibration(Calibration.identity(ALGORITHMS))
        calibrated = plan_threshold(n, n // 2, stats=stats, fused_available=True)
        assert calibrated.algorithm == base.algorithm, (cf, base, calibrated)
        words = dict(calibrated.candidates)
        assert calibrated.candidates_us, cf
        for backend, us in calibrated.candidates_us:
            assert us == pytest.approx(words[backend] / 1024.0), (cf, backend)
        # the µs list is sorted: a backend that touches fewer words is
        # never priced above one that touches more
        prices = [us for _, us in calibrated.candidates_us]
        assert prices == sorted(prices)
        assert calibrated.cost_us == pytest.approx(
            calibrated.cost / 1024.0
        ), (cf, calibrated)


def test_skewed_calibration_steers_selection(_no_calibration):
    """The point of calibration: when measurement says the words-best
    backend is slow on this device, the planner picks the measured-fast
    one (and says so in the rationale)."""
    from repro.core.calibration import Calibration, set_calibration
    from repro.query import BitmapIndex

    n, n_tiles = 8, 8
    bits = _bench_clean_fraction_bits(n, n_tiles, 0.0, seed=1)
    idx = BitmapIndex.from_dense(jnp.asarray(bits))
    stats = idx.store.member_stats(None)
    base = plan_threshold(n, n // 2, stats=stats, fused_available=True)
    others = [b for b, _ in base.candidates if b not in (base.algorithm, "tiled_fused")]
    assert others, base

    skew = Calibration.identity(ALGORITHMS)
    skew.us_per_kword[base.algorithm] = 1e6  # "measured" terrible
    set_calibration(skew)
    steered = plan_threshold(n, n // 2, stats=stats, fused_available=True)
    assert steered.algorithm != base.algorithm, steered
    assert steered.algorithm in others
    assert "calibrated" in steered.rationale


def test_calibration_cost_us_monotone_in_words():
    from repro.core.calibration import Calibration

    c = Calibration(device="x", us_per_kword={"ssum": 3.0}, dispatch_us={"ssum": 50.0})
    prices = [c.cost_us("ssum", w) for w in (0, 1024, 4096, 1 << 20)]
    assert prices == sorted(prices) and prices[0] == 50.0
    assert c.cost_us("nope", 1024) is None
    assert c.cost_us("ssum", None) is None


def test_calibration_observe_ewma_and_clamp():
    from repro.core.calibration import Calibration

    c = Calibration.identity(("ssum",))
    c.observe("ssum", 1024, 1.0)  # absurd 1s observation: clamped to 8x
    assert c.us_per_kword["ssum"] == pytest.approx(0.8 * 1.0 + 0.2 * 8.0)
    assert c.samples["ssum"] == 1
    # unknown backends are admitted at the observed rate
    c.observe("looped", 1024, 1e-6)
    assert c.us_per_kword["looped"] == pytest.approx(1.0)
    # junk observations are ignored
    before = dict(c.us_per_kword)
    c.observe("ssum", None, 1.0)
    c.observe("ssum", 0, 1.0)
    c.observe("ssum", 1024, 0.0)
    assert c.us_per_kword == before


def test_calibration_persist_roundtrip(tmp_path, _no_calibration):
    from repro.core.calibration import Calibration, get_calibration
    from repro.persist import load_calibration, save_calibration
    from repro.persist.calibration import ensure_calibration

    c = Calibration(device="identity", us_per_kword={"ssum": 2.5, "fused": 0.5},
                    dispatch_us={"fused": 40.0}, samples={"ssum": 3})
    target = save_calibration(c, tmp_path)
    assert target.name == "calibration.json"
    back = load_calibration(tmp_path)
    assert back is not None and back.to_obj() == c.to_obj()

    # device-mismatched constants are stale: refuse unless asked
    c2 = Calibration(device="some_tpu", us_per_kword={"ssum": 9.0})
    save_calibration(c2, tmp_path / "other")
    assert load_calibration(tmp_path / "other") is None
    loose = load_calibration(tmp_path / "other", allow_mismatch=True)
    assert loose is not None and loose.us_per_kword["ssum"] == 9.0

    # ensure_calibration: load-or-measure, installs as process-active
    got = ensure_calibration(tmp_path, repeats=1, n_words=256)
    assert got.to_obj() == c.to_obj()  # loaded, not re-measured
    assert get_calibration() is got


def test_plan_memo_invalidated_by_calibration_swap(_no_calibration):
    """Swapping calibration constants must not serve stale memoized plans:
    the memo key embeds the calibration generation."""
    from repro.core.calibration import Calibration, set_calibration
    from repro.query import BitmapIndex, clear_compiled_cache

    clear_compiled_cache()
    idx = BitmapIndex.from_dense(jnp.asarray(_mk(8, 300, 0.3, seed=4)[0]))
    q = Threshold(4)
    assert idx.explain(q).memo == "miss"
    assert idx.explain(q).memo == "hit"
    set_calibration(Calibration.identity(ALGORITHMS))
    fresh = idx.explain(q)
    assert fresh.memo == "miss", "stale pre-calibration plan served"
    assert fresh.cost_us is not None
    assert idx.explain(q).memo == "hit"
    clear_compiled_cache()


def test_topology_swap_resets_stale_constants(_no_calibration):
    """Constants recorded on another device topology must be dropped, not
    EWMA-blended: the observe clamp anchors every new sample to within 8x
    of the dead running value, so a swapped device would be mispriced
    forever (a 1e9 us/kword constant can never decay to ~1e3)."""
    from repro.core.calibration import Calibration, device_signature

    dead = Calibration(device="tpux8", us_per_kword={"ssum": 1e9},
                       samples={"ssum": 500})
    assert device_signature() != "tpux8" and dead.is_stale()
    dead.observe("ssum", 1024, 1e-3)  # 1000us for 1k words
    assert dead.device == device_signature() and not dead.is_stale()
    # re-admitted at the OBSERVED rate, not clamped around the dead value
    assert dead.us_per_kword["ssum"] == pytest.approx(1000.0)
    assert dead.samples["ssum"] == 1

    # portable calibrations are never stale: identity keeps its constants
    ident = Calibration.identity(("ssum",))
    assert not ident.is_stale("tpux8") and not ident.is_stale()
    ident.observe("ssum", 1024, 1e-6)
    assert ident.samples["ssum"] == 1 and ident.device == "identity"


def test_stale_active_calibration_reset_on_read(_no_calibration):
    """get_calibration() topology-checks the installed constants and bumps
    the plan-memo generation when it has to reset them -- memoized plans
    priced with dead constants must not be served."""
    from repro.core.calibration import (
        Calibration,
        calibration_generation,
        device_signature,
        get_calibration,
        set_calibration,
    )

    stale = Calibration(device="gpux64", us_per_kword={"ssum": 1e9})
    set_calibration(stale)
    gen = calibration_generation()
    active = get_calibration()
    assert active is stale
    assert active.device == device_signature() and not active.us_per_kword
    assert calibration_generation() == gen + 1
    # subsequent reads are quiet: no further resets or generation bumps
    assert get_calibration() is stale
    assert calibration_generation() == gen + 1


def test_load_calibration_adopts_legacy_device_stamp(tmp_path, _no_calibration):
    """Files written before signatures carried device counts stamped the
    bare backend name; loading must adopt the full signature so the
    staleness check doesn't immediately wipe the loaded constants."""
    import jax

    from repro.core.calibration import Calibration, device_signature
    from repro.persist import load_calibration, save_calibration

    legacy = Calibration(device=jax.default_backend(),
                         us_per_kword={"ssum": 2.0}, samples={"ssum": 4})
    save_calibration(legacy, tmp_path)
    back = load_calibration(tmp_path)
    assert back is not None
    assert back.device == device_signature() and not back.is_stale()
    assert back.us_per_kword == {"ssum": 2.0}

    current = Calibration(device=device_signature(), us_per_kword={"ssum": 1.0})
    save_calibration(current, tmp_path / "sig")
    back = load_calibration(tmp_path / "sig")
    assert back is not None and not back.is_stale()
