"""Every plan the planner can emit names a runnable executor (ISSUE fix:
the seed's planner returned wide_or/wide_and/rbmrg_block/dsk which
threshold() rejected)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bitmaps import pack, unpack
from repro.core.planner import plan_query, plan_threshold
from repro.core.threshold import ALGORITHMS, threshold
from repro.query import Interval, Threshold, execute


def _mk(n, r, density, seed=0):
    rng = np.random.default_rng(seed)
    bits = rng.random((n, r)) < density
    return bits, pack(jnp.asarray(bits))


# (n, t, planner kwargs, data density) covering every reachable branch
SCENARIOS = [
    (8, 1, {}, 0.3, "wide_or"),
    (8, 8, {}, 0.3, "wide_and"),
    (16, 2, {}, 0.3, "looped"),
    (16, 8, {"clean_fraction": 0.9}, 0.02, "rbmrg_block"),
    (16, 15, {"density": 1e-4, "on_device": False}, 0.005, "dsk"),
    (16, 8, {}, 0.3, "fused"),
    (16, 8, {"fused_available": False}, 0.3, "ssum"),
    (2500, 700, {}, 0.3, "scancount_streaming"),
]


@pytest.mark.parametrize("n,t,kw,density,expected_alg", SCENARIOS)
def test_every_reachable_plan_executes(n, t, kw, density, expected_alg):
    plan = plan_threshold(n, t, **kw)
    assert plan.algorithm == expected_alg, plan
    assert plan.algorithm in ALGORITHMS
    bits, bm = _mk(n, 300, density, seed=n * 31 + t)
    got = np.asarray(unpack(threshold(bm, t, plan.algorithm), 300))
    np.testing.assert_array_equal(got, bits.sum(0) >= t, err_msg=plan.algorithm)


def test_all_algorithm_names_are_executable():
    """threshold() accepts every name in ALGORITHMS (no planner orphan)."""
    bits, bm = _mk(6, 200, 0.3, seed=5)
    counts = bits.sum(0)
    for alg in ALGORITHMS:
        t = {"wide_or": 1, "wide_and": 6}.get(alg, 3)
        got = np.asarray(unpack(threshold(bm, t, alg), 200))
        np.testing.assert_array_equal(got, counts >= t, err_msg=alg)


def test_wide_reductions_validate_t():
    _, bm = _mk(6, 100, 0.5)
    with pytest.raises(ValueError):
        threshold(bm, 3, "wide_or")
    with pytest.raises(ValueError):
        threshold(bm, 3, "wide_and")


def test_plan_query_names_resolve():
    """plan_query outputs execute directly through the query layer."""
    bits, bm = _mk(10, 300, 0.3, seed=9)
    counts = bits.sum(0)
    cases = [
        (Threshold(1), counts >= 1),
        (Threshold(5), counts >= 5),
        (Interval(2, 6), (counts >= 2) & (counts <= 6)),
        (Interval(2, 6) & ~Threshold(8), (counts >= 2) & (counts <= 6) & ~(counts >= 8)),
    ]
    for q, expect in cases:
        plan = plan_query(q, 10)
        got = np.asarray(unpack(execute(bm, q), 300))
        np.testing.assert_array_equal(got, expect, err_msg=f"{q} via {plan.algorithm}")
