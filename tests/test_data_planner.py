"""Data pipeline determinism + paper dataset generators + planner rules."""
import numpy as np

from repro.configs import get_config
from repro.core.bitmaps import cardinality
from repro.core.planner import plan_threshold
from repro.data import DataConfig, arch_batch, lm_batch
from repro.data.paper_datasets import (
    clustered_set,
    similarity_query,
    synthetic_dataset,
    uniform_set,
)


def test_lm_batch_deterministic_per_step():
    dc = DataConfig(vocab=1000, batch=4, seq=32, seed=7)
    a = lm_batch(dc, 5)
    b = lm_batch(dc, 5)
    c = lm_batch(dc, 6)
    assert np.array_equal(a["tokens"], b["tokens"])  # restart replays
    assert not np.array_equal(a["tokens"], c["tokens"])


def test_lm_batch_host_sharding():
    full = DataConfig(vocab=100, batch=8, seq=16, seed=1)
    h0 = DataConfig(vocab=100, batch=8, seq=16, seed=1, n_hosts=2, host_id=0)
    assert lm_batch(h0, 0)["tokens"].shape[0] == 4
    assert lm_batch(full, 0)["tokens"].shape[0] == 8


def test_arch_batch_shapes():
    for arch in ("internvl2-26b", "hubert-xlarge", "qwen3-1.7b"):
        cfg = get_config(arch, reduced=True)
        b = arch_batch(cfg, 2, 32, "train")
        assert b["labels"].shape == (2, 32)
        if cfg.frontend == "vision":
            assert b["tokens"].shape[1] == 32 - cfg.frontend_tokens
            assert float(b["mask"][:, : cfg.frontend_tokens].sum()) == 0.0
        if cfg.frontend == "audio":
            assert b["features"].shape == (2, 32, cfg.frontend_dim)


def test_synthetic_dataset_paper_5_3():
    packed, r, lists = synthetic_dataset("uniform", "dense", n_bitmaps=8, card=500, seed=1111)
    assert r == 1500
    assert all(len(l) == 500 for l in lists)
    assert np.asarray(cardinality(packed)).tolist() == [500] * 8
    packed_c, r_c, lists_c = synthetic_dataset("clustered", "dense", n_bitmaps=4, card=500)
    # clustered data has far fewer runs than uniform at equal cardinality
    from repro.core.blockrle import runcount

    assert runcount(packed_c) < runcount(packed[:4])


def test_similarity_query_selects_containing_bitmaps():
    rng = np.random.default_rng(0)
    lists = [np.sort(rng.choice(1000, 100, replace=False)) for _ in range(20)]
    sel, rid = similarity_query(lists, n=5, rid=int(lists[3][0]))
    for i in set(sel):
        l = lists[i]
        j = np.searchsorted(l, rid)
        # either contains rid, or was a replicated filler when < n contain it
    assert len(sel) == 5


def test_planner_rules():
    assert plan_threshold(8, 1).algorithm == "wide_or"
    assert plan_threshold(8, 8).algorithm == "wide_and"
    assert plan_threshold(64, 2).algorithm == "looped"
    assert plan_threshold(64, 30, clean_fraction=0.9).algorithm == "rbmrg_block"
    assert plan_threshold(64, 30).algorithm == "fused"
    assert plan_threshold(64, 62, density=1e-4, on_device=False).algorithm == "dsk"
