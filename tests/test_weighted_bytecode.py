"""Weighted-threshold decomposition + byte-code compilation layers."""
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core.bitmaps import pack, unpack
from repro.core.bytecode import Interpreter, compile_circuit
from repro.core.circuits import build_threshold_circuit
from repro.core.threshold import weighted_threshold
from repro.core.weighted import (
    build_weighted_threshold_circuit,
    decomposed_gate_cost,
    replication_gate_cost,
    weighted_threshold_decomposed,
)


@given(st.data())
@settings(max_examples=25, deadline=None)
def test_decomposed_matches_weighted_counts(data):
    n = data.draw(st.integers(2, 8))
    r = data.draw(st.integers(1, 120))
    weights = tuple(data.draw(st.integers(0, 37)) for _ in range(n))
    if sum(weights) == 0:
        weights = weights[:-1] + (1,)
    t = data.draw(st.integers(1, max(sum(weights), 1)))
    rng = np.random.default_rng(data.draw(st.integers(0, 2**31 - 1)))
    bits = rng.random((n, r)) < 0.4
    bm = pack(jnp.asarray(bits))
    got = np.asarray(unpack(weighted_threshold_decomposed(bm, weights, t), r))
    expect = (bits * np.array(weights)[:, None]).sum(0) >= t
    np.testing.assert_array_equal(got, expect)


def test_decomposed_matches_replication():
    rng = np.random.default_rng(0)
    bits = rng.random((5, 200)) < 0.3
    bm = pack(jnp.asarray(bits))
    weights = (3, 1, 4, 1, 5)
    for t in (2, 7, 14):
        a = np.asarray(weighted_threshold(bm, list(weights), t))
        b = np.asarray(weighted_threshold_decomposed(bm, weights, t))
        np.testing.assert_array_equal(a, b)


def test_decomposition_beats_replication_on_large_weights():
    weights = [997, 512, 613, 700, 801, 64, 900, 1000] * 4  # 32 inputs
    t = sum(weights) // 2
    rep = replication_gate_cost(weights, t)
    dec = decomposed_gate_cost(weights, t)
    assert dec * 20 < rep, (dec, rep)  # >20x smaller circuit


def test_bytecode_matches_direct_evaluation():
    rng = np.random.default_rng(1)
    for n, t in [(5, 2), (16, 9), (33, 20)]:
        circ = build_threshold_circuit(n, t, "ssum")
        bc = compile_circuit(circ)
        words = rng.integers(0, 2**32, (n, 40), dtype=np.uint32)
        got = Interpreter().run(bc, list(words))
        (expect,) = circ.evaluate([jnp.asarray(w) for w in words])
        np.testing.assert_array_equal(got, np.asarray(expect))
        # register allocation: far fewer registers than gates (paper Table 3
        # note: "space for o(N) bitmaps would suffice")
        assert bc.n_registers <= n + 8
        assert bc.peak_registers <= bc.n_registers + n


def test_bytecode_reclaims_registers():
    circ = build_threshold_circuit(64, 32, "ssum")
    bc = compile_circuit(circ)
    assert len(bc.instructions) == circ.gate_count()
    # ~5N gates but live set stays near N
    assert bc.n_registers < circ.gate_count() / 3
