"""Oracle property suite: every algorithm in ALGORITHMS (and the query API)
against SCANCOUNT on randomized (N, T, n_words) grids, including the
degenerate T=1 / T=N / T>N edges, plus weighted replication vs binary
decomposition equivalence.  Deterministic (seeded) -- no hypothesis needed."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bitmaps import pack, unpack
from repro.core.threshold import ALGORITHMS, threshold, weighted_threshold
from repro.core.weighted import weighted_threshold_decomposed
from repro.query import BitmapIndex, Threshold

# (n, r, density); r values straddle word boundaries
GRID = [
    (2, 31, 0.5),
    (3, 64, 0.9),
    (5, 100, 0.05),
    (9, 257, 0.3),
    (17, 130, 0.5),
    (33, 96, 0.7),
]

# wide_or / wide_and only exist at the degenerate ends; sopckt blows up
# combinatorially and is capped to tiny (N, T) like the paper does
_GENERAL = tuple(a for a in ALGORITHMS if a not in ("wide_or", "wide_and", "sopckt"))


def _oracle_and_bm(n, r, density, seed):
    rng = np.random.default_rng(seed)
    bits = rng.random((n, r)) < density
    return bits.sum(0), pack(jnp.asarray(bits))


def _ts(n):
    """Thresholds including every degenerate edge."""
    return sorted({1, 2, (n + 1) // 2, n - 1, n, n + 1, n + 3})


@pytest.mark.parametrize("n,r,density", GRID)
def test_all_algorithms_match_scancount(n, r, density):
    counts, bm = _oracle_and_bm(n, r, density, seed=n * 7919 + r)
    for t in _ts(n):
        oracle = np.asarray(unpack(threshold(bm, t, "scancount"), r))
        np.testing.assert_array_equal(oracle, counts >= t, err_msg=f"scancount t={t}")
        for alg in _GENERAL:
            if alg == "scancount":
                continue
            got = np.asarray(unpack(threshold(bm, t, alg), r))
            np.testing.assert_array_equal(got, oracle, err_msg=f"{alg} t={t} n={n}")
        # degenerate ends exercise the wide reductions too
        if t == 1:
            got = np.asarray(unpack(threshold(bm, t, "wide_or"), r))
            np.testing.assert_array_equal(got, oracle, err_msg="wide_or")
        if t == n:
            got = np.asarray(unpack(threshold(bm, t, "wide_and"), r))
            np.testing.assert_array_equal(got, oracle, err_msg="wide_and")


def test_sopckt_small_against_oracle():
    counts, bm = _oracle_and_bm(5, 70, 0.5, seed=3)
    for t in (1, 2, 3, 5):
        got = np.asarray(unpack(threshold(bm, t, "sopckt"), 70))
        np.testing.assert_array_equal(got, counts >= t, err_msg=f"sopckt t={t}")


@pytest.mark.parametrize("n,r,density", GRID[:4])
def test_query_api_matches_scancount(n, r, density):
    rng = np.random.default_rng(n * 31 + r)
    bits = rng.random((n, r)) < density
    counts = bits.sum(0)
    idx = BitmapIndex.from_dense(jnp.asarray(bits))
    for t in _ts(n):
        got = np.asarray(unpack(idx.execute(Threshold(t)), r))
        np.testing.assert_array_equal(got, counts >= t, err_msg=f"query t={t}")


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_weighted_replication_vs_decomposition(seed):
    """Paper 2.3 replication == beyond-paper binary decomposition, and both
    == the weighted counting oracle."""
    rng = np.random.default_rng(seed)
    n, r = 6, 150
    bits = rng.random((n, r)) < 0.4
    bm = pack(jnp.asarray(bits))
    w = rng.integers(1, 7, n)
    wcounts = (bits * w[:, None]).sum(0)
    total = int(w.sum())
    for t in sorted({1, 3, total // 2, total, total + 1}):
        rep = np.asarray(unpack(weighted_threshold(bm, w.tolist(), t), r))
        dec = np.asarray(unpack(weighted_threshold_decomposed(bm, tuple(w), t), r))
        np.testing.assert_array_equal(rep, wcounts >= t, err_msg=f"replication t={t}")
        np.testing.assert_array_equal(dec, wcounts >= t, err_msg=f"decomposed t={t}")


def test_zero_weights_drop_inputs():
    rng = np.random.default_rng(4)
    bits = rng.random((4, 90)) < 0.5
    bm = pack(jnp.asarray(bits))
    w = (0, 2, 0, 3)
    wcounts = (bits * np.array(w)[:, None]).sum(0)
    got = np.asarray(unpack(weighted_threshold_decomposed(bm, w, 3), 90))
    np.testing.assert_array_equal(got, wcounts >= 3)
