"""Checkpointing + fault tolerance: roundtrip, atomicity, resume, monitors."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import CheckpointManager
from repro.configs import get_config
from repro.data import DataConfig, lm_batch
from repro.ft import Heartbeat, StragglerMonitor
from repro.train import OptConfig, TrainConfig, init_train_state, make_train_step

CFG = get_config("qwen3-1.7b", reduced=True)


def _tree_equal(a, b):
    return all(
        jax.tree.leaves(jax.tree.map(lambda x, y: bool(jnp.array_equal(x, y)), a, b))
    )


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    state = init_train_state(CFG, jax.random.PRNGKey(0))
    mgr.save(7, state, extra={"note": "x"})
    assert mgr.all_steps() == [7]
    restored = mgr.restore(7, state)
    assert _tree_equal(state, restored)
    assert mgr.manifest(7)["extra"]["note"] == "x"


def test_atomic_publish_no_tmp_visible(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    state = {"a": jnp.arange(4)}
    mgr.save(1, state)
    entries = os.listdir(tmp_path)
    assert "step_00000001" in entries
    assert not any(e.endswith(".tmp") for e in entries)


def test_retention_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=False)
    state = {"a": jnp.arange(3)}
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
    assert mgr.all_steps() == [3, 4]


def test_async_save_then_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    state = init_train_state(CFG, jax.random.PRNGKey(1))
    mgr.save(3, state)
    mgr.wait()
    assert mgr.latest_step() == 3


def test_crash_resume_replays_identically(tmp_path):
    """Train 6 steps straight vs train 3 + 'crash' + resume 3: identical
    final params (determinism of ckpt + data stream)."""
    tc = TrainConfig(opt=OptConfig(peak_lr=1e-3, warmup_steps=2, total_steps=10))
    dc = DataConfig(vocab=CFG.vocab, batch=4, seq=32)
    step = jax.jit(make_train_step(CFG, tc))

    s = init_train_state(CFG, jax.random.PRNGKey(4))
    for i in range(6):
        s, _ = step(s, lm_batch(dc, i))
    straight = s

    mgr = CheckpointManager(str(tmp_path), async_save=False)
    s = init_train_state(CFG, jax.random.PRNGKey(4))
    for i in range(3):
        s, _ = step(s, lm_batch(dc, i))
    mgr.save(3, s)
    del s  # crash
    s2 = mgr.restore(3, init_train_state(CFG, jax.random.PRNGKey(4)))
    for i in range(3, 6):
        s2, _ = step(s2, lm_batch(dc, i))
    d = jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), straight["params"], s2["params"]
    )
    assert max(jax.tree.leaves(d)) < 1e-6


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(threshold=2.0, warmup=3)
    events = [mon.record(i, 0.1) for i in range(8)]
    assert all(e is None for e in events)
    ev = mon.record(8, 0.5)
    assert ev is not None and ev.ratio > 2.0
    # outlier must not drag the EWMA up
    assert mon.ewma < 0.12
    assert mon.record(9, 0.1) is None


def test_heartbeat_dead_host_detection():
    hb = Heartbeat(hosts=4, timeout=10.0)
    now = 1000.0
    for h in range(4):
        hb.beat(h, now)
    hb.beat(0, now + 20)
    hb.beat(1, now + 20)
    hb.beat(2, now + 20)
    assert hb.dead_hosts(now + 21) == [3]
    assert hb.surviving_shards(now + 21) == [0, 1, 2]


def test_preemption_handler_flag():
    from repro.ft import PreemptionHandler

    h = PreemptionHandler()
    assert not h.should_stop
    h.should_stop = True  # simulate signal path
    assert h.should_stop
