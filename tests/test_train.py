"""Training substrate: convergence, microbatching, optimizer, schedule."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import DataConfig, lm_batch
from repro.train import (
    OptConfig,
    TrainConfig,
    init_train_state,
    make_train_step,
    schedule,
)

CFG = get_config("qwen3-1.7b", reduced=True)


def test_loss_decreases():
    tc = TrainConfig(opt=OptConfig(peak_lr=3e-3, warmup_steps=5, total_steps=100))
    state = init_train_state(CFG, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(CFG, tc), donate_argnums=0)
    dc = DataConfig(vocab=CFG.vocab, batch=8, seq=64)
    losses = []
    for i in range(15):
        state, m = step(state, lm_batch(dc, i))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


def test_microbatch_accumulation_matches_full_batch():
    tc1 = TrainConfig(opt=OptConfig(peak_lr=1e-3), microbatches=1)
    tc4 = TrainConfig(opt=OptConfig(peak_lr=1e-3), microbatches=4)
    dc = DataConfig(vocab=CFG.vocab, batch=8, seq=32)
    batch = lm_batch(dc, 0)
    s1 = init_train_state(CFG, jax.random.PRNGKey(1))
    s4 = init_train_state(CFG, jax.random.PRNGKey(1))
    s1, m1 = jax.jit(make_train_step(CFG, tc1))(s1, batch)
    s4, m4 = jax.jit(make_train_step(CFG, tc4))(s4, batch)
    # microbatched mean loss == full-batch loss; grads may differ slightly
    # only through fp accumulation order
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-3
    d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), s1["params"], s4["params"])
    assert max(jax.tree.leaves(d)) < 5e-3


def test_remat_matches_no_remat():
    dc = DataConfig(vocab=CFG.vocab, batch=4, seq=32)
    batch = lm_batch(dc, 0)
    outs = []
    for remat in (False, True):
        tc = TrainConfig(opt=OptConfig(peak_lr=1e-3), remat=remat)
        s = init_train_state(CFG, jax.random.PRNGKey(2))
        s, m = jax.jit(make_train_step(CFG, tc))(s, batch)
        outs.append((float(m["loss"]), s))
    assert abs(outs[0][0] - outs[1][0]) < 1e-5
    d = jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), outs[0][1]["params"], outs[1][1]["params"]
    )
    assert max(jax.tree.leaves(d)) < 1e-4


def test_lr_schedule_shape():
    oc = OptConfig(peak_lr=1e-3, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    lrs = [float(schedule(oc, jnp.int32(s))) for s in (0, 5, 10, 50, 100)]
    assert lrs[0] == 0.0
    assert abs(lrs[1] - 5e-4) < 1e-9  # linear warmup
    assert abs(lrs[2] - 1e-3) < 1e-9  # peak
    assert lrs[3] < lrs[2]
    assert abs(lrs[4] - 1e-4) < 1e-6  # min ratio


def test_grad_clipping_bounds_update():
    oc = OptConfig(peak_lr=1.0, warmup_steps=0, total_steps=10, clip_norm=1e-6,
                   weight_decay=0.0)
    tc = TrainConfig(opt=oc)
    dc = DataConfig(vocab=CFG.vocab, batch=4, seq=32)
    s = init_train_state(CFG, jax.random.PRNGKey(3))
    before = jax.tree.map(lambda x: x.copy(), s["params"])
    s, m = jax.jit(make_train_step(CFG, tc))(s, lm_batch(dc, 0))
    assert float(m["grad_norm"]) > 1e-3  # raw grads are not tiny
    # but clipped update magnitude stays bounded by ~lr * ~clip/eps-ish scale
    d = max(
        jax.tree.leaves(
            jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), s["params"], before)
        )
    )
    assert d < 2.0
