"""Distributed substrate tests.

These need multiple XLA devices; the device count is fixed at first jax
init, so each test runs a subprocess with XLA_FLAGS set to 8 host devices.
"""
import os
import subprocess
import sys

import jax
import pytest

pytestmark = pytest.mark.skipif(
    not hasattr(jax, "shard_map"),
    reason="substrate tests use the jax>=0.5 top-level shard_map API",
)

ENV = {**os.environ, "XLA_FLAGS": "--xla_force_host_platform_device_count=8",
       "PYTHONPATH": os.path.join(os.path.dirname(__file__), "..", "src")}


def _run(script: str):
    res = subprocess.run(
        [sys.executable, "-c", script], env=ENV, capture_output=True, text=True, timeout=600
    )
    assert res.returncode == 0, f"STDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr[-3000:]}"
    return res.stdout


def test_sharded_train_step_matches_single_device():
    _run(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.data import DataConfig, lm_batch
from repro.dist.context import ShardingRules, use_rules
from repro.launch.mesh import make_host_mesh
from repro.launch.sharding import batch_shardings, state_shardings
from repro.train import OptConfig, TrainConfig, init_train_state, make_train_step

cfg = get_config("qwen3-1.7b", reduced=True)
tc = TrainConfig(opt=OptConfig(peak_lr=1e-3))
dc = DataConfig(vocab=cfg.vocab, batch=8, seq=32)
batch = lm_batch(dc, 0)

# single-device reference
s0 = init_train_state(cfg, jax.random.PRNGKey(0))
s_ref, m_ref = jax.jit(make_train_step(cfg, tc))(s0, batch)

# sharded: 4-way data x 2-way model
mesh = make_host_mesh(data=4, model=2)
rules = ShardingRules(mesh, batch_axes=("data",))
with use_rules(rules), mesh:
    s1 = init_train_state(cfg, jax.random.PRNGKey(0))
    sh = state_shardings(s1, mesh, cfg)
    s1 = jax.tree.map(jax.device_put, s1, sh)
    step = jax.jit(make_train_step(cfg, tc),
                   in_shardings=(sh, batch_shardings(batch, mesh, 8)))
    s_sh, m_sh = step(s1, batch)
assert abs(float(m_ref["loss"]) - float(m_sh["loss"])) < 1e-3, (m_ref, m_sh)
d = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()), s_ref["params"], s_sh["params"])
assert max(jax.tree.leaves(d)) < 5e-3, max(jax.tree.leaves(d))
print("sharded == single-device OK")
"""
    )


def test_moe_shard_map_matches_local():
    _run(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_config
from repro.data import arch_batch
from repro.dist.context import ShardingRules, use_rules
from repro.launch.mesh import make_host_mesh
from repro.models import forward, init_params

import dataclasses
cfg = dataclasses.replace(get_config("granite-moe-1b-a400m", reduced=True),
                          capacity_factor=8.0)
params = init_params(cfg, jax.random.PRNGKey(0))
batch = arch_batch(cfg, 4, 32, "train", seed=0)
h_local, _, aux_local = forward(params, cfg, batch)

mesh = make_host_mesh(data=4, model=2)
rules = ShardingRules(mesh, batch_axes=("data",))
with use_rules(rules), mesh:
    h_dist, _, aux_dist = jax.jit(lambda p, b: forward(p, cfg, b))(params, batch)
np.testing.assert_allclose(np.asarray(h_local), np.asarray(h_dist), atol=3e-3, rtol=1e-2)
print("moe shard_map == local OK", float(aux_local), float(aux_dist))
"""
    )


def test_int8_ring_allreduce():
    _run(
        """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.dist.compression import _ring_allreduce_int8, collective_bytes_saved
from repro.launch.mesh import make_host_mesh

mesh = make_host_mesh(data=8, model=1)
xs = jnp.asarray(np.random.default_rng(0).normal(size=(8, 257)).astype(np.float32))
f = jax.jit(jax.shard_map(lambda x: _ring_allreduce_int8(x, "data", 8), mesh=mesh,
            in_specs=P("data", None), out_specs=P("data", None), check_vma=False))
out = np.asarray(f(xs))
expect = np.asarray(xs.sum(0))
rel = np.abs(out - expect[None]).max() / np.abs(expect).max()
assert rel < 0.05, rel
hlo = f.lower(xs).compile().as_text()
assert "s8" in hlo and "collective-permute" in hlo
acct = collective_bytes_saved(1_000_000, 8)
assert acct["fp32_psum_bytes"] / acct["int8_ring_bytes"] == 4.0
print("int8 ring OK rel_err", rel)
"""
    )


def test_error_feedback_converges():
    _run(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.dist.compression import ErrorFeedback, quantize_int8, dequantize_int8

# lossy reduce with EF: mean of quantised grads must track the true mean
ef = ErrorFeedback()
rng = np.random.default_rng(0)
true = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
acc_err = []
for step in range(50):
    g = {"w": true + 0.01 * jnp.asarray(rng.normal(size=(64,)).astype(np.float32))}
    red = ef.apply(g, lambda t: jax.tree.map(lambda x: dequantize_int8(*quantize_int8(x)), t))
    acc_err.append(float(jnp.abs(red["w"] - g["w"]).mean()))
# with EF the *accumulated* bias stays bounded (errors don't compound)
assert np.mean(acc_err[-10:]) < 0.05, acc_err[-5:]
print("error feedback OK")
"""
    )


def test_pipeline_parallel_matches_sequential():
    _run(
        """
import jax, jax.numpy as jnp, numpy as np
from repro.dist.pipeline import pipeline_forward
from repro.launch.mesh import make_host_mesh

mesh = make_host_mesh(data=1, model=1)
import jax.sharding
mesh = jax.make_mesh((8,), ("pod",), axis_types=(jax.sharding.AxisType.Auto,))

def stage_fn(p, x):
    return jnp.tanh(x @ p["w"])

rng = np.random.default_rng(0)
S = 8  # stages
stage_params = {"w": jnp.asarray(rng.normal(size=(S, 16, 16)).astype(np.float32) / 4)}
x = jnp.asarray(rng.normal(size=(4, 2, 16)).astype(np.float32))  # 4 microbatches

out = pipeline_forward(stage_fn, x, stage_params, mesh, axis_name="pod")
# sequential reference
ref = x
for s in range(S):
    ref = stage_fn({"w": stage_params["w"][s]}, ref)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-5)
print("pipeline == sequential OK")
"""
    )


def test_elastic_checkpoint_reshard():
    _run(
        """
import jax, jax.numpy as jnp, tempfile
from repro.ckpt import CheckpointManager
from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.launch.sharding import state_shardings
from repro.train import init_train_state

cfg = get_config("qwen3-1.7b", reduced=True)
state = init_train_state(cfg, jax.random.PRNGKey(0))
with tempfile.TemporaryDirectory() as d:
    mesh_a = make_host_mesh(data=8, model=1)
    sh_a = state_shardings(state, mesh_a, cfg)
    state_a = jax.tree.map(jax.device_put, state, sh_a)
    mgr = CheckpointManager(d, async_save=False)
    mgr.save(1, state_a)
    # restore onto a DIFFERENT mesh (elastic rescale 8x1 -> 2x4)
    mesh_b = make_host_mesh(data=2, model=4)
    sh_b = state_shardings(state, mesh_b, cfg)
    state_b = mgr.restore(1, state, sh_b)
    ok = jax.tree.map(lambda a, b: bool(jnp.array_equal(a, b)), state_a, state_b)
    assert all(jax.tree.leaves(ok))
print("elastic reshard OK")
"""
    )
