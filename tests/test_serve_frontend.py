"""Serving front-end: cache tier, dedup fan-out, admission, batching.

The acceptance bar for ``repro.serve.QueryServer``:

  * everything served is bit-identical to direct ``BitmapIndex.execute``
    (oracle), cached or not, on every backend;
  * a streaming mutation invalidates exactly the cache entries reading a
    touched column -- and a post-mutation resubmit observes the NEW bits
    (the stale-read regression);
  * identical in-flight queries run once and fan out to every waiter;
  * past ``max_pending`` distinct queries, ``submit`` sheds with
    :class:`Overloaded`;
  * plans come through the per-store memo (hit/miss counters move, and
    ``clear_compiled_cache`` clears it).
"""
from __future__ import annotations

import threading

import numpy as np
import pytest

from repro.core.threshold import ALGORITHMS
from repro.query import (
    And,
    AndNot,
    BitmapIndex,
    Col,
    Interval,
    Not,
    Threshold,
    clear_compiled_cache,
    plan_memo_info,
)
from repro.serve import Overloaded, QueryServer, shape_bucket
from repro.stream import StreamingIndex


def _bits(n=8, r=512, seed=0, density=0.3):
    rng = np.random.default_rng(seed)
    return rng.random((n, r)) < density


def _names(n):
    return [f"s{i}" for i in range(n)]


@pytest.fixture(autouse=True)
def _fresh_caches():
    clear_compiled_cache()
    yield
    clear_compiled_cache()


# -- oracle: served == executed, across the cache tier and every backend ---

def test_served_bit_identical_to_execute():
    bits = _bits()
    idx = BitmapIndex.from_dense(bits, names=_names(8))
    server = QueryServer(idx, window=0)
    pool = [
        Interval(2, 6),
        Threshold(3, over=("s0", "s1", "s2", "s4")),
        And(Threshold(2, over=("s1", "s3", "s5")), Not(Col("s7"))),
        AndNot(Interval(1, 2, over=("s2", "s6")), Col("s0")),
    ]
    futs = [server.submit(q) for q in pool]
    while server.pump():
        pass
    for q, f in zip(pool, futs):
        np.testing.assert_array_equal(
            np.asarray(f.result(0)), np.asarray(idx.execute(q))
        )


@pytest.mark.parametrize("alg", ALGORITHMS)
def test_cached_result_bit_identical_per_backend(alg):
    """First serve executes; the resubmit is a cache hit -- both must equal
    direct execution on the same backend (bare threshold: every backend
    accepts it)."""
    bits = _bits(n=6, r=256, seed=3)
    idx = BitmapIndex.from_dense(bits, names=_names(6))
    server = QueryServer(idx, window=0)
    t = {"wide_or": 1, "wide_and": 6}.get(alg, 3)  # degenerate-only backends
    q = Threshold(t, over=tuple(_names(6)))
    ref = np.asarray(idx.execute(q, backend=alg))

    cold = server.submit(q, backend=alg)
    assert server.pump() == 1
    np.testing.assert_array_equal(np.asarray(cold.result(0)), ref)

    warm = server.submit(q, backend=alg)
    assert warm.done(), "second submit should complete from the result cache"
    np.testing.assert_array_equal(np.asarray(warm.result(0)), ref)
    info = server.info()
    assert info["cache_hits"] == 1 and info["executed"] == 1


def test_semantic_cache_key_ignores_member_order():
    idx = BitmapIndex.from_dense(_bits(), names=_names(8))
    server = QueryServer(idx, window=0)
    a = server.submit(Threshold(2, over=("s1", "s3", "s5")))
    server.pump()
    b = server.submit(Threshold(2, over=("s5", "s1", "s3")))
    assert b.done() and server.info()["cache_hits"] == 1
    np.testing.assert_array_equal(np.asarray(a.result(0)), np.asarray(b.result(0)))


# -- streaming invalidation: exact, and no stale reads ---------------------

def test_invalidation_touches_exactly_mutated_columns():
    bits = _bits()
    stream = StreamingIndex.from_dense(bits, names=_names(8))
    server = QueryServer(stream, window=0)
    q_a = Threshold(1, over=("s0", "s1"))
    q_b = Threshold(1, over=("s6", "s7"))
    server.serve_many([q_a, q_b])
    assert server.info()["cache_entries"] == 2

    stream.set_bits("s0", [5])  # touches q_a's support only
    info = server.info()
    assert info["invalidations"] == 1
    assert info["cache_entries"] == 1

    hit = server.submit(q_b)  # untouched support: still a hit
    assert hit.done() and server.info()["cache_hits"] == 1


def test_no_stale_reads_after_update():
    """The regression the version vector exists for: mutate, resubmit, and
    the served bits must be the NEW bits."""
    bits = _bits(n=4, r=256, seed=7, density=0.0)  # all-zero columns
    stream = StreamingIndex.from_dense(bits, names=_names(4))
    server = QueryServer(stream, window=0)
    q = Threshold(1, over=("s0", "s1"))
    before = server.serve_many([q])[0]
    assert not np.asarray(before).any()

    stream.set_bits("s0", [0, 33, 77])
    after = server.serve_many([q])[0]
    np.testing.assert_array_equal(
        np.asarray(after), np.asarray(stream.index().execute(q))
    )
    assert np.asarray(after).any(), "served result must observe the mutation"


def test_view_columns_cascade_invalidation():
    bits = _bits()
    stream = StreamingIndex.from_dense(bits, names=_names(8))
    stream.materialize("hot", Threshold(2, over=("s0", "s1", "s2")))
    server = QueryServer(stream, window=0)
    served = server.serve_many([Col("hot")])[0]
    assert server.info()["cache_entries"] == 1
    np.testing.assert_array_equal(
        np.asarray(served), np.asarray(stream.index().execute(Col("hot")))
    )

    stream.set_bits("s1", [3])  # an INPUT of the view, not the view itself
    assert server.info()["cache_entries"] == 0, "view entry must cascade out"
    fresh = server.serve_many([Col("hot")])[0]
    np.testing.assert_array_equal(
        np.asarray(fresh), np.asarray(stream.index().execute(Col("hot")))
    )


# -- dedup: one execution, many waiters ------------------------------------

def test_dedup_fans_out_single_execution():
    idx = BitmapIndex.from_dense(_bits(), names=_names(8))
    server = QueryServer(idx, window=0)
    q = Interval(2, 5)
    futs = [server.submit(q) for _ in range(5)]
    # member order must not defeat dedup either
    futs.append(server.submit(Interval(2, 5, over=tuple(reversed(_names(8))))))
    server.pump()
    info = server.info()
    assert info["executed"] == 1 and info["batches"] == 1
    assert info["dedup_hits"] == 5
    assert info["served"] == 6
    ref = np.asarray(idx.execute(q))
    for f in futs:
        np.testing.assert_array_equal(np.asarray(f.result(0)), ref)


# -- admission control ------------------------------------------------------

def test_overload_sheds_with_explicit_signal():
    idx = BitmapIndex.from_dense(_bits(), names=_names(8))
    server = QueryServer(idx, window=0, max_pending=2, cache_entries=0)
    server.submit(Threshold(1, over=("s0",)))
    server.submit(Threshold(1, over=("s1",)))
    with pytest.raises(Overloaded):
        server.submit(Threshold(1, over=("s2",)))
    # duplicates of an admitted query are always accepted
    server.submit(Threshold(1, over=("s0",)))
    info = server.info()
    assert info["shed"] == 1 and info["dedup_hits"] == 1
    while server.pump():
        pass


# -- micro-batching ----------------------------------------------------------

def test_same_shape_queries_share_one_batch():
    idx = BitmapIndex.from_dense(_bits(), names=_names(8))
    server = QueryServer(idx, window=0)
    qs = [Threshold(2, over=("s0", "s1", "s2")),
          Threshold(3, over=("s3", "s5", "s7")),
          Threshold(1, over=("s4", "s6", "s0"))]
    assert len({shape_bucket(q) for q in qs}) == 1
    outs = server.serve_many(qs)
    info = server.info()
    assert info["batches"] == 1 and info["batch_size_hist"] == {3: 1}
    for q, out in zip(qs, outs):
        np.testing.assert_array_equal(np.asarray(out), np.asarray(idx.execute(q)))


def test_shape_bucket_drops_names_keeps_arity():
    a = Threshold(2, over=("s0", "s1", "s2"))
    b = Threshold(5, over=("s3", "s4", "s5"))
    c = Threshold(2, over=("s0", "s1"))
    assert shape_bucket(a) == shape_bucket(b)
    assert shape_bucket(a) != shape_bucket(c)
    assert shape_bucket(And(a, Not(Col("s0")))) == shape_bucket(And(b, Not(Col("s7"))))


# -- batcher thread ----------------------------------------------------------

def test_threaded_mode_serves_concurrent_clients():
    bits = _bits(n=8, r=512, seed=11)
    idx = BitmapIndex.from_dense(bits, names=_names(8))
    pool = [Interval(2, 6), Threshold(2, over=("s0", "s3", "s6")),
            And(Col("s1"), Not(Col("s2")))]
    refs = [np.asarray(idx.execute(q)) for q in pool]
    with QueryServer(idx, window=0.001) as server:
        results: list = [None] * 4

        def client(ci):
            futs = [server.submit(pool[(ci + j) % len(pool)]) for j in range(9)]
            results[ci] = [np.asarray(f.result(30)) for f in futs]

        threads = [threading.Thread(target=client, args=(ci,)) for ci in range(4)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
    for ci, got in enumerate(results):
        for j, arr in enumerate(got):
            np.testing.assert_array_equal(arr, refs[(ci + j) % len(pool)])
    info = server.info()
    assert info["served"] == 36 and info["pending"] == 0


# -- plan memo ---------------------------------------------------------------

def test_plan_memo_hit_miss_and_clear():
    idx = BitmapIndex.from_dense(_bits(), names=_names(8))
    base = plan_memo_info()
    q = Threshold(3, over=("s0", "s2", "s4", "s6"))
    p0 = idx.explain(q)
    p1 = idx.explain(Threshold(3, over=("s6", "s4", "s2", "s0")))  # semantic twin
    assert p0.memo == "miss" and p1.memo == "hit"
    assert p1.algorithm == p0.algorithm
    info = plan_memo_info()
    assert info["misses"] >= base["misses"] + 1
    assert info["hits"] >= base["hits"] + 1
    clear_compiled_cache()
    cleared = plan_memo_info()
    assert cleared["entries"] == 0 and cleared["hits"] == 0 and cleared["misses"] == 0
    assert idx.explain(q).memo == "miss"


def test_server_info_reports_plan_memo_counters():
    idx = BitmapIndex.from_dense(_bits(), names=_names(8))
    server = QueryServer(idx, window=0)
    server.serve_many([Interval(2, 4)])
    assert "plan_memo" in server.info()
    assert set(server.info()["plan_memo"]) >= {"hits", "misses", "entries"}
