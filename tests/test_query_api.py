"""Unified query API: expressions, BitmapIndex execution, cache, batching."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.bitmaps import pack, unpack
from repro.query import (
    And,
    AndNot,
    BitmapIndex,
    Col,
    Exactly,
    Interval,
    Majority,
    Not,
    Or,
    Parity,
    Sym,
    Threshold,
    Weighted,
    clear_compiled_cache,
    compiled_cache_info,
    execute,
)

N, R = 14, 500


@pytest.fixture(scope="module")
def data():
    rng = np.random.default_rng(42)
    bits = rng.random((N, R)) < 0.3
    return bits, bits.sum(0)


@pytest.fixture()
def idx(data):
    bits, _ = data
    return BitmapIndex.from_dense(jnp.asarray(bits))


def got(idx, q, **kw):
    return np.asarray(unpack(idx.execute(q, **kw), idx.r))


def test_acceptance_composed_expression(idx, data):
    """The ISSUE's acceptance query, planner-routed, vs the oracle."""
    _, counts = data
    expect = (counts >= 2) & (counts <= 10) & ~(counts >= 12)
    q = And(Interval(2, 10), Not(Threshold(12)))
    np.testing.assert_array_equal(got(idx, q), expect)
    # operator sugar builds the same tree (and hits the same cache entry)
    assert (Interval(2, 10) & ~Threshold(12)).key() == q.key()


def test_every_leaf_matches_oracle(idx, data):
    bits, counts = data
    checks = [
        (Threshold(4), counts >= 4),
        (Interval(3, 7), (counts >= 3) & (counts <= 7)),
        (Exactly(5), counts == 5),
        (Parity(), counts % 2 == 1),
        (Majority(), counts >= (N + 1) // 2),
        (Sym(tuple(w % 3 == 1 for w in range(N + 1))), np.array([c % 3 == 1 for c in counts])),
        (Col("c3"), bits[3]),
    ]
    for q, expect in checks:
        np.testing.assert_array_equal(got(idx, q), expect, err_msg=repr(q))


def test_combinators_match_oracle(idx, data):
    bits, counts = data
    checks = [
        (And("c0", "c1", "c2"), bits[0] & bits[1] & bits[2]),
        (Or("c0", "c1", "c2"), bits[0] | bits[1] | bits[2]),
        (Not("c0"), ~bits[0]),
        (AndNot(Threshold(3), "c0"), (counts >= 3) & ~bits[0]),
        (Or(And("c0", "c1"), And("c2", "c3")), (bits[0] & bits[1]) | (bits[2] & bits[3])),
    ]
    for q, expect in checks:
        np.testing.assert_array_equal(got(idx, q), expect, err_msg=repr(q))


def test_weighted_matches_oracle(idx, data):
    bits, _ = data
    rng = np.random.default_rng(7)
    w = rng.integers(0, 9, N)
    wcounts = (bits * w[:, None]).sum(0)
    for t in (1, 5, int(w.sum()) // 2, int(w.sum())):
        q = Weighted(tuple(int(x) for x in w), t)
        np.testing.assert_array_equal(got(idx, q), wcounts >= t, err_msg=f"t={t}")


def test_over_subsets_and_subqueries(idx, data):
    bits, _ = data
    sub = bits[:5].sum(0)
    np.testing.assert_array_equal(
        got(idx, Threshold(2, over=tuple(f"c{i}" for i in range(5)))), sub >= 2
    )
    # a gate output votes inside an adder
    votes = bits[0].astype(int) + (bits[1] & bits[2]).astype(int) + bits[3].astype(int)
    q = Threshold(2, over=("c0", And("c1", "c2"), "c3"))
    np.testing.assert_array_equal(got(idx, q), votes >= 2)


def test_degenerate_thresholds(idx, data):
    _, counts = data
    assert got(idx, Threshold(0)).all()
    assert not got(idx, Threshold(N + 1)).any()
    np.testing.assert_array_equal(got(idx, Threshold(1)), counts >= 1)
    np.testing.assert_array_equal(got(idx, Threshold(N)), counts >= N)


def test_backend_override_fused_and_circuit(idx, data):
    _, counts = data
    expect = (counts >= 2) & (counts <= 10)
    for backend in ("circuit", "fused"):
        np.testing.assert_array_equal(
            got(idx, Interval(2, 10), backend=backend), expect, err_msg=backend
        )


def test_every_backend_agrees_on_threshold(idx, data):
    _, counts = data
    from repro.query import THRESHOLD_BACKENDS

    for backend in THRESHOLD_BACKENDS:
        if backend == "sopckt":
            continue  # combinatorial blow-up at N=14, T=7
        t = {"wide_or": 1, "wide_and": N}.get(backend, 7)
        np.testing.assert_array_equal(
            got(idx, Threshold(t), backend=backend), counts >= t, err_msg=backend
        )


def test_execute_many_batches_into_one_circuit(idx, data):
    _, counts = data
    clear_compiled_cache()
    qs = [Threshold(4), Interval(2, 10), Parity()]
    res = idx.execute_many(qs)
    np.testing.assert_array_equal(np.asarray(unpack(res[0], idx.r)), counts >= 4)
    np.testing.assert_array_equal(
        np.asarray(unpack(res[1], idx.r)), (counts >= 2) & (counts <= 10)
    )
    np.testing.assert_array_equal(np.asarray(unpack(res[2], idx.r)), counts % 2 == 1)
    info = compiled_cache_info()
    assert info["size"] == 1, info  # ONE multi-output compilation for 3 queries
    idx.execute_many(qs)
    assert compiled_cache_info()["hits"] >= 1


def test_compiled_cache_shared_across_indexes(data):
    bits, counts = data
    clear_compiled_cache()
    a = BitmapIndex.from_dense(jnp.asarray(bits))
    b = BitmapIndex.from_dense(jnp.asarray(~bits))
    q = And(Interval(2, 10), Not(Threshold(12)))
    ra = a.execute(q, backend="circuit")
    rb = b.execute(q, backend="circuit")
    info = compiled_cache_info()
    assert info["misses"] == 1 and info["hits"] == 1, info  # same schema, one compile
    inv = (~bits).sum(0)
    np.testing.assert_array_equal(
        np.asarray(unpack(rb, R)), (inv >= 2) & (inv <= 10) & ~(inv >= 12)
    )
    assert not np.array_equal(np.asarray(ra), np.asarray(rb))


def test_virtual_column_roundtrip(idx, data):
    bits, counts = data
    hot = idx.execute(Threshold(3))
    idx2 = idx.add_column("hot", hot)
    assert "hot" in idx2 and "hot" not in idx  # add_column returns a NEW index
    np.testing.assert_array_equal(
        got(idx2, And("hot", Not("c0"))), (counts >= 3) & ~bits[0]
    )
    with pytest.raises(ValueError):
        idx2.add_column("hot", hot)


def test_stale_index_reference_survives_add_column(idx, data):
    """A reference taken before add_column keeps planning/executing against
    its own schema (indexes are immutable TileStore wrappers)."""
    bits, counts = data
    stale = idx
    before_names = stale.names
    hot = idx.execute(Threshold(3))
    grown = idx.add_column("hot", hot)
    # the stale index: unchanged schema, still plans and executes correctly
    assert stale.names == before_names
    assert stale.n == N and grown.n == N + 1
    plan = stale.explain(Threshold(4))
    assert plan.algorithm in ("fused", "ssum", "tiled_fused", "looped")
    np.testing.assert_array_equal(got(stale, Threshold(4)), counts >= 4)
    with pytest.raises(KeyError):
        stale.execute(Col("hot"))
    # Threshold over ALL columns means different member sets per index
    np.testing.assert_array_equal(got(stale, Threshold(N)), counts >= N)
    np.testing.assert_array_equal(
        got(grown, Threshold(N + 1)), (counts + (counts >= 3)) >= N + 1
    )


def test_tail_masking_is_canonical(data):
    bits, counts = data
    idx = BitmapIndex.from_dense(jnp.asarray(bits))  # R=500 is not a word multiple
    out = np.asarray(idx.execute(Not(Threshold(1))))
    # bits past r must be zero even though NOT sets them pre-mask
    spill = (32 - R % 32) % 32
    assert spill > 0
    assert int(out[-1]) >> (R % 32) == 0
    np.testing.assert_array_equal(np.asarray(unpack(out, R)), counts == 0)


def test_explain_and_planner_routing(idx):
    assert idx.explain(Threshold(1)).algorithm == "wide_or"
    assert idx.explain(Threshold(N)).algorithm == "wide_and"
    # T=2 with member stats: the cost model prices looped at 2NT words,
    # above the fused dense sweep, and the planner honors its own ranking
    # (the scalar interface, without stats, still routes T<=3 to looped)
    p2 = idx.explain(Threshold(2))
    assert p2.algorithm == "fused"
    assert p2.cost == min(c for b, c in p2.candidates if b != "tiled_fused")
    assert idx.explain(And(Interval(2, 10), Not(Threshold(12)))).algorithm in (
        "circuit",
        "fused",
    )
    assert idx.explain(Col("c0")).algorithm == "column"


def test_functional_execute_matches_index(data):
    bits, counts = data
    bm = pack(jnp.asarray(bits))
    out = execute(bm, Interval(2, 10), r=R)
    np.testing.assert_array_equal(
        np.asarray(unpack(out, R)), (counts >= 2) & (counts <= 10)
    )


def test_errors(idx):
    with pytest.raises(KeyError):
        idx.execute(Col("nope"))
    with pytest.raises(KeyError):  # explain and execute agree on bad names
        idx.explain(Threshold(1, over=(Col("nope"),)))
    with pytest.raises(ValueError):
        idx.execute(And(Interval(2, 3), Parity()), backend="looped")
    with pytest.raises(ValueError):
        Sym((True, False)).truth(5)
    with pytest.raises(TypeError):
        And(Interval(1, 2), 3)
